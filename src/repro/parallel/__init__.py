"""Distribution: sharding rules, jet staged collectives, compression."""
from .compat import shard_map
from .sharding import ParallelCtx, single_device_ctx

__all__ = ["ParallelCtx", "shard_map", "single_device_ctx"]
