"""Distribution: sharding rules, jet staged collectives, compression."""
from .sharding import ParallelCtx, single_device_ctx

__all__ = ["ParallelCtx", "single_device_ctx"]
