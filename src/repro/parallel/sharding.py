"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP) for the production
mesh, MaxText-style.

Mesh axes:
  * ``pod``   — data parallel across pods (multi-pod mesh only)
  * ``data``  — data parallel + FSDP (ZeRO-3 parameter/optimizer sharding)
  * ``model`` — tensor parallel (heads/ff), expert parallel (MoE),
                sequence parallel (decode KV)

Logical axes used by the model code:

  batch        -> (pod, data)         activations
  seq          -> None (train) / model (decode KV: sequence parallel)
  embed        -> None                activation feature dim
  heads        -> model               attention q heads
  kv_heads     -> model-if-divisible  (else replicated; SP covers decode)
  mlp          -> model               FFN hidden
  expert       -> model               MoE expert dim
  vocab        -> model               embedding/unembedding vocab shards
  fsdp         -> data                the non-TP dim of every weight
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ParallelCtx:
    """Everything the model needs to know about distribution.

    ``mesh=None`` means single-device eager execution (unit tests); all
    constraint application becomes a no-op and MoE uses its dense reference
    path unless ``force_ep`` is set.
    """
    mesh: Optional[Mesh] = None
    data_axes: Tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    model_axis: str = "model"
    fsdp: bool = True                        # ZeRO-3 parameter sharding
    seq_parallel_decode: bool = True
    use_ep: bool = True                      # shard_map expert parallelism
    remat: str = "full"                      # full | dots | none
    moe_capacity_factor: Optional[float] = None
    # staged (jet) collectives toggle for the hillclimbed configs
    jet_collectives: bool = False
    jet_chunk_bytes: int = 256 << 10         # READ fragment size (paper)
    jet_window: int = 4                      # in-flight fragments
    # perf-variant flags (EXPERIMENTS.md §Perf). Defaults preserve the
    # paper-faithful baseline; the dry-run --variant switch flips them.
    bf16_weight_gather: bool = False         # cast params to compute dtype
    #                                          BEFORE FSDP gathers (2B wire)

    # ---- helpers -------------------------------------------------------- #
    @property
    def have_mesh(self) -> bool:
        return self.mesh is not None

    def axis_size(self, name: str) -> int:
        if not self.have_mesh:
            return 1
        return self.mesh.shape[name]

    @property
    def model_size(self) -> int:
        return self.axis_size(self.model_axis) if self.have_mesh else 1

    @property
    def dp_size(self) -> int:
        if not self.have_mesh:
            return 1
        s = 1
        for a in self.data_axes:
            s *= self.axis_size(a)
        return s

    def _div(self, n: int, axis: Optional[str]) -> bool:
        return axis is not None and self.have_mesh and \
            n % self.axis_size(axis) == 0

    # ---- PartitionSpecs -------------------------------------------------- #
    def batch_axes_for(self, b: int) -> Tuple[str, ...]:
        """Largest prefix-combination of data axes that divides batch ``b``
        (batch=1 long-context decode falls back to replication)."""
        if not self.have_mesh:
            return ()
        axes = []
        prod = 1
        for a in self.data_axes:
            prod *= self.axis_size(a)
            if b % prod == 0:
                axes.append(a)
            else:
                break
        return tuple(axes)

    def act_for(self, b: int, trailing: int = 2) -> P:
        """Activations [B, ..., D]: batch sharded where divisible."""
        ax = self.batch_axes_for(b)
        return P(ax if ax else None, *([None] * trailing))

    def spec_weight(self, shape: Tuple[int, ...], tp_dim: Optional[int],
                    fsdp_dim: Optional[int]) -> P:
        """Weight spec: TP on ``tp_dim`` over model axis, FSDP on
        ``fsdp_dim`` over data axis (when divisible)."""
        parts: list = [None] * len(shape)
        if tp_dim is not None and self._div(shape[tp_dim], self.model_axis):
            parts[tp_dim] = self.model_axis
        if (self.fsdp and fsdp_dim is not None and fsdp_dim != tp_dim
                and self._div(shape[fsdp_dim], "data")
                and "data" in (self.mesh.axis_names if self.have_mesh
                               else ())):
            parts[fsdp_dim] = "data"
        return P(*parts)

    def sharding(self, spec: P) -> Optional[NamedSharding]:
        if not self.have_mesh:
            return None
        return NamedSharding(self.mesh, spec)

    def constrain(self, x, spec: P):
        if not self.have_mesh:
            return x
        return jax.lax.with_sharding_constraint(x, self.sharding(spec))

    def kv_cache_spec(self, b: int, s: int) -> P:
        """Decode KV cache [B, S, Hkv, hd]: batch over data axes, sequence
        over the model axis (sequence parallelism — head-count agnostic)."""
        ax = self.batch_axes_for(b)
        bspec = ax if ax else None
        if self.seq_parallel_decode and self._div(s, self.model_axis):
            return P(bspec, self.model_axis, None, None)
        return P(bspec, None, None, None)


def single_device_ctx(**kw) -> ParallelCtx:
    return ParallelCtx(mesh=None, **kw)
