"""GPipe-style pipeline parallelism over the ``pod`` axis.

The multi-pod mesh's default use of ``pod`` is hierarchical data parallel
(DESIGN.md §4).  Alternatively the two pods can run as two pipeline
stages: each pod holds half the layer stack and microbatch activations
hand off over the cross-pod links via ``ppermute`` — a *far* smaller
cross-pod payload than DP's gradient all-reduce when layers are wide
(activations [B_micro, T, D] vs parameter-sized gradients).

This is the paper's large-message story applied across pods: the
inter-stage activation is the READ payload, the pipeline register is the
single-slot staging buffer, and the microbatch count bounds in-flight
work exactly like the in-flight-bytes window.

Autodiff: ``jax.grad`` differentiates straight through the schedule
(the transpose of ``ppermute`` is the reversed permutation), yielding
GPipe's synchronous backward.  Combine with ``jax.checkpoint`` around
``stage_fn`` for activation memory ~ O(microbatches) per stage.

Off by default; exercised by tests/multidev_driver.py.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def stage_index(axis_name: str) -> jnp.ndarray:
    return jax.lax.axis_index(axis_name)


def gpipe(stage_fn: Callable, x_micro: jnp.ndarray, axis_name: str,
          n_stages: int) -> jnp.ndarray:
    """Run ``stage_fn`` as an ``n_stages``-deep pipeline (inside shard_map).

    ``stage_fn(x) -> y`` applies THIS device's stage (it closes over the
    local stage parameters; activations keep one shape across stages).
    ``x_micro``: [M, ...] microbatches, replicated over ``axis_name``.
    Returns [M, ...] final-stage outputs, valid on the last stage's rank
    (use :func:`broadcast_from_last` to make them SPMD-uniform).
    """
    m = x_micro.shape[0]
    r = jax.lax.axis_index(axis_name)
    fwd = [(i, i + 1) for i in range(n_stages - 1)]

    def tick(reg, t):
        # stage 0 ingests microbatch t; others take the pipeline register
        mb = jax.lax.dynamic_index_in_dim(
            x_micro, jnp.clip(t, 0, m - 1), 0, keepdims=False)
        inp = jnp.where(r == 0, mb, reg)
        out = stage_fn(inp)
        # hand off to the next stage (last stage's send is dropped)
        reg_next = jax.lax.ppermute(out, axis_name, fwd)
        return reg_next, out

    _, emits = jax.lax.scan(tick, jnp.zeros_like(x_micro[0]),
                            jnp.arange(m + n_stages - 1))
    # the last stage emits microbatch k at tick k + (n_stages - 1)
    return emits[n_stages - 1:]


def broadcast_from_last(y: jnp.ndarray, axis_name: str,
                        n_stages: int) -> jnp.ndarray:
    """Make the final-stage output uniform across the pipeline axis."""
    r = jax.lax.axis_index(axis_name)
    mask = (r == n_stages - 1).astype(y.dtype)
    return jax.lax.psum(y * mask, axis_name)


def stack_stages(params_tree, n_stages: int):
    """Split a [L, ...]-stacked layer tree into [S, L/S, ...] stage
    stacks (shard dim 0 over the pipeline axis in shard_map in_specs)."""
    def split(leaf):
        l = leaf.shape[0]
        assert l % n_stages == 0, f"layers {l} % stages {n_stages} != 0"
        return leaf.reshape((n_stages, l // n_stages) + leaf.shape[1:])
    return jax.tree.map(split, params_tree)
