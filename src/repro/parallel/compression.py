"""Gradient compression for cross-pod sync: blockwise-int8 with error
feedback (EF21-style).  At 512+ chips the pod-to-pod links are the scarcest
resource; quantizing the inter-pod all-reduce to int8 cuts that traffic 4x
while error feedback keeps the optimizer unbiased in the long run.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_flat(x: jnp.ndarray) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise symmetric int8. Returns (q [N/B, B] int8, scale [N/B])."""
    flat, _ = _pad_flat(x)
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    shape, dtype=jnp.float32) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def quantize_int8_rowwise(x: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                   jnp.ndarray]:
    """Symmetric int8 with one scale per last-dim row — NO reshape.

    Keeping the parameter's shape (q) and its leading dims (scale) means
    the quantized optimizer state carries the parameter's sharding
    verbatim.  The flat ``[-1, 256]`` layout of :func:`quantize_int8`
    forced the SPMD partitioner into full-tensor rematerialization when a
    leaf was sharded on interior dims (e.g. llama4 expert weights
    [units, E, D, F] sharded (model, data)): ~483 GB of all-gather per
    tensor per step.  Row-wise scales eliminate that entirely.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)[..., None]
    q = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_rowwise(q: jnp.ndarray, scale: jnp.ndarray,
                            dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def compressed_psum(x: jnp.ndarray, err: jnp.ndarray, axis_name: str
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback compressed all-reduce (mean) over ``axis_name``.

    Returns (mean_of_dequantized, new_error).  The wire format IS int8:
    each rank all-gathers its int8 payload plus one f32 scale per last-dim
    row (~3.9x less traffic than an f32 all-reduce), then dequantizes and
    averages locally.  Error feedback keeps the long-run mean unbiased."""
    target = x + err
    q, scale = quantize_int8_rowwise(target)
    deq = dequantize_int8_rowwise(q, scale)
    new_err = target - deq
    # int8 on the wire
    q_all = jax.lax.all_gather(q, axis_name)          # [n, ...] int8
    s_all = jax.lax.all_gather(scale, axis_name)      # [n, ...] f32 rows
    deq_all = q_all.astype(jnp.float32) * s_all[..., None]
    return jnp.mean(deq_all, axis=0), new_err


def compression_ratio(shape) -> float:
    n = 1
    for s in shape:
        n *= s
    raw = n * 4
    comp = n * 1 + (-(-n // BLOCK)) * 4
    return raw / comp
