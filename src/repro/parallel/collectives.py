"""Jet staged collectives: RDCA applied across chips.

The paper's receive path keeps DRAM out of the datapath by having consumers
eat fragments straight from a small recycled cache pool.  The TPU analogue:
never materialize the all-gathered operand in HBM — pass shards around a ring
(`ppermute`) and have the MXU consume each shard the step it arrives, with at
most ``window`` fragments in flight (the paper's in-flight window).

Primitives (all used *inside* shard_map):
  * ring_allgather_matmul    — y = x @ W, W sharded on the contraction dim
  * ring_matmul_reduce_scatter — y_shard = (x @ W) reduce-scattered
  * windowed_allgather       — chunked all-gather with bounded in-flight bytes
  * srq_combine              — small-message combine for (o, lse) partials
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _ring_perm(axis_name: str, n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def ring_allgather_matmul(x: jnp.ndarray, w_shard: jnp.ndarray,
                          axis_name: str, axis_size: int,
                          frags: int = 1) -> jnp.ndarray:
    """y = x @ W_full where W is sharded on dim 0 (contraction) over
    ``axis_name``.  x: [..., D] (full D locally); w_shard: [D/m, F].

    Each ring step consumes the currently-held W shard against the matching
    x slice while the next shard travels — W_full never exists.  ``frags``
    further fragments each shard (the paper's <=256 KB READ fragments) to
    shrink the staging footprint; the Pallas staged_matmul plays the same
    game inside one chip.
    """
    m = axis_size
    r = jax.lax.axis_index(axis_name)
    dk = w_shard.shape[0]
    perm = _ring_perm(axis_name, m)

    def step(carry, i):
        y, w_cur = carry
        src = (r - i) % m                     # owner of w_cur after i hops
        xs = jax.lax.dynamic_slice_in_dim(x, src * dk, dk, axis=x.ndim - 1)
        if frags > 1:
            fk = dk // frags
            for f in range(frags):            # fragment-granular recycle
                y = y + jax.lax.dynamic_slice_in_dim(
                    xs, f * fk, fk, axis=x.ndim - 1) @ \
                    jax.lax.dynamic_slice_in_dim(w_cur, f * fk, fk, 0)
        else:
            y = y + xs @ w_cur
        w_nxt = jax.lax.ppermute(w_cur, axis_name, perm)
        return (y, w_nxt), None

    y0 = jnp.zeros(x.shape[:-1] + (w_shard.shape[1],),
                   jnp.promote_types(x.dtype, w_shard.dtype))
    (y, _), _ = jax.lax.scan(step, (y0, w_shard), jnp.arange(m))
    return y.astype(x.dtype)


def ring_reduce_scatter(y_partial: jnp.ndarray, axis_name: str,
                        axis_size: int) -> jnp.ndarray:
    """Ring reduce-scatter over the last axis.

    ``y_partial``: [..., F] per-rank partial sums (e.g. after a TP matmul
    whose contraction dim was sharded).  Returns the summed shard
    [..., F/m] owned by this rank.  The accumulating fragment rides the
    ring — the full summed [..., F] tensor never exists on any chip
    (memory out of the datapath).
    """
    m = axis_size
    r = jax.lax.axis_index(axis_name)
    perm = _ring_perm(axis_name, m)
    fk = y_partial.shape[-1] // m
    ax = y_partial.ndim - 1

    def contribution(c):
        return jax.lax.dynamic_slice_in_dim(y_partial, c * fk, fk, axis=ax)

    # chunk c starts at rank (c+1)%m and lands fully-summed at rank c
    acc = contribution((r - 1) % m).astype(jnp.float32)

    def step(acc, i):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        c = (r - 1 - (i + 1)) % m
        return acc + contribution(c), None

    acc, _ = jax.lax.scan(step, acc, jnp.arange(m - 1))
    return acc.astype(y_partial.dtype)


def windowed_allgather(x_shard: jnp.ndarray, axis_name: str, axis_size: int,
                       window: int = 4) -> jnp.ndarray:
    """Chunked ring all-gather with at most ``window`` fragments in flight.

    Functionally identical to lax.all_gather(tiled); structurally it is the
    receiver-driven READ: fragments arrive one ring hop per step and are
    written into the local assembly buffer.  ``window`` bounds in-flight
    fragments (XLA's scheduler sees ``window`` independent ppermute chains).
    """
    m = axis_size
    r = jax.lax.axis_index(axis_name)
    perm = _ring_perm(axis_name, m)
    n0 = x_shard.shape[0]
    out = jnp.zeros((m * n0,) + x_shard.shape[1:], x_shard.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, x_shard, r * n0, 0)
    # split each shard into `window` fragments; run `window` interleaved rings
    frag = max(1, n0 // window)
    bufs = [jax.lax.dynamic_slice_in_dim(x_shard, f * frag,
                                         min(frag, n0 - f * frag), 0)
            for f in range(min(window, -(-n0 // frag)))]

    for i in range(m - 1):
        new_bufs = []
        for f, b in enumerate(bufs):
            b = jax.lax.ppermute(b, axis_name, perm)
            src = (r - i - 1) % m
            out = jax.lax.dynamic_update_slice_in_dim(
                out, b, src * n0 + f * frag, 0)
            new_bufs.append(b)
        bufs = new_bufs
    return out


def srq_combine(o_part: jnp.ndarray, lse_part: jnp.ndarray,
                axis_name: str) -> jnp.ndarray:
    """Distributed-decode small-message combine: all-gather per-shard
    (o, lse) tuples (a few KB — the SRQ path) and merge with stable softmax
    weights.  o_part: [B,H,D]; lse_part: [B,H]."""
    o_all = jax.lax.all_gather(o_part, axis_name)        # [m,B,H,D]
    lse_all = jax.lax.all_gather(lse_part, axis_name)    # [m,B,H]
    m = lse_all.max(axis=0, keepdims=True)
    w = jnp.exp(lse_all - m)
    w = w / jnp.maximum(w.sum(axis=0, keepdims=True), 1e-30)
    return (o_all * w[..., None]).sum(axis=0)
