"""Version-portable ``shard_map``.

``jax.shard_map`` only exists from jax 0.6; earlier releases ship it as
``jax.experimental.shard_map.shard_map`` with a slightly different keyword
surface (``check_rep`` instead of ``check_vma``, and an ``auto`` set that is
the complement of the modern ``axis_names``).  Every call site in this repo
goes through this wrapper so the codebase runs on both API generations.
"""
from __future__ import annotations

from typing import Optional, Set

import jax

try:  # legacy location (jax < 0.6)
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
except ImportError:  # pragma: no cover — modern jax removed the alias
    _legacy_shard_map = None

_HAS_NATIVE = hasattr(jax, "shard_map")

# Partial-manual shard_map (axis_names ⊂ mesh axes) is unusable on legacy
# jax: a lax.scan whose body carries a with_sharding_constraint on an auto
# axis hits `Check failed: sharding.IsManualSubgroup()` inside XLA's SPMD
# partitioner (fatal process abort, XLA < 2025).  Callers that scan over
# layers must gate that code path on this flag and fall back to a fully
# automatic (pjit) formulation.
PARTIAL_MANUAL_SAFE = _HAS_NATIVE


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              axis_names: Optional[Set[str]] = None):
    """``jax.shard_map`` with automatic fallback to the experimental API.

    ``axis_names`` restricts which mesh axes are manual (the rest stay
    auto-partitioned); on the legacy API this is expressed as the
    complementary ``auto`` frozenset.
    """
    if _HAS_NATIVE:
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    if _legacy_shard_map is None:  # pragma: no cover
        raise RuntimeError("no shard_map implementation available in this "
                           "jax installation")
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
