"""Version-portable ``shard_map``.

``jax.shard_map`` only exists from jax 0.6; earlier releases ship it as
``jax.experimental.shard_map.shard_map`` with a slightly different keyword
surface (``check_rep`` instead of ``check_vma``, and an ``auto`` set that is
the complement of the modern ``axis_names``).  Every call site in this repo
goes through this wrapper so the codebase runs on both API generations.
"""
from __future__ import annotations

from typing import Optional, Set

import jax

try:  # legacy location (jax < 0.6)
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
except ImportError:  # pragma: no cover — modern jax removed the alias
    _legacy_shard_map = None

_HAS_NATIVE = hasattr(jax, "shard_map")

# Partial-manual shard_map (axis_names ⊂ mesh axes) is unusable on legacy
# jax: a lax.scan whose body carries a with_sharding_constraint on an auto
# axis hits `Check failed: sharding.IsManualSubgroup()` inside XLA's SPMD
# partitioner (fatal process abort, XLA < 2025).  Callers that scan over
# layers must gate that code path on this flag and fall back to a fully
# automatic (pjit) formulation.
PARTIAL_MANUAL_SAFE = _HAS_NATIVE


def farm_dispatch_probe(min_devices: int = 2):
    """Can the sweep farm shard chunks across local jax devices?

    Returns ``(ok, reason)``.  Device dispatch needs (a) more than one
    local device to shard over and (b) the native ``jax.shard_map``
    surface — the legacy experimental API (jax < 0.6) aborts the process
    on the partial-manual scan pattern the farm uses (see
    :data:`PARTIAL_MANUAL_SAFE`), so on legacy jax the farm must degrade
    to single-device chunked execution with a warning, never crash.
    ``reason`` is human-readable and ends up in the run manifest.
    """
    n_dev = len(jax.devices())
    if n_dev < min_devices:
        return False, (f"only {n_dev} local jax device(s) "
                       f"(need >= {min_devices}); chunks run on one "
                       "device")
    if not _HAS_NATIVE:
        return False, (f"legacy jax {jax.__version__} < 0.6: native "
                       "shard_map missing and the experimental API is "
                       "not partial-manual safe; chunks run on one "
                       "device")
    return True, f"{n_dev} local devices, native shard_map"


def shard_map(f, *, mesh, in_specs, out_specs,
              check_vma: Optional[bool] = None,
              axis_names: Optional[Set[str]] = None):
    """``jax.shard_map`` with automatic fallback to the experimental API.

    ``axis_names`` restricts which mesh axes are manual (the rest stay
    auto-partitioned); on the legacy API this is expressed as the
    complementary ``auto`` frozenset.
    """
    if _HAS_NATIVE:
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    if _legacy_shard_map is None:  # pragma: no cover
        raise RuntimeError("no shard_map implementation available in this "
                           "jax installation")
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _legacy_shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
