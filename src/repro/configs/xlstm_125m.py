"""xLSTM-125M (sLSTM + mLSTM blocks). [arXiv:2405.04517; unverified]
d_ff=0: xLSTM blocks carry their own up/down projections.  Constant-size
recurrent state -> sub-quadratic, long_500k eligible."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    head_dim=192, d_ff=0, vocab_size=50_304,
    xlstm=True, slstm_every=4,   # blocks 4, 8, 12 are sLSTM
    subquadratic=True,
    source="arXiv:2405.04517; unverified",
)
