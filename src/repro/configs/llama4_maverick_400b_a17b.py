"""Llama-4 Maverick 400B-A17B (MoE, early fusion).
[hf:meta-llama/Llama-4-Scout-17B-16E family; unverified]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=202_048,
    rope_theta=500_000.0,
    num_experts=128, top_k=1, moe_every=2, shared_expert=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
