"""ChatGLM3-6B (dense, 2d/partial RoPE, GQA kv=2). [arXiv:2406.12793; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b", family="dense",
    num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
    head_dim=128, d_ff=13_696, vocab_size=65_024,
    rope_fraction=0.5,   # rotary applied to half the head dim (2d RoPE)
    source="arXiv:2406.12793; hf",
)
