"""The paper's own configuration: Jet on the two measurement testbeds
(§2.1, §6.1).  This is not an LM architecture — it parameterizes the
receive-datapath substrate (simulator, serving admission, collectives)."""
from repro.core.jet import JetConfig
from repro.core.simulator import testbed_100g, testbed_25g

JET_CONFIG = JetConfig(
    pool_bytes=12 << 20,          # 12 MB LLC (20% of cache)  §6.1
    srq_bytes=4 << 20,            # 4 MB small-message share   §4.1.3
    srq_wqes=1024,                # 1K pre-posted 4 KB WQEs    §4.1.3
    max_concurrency=32,           # READ concurrency window    §4.1.2
    max_inflight_bytes=8 << 20,   # in-flight byte window      §4.1.2
)

TESTBEDS = {
    "25g_pfc": testbed_25g,       # 2x25 Gbps, PFC-enabled, DDIO 4 MB
    "100g_pfcfree": testbed_100g, # 2x100 Gbps, PFC-free, DDIO 6 MB
}
