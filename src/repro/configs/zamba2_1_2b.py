"""Zamba2-1.2B (Mamba2 backbone + shared attention block).
[arXiv:2411.15242; hf]  ssm_state=64; the shared transformer block is
invoked every 6th position (weights shared across invocations)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=32_000,
    ssm_state=64, ssm_head_dim=64, ssm_groups=1, attn_every=6,
    subquadratic=True,
    source="arXiv:2411.15242; hf",
)
