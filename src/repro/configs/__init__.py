"""Config registry: ``get_arch(name)`` / ``--arch <id>`` selection."""
from __future__ import annotations

from typing import Dict, List

from .base import ArchConfig, ShapeConfig, SHAPES, cells, eligible
from .chatglm3_6b import CONFIG as _chatglm3
from .gemma_7b import CONFIG as _gemma
from .h2o_danube_1_8b import CONFIG as _danube
from .llama4_maverick_400b_a17b import CONFIG as _maverick
from .llama4_scout_17b_a16e import CONFIG as _scout
from .llama_3_2_vision_11b import CONFIG as _vision
from .musicgen_large import CONFIG as _musicgen
from .starcoder2_15b import CONFIG as _starcoder2
from .xlstm_125m import CONFIG as _xlstm
from .zamba2_1_2b import CONFIG as _zamba2

ARCHS: Dict[str, ArchConfig] = {c.name: c for c in [
    _maverick, _scout, _chatglm3, _danube, _starcoder2, _gemma,
    _musicgen, _xlstm, _vision, _zamba2,
]}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> List:
    return cells(list(ARCHS.values()))


def tiny_config(arch: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: small widths/layers,
    few experts, tiny vocab — structure preserved."""
    import dataclasses
    kw = dict(
        num_layers=min(arch.num_layers, _tiny_layers(arch)),
        d_model=128,
        num_heads=4,
        num_kv_heads=max(1, min(arch.num_kv_heads,
                                4 if arch.num_kv_heads >= arch.num_heads
                                else 2)),
        head_dim=32 if arch.head_dim else 0,
        d_ff=256 if arch.d_ff else 0,
        vocab_size=512,
        num_experts=min(arch.num_experts, 4),
        num_patches=64 if arch.num_patches else 0,
        ssm_state=min(arch.ssm_state, 16),
        ssm_head_dim=32 if arch.ssm_state else arch.ssm_head_dim,
        sliding_window=64 if arch.sliding_window else None,
        name=arch.name + "-tiny",
    )
    return dataclasses.replace(arch, **kw)


def _tiny_layers(arch: ArchConfig) -> int:
    # keep enough layers to include one of each special block
    n = 2
    for cadence in (arch.moe_every if arch.num_experts else 0,
                    arch.attn_every, arch.slstm_every,
                    arch.cross_attn_every):
        if cadence:
            n = max(n, cadence + 1)
    return n


__all__ = ["ARCHS", "ArchConfig", "SHAPES", "ShapeConfig", "all_cells",
           "cells", "eligible", "get_arch", "get_shape", "tiny_config"]
