"""Gemma-7B (dense, GeGLU, head_dim=256). [arXiv:2403.08295; hf]
Note attn inner dim (16*256=4096) exceeds d_model (3072)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24_576, vocab_size=256_000,
    mlp="geglu", tie_embeddings=True,
    source="arXiv:2403.08295; hf",
)
