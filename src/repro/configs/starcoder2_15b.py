"""StarCoder2-15B (dense, GQA kv=4, RoPE, plain-GELU MLP).
[arXiv:2402.19173; hf]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    head_dim=128, d_ff=24_576, vocab_size=49_152,
    rope_theta=100_000.0, mlp="gelu",
    source="arXiv:2402.19173; hf",
)
