"""Architecture + input-shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; every assigned
input shape a :class:`ShapeConfig`.  The dry-run / launcher cells are the
cross product filtered by :func:`cells` (long_500k only for sub-quadratic
archs — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # attention options
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0      # chatglm: 0.5 (partial/2d rotary)
    sliding_window: Optional[int] = None
    mlp: str = "swiglu"             # swiglu | geglu | gelu
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 1
    moe_every: int = 1              # MoE layer every k-th block
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    attn_every: int = 0             # zamba2: shared attn block cadence
    # xLSTM
    xlstm: bool = False
    slstm_every: int = 0            # sLSTM at every k-th block
    # VLM
    cross_attn_every: int = 0
    num_patches: int = 0
    # audio
    num_codebooks: int = 0
    # long-context eligibility
    subquadratic: bool = False
    source: str = ""

    # ---- derived ------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.hd

    @property
    def ssm_heads(self) -> int:
        """Mamba2 heads: d_inner = 2*d_model, head_dim = ssm_head_dim."""
        return (2 * self.d_model) // self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return (i + 1) % self.moe_every == 0

    # ---- parameter counting (for 6ND MODEL_FLOPS) ---------------------- #
    def _mlp_params(self) -> int:
        gated = self.mlp in ("swiglu", "geglu")
        return (3 if gated else 2) * self.d_model * self.d_ff

    def _attn_params(self) -> int:
        return (self.d_model * self.attn_dim          # Q
                + 2 * self.d_model * self.kv_dim      # K, V
                + self.attn_dim * self.d_model)       # O

    def _mamba_params(self) -> int:
        d_in = 2 * self.d_model
        n, g = self.ssm_state, self.ssm_groups
        # in_proj: x, z branches + B, C, dt heads; out_proj
        return (self.d_model * (2 * d_in + 2 * g * n + self.ssm_heads)
                + d_in * self.d_model)

    def _xlstm_params(self) -> int:
        # mLSTM block: q,k,v,o + gates; approximate with 4*d^2 + 2*d*ff-less
        d = self.d_model
        return 4 * d * d + 3 * d * d // 4  # projections + gate projections

    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params) excluding the input embedding
        gather (which contributes no matmul FLOPs)."""
        d, v = self.d_model, self.vocab_size
        total = active = 0
        for i in range(self.num_layers):
            if self.xlstm:
                p = self._xlstm_params()
            elif self.family in ("ssm", "hybrid") and not self._is_attn(i):
                p = self._mamba_params()
            else:
                p = self._attn_params()
                if (self.cross_attn_every and
                        (i + 1) % self.cross_attn_every == 0):
                    p += self._attn_params()  # extra cross-attn
            total += p
            active += p
            if self.xlstm:
                continue
            if self.family in ("ssm", "hybrid") and not self._is_attn(i):
                continue
            if self.is_moe_layer(i):
                total += self.num_experts * self._mlp_params()
                active += self.top_k * self._mlp_params()
                if self.shared_expert:
                    total += self._mlp_params()
                    active += self._mlp_params()
                total += d * self.num_experts      # router
                active += d * self.num_experts
            elif self.d_ff:
                total += self._mlp_params()
                active += self._mlp_params()
        # unembedding projection participates in matmul FLOPs
        total += d * v
        active += d * v
        return total, active

    def _is_attn(self, i: int) -> bool:
        """For hybrid (zamba2): True if block i is the shared attn block."""
        if self.family not in ("ssm", "hybrid"):
            return True
        if not self.attn_every:
            return False
        return (i + 1) % self.attn_every == 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def eligible(arch: ArchConfig, shape: ShapeConfig) -> bool:
    """long_500k requires sub-quadratic attention (DESIGN.md §5)."""
    if shape.name == "long_500k":
        return arch.subquadratic
    return True


def cells(archs: List[ArchConfig]) -> List[Tuple[ArchConfig, ShapeConfig]]:
    return [(a, s) for a in archs for s in SHAPES.values()
            if eligible(a, s)]
