"""Llama-3.2-Vision-11B (cross-attn image layers every 5th block).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]  The vision tower is a
STUB per assignment: input_specs() provides precomputed, already-projected
patch embeddings [B, num_patches, d_model]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=14_336, vocab_size=128_256,
    rope_theta=500_000.0,
    cross_attn_every=5, num_patches=1600,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
