"""MusicGen-Large (decoder-only over EnCodec tokens).
[arXiv:2306.05284; hf]  The EnCodec frontend is a STUB per assignment:
input_specs() provides 4-codebook token ids; the embedding sums codebooks
(delay pattern applied upstream).  Positional encoding adapted to RoPE
(original: sinusoidal) — recorded in DESIGN.md."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=2048,
    mlp="gelu", num_codebooks=4,
    source="arXiv:2306.05284; hf",
)
