"""Fault-tolerant training loop.

Production behaviors (all exercised by tests):
  * async checkpoint every N steps, atomic commit, keep-last-k;
  * crash/preemption recovery: any exception triggers a final sync
    checkpoint attempt; on restart the loop resumes from the latest step
    with a bit-identical data cursor;
  * fault injection hook (tests simulate node failure mid-run);
  * straggler monitor: EWMA of step wall-time; a step slower than
    ``k x ewma`` raises a flag and (optionally) triggers remediation — the
    Jet escape ladder applied to compute (log -> rebalance -> shrink work);
  * elastic rescale: restoring onto a different mesh just supplies different
    shardings to ``restore`` (see checkpoint.ckpt).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import numpy as np

from ..checkpoint import ckpt
from ..configs.base import ArchConfig
from ..optim import adamw
from ..parallel.sharding import ParallelCtx
from . import steps as steps_mod


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_ewma: float = 0.9


class StragglerMonitor:
    """Per-step wall-time EWMA; flags outliers (the straggler-mitigation
    hook — on a real fleet the flag keys host replacement / data
    rebalancing)."""

    def __init__(self, factor: float, ewma: float):
        self.factor = factor
        self.alpha = ewma
        self.mean: Optional[float] = None
        self.flags = 0

    def observe(self, dt: float) -> bool:
        if self.mean is None:
            self.mean = dt
            return False
        is_straggler = dt > self.factor * self.mean
        self.mean = self.alpha * self.mean + (1 - self.alpha) * dt
        if is_straggler:
            self.flags += 1
        return is_straggler


def run(cfg: ArchConfig, ctx: ParallelCtx, opt_cfg: adamw.OptConfig,
        loop_cfg: LoopConfig, data: Iterable[Dict[str, np.ndarray]],
        key, fault_injector: Optional[Callable[[int], None]] = None,
        state: Optional[Dict[str, Any]] = None,
        compute_dtype=None, accum_steps: int = 1) -> Dict[str, Any]:
    """Run (or resume) training; returns the final state + history.

    ``accum_steps > 1``: each pipeline batch is split into microbatches
    [A, B/A, ...] and gradients accumulate (steps.make_train_step)."""
    import jax.numpy as jnp
    compute_dtype = compute_dtype or jnp.float32
    train_step = jax.jit(steps_mod.make_train_step(
        cfg, ctx, opt_cfg, compute_dtype, accum_steps=accum_steps))
    saver = ckpt.AsyncSaver()
    data_it = iter(data)

    start_step = 0
    if state is None:
        latest = ckpt.latest_step(loop_cfg.ckpt_dir)
        if latest is not None:
            like = steps_mod.abstract_state(cfg, opt_cfg)
            state, extra = ckpt.restore(loop_cfg.ckpt_dir, like)
            start_step = int(extra.get("step", latest))
            # fast-forward the data cursor for bit-identical resume
            for _ in range(int(extra.get("cursor", start_step))):
                next(data_it)
        else:
            state = steps_mod.init_state(cfg, opt_cfg, key)

    monitor = StragglerMonitor(loop_cfg.straggler_factor,
                               loop_cfg.straggler_ewma)
    history = []
    step = start_step
    try:
        while step < loop_cfg.total_steps:
            if fault_injector is not None:
                fault_injector(step)
            batch = {k: jax.numpy.asarray(v)
                     for k, v in next(data_it).items()}
            if accum_steps > 1:
                batch = {k: v.reshape((accum_steps,
                                       v.shape[0] // accum_steps)
                                      + v.shape[1:])
                         for k, v in batch.items()}
            t0 = time.time()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            straggle = monitor.observe(dt)
            step += 1
            if step % loop_cfg.log_every == 0 or straggle:
                history.append({"step": step, "loss": loss, "dt": dt,
                                "straggler": straggle})
            if step % loop_cfg.ckpt_every == 0:
                saver.save(state, loop_cfg.ckpt_dir, step,
                           extra={"step": step, "cursor": step},
                           keep_last=loop_cfg.keep_last)
    except KeyboardInterrupt:
        # preemption: best-effort sync checkpoint at the step boundary
        saver.wait()
        ckpt.save(state, loop_cfg.ckpt_dir, step,
                  extra={"step": step, "cursor": step},
                  keep_last=loop_cfg.keep_last)
        raise
    saver.wait()
    ckpt.save(state, loop_cfg.ckpt_dir, step,
              extra={"step": step, "cursor": step},
              keep_last=loop_cfg.keep_last)
    return {"state": state, "history": history,
            "straggler_flags": monitor.flags, "final_step": step}
