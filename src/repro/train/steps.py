"""Train / eval step construction: loss + grad + AdamW, with param-sharding
rules applied (FSDP/TP/EP), ready for jit/pjit under a mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import api as model_api
from ..optim import adamw
from ..parallel.compat import PARTIAL_MANUAL_SAFE, shard_map
from ..parallel.sharding import ParallelCtx

# (tp_dim, fsdp_dim) by leaf name, negative indices from the end
_RULES = {
    "wq": (-1, -2), "wk": (-1, -2), "wv": (-1, -2),
    "w_in": (-1, -2), "w_gate": (-1, -2), "w_x": (-1, -2),
    "w_xbc": (-1, -2), "w_z": (-1, -2), "w_dt": (-1, -2),
    "w_if": (-1, -2),
    "wo": (-2, -1), "w_out": (-2, -1),
    "e_in": (-3, -2), "e_gate": (-3, -2), "e_out": (-3, -1),
    "embed": (-2, -1), "unembed": (-1, -2),
}


def param_spec(path, leaf, ctx: ParallelCtx) -> P:
    name = None
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            name = k
            break
    rule = _RULES.get(name)
    if rule is None or not ctx.have_mesh:
        return P()
    tp, fs = rule
    nd = leaf.ndim
    parts: list = [None] * nd
    tp_i, fs_i = tp % nd, fs % nd
    if leaf.shape[tp_i] % ctx.model_size == 0 and leaf.shape[tp_i] > 1:
        parts[tp_i] = ctx.model_axis
    if (ctx.fsdp and fs_i != tp_i and "data" in ctx.mesh.axis_names
            and leaf.shape[fs_i] % ctx.mesh.shape["data"] == 0
            and leaf.shape[fs_i] > 1):
        parts[fs_i] = "data"
    return P(*parts)


def param_specs(params, ctx: ParallelCtx):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, ctx), params)


def param_shardings(params, ctx: ParallelCtx):
    return jax.tree.map(lambda s: ctx.sharding(s),
                        param_specs(params, ctx))


def opt_state_specs(opt_state, params_specs, ctx: ParallelCtx):
    """Moments inherit their parameter's spec (ZeRO).  Row-wise int8
    moments: ``q`` keeps the parameter's exact shape (same spec); ``s``
    drops the last dim (same spec truncated) — sharding-preserving, no
    reshape (see parallel.compression.quantize_int8_rowwise)."""
    def one(moment_tree):
        def match(path, leaf):
            is_scale = getattr(path[-1], "key", None) == "s"
            trimmed = [p for p in path
                       if getattr(p, "key", None) not in ("q", "s")]
            if leaf.ndim == 0:
                return P()
            if is_scale:
                # spec of the parent parameter, truncated to scale's dims
                parent = jax.ShapeDtypeStruct(tuple(leaf.shape) + (1,),
                                              leaf.dtype)
                spec = param_spec(trimmed, parent, ctx)
                return P(*tuple(spec)[:leaf.ndim])
            return param_spec(trimmed, leaf, ctx)
        return jax.tree_util.tree_map_with_path(match, moment_tree)
    return {"m": one(opt_state["m"]), "v": one(opt_state["v"]),
            "count": P()}


def batch_specs(batch, ctx: ParallelCtx):
    def one(x):
        ax = ctx.batch_axes_for(x.shape[0])
        return P(ax if ax else None, *([None] * (x.ndim - 1)))
    return jax.tree.map(one, batch)


# --------------------------------------------------------------------------- #
def make_train_step(cfg: ArchConfig, ctx: ParallelCtx,
                    opt_cfg: adamw.OptConfig,
                    compute_dtype=jnp.bfloat16, accum_steps: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``accum_steps > 1`` enables gradient-accumulation microbatching: the
    batch arrives pre-split as [A, B/A, ...] (leading accum dim
    *unsharded*, micro dim data-sharded) and a lax.scan accumulates f32
    grads — activation live range (and temp HBM) divides by A, which is
    what fits the 400B train cells on 16 GB v5e chips.
    """
    grad_fn = jax.value_and_grad(model_api.loss_fn, has_aux=True)

    def compute_grads(params, batch, gctx=ctx):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, cfg, gctx, batch,
                                             compute_dtype)
            return grads, loss, metrics
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)

        def micro(carry, mb):
            g_acc, l_acc, a_acc = carry
            (l, m), g = grad_fn(params, cfg, gctx, mb, compute_dtype)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            a_new = {k: a_acc[k] + m[k] for k in a_acc}
            return (g_acc, l_acc + l, a_new), None

        aux0 = {k: jnp.zeros((), jnp.float32)
                for k in ("loss", "lb_loss", "overflow")}
        (grads, loss, asum), _ = jax.lax.scan(
            micro, (g0, jnp.zeros((), jnp.float32), aux0), batch)
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        return grads, loss * inv, {k: v * inv for k, v in asum.items()}

    def plain_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        grads, loss, metrics = compute_grads(params, batch)
        new_params, new_opt, stats = adamw.update(grads, state["opt"],
                                                  params, opt_cfg)
        out = {"params": new_params, "opt": new_opt,
               "step": state["step"] + 1}
        if "err" in state:
            out["err"] = state["err"]
        return out, {**metrics, **stats}

    # the manual-'pod' region scans over layers with auto-axis sharding
    # constraints inside, which legacy jax cannot partition (see compat) —
    # there the cross-pod sync falls back to exact (uncompressed) pjit.
    want_pod = (opt_cfg.compressed_pod_grads and ctx.have_mesh
                and "pod" in ctx.mesh.axis_names)
    use_pod = want_pod and PARTIAL_MANUAL_SAFE
    if want_pod and not use_pod:
        import warnings
        warnings.warn(
            "compressed_pod_grads requested but partial-manual shard_map "
            "is unusable on this jax version; falling back to exact "
            "(uncompressed) cross-pod gradient sync", RuntimeWarning,
            stacklevel=2)
    if not use_pod:
        return plain_step

    # --- hierarchical compressed cross-pod sync --------------------------- #
    # shard_map manual over 'pod' only: inside the body the batch is the
    # pod-local shard (loss/grads reduce over data/model via the auto
    # axes); the pod-axis gradient mean rides int8 + error feedback.
    from ..parallel.compression import compressed_psum
    import dataclasses as _dc

    # constraints inside the manual-'pod' region may only use auto axes
    inner_ctx = _dc.replace(
        ctx, data_axes=tuple(a for a in ctx.data_axes if a != "pod"))

    def pod_body(state, batch):
        params = state["params"]
        grads, loss, metrics = compute_grads(params, batch, inner_ctx)

        def one(g, e):
            mean, new_e = compressed_psum(g.astype(jnp.float32),
                                          e.astype(jnp.float32), "pod")
            return mean, new_e.astype(jnp.bfloat16)
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(state["err"])
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        grads = tdef.unflatten([p[0] for p in pairs])
        new_err = tdef.unflatten([p[1] for p in pairs])
        new_params, new_opt, stats = adamw.update(grads, state["opt"],
                                                  params, opt_cfg)
        metrics = {**metrics, **stats,
                   "loss": jax.lax.pmean(metrics["loss"]
                                         if "loss" in metrics else loss,
                                         "pod")}
        return ({"params": new_params, "opt": new_opt, "err": new_err,
                 "step": state["step"] + 1}, metrics)

    def pod_step(state, batch):
        bdim = 1 if accum_steps > 1 else 0
        bspec = jax.tree.map(
            lambda x: P(*([None] * bdim + ["pod"] +
                          [None] * (x.ndim - bdim - 1))), batch)
        return shard_map(
            pod_body, mesh=ctx.mesh,
            in_specs=(jax.tree.map(lambda _: P(), state), bspec),
            out_specs=(jax.tree.map(lambda _: P(), state),
                       jax.tree.map(lambda _: P(),
                                    {"loss": 0, "lb_loss": 0,
                                     "overflow": 0, "lr": 0,
                                     "grad_norm": 0})),
            check_vma=False, axis_names={"pod"})(state, batch)

    return pod_step


def make_eval_step(cfg: ArchConfig, ctx: ParallelCtx,
                   compute_dtype=jnp.bfloat16):
    def eval_step(params, batch):
        loss, metrics = model_api.loss_fn(params, cfg, ctx, batch,
                                          compute_dtype)
        return metrics
    return eval_step


def init_state(cfg: ArchConfig, opt_cfg: adamw.OptConfig, key,
               dtype=jnp.float32) -> Dict[str, Any]:
    params = model_api.init_params(cfg, key, dtype)
    state = {"params": params, "opt": adamw.init(params, opt_cfg),
             "step": jnp.zeros((), jnp.int32)}
    if opt_cfg.compressed_pod_grads:
        # bf16 error-feedback residuals for the int8 cross-pod grad mean
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
    return state


def abstract_state(cfg: ArchConfig, opt_cfg: adamw.OptConfig,
                   dtype=jnp.float32):
    """ShapeDtypeStructs of the full train state (no allocation)."""
    return jax.eval_shape(
        functools.partial(init_state, cfg, opt_cfg, dtype=dtype),
        jax.random.key(0))


def state_specs(state, ctx: ParallelCtx):
    p_specs = param_specs(state["params"], ctx)
    specs = {"params": p_specs,
             "opt": opt_state_specs(state["opt"], p_specs, ctx),
             "step": P()}
    if "err" in state:
        specs["err"] = p_specs       # residuals mirror the param sharding
    return specs
