from .loop import LoopConfig, StragglerMonitor, run
from .steps import (abstract_state, init_state, make_eval_step,
                    make_train_step, param_specs, state_specs)
__all__ = ["LoopConfig", "StragglerMonitor", "abstract_state", "init_state",
           "make_eval_step", "make_train_step", "param_specs", "run",
           "state_specs"]
