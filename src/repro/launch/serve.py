"""Serving launcher: batched requests through the Jet-admitted engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --tiny \
      --requests 12 --prompt-len 24 --max-new 16
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    import jax
    import numpy as np

    from ..configs import get_arch, tiny_config
    from ..models import api as model_api
    from ..parallel.sharding import single_device_ctx
    from ..serving.engine import EngineConfig, Request, ServingEngine

    cfg = get_arch(args.arch)
    if args.tiny:
        cfg = tiny_config(cfg)
    ctx = single_device_ctx(moe_capacity_factor=2.0)
    params = model_api.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(cfg, EngineConfig(max_lanes=args.lanes,
                                             max_len=args.max_len),
                           params, ctx)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(2, cfg.vocab_size,
                              size=args.prompt_len).astype(np.int32)
        engine.submit(Request(i, prompt, args.max_new))
    engine.run_until_done(max_ticks=args.requests * (args.max_new + 4))
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in engine.done.values())
    print(f"served {len(engine.done)}/{args.requests} requests, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/max(dt,1e-9):.1f} tok/s)")
    print("jet:", engine.jet.stats())


if __name__ == "__main__":
    main()
