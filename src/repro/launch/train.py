"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b \
      --steps 50 --batch 8 --seq 512 [--mesh 1x1|2x4|single] [--tiny]

``--mesh single`` targets the production 16x16 mesh (requires 256 devices —
use the dry-run on CPU).  On CPU the default is a 1x1 mesh with the reduced
config unless ``--full`` is given.
"""
from __future__ import annotations

import argparse
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM host mesh (e.g. 2x4) or 'single'/'multi'")
    ap.add_argument("--tiny", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--int8-moments", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "layer_out", "none"])
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host devices (set before jax init)")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.host_devices}")

    import jax

    from ..configs import get_arch, tiny_config
    from ..data import pipeline
    from ..configs.base import ShapeConfig
    from ..optim import adamw
    from ..parallel.sharding import single_device_ctx
    from ..train import loop as loop_mod
    from .mesh import ctx_for_mesh, make_mesh, make_production_mesh

    cfg = get_arch(args.arch)
    if args.tiny:
        cfg = tiny_config(cfg)

    if args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        ctx = ctx_for_mesh(mesh, remat=args.remat)
    elif args.mesh == "1x1":
        mesh = None
        ctx = single_device_ctx(remat=args.remat, moe_capacity_factor=2.0)
    else:
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
        ctx = ctx_for_mesh(mesh, remat=args.remat)

    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    data = pipeline.for_arch(cfg, shape)
    opt_cfg = adamw.OptConfig(lr=args.lr, int8_moments=args.int8_moments,
                              total_steps=args.steps)
    loop_cfg = loop_mod.LoopConfig(total_steps=args.steps,
                                   ckpt_every=args.ckpt_every,
                                   ckpt_dir=args.ckpt_dir)

    def run():
        out = loop_mod.run(cfg, ctx, opt_cfg, loop_cfg, data,
                           jax.random.key(0), accum_steps=args.accum)
        for h in out["history"]:
            print(f"step {h['step']:5d} loss {h['loss']:.4f} "
                  f"dt {h['dt']*1e3:.0f}ms"
                  + (" [straggler]" if h["straggler"] else ""))
        print(f"final step {out['final_step']}, "
              f"straggler flags: {out['straggler_flags']}")

    if mesh is not None:
        with mesh:
            run()
    else:
        run()


if __name__ == "__main__":
    main()
