"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single-pod: (data=16, model=16) = 256 chips
(TPU v5e pod).  Multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..parallel.sharding import ParallelCtx


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def ctx_for_mesh(mesh, **kw) -> ParallelCtx:
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ParallelCtx(mesh=mesh, data_axes=data_axes, **kw)


def small_host_mesh(n: Optional[int] = None, model: int = 2):
    """Host-device mesh for tests (requires XLA_FLAGS host device count)."""
    n = n or len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))
