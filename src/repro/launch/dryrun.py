import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           ).strip()
"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against ShapeDtypeStruct inputs on 512 placeholder host devices.

Proves: the sharding config is coherent (no mismatch), the program fits
(memory analysis), and yields the HLO FLOP/byte/collective numbers the
roofline analysis (benchmarks/roofline.py) consumes.

Usage:
  python -m repro.launch.dryrun --arch llama4-scout-17b-a16e --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--force]
Outputs one JSON per cell under experiments/dryrun/.
"""
import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import ARCHS, SHAPES, eligible, get_arch, get_shape
from ..models import api as model_api
from ..optim import adamw
from ..parallel.sharding import ParallelCtx
from ..train import steps as steps_mod
from . import hlo_analysis
from .mesh import ctx_for_mesh, make_production_mesh

_DTSIZE = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
           "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
           "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(\(?[a-z0-9]+\[[0-9,]*\][^)]*\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTSIZE:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTSIZE[dt]
    return total


def collective_bytes(hlo: str) -> dict:
    """Per-device ICI bytes by collective type, ring-algorithm accounting."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        shapes_str, op = m.group(1), m.group(2)
        size = _shape_bytes(shapes_str)
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            g2 = _GROUPS2_RE.search(line)
            n = int(g2.group(2)) if g2 else 2
        n = max(n, 2)
        if op == "all-gather":
            # result holds the gathered tensor; each device receives
            # (n-1)/n of it over the ring
            b = size * (n - 1) / n
        elif op == "all-reduce":
            b = 2.0 * size * (n - 1) / n
        elif op == "reduce-scatter":
            b = size * (n - 1)   # result is the scattered shard; ring moves
            #                      (n-1)/n of the n-x-larger input
        elif op == "all-to-all":
            b = size * (n - 1) / n
        else:  # collective-permute
            b = size
        out[op] += b
        counts[op] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def sharded_arg_bytes(tree, specs, mesh) -> int:
    """Per-device bytes of inputs given their PartitionSpecs."""
    total = 0
    flat_t = jax.tree.leaves(tree)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_t, flat_s):
        shards = 1
        for axes in spec:
            if axes is None:
                continue
            for ax in (axes if isinstance(axes, tuple) else (axes,)):
                shards *= mesh.shape[ax]
        total += leaf.size * leaf.dtype.itemsize // max(1, shards)
    return total


# --------------------------------------------------------------------------- #
def build_cell(arch_name: str, shape_name: str, mesh, variant: dict):
    """Returns (fn, args, in_shardings, arg_specs) ready to lower."""
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ctx = ctx_for_mesh(
        mesh,
        remat=variant.get("remat", "full"),
        fsdp=variant.get("fsdp", True),
        use_ep=variant.get("use_ep", True),
        seq_parallel_decode=variant.get("seq_parallel_decode", True),
        bf16_weight_gather=variant.get("bf16_weight_gather", False),
        jet_collectives=variant.get("jet_collectives", False),
        jet_window=variant.get("jet_window", 4),
    )
    big = cfg.param_counts()[0] > 50e9
    opt_cfg = adamw.OptConfig(
        int8_moments=variant.get("int8_moments", big),
        compressed_pod_grads=variant.get("compressed_pod_grads", False))
    compute_dtype = jnp.bfloat16

    inputs = model_api.input_specs(cfg, shape, compute_dtype)
    accum = int(variant.get("accum", 1))
    if shape.kind == "train":
        state = steps_mod.abstract_state(cfg, opt_cfg)
        state_specs = steps_mod.state_specs(state, ctx)
        if accum > 1:
            # microbatched layout: [A, B/A, ...] — accum dim unsharded,
            # micro batch dim data-sharded (see steps.make_train_step)
            inputs = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (accum, s.shape[0] // accum) + s.shape[1:], s.dtype),
                inputs)
            micro_specs = steps_mod.batch_specs(
                jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                    s.shape[1:], s.dtype), inputs), ctx)
            batch_specs = jax.tree.map(
                lambda sp: P(None, *tuple(sp)),
                micro_specs, is_leaf=lambda x: isinstance(x, P))
        else:
            batch_specs = steps_mod.batch_specs(inputs, ctx)
        fn = steps_mod.make_train_step(cfg, ctx, opt_cfg, compute_dtype,
                                       accum_steps=accum)
        args = (state, inputs)
        shardings = (jax.tree.map(ctx.sharding, state_specs,
                                  is_leaf=lambda x: isinstance(x, P)),
                     jax.tree.map(ctx.sharding, batch_specs,
                                  is_leaf=lambda x: isinstance(x, P)))
        specs = (state_specs, batch_specs)
        donate = (0,)
    elif shape.kind == "prefill":
        params = model_api.abstract_params(
            cfg, jnp.bfloat16 if variant.get("serve_bf16") else jnp.float32)
        p_specs = steps_mod.param_specs(params, ctx)
        i_specs = steps_mod.batch_specs(inputs, ctx)

        def fn(params, batch):
            return model_api.prefill(params, cfg, ctx, batch["tokens"],
                                     batch.get("patches"),
                                     max_len=shape.seq_len,
                                     compute_dtype=compute_dtype)
        args = (params, inputs)
        shardings = (jax.tree.map(ctx.sharding, p_specs,
                                  is_leaf=lambda x: isinstance(x, P)),
                     jax.tree.map(ctx.sharding, i_specs,
                                  is_leaf=lambda x: isinstance(x, P)))
        specs = (p_specs, i_specs)
        donate = ()
    else:  # decode
        params = model_api.abstract_params(
            cfg, jnp.bfloat16 if variant.get("serve_bf16") else jnp.float32)
        p_specs = steps_mod.param_specs(params, ctx)
        b = shape.global_batch
        state = inputs["state"]

        def kv_spec(leaf):
            # KV caches [.., B, S, Hkv, hd] (stacked: n_units leading);
            # ssm states and small tensors: batch-shard only.
            if leaf.ndim >= 4 and leaf.shape[-3] % 16 == 0 and \
                    leaf.shape[-3] >= 4096:
                lead = [None] * (leaf.ndim - 4)
                ax = ctx.batch_axes_for(leaf.shape[-4])
                return P(*lead, ax if ax else None, ctx.model_axis, None,
                         None)
            # batch axis is first (remainder) or second (pattern-stacked)
            for bdim in range(min(2, leaf.ndim)):
                if leaf.shape[bdim] == b:
                    ax = ctx.batch_axes_for(b)
                    parts = [None] * leaf.ndim
                    if ax:
                        parts[bdim] = ax
                    return P(*parts)
            return P()
        s_specs = jax.tree.map(kv_spec, state)
        tok_spec = P(ctx.batch_axes_for(b) or None)
        len_spec = P(ctx.batch_axes_for(b) or None)

        def fn(params, state, tokens, lengths):
            return model_api.decode_step(params, cfg, ctx, state, tokens,
                                         lengths,
                                         compute_dtype=compute_dtype)
        args = (params, state, inputs["tokens"], inputs["lengths"])
        tok_sp = P(*([ctx.batch_axes_for(b) or None] +
                     [None] * (inputs["tokens"].ndim - 1)))
        shardings = (jax.tree.map(ctx.sharding, p_specs,
                                  is_leaf=lambda x: isinstance(x, P)),
                     jax.tree.map(ctx.sharding, s_specs,
                                  is_leaf=lambda x: isinstance(x, P)),
                     ctx.sharding(tok_sp), ctx.sharding(len_spec))
        specs = (p_specs, s_specs, tok_sp, len_spec)
        donate = (1,)
    return fn, args, shardings, specs, donate


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             out_dir: str, variant=None, force: bool = False) -> dict:
    variant = variant or {}
    vtag = ("__" + variant["tag"]) if variant.get("tag") else ""
    out_path = os.path.join(
        out_dir, f"{arch_name}__{shape_name}__{mesh_kind}{vtag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)
    if variant.get("mesh_shape"):
        # custom mesh (e.g. a dedicated serving mesh (data=4, model=64)
        # for 400B-class decode — see EXPERIMENTS.md §Perf cell C)
        shape = tuple(int(v) for v in variant["mesh_shape"])
        axes = ("pod", "data", "model")[-len(shape):]
        mesh = jax.make_mesh(shape, axes)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(zip(mesh.axis_names,
                                  [int(s) for s in mesh.devices.shape])),
           "variant": {k: v for k, v in variant.items() if k != "tag"},
           "tag": variant.get("tag", "")}
    t0 = time.time()
    try:
        with mesh:
            fn, args, shardings, specs, donate = build_cell(
                arch_name, shape_name, mesh, variant)
            lowered = jax.jit(fn, in_shardings=shardings,
                              donate_argnums=donate).lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            cost = compiled.cost_analysis() or {}
            if isinstance(cost, (list, tuple)):
                # jax <= 0.4.x returns a one-element list of dicts
                cost = cost[0] if cost else {}
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes(hlo)           # raw (loop-unaware)
            deep = hlo_analysis.analyze(hlo)       # trip-count-corrected
            rec.update({
                "ok": True,
                "lower_s": round(t1 - t0, 2),
                "compile_s": round(t2 - t1, 2),
                # XLA numbers (NOTE: while-loop bodies counted once)
                "xla_flops_per_device": float(cost.get("flops", -1.0)),
                "xla_bytes_per_device": float(cost.get("bytes accessed",
                                                       -1.0)),
                # trip-count-corrected numbers (launch.hlo_analysis)
                "flops_per_device": deep["dot_flops"],
                "dot_bytes_per_device": deep["dot_bytes"],
                "collective_bytes_per_device": deep["coll"],
                "collective_total_per_device": deep["coll_total"],
                "collective_counts": deep["coll_counts"],
                "trip_counts": deep["trip_counts"],
                "collective_bytes_raw": coll,
                "arg_bytes_per_device": _safe_arg_bytes(args, specs, mesh),
                "hlo_lines": hlo.count("\n"),
            })
            if mem is not None:
                for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                             "output_size_in_bytes",
                             "generated_code_size_in_bytes"):
                    v = getattr(mem, attr, None)
                    if v is not None:
                        rec[attr] = int(v)
    except Exception as e:  # noqa: BLE001 — record the failure verbatim
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
    rec["total_s"] = round(time.time() - t0, 2)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def _safe_arg_bytes(args, specs, mesh) -> int:
    try:
        return sharded_arg_bytes(args, specs, mesh)
    except Exception:  # noqa: BLE001
        return -1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default=None,
                    help="JSON dict of ParallelCtx overrides + 'tag'")
    args = ap.parse_args()
    variant = json.loads(args.variant) if args.variant else {}

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for a in archs:
        for s in shapes:
            if not eligible(get_arch(a), get_shape(s)):
                continue
            for m in meshes:
                cells.append((a, s, m))

    n_ok = 0
    for i, (a, s, m) in enumerate(cells):
        rec = run_cell(a, s, m, args.out, variant, args.force)
        ok = rec.get("ok")
        n_ok += bool(ok)
        gf = rec.get("flops_per_device", 0) / 1e9 if ok else 0
        print(f"[{i+1}/{len(cells)}] {a} x {s} x {m}: "
              f"{'OK' if ok else 'FAIL'} "
              f"({rec['total_s']}s, {gf:.1f} GF/dev)"
              + ("" if ok else f"  {rec.get('error','')[:200]}"),
              flush=True)
    print(f"dry-run complete: {n_ok}/{len(cells)} cells OK")
    if n_ok < len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
