"""Trip-count-aware HLO analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE regardless
of trip count (verified: a 10-iteration scan of a matmul reports 1 matmul of
FLOPs).  Our models scan over layer units, so FLOPs/bytes/collectives must be
multiplied by trip counts.  This module parses the optimized HLO:

  * splits the module into computations,
  * builds a call graph (while body/cond, fusion ``calls=``, ``to_apply=``),
  * extracts each while loop's trip count from its condition's comparison
    constant,
  * accumulates dot FLOPs, dot operand/result bytes (HBM-traffic proxy) and
    per-type collective bytes, each weighted by loop multiplicity.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTSIZE = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
           "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
           "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "s4": 1,
           "u4": 1}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_BLOCK_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+)\s*:\s*([a-z][a-z0-9]*\[[0-9,]*\])")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)(%?[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%?[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%?[\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT_OPND_RE = re.compile(r"dot\(([^)]*)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0, 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n, n * _DTSIZE.get(dt, 4)


def _first_shapes(s: str) -> List[str]:
    return [f"{dt}[{dims}]" for dt, dims in _SHAPE_RE.findall(s)]


@dataclasses.dataclass
class Block:
    name: str
    lines: List[str]
    params: Dict[str, str]          # param name -> shape string
    is_entry: bool = False


def _split_blocks(hlo: str) -> Dict[str, Block]:
    blocks: Dict[str, Block] = {}
    cur: Optional[Block] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _BLOCK_RE.match(line)
            if m and line.rstrip().endswith("{"):
                name = m.group(2).lstrip("%")
                params = {pm.group(1): pm.group(2)
                          for pm in _PARAM_RE.finditer(m.group(3))}
                cur = Block(name, [], params, is_entry=bool(m.group(1)))
        else:
            if line.startswith("}"):
                blocks[cur.name] = cur
                cur = None
            else:
                cur.lines.append(line)
    return blocks


@dataclasses.dataclass
class BlockStats:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: dict.fromkeys(_COLL_OPS, 0.0))
    coll_counts: Dict[str, int] = dataclasses.field(
        default_factory=lambda: dict.fromkeys(_COLL_OPS, 0))
    whiles: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    calls: List[str] = dataclasses.field(default_factory=list)
    convs: int = 0


def _sym_table(block: Block) -> Dict[str, str]:
    """name -> result shape string (first shape on the def line)."""
    table = dict(block.params)
    for line in block.lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name = m.group(1).lstrip("%")
        shapes = _first_shapes(m.group(2).split("(")[0])
        if shapes:
            table[name] = shapes[0]
        else:
            # tuple results: keep the full rhs for byte summing
            table[name] = m.group(2)
    return table


_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _collective_operands(line: str, op: str) -> List[str]:
    """Operand names of a collective call line."""
    tail = line.split(f" {op}(")[-1] if f" {op}(" in line else \
        line.split(f" {op}-start(")[-1]
    names = []
    for tok in tail.split(")")[0].split(","):
        tok = tok.strip().lstrip("%")
        if tok:
            names.append(tok.split(" ")[-1].lstrip("%"))
    return names


def _bf16_on_tpu(line: str, op: str) -> bool:
    """True when this f32 collective would run at bf16 width on TPU.

    The CPU backend's float-normalization pass rewrites bf16 collectives:
    reductions get '..._promoted' reducers and data-movement collectives
    get their convert hoisted in front (operand named '*convert*').  The
    TPU backend executes both natively in bf16, so the roofline must count
    them at 2 bytes.
    """
    if "promoted" in line:
        return True
    ops = _collective_operands(line, op)
    return bool(ops) and all("convert" in n for n in ops)


def _analyze_block(block: Block) -> BlockStats:
    st = BlockStats()
    sym = _sym_table(block)
    for line in block.lines:
        if " dot(" in line:
            m = _DEF_RE.match(line)
            if not m:
                continue
            res_shapes = _first_shapes(m.group(2).split(" dot(")[0])
            if not res_shapes:
                continue
            res_elems, res_bytes = _shape_elems_bytes(res_shapes[0])
            ops = _DOT_OPND_RE.search(line)
            cm = _CONTRACT_RE.search(line)
            k = 1
            lhs_bytes = rhs_bytes = 0
            if ops and cm:
                opnames = [o.strip().lstrip("%").split(" ")[-1]
                           for o in ops.group(1).split(",")]
                lhs_shape = sym.get(opnames[0], "")
                rhs_shape = sym.get(opnames[1], "") if len(opnames) > 1 \
                    else ""
                lm = _SHAPE_RE.match(lhs_shape or "")
                if lm:
                    dims = [int(d) for d in lm.group(2).split(",") if d]
                    for ci in cm.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                _, lhs_bytes = _shape_elems_bytes(lhs_shape or "")
                _, rhs_bytes = _shape_elems_bytes(rhs_shape or "")
            st.dot_flops += 2.0 * res_elems * k
            st.dot_bytes += res_bytes + lhs_bytes + rhs_bytes
            continue
        if " convolution(" in line:
            st.convs += 1
        for op in _COLL_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                if "-done(" in line:
                    continue
                m = _DEF_RE.match(line)
                if not m:
                    break
                head = m.group(2).split(f" {op}")[0]
                size = sum(_shape_elems_bytes(s)[1]
                           for s in _first_shapes(head))
                # Count collectives the CPU backend widened to f32 at
                # their on-TPU (bf16) width — see _bf16_on_tpu.
                if _bf16_on_tpu(line, op):
                    size /= 2.0
                g = _GROUPS_RE.search(line)
                if g:
                    n = len(g.group(1).split(","))
                else:
                    g2 = _GROUPS2_RE.search(line)
                    n = int(g2.group(2)) if g2 else 2
                n = max(n, 2)
                if op == "all-gather":
                    b = size * (n - 1) / n
                elif op == "all-reduce":
                    b = 2.0 * size * (n - 1) / n
                elif op == "reduce-scatter":
                    b = size * (n - 1)
                elif op == "all-to-all":
                    b = size * (n - 1) / n
                else:
                    b = size
                st.coll[op] += b
                st.coll_counts[op] += 1
                break
        bm = _BODY_RE.search(line)
        if bm and " while(" in line:
            cm2 = _COND_RE.search(line)
            st.whiles.append((bm.group(1).lstrip("%"),
                              cm2.group(1).lstrip("%") if cm2 else ""))
            continue
        cm3 = _CALL_RE.findall(line)
        if cm3 and " while(" not in line:
            st.calls.extend(c.lstrip("%") for c in cm3)
    return st


def _trip_count(cond_block: Optional[Block]) -> int:
    if cond_block is None:
        return 1
    consts = [int(c) for line in cond_block.lines
              for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def analyze(hlo: str) -> Dict[str, object]:
    blocks = _split_blocks(hlo)
    stats = {name: _analyze_block(b) for name, b in blocks.items()}
    entry = next((b.name for b in blocks.values() if b.is_entry), None)
    if entry is None:
        return {"error": "no ENTRY computation found"}

    totals = {"dot_flops": 0.0, "dot_bytes": 0.0,
              "coll": dict.fromkeys(_COLL_OPS, 0.0),
              "coll_counts": dict.fromkeys(_COLL_OPS, 0),
              "convs": 0, "trip_counts": []}

    seen_depth = [0]

    def visit(name: str, mult: float) -> None:
        if name not in stats or seen_depth[0] > 64:
            return
        seen_depth[0] += 1
        st = stats[name]
        totals["dot_flops"] += mult * st.dot_flops
        totals["dot_bytes"] += mult * st.dot_bytes
        for op in _COLL_OPS:
            totals["coll"][op] += mult * st.coll[op]
            totals["coll_counts"][op] += st.coll_counts[op]
        totals["convs"] += st.convs
        for body, cond in st.whiles:
            tc = _trip_count(blocks.get(cond))
            totals["trip_counts"].append(tc)
            visit(body, mult * tc)
        for c in st.calls:
            if c != name:
                visit(c, mult)
        seen_depth[0] -= 1

    visit(entry, 1.0)
    totals["coll_total"] = sum(totals["coll"].values())
    return totals
