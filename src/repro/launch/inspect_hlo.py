import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           ).strip()
"""Collective-shape inspector: lower one dry-run cell and print every
collective op with its shape, replica-group size, trip-count weight and
ring-model bytes — the profiling view the perf loop works from.

  PYTHONPATH=src python -m repro.launch.inspect_hlo \
      --arch chatglm3-6b --shape train_4k [--mesh single] [--variant '{...}']
"""
import argparse
import json
import re
from collections import defaultdict

import jax

from . import hlo_analysis
from .dryrun import build_cell
from .mesh import make_production_mesh

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def inspect(hlo: str, top: int = 25):
    blocks = hlo_analysis._split_blocks(hlo)
    stats = {n: hlo_analysis._analyze_block(b) for n, b in blocks.items()}
    entry = next(b.name for b in blocks.values() if b.is_entry)

    # block -> multiplicity (product of enclosing while trip counts)
    mult = defaultdict(float)

    def visit(name, m):
        if name not in stats:
            return
        mult[name] = max(mult[name], m)
        st = stats[name]
        for body, cond in st.whiles:
            visit(body, m * hlo_analysis._trip_count(blocks.get(cond)))
        for c in st.calls:
            if c != name:
                visit(c, m)

    visit(entry, 1.0)

    rows = []
    for bname, block in blocks.items():
        m = mult.get(bname, 0.0)
        if m == 0.0:
            continue
        for line in block.lines:
            for op in _COLL_OPS:
                if f" {op}(" not in line and f" {op}-start(" not in line:
                    continue
                if "-done(" in line:
                    continue
                dm = hlo_analysis._DEF_RE.match(line)
                if not dm:
                    continue
                head = dm.group(2).split(f" {op}")[0]
                shapes = hlo_analysis._first_shapes(head)
                size = sum(hlo_analysis._shape_elems_bytes(s)[1]
                           for s in shapes)
                g = hlo_analysis._GROUPS_RE.search(line)
                n = (len(g.group(1).split(",")) if g else
                     int(hlo_analysis._GROUPS2_RE.search(line).group(2))
                     if hlo_analysis._GROUPS2_RE.search(line) else 2)
                n = max(n, 2)
                factor = {"all-gather": (n - 1) / n,
                          "all-reduce": 2 * (n - 1) / n,
                          "reduce-scatter": float(n - 1),
                          "all-to-all": (n - 1) / n,
                          "collective-permute": 1.0}[op]
                bf16 = hlo_analysis._bf16_on_tpu(line, op)
                rows.append({
                    "op": op + ("*" if bf16 else ""),
                    "shape": "+".join(shapes[:3]), "groups": n,
                    "trip_mult": m, "bytes_one": size,
                    "bytes_total": size * factor * m * (0.5 if bf16
                                                        else 1.0),
                    "block": bname[:40],
                })
                break
    rows.sort(key=lambda r: -r["bytes_total"])
    return rows[:top]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()
    variant = json.loads(args.variant) if args.variant else {}

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    with mesh:
        fn, cargs, shardings, specs, donate = build_cell(
            args.arch, args.shape, mesh, variant)
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*cargs)
        compiled = lowered.compile()
        hlo = compiled.as_text()
    rows = inspect(hlo, args.top)
    total = defaultdict(float)
    for r in rows:
        total[r["op"]] += r["bytes_total"]
    print(f"{'op':18s} {'shape':44s} {'grp':>4s} {'trips':>6s} "
          f"{'GB_total':>9s}  block   (* = counted bf16: CPU backend "
          f"widened, TPU native)")
    for r in rows:
        print(f"{r['op']:18s} {r['shape']:44s} {r['groups']:4d} "
              f"{r['trip_mult']:6.0f} {r['bytes_total']/1e9:9.2f}  "
              f"{r['block']}")
    print("\nper-op totals (top rows only):",
          {k: f"{v/1e9:.1f}GB" for k, v in total.items()})


if __name__ == "__main__":
    main()
