"""Cache-pressure-aware escape controller (paper §4.3, Algorithm 1).

Three escalating actions when the cache-resident buffer pool runs low:

1. ``REPLACE``  — swap straggler buffers for DRAM-backed ones (pool size
   constant, bounded by ``MEM_ESC`` borrowed DRAM);
2. ``COPY``     — for every app whose straggler ratio exceeds ``CREDIT``,
   copy its resident data to DRAM and free its cache slots;
3. ``MARK_ECN`` — last resort: signal congestion back to senders (on TPU:
   shrink the chunk-scheduler window, see window.ReadWindow.on_ecn).

Thresholds: CACHE_DANGER < CACHE_SAFE (fractions of pool available).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Tuple

from .pool import SlabPool


class Action(enum.Enum):
    NONE = "none"
    REPLACE = "replace"
    COPY = "copy"
    MARK_ECN = "mark_ecn"


@dataclasses.dataclass
class EscapeConfig:
    cache_safe: float = 0.20      # act when < 20% of pool is available
    cache_danger: float = 0.05    # last resort when < 5% available
    mem_esc_bytes: int = 2 << 20  # max DRAM borrowed via REPLACE
    credit: float = 0.5           # straggler ratio marking a slow app
    straggler_age: float = 1e-3   # seconds a slot may live before straggling
    max_replace_per_tick: int = 64


@dataclasses.dataclass
class EscapeStats:
    replaces: int = 0
    copies: int = 0
    ecn_marks: int = 0
    bytes_copied: int = 0
    bytes_replaced: int = 0


class EscapeController:
    """Faithful implementation of the paper's Algorithm 1."""

    def __init__(self, cfg: EscapeConfig = EscapeConfig()):
        self.cfg = cfg
        self.stats = EscapeStats()

    def step(self, pool: SlabPool, now: float
             ) -> List[Tuple[Action, object]]:
        """One escape() invocation. Returns the actions taken (with args)."""
        cfg = self.cfg
        actions: List[Tuple[Action, object]] = []
        avl = pool.available_bytes / max(1, pool.capacity_bytes)

        if avl >= cfg.cache_safe:                 # pool is fine
            return [(Action.NONE, None)]

        if pool.replace_mem_bytes < cfg.mem_esc_bytes:
            # Action 1: replace straggler buffers.
            replaced = 0
            for app in pool.apps():
                for sid in pool.straggler_slots(app, now, cfg.straggler_age):
                    if (replaced >= cfg.max_replace_per_tick or
                            pool.replace_mem_bytes >= cfg.mem_esc_bytes):
                        break
                    self.stats.bytes_replaced += pool.replace([sid])
                    replaced += 1
            if replaced:
                self.stats.replaces += replaced
                actions.append((Action.REPLACE, replaced))
        else:
            # Action 2: copy slow-releasing apps' data to DRAM.
            for app in pool.apps():
                if pool.straggler_ratio(app, now,
                                        cfg.straggler_age) > cfg.credit:
                    freed = pool.evict_app(app)
                    self.stats.copies += 1
                    self.stats.bytes_copied += freed
                    actions.append((Action.COPY, app))

        # Action 3: if still in danger, mark ECN.
        avl = pool.available_bytes / max(1, pool.capacity_bytes)
        if avl < cfg.cache_danger:
            self.stats.ecn_marks += 1
            actions.append((Action.MARK_ECN, None))

        return actions or [(Action.NONE, None)]
