"""The Jet service facade (paper §3): registration, QoS admission queues and
the receive workflow glue between the RNIC ("network"), the cache-resident
buffer pool, the recycle controller and the escape controller.

This is the host-side service object used by the serving engine
(`repro.serving.engine`).  The admission machinery itself — the QoS
classes, the priority pump order, the expected-footprint rule and the §5
low-QoS DRAM fallback — lives in :mod:`repro.core.datapath`
(``AdmissionQueues``), which is the same policy module the fluid
simulator and the fabric engines advance in stacked-array form; this
facade binds it to the concrete pool/window/recycle/escape objects.
The in-graph realization of the same ideas lives in `repro.kernels`
(staged consumption) and `repro.parallel.collectives` (windowed chunked
collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .datapath import Admit, AdmissionQueues, QoS, expected_footprint
from .escape import Action, EscapeConfig, EscapeController
from .pool import SlabPool
from .recycle import RecycleModel, paper_default
from .window import ReadWindow

SMALL_MSG_BYTES = 4 << 10  # paper §4.1.1: <4 KB -> SEND/RECV via SRQ


@dataclasses.dataclass
class JetConfig:
    pool_bytes: int = 12 << 20
    srq_bytes: int = 4 << 20            # small-message share (initial)
    srq_min_bytes: int = 1 << 20        # floor when rebalancing (paper §4.1.3)
    srq_wqes: int = 1024                # pre-posted 4 KB WQEs
    max_concurrency: int = 32
    max_inflight_bytes: int = 8 << 20
    expected_timespan_us: float = 200.0
    max_concurrent_transfers: int = 128
    escape: EscapeConfig = dataclasses.field(default_factory=EscapeConfig)


@dataclasses.dataclass
class Transfer:
    xfer_id: int
    app_id: int
    nbytes: int
    qos: QoS
    slots: List[int] = dataclasses.field(default_factory=list)
    small: bool = False


class JetService:
    """Admission + pool orchestration for the receive path (paper §3.2)."""

    def __init__(self, cfg: JetConfig = JetConfig(),
                 recycle: Optional[RecycleModel] = None):
        self.cfg = cfg
        self.pool = SlabPool(cfg.pool_bytes)
        self.window = ReadWindow(cfg.max_concurrency, cfg.max_inflight_bytes)
        self.recycle = recycle or paper_default()
        self.escape = EscapeController(cfg.escape)
        self._apps: Dict[int, QoS] = {}
        self._queues = AdmissionQueues()
        self._live: Dict[int, Transfer] = {}
        self._next_id = 0
        self.rejected_small = 0
        self.memory_fallbacks = 0   # low-QoS apps pushed to DRAM buffers (§5)
        # Network backpressure gate (PFC pause / fabric congestion): while
        # asserted, no new transfers are admitted to the pool — arrivals
        # are stalled on the wire, so reserving cache slots for them would
        # only deepen the pressure that caused the pause.
        self.network_paused = False

    # -- step 1: registration -------------------------------------------------
    def register(self, app_id: int, qos: QoS = QoS.NORMAL) -> None:
        self._apps[app_id] = qos

    # -- step 2: transfer request ---------------------------------------------
    def request(self, app_id: int, nbytes: int, now: float) -> int:
        """Host B announces a transfer; returns transfer id (queued)."""
        if app_id not in self._apps:
            raise KeyError(f"app {app_id} not registered with Jet")
        t = Transfer(self._next_id, app_id, nbytes, self._apps[app_id],
                     small=nbytes < SMALL_MSG_BYTES)
        self._next_id += 1
        self._queues.push(t, t.qos)
        return t.xfer_id

    def _expected_footprint(self, nbytes: int) -> int:
        """Admission rule (§3.2 step 2), shared with the fluid datapath."""
        return expected_footprint(nbytes, self.cfg.expected_timespan_us)

    # -- network feedback ------------------------------------------------------
    def set_backpressure(self, paused: bool) -> None:
        """Assert/clear the network backpressure gate (e.g. the receiver's
        PFC pause state, or fabric-level pool-danger signalling)."""
        self.network_paused = bool(paused)

    # -- step 3: admission + allocation ----------------------------------------
    def queue_depth(self, qos: Optional[QoS] = None) -> int:
        return (len(self._queues) if qos is None
                else self._queues.depth(qos))

    def pump(self, now: float) -> List[Transfer]:
        """Admit queued transfers in QoS-priority, FIFO-within-class order
        (the shared :class:`~repro.core.datapath.AdmissionQueues` pump)."""
        if self.network_paused:
            return []

        def try_admit(t: Transfer) -> Admit:
            if len(self._live) >= self.cfg.max_concurrent_transfers:
                return Admit.STOP
            need = self.pool.slots_needed(t.nbytes) * self.pool.slot_bytes
            if self._expected_footprint(t.nbytes) > \
                    self.pool.available_bytes or \
                    need > self.pool.available_bytes:
                return Admit.DEFER
            slots = self.pool.alloc(t.app_id, t.nbytes, now)
            if slots is None:
                return Admit.DEFER
            t.slots = slots
            self._live[t.xfer_id] = t
            return Admit.OK

        def fallback(t: Transfer) -> None:
            # §5: low-QoS transfers fall back to DRAM buffers
            self.memory_fallbacks += 1

        return self._queues.pump(try_admit, fallback)

    # -- steps 4-6: arrival notification + release ------------------------------
    def complete(self, xfer_id: int, now: float) -> None:
        """Application finished consuming; release slots back to the pool.

        Idempotent w.r.t. escape: an escape COPY may already have evicted
        the transfer's slots (and ``tick_escape`` may have dropped its
        bookkeeping) — completing such a transfer is a no-op, not an error.
        """
        t = self._live.pop(xfer_id, None)
        if t is None:
            return
        # slots may have been evicted by an escape COPY already
        live = [s for s in t.slots if s in self.pool._slots]
        if live:
            self.pool.free(t.app_id, live)

    def tick_escape(self, now: float) -> List[Tuple[Action, object]]:
        acts = self.escape.step(self.pool, now)
        for a, _ in acts:
            if a is Action.MARK_ECN:
                self.window.on_ecn()
        if all(a is Action.NONE for a, _ in acts):
            self.window.on_quiet()
        # drop bookkeeping for transfers fully evicted by COPY
        for xid in [x for x, t in self._live.items()
                    if not any(s in self.pool._slots for s in t.slots)]:
            self._live.pop(xid)
        return acts

    # -- introspection -----------------------------------------------------------
    def stats(self) -> dict:
        return dict(pool_available=self.pool.available_bytes,
                    live_transfers=len(self._live),
                    queued=len(self._queues),
                    queued_by_qos={q.name: self._queues.depth(q)
                                   for q in QoS},
                    window_cap=self.window.cap_bytes,
                    escape=dataclasses.asdict(self.escape.stats),
                    network_paused=self.network_paused,
                    memory_fallbacks=self.memory_fallbacks)
