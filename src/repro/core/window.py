"""Receiver-side READ control (paper §4.1.2).

Two coupled sliding windows govern large-message ("READ") admission:

* a **concurrency window** — at most ``max_concurrency`` READs in flight
  (paper: 32; Fig. 5 shows 4 already saturates 2x100 Gbps);
* an **in-flight-bytes window** — at most ``max_inflight_bytes`` of requested
  data in transit (paper: 8 MB).

Messages are fragmented to ``fragment_bytes`` (paper: 256 KB) before entering
the window.  Requests that do not fit wait in a FIFO queue (paper: "queued and
deferred until sufficient window capacity is allocated").

The window also implements the DCQCN-inspired AIMD backpressure that replaces
ECN-in-CNP on TPU (DESIGN.md §2, assumption 2): ``on_ecn`` multiplicatively
shrinks the byte window; ``on_quiet`` additively recovers it.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Tuple

FRAGMENT_BYTES_DEFAULT = 256 << 10   # paper §4.1.2
MAX_CONCURRENCY_DEFAULT = 32         # paper Fig. 5 / §4.1.2
MAX_INFLIGHT_BYTES_DEFAULT = 8 << 20 # paper §4.1.2


def fragment(nbytes: int, fragment_bytes: int = FRAGMENT_BYTES_DEFAULT
             ) -> List[int]:
    """Slice a message into fragments of at most ``fragment_bytes``."""
    if nbytes <= 0:
        raise ValueError("message must be positive-sized")
    full, rem = divmod(nbytes, fragment_bytes)
    return [fragment_bytes] * full + ([rem] if rem else [])


@dataclasses.dataclass
class ReadRequest:
    req_id: int
    nbytes: int
    submit_ts: float
    admit_ts: Optional[float] = None


class ReadWindow:
    """Concurrency + in-flight-bytes sliding windows with FIFO deferral."""

    def __init__(self,
                 max_concurrency: int = MAX_CONCURRENCY_DEFAULT,
                 max_inflight_bytes: int = MAX_INFLIGHT_BYTES_DEFAULT,
                 fragment_bytes: int = FRAGMENT_BYTES_DEFAULT,
                 min_inflight_bytes: Optional[int] = None,
                 aimd_beta: float = 0.5,
                 aimd_step: int = 256 << 10):
        self.max_concurrency = max_concurrency
        self.max_inflight_bytes = max_inflight_bytes
        self.fragment_bytes = fragment_bytes
        # AIMD state (escape backpressure)
        self._cap_bytes = max_inflight_bytes
        self._min_bytes = min_inflight_bytes or fragment_bytes
        self._beta = aimd_beta
        self._step = aimd_step
        # windows
        self.inflight: Dict[int, ReadRequest] = {}
        self.inflight_bytes = 0
        self.pending: Deque[ReadRequest] = collections.deque()
        self._next_id = 0
        # stats
        self.admitted = 0
        self.deferred = 0
        self.ecn_events = 0

    # -- public API ----------------------------------------------------------
    @property
    def cap_bytes(self) -> int:
        return self._cap_bytes

    def submit(self, nbytes: int, now: float) -> int:
        """Submit a READ; returns its id. Fragmentation happens on admit."""
        if nbytes > self.fragment_bytes:
            # window admission operates on fragments; large messages are
            # split and each fragment becomes its own READ (paper §4.1.2).
            raise ValueError(
                "submit() takes a single fragment; use submit_message()")
        req = ReadRequest(self._next_id, nbytes, now)
        self._next_id += 1
        self.pending.append(req)
        return req.req_id

    def submit_message(self, nbytes: int, now: float) -> List[int]:
        return [self.submit(f, now) for f in fragment(nbytes,
                                                      self.fragment_bytes)]

    def pump(self, now: float) -> List[ReadRequest]:
        """Admit FIFO-pending requests while both windows have room."""
        admitted = []
        while self.pending:
            head = self.pending[0]
            if (len(self.inflight) + 1 > self.max_concurrency or
                    self.inflight_bytes + head.nbytes > self._cap_bytes):
                self.deferred += 1
                break
            self.pending.popleft()
            head.admit_ts = now
            self.inflight[head.req_id] = head
            self.inflight_bytes += head.nbytes
            self.admitted += 1
            admitted.append(head)
        return admitted

    def complete(self, req_id: int) -> ReadRequest:
        req = self.inflight.pop(req_id)
        self.inflight_bytes -= req.nbytes
        return req

    # -- AIMD backpressure (DESIGN.md: ECN -> window) -------------------------
    def on_ecn(self) -> None:
        self.ecn_events += 1
        self._cap_bytes = max(self._min_bytes,
                              int(self._cap_bytes * self._beta))

    def on_quiet(self) -> None:
        self._cap_bytes = min(self.max_inflight_bytes,
                              self._cap_bytes + self._step)

    # -- invariants (used by property tests) ---------------------------------
    def check_invariants(self) -> None:
        assert len(self.inflight) <= self.max_concurrency
        assert self.inflight_bytes <= self._cap_bytes <= self.max_inflight_bytes
        assert self.inflight_bytes == sum(r.nbytes
                                          for r in self.inflight.values())
        assert self._cap_bytes >= self._min_bytes
