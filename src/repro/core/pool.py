"""Cache-resident buffer pool (paper §4.1, §4.2.1).

Two variants:

* :class:`SlabPool` — the host-side slab allocator that backs the Jet service
  (admission control, serving engine, and the discrete-event simulator). It
  manages the reserved "LLC" area at 4 KB slot granularity, tracks per-app
  allocations in arrival order (monotonic timestamps -> O(1) straggler head
  check, paper §4.3), and supports the escape controller's *replace* action
  (swap a straggler slot for a DRAM-backed one so the recyclable size is
  constant).

* :class:`DevicePool` — a functional, jit-compatible allocator used by the
  paged KV cache on device (the same slab idea expressed as a free bitmap in a
  jnp array).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp

SLOT_BYTES_DEFAULT = 4 * 1024  # paper: slab granularity 4 KB


# --------------------------------------------------------------------------- #
# Host-side slab pool
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _Slot:
    slot_id: int
    app_id: Optional[int] = None
    alloc_ts: float = 0.0
    replaced: bool = False  # True => DRAM-backed escape slot


class SlabPool:
    """Slab allocator over the reserved cache area (paper §4.2).

    ``capacity_bytes`` is the reserved LLC area (12 MB in the paper).
    Allocations are rounded up to whole 4 KB slots.  Slots belonging to one
    app are kept in allocation order, so the oldest slot is O(1) to find
    (paper: "checking the timestamp of the head node ... O(1)").
    """

    def __init__(self, capacity_bytes: int = 12 << 20,
                 slot_bytes: int = SLOT_BYTES_DEFAULT):
        if capacity_bytes % slot_bytes:
            raise ValueError("capacity must be a multiple of slot size")
        self.slot_bytes = slot_bytes
        self.num_slots = capacity_bytes // slot_bytes
        self._free: Deque[int] = collections.deque(range(self.num_slots))
        self._slots: Dict[int, _Slot] = {}
        # per-app FIFO of live slot ids (allocation order == timestamp order)
        self._by_app: Dict[int, Deque[int]] = collections.defaultdict(
            collections.deque)
        # escape bookkeeping
        self._replaced_live: Set[int] = set()
        self.replace_mem_bytes = 0          # DRAM currently borrowed (escape)
        self._next_extra_id = self.num_slots

    # -- basic queries ------------------------------------------------------
    @property
    def capacity_bytes(self) -> int:
        return self.num_slots * self.slot_bytes

    @property
    def used_slots(self) -> int:
        return len(self._slots)

    @property
    def available_bytes(self) -> int:
        return len(self._free) * self.slot_bytes

    @property
    def available_fraction(self) -> float:
        return len(self._free) / max(
            1, len(self._free) + len(self._slots) - len(self._replaced_live))

    def held_slots(self, app_id: int) -> int:
        return len(self._by_app.get(app_id, ()))

    def apps(self) -> List[int]:
        return [a for a, q in self._by_app.items() if q]

    # -- alloc / free -------------------------------------------------------
    def slots_needed(self, nbytes: int) -> int:
        return max(1, -(-nbytes // self.slot_bytes))

    def alloc(self, app_id: int, nbytes: int, now: float) -> Optional[List[int]]:
        """Allocate slots for ``nbytes``; None if the pool can't satisfy it."""
        n = self.slots_needed(nbytes)
        if n > len(self._free):
            return None
        ids = [self._free.popleft() for _ in range(n)]
        for sid in ids:
            self._slots[sid] = _Slot(sid, app_id, now)
            self._by_app[app_id].append(sid)
        return ids

    def free(self, app_id: int, slot_ids: List[int]) -> None:
        for sid in slot_ids:
            slot = self._slots.pop(sid, None)
            if slot is None:
                raise KeyError(f"double free of slot {sid}")
            if slot.app_id != app_id:
                raise ValueError(f"slot {sid} owned by {slot.app_id}, "
                                 f"freed by {app_id}")
            try:
                self._by_app[app_id].remove(sid)
            except ValueError:
                pass
            if slot.replaced:
                # a DRAM-backed escape slot retires instead of rejoining
                self._replaced_live.discard(sid)
                self.replace_mem_bytes -= self.slot_bytes
            else:
                self._free.append(sid)

    # -- straggler accounting (paper §4.3) ----------------------------------
    def oldest_age(self, app_id: int, now: float) -> float:
        q = self._by_app.get(app_id)
        if not q:
            return 0.0
        return now - self._slots[q[0]].alloc_ts

    def straggler_slots(self, app_id: int, now: float,
                        age_threshold: float) -> List[int]:
        """Slots held longer than ``age_threshold`` (oldest-first prefix)."""
        out: List[int] = []
        for sid in self._by_app.get(app_id, ()):
            if now - self._slots[sid].alloc_ts > age_threshold:
                out.append(sid)
            else:
                break  # timestamps are monotone within an app's deque
        return out

    def straggler_ratio(self, app_id: int, now: float,
                        age_threshold: float) -> float:
        held = self.held_slots(app_id)
        if held == 0:
            return 0.0
        return len(self.straggler_slots(app_id, now, age_threshold)) / held

    # -- escape actions (paper §4.3) -----------------------------------------
    def replace(self, slot_ids: List[int]) -> int:
        """Escape action 1: *replace straggler buffers*.

        Each straggler slot is re-backed by DRAM (it no longer occupies the
        reserved cache) and a fresh cache slot joins the free list, keeping the
        recyclable pool size constant.  Returns bytes of DRAM borrowed.
        """
        borrowed = 0
        for sid in slot_ids:
            slot = self._slots.get(sid)
            if slot is None or slot.replaced:
                continue
            slot.replaced = True
            self._replaced_live.add(sid)
            self.replace_mem_bytes += self.slot_bytes
            borrowed += self.slot_bytes
            # fresh DRAM-backed identity joins the free list in its stead
            self._free.append(self._next_extra_id)
            self._next_extra_id += 1
        return borrowed

    def evict_app(self, app_id: int) -> int:
        """Escape action 2: *copy to memory* — forcibly release all of an
        app's cache slots (data now lives in DRAM).  Returns bytes freed."""
        ids = list(self._by_app.get(app_id, ()))
        n = len(ids)
        if n:
            self.free(app_id, ids)
        return n * self.slot_bytes


# --------------------------------------------------------------------------- #
# Device-side functional pool (paged KV cache backing)
# --------------------------------------------------------------------------- #
@jax.tree_util.register_pytree_node_class
class DevicePool:
    """Functional slab pool: a free bitmap over ``num_slots`` device pages."""

    def __init__(self, free: jnp.ndarray):
        self.free = free  # bool[num_slots]

    # pytree plumbing
    def tree_flatten(self):
        return (self.free,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def create(cls, num_slots: int) -> "DevicePool":
        return cls(jnp.ones((num_slots,), dtype=bool))

    @property
    def num_slots(self) -> int:
        return self.free.shape[0]

    def available(self) -> jnp.ndarray:
        return jnp.sum(self.free)

    def alloc(self, n: int) -> Tuple["DevicePool", jnp.ndarray, jnp.ndarray]:
        """Allocate ``n`` slots (static).  Returns (pool, idx[n], ok).

        When fewer than ``n`` slots are free, ``ok`` is False and the invalid
        positions of ``idx`` are -1 (callers route those to the escape path —
        the DRAM-backed overflow tier)."""
        idx = jnp.flatnonzero(self.free, size=n, fill_value=-1)
        ok = jnp.all(idx >= 0)
        taken = jnp.zeros_like(self.free).at[jnp.where(idx >= 0, idx, 0)].set(
            idx >= 0)
        return DevicePool(self.free & ~taken), idx, ok

    def release(self, idx: jnp.ndarray) -> "DevicePool":
        """Free slots listed in ``idx`` (entries < 0 are ignored)."""
        valid = idx >= 0
        free = self.free.at[jnp.where(valid, idx, 0)].max(valid)
        return DevicePool(free)
