"""Discrete-event (fluid, 1 us tick) simulator of the RDMA receiver host
datapath — the measurement substrate of the paper (§2, §6).

The container has no RNIC/DRAM-contention hardware, so the paper's
*measurement* results are reproduced with a calibrated simulator that models:

  sender (DCQCN rate machine, PFC pause)  ->  link  ->  RNIC FIFO buffer
      ->  drain to host, gated by
            - PCIe bandwidth
            - [ddio mode]   DRAM bandwidth left over by contending CPU cores,
                            x2 traffic on DDIO write-allocate miss (leaky DMA)
            - [jet  mode]   free space in the cache-resident buffer pool
      ->  post-NIC residence (consumer latency, message- or slice-granular
          release = the recycle controller), stragglers, escape ladder.

Everything observable in the paper's figures is surfaced in SimResult:
goodput, avg/P99 latency, PFC pause duration, CNP count, DDIO miss rate,
DRAM bandwidth consumed, pool occupancy, escape action counts.

Calibration constants mirror the paper's two testbeds:
  * 2x25 Gbps PFC-enabled, PCIe3 x8,  ~64 GB/s DRAM, DDIO 4 MB
  * 2x100 Gbps PFC-free,   PCIe4 x16, ~250 GB/s DRAM, DDIO 6 MB
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from .datapath import (ClassBytes, HostDatapath, N_QOS,  # noqa: F401
                       hold_us_baseline, hold_us_jet)
from .dcqcn import DcqcnConfig, DcqcnRate
from .recycle import RecycleModel, paper_default


# --------------------------------------------------------------------------- #
# Configuration
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class SimConfig:
    mode: str = "ddio"                 # "ddio" (baseline) | "jet"
    pfc_enabled: bool = False
    sim_time_s: float = 0.03
    dt_us: float = 1.0

    # network / workload
    line_rate_gbps: float = 200.0      # dual-port 100 Gbps
    num_qps: int = 32
    msg_bytes: int = 256 << 10
    incast_senders: int = 1            # >1 models in-cast (HPC all-to-all)
    offered_gbps: Optional[float] = None  # open-loop load cap (None=saturate)

    # host
    pcie_gbps: float = 2048.0          # PCIe 4.0 x16 ~ 32 GB/s
    membw_total_gbps: float = 2000.0   # 250 GB/s
    cpu_membw_gbps: float = 1760.0     # 220 GB/s of CPU-side contention
    cpu_membw_schedule: Optional[Callable[[float], float]] = None
    app_gbps: float = 3200.0           # app-side consumption bandwidth
    consumer_latency_us: float = 60.0  # SSD/GPU/compute hand-off latency

    # DDIO (baseline)
    ddio_bytes: int = 6 << 20
    miss_knee: float = 0.5             # miss ramps over knee*ddio_bytes

    # RNIC buffer & congestion signalling
    rnic_buffer_bytes: int = 2 << 20
    pfc_xoff: float = 0.80
    pfc_xon: float = 0.50
    # per-class receiver PFC: evaluate the xoff/xon watermarks on each
    # admission class's occupancy of its 1/N_QOS buffer partition and
    # pause only that class on the access link (mirrors the switch's
    # 802.1Qbb per-priority pause, whose watermarks are also fractions
    # of a per-class partition — evaluating against the *full* shared
    # buffer would assert too late and forfeit losslessness).  False =
    # legacy whole-link gate on total occupancy.
    host_pfc_per_tc: bool = False
    ecn_threshold: float = 0.15
    cnp_interval_us: float = 50.0
    # ConnectX-6 DX marks CNPs on an RNIC-buffer watermark (§2.1); older
    # CX-4 (25G testbed) lacks the feature and relies on PFC backpressure.
    rnic_ecn_cnp: bool = True

    # Jet
    jet_pool_bytes: int = 12 << 20
    recycle: RecycleModel = dataclasses.field(default_factory=paper_default)
    straggler_frac: float = 0.005
    straggler_mult: float = 20.0
    cache_safe: float = 0.20
    cache_danger: float = 0.05
    mem_esc_bytes: int = 2 << 20

    dcqcn: DcqcnConfig = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.dcqcn is None:
            self.dcqcn = DcqcnConfig(line_rate_gbps=self.line_rate_gbps *
                                     self.incast_senders)


def testbed_25g(mode: str = "ddio", **kw) -> SimConfig:
    """2x25 Gbps PFC-enabled testbed (§2.1): PCIe3 x8, 64 GB/s DRAM."""
    base = dict(pfc_enabled=True, line_rate_gbps=50.0, pcie_gbps=500.0,
                membw_total_gbps=512.0, cpu_membw_gbps=456.0,
                ddio_bytes=4 << 20, rnic_ecn_cnp=False)
    base.update(kw)
    return SimConfig(mode=mode, **base)


def testbed_100g(mode: str = "ddio", **kw) -> SimConfig:
    """2x100 Gbps PFC-free testbed (§2.1): PCIe4 x16, 250 GB/s DRAM."""
    base = dict(pfc_enabled=False, line_rate_gbps=200.0, pcie_gbps=2048.0,
                membw_total_gbps=2000.0, cpu_membw_gbps=1760.0,
                ddio_bytes=6 << 20)
    base.update(kw)
    return SimConfig(mode=mode, **base)


# --------------------------------------------------------------------------- #
# Results
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class SimResult:
    goodput_gbps: float
    avg_latency_us: float
    p99_latency_us: float
    p999_latency_us: float
    pfc_pause_us: float
    cnp_count: float
    ddio_miss_rate: float
    nic_dram_gbps: float          # DRAM bandwidth induced by the datapath
    pool_peak_bytes: int
    pool_avg_bytes: float
    escape_replaces: int
    escape_copies: int
    escape_ecn: int
    escape_dram_gbps: float
    dropped_bytes: int
    completed_messages: int
    mem_fallback_bytes: float = 0.0    # LOW-QoS bytes spilled to DRAM (§5)

    def as_row(self) -> dict:
        return dataclasses.asdict(self)


# --------------------------------------------------------------------------- #
# The step-able receiver host (the tick body behind run_sim and the fabric)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class HostFeedback:
    """Per-tick receiver feedback routed back to the sender/fabric."""
    accepted: float = 0.0     # bytes taken into the RNIC buffer
    dropped: float = 0.0      # bytes lost at the RNIC (lossy mode)
    cnps: int = 0             # congestion notifications for the sender(s)
    pfc_paused: bool = False  # receiver asserts pause on its access link
    accepted_qos: Optional[List[float]] = None  # per-class split (QoS order)


class ReceiverHost:
    """The paper's receiver datapath advanced one fluid tick at a time.

    A thin network-facing wrapper around :class:`~repro.core.datapath
    .HostDatapath` (the shared admission/QoS/recycle/escape state
    machine): this class owns what the *link* sees — PFC pause state,
    RNIC-watermark CNP pacing, drop accounting, per-message latency
    bookkeeping — and delegates everything behind the RNIC to the
    datapath.  The caller supplies the bytes arriving on the access link
    each tick (already gated by any PFC pause it honours), either as a
    plain float (all NORMAL QoS — bit-identical to the pre-datapath
    scalar buffer) or as a per-class ``[HIGH, NORMAL, LOW]`` sequence,
    and routes the returned CNPs to the congestion-controlled sender(s).
    ``run_sim`` drives exactly one of these; ``repro.fabric`` composes N
    of them behind a Clos fabric.
    """

    def __init__(self, cfg: SimConfig, sim_ticks: Optional[int] = None):
        c = self.cfg = cfg
        self.dt = c.dt_us
        ticks = (sim_ticks if sim_ticks is not None
                 else int(c.sim_time_s * 1e6 / self.dt))
        self.dp = HostDatapath(c, ticks, dt_us=self.dt)

        self.pfc_paused = False
        self.pfc_paused_cls = [False] * N_QOS  # per-class pause state
        self.pfc_pause_us = 0.0
        self.cnp_count = 0.0
        self.cnp_accum_us = c.cnp_interval_us  # allow an immediate first CNP

        self.total_arrived = 0.0          # accepted into RNIC buffer
        self.total_drained = 0.0          # delivered to host datapath
        self.dropped = 0.0

        # Message latency tracking.  The num_qps concurrent QPs stripe
        # their messages across the wire, so one "generation" = num_qps
        # messages that start and finish together; per-message latency is
        # the generation's transit time (round-robin interleave approx).
        self.msg = float(c.num_qps * c.msg_bytes)
        self.starts: List[float] = []     # t of first byte into RNIC
        self.dones: List[float] = []      # t of last byte drained
        self.n_started = 0
        self.n_drained_msgs = 0

        self.hold_b = hold_us_baseline(c)
        self.hold_j = hold_us_jet(c)
        self.t = 0

    def crash_reset(self) -> None:
        """NIC/host crash (fabric fault layer): zero the admission and
        pause state the link sees — the datapath's in-flight bytes and
        the PFC gate — keeping cumulative counters and message
        bookkeeping (a restarted host resumes the same run)."""
        self.dp.crash_reset()
        self.pfc_paused = False
        self.pfc_paused_cls = [False] * N_QOS

    # network-facing views of the shared datapath state
    @property
    def rnic_q(self) -> float:
        return self.dp.rnic_q

    @property
    def paused_classes(self) -> frozenset:
        """QoS classes currently paused on the access link.  Legacy
        whole-link mode reports every class while paused — the gate
        stalls them all."""
        if self.cfg.host_pfc_per_tc:
            return frozenset(i for i, p in enumerate(self.pfc_paused_cls)
                             if p)
        return frozenset(range(N_QOS)) if self.pfc_paused else frozenset()

    @property
    def resident(self) -> float:
        return self.dp.resident

    def step(self, arriving: ClassBytes) -> HostFeedback:
        """Advance one tick with ``arriving`` bytes offered on the link
        (a float = all NORMAL class, or a per-QoS-class sequence)."""
        c = self.cfg
        dt = self.dt
        t = self.t
        if t >= self.dp.horizon:
            # past this point the release arrays would silently stop
            # cycling bytes and the pool would deadlock — fail loudly
            raise RuntimeError(
                f"ReceiverHost stepped past its horizon ({self.dp.horizon} "
                f"ticks); construct it with sim_ticks covering the run")
        now_us = t * dt
        fb = HostFeedback()
        cpu_bw = (c.cpu_membw_schedule(now_us * 1e-6)
                  if c.cpu_membw_schedule else c.cpu_membw_gbps)

        # ---- link -> RNIC (QoS-classed admission) ------------------------- #
        accepted, per_class, total_in = self.dp.admit_link(arriving)
        self.dropped += total_in - accepted
        fb.dropped = total_in - accepted
        fb.accepted = accepted
        fb.accepted_qos = per_class
        # message start timestamps
        new_started = int((self.total_arrived + accepted) // self.msg) \
            - int(self.total_arrived // self.msg)
        if self.total_arrived == 0 and accepted > 0 and self.n_started == 0:
            new_started += 1
        for _ in range(new_started):
            self.starts.append(now_us)
            self.n_started += 1
        self.total_arrived += accepted

        # ---- the shared datapath tick: drain / release / escape ----------- #
        dfb = self.dp.step(t, cpu_bw)
        drained = dfb.drained
        # message drain-completion timestamps
        new_done = int((self.total_drained + drained) // self.msg) \
            - int(self.total_drained // self.msg)
        for _ in range(new_done):
            self.dones.append(now_us)
            self.n_drained_msgs += c.num_qps
        self.total_drained += drained
        # escape-ladder ECN (rung 3) surfaces as CNPs toward the sender
        if dfb.ecn_fires:
            self.cnp_count += dfb.ecn_fires
            fb.cnps += dfb.ecn_fires

        # ---- congestion signalling ---------------------------------------- #
        q_frac = self.dp.rnic_q / c.rnic_buffer_bytes
        if c.pfc_enabled:
            if c.host_pfc_per_tc:
                # per-class watermarks on each class's 1/N_QOS buffer
                # partition: the congested class pauses without stalling
                # the others, and the summed assert points leave the
                # same headroom as the legacy whole-buffer gate (pausing
                # on fractions of the *total* buffer would fire too late
                # and drop — the receiver-side twin of the switch's
                # partitioned per-priority watermarks)
                share = c.rnic_buffer_bytes / N_QOS
                for i in range(N_QOS):
                    fr = self.dp.qos_q[i] / share
                    if self.pfc_paused_cls[i]:
                        if fr < c.pfc_xon:
                            self.pfc_paused_cls[i] = False
                    elif fr > c.pfc_xoff:
                        self.pfc_paused_cls[i] = True
                self.pfc_paused = any(self.pfc_paused_cls)
            else:
                if self.pfc_paused:
                    if q_frac < c.pfc_xon:
                        self.pfc_paused = False
                elif q_frac > c.pfc_xoff:
                    self.pfc_paused = True
            if self.pfc_paused:
                self.pfc_pause_us += dt
        # RNIC-watermark CNPs (ConnectX-6 DX feature, §2.1)
        self.cnp_accum_us += dt
        if (c.rnic_ecn_cnp and q_frac > c.ecn_threshold
                and self.cnp_accum_us >= c.cnp_interval_us):
            self.cnp_accum_us = 0.0
            self.cnp_count += 1
            fb.cnps += 1

        fb.pfc_paused = self.pfc_paused
        self.t += 1
        return fb

    def finalize(self) -> SimResult:
        """Aggregate the per-tick state into the paper-facing SimResult."""
        c = self.cfg
        dp = self.dp
        ticks = max(1, self.t)
        sim_us = ticks * self.dt
        goodput = self.total_drained * 8.0 / (sim_us * 1e-6) / 1e9
        post = (self.hold_j if c.mode == "jet" else self.hold_b)
        lats = [d - s + post for s, d in zip(self.starts, self.dones)]
        lats = lats[len(lats) // 10:]      # drop warm-up decile
        if not lats:
            lats = [float("nan")]
        arr = np.array(lats)
        return SimResult(
            goodput_gbps=goodput,
            avg_latency_us=float(np.mean(arr)),
            p99_latency_us=float(np.percentile(arr, 99)),
            p999_latency_us=float(np.percentile(arr, 99.9)),
            pfc_pause_us=self.pfc_pause_us,
            cnp_count=self.cnp_count,
            ddio_miss_rate=(dp.miss_sum / dp.miss_n)
            if dp.miss_n else 0.0,
            nic_dram_gbps=dp.nic_dram_bytes * 8.0 / (sim_us * 1e-6) / 1e9,
            pool_peak_bytes=int(dp.pool_peak),
            pool_avg_bytes=dp.pool_sum / ticks,
            escape_replaces=dp.replaces,
            escape_copies=dp.copies,
            escape_ecn=dp.ecns,
            escape_dram_gbps=dp.escape_dram_bytes * 8.0
            / (sim_us * 1e-6) / 1e9,
            dropped_bytes=int(self.dropped),
            completed_messages=self.n_drained_msgs,
            mem_fallback_bytes=dp.mem_fallback_bytes,
        )


# --------------------------------------------------------------------------- #
# Simulator
# --------------------------------------------------------------------------- #
class ReceiverSim:
    """Single-host driver: one DCQCN sender feeding one ReceiverHost.

    Preserves the original ``run()`` API and its exact numerics: the
    sender is gated by the receiver's PFC state and receives the
    receiver's CNPs within the same tick.
    """

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg

    # message-granular post-NIC hold time (baseline, non-pipelined)
    def _hold_us_baseline(self) -> float:
        return hold_us_baseline(self.cfg)

    # slice-granular hold (Jet recycle pipeline)
    def _hold_us_jet(self) -> float:
        return hold_us_jet(self.cfg)

    def run(self) -> SimResult:
        c = self.cfg
        dt = c.dt_us                       # us
        ticks = int(c.sim_time_s * 1e6 / dt)
        bytes_per_gbps_tick = 1e9 / 8.0 * dt * 1e-6   # bytes per (Gbps*tick)

        rate = DcqcnRate(c.dcqcn)
        host = ReceiverHost(c, sim_ticks=ticks)
        for _ in range(ticks):
            offered = min(rate.advance(dt), c.line_rate_gbps *
                          c.incast_senders)
            if c.offered_gbps is not None:
                offered = min(offered, c.offered_gbps)
            arriving = (0.0 if host.pfc_paused
                        else offered * bytes_per_gbps_tick)
            fb = host.step(arriving)
            for _ in range(fb.cnps):
                rate.on_cnp()
        return host.finalize()


def run_sim(cfg: SimConfig) -> SimResult:
    return ReceiverSim(cfg).run()
