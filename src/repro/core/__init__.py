"""Jet/RDCA core: the paper's primary contribution.

- pool:      cache-resident buffer pool (slab; host + device variants)
- window:    receiver-side READ control (concurrency + in-flight bytes)
- recycle:   swift cache recycle model (pipeline, threads, offload)
- escape:    cache-pressure-aware escape ladder (replace / copy / ECN)
- dcqcn:     DCQCN sender rate machine (congestion-control substrate)
- datapath:  the shared host receive datapath — ONE admission/QoS/
             recycle/escape state machine for every layer that models a
             receiving host
- jet:       the Jet service facade (registration, QoS admission)
- simulator: receive-datapath discrete-event simulator (paper figures)

HostDatapath layering (who wraps what)
--------------------------------------
``datapath.HostDatapath`` (tick-driven fluid machine) and
``datapath.AdmissionQueues`` (event-driven QoS pump) are the single
source of truth for the §3-§4 host-side workflow:

* ``simulator.ReceiverHost`` wraps ``HostDatapath`` and adds the
  network face (PFC pause, RNIC-watermark CNPs, message latency) —
  this is what ``run_sim`` and the ``repro.fabric`` scalar driver
  advance;
* ``repro.fabric.sweep`` / ``repro.fabric.vector`` advance the same
  step semantics in stacked-array form (``[G, R]`` receivers with the
  QoS classes as a ``[G, Q, R]`` block) — the scalar machine here is
  their float64 verification reference;
* ``jet.JetService`` wraps ``AdmissionQueues`` around the concrete
  pool/window/recycle/escape objects — this is what the serving engine
  drives, and its ``set_backpressure`` gate is how fabric congestion
  reaches decode-lane admission (``examples/serving_on_fabric.py``).
"""
from .datapath import (Admit, AdmissionQueues, DatapathFeedback,
                       HostDatapath, N_QOS, expected_footprint)
from .dcqcn import DcqcnConfig, DcqcnRate
from .escape import Action, EscapeConfig, EscapeController, EscapeStats
from .jet import JetConfig, JetService, QoS, SMALL_MSG_BYTES
from .pool import DevicePool, SlabPool
from .recycle import (RecycleModel, little_law_bytes, paper_default,
                      paper_unoptimized, slice_message)
from .simulator import (ReceiverSim, SimConfig, SimResult, run_sim,
                        testbed_100g, testbed_25g)
from .window import ReadWindow, fragment

__all__ = [
    "Action", "Admit", "AdmissionQueues", "DatapathFeedback",
    "DcqcnConfig", "DcqcnRate", "DevicePool", "EscapeConfig",
    "EscapeController", "EscapeStats", "HostDatapath", "JetConfig",
    "JetService", "N_QOS", "QoS",
    "ReadWindow", "ReceiverSim", "RecycleModel", "SimConfig", "SimResult",
    "SlabPool", "SMALL_MSG_BYTES", "expected_footprint", "fragment",
    "little_law_bytes",
    "paper_default", "paper_unoptimized", "run_sim", "slice_message",
    "testbed_100g", "testbed_25g",
]
