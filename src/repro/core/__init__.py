"""Jet/RDCA core: the paper's primary contribution.

- pool:      cache-resident buffer pool (slab; host + device variants)
- window:    receiver-side READ control (concurrency + in-flight bytes)
- recycle:   swift cache recycle model (pipeline, threads, offload)
- escape:    cache-pressure-aware escape ladder (replace / copy / ECN)
- dcqcn:     DCQCN sender rate machine (congestion-control substrate)
- jet:       the Jet service facade (registration, QoS admission)
- simulator: receive-datapath discrete-event simulator (paper figures)
"""
from .dcqcn import DcqcnConfig, DcqcnRate
from .escape import Action, EscapeConfig, EscapeController, EscapeStats
from .jet import JetConfig, JetService, QoS, SMALL_MSG_BYTES
from .pool import DevicePool, SlabPool
from .recycle import (RecycleModel, little_law_bytes, paper_default,
                      paper_unoptimized, slice_message)
from .simulator import (ReceiverSim, SimConfig, SimResult, run_sim,
                        testbed_100g, testbed_25g)
from .window import ReadWindow, fragment

__all__ = [
    "Action", "DcqcnConfig", "DcqcnRate", "DevicePool", "EscapeConfig",
    "EscapeController", "EscapeStats", "JetConfig", "JetService", "QoS",
    "ReadWindow", "ReceiverSim", "RecycleModel", "SimConfig", "SimResult",
    "SlabPool", "SMALL_MSG_BYTES", "fragment", "little_law_bytes",
    "paper_default", "paper_unoptimized", "run_sim", "slice_message",
    "testbed_100g", "testbed_25g",
]
