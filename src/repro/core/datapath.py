"""The shared host receive datapath (paper §3–§4): one state machine for
admission, QoS queueing, recycle release and the escape ladder, used by
every layer that models a receiving host.

The paper's claim is that the *host-side* cache-pool workflow (admission
by expected footprint, QoS-classed queues, swift recycle, the
replace/copy/ECN escape ladder) and the *network-side* congestion control
(ECN/CNP/PFC) only work because they co-operate.  Before this module the
repo had three parallel realizations of that workflow — ``JetService``
(event-driven serving), ``ReceiverSim`` (fluid simulation) and the fabric
receiver hosts — that could drift apart.  Now there is one:

``HostDatapath``
    The tick-driven *fluid* state machine: per-QoS RNIC buffer classes,
    drain to the cache pool (Jet) or through DDIO (baseline), release
    rings (the recycle model), the escape ladder, low-QoS DRAM spill
    (§5).  Wrapped by :class:`repro.core.simulator.ReceiverHost` (and
    therefore by ``run_sim`` and the fabric driver), and mirrored in
    stacked-array form by :mod:`repro.fabric.sweep` and
    :mod:`repro.fabric.vector` — the step semantics here are the scalar
    reference for both vector engines.

``AdmissionQueues``
    The event-driven *discrete* admission machinery: QoS-priority FIFO
    queues with the §3.2 pump order and the §5 low-QoS fallback.
    Wrapped by :class:`repro.core.jet.JetService` (and therefore by the
    serving engine).

Both share this module's :class:`QoS` classes, priority order and the
``expected_footprint`` admission rule, so a QoS decision made by the
serving engine and one made inside a fabric sweep follow the same policy.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Callable, Deque, List, Optional, Sequence, Tuple, Union

import numpy as np


class QoS(enum.IntEnum):
    """Transfer service classes (paper §3.2); lower value = higher
    priority.  Priority order is the iteration order everywhere: queue
    pump, RNIC buffer space allocation, drain budget."""
    HIGH = 0
    NORMAL = 1
    LOW = 2


N_QOS = len(QoS)


def expected_footprint(nbytes: int, expected_timespan_us: float) -> int:
    """Admission rule (§3.2 step 2): expected throughput x timespan,
    capped by the transfer size itself (Little's law working set)."""
    rate_gbps = 8.0 * nbytes / max(expected_timespan_us, 1e-9) / 1e3
    little = rate_gbps * 1e9 / 8.0 * expected_timespan_us * 1e-6
    return min(nbytes, int(little))


# --------------------------------------------------------------------------- #
# Event-driven admission (wrapped by JetService)
# --------------------------------------------------------------------------- #
class Admit(enum.Enum):
    """Outcome of a ``try_admit`` probe during a queue pump."""
    OK = "ok"          # admitted; pop and continue with this class
    DEFER = "defer"    # resource pressure; LOW falls back, others wait
    STOP = "stop"      # global limit (e.g. max concurrent); stop pumping


class AdmissionQueues:
    """QoS-priority FIFO admission queues (paper §3.2 step 3).

    Generic over the admitted item type: the caller supplies a
    ``try_admit(item) -> Admit`` probe (pool allocation, lane
    availability, ...) and optionally a ``fallback(item)`` sink invoked
    when a LOW-class head cannot be admitted (§5: low-QoS transfers fall
    back to DRAM buffers instead of waiting for cache).
    """

    def __init__(self) -> None:
        self._queues: "collections.OrderedDict[QoS, Deque]" = \
            collections.OrderedDict((q, collections.deque()) for q in QoS)

    def push(self, item, qos: QoS) -> None:
        self._queues[QoS(qos)].append(item)

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def depth(self, qos: QoS) -> int:
        return len(self._queues[QoS(qos)])

    def pump(self, try_admit: Callable[[object], "Admit"],
             fallback: Optional[Callable[[object], None]] = None) -> List:
        """Admit in QoS-priority, FIFO-within-class order.

        A ``DEFER`` head blocks only its own class (lower classes still
        get probed — small LOW transfers may fit where a big NORMAL one
        did not), except LOW itself, which falls back to ``fallback``
        and keeps draining.  ``STOP`` ends the pump entirely.
        """
        admitted: List = []
        for qos in QoS:
            q = self._queues[qos]
            while q:
                verdict = try_admit(q[0])
                if verdict is Admit.STOP:
                    return admitted
                if verdict is Admit.DEFER:
                    if qos is QoS.LOW and fallback is not None:
                        fallback(q.popleft())
                        continue
                    break
                admitted.append(q.popleft())
        return admitted


# --------------------------------------------------------------------------- #
# Tick-driven fluid datapath (wrapped by ReceiverHost / the fabric)
# --------------------------------------------------------------------------- #
def hold_us_baseline(c) -> float:
    """Message-granular post-NIC hold time (baseline, non-pipelined)."""
    return (c.consumer_latency_us +
            c.msg_bytes * 8.0 / (c.app_gbps * 1e9) * 1e6)


def hold_us_jet(c) -> float:
    """Slice-granular hold (Jet recycle pipeline): consumer latency
    dominates, the pipeline transit adds ~3 slice-times (paper §4.2.2)."""
    r = c.recycle
    per_byte_ns = r.get_ns_per_byte + r.process_ns_per_byte()
    transit = 3.0 * r.slice_bytes * per_byte_ns * 1e-3
    if not r.pipelined:
        # unpipelined Jet holds whole messages (ablation mode)
        return hold_us_baseline(c) + transit
    return c.consumer_latency_us + transit


ClassBytes = Union[float, Sequence[float]]


@dataclasses.dataclass
class DatapathFeedback:
    """One tick's outputs, routed back toward the network by the wrapper."""
    drained: float = 0.0        # bytes delivered to the host (goodput)
    pool_drained: float = 0.0   # subset that entered pool / DDIO residency
    fallback: float = 0.0       # LOW-QoS bytes spilled to DRAM (§5)
    ecn_fires: int = 0          # escape-ladder MARK_ECN count (rung 3)


class HostDatapath:
    """The receive datapath behind the RNIC, advanced one fluid tick at a
    time: per-QoS buffer classes -> pool/DDIO drain -> recycle release ->
    escape ladder.

    This is the admission/escape/recycle tick body formerly inlined in
    ``ReceiverSim.run()`` (then ``ReceiverHost.step``), extracted so the
    single-host simulator, the multi-host fabric and (in stacked-array
    form) the vector engines advance the *same* machine.  ``run_sim``
    numerics are preserved bit-for-bit: with all traffic in the NORMAL
    class every per-class loop reduces to the original scalar arithmetic
    (mins over classes with zero-byte classes are exact no-ops).

    The RNIC buffer itself is modeled here as the three class queues
    (``qos_q``); :attr:`rnic_q` is their total, which is what PFC/ECN
    watermarks observe.  Buffer space and drain budget are granted in
    QoS-priority order; under pool pressure (< ``cache_safe`` available)
    the LOW class spills to DRAM instead of competing for cache slots —
    the fluid rendition of ``JetService``'s §5 memory fallback.
    """

    def __init__(self, cfg, sim_ticks: int, dt_us: Optional[float] = None):
        c = self.cfg = cfg
        self.dt = float(dt_us if dt_us is not None else c.dt_us)
        # release buckets (bytes becoming consumable at tick t);
        # 1 s slack past the end for straggler releases
        self.horizon = sim_ticks + int(1e6 / self.dt)
        self.rel_base = np.zeros(self.horizon, dtype=np.float64)
        self.rel_strag = np.zeros(self.horizon, dtype=np.float64)

        self.qos_q: List[float] = [0.0] * N_QOS   # RNIC buffer, by class
        self.resident = 0.0               # post-NIC bytes not yet consumed
        self.strag_resident = 0.0
        self.escape_debt = 0.0            # escaped bytes whose release is void
        self.replace_debt = 0.0           # portion of debt borrowed by REPLACE
        self.pool_cap = float(c.jet_pool_bytes)
        self.replace_mem = 0.0
        self.ecn_escape_accum_us = 0.0

        # accounting
        self.nic_dram_bytes = 0.0
        self.escape_dram_bytes = 0.0
        self.mem_fallback_bytes = 0.0
        self.miss_sum, self.miss_n = 0.0, 0
        self.pool_peak, self.pool_sum = 0.0, 0.0
        self.replaces = self.copies = self.ecns = 0

        hold_b, hold_j = hold_us_baseline(c), hold_us_jet(c)
        self.hold_us = hold_j if c.mode == "jet" else hold_b
        self.d_base = max(1, int(self.hold_us / self.dt))
        self.d_strag = max(1, int(self.hold_us * c.straggler_mult / self.dt))

    def crash_reset(self) -> None:
        """NIC/host crash (fault layer): every byte in flight through
        the datapath is gone — RNIC class queues, resident pool
        contents, straggler state, escape/replace debts, and all
        pending release buckets.  Cumulative accounting counters are
        deliberately preserved (they describe the run, not the
        machine)."""
        self.rel_base[:] = 0.0
        self.rel_strag[:] = 0.0
        for cls in range(N_QOS):
            self.qos_q[cls] = 0.0
        self.resident = 0.0
        self.strag_resident = 0.0
        self.escape_debt = 0.0
        self.replace_debt = 0.0
        self.replace_mem = 0.0
        self.ecn_escape_accum_us = 0.0

    # -- RNIC buffer ---------------------------------------------------------
    @property
    def rnic_q(self) -> float:
        return sum(self.qos_q)

    def admit_link(self, arriving: ClassBytes) \
            -> Tuple[float, List[float], float]:
        """Accept link arrivals into the RNIC buffer, allocating space in
        QoS-priority order.  ``arriving`` is a plain float (all NORMAL —
        the single-host fast path, bit-identical to the pre-refactor
        scalar buffer) or a per-class sequence.  Returns ``(accepted
        total, accepted per class, offered total)``; the offered-accepted
        remainder is dropped upstream (lossy) or was never sent (PFC
        gates arrivals at the caller)."""
        space = max(0.0, self.cfg.rnic_buffer_bytes - self.rnic_q)
        if not isinstance(arriving, (tuple, list, np.ndarray)):
            offered = float(arriving)
            take = min(offered, space)
            self.qos_q[QoS.NORMAL] += take
            per_class = [0.0] * N_QOS
            per_class[QoS.NORMAL] = take
            return take, per_class, offered
        per_class = [0.0] * N_QOS
        total = offered = 0.0
        for cls in QoS:
            offered += float(arriving[cls])
            take = min(float(arriving[cls]), space)
            space -= take
            self.qos_q[cls] += take
            per_class[cls] = take
            total += take
        return total, per_class, offered

    # -- the tick ------------------------------------------------------------
    def step(self, t: int, cpu_bw_gbps: float) -> DatapathFeedback:
        """Drain the RNIC buffer toward the host, process due releases and
        run the escape ladder for tick ``t``."""
        c = self.cfg
        dt = self.dt
        if t >= self.horizon:
            # past this point the release arrays would silently stop
            # cycling bytes and the pool would deadlock — fail loudly
            raise RuntimeError(
                f"HostDatapath stepped past its horizon ({self.horizon} "
                f"ticks); construct it with sim_ticks covering the run")
        bytes_per_gbps_tick = 1e9 / 8.0 * dt * 1e-6
        fb = DatapathFeedback()
        q = self.qos_q

        # ---- drain RNIC -> host ------------------------------------------ #
        if c.mode == "ddio":
            # posted per-QP receive buffers + unconsumed post-NIC bytes
            working_set = c.num_qps * c.msg_bytes + self.resident
            over = working_set - c.ddio_bytes
            miss = min(1.0, max(0.0, over / (c.miss_knee * c.ddio_bytes)))
            self.miss_sum += miss
            self.miss_n += 1
            avail_dram = max(0.0, c.membw_total_gbps - cpu_bw_gbps)
            drain_bw = c.pcie_gbps
            if miss > 1e-9:
                # each drained byte costs ~2*miss bytes of DRAM traffic
                drain_bw = min(drain_bw, avail_dram / (2.0 * miss))
            budget = drain_bw * bytes_per_gbps_tick
            drained = 0.0
            for cls in QoS:
                take = min(q[cls], budget)
                q[cls] -= take
                budget -= take
                drained += take
            self.nic_dram_bytes += drained * 2.0 * miss
            pool_drained = drained
            strag_share = 0.0
        else:  # jet
            pool_free = max(0.0, self.pool_cap - self.resident)
            spill_low = pool_free / self.pool_cap < c.cache_safe
            budget = min(c.pcie_gbps, c.line_rate_gbps * 4.0) \
                * bytes_per_gbps_tick
            pool_drained = 0.0
            fallback = 0.0
            for cls in QoS:
                if cls is QoS.LOW and spill_low:
                    # §5: under cache pressure LOW-QoS bytes land in DRAM
                    # buffers instead of competing for pool slots
                    take = min(q[cls], budget)
                    fallback += take
                else:
                    take = min(q[cls], budget, pool_free)
                    pool_free -= take
                    pool_drained += take
                q[cls] -= take
                budget -= take
            drained = pool_drained + fallback
            self.mem_fallback_bytes += fallback
            self.nic_dram_bytes += fallback   # spilled writes hit DRAM 1x
            fb.fallback = fallback
            strag_share = c.straggler_frac

        # schedule release (only bytes that actually took up residency)
        if pool_drained > 0.0:
            base_part = pool_drained * (1.0 - strag_share)
            strag_part = pool_drained * strag_share
            bt = min(self.horizon - 1, t + self.d_base)
            st = min(self.horizon - 1, t + self.d_strag)
            self.rel_base[bt] += base_part
            self.rel_strag[st] += strag_part
            self.resident += pool_drained
            self.strag_resident += strag_part

        # ---- post-NIC consumption ---------------------------------------- #
        for arr, is_strag in ((self.rel_base, False), (self.rel_strag, True)):
            r = arr[t]
            if r <= 0.0:
                continue
            if self.escape_debt > 0.0:
                void = min(r, self.escape_debt)
                self.escape_debt -= void
                r -= void
                # a released straggler that had been REPLACE-escaped
                # retires its DRAM borrow (re-arming the replace rung)
                repay = min(void, self.replace_debt)
                self.replace_debt -= repay
                self.replace_mem = max(0.0, self.replace_mem - repay)
            self.resident = max(0.0, self.resident - r)
            if is_strag:
                self.strag_resident = max(0.0, self.strag_resident - r)

        # ---- Jet escape ladder (paper Algorithm 1) ------------------------ #
        if c.mode == "jet":
            avail_frac = max(0.0, self.pool_cap - self.resident) \
                / self.pool_cap
            if avail_frac < c.cache_safe:
                if self.replace_mem < c.mem_esc_bytes:
                    x = min(self.strag_resident,
                            c.mem_esc_bytes - self.replace_mem)
                    if x > 0.0:
                        self.resident -= x
                        self.strag_resident -= x
                        self.escape_debt += x
                        self.replace_debt += x
                        self.replace_mem += x
                        self.replaces += 1
                        # background re-touch traffic, low frequency
                        self.escape_dram_bytes += x * 0.1
                else:
                    x = self.strag_resident
                    if x > 0.0:
                        self.resident -= x
                        self.strag_resident = 0.0
                        self.escape_debt += x
                        self.escape_dram_bytes += x  # the copy itself
                        self.copies += 1
                avail_frac = max(0.0, self.pool_cap - self.resident) \
                    / self.pool_cap
                if avail_frac < c.cache_danger:
                    self.ecn_escape_accum_us += dt
                    if self.ecn_escape_accum_us >= c.cnp_interval_us:
                        self.ecn_escape_accum_us = 0.0
                        self.ecns += 1
                        fb.ecn_fires += 1
            self.pool_sum += self.resident
            self.pool_peak = max(self.pool_peak, self.resident)

        fb.drained = drained
        fb.pool_drained = pool_drained
        return fb
