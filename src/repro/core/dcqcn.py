"""DCQCN sender rate controller (Zhu et al., SIGCOMM'15), as referenced by the
paper (§2.1).  Used by the receive-datapath simulator to model how CNPs
produced by the receiver (RNIC buffer watermark / Jet MARK_ECN) throttle
senders, and reused as the AIMD policy behind the chunk-scheduler window.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class DcqcnConfig:
    line_rate_gbps: float = 100.0
    min_rate_gbps: float = 0.1
    g: float = 1.0 / 256.0          # alpha EWMA gain
    alpha_timer_us: float = 55.0    # alpha update period without CNPs
    rate_timer_us: float = 300.0    # rate-increase period T
    byte_counter_mb: float = 10.0   # rate-increase byte counter B
    ai_rate_gbps: float = 5.0       # additive increase R_AI
    hai_rate_gbps: float = 50.0     # hyper increase R_HAI
    f_threshold: int = 5            # fast-recovery stages before AI/HAI


class DcqcnRate:
    """Per-sender DCQCN state machine (rate in Gbps)."""

    def __init__(self, cfg: DcqcnConfig = DcqcnConfig()):
        self.cfg = cfg
        self.rc = cfg.line_rate_gbps   # current rate
        self.rt = cfg.line_rate_gbps   # target rate
        self.alpha = 1.0
        self._t_us = 0.0               # since last rate decrease (timer)
        self._bytes = 0.0              # since last rate decrease (counter)
        self._alpha_t_us = 0.0
        self._t_stage = 0
        self._b_stage = 0
        self.cnp_count = 0

    def on_signal(self, rtt_us: float, util: float, dt_us: float) -> None:
        """Per-tick fabric telemetry (delay / utilization).  DCQCN is
        ECN-driven and ignores it — the hook exists so every controller
        behind :data:`repro.fabric.cc.CongestionControl` shares one
        calling convention."""

    def on_cnp(self) -> None:
        """Rate decrease on congestion notification."""
        self.cnp_count += 1
        self.rt = self.rc
        self.rc = max(self.cfg.min_rate_gbps,
                      self.rc * (1.0 - self.alpha / 2.0))
        self.alpha = min(1.0, (1.0 - self.cfg.g) * self.alpha + self.cfg.g)
        self._t_us = 0.0
        self._bytes = 0.0
        self._t_stage = 0
        self._b_stage = 0
        self._alpha_t_us = 0.0

    def advance(self, dt_us: float) -> float:
        """Advance timers by ``dt_us``; returns the current rate (Gbps)."""
        cfg = self.cfg
        self._alpha_t_us += dt_us
        if self._alpha_t_us >= cfg.alpha_timer_us:
            self._alpha_t_us = 0.0
            self.alpha = max(0.0, (1.0 - cfg.g) * self.alpha)

        self._t_us += dt_us
        self._bytes += self.rc * 1e9 / 8.0 * dt_us * 1e-6
        fired = False
        if self._t_us >= cfg.rate_timer_us:
            self._t_us = 0.0
            self._t_stage += 1
            fired = True
        if self._bytes >= cfg.byte_counter_mb * (1 << 20):
            self._bytes = 0.0
            self._b_stage += 1
            fired = True
        if fired:
            stage = min(self._t_stage, self._b_stage)
            if stage < cfg.f_threshold:          # fast recovery
                pass
            elif stage == cfg.f_threshold:        # additive increase
                self.rt = min(cfg.line_rate_gbps, self.rt + cfg.ai_rate_gbps)
            else:                                 # hyper increase
                self.rt = min(cfg.line_rate_gbps, self.rt + cfg.hai_rate_gbps)
            self.rc = min(cfg.line_rate_gbps, 0.5 * (self.rc + self.rt))
        return self.rc
