"""Swift cache recycle controller (paper §4.2).

The recycle controller's goal: shrink the *post-NIC timespan* so that (by
Little's law) a smaller reserved cache sustains line rate.  The paper's three
accelerations are modeled explicitly so benchmarks can ablate them:

1. **multi-threading** — data-processing stages run ``threads``-wide;
2. **pipelining** — messages are cut into <=4 KB slices that flow through
   get -> process -> release; a slice's slot frees as soon as *that slice*
   is consumed rather than when the whole message is;
3. **simplification** — CRC offloaded to the NIC (cost 0) and struct-based
   in-place (de)serialization (huibuffer) instead of copy-based (protobuf).

On TPU the same pipeline shape appears inside the Pallas kernels (BlockSpec
double-buffering = slice pipeline); this module is the quantitative model used
by admission control, the simulator and the pool-sizing benchmark.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List

SLICE_BYTES_DEFAULT = 4 << 10  # paper §4.2.2


def slice_message(nbytes: int, slice_bytes: int = SLICE_BYTES_DEFAULT
                  ) -> List[int]:
    if nbytes <= 0:
        raise ValueError("message must be positive-sized")
    full, rem = divmod(nbytes, slice_bytes)
    return [slice_bytes] * full + ([rem] if rem else [])


def little_law_bytes(rate_gbps: float, timespan_us: float) -> float:
    """Average resident bytes = arrival rate x residence time (paper §2.2).

    e.g. 200 Gbps x 200 us = 5 MB — the feasibility argument for RDCA."""
    return rate_gbps * 1e9 / 8.0 * timespan_us * 1e-6


@dataclasses.dataclass
class RecycleModel:
    """Post-NIC timespan model for one received message.

    Default per-byte costs are calibrated so that the *unoptimized* pipeline
    yields a few hundred us for 256 KB messages (paper §1: "hundreds of us on
    average") and the optimized one tens of us.
    """
    # stage costs
    get_ns_per_byte: float = 0.012       # RNIC -> cache landing (PCIe-paced)
    crc_ns_per_byte: float = 0.25        # software CRC32C
    serialize_ns_per_byte: float = 0.30  # protobuf-style copy (de)serialize
    app_ns_per_byte: float = 0.10        # application touch/consume
    fixed_overhead_us: float = 3.0       # syscalls, completion handling
    # optimizations (paper §4.2.2)
    threads: int = 1
    pipelined: bool = False
    crc_offload: bool = False            # CRC -> RNIC (CX-5+)
    struct_serialization: bool = False   # huibuffer: in-place, ~zero copy
    slice_bytes: int = SLICE_BYTES_DEFAULT

    # -- derived ------------------------------------------------------------
    def process_ns_per_byte(self) -> float:
        crc = 0.0 if self.crc_offload else self.crc_ns_per_byte
        ser = (0.02 if self.struct_serialization
               else self.serialize_ns_per_byte)
        return (crc + ser + self.app_ns_per_byte) / max(1, self.threads)

    def slot_holding_time_us(self, msg_bytes: int) -> float:
        """How long one buffer slot stays allocated (drives pool sizing).

        Non-pipelined: the whole message's slots are held until the full
        message is processed.  Pipelined: a slot is held for roughly one
        slice's transit through the 3 deep stages.
        """
        per_byte = self.get_ns_per_byte + self.process_ns_per_byte()
        if not self.pipelined:
            return self.fixed_overhead_us + msg_bytes * per_byte * 1e-3
        n_slices = len(slice_message(msg_bytes, self.slice_bytes))
        slice_us = self.slice_bytes * per_byte * 1e-3
        # 3-stage pipeline: a slot is occupied for ~3 slice-times, plus the
        # fixed overhead amortized over all slices of the message.
        return 3.0 * slice_us + self.fixed_overhead_us / n_slices

    def message_latency_us(self, msg_bytes: int) -> float:
        """End-to-end post-NIC latency of the *message* (not slot time)."""
        per_byte = self.get_ns_per_byte + self.process_ns_per_byte()
        base = self.fixed_overhead_us + msg_bytes * per_byte * 1e-3
        if not self.pipelined:
            return base
        # pipeline overlaps get/process/release: ~ dominated by slowest stage
        bottleneck = max(self.get_ns_per_byte, self.process_ns_per_byte())
        return (self.fixed_overhead_us + 3 * self.slice_bytes * per_byte * 1e-3
                + msg_bytes * bottleneck * 1e-3)

    def resident_bytes(self, rate_gbps: float, msg_bytes: int) -> float:
        """Little's-law average pool occupancy at ``rate_gbps``."""
        return little_law_bytes(rate_gbps,
                                self.slot_holding_time_us(msg_bytes))

    def required_pool_bytes(self, rate_gbps: float, msg_bytes: int,
                            headroom: float = 2.0) -> int:
        """Pool size with jitter headroom, rounded up to whole MB."""
        need = self.resident_bytes(rate_gbps, msg_bytes) * headroom
        return int(math.ceil(need / (1 << 20))) << 20


def paper_default() -> RecycleModel:
    """The fully-optimized Jet configuration (paper §4.2)."""
    return RecycleModel(threads=4, pipelined=True, crc_offload=True,
                        struct_serialization=True)


def paper_unoptimized() -> RecycleModel:
    """Strawman: single-threaded, message-granular, software CRC, protobuf."""
    return RecycleModel()
