"""Multi-host discrete-event driver: senders -> Clos switches -> receivers.

Per 1 us fluid tick (same timebase as the single-host simulator):

0. scheduled link failures fire (in-flight bytes on a dead link are
   dropped and re-credited — fluid go-back-N) and the routing layer
   resolves each cross-leaf flow's spine choice / spray split from
   per-uplink queue depth and link up/down state
   (:mod:`repro.fabric.routing`; ``static_ecmp`` keeps the frozen
   pre-routing-layer next hops, bit-for-bit);
1. every flow's DCQCN machine offers bytes into its host NIC queue;
2. queues forward in tier order (host->leaf, leaf->spine, and on
   3-level fabrics spine->super-spine, super-spine->spine, then
   spine->leaf, leaf->host), so an uncongested byte traverses the
   fabric in one tick — the cut-through limit, which keeps a
   1-sender/1-receiver fabric numerically equivalent to
   ``repro.core.run_sim``;
3. each receiver's :class:`ReceiverHost` advances one tick on the arrived
   bytes; its CNPs (RNIC watermark / Jet escape ECN) and the ECN marks the
   switches stamped on departing bytes are converted into per-flow CNPs
   that throttle exactly the offending senders;
4. switch ports refresh per-TC PFC xoff/xon state; a paused
   ``(ingress link, tc)`` pair stalls that class's flows on that link
   next tick.  With ``SwitchConfig.per_tc`` (the default) pause is
   per-priority, so a congested class no longer head-of-line-blocks the
   other classes sharing the link; with ``per_tc=False`` every flow
   rides TC 0 and the legacy whole-link pause (congestion spreading,
   §2.1) is reproduced exactly.

Outputs one :class:`~repro.core.simulator.SimResult` per receiver plus
fabric-level metrics: per-flow goodput, victim-flow goodput, pause-frame
fan-out and incast completion time.

Forwarding uses *batch-fluid* semantics: all bytes arriving at an output
port within one tick stage are enqueued as a single batch (proportional
buffer-space allocation, one ECN-knee decision against the pre-batch
occupancy) rather than flow-by-flow in container iteration order.  A
fluid-model tick has no intra-tick arrival order, so this is the faithful
semantics — and it is what makes the tick body expressible as fixed
array operations, which :mod:`repro.fabric.vector` exploits to advance
whole scenario grids at once.  With a single flow per batch (e.g. the
1-sender/1-receiver equivalence anchor) it reduces exactly to the
sequential semantics.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Set, Tuple

from ..core.datapath import N_QOS, QoS
from ..core.simulator import SimConfig, SimResult, testbed_100g
from .cc import CcConfig
from .faults import (FaultConfig, FlowRecovery, corrupt_hash, fault_hash,
                     flap_down_now, flap_edge, has_pause_cycle, link_salt,
                     loss_threshold)
from .hosts import ReceiverHost, SenderHost
from .messages import MessageConfig, MessageTracker, exact_percentile
from .routing import (RoutingConfig, adaptive_pick, flowlet_hash,
                      spray_weights, weighted_pick)
from .switch import OutputPort, PauseKey, Switch, SwitchConfig
from .topology import LinkKey, Topology


@dataclasses.dataclass
class Flow:
    """One sender->receiver transfer riding the fabric."""
    src: str
    dst: str
    offered_gbps: Optional[float] = None     # open-loop cap (None=saturate)
    burst_bytes: Optional[float] = None      # closed flow: stop after burst
    start_us: float = 0.0
    tag: str = ""                            # e.g. "incast" | "victim"
    qos: QoS = QoS.NORMAL                    # receiver admission class (§3.2)
    #                                          + switch traffic class (per-TC
    #                                          queues, SwitchConfig.per_tc)
    # burst-train source: (on_us, off_us) duty cycle — the flow offers
    # bytes only during the on-phase (OLTP client trains); None = always on
    on_off_us: Optional[Tuple[float, float]] = None
    # per-flow NP->RP CNP propagation delay override; None falls back to
    # FabricConfig.cnp_delay_us
    cnp_delay_us: Optional[float] = None
    # op-granular message layer (verbs WRITE/SEND, outstanding window,
    # per-message latency percentiles); None falls back to
    # FabricConfig.msg, and None there means plain fluid bytes
    msg: Optional[MessageConfig] = None
    # congestion-control selection (dcqcn / timely / hpcc); None falls
    # back to FabricConfig.cc, and None there means per-line-rate DCQCN
    cc: Optional[CcConfig] = None


def burst_done_bytes(burst_bytes: float) -> float:
    """Delivered-bytes threshold at which a closed flow counts as complete.

    Fluid go-back-N never delivers the *last* byte sharply: once drops or
    RNIC backpressure kick in, the remaining bytes decay geometrically, so
    "time of the final 1e-6 bytes" is log-sensitive to the threshold and
    numerically meaningless.  A closed flow therefore completes at 99.99%
    delivery — discrete wire traffic would have finished in one more MTU —
    which both the scalar driver and the vectorized engine can place to
    within a tick of each other.
    """
    return burst_bytes - max(1e-6, 1e-4 * burst_bytes)


@dataclasses.dataclass
class FabricConfig:
    sim_time_s: float = 0.01
    dt_us: float = 1.0
    switch: SwitchConfig = dataclasses.field(default_factory=SwitchConfig)
    # SimConfig factory per receiver host (mode, pool, DDIO, PFC, ...)
    receiver_cfg: Callable[[str], SimConfig] = \
        lambda host: testbed_100g("jet")
    # CNP propagation delay NP -> RP (us): a congestion notification
    # generated at the receiver (escape-ladder ECN, RNIC watermark, paced
    # switch marks) cuts its sender's DCQCN rate this many microseconds
    # later.  0.0 = same-tick delivery (the pre-delay behaviour).
    cnp_delay_us: float = 0.0
    # per-tick path selection over the spine candidates (static ECMP,
    # flowlet-weighted ECMP, adaptive least-congested, packet spray) —
    # see repro.fabric.routing.  static_ecmp reproduces the pre-routing-
    # layer driver bit-for-bit.
    routing: RoutingConfig = dataclasses.field(default_factory=RoutingConfig)
    # fabric-wide message-layer / congestion-control defaults (per-flow
    # Flow.msg / Flow.cc override); None keeps the pre-message fluid
    # semantics and per-line-rate DCQCN exactly
    msg: Optional[MessageConfig] = None
    cc: Optional[CcConfig] = None
    # fault injection + loss recovery (repro.fabric.faults).  None is
    # bit-equal to the pre-fault engines; any FaultConfig — even an
    # all-zero one — also engages the RTO/retransmit ledger for every
    # flow carrying a MessageConfig (MessageConfig.recovery picks
    # go-back-N vs IRN-style selective)
    faults: Optional[FaultConfig] = None


@dataclasses.dataclass
class FabricResult:
    per_host: Dict[str, SimResult]
    flow_goodput_gbps: Dict[int, float]
    flow_delivered_bytes: Dict[int, float]
    flow_completion_us: Dict[int, float]     # closed flows; inf if unfinished
    flow_tags: Dict[int, str]
    incast_completion_us: float              # max over tag=="incast" flows
    victim_goodput_gbps: float               # mean over tag=="victim" flows;
    #                                          0.0 when has_victim is False
    pause_link_us: Dict[LinkKey, float]      # link paused in >=1 TC
    pause_fanout: int                        # distinct links ever paused
    ecn_marked_bytes: float
    switch_dropped_bytes: float
    has_victim: bool = False                 # any tag=="victim" flow present
    # per-priority pause breakdown: (link, tc) -> paused microseconds.
    # With per-TC queues a pause stalls one class on one ingress link;
    # summing over links per tc gives the class-level pause budget.
    pause_tc_us: Dict[PauseKey, float] = \
        dataclasses.field(default_factory=dict)
    # routing-layer observability: fraction of each leaf->spine uplink's
    # capacity-time actually carried, and how often flows changed spine
    # (0 everywhere under static_ecmp)
    uplink_util: Dict[LinkKey, float] = \
        dataclasses.field(default_factory=dict)
    flow_reroutes: Dict[int, int] = dataclasses.field(default_factory=dict)
    reroute_count: int = 0
    # message layer (flows with a MessageConfig): exact per-message
    # completion latencies in completion order, per flow
    msg_latency_us: Dict[int, List[float]] = \
        dataclasses.field(default_factory=dict)
    msg_last_done_us: Dict[int, float] = \
        dataclasses.field(default_factory=dict)
    has_messages: bool = False               # any flow ran the op layer
    sim_us: float = 0.0                      # simulated horizon
    # fault layer (FabricConfig.faults) — graceful-degradation metrics.
    # dropped_pkts counts fault-injected drops only (stochastic loss,
    # corruption, flap/fail in-flight kills, crash discards, go-back-N
    # duplicate discards) in MTU units; buffer tail drops stay in
    # switch_dropped_bytes as before
    dropped_pkts: float = 0.0
    retransmit_bytes: float = 0.0            # recovery-ledger re-credits
    # crashed host -> us from crash to first post-restart accepted byte
    # (inf if it never recovered within the horizon)
    crash_recovery_us: Dict[str, float] = \
        dataclasses.field(default_factory=dict)
    deadlock_ticks: int = 0                  # ticks with a cyclic per-TC
    #                                          pause dependency (same
    #                                          watchdog in every engine)
    # routing-aware PFC-storm observability: per-TC count of distinct
    # ingress links ever paused, against the candidate ingress sets the
    # routing layer could steer through (OutputPort.static_ingress /
    # the vector prev-mat)
    pause_tc_fanout: Dict[int, int] = dataclasses.field(default_factory=dict)
    n_pausable_links: int = 0
    # links whose failure window covered the whole horizon: they carried
    # nothing and could pause nothing, so they are excluded from the
    # pause_storm denominator (at aggregation) and from the
    # uplink_imbalance mean — a dead uplink is a wiring fact, not a
    # load-balance signal.  Flapping links keep some up-time and stay in.
    dead_links: Set[LinkKey] = dataclasses.field(default_factory=set)

    def pause_storm(self) -> float:
        """PFC-storm severity: the worst traffic class's pause fan-out
        as a fraction of the candidate ingress links it *could* pause
        under the active routing mode (links down for the entire window
        are excluded from the denominator — they can never pause).
        1.0 = some class paused every candidate ingress at least once;
        0.0 (never NaN) when nothing paused or the fabric has no
        pausable links — same contract as :meth:`uplink_imbalance`."""
        if not self.pause_tc_fanout or self.n_pausable_links <= 0:
            return 0.0
        return max(self.pause_tc_fanout.values()) / self.n_pausable_links

    def _msg_pool(self, tag: Optional[str]) -> List[float]:
        return [v for fid, vals in self.msg_latency_us.items()
                if tag is None or self.flow_tags[fid] == tag
                for v in vals]

    def msg_percentile(self, q: float, tag: Optional[str] = None) -> float:
        """Exact nearest-rank percentile of message latency pooled over
        all message flows (optionally one tag).  0.0 (never NaN) when no
        messages completed — check :attr:`has_messages` to tell "no op
        layer" apart from "nothing finished", same contract as
        :meth:`tagged_goodput`."""
        return exact_percentile(self._msg_pool(tag), q)

    def msg_count(self, tag: Optional[str] = None) -> int:
        """Completed messages pooled over message flows."""
        return len(self._msg_pool(tag))

    def msg_rate_mops(self, tag: Optional[str] = None) -> float:
        """Completed message ops per microsecond == Mops; 0.0 (never
        NaN) when nothing completed or the horizon is empty."""
        n = self.msg_count(tag)
        return n / self.sim_us if self.sim_us > 0.0 and n else 0.0

    def uplink_imbalance(self) -> float:
        """Load-balance quality: max/mean utilization over the fabric
        uplinks that had any up-time (an idle-but-alive uplink is
        imbalance — perfect spraying scores 1.0, everything piled on
        one of N uplinks scores N — but a link that was down for the
        whole window is wiring, not imbalance, and is excluded).  0.0
        (never NaN) when the fabric has no live uplinks or carried
        nothing, so sweep summaries can aggregate it unconditionally —
        same contract as :meth:`tagged_goodput`."""
        vals = [u for lk, u in self.uplink_util.items()
                if lk not in self.dead_links]
        if not vals:
            return 0.0
        mean = sum(vals) / len(vals)
        return max(vals) / mean if mean > 0.0 else 0.0

    def has_tag(self, tag: str) -> bool:
        return any(t == tag for t in self.flow_tags.values())

    def tagged_goodput(self, tag: str) -> float:
        """Mean goodput over flows with ``tag``; 0.0 (not NaN) when no flow
        carries the tag, so fleet summaries that average over scenarios
        never silently absorb a NaN — check :meth:`has_tag` to tell "no
        such flows" apart from "flows starved to zero"."""
        vals = [g for fid, g in self.flow_goodput_gbps.items()
                if self.flow_tags[fid] == tag]
        return sum(vals) / len(vals) if vals else 0.0


def run_fabric(topo: Topology, flows: List[Flow],
               fcfg: Optional[FabricConfig] = None) -> FabricResult:
    fcfg = fcfg or FabricConfig()
    topo.validate()
    dt = fcfg.dt_us
    ticks = int(fcfg.sim_time_s * 1e6 / dt)

    # -- build components ---------------------------------------------------
    rcfg = fcfg.routing
    F = len(flows)
    fail_ticks = topo.failure_ticks(dt)
    if any(fcfg.receiver_cfg(h).host_pfc_per_tc
           for h in sorted({f.dst for f in flows})) \
            and not fcfg.switch.per_tc:
        # the receiver's per-class gate pauses (access link, tc) pairs;
        # with a single-queue legacy switch those classes don't exist on
        # the wire, and silently falling back to the whole-link gate
        # would diverge from the per-class watermark arithmetic
        raise ValueError("host_pfc_per_tc requires SwitchConfig.per_tc")
    # dynamic-routing land: per-tick spine selection and/or link-failure
    # events (scheduled windows or flap cycles).  Static ECMP without
    # failures takes the frozen next_hop fast path below, bit-equal to
    # the pre-routing-layer driver.
    flaps = topo.flap_ticks(dt)
    dyn = rcfg.is_dynamic or bool(fail_ticks) or bool(flaps)

    # per-flow message-layer / CC resolution (Flow overrides FabricConfig)
    msg_of: List[Optional[MessageConfig]] = [f.msg or fcfg.msg
                                             for f in flows]
    cc_of: List[Optional[CcConfig]] = [f.cc or fcfg.cc for f in flows]
    trackers: Dict[int, MessageTracker] = {
        fid: MessageTracker(m) for fid, m in enumerate(msg_of)
        if m is not None}
    # delay/INT telemetry is only computed when a non-DCQCN controller
    # is present (DCQCN ignores it; skipping keeps the legacy path
    # byte-identical and cheap)
    need_cc = any(c is not None and c.algo != "dcqcn" for c in cc_of)
    cc_flow_ids = [fid for fid in range(F)
                   if cc_of[fid] is not None
                   and cc_of[fid].algo != "dcqcn"]
    bpt = 1e9 / 8.0 * dt * 1e-6                    # bytes per Gbps*tick

    senders: Dict[int, SenderHost] = {}
    next_hop: Dict[Tuple[str, int], str] = {}      # (node, fid) -> next node
    cross_flows: List[int] = []                    # rerouteable flow ids
    flow_leaves: Dict[int, Tuple[str, str]] = {}   # fid -> (src, dst leaf)
    cur_spine: Dict[int, int] = {}                 # current candidate index
    route_frac: Dict[int, Dict[str, float]] = {}   # fid -> {spine: frac}
    # rerouteable flows only: the wired candidate structure.  cand_of is
    # the first-hop spine per candidate (what the routing layer picks
    # between); cand_paths_of the full interior node path per candidate
    # — on a 3-level fabric choosing the pod spine chooses the plane, so
    # everything below the source leaf is frozen per candidate.
    cand_of: Dict[int, List[str]] = {}
    cand_paths_of: Dict[int, List[List[str]]] = {}
    flow_reroutes: Dict[int, int] = {fid: 0 for fid in range(F)}
    for fid, f in enumerate(flows):
        nodes = topo.route(f.src, f.dst, fid)      # validates + static path
        sl, dl = topo.host_leaf[f.src], topo.host_leaf[f.dst]
        flow_leaves[fid] = (sl, dl)
        next_hop[(f.src, fid)] = sl
        if sl == dl:
            next_hop[(sl, fid)] = f.dst
        else:
            next_hop[(dl, fid)] = f.dst
            paths = topo.candidate_paths(f.src, f.dst)
            cands = [p[1] for p in paths]
            deep = any(len(p) > 3 for p in paths)  # transits super-spines
            if rcfg.is_dynamic or (dyn and not deep):
                # the leaf->spine hop is resolved per tick (or could be,
                # under a failure schedule): freeze every hop *below*
                # the source leaf on every candidate path and let the
                # drain fall through to route_frac at the leaf
                if len(set(cands)) != len(cands):
                    raise ValueError(
                        "dynamic routing needs a unique candidate path "
                        "per first-hop spine; this fabric has several "
                        "super-spines per plane — use static_ecmp or "
                        "sspines_per_plane=1")
                for p in paths:
                    for a, b in zip(p[1:], p[2:]):
                        next_hop[(a, fid)] = b
                cross_flows.append(fid)
                cand_of[fid] = cands
                cand_paths_of[fid] = paths
                k0 = fid % len(cands)
                cur_spine[fid] = k0
                route_frac[fid] = {cands[k0]: 1.0}
            else:
                # static route (including failure schedules on 3-level
                # fabrics): freeze the chosen path end to end
                for a, b in zip(nodes[1:], nodes[2:]):
                    next_hop[(a, fid)] = b
        senders[fid] = SenderHost(
            line_rate_gbps=topo.access_gbps(f.src),
            offered_gbps=f.offered_gbps, burst_bytes=f.burst_bytes,
            start_us=f.start_us, on_off_us=f.on_off_us,
            cc=cc_of[fid],
            op_cap_gbps=(msg_of[fid].op_rate_gbps
                         if msg_of[fid] is not None else None))

    recv_hosts = sorted({f.dst for f in flows})
    receivers: Dict[str, ReceiverHost] = {
        h: ReceiverHost(fcfg.receiver_cfg(h), sim_ticks=ticks)
        for h in recv_hosts}

    # host NIC egress queues (source-side backlog onto the access link);
    # NICs never ECN-mark their own egress — only switches do
    nic_cfg = dataclasses.replace(fcfg.switch, ecn_enabled=False)
    nic_ports: Dict[str, OutputPort] = {}
    for f in flows:
        if f.src not in nic_ports:
            nic_ports[f.src] = OutputPort(
                topo.link(f.src, topo.host_leaf[f.src]), nic_cfg)
    switches: Dict[str, Switch] = {}
    for name in topo.leaves + topo.spines + topo.super_spines:
        out = [l for l in topo.links.values() if l.src == name]
        switches[name] = Switch(name, out, fcfg.switch)
    port_by_link: Dict[LinkKey, OutputPort] = {
        p.link.key: p for p in nic_ports.values()}
    for sw in switches.values():
        for p in sw.ports.values():
            port_by_link[p.link.key] = p

    if dyn:
        # pause targeting in dynamic-routing land covers the whole
        # candidate ingress set of every queued flow (mixed provenance
        # under spraying/rerouting; see OutputPort.static_ingress)
        ingress: Dict[LinkKey, Dict[int, Tuple[LinkKey, ...]]] = {}
        for fid, f in enumerate(flows):
            sl, dl = flow_leaves[fid]
            acc = (f.src, sl)
            if sl == dl:
                ingress.setdefault((sl, f.dst), {})[fid] = (acc,)
            elif fid in cand_paths_of:
                last_hops = []
                for p in cand_paths_of[fid]:
                    prev = acc
                    for a, b in zip(p, p[1:]):
                        ingress.setdefault((a, b), {})[fid] = (prev,)
                        prev = (a, b)
                    last_hops.append(prev)
                ingress.setdefault((dl, f.dst), {})[fid] = \
                    tuple(last_hops)
            else:
                # frozen end-to-end route (static mode under a failure
                # schedule on a 3-level fabric): exact chain provenance
                prev = acc
                node = sl
                while node != dl:
                    nh = next_hop[(node, fid)]
                    ingress.setdefault((node, nh), {})[fid] = (prev,)
                    prev = (node, nh)
                    node = nh
                ingress.setdefault((dl, f.dst), {})[fid] = (prev,)
        for lk, m in ingress.items():
            port_by_link[lk].static_ingress = m

    # spray reorder settling: sprayed arrivals wait settle_ticks before
    # entering receiver admission (per-flow ring, 0 = pass-through)
    settle_ticks = int(round(rcfg.spray_settle_us / dt)) \
        if rcfg.mode == "spray" else 0
    Hs = settle_ticks + 1
    if settle_ticks:
        cross_set = set(cross_flows)
        settle_f = [settle_ticks if fid in cross_set else 0
                    for fid in range(F)]
        ring_b = [[0.0] * Hs for _ in range(F)]
        ring_m = [[0.0] * Hs for _ in range(F)]

    # per-uplink carried bytes (load-balance observability): leaf->spine
    # everywhere, plus spine->super-spine on 3-level fabrics
    uplink_tx: Dict[LinkKey, float] = {
        l.key: 0.0 for l in topo.fabric_uplinks()}

    # routing-step invariants: decision constants and the cross-leaf
    # flows grouped by (source leaf, dest leaf) — uplink occupancy is a
    # per-pair candidate read and the up-mask a per-pair read, not
    # per-flow.  pair_info carries the shared candidate structure: the
    # first-hop spines and each candidate's interior link chain (the
    # whole chain must be up for the candidate to count as up).
    route_buf = float(fcfg.switch.port_buffer_bytes)
    route_hyst = rcfg.hysteresis_frac * route_buf
    leaf_pairs: Dict[Tuple[str, str], List[int]] = {}
    pair_info: Dict[Tuple[str, str],
                    Tuple[List[str], List[List[LinkKey]]]] = {}
    for fid in cross_flows:
        pr = flow_leaves[fid]
        leaf_pairs.setdefault(pr, []).append(fid)
        if pr not in pair_info:
            paths = cand_paths_of[fid]
            pair_info[pr] = (cand_of[fid],
                             [list(zip(p, p[1:])) for p in paths])

    # flowlet bookkeeping (weighted_ecmp): a flow opens a new flowlet —
    # and re-hashes — on its first NIC injection after an idle gap
    # longer than flowlet_gap_us; a continuously-backlogged flow is one
    # flowlet and keeps its spine until the path dies
    flet_track = rcfg.mode == "weighted_ecmp" and bool(cross_flows)
    flet_gap = max(1, int(round(rcfg.flowlet_gap_us / dt)))
    flet_last = {fid: -(1 << 30) for fid in cross_flows}  # last active tick
    flet_k = {fid: 0 for fid in cross_flows}              # flowlet index
    flet_boundary: Set[int] = set()

    # switch traffic class of each flow: the QoS class selects the
    # per-TC queue along the route; legacy per-link mode collapses
    # everything onto TC 0 (one queue, one watermark — the pre-per-TC
    # pause behaviour)
    tc_of = [int(f.qos) if fcfg.switch.per_tc else 0 for f in flows]

    # -- fault layer (repro.fabric.faults) -----------------------------------
    flt = fcfg.faults
    # recovery ledgers: engaged per flow iff a FaultConfig is attached
    # AND the flow runs the message layer; every other flow keeps the
    # fluid core's instant drop-re-credit via lose()
    recovery: Dict[int, FlowRecovery] = {}
    if flt is not None:
        for fid, m in enumerate(msg_of):
            if m is not None:
                recovery[fid] = FlowRecovery.from_msg(m, dt)

    def lose(fid: int, b: float) -> None:
        """Route dropped bytes: into the flow's retransmit ledger when
        recovery is engaged, else instantly re-credited (go-back-N of
        the fluid core) — bit-identical to the pre-fault driver when
        ``recovery`` is empty."""
        rec = recovery.get(fid)
        if rec is None:
            senders[fid].credit(b)
        else:
            rec.on_loss(b)

    # stochastic loss: one counter-based hash per (link, tick); the
    # whole drained batch drops when it fires (fluid burst loss), so
    # the expected byte-loss fraction equals the configured rate.  The
    # corruption stream models CRC failures at the receiving NIC and
    # only applies to receiver access links.
    flt_loss = flt is not None and flt.any_loss
    if flt_loss:
        salt_of = {lk: link_salt(lk[0], lk[1], flt.seed)
                   for lk in port_by_link}
        loss_thr = {lk: loss_threshold(flt.rate_for(*lk))
                    for lk in port_by_link}
        corr_thr = {lk: (loss_threshold(flt.corrupt_rate)
                         if lk[1] in receivers else 0)
                    for lk in port_by_link}
    # NIC/host crash--restart windows in tick space
    crash_win: Dict[str, Tuple[int, int]] = {}
    if flt is not None:
        for h, (a_us, r_us) in flt.crashes.items():
            if h not in receivers:
                raise ValueError(f"crash scheduled on {h!r}, which is "
                                 "not a receiver in this run")
            at = max(0, int(round(a_us / dt)))
            crash_win[h] = (at, max(at + 1, int(round(r_us / dt))))
    crash_rec_us: Dict[str, float] = {}     # first post-restart byte
    flt_dropped = 0.0                       # fault-injected drops, bytes
    deadlock_ticks = 0
    prog_set: Set[int] = set()              # flows delivered-to this tick

    # candidate ingress links that PFC could ever pause (the routing-
    # aware denominator of FabricResult.pause_storm): every flow's
    # access link plus, cross-leaf, every interior link of each
    # candidate path (all candidates in dynamic-routing land, the
    # frozen path under static ECMP) — the scalar twin of the vector
    # prev-mat
    pausable: Set[LinkKey] = set()
    for fid, f in enumerate(flows):
        sl, dl = flow_leaves[fid]
        pausable.add((f.src, sl))
        if sl == dl:
            continue
        if fid in cand_paths_of:
            for p in cand_paths_of[fid]:
                pausable.update(zip(p, p[1:]))
        else:
            node = sl
            while node != dl:
                nh = next_hop[(node, fid)]
                pausable.add((node, nh))
                node = nh

    # -- per-flow CNP pacing at the receiver NP (DCQCN) ----------------------
    cnp_accum_us = {fid: math.inf for fid in senders}   # immediate first CNP
    marked_backlog = {fid: 0.0 for fid in senders}
    # CNP propagation: a notification generated at tick t cuts its sender
    # at t + delay ticks; the delay is per flow (Flow.cnp_delay_us
    # overriding FabricConfig.cnp_delay_us), so pending notifications
    # live in a min-heap on due tick (insertion order breaks ties)
    cnp_delay_ticks = {
        fid: max(0, int(round(
            (f.cnp_delay_us if f.cnp_delay_us is not None
             else fcfg.cnp_delay_us) / dt)))
        for fid, f in enumerate(flows)}
    pending_cnps: List[Tuple[int, int, int]] = []       # (due, seq, fid)
    cnp_seq = 0
    flows_by_dst: Dict[str, List[int]] = {}
    for fid, f in enumerate(flows):
        flows_by_dst.setdefault(f.dst, []).append(fid)
    # heaviest recently-arriving flow per receiver: the CNP target while
    # the access link is paused and nothing arrives (run_sim always
    # delivers receiver CNPs to its sender; the fabric must too)
    last_heavy: Dict[str, Optional[int]] = {}

    delivered = {fid: 0.0 for fid in senders}
    completion = {fid: math.inf for fid in senders}
    # per-tick drained bytes per link — the txRate leg of the HPCC-style
    # INT signal (only maintained when a delay/INT controller is active)
    tick_tx: Dict[LinkKey, float] = {}
    pause_link_us: Dict[LinkKey, float] = {}
    pause_tc_us: Dict[PauseKey, float] = {}
    # (ingress link -> paused TC set) as of the previous tick's PFC pass
    paused_by_link: Dict[LinkKey, frozenset] = {}
    _no_tcs: frozenset = frozenset()

    hosts_set = set(topo.hosts)
    Batches = Dict[Tuple[str, str], List[Tuple[int, float, float,
                                               Optional[LinkKey], int]]]

    def flush(batches: Batches) -> None:
        """Enqueue one stage's accumulated arrivals, one batch per
        destination port; tail-dropped bytes are re-credited to their
        senders (fluid go-back-N retransmission) or, with recovery
        engaged, wait in the retransmit ledger."""
        for (sw, dst), items in batches.items():
            for fid, lost in switches[sw].ports[dst] \
                    .enqueue_batch(items).items():
                lose(fid, lost)

    def drain_stage(ports, arrivals, batches: Batches,
                    down_now: frozenset, t: int) -> float:
        """Drain ``ports`` [(owner switch or None, port)]; forwarded bytes
        land in next-hop ``batches``, host-bound bytes in ``arrivals``.
        Dead links forward nothing; a cross-leaf flow without a frozen
        next hop is split over ``route_frac`` (this tick's routing).
        Returns the bytes killed by stochastic loss/corruption."""
        killed = 0.0
        for owner, port in ports:
            lk = port.link.key
            if lk in down_now:
                continue
            dst = port.link.dst
            to_host = dst in hosts_set
            # stochastic faults: when the per-(link, tick) hash fires,
            # everything this port drains this tick is lost on the wire
            # (ECN marks ride the bytes and die with them)
            drop_link = False
            if flt_loss:
                drop_link = fault_hash(t, salt_of[lk]) < loss_thr[lk]
                if not drop_link and corr_thr[lk]:
                    drop_link = corrupt_hash(t, salt_of[lk]) < corr_thr[lk]
            # switch-side PFC is per (link, tc); the receiver-side RNIC
            # gate pauses its whole access link, or — with
            # host_pfc_per_tc — only the congested admission classes
            port.paused_tcs = paused_by_link.get(lk, _no_tcs)
            port.paused = False
            if to_host and dst in receivers:
                rx = receivers[dst]
                if rx.cfg.pfc_enabled:
                    if rx.cfg.host_pfc_per_tc:   # implies switch.per_tc
                        port.paused_tcs = \
                            port.paused_tcs | rx.paused_classes
                    else:
                        port.paused = rx.pfc_paused
            track = lk in uplink_tx
            for fid, b, m in port.drain(dt):
                if drop_link:
                    lose(fid, b)
                    killed += b
                    continue
                if track:
                    uplink_tx[lk] += b
                if need_cc:
                    tick_tx[lk] = tick_tx.get(lk, 0.0) + b
                if to_host:
                    cur = arrivals.setdefault(dst, {}) \
                        .setdefault(fid, [0.0, 0.0])
                    cur[0] += b
                    cur[1] += m
                else:
                    nh = next_hop.get((dst, fid))
                    if nh is not None:
                        batches.setdefault((dst, nh), []) \
                            .append((fid, b, m, lk, tc_of[fid]))
                    else:
                        for sp_name, fr in route_frac[fid].items():
                            batches.setdefault((dst, sp_name), []) \
                                .append((fid, b * fr, m * fr, lk,
                                         tc_of[fid]))
        return killed

    # the forwarding stages of one tick, in traversal order; a port
    # drains once per tick, after every same-tick upstream stage has
    # deposited into it (cut-through: an uncongested byte crosses the
    # whole fabric in one tick).  On a 2-tier fabric the super-spine
    # stages are empty and the spine-down stage is exactly the old
    # all-spine-port stage; on a 3-level fabric a spine's super-spine-
    # facing ports drain before the super-spines and its leaf-facing
    # ports after, so cross-pod bytes still cross in one tick.
    sspine_set = set(topo.super_spines)
    stage_nic = [(None, p) for p in nic_ports.values()]
    stage_up = [(leaf, p) for leaf in topo.leaves
                for p in switches[leaf].ports.values()
                if p.link.dst not in hosts_set]
    stage_s_up = [(sp, p) for sp in topo.spines
                  for p in switches[sp].ports.values()
                  if p.link.dst in sspine_set]
    stage_ss = [(ss, p) for ss in topo.super_spines
                for p in switches[ss].ports.values()]
    stage_s_down = [(sp, p) for sp in topo.spines
                    for p in switches[sp].ports.values()
                    if p.link.dst not in sspine_set]
    stage_down = [(leaf, p) for leaf in topo.leaves
                  for p in switches[leaf].ports.values()
                  if p.link.dst in hosts_set]
    stages = [st for st in (stage_nic, stage_up, stage_s_up, stage_ss,
                            stage_s_down, stage_down) if st]

    _no_links: frozenset = frozenset()
    for t in range(ticks):
        now_us = (t + 1) * dt
        # ---- 0. link failure / flap / crash events ------------------------ #
        down_now = _no_links
        if fail_ticks or flaps:
            down = {lk for lk, (a, u) in fail_ticks.items() if a <= t < u}
            edges = [lk for lk, (a, _) in fail_ticks.items() if a == t]
            for lk, (s0, per, dn) in flaps.items():
                if flap_down_now(t, s0, per, dn):
                    down.add(lk)
                if flap_edge(t, s0, per):
                    edges.append(lk)
            down_now = frozenset(down)
            for lk in edges:
                port = port_by_link.get(lk)
                if port is not None:
                    # in-flight bytes die with the link; fluid
                    # go-back-N (or the recovery ledger) re-credits
                    # them for retransmission
                    for fid, lost in port.drop_all().items():
                        lose(fid, lost)
                        if flt is not None:
                            flt_dropped += lost
        if crash_win:
            for h, (a, _) in crash_win.items():
                if a == t:
                    # the NIC dies: everything queued on the access
                    # link is lost and the receiver's admission state
                    # zeroes; arrivals are discarded until restart
                    port = port_by_link.get((topo.host_leaf[h], h))
                    if port is not None:
                        for fid, lost in port.drop_all().items():
                            lose(fid, lost)
                            flt_dropped += lost
                    receivers[h].crash_reset()
                    last_heavy[h] = None

        # ---- 1. senders inject into their NIC queue ----------------------- #
        # one batch per NIC port: each class's buffer partition is split
        # proportionally over that class's flows (source-side
        # backpressure never overflows the NIC queue, so un-injectable
        # bytes are refunded, not dropped)
        offers: Dict[str, List[Tuple[int, float]]] = {}
        for fid, f in enumerate(flows):
            tr = trackers.get(fid)
            b = senders[fid].offer(
                dt, window_room=(None if tr is None else
                                 tr.window_room_bytes(
                                     senders[fid].injected,
                                     delivered[fid])))
            if b > 0.0:
                offers.setdefault(f.src, []).append((fid, b))
        nic_take: Dict[int, float] = {}
        for host, items in offers.items():
            port = nic_ports[host]
            by_tc: Dict[int, List[Tuple[int, float]]] = {}
            for fid, b in items:
                by_tc.setdefault(tc_of[fid], []).append((fid, b))
            batch = []
            for tc, tc_items in by_tc.items():
                space = max(0.0, fcfg.switch.port_buffer_bytes
                            - port.tc_bytes(tc))
                total = sum(b for _, b in tc_items)
                scale = 1.0 if total <= space else space / total
                for fid, b in tc_items:
                    take = b if scale >= 1.0 else b * scale
                    senders[fid].injected -= b - take
                    nic_take[fid] = take
                    batch.append((fid, take, 0.0, None, tc))
            port.enqueue_batch(batch)
        if flet_track:
            # flowlet boundaries open on the first injection after an
            # idle gap; the flowlet index advances with the boundary so
            # the re-hash below draws a fresh deterministic hash
            flet_boundary.clear()
            for fid in cross_flows:
                if nic_take.get(fid, 0.0) > 0.0:
                    if t - flet_last[fid] > flet_gap:
                        flet_boundary.add(fid)
                        flet_k[fid] += 1
                    flet_last[fid] = t

        # ---- 1.5 routing layer: per-tick candidate selection -------------- #
        if rcfg.is_dynamic and cross_flows:
            occ_of_pair: Dict[Tuple[str, str], List[float]] = {}
            for (sl, dl), pair_fids in leaf_pairs.items():
                cands, plinks = pair_info[(sl, dl)]
                nc = len(cands)
                occ = occ_of_pair.get((sl, dl))
                if occ is None:
                    up_ports = switches[sl].ports
                    occ = occ_of_pair[(sl, dl)] = \
                        [up_ports[s].queued_bytes for s in cands]
                up = [all(lk not in down_now for lk in plinks[i])
                      for i in range(nc)]
                for fid in pair_fids:
                    cur = cur_spine[fid]
                    if rcfg.mode == "adaptive":
                        new = adaptive_pick(occ, up, cur, route_hyst)
                    elif rcfg.mode == "weighted_ecmp":
                        # a flowlet boundary (idle gap exceeded — see
                        # step 1) or a dead current path re-hashes onto
                        # the free-space-weighted candidate distribution
                        new = cur
                        if fid in flet_boundary or not up[cur]:
                            w = [max(route_buf - occ[i], 0.0)
                                 if up[i] else 0.0 for i in range(nc)]
                            if sum(w) > 0.0:
                                new = weighted_pick(
                                    w, flowlet_hash(fid, flet_k[fid]))
                    else:                                   # spray
                        new = cur
                        fr = spray_weights(occ, up, route_buf, cur)
                        route_frac[fid] = {cands[i]: fr[i]
                                           for i in range(nc)
                                           if fr[i] > 0.0}
                    if new != cur:
                        flow_reroutes[fid] += 1
                        cur_spine[fid] = new
                    if rcfg.mode != "spray":
                        route_frac[fid] = {cands[new]: 1.0}

        # ---- 2. tier-ordered forwarding ----------------------------------- #
        arrivals: Dict[str, Dict[int, List[float]]] = {}
        if need_cc:
            tick_tx.clear()
        for stage in stages:
            batches: Batches = {}
            flt_dropped += drain_stage(stage, arrivals, batches,
                                       down_now, t)
            flush(batches)

        # ---- 2.2 congestion signals: path delay + INT utilization --------- #
        # end-of-forwarding queue state along each flow's current path,
        # converted into the two telemetry channels the CC zoo consumes:
        # rtt = base + sum(queue/drain-budget) and util = max per-hop
        # HPCC-style (txRate/B + qlen/(B*T)).  Same arithmetic, same
        # read point as the vector engines' masked lanes.
        if need_cc:
            for fid in cc_flow_ids:
                c = cc_of[fid]
                f = flows[fid]
                sl, dl = flow_leaves[fid]
                if sl == dl:
                    path = (nic_ports[f.src], switches[sl].ports[f.dst])
                else:
                    # walk the flow's current frozen chain below its
                    # first hop (2-tier: leaf->spine->leaf->host;
                    # 3-level adds the super-spine transit)
                    hop = cand_of[fid][cur_spine[fid]] \
                        if fid in cur_spine else next_hop[(sl, fid)]
                    ports = [nic_ports[f.src], switches[sl].ports[hop]]
                    node = hop
                    while node != f.dst:
                        nh = next_hop[(node, fid)]
                        ports.append(switches[node].ports[nh])
                        node = nh
                    path = tuple(ports)
                qd = 0.0
                util = 0.0
                for port in path:
                    budget = port.link.gbps * bpt
                    q = port.queued_bytes
                    qd += q / budget
                    u = (tick_tx.get(port.link.key, 0.0)
                         + q * (dt / c.base_rtt_us)) / budget
                    if u > util:
                        util = u
                senders[fid].on_signal(c.base_rtt_us + qd * dt, util, dt)

        # ---- 2.5 spray reorder settling ----------------------------------- #
        if settle_ticks:
            slot = t % Hs
            for fid in range(F):
                ring_b[fid][slot] = 0.0
                ring_m[fid][slot] = 0.0
            for host, arr in arrivals.items():
                for fid, (b, m) in arr.items():
                    ring_b[fid][slot] = b
                    ring_m[fid][slot] = m
            arrivals = {}
            for fid, f in enumerate(flows):
                rs = (t - settle_f[fid]) % Hs
                b = ring_b[fid][rs]
                if b > 0.0:
                    arrivals.setdefault(f.dst, {})[fid] = \
                        [b, ring_m[fid][rs]]

        # ---- 3. receivers advance; CNPs route back ------------------------ #
        for host, rx in receivers.items():
            arr = arrivals.get(host, {})
            # fault layer: a crashed host discards everything on its
            # access link until restart; a gapped go-back-N window
            # discards out-of-order arrivals as duplicates (both feed
            # the retransmit ledger / instant re-credit via lose())
            cw = crash_win.get(host)
            if cw is not None and cw[0] <= t < cw[1] and arr:
                for fid, (b, _) in arr.items():
                    lose(fid, b)
                    flt_dropped += b
                arr = {}
            if recovery and arr:
                for fid in list(arr):
                    rec = recovery.get(fid)
                    if rec is not None and rec.gapped:
                        b = arr[fid][0]
                        rec.on_arrival(b)    # dup: discarded + ledgered
                        flt_dropped += b
                        del arr[fid]
            # arrivals enter the datapath's QoS admission classes: RNIC
            # buffer space is granted in priority order, so a LOW-class
            # bulk flow can no longer crowd out a HIGH-class one
            per_class = [0.0] * N_QOS
            for fid, (b, _) in arr.items():
                per_class[flows[fid].qos] += b
            total = sum(per_class)
            fb = rx.step(per_class)
            if cw is not None and t >= cw[1] and fb.accepted > 0.0 \
                    and host not in crash_rec_us:
                # first byte accepted after restart: recovery latency
                crash_rec_us[host] = now_us - cw[0] * dt
            if total > 0.0:
                acc = fb.accepted_qos or [0.0] * N_QOS
                share = [acc[q] / per_class[q] if per_class[q] > 0.0
                         else 0.0 for q in range(N_QOS)]
                for fid, (b, _) in arr.items():
                    d = b * share[flows[fid].qos]
                    delivered[fid] += d
                    # RNIC tail-drops are retransmitted too (fluid RC)
                    lose(fid, b - d)
                    if recovery and d > 0.0:
                        prog_set.add(fid)
                    f = flows[fid]
                    if (f.burst_bytes is not None
                            and math.isinf(completion[fid])
                            and delivered[fid]
                            >= burst_done_bytes(f.burst_bytes)):
                        completion[fid] = now_us
            # receiver-generated CNPs (escape-ladder ECN + RNIC watermark)
            # hit the heaviest arriving flow; with the access link paused
            # (arr empty) they fall back to the most recent heavy flow so
            # senders stay throttled during pauses, as in run_sim
            if arr:
                # deterministic tie-break (lowest flow id), independent of
                # arrival-dict insertion order — the vector engine's argmax
                # resolves ties the same way
                last_heavy[host] = max(sorted(arr), key=lambda i: arr[i][0])
            heavy = last_heavy.get(host)
            if fb.cnps and heavy is not None:
                for _ in range(fb.cnps):
                    heapq.heappush(pending_cnps,
                                   (t + cnp_delay_ticks[heavy], cnp_seq,
                                    heavy))
                    cnp_seq += 1
            # switch ECN marks -> per-flow CNPs, paced per DCQCN NP; the
            # pacing clock runs for every flow of this receiver, so marks
            # owed to a stalled/paused flow still convert on schedule
            for fid, (_, m) in arr.items():
                marked_backlog[fid] += m
            interval = rx.cfg.cnp_interval_us
            for fid in flows_by_dst.get(host, ()):
                cnp_accum_us[fid] += dt
                if marked_backlog[fid] > 0.0 and \
                        cnp_accum_us[fid] >= interval:
                    cnp_accum_us[fid] = 0.0
                    marked_backlog[fid] = 0.0
                    heapq.heappush(pending_cnps,
                                   (t + cnp_delay_ticks[fid], cnp_seq, fid))
                    cnp_seq += 1
        # deliver CNPs whose propagation delay has elapsed (same tick
        # when the flow's delay is 0 — the sender's rate machine is only
        # read at the next tick's offer, so end-of-tick delivery is exact)
        while pending_cnps and pending_cnps[0][0] <= t:
            _, _, fid = heapq.heappop(pending_cnps)
            senders[fid].on_cnp()

        # ---- 3.5 message layer: starts / completions this tick ------------ #
        # end-of-tick cumulative counters (post re-credit): a message
        # starts when injected bytes cross its threshold, completes when
        # delivered bytes do — go-back-N losses stretch exactly the
        # open messages' latency
        for fid, tr in trackers.items():
            tr.observe(now_us, senders[fid].injected, delivered[fid],
                       start_us=t * dt)

        # ---- 3.7 retransmit timers (fault layer) -------------------------- #
        # after the message observe: both engines record this tick's
        # latencies against the pre-fire injected count, and the
        # re-credit reopens the sender's tap from the next offer on
        if recovery:
            for fid, rec in recovery.items():
                credit = rec.tick(fid in prog_set)
                if credit > 0.0:
                    senders[fid].credit(credit)
            prog_set.clear()

        # ---- 4. PFC pause propagation ------------------------------------- #
        paused_pairs: Set[PauseKey] = set()
        for sw in switches.values():
            paused_pairs |= sw.update_pfc()
        if flt is not None and paused_pairs \
                and has_pause_cycle(paused_pairs):
            deadlock_ticks += 1
        by_link: Dict[LinkKey, Set[int]] = {}
        for lk, tc in paused_pairs:
            by_link.setdefault(lk, set()).add(tc)
            pause_tc_us[(lk, tc)] = pause_tc_us.get((lk, tc), 0.0) + dt
        paused_by_link = {lk: frozenset(tcs) for lk, tcs in by_link.items()}
        for lk in paused_by_link:
            pause_link_us[lk] = pause_link_us.get(lk, 0.0) + dt

    # -- aggregate ----------------------------------------------------------
    sim_us = ticks * dt
    per_host = {h: rx.finalize() for h, rx in receivers.items()}
    goodput = {fid: delivered[fid] * 8.0 / (sim_us * 1e-6) / 1e9
               for fid in delivered}
    tags = {fid: f.tag for fid, f in enumerate(flows)}
    incast = [completion[fid] for fid, f in enumerate(flows)
              if f.tag == "incast" and f.burst_bytes is not None]
    victims = [goodput[fid] for fid, f in enumerate(flows)
               if f.tag == "victim"]
    uplink_util = {}
    for lk, tx in uplink_tx.items():
        cap = topo.links[lk].gbps * 1e9 / 8.0 * (sim_us * 1e-6)
        uplink_util[lk] = tx / cap if cap > 0.0 else 0.0
    pause_tc_fanout: Dict[int, int] = {}
    for (lk, tc) in pause_tc_us:
        pause_tc_fanout[tc] = pause_tc_fanout.get(tc, 0) + 1
    # links down for the entire window carried nothing and could pause
    # nothing: drop them from the storm denominator and let
    # uplink_imbalance() skip them (flaps always leave some up-time)
    dead_links = {lk for lk, (a, u) in fail_ticks.items()
                  if a <= 0 and u >= ticks}
    return FabricResult(
        per_host=per_host,
        flow_goodput_gbps=goodput,
        flow_delivered_bytes=dict(delivered),
        flow_completion_us=dict(completion),
        flow_tags=tags,
        incast_completion_us=max(incast) if incast else float("nan"),
        victim_goodput_gbps=(sum(victims) / len(victims)
                             if victims else 0.0),
        has_victim=bool(victims),
        pause_link_us=pause_link_us,
        pause_tc_us=pause_tc_us,
        pause_fanout=len(pause_link_us),
        ecn_marked_bytes=sum(s.marked_bytes() for s in switches.values()),
        switch_dropped_bytes=sum(s.dropped_bytes()
                                 for s in switches.values())
        + sum(p.dropped_bytes for p in nic_ports.values()),
        uplink_util=uplink_util,
        flow_reroutes=dict(flow_reroutes),
        reroute_count=sum(flow_reroutes.values()),
        msg_latency_us={fid: tr.latencies for fid, tr in trackers.items()},
        msg_last_done_us={fid: tr.last_done_us
                          for fid, tr in trackers.items()},
        has_messages=bool(trackers),
        sim_us=sim_us,
        dropped_pkts=(flt_dropped / flt.mtu_bytes
                      if flt is not None else 0.0),
        retransmit_bytes=sum(r.retx_bytes for r in recovery.values()),
        crash_recovery_us={h: crash_rec_us.get(h, math.inf)
                           for h in crash_win},
        deadlock_ticks=deadlock_ticks,
        pause_tc_fanout=pause_tc_fanout,
        n_pausable_links=len(pausable - dead_links),
        dead_links=dead_links,
    )
