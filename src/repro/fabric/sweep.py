"""Vectorized parameter-sweep engine for the receiver datapath.

Packs the per-host fluid state of :class:`~repro.fabric.hosts.ReceiverHost`
(DCQCN machine, RNIC queue, DDIO/Jet drain, release rings, escape ladder,
PFC/CNP signalling) into stacked arrays and advances *all sweep points at
once*: one ``jax.vmap`` over the grid, one ``jax.lax.scan`` over ticks, one
XLA program — hundred-point sweeps run in seconds instead of minutes of
sequential ``run_sim`` python loops.

The exact same step function also runs batched under numpy (the
``backend="numpy"`` verification reference): both paths share a single
source of truth and differ only in the array namespace and the ring
scatter/gather, so their results agree to float32 round-off.  This
engine sweeps the *receiver* datapath only; op-granular message latency
lives in the fabric layer (:mod:`repro.fabric.messages`, tracked by both
``run_fabric`` and ``run_fabric_sweep`` via a log-bucket histogram) —
here the recurrence stays identical to ``run_sim`` and goodput matches
the scalar simulator point-for-point.

The release rings are circular (mod-H indexing) rather than run_sim's
full-horizon arrays: slot ``t % H`` is *written* every tick with that
tick's scheduled release and *read* ``d`` ticks later at ``(t - d) % H``.
H exceeds the largest delay, so a slot is always consumed before the ring
wraps back over it — no scatter-add and no zeroing, which keeps the hot
loop to one dynamic-update-slice + one gather per ring.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..core.simulator import SimConfig
from .hosts import hold_us_baseline, hold_us_jet
from ._scan import pick_unroll

_F = np.float32


# --------------------------------------------------------------------------- #
# Parameter packing
# --------------------------------------------------------------------------- #
_SCALARS = [
    # (name, extractor)
    ("jet", lambda c: 1.0 if c.mode == "jet" else 0.0),
    ("pfc_en", lambda c: 1.0 if c.pfc_enabled else 0.0),
    ("wm_cnp", lambda c: 1.0 if c.rnic_ecn_cnp else 0.0),
    ("line", lambda c: c.line_rate_gbps * c.incast_senders),
    ("line1", lambda c: c.line_rate_gbps),
    ("cap", lambda c: np.inf if c.offered_gbps is None else c.offered_gbps),
    ("pcie", lambda c: c.pcie_gbps),
    ("membw", lambda c: c.membw_total_gbps),
    ("cpu_bw", lambda c: c.cpu_membw_gbps),
    ("qp_bytes", lambda c: c.num_qps * c.msg_bytes),
    ("ddio", lambda c: c.ddio_bytes),
    ("knee", lambda c: c.miss_knee),
    ("rnic_buf", lambda c: c.rnic_buffer_bytes),
    ("xoff", lambda c: c.pfc_xoff),
    ("xon", lambda c: c.pfc_xon),
    ("ecn_th", lambda c: c.ecn_threshold),
    ("cnp_iv", lambda c: c.cnp_interval_us),
    ("pool", lambda c: c.jet_pool_bytes),
    ("sfrac", lambda c: c.straggler_frac),
    ("safe", lambda c: c.cache_safe),
    ("danger", lambda c: c.cache_danger),
    ("mem_esc", lambda c: c.mem_esc_bytes),
    # DCQCN
    ("dline", lambda c: c.dcqcn.line_rate_gbps),
    ("minr", lambda c: c.dcqcn.min_rate_gbps),
    ("g", lambda c: c.dcqcn.g),
    ("a_tmr", lambda c: c.dcqcn.alpha_timer_us),
    ("r_tmr", lambda c: c.dcqcn.rate_timer_us),
    ("bctr", lambda c: c.dcqcn.byte_counter_mb * (1 << 20)),
    ("ai", lambda c: c.dcqcn.ai_rate_gbps),
    ("hai", lambda c: c.dcqcn.hai_rate_gbps),
    ("fth", lambda c: c.dcqcn.f_threshold),
]


@dataclasses.dataclass
class SweepParams:
    """Stacked per-point parameters (all float32 arrays of shape [P])."""
    vals: Dict[str, np.ndarray]
    d_base: np.ndarray            # int32 release delays (ticks)
    d_strag: np.ndarray
    n_points: int
    ticks: int
    dt_us: float
    ring_len: int

    def envelope(self) -> dict:
        """Structure envelope for chunked execution (see the farm layer):
        passing this to :meth:`from_configs` on a slice of the grid floors
        the ring length so every chunk traces the same program shape."""
        return {"ring_len": self.ring_len}

    @classmethod
    def from_configs(cls, configs: Sequence[SimConfig],
                     envelope: dict | None = None) -> "SweepParams":
        if not configs:
            raise ValueError("empty sweep grid")
        dt = configs[0].dt_us
        ticks = int(configs[0].sim_time_s * 1e6 / dt)
        for c in configs:
            if c.dt_us != dt or int(c.sim_time_s * 1e6 / c.dt_us) != ticks:
                raise ValueError("sweep points must share dt and sim_time")
            if c.cpu_membw_schedule is not None:
                raise ValueError("cpu_membw_schedule is not sweepable; "
                                 "use run_sim for scheduled contention")
        vals = {name: np.array([fn(c) for c in configs], dtype=_F)
                for name, fn in _SCALARS}
        d_b, d_s = [], []
        for c in configs:
            hold = hold_us_jet(c) if c.mode == "jet" \
                else hold_us_baseline(c)
            d_b.append(max(1, int(hold / dt)))
            d_s.append(max(1, int(hold * c.straggler_mult / dt)))
        ring = int(max(max(d_b), max(d_s))) + 2
        if envelope:
            ring = max(ring, int(envelope.get("ring_len", 0)))
        return cls(vals=vals, d_base=np.array(d_b, np.int32),
                   d_strag=np.array(d_s, np.int32),
                   n_points=len(configs), ticks=ticks, dt_us=dt,
                   ring_len=ring)


def grid_configs(mk, mode: str = "jet", sim_time_s: float = 0.01,
                 **axes: Sequence) -> Tuple[List[SimConfig], List[dict]]:
    """Cartesian sweep grid: ``mk(mode, sim_time_s=..., **point)`` per
    combination of the ``axes`` lists.  Returns (configs, point-dicts)."""
    names = sorted(axes)
    configs, points = [], []
    for combo in itertools.product(*(axes[n] for n in names)):
        pt = dict(zip(names, combo))
        configs.append(mk(mode, sim_time_s=sim_time_s, **pt))
        points.append(pt)
    return configs, points


# --------------------------------------------------------------------------- #
# The shared per-tick step
# --------------------------------------------------------------------------- #
def _make_step(xp, ring_get, ring_set, p: Dict, dt: float,
               H: int, d_base, d_strag):
    """Build step(state, t) -> state in the given array namespace ``xp``.

    ``p`` maps parameter names to arrays (shape [] under vmap, [P] under
    numpy); the ring_* helpers hide the gather/update difference."""
    bpt = _F(1e9 / 8.0 * dt * 1e-6)      # bytes per (Gbps * tick)
    fdt = _F(dt)

    def cut(s, fire):
        """DCQCN on_cnp for points where ``fire`` holds."""
        s = dict(s)
        s["rt"] = xp.where(fire, s["rc"], s["rt"])
        s["rc"] = xp.where(fire,
                           xp.maximum(p["minr"],
                                      s["rc"] * (1.0 - s["alpha"] / 2.0)),
                           s["rc"])
        s["alpha"] = xp.where(
            fire, xp.minimum(_F(1.0), (1.0 - p["g"]) * s["alpha"] + p["g"]),
            s["alpha"])
        for k in ("t_us", "byts", "t_stage", "b_stage", "a_tus"):
            s[k] = xp.where(fire, _F(0.0), s[k])
        return s

    def step(s, t):
        s = dict(s)
        # ---- DCQCN advance ------------------------------------------------ #
        s["a_tus"] = s["a_tus"] + fdt
        a_fire = s["a_tus"] >= p["a_tmr"]
        s["alpha"] = xp.where(a_fire, (1.0 - p["g"]) * s["alpha"],
                              s["alpha"])
        s["a_tus"] = xp.where(a_fire, _F(0.0), s["a_tus"])
        s["t_us"] = s["t_us"] + fdt
        s["byts"] = s["byts"] + s["rc"] * bpt
        t_fire = s["t_us"] >= p["r_tmr"]
        s["t_stage"] = s["t_stage"] + t_fire
        s["t_us"] = xp.where(t_fire, _F(0.0), s["t_us"])
        b_fire = s["byts"] >= p["bctr"]
        s["b_stage"] = s["b_stage"] + b_fire
        s["byts"] = xp.where(b_fire, _F(0.0), s["byts"])
        fired = t_fire | b_fire
        stage = xp.minimum(s["t_stage"], s["b_stage"])
        s["rt"] = xp.where(fired & (stage == p["fth"]),
                           xp.minimum(p["dline"], s["rt"] + p["ai"]),
                           s["rt"])
        s["rt"] = xp.where(fired & (stage > p["fth"]),
                           xp.minimum(p["dline"], s["rt"] + p["hai"]),
                           s["rt"])
        s["rc"] = xp.where(fired,
                           xp.minimum(p["dline"],
                                      0.5 * (s["rc"] + s["rt"])),
                           s["rc"])

        # ---- sender -> RNIC ----------------------------------------------- #
        offered = xp.minimum(xp.minimum(s["rc"], p["line"]), p["cap"])
        arriving = xp.where(s["pfc"], _F(0.0), offered * bpt)
        space = p["rnic_buf"] - s["rnic_q"]
        accepted = xp.minimum(arriving, xp.maximum(space, _F(0.0)))
        s["dropped"] = s["dropped"] + (arriving - accepted)
        s["rnic_q"] = s["rnic_q"] + accepted

        # ---- drain RNIC -> host ------------------------------------------- #
        jet = p["jet"] > 0.5
        ws = p["qp_bytes"] + s["resident"]
        miss = xp.clip((ws - p["ddio"]) / (p["knee"] * p["ddio"]),
                       _F(0.0), _F(1.0))
        s["miss_sum"] = s["miss_sum"] + xp.where(jet, _F(0.0), miss)
        avail_dram = xp.maximum(_F(0.0), p["membw"] - p["cpu_bw"])
        ddio_bw = xp.where(miss > 1e-9,
                           xp.minimum(p["pcie"],
                                      avail_dram / (2.0 * miss + 1e-30)),
                           p["pcie"])
        ddio_drained = xp.minimum(s["rnic_q"], ddio_bw * bpt)
        pool_free = xp.maximum(_F(0.0), p["pool"] - s["resident"])
        jet_bw = xp.minimum(p["pcie"], p["line1"] * 4.0)
        jet_drained = xp.minimum(xp.minimum(s["rnic_q"], jet_bw * bpt),
                                 pool_free)
        drained = xp.where(jet, jet_drained, ddio_drained)
        s["nic_dram"] = s["nic_dram"] + \
            xp.where(jet, _F(0.0), ddio_drained * 2.0 * miss)
        strag_share = xp.where(jet, p["sfrac"], _F(0.0))
        s["rnic_q"] = s["rnic_q"] - drained
        base_part = drained * (1.0 - strag_share)
        strag_part = drained * strag_share
        # write this tick's scheduled release at t%H; it is consumed at
        # t+d (< t+H), i.e. before the ring wraps over the slot
        s["ring_b"] = ring_set(s["ring_b"], t % H, base_part)
        s["ring_s"] = ring_set(s["ring_s"], t % H, strag_part)
        s["resident"] = s["resident"] + drained
        s["strag_res"] = s["strag_res"] + strag_part
        s["drained"] = s["drained"] + drained

        # ---- post-NIC consumption ----------------------------------------- #
        for ring_key, delay, is_strag in (("ring_b", d_base, False),
                                          ("ring_s", d_strag, True)):
            # releases scheduled ``delay`` ticks ago (zero before warm-up:
            # unwritten slots still hold their initial 0)
            r = ring_get(s[ring_key], (t - delay) % H)
            r = xp.where(t >= delay, r, _F(0.0))
            void = xp.minimum(r, s["esc_debt"])
            s["esc_debt"] = s["esc_debt"] - void
            r = r - void
            repay = xp.minimum(void, s["repl_debt"])
            s["repl_debt"] = s["repl_debt"] - repay
            s["repl_mem"] = xp.maximum(_F(0.0), s["repl_mem"] - repay)
            s["resident"] = xp.maximum(_F(0.0), s["resident"] - r)
            if is_strag:
                s["strag_res"] = xp.maximum(_F(0.0), s["strag_res"] - r)

        # ---- Jet escape ladder -------------------------------------------- #
        avail = xp.maximum(_F(0.0), p["pool"] - s["resident"]) / p["pool"]
        esc_on = jet & (avail < p["safe"])
        can_replace = s["repl_mem"] < p["mem_esc"]
        x_rep = xp.where(esc_on & can_replace,
                         xp.maximum(_F(0.0),
                                    xp.minimum(s["strag_res"],
                                               p["mem_esc"]
                                               - s["repl_mem"])),
                         _F(0.0))
        s["resident"] = s["resident"] - x_rep
        s["strag_res"] = s["strag_res"] - x_rep
        s["esc_debt"] = s["esc_debt"] + x_rep
        s["repl_debt"] = s["repl_debt"] + x_rep
        s["repl_mem"] = s["repl_mem"] + x_rep
        s["esc_dram"] = s["esc_dram"] + 0.1 * x_rep
        s["replaces"] = s["replaces"] + (x_rep > 0.0)
        x_cop = xp.where(esc_on & ~can_replace, s["strag_res"], _F(0.0))
        s["resident"] = s["resident"] - x_cop
        s["strag_res"] = s["strag_res"] - x_cop
        s["esc_debt"] = s["esc_debt"] + x_cop
        s["esc_dram"] = s["esc_dram"] + x_cop
        s["copies"] = s["copies"] + (x_cop > 0.0)
        avail2 = xp.maximum(_F(0.0), p["pool"] - s["resident"]) / p["pool"]
        in_danger = esc_on & (avail2 < p["danger"])
        s["ecn_tus"] = xp.where(in_danger, s["ecn_tus"] + fdt, s["ecn_tus"])
        fire_ecn = in_danger & (s["ecn_tus"] >= p["cnp_iv"])
        s["ecn_tus"] = xp.where(fire_ecn, _F(0.0), s["ecn_tus"])
        s["cnps"] = s["cnps"] + fire_ecn
        s["ecns"] = s["ecns"] + fire_ecn
        s["pool_sum"] = s["pool_sum"] + xp.where(jet, s["resident"],
                                                 _F(0.0))
        s["pool_peak"] = xp.maximum(s["pool_peak"],
                                    xp.where(jet, s["resident"], _F(0.0)))

        # ---- congestion signalling ----------------------------------------- #
        q_frac = s["rnic_q"] / p["rnic_buf"]
        pfc_en = p["pfc_en"] > 0.5
        s["pfc"] = pfc_en & xp.where(s["pfc"], q_frac >= p["xon"],
                                     q_frac > p["xoff"])
        s["pfc_us"] = s["pfc_us"] + xp.where(s["pfc"], fdt, _F(0.0))
        s["cnp_tus"] = s["cnp_tus"] + fdt
        fire_wm = (p["wm_cnp"] > 0.5) & (q_frac > p["ecn_th"]) \
            & (s["cnp_tus"] >= p["cnp_iv"])
        s["cnp_tus"] = xp.where(fire_wm, _F(0.0), s["cnp_tus"])
        s["cnps"] = s["cnps"] + fire_wm

        # rate cuts, in the same order run_sim applies them
        s = cut(s, fire_ecn)
        s = cut(s, fire_wm)
        return s

    return step


def _init_state(xp, shape, H, p):
    z = lambda: xp.zeros(shape, _F)   # noqa: E731
    s = {k: z() for k in
         ("t_us", "byts", "t_stage", "b_stage", "a_tus", "ecn_tus",
          "rnic_q", "resident", "strag_res", "esc_debt", "repl_debt",
          "repl_mem", "dropped", "drained", "nic_dram", "esc_dram",
          "miss_sum", "pool_sum", "pool_peak", "cnps", "ecns",
          "replaces", "copies", "pfc_us")}
    s["rc"] = p["dline"] + z()
    s["rt"] = p["dline"] + z()
    s["alpha"] = xp.ones(shape, _F)
    s["cnp_tus"] = p["cnp_iv"] + z()   # allow an immediate first CNP
    s["pfc"] = xp.zeros(shape, bool)
    s["ring_b"] = xp.zeros(shape + (H,), _F)
    s["ring_s"] = xp.zeros(shape + (H,), _F)
    return s


def _results(s, sp: SweepParams) -> Dict[str, np.ndarray]:
    sim_us = sp.ticks * sp.dt_us
    drained = np.asarray(s["drained"], np.float64)
    miss_n = np.maximum(1, sp.ticks * (1.0 - sp.vals["jet"]))
    return {
        "goodput_gbps": drained * 8.0 / (sim_us * 1e-6) / 1e9,
        "cnp_count": np.asarray(s["cnps"], np.float64),
        "escape_ecn": np.asarray(s["ecns"], np.float64),
        "escape_replaces": np.asarray(s["replaces"], np.float64),
        "escape_copies": np.asarray(s["copies"], np.float64),
        "ddio_miss_rate": np.asarray(s["miss_sum"], np.float64) / miss_n,
        "pool_peak_bytes": np.asarray(s["pool_peak"], np.float64),
        "pool_avg_bytes": np.asarray(s["pool_sum"], np.float64) / sp.ticks,
        "pfc_pause_us": np.asarray(s["pfc_us"], np.float64),
        "dropped_bytes": np.asarray(s["dropped"], np.float64),
        "nic_dram_gbps": np.asarray(s["nic_dram"], np.float64) * 8.0
        / (sim_us * 1e-6) / 1e9,
        "escape_dram_gbps": np.asarray(s["esc_dram"], np.float64) * 8.0
        / (sim_us * 1e-6) / 1e9,
    }


# --------------------------------------------------------------------------- #
# Backends
# --------------------------------------------------------------------------- #
def _run_numpy(sp: SweepParams) -> Dict[str, np.ndarray]:
    P, H = sp.n_points, sp.ring_len
    rows = np.arange(P)

    def ring_get(ring, idx):            # idx: [P] int array
        return ring[rows, idx]

    def ring_set(ring, idx, v):         # idx: scalar (t % H)
        ring[:, idx] = v
        return ring

    p = sp.vals
    step = _make_step(np, ring_get, ring_set, p, sp.dt_us, H,
                      sp.d_base, sp.d_strag)
    s = _init_state(np, (P,), H, p)
    for t in range(sp.ticks):
        s = step(s, t)
    return _results(s, sp)


@functools.lru_cache(maxsize=8)
def _jax_program(n_points: int, ticks: int, ring_len: int, dt_us: float,
                 unroll: int):
    """Compiled sweep program, cached on the trace-relevant shape tuple so
    repeated sweeps over same-shaped grids skip compilation.

    The initial scan carry is an argument (built cheaply in numpy per
    call) rather than a traced constant, so ``donate_argnums`` lets XLA
    reuse its buffers — the [P, H] release rings dominate the state —
    instead of holding the zero-init copy alive next to the running
    carry.  The unroll factor comes from :func:`repro.fabric._scan
    .pick_unroll`: measured on this stack, ``unroll=1`` beats the old
    hard-coded 8 both cold (~5x less XLA compile) and warm (~1.6x — the
    body is already hundreds of fused element-wise ops, so while-loop
    overhead is negligible and unrolling only bloats the program).
    """
    import jax
    import jax.numpy as jnp

    H = ring_len

    def ring_get(ring, idx):
        return ring[idx]

    def ring_set(ring, idx, v):
        return ring.at[idx].set(v)

    def one_point(s0, pvals, d_b, d_s):
        step = _make_step(jnp, ring_get, ring_set, pvals,
                          dt_us, H, d_b, d_s)

        def body(s, t):
            return step(s, t), None

        s, _ = jax.lax.scan(body, s0, jnp.arange(ticks), unroll=unroll)
        return s

    return jax.jit(jax.vmap(one_point), donate_argnums=(0,))


def _run_jax(sp: SweepParams, unroll="auto") -> Dict[str, np.ndarray]:
    import jax.numpy as jnp

    u = pick_unroll(None if unroll == "auto" else unroll)
    fn = _jax_program(sp.n_points, sp.ticks, sp.ring_len, sp.dt_us, u)
    s0 = _init_state(np, (sp.n_points,), sp.ring_len, sp.vals)
    pv = {k: jnp.asarray(v) for k, v in sp.vals.items()}
    final = fn({k: jnp.asarray(v) for k, v in s0.items()}, pv,
               jnp.asarray(sp.d_base), jnp.asarray(sp.d_strag))
    final = {k: np.asarray(v) for k, v in final.items()}
    return _results(final, sp)


def run_sweep(configs: Sequence[SimConfig], backend: str = "jax",
              unroll="auto",
              envelope: dict | None = None) -> Dict[str, np.ndarray]:
    """Advance every config in ``configs`` through the full fluid recurrence
    at once; returns {metric: array[P]} aligned with the input order.

    ``envelope`` (from :meth:`SweepParams.envelope` of the full grid) floors
    the ring length so chunked runs of a larger grid share one compiled
    program shape; per-point results are unchanged (release slots past a
    point's own delay are never read)."""
    sp = SweepParams.from_configs(configs, envelope=envelope)
    if backend == "numpy":
        out = _run_numpy(sp)
    elif backend == "jax":
        out = _run_jax(sp, unroll)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    return out
