"""Versioned run artifacts for farm sweeps.

A farm run writes everything it learns under one directory::

    experiments/runs/<run_id>/
        manifest.json        # grid spec, chunk plan, envelope, git SHA,
                             # engine, per-chunk status + timings
        chunk_0000.npz       # per-chunk FabricResult shards (real points
        chunk_0001.npz       #   only -- padding is sliced off on save)
        ...
        result.npz           # merged [G] metric table, input order

The manifest is the resume contract: a restarted run re-reads it, checks
which ``chunk_*.npz`` shards exist and are loadable, and dispatches only
the missing chunks (see :func:`repro.fabric.farm.run_farm`).  Shards are
written atomically (tmp file + ``os.replace``) so a killed run can never
leave a half-written shard that a resume would trust.

Everything here is plain numpy + json on purpose: artifacts must be
readable without jax and from any process (the trajectory dashboard and
the CI resume assertion both consume them cold).
"""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

DEFAULT_RUNS_DIR = os.path.join("experiments", "runs")

_MANIFEST = "manifest.json"
_RESULT = "result.npz"


def new_run_id(prefix: str = "run") -> str:
    """Timestamped, collision-resistant run id (sortable by start time)."""
    stamp = time.strftime("%Y%m%d-%H%M%S")
    salt = os.urandom(3).hex()
    return f"{prefix}-{stamp}-{salt}"


def git_sha(repo_dir: Optional[str] = None) -> str:
    """Current git commit (short), or ``"unknown"`` outside a checkout."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=repo_dir, capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except OSError:
        return "unknown"


def config_hash(scens: Sequence) -> str:
    """Cheap fingerprint of a scenario grid: point count + names.

    Scenario names encode every axis value the builders sweep, so two
    grids with equal hashes ran the same points in the same order —
    which is exactly what a resume must check before trusting shards.
    """
    import hashlib
    h = hashlib.sha256()
    h.update(str(len(scens)).encode())
    for sc in scens:
        h.update(getattr(sc, "name", repr(sc)).encode())
        h.update(b"\0")
    return h.hexdigest()[:16]


def run_dir(run_id: str, out_dir: str = DEFAULT_RUNS_DIR) -> str:
    return os.path.join(out_dir, run_id)


def chunk_path(rdir: str, chunk: int) -> str:
    return os.path.join(rdir, f"chunk_{chunk:04d}.npz")


def _atomic_write_bytes(path: str, write_fn) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        write_fn(f)
    os.replace(tmp, path)


def write_manifest(rdir: str, manifest: dict) -> None:
    os.makedirs(rdir, exist_ok=True)
    _atomic_write_bytes(
        os.path.join(rdir, _MANIFEST),
        lambda f: f.write(json.dumps(manifest, indent=2,
                                     sort_keys=True).encode()))


def read_manifest(rdir: str) -> Optional[dict]:
    path = os.path.join(rdir, _MANIFEST)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def save_chunk(rdir: str, chunk: int, results: Dict[str, np.ndarray],
               meta: Optional[dict] = None) -> str:
    """Persist one chunk's (already de-padded) result arrays + metadata."""
    os.makedirs(rdir, exist_ok=True)
    path = chunk_path(rdir, chunk)
    payload = {k: np.asarray(v) for k, v in results.items()}
    payload["__meta__"] = np.frombuffer(
        json.dumps(meta or {}).encode(), dtype=np.uint8)
    _atomic_write_bytes(path, lambda f: np.savez(f, **payload))
    return path


def load_chunk(rdir: str, chunk: int):
    """Load one shard -> ``(results, meta)``; ``None`` if missing/corrupt."""
    path = chunk_path(rdir, chunk)
    if not os.path.exists(path):
        return None
    try:
        with np.load(path) as z:
            results = {k: z[k] for k in z.files if k != "__meta__"}
            meta = json.loads(z["__meta__"].tobytes().decode()) \
                if "__meta__" in z.files else {}
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return None
    return results, meta


def completed_chunks(rdir: str, n_chunks: int) -> List[int]:
    """Chunk indices whose shards exist *and* load cleanly."""
    done = []
    for k in range(n_chunks):
        if load_chunk(rdir, k) is not None:
            done.append(k)
    return done


def merge_chunks(rdir: str, plan: Sequence[dict],
                 n_points: int) -> Dict[str, np.ndarray]:
    """Stitch every chunk shard back into [G]-length arrays (input
    order), persist as ``result.npz`` and return the merged table."""
    merged: Dict[str, np.ndarray] = {}
    for entry in plan:
        loaded = load_chunk(rdir, entry["chunk"])
        if loaded is None:
            raise FileNotFoundError(
                f"missing chunk shard {entry['chunk']} in {rdir}; "
                "run is incomplete — resume it first")
        results, _ = loaded
        for k, v in results.items():
            if k not in merged:
                merged[k] = np.zeros((n_points,) + v.shape[1:], v.dtype)
            merged[k][entry["start"]:entry["stop"]] = v
    _atomic_write_bytes(os.path.join(rdir, _RESULT),
                        lambda f: np.savez(f, **merged))
    return merged


def load_result(rdir: str) -> Optional[Dict[str, np.ndarray]]:
    path = os.path.join(rdir, _RESULT)
    if not os.path.exists(path):
        return None
    with np.load(path) as z:
        return {k: z[k] for k in z.files}


def list_runs(out_dir: str = DEFAULT_RUNS_DIR) -> List[dict]:
    """Manifests of every run under ``out_dir``, newest first."""
    if not os.path.isdir(out_dir):
        return []
    runs = []
    for name in sorted(os.listdir(out_dir), reverse=True):
        m = read_manifest(os.path.join(out_dir, name))
        if m is not None:
            runs.append(m)
    return runs
