"""Op-granular message layer decorated over the fluid fabric core.

The fluid engines move *bytes*; the paper's headline claims are about
*tail message latency* (memory-bandwidth contention causes "a large
increase of tail latency"; Lamda cuts HPC communication latency by
35.1%).  This module adds the op layer without abandoning the fluid
core: a flow with a :class:`MessageConfig` is interpreted as a stream of
fixed-size verbs operations riding the flow's byte stream.  Message
``k`` *starts* when the flow's cumulative injected bytes first exceed
``k * msg_bytes`` (its first byte enters the stream — op latency
includes serialization, like a verbs post-to-CQE time) and *completes*
when cumulative delivered bytes reach ``(k+1) * msg_bytes`` — so drops
and RNIC tail-drops, which the fluid core
re-credits to ``injected`` (go-back-N retransmission), automatically
stretch exactly the in-flight messages' latency, and an outstanding
window ``W`` caps ``injected - delivered`` at ``W * msg_bytes`` (the
classic verbs queue-depth sweep knob).

Verbs semantics follow the RDMA verbs split the paper's testbed
measures:

``write``
    One-sided RDMA WRITE: no receiver CPU involvement.  Per-op issue
    overhead ``write_gap_us`` caps the op rate (the Mops plateau for
    small messages); the wire latency is the message latency.
``send``
    Two-sided SEND/RECV: the receiver must post + complete a WQE, so
    each op pays ``send_extra_us`` of receiver-side completion latency
    on top of the wire time, and the per-op gap ``send_gap_us`` is
    larger (both sides touch descriptors).

Per-message completion times feed two percentile paths with a tested
agreement bound:

* the scalar driver keeps the exact per-message latency list
  (:class:`MessageTracker`) — sort + nearest-rank gives the reference
  p50/p99/p999;
* the vector engines (numpy/jax) fold completions into a fixed
  ``HIST_BUCKETS``-bucket log-spaced histogram (:class:`LogHistogram`
  arithmetic, streamed as a per-flow count tensor) whose geometric-
  midpoint percentile estimate is within a *documented* relative bound
  of the exact value: buckets grow by ``r = (hi/lo)**(1/B)`` per step,
  the midpoint is off from any value in the bucket by at most a factor
  ``sqrt(r)``, hence ``rel_error <= sqrt(r) - 1``
  (:func:`hist_rel_error_bound`; ~4.7% for the default 128 buckets over
  [1 us, 1e5 us]).  ``tests/test_messages.py`` pins this bound.

Message counting uses ``floor(bytes / msg_bytes + MSG_COUNT_EPS)`` in
every engine: the epsilon (1e-6 of a message) makes the count robust to
the ~1e-13-relative accumulation differences between the scalar float64
sums and the split hi/lo accumulators of the vector engines, so a burst
that ends exactly on a message boundary counts identically everywhere.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

VERBS = ("write", "send")
RECOVERY_MODES = ("go_back_n", "selective")

# log-histogram domain shared by every engine: 1 us (one tick — nothing
# completes faster) to 100 ms (the default sim horizon)
HIST_MIN_US = 1.0
HIST_MAX_US = 1e5
HIST_BUCKETS = 128

# counting slack, in units of one message (see module docstring)
MSG_COUNT_EPS = 1e-6


@dataclasses.dataclass
class MessageConfig:
    """Op-layer interpretation of one flow's byte stream.

    ``window=None`` means an unbounded outstanding window: the op layer
    only *observes* the fluid stream (message latencies are still
    recorded) without ever gating injection — with DCQCN this reproduces
    the plain fluid goodput.  The vector engines require a finite
    window (state is carried in a fixed ring); use the scalar driver
    for the unbounded case.
    """
    verb: str = "write"
    msg_bytes: float = 64 * 1024
    window: Optional[int] = 16           # max outstanding messages
    # per-op issue overhead (us) — caps the op rate: the Mops plateau
    # observed for small messages when the wire is not the bottleneck
    write_gap_us: float = 0.25
    send_gap_us: float = 0.70
    # two-sided receive completion cost added to every SEND's latency
    send_extra_us: float = 1.5
    # loss recovery (active only when FabricConfig.faults is set — see
    # repro.fabric.faults): go_back_n replays the whole outstanding
    # span after an RTO with exponential backoff and discards
    # out-of-gap arrivals as duplicates; selective (IRN-style) keeps
    # what arrived and replays only the lost span after a NACK delay
    recovery: str = "go_back_n"
    rto_us: float = 50.0                 # base retransmission timeout
    rto_backoff: float = 2.0             # RTO multiplier per retry
    rto_cap: int = 6                     # max backoff doublings
    nack_us: float = 8.0                 # selective-retransmit delay

    def __post_init__(self) -> None:
        if self.verb not in VERBS:
            raise ValueError(f"unknown verb {self.verb!r}; "
                             f"pick one of {VERBS}")
        if self.msg_bytes <= 0.0:
            raise ValueError("msg_bytes must be positive")
        if self.window is not None and self.window < 1:
            raise ValueError("window must be >= 1 (or None for unbounded)")
        if self.write_gap_us <= 0.0 or self.send_gap_us <= 0.0:
            raise ValueError("per-op gaps must be positive")
        if self.send_extra_us < 0.0:
            raise ValueError("send_extra_us must be >= 0")
        if self.recovery not in RECOVERY_MODES:
            raise ValueError(f"unknown recovery {self.recovery!r}; "
                             f"pick one of {RECOVERY_MODES}")
        if self.rto_us <= 0.0 or self.nack_us <= 0.0:
            raise ValueError("rto_us and nack_us must be positive")
        if self.rto_backoff < 1.0:
            raise ValueError("rto_backoff must be >= 1")
        if self.rto_cap < 0:
            raise ValueError("rto_cap must be >= 0")

    @property
    def op_gap_us(self) -> float:
        return self.write_gap_us if self.verb == "write" \
            else self.send_gap_us

    @property
    def extra_us(self) -> float:
        """Latency added to every message (two-sided completion cost)."""
        return self.send_extra_us if self.verb == "send" else 0.0

    @property
    def op_rate_gbps(self) -> float:
        """Issue-rate cap as a byte rate: one op per ``op_gap_us``.

        ``msg_bytes * 8 bits / (gap us)`` — for large messages this is
        far above any line rate (the wire dominates); for small ones it
        is the binding cap that produces the Mops plateau.
        """
        return self.msg_bytes * 0.008 / self.op_gap_us

    def verb_code(self) -> int:
        """Integer code for stacked per-point parameters (vector)."""
        return VERBS.index(self.verb)

    def recovery_code(self) -> int:
        """Integer code for stacked per-point parameters (vector)."""
        return RECOVERY_MODES.index(self.recovery)


def msg_count(total_bytes: float, msg_bytes: float) -> int:
    """Whole messages contained in ``total_bytes`` (epsilon-robust).

    Counts *completion* crossings: message ``i`` is covered once
    ``total_bytes >= (i+1) * msg_bytes``."""
    return int(math.floor(total_bytes / msg_bytes + MSG_COUNT_EPS))


def msg_started(total_bytes: float, msg_bytes: float) -> int:
    """Messages whose *first* byte is inside ``total_bytes``.

    A verbs op is posted when its first byte enters the stream, so op
    latency includes serialization: ``ceil`` rather than ``floor``, with
    the same epsilon convention (an exact multiple starts nothing new).
    """
    return int(math.ceil(total_bytes / msg_bytes - MSG_COUNT_EPS))


def exact_percentile(values: Sequence[float], q: float) -> float:
    """Nearest-rank percentile of exact samples; 0.0 on an empty set.

    ``rank = ceil(q/100 * n)`` (clamped to [1, n]) — the same convention
    the histogram estimator applies to bucket counts, so the two paths
    agree up to bucket quantization only.
    """
    n = len(values)
    if n == 0:
        return 0.0
    s = sorted(values)
    rank = max(1, min(n, int(math.ceil(q / 100.0 * n))))
    return s[rank - 1]


def hist_ratio(lo: float = HIST_MIN_US, hi: float = HIST_MAX_US,
               buckets: int = HIST_BUCKETS) -> float:
    """Per-bucket growth factor ``r`` of the log-spaced histogram."""
    return (hi / lo) ** (1.0 / buckets)


def hist_rel_error_bound(lo: float = HIST_MIN_US, hi: float = HIST_MAX_US,
                         buckets: int = HIST_BUCKETS) -> float:
    """Documented worst-case relative error of the midpoint estimate.

    A value in bucket ``b`` lies in ``[lo*r^b, lo*r^(b+1))``; the
    estimate is the geometric midpoint ``lo*r^(b+0.5)``, at most a
    factor ``sqrt(r)`` away, i.e. relative error ``sqrt(r) - 1``.
    The bound only covers in-domain samples: latencies above ``hi``
    land in the explicit overflow counter (:class:`LogHistogram`
    ``overflow_count``), where no midpoint exists — a percentile that
    lands in overflow is reported as ``hi`` (a *lower* bound) and
    :meth:`LogHistogram.rel_error_bound` widens to ``inf`` so the
    violation is signalled rather than silent.
    """
    return math.sqrt(hist_ratio(lo, hi, buckets)) - 1.0


def hist_bucket(v_us: float, lo: float = HIST_MIN_US,
                hi: float = HIST_MAX_US,
                buckets: int = HIST_BUCKETS) -> int:
    """Bucket index of a latency sample (clamped into [0, buckets-1])."""
    if v_us <= lo:
        return 0
    b = int(math.floor(math.log(v_us / lo) / math.log(hist_ratio(
        lo, hi, buckets))))
    return min(max(b, 0), buckets - 1)


def hist_estimate(bucket: int, lo: float = HIST_MIN_US,
                  hi: float = HIST_MAX_US,
                  buckets: int = HIST_BUCKETS) -> float:
    """Geometric-midpoint latency estimate of a bucket."""
    return lo * hist_ratio(lo, hi, buckets) ** (bucket + 0.5)


class LogHistogram:
    """Streaming fixed-bucket log histogram with nearest-rank percentiles.

    The deterministic reference implementation of the arithmetic the
    vector engines carry as a ``[buckets]`` count tensor per flow —
    same bucket edges, same midpoint estimate, same nearest-rank
    convention as :func:`exact_percentile`.
    """

    def __init__(self, lo: float = HIST_MIN_US, hi: float = HIST_MAX_US,
                 buckets: int = HIST_BUCKETS):
        if not (hi > lo > 0.0) or buckets < 1:
            raise ValueError("need hi > lo > 0 and buckets >= 1")
        self.lo, self.hi, self.buckets = lo, hi, buckets
        self.counts = [0] * buckets
        self.n = 0
        # samples above hi: counted (they are real completions — n and
        # percentile ranks include them) but kept out of the in-range
        # buckets, whose midpoint estimate would otherwise silently
        # report a value *below* the true latency
        self.overflow_count = 0

    def add(self, v_us: float) -> None:
        if v_us > self.hi:
            self.overflow_count += 1
        else:
            self.counts[hist_bucket(v_us, self.lo, self.hi,
                                    self.buckets)] += 1
        self.n += 1

    def rel_error_bound(self) -> float:
        """The documented midpoint bound — widened to ``inf`` when any
        sample overflowed the domain (the overflow region has no
        midpoint, so no finite bound holds)."""
        if self.overflow_count:
            return math.inf
        return hist_rel_error_bound(self.lo, self.hi, self.buckets)

    def percentile(self, q: float) -> float:
        """Estimated q-th percentile; 0.0 on an empty histogram.  A
        rank that lands in the overflow region reports ``hi`` — an
        explicit lower bound on the true value (check
        :attr:`overflow_count` / :meth:`rel_error_bound`)."""
        if self.n == 0:
            return 0.0
        rank = max(1, min(self.n, int(math.ceil(q / 100.0 * self.n))))
        acc = 0
        for b, c in enumerate(self.counts):
            acc += c
            if acc >= rank:
                return hist_estimate(b, self.lo, self.hi, self.buckets)
        return self.hi


def percentile_from_counts(counts, q: float, lo: float = HIST_MIN_US,
                           hi: float = HIST_MAX_US, overflow=None):
    """Vectorized nearest-rank percentile over histogram count arrays.

    ``counts`` is any numpy-like array ``[..., B]`` (the vector engines'
    per-flow or per-point histograms); returns ``[...]`` midpoint
    estimates, 0.0 where the histogram is empty.  ``overflow`` is an
    optional ``[...]`` count of samples above ``hi`` (the vector twin
    of :attr:`LogHistogram.overflow_count`): overflowed samples join
    the rank denominator, and a rank landing in the overflow region
    reports ``hi`` — an explicit lower bound — instead of an in-range
    midpoint below the true value.  Imports numpy lazily so the scalar
    path stays dependency-free.
    """
    import numpy as np
    c = np.asarray(counts, dtype=np.float64)
    buckets = c.shape[-1]
    in_range = c.sum(axis=-1)
    over = np.zeros_like(in_range) if overflow is None \
        else np.asarray(overflow, dtype=np.float64)
    n = in_range + over
    rank = np.maximum(1.0, np.minimum(n, np.ceil(q / 100.0 * n)))
    cum = np.cumsum(c, axis=-1)
    idx = np.argmax(cum >= rank[..., None], axis=-1)
    est = lo * hist_ratio(lo, hi, buckets) ** (idx + 0.5)
    est = np.where(rank > in_range, hi, est)
    return np.where(n > 0, est, 0.0)


class MessageTracker:
    """Exact per-flow message bookkeeping for the scalar driver.

    ``observe(now, injected, delivered)`` is called once per tick with
    the flow's cumulative byte counters (post re-credit, so go-back-N
    losses keep the affected messages open).  Message ``i`` starts when
    its first byte injects (``injected`` crosses ``i * msg_bytes``) and
    completes when its last byte lands (``delivered`` crosses
    ``(i+1) * msg_bytes``), so the recorded latency covers
    serialization + transit + queueing + retransmission, like a verbs
    post-to-CQE time.  The started high-water mark only ever grows — a
    re-credit that drops ``injected`` below an already-started
    message's threshold does *not* restart it; the message keeps its
    original start time and simply completes later (go-back-N: the op
    is done when its bytes finally all arrive).
    """

    def __init__(self, cfg: MessageConfig):
        self.cfg = cfg
        self.starts: List[float] = []        # start time per message index
        self.latencies: List[float] = []     # completion order == index order
        self.hw = 0                          # messages started
        self.done = 0                        # messages completed
        self.last_done_us = 0.0
        # latencies above the shared histogram domain (HIST_MAX_US):
        # exact percentiles are unaffected, but any histogram built
        # from this flow would overflow — nonzero means the documented
        # 4.6% bound does not hold for this flow's tail
        self.overflow_count = 0

    @property
    def outstanding(self) -> int:
        return self.hw - self.done

    def window_room_bytes(self, injected: float, delivered: float) -> float:
        """Bytes the outstanding window still admits (inf if unbounded)."""
        if self.cfg.window is None:
            return math.inf
        return max(self.cfg.window * self.cfg.msg_bytes
                   - (injected - delivered), 0.0)

    def observe(self, now_us: float, injected: float, delivered: float,
                start_us: Optional[float] = None) -> None:
        """Record this tick's crossings.  ``now_us`` is the tick's *end*
        (completion timestamp); ``start_us`` is the tick's *beginning*
        (start timestamp of messages first injected this tick), so a
        message injected and delivered within one cut-through tick
        reports one tick of latency — the fluid model's floor — rather
        than zero, keeping every sample inside the histogram domain.
        """
        if start_us is None:
            start_us = now_us
        m = self.cfg.msg_bytes
        ns = msg_started(injected, m)
        while self.hw < ns:
            self.starts.append(start_us)
            self.hw += 1
        nd = min(msg_count(delivered, m), self.hw)
        extra = self.cfg.extra_us
        while self.done < nd:
            lat = now_us - self.starts[self.done] + extra
            self.latencies.append(lat)
            if lat > HIST_MAX_US:
                self.overflow_count += 1
            self.done += 1
            self.last_done_us = now_us

    def percentile(self, q: float) -> float:
        return exact_percentile(self.latencies, q)
