"""Shared ``lax.scan`` compile-cost machinery for the sweep engines.

Both vectorized engines (:mod:`repro.fabric.sweep` — the single-receiver
datapath grid — and :mod:`repro.fabric.vector` — the whole-fabric grid)
are one ``jax.vmap`` + ``lax.scan`` program whose cold-start cost is
dominated by XLA compiling the scan body.  Two levers live here:

* **unroll choice.**  ``lax.scan(..., unroll=u)`` duplicates the body
  ``u`` times: compile time grows roughly linearly with ``u`` while the
  per-iteration while-loop overhead shrinks.  Measured on the container's
  CPU backend (jax 0.4.37) the crossover never arrives for these step
  bodies — a 10k-tick / 36-point datapath sweep compiles in ~1.5 s at
  ``unroll=1`` vs ~7.4 s at the old hard-coded ``unroll=8`` *and* runs
  warm ~1.6x faster (0.30 s vs 0.50 s), because the body is already a few
  hundred fused element-wise ops and the loop overhead is negligible
  next to their dispatch.  ``pick_unroll`` encodes that as a cached
  choice: an explicit override (argument or ``REPRO_SCAN_UNROLL``) wins,
  then a persisted autotune result (``experiments/bench/scan_unroll.json``,
  written by ``benchmarks/bench_fabric.py`` which times {1, 4, 8} on the
  real program), then the measured default of 1.

* **donated carries.**  The jitted programs take their initial scan
  carry as an argument donated via ``donate_argnums``, so XLA reuses the
  (grid x ring-horizon) state buffers instead of keeping both the
  zero-init copy and the running carry alive.

* **persistent compilation cache.**  The step bodies are deterministic
  functions of the grid *structure*, so their XLA executables are
  reusable across processes.  :func:`configure_persistent_cache` points
  jax's disk cache at ``JAX_COMPILATION_CACHE_DIR`` (no-op when the env
  var is unset) and lowers the min-compile-time threshold to 0 s so the
  quick-mode CI programs are cached too; CI restores the directory via
  ``actions/cache`` so the fused-kernel compile cost is paid once per
  toolchain bump, not per push.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Optional

UNROLL_CANDIDATES = (1, 4, 8)

# autotune results persisted by benchmarks/bench_fabric.py
_CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "bench", "scan_unroll.json")


@functools.lru_cache(maxsize=None)
def _cached_autotune() -> Optional[int]:
    try:
        with open(_CACHE_PATH) as f:
            u = int(json.load(f)["unroll"])
        return u if u in UNROLL_CANDIDATES else None
    except (OSError, ValueError, KeyError, TypeError):
        return None


def pick_unroll(override: Optional[int] = None) -> int:
    """Scan unroll factor: override > ``REPRO_SCAN_UNROLL`` env > cached
    autotune (bench-measured winner over {1, 4, 8}) > measured default 1."""
    if override is not None:
        return max(1, int(override))
    env = os.environ.get("REPRO_SCAN_UNROLL")
    if env:
        return max(1, int(env))
    cached = _cached_autotune()
    return cached if cached is not None else 1


def configure_persistent_cache() -> Optional[str]:
    """Enable jax's on-disk executable cache when the environment asks
    for one (``JAX_COMPILATION_CACHE_DIR``).  Returns the cache dir, or
    None when the env var is unset.  Safe to call before or after other
    jax work, and idempotent."""
    cache_dir = os.environ.get("JAX_COMPILATION_CACHE_DIR")
    if not cache_dir:
        return None
    cache_dir = os.path.expanduser(cache_dir)
    import jax

    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    return cache_dir


def save_autotune(unroll: int) -> str:
    """Persist a bench-measured unroll winner for future processes."""
    path = os.path.abspath(_CACHE_PATH)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"unroll": int(unroll)}, f)
    _cached_autotune.cache_clear()
    return path
