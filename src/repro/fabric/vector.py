"""Vectorized fabric engine: whole-grid multi-host simulation.

``run_fabric`` advances one scenario with Python dicts of ``SenderHost`` /
``Switch`` / ``ReceiverHost`` objects — minutes per grid point for the
fleet experiments the paper cares about (incast completion, victim-flow
goodput, PFC pause fan-out, Lamda §5-6).  This module packs the *entire*
tick body into stacked arrays and advances all grid points at once:

* per-flow DCQCN/offer state as ``[F]`` arrays (``[G, F]`` across the
  grid) — rate machines, injected/delivered byte counters, CNP pacing,
  plus a circular delay ring for CNP propagation (``cnp_delay_us``);
* per-port queue state as ``[P, F]`` byte/mark matrices covering the NIC
  egress queues and every switch output port on some flow's path — a
  flow's bytes belong to exactly one traffic class, so the classed
  ``[Q, P]`` per-TC occupancy / PFC assert / pause state is derived with
  one ``[Q, F] @ [F, P]`` one-hot matmul and the drain's strict-priority
  budget grants are priority-unrolled over ``Q`` (the PR 3 receiver-block
  pattern); legacy per-link points collapse every flow onto TC 0;
* per-receiver datapath state as ``[R]`` arrays — including the
  :class:`~repro.core.datapath.HostDatapath` QoS admission classes as a
  stacked ``[G, Q, R]`` block (``Q = 3`` service classes, priority-order
  space/drain grants, §5 low-QoS DRAM spill) — plus ``[R, H]`` circular
  release rings (the ``sweep.py`` ring trick);
* routing as per-tick state: on the static fast path (every point
  ``static_ecmp`` with no failure schedule) :meth:`Topology.route` is
  precomputed into flow->port incidence one-hots exactly as before; in
  dynamic-routing land the port set covers every *candidate* uplink/
  downlink (``[S, F, P]`` one-hots), the spine choice is a ``[G, F]``
  scan carry updated each tick (argmin/hash/softmax-free weight
  arithmetic identical to :mod:`repro.fabric.routing`), link failures
  are per-point ``[G, P]`` tick windows that zero budgets and drop
  in-flight bytes, and spray's reorder settling is one more slot-major
  ring.  Either way each forwarding stage stays a gather, a batch
  enqueue and a scatter — no data-dependent control flow.

One ``jax.vmap`` over the scenario grid x one ``jax.lax.scan`` over ticks
= one XLA program; a batched-numpy backend runs the *same* step function
(float64) as the verification reference, mirroring the single-source-of-
truth design of :mod:`repro.fabric.sweep`.

Semantics are exactly the batch-fluid tick of :func:`repro.fabric.run_fabric`
(see its module docstring): four tier-ordered forwarding stages with
cut-through within the tick, proportional buffer-space allocation and a
single pre-batch ECN-knee decision per port per stage, receiver CNPs to
the heaviest recently-arriving flow (lowest flow id on ties), per-flow
DCQCN CNP pacing of switch ECN marks, and per-priority PFC pause
propagation targeted at the ``(ingress link, tc)`` pairs of flows queued
in over-watermark classes.  A
1-sender/1-receiver grid therefore reproduces ``run_sim`` goodput, and
small incast grids match the scalar driver per flow.

Grid points must share the topology *structure* (same node/link graph,
same flows, same receiver set, same tick count); everything numeric may
vary per point: receiver ``SimConfig`` knobs, ``SwitchConfig`` scalars
(including the strict/WRR scheduler and per-TC host PFC), link rates,
per-flow offered load / burst size / start time, and — the PR 5 lift —
routing mode and link-failure schedules.  The former "grid points must
share routes" restriction only survives on the static fast path, where
frozen routes *are* the structure.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.datapath import N_QOS
from ..core.dcqcn import DcqcnConfig
from .cc import CcConfig
from .hosts import hold_us_baseline, hold_us_jet
from .faults import link_salt, loss_threshold
from .messages import (HIST_BUCKETS, HIST_MIN_US, MSG_COUNT_EPS, hist_ratio,
                       percentile_from_counts)
from .topology import NEVER_TICK
from ._scan import pick_unroll
from . import fused
from .fused import AdaptiveConfig

_STAGES = 4          # NIC egress, leaf uplink, spine, leaf downlink
# sparse-incidence stage slots (3-level pod fabrics): NIC egress,
# leaf uplink, spine uplink (-> super-spine), super-spine, spine
# downlink, leaf downlink.  A 2-tier flow simply leaves slots 2-3 empty.
_STAGES_SP = 6

# pvals entries that stay integer (tick indices, codes, ring offsets)
_INT_KEYS = frozenset(["d_base", "d_strag", "cnp_dly", "fail_at",
                       "fail_until", "rmode", "flet", "settle", "sched",
                       "cc_algo", "f_salt", "f_thr", "f_cthr",
                       "flap_start", "flap_period", "flap_down",
                       "crash_at", "crash_until", "rto_ticks",
                       "nack_ticks", "rto_cap"])

# CcConfig knobs stacked per flow when any point runs a non-DCQCN
# controller (masked `where` lanes select the algorithm per flow)
_CC_SCALARS = [
    ("cc_minr", lambda c: c.min_rate_gbps),
    ("base_rtt", lambda c: c.base_rtt_us),
    ("cc_upd", lambda c: c.update_us),
    ("t_low", lambda c: c.t_low_us),
    ("t_high", lambda c: c.t_high_us),
    ("tl_beta", lambda c: c.timely_beta),
    ("tl_add", lambda c: c.timely_add_gbps),
    ("tl_a", lambda c: c.timely_ewma),
    ("hp_eta", lambda c: c.hpcc_eta),
    ("hp_ai", lambda c: c.hpcc_ai_gbps),
]
_CC_DEFAULT = CcConfig()


# --------------------------------------------------------------------------- #
# Packing: scenarios -> static structure + stacked per-point parameters
# --------------------------------------------------------------------------- #
_RECV_SCALARS = [
    ("jet", lambda c: 1.0 if c.mode == "jet" else 0.0),
    ("pfc_en", lambda c: 1.0 if c.pfc_enabled else 0.0),
    ("wm_cnp", lambda c: 1.0 if c.rnic_ecn_cnp else 0.0),
    ("line1", lambda c: c.line_rate_gbps),
    ("pcie", lambda c: c.pcie_gbps),
    ("membw", lambda c: c.membw_total_gbps),
    ("cpu_bw", lambda c: c.cpu_membw_gbps),
    ("qp_bytes", lambda c: c.num_qps * c.msg_bytes),
    ("ddio", lambda c: c.ddio_bytes),
    ("knee", lambda c: c.miss_knee),
    ("rnic_buf", lambda c: c.rnic_buffer_bytes),
    ("xoff", lambda c: c.pfc_xoff),
    ("xon", lambda c: c.pfc_xon),
    ("ecn_th", lambda c: c.ecn_threshold),
    ("cnp_iv", lambda c: c.cnp_interval_us),
    ("pool", lambda c: c.jet_pool_bytes),
    ("sfrac", lambda c: c.straggler_frac),
    ("safe", lambda c: c.cache_safe),
    ("danger", lambda c: c.cache_danger),
    ("mem_esc", lambda c: c.mem_esc_bytes),
]

_DCQCN_SCALARS = [
    ("dline", lambda d: d.line_rate_gbps),
    ("minr", lambda d: d.min_rate_gbps),
    ("g", lambda d: d.g),
    ("a_tmr", lambda d: d.alpha_timer_us),
    ("r_tmr", lambda d: d.rate_timer_us),
    ("bctr", lambda d: d.byte_counter_mb * (1 << 20)),
    ("ai", lambda d: d.ai_rate_gbps),
    ("hai", lambda d: d.hai_rate_gbps),
    ("fth", lambda d: float(d.f_threshold)),
]

_SWITCH_SCALARS = [
    ("buf", lambda s: float(s.port_buffer_bytes)),
]

# per-TC switch knobs: resolved to [N_QOS]-vectors per grid point (the
# scalar fields with optional tc_* overrides, see SwitchConfig)
_SWITCH_TC = [
    ("kmin", lambda s, tc: s.kmin_frac(tc)),
    ("sw_xoff", lambda s, tc: s.xoff_frac(tc)),
    ("sw_xon", lambda s, tc: s.xon_frac(tc)),
]


@dataclasses.dataclass
class FabricSweepParams:
    """Static fabric structure + stacked per-point parameters.

    Shapes: F flows, P ports, R receivers, G grid points, H ring horizon.
    """
    # -- static structure (shared by every grid point) ----------------------
    port_keys: List[Tuple[str, str]]     # port id -> out-link key
    recv_hosts: List[str]
    flow_tags: List[str]
    stage_mask: np.ndarray               # [S, P] bool: ports of each stage
    occ: List[np.ndarray]                # S x [P, F]: flow's port per stage
    dest: List[np.ndarray]               # 3 x [P, F]: routing after stage k
    recv_onehot: np.ndarray              # [R, F]
    recv_of: np.ndarray                  # [F] int32
    qos_of: np.ndarray                   # [F] int32: flow's admission class
    prev_onehot: np.ndarray              # [P, F, P]: ingress port of (p, f)
    owner_recv: np.ndarray               # [P] int32: stage-3 port's receiver
    # -- per-point parameters ----------------------------------------------
    pvals: Dict[str, np.ndarray]         # [G], [G, F], [G, R] or [G, P]
    n_points: int
    n_flows: int
    n_ports: int
    n_recv: int
    ticks: int
    dt_us: float
    ring_len: int
    cnp_ring: int                        # CNP propagation ring length
    structure_key: str
    # -- dynamic-routing structure (None on the static fast path) -----------
    # With any point in dynamic-routing land (mode != static_ecmp or a
    # failure schedule), ports cover every *candidate* uplink/downlink
    # and the spine choice becomes per-tick carry state [G, F].
    upP: Optional[np.ndarray] = None     # [S, F, P] candidate uplink 1-hot
    dnP: Optional[np.ndarray] = None     # [S, F, P] candidate downlink
    candS: Optional[np.ndarray] = None   # [S, F] bool candidacy
    crossF: Optional[np.ndarray] = None  # [F] bool: cross-leaf flow
    T1: Optional[np.ndarray] = None      # [P, F, P] uplink->downlink map
    init_spine: Optional[np.ndarray] = None   # [F] int32 (fid % S)
    dyn_route: bool = False
    any_wrr: bool = False                # any point schedules WRR drain
    host_tc: bool = False                # any point runs per-TC host PFC
    settle_ring: int = 1                 # Hs (spray reorder settling)
    n_spines: int = 0
    any_cc: bool = False                 # any point runs a non-DCQCN CC
    any_msg: bool = False                # any point runs the message layer
    msg_ring: int = 1                    # Lm (message start-time ring)
    any_flt: bool = False                # any point attaches a FaultConfig
    any_flap: bool = False               # any point schedules link flaps
    # -- sparse-incidence structure (3-level pod fabrics) --------------------
    # Queue state becomes [.., 2, S, F] slot entries (S = _STAGES_SP):
    # slot (s, f) holds flow f's bytes queued at ``port_of[s, f]``
    # (n_ports = "slot unused").  ``prv_port`` is each slot's ingress
    # port (PFC pause target), ``nxt_slot`` the next occupied slot a
    # stage's drain output enqueues into (_STAGES_SP = "delivered").
    sparse: bool = False
    port_of: Optional[np.ndarray] = None     # [6, F] int32
    prv_port: Optional[np.ndarray] = None    # [6, F] int32
    nxt_slot: Optional[np.ndarray] = None    # [6, F] int32
    pack_fail: bool = False              # sparse grid with failure windows
    # candidate-ingress pause structure under failure schedules: the
    # scalar driver treats shallow (intra-pod, multi-candidate) flows as
    # rerouteable, so their last-hop queue pauses *every* candidate
    # downlink and every candidate hop joins the pausable denominator
    # (OutputPort.static_ingress semantics).  [2, E] (flow, target port)
    # extra pause pairs, plus the candidate hop ports for n_pausable.
    pause_extra: Optional[np.ndarray] = None
    pausable_extra: Optional[np.ndarray] = None

    def envelope(self) -> dict:
        """Chunk-boundary envelope of this packing: the capability
        flags and ring horizons a *sub-grid* packing must be floored at
        to trace the identical program (pass to
        :meth:`from_scenarios` via ``envelope=``).  Pack the full grid
        once, then pack each chunk under the full grid's envelope — the
        chunks then share one ``structure_key`` (one cached compilation
        per canonical chunk shape) and reproduce the monolithic run
        bit-for-bit."""
        return {"ring_len": self.ring_len, "cnp_ring": self.cnp_ring,
                "settle_ring": self.settle_ring,
                "msg_ring": self.msg_ring,
                "dyn": self.dyn_route or self.pack_fail,
                "wrr": self.any_wrr, "host_tc": self.host_tc,
                "cc": self.any_cc, "msg": self.any_msg,
                "flt": self.any_flt, "flap": self.any_flap}

    @classmethod
    def from_scenarios(cls, scens: Sequence, sparse: bool = False,
                       envelope: Optional[dict] = None
                       ) -> "FabricSweepParams":
        """Pack a grid of :class:`~repro.fabric.scenarios.Scenario`-likes
        (anything with ``.topology``, ``.flows``, ``.fabric``).

        ``sparse=True`` packs the segmented-incidence structure instead
        of the dense port x flow one-hots — required for 3-level
        (super-spine) topologies, and the scalable choice for any large
        static fabric.  Sparse packing supports static ECMP plus
        failure/flap windows and the CC zoo; dynamic routing modes, the
        message layer and FaultConfig injection stay dense-only.

        ``envelope`` (see :meth:`envelope`) floors the capability flags
        and ring horizons at the values of a *larger* grid this packing
        is a chunk of.  The flags (``dyn``/``wrr``/``cc``/``msg``/
        ``flt``/…) and ring lengths (``ring_len``/``cnp_ring``/…) are
        normally "any/max over the grid", so slicing a heterogeneous
        grid would give each chunk a different compiled program *and*
        different semantics than the monolithic run.  Passing the full
        grid's envelope forces every chunk onto the monolithic grid's
        program structure, which is what makes chunked execution
        bit-identical to the one-program run (the sweep-farm contract,
        held by ``tests/test_farm.py``)."""
        if not scens:
            raise ValueError("empty fabric sweep grid")
        s0 = scens[0]
        topo0, flows0 = s0.topology, s0.flows
        dt = s0.fabric.dt_us
        ticks = int(s0.fabric.sim_time_s * 1e6 / dt)
        F = len(flows0)
        # engine-level capability flags: shared *structure*, selected per
        # point by plain parameters (rmode / sched / hpfc)
        dyn = any(s.fabric.routing.is_dynamic or bool(s.topology.link_down)
                  or bool(s.topology.link_flaps) for s in scens)
        any_wrr = any(s.fabric.switch.scheduler == "wrr" for s in scens)
        any_flt = any(s.fabric.faults is not None for s in scens)
        any_flap = any(bool(s.topology.link_flaps) for s in scens)
        recv_hosts = sorted({f.dst for f in flows0})
        host_tc = any(s.fabric.switch.per_tc
                      and s.fabric.receiver_cfg(h).host_pfc_per_tc
                      for s in scens for h in recv_hosts)

        # message layer / CC zoo: per-flow Flow overrides falling back to
        # the FabricConfig defaults, resolved exactly as run_fabric does
        def msg_of(s):
            return [f.msg if f.msg is not None else s.fabric.msg
                    for f in s.flows]

        def cc_of(s):
            return [f.cc if f.cc is not None else s.fabric.cc
                    for f in s.flows]

        any_msg = any(m is not None for s in scens for m in msg_of(s))
        any_cc = any(c is not None and c.algo != "dcqcn"
                     for s in scens for c in cc_of(s))
        # chunk-boundary envelope: floor the capability flags at the
        # enclosing grid's, so every chunk traces the monolithic
        # program (a chunk with no msg/cc/fault/dynamic points must not
        # silently compile the cheaper structure)
        env = dict(envelope or {})
        dyn = dyn or bool(env.get("dyn"))
        any_wrr = any_wrr or bool(env.get("wrr"))
        any_flt = any_flt or bool(env.get("flt"))
        any_flap = any_flap or bool(env.get("flap"))
        host_tc = host_tc or bool(env.get("host_tc"))
        any_msg = any_msg or bool(env.get("msg"))
        any_cc = any_cc or bool(env.get("cc"))
        pods = any(s.topology.super_spines for s in scens)
        pack_fail = False
        if sparse:
            # sparse incidence freezes routes as structure: static ECMP
            # only, with failure/flap windows and the CC zoo as
            # per-point parameters
            if any(s.fabric.routing.is_dynamic for s in scens):
                raise ValueError(
                    "sparse incidence supports static_ecmp routing only; "
                    "dynamic routing modes need the dense engine "
                    "(2-tier topologies)")
            if any_msg:
                raise ValueError("sparse incidence does not support the "
                                 "message layer; use the dense engine")
            if any_flt:
                raise ValueError("sparse incidence does not support "
                                 "FaultConfig injection; use the dense "
                                 "engine")
            pack_fail = dyn         # only failure/flap schedules remain
            dyn = False
        elif pods:
            raise ValueError(
                "3-level (super-spine) topologies need the sparse-"
                "incidence engine: run_fabric_sweep(..., "
                "incidence='auto' or 'sparse')")
        if any_msg:
            for s in scens:
                for m in msg_of(s):
                    if m is not None and m.window is None:
                        raise ValueError(
                            "MessageConfig.window=None (unbounded) is "
                            "scalar-only; the vector engines carry "
                            "message starts in a fixed ring — set a "
                            "finite window or use run_fabric")
        for s in scens:
            s.topology.validate()
            if s.fabric.dt_us != dt or \
                    int(s.fabric.sim_time_s * 1e6 / s.fabric.dt_us) != ticks:
                raise ValueError("grid points must share dt and sim_time")
            if len(s.flows) != F or any(
                    (a.src, a.dst, a.tag, a.qos)
                    != (b.src, b.dst, b.tag, b.qos)
                    for a, b in zip(s.flows, flows0)):
                raise ValueError("grid points must share the flow set "
                                 "(src/dst/tag/qos); offered/burst/start "
                                 "may vary")
        if not dyn:
            # static fast path: routes are frozen structure and must agree
            routes = [topo0.route(f.src, f.dst, fid)
                      for fid, f in enumerate(flows0)]
            for s in scens:
                if any(s.topology.route(f.src, f.dst, fid) != routes[fid]
                       for fid, f in enumerate(s.flows)):
                    raise ValueError("grid points must share routes (same "
                                     "topology structure)")
        else:
            # dynamic-routing land: routes are per-tick state, so only the
            # node/link *structure* must agree; routing mode and failure
            # schedules are per-point parameters
            for s in scens:
                tt = s.topology
                if (sorted(tt.links) != sorted(topo0.links)
                        or tt.host_leaf != topo0.host_leaf
                        or tt.spines != topo0.spines
                        or tt.leaves != topo0.leaves):
                    raise ValueError(
                        "grid points must share topology structure "
                        "(nodes and links); link rates, failure "
                        "schedules and routing mode may vary")

        # ---- ports on some flow's path, tagged with their stage ---------- #
        port_id: Dict[Tuple[str, str], int] = {}
        port_stage: List[int] = []

        def add(key, stage):
            pid = port_id.setdefault(key, len(port_id))
            if pid == len(port_stage):
                port_stage.append(stage)
            elif port_stage[pid] != stage:
                raise ValueError(f"port {key} used in two stages")
            return pid

        Sn = len(topo0.spines)
        cols = np.arange(F)
        upP = dnP = candS = crossF = T1 = init_spine = None
        port_of = prv_port = nxt_slot = None
        pause_extra = pausable_extra = None
        if sparse:
            # six tier-ordered stage slots; each flow occupies the slots
            # of its frozen route (2/4/6 hops) and every port belongs to
            # exactly one slot, so per-(port, TC) totals are segment
            # sums over the S*F (slot, flow) entries instead of [P, F]
            # one-hot products — cost grows with flows x hops, not
            # flows x ports
            slot_of = {3: (0, 5), 5: (0, 1, 4, 5), 7: tuple(range(6))}
            stage_ports = np.full((_STAGES_SP, F), -1, np.int64)
            for fid, nodes in enumerate(routes):
                slots = slot_of.get(len(nodes))
                if slots is None:
                    raise ValueError(
                        f"unsupported route length {len(nodes)}")
                for sl_i, hop in zip(slots, zip(nodes, nodes[1:])):
                    stage_ports[sl_i, fid] = add(hop, sl_i)
            # scalar twin under failure schedules: run_fabric treats a
            # shallow (intra-pod, multi-candidate) flow as rerouteable,
            # so its last-hop queue pauses the whole candidate downlink
            # set and every candidate hop joins the pausable ports
            # (OutputPort.static_ingress semantics); deep super-spine
            # routes stay frozen exact chains in both drivers
            ex_f, ex_p, cand_ports = [], [], []
            if pack_fail:
                for fid, f in enumerate(flows0):
                    if len(routes[fid]) != 5:
                        continue
                    paths = topo0.candidate_paths(f.src, f.dst)
                    if len(paths) <= 1:
                        continue
                    frozen_dn = stage_ports[4, fid]
                    for pth in paths:
                        pu = add((pth[0], pth[1]), 1)
                        pd = add((pth[1], pth[2]), 4)
                        cand_ports += [pu, pd]
                        if pd != frozen_dn:
                            ex_f.append(fid)
                            ex_p.append(pd)
            if ex_f:
                pause_extra = np.array([ex_f, ex_p], np.int32)
            if cand_ports:
                pausable_extra = np.array(sorted(set(cand_ports)),
                                          np.int32)
            P = len(port_id)
            port_keys = list(port_id)
            port_of = np.where(stage_ports >= 0, stage_ports,
                               P).astype(np.int32)
            prv_port = np.full((_STAGES_SP, F), P, np.int32)
            nxt_slot = np.full((_STAGES_SP, F), _STAGES_SP, np.int32)
            for fid in range(F):
                used = np.flatnonzero(stage_ports[:, fid] >= 0)
                for a, b in zip(used, used[1:]):
                    nxt_slot[a, fid] = b
                    prv_port[b, fid] = stage_ports[a, fid]
            occ, dest = [], []
            prev_onehot = np.zeros((0, F, 0))
        elif not dyn:
            stage_ports = np.full((_STAGES, F), -1, np.int32)
            prev_port = np.full((_STAGES, F), -1, np.int32)
            for fid, nodes in enumerate(routes):
                if len(nodes) == 3:                   # intra-leaf
                    src, leaf, dst = nodes
                    p0 = add((src, leaf), 0)
                    p3 = add((leaf, dst), 3)
                    stage_ports[0, fid], stage_ports[3, fid] = p0, p3
                    prev_port[3, fid] = p0
                else:                                 # via one spine
                    src, sl, spine, dl, dst = nodes
                    p0 = add((src, sl), 0)
                    p1 = add((sl, spine), 1)
                    p2 = add((spine, dl), 2)
                    p3 = add((dl, dst), 3)
                    stage_ports[:, fid] = (p0, p1, p2, p3)
                    prev_port[1, fid], prev_port[2, fid], \
                        prev_port[3, fid] = p0, p1, p2
            P = len(port_id)
            port_keys = list(port_id)

            def onehot(idx):                          # [P, F] from [F] ids
                oh = np.zeros((P, F))
                valid = idx >= 0
                oh[idx[valid], cols[valid]] = 1.0
                return oh

            occ = [onehot(stage_ports[k]) for k in range(_STAGES)]
            # destination port after stages 0..2 (stage 3 -> receivers)
            d0 = np.where(stage_ports[1] >= 0, stage_ports[1],
                          stage_ports[3])
            dest = [onehot(d0), onehot(stage_ports[2]),
                    onehot(stage_ports[3])]
            prev_onehot = np.zeros((P, F, P))
            for k in range(1, _STAGES):
                for fid in range(F):
                    p, pr = stage_ports[k, fid], prev_port[k, fid]
                    if p >= 0 and pr >= 0:
                        prev_onehot[p, fid, pr] = 1.0
        else:
            # every candidate uplink/downlink joins the port set; the
            # per-tick routing weights decide where bytes actually go
            hl = topo0.host_leaf
            stage0 = np.full(F, -1, np.int64)
            stage3 = np.full(F, -1, np.int64)
            up_ids = np.full((Sn, F), -1, np.int64)
            dn_ids = np.full((Sn, F), -1, np.int64)
            for fid, f in enumerate(flows0):
                sl, dl = hl[f.src], hl[f.dst]
                if f.src == f.dst:
                    raise ValueError("flow endpoints must differ")
                stage0[fid] = add((f.src, sl), 0)
                if sl == dl:
                    stage3[fid] = add((sl, f.dst), 3)
                else:
                    if not Sn:
                        raise ValueError(f"no spine connects {sl}->{dl}")
                    for si, sp in enumerate(topo0.spines):
                        up_ids[si, fid] = add((sl, sp), 1)
                        dn_ids[si, fid] = add((sp, dl), 2)
                    stage3[fid] = add((dl, f.dst), 3)
            P = len(port_id)
            port_keys = list(port_id)

            def onehot(idx):
                oh = np.zeros((P, F))
                valid = idx >= 0
                oh[idx[valid], cols[valid]] = 1.0
                return oh

            candS = up_ids >= 0
            crossF = candS.any(0) if Sn else np.zeros(F, bool)
            occ1 = np.zeros((P, F))
            occ2 = np.zeros((P, F))
            upP = np.zeros((Sn, F, P))
            dnP = np.zeros((Sn, F, P))
            T1 = np.zeros((P, F, P))
            prev_onehot = np.zeros((P, F, P))
            for fid in range(F):
                p0, p3 = stage0[fid], stage3[fid]
                if crossF[fid]:
                    for si in range(Sn):
                        pu, pd = up_ids[si, fid], dn_ids[si, fid]
                        occ1[pu, fid] = occ2[pd, fid] = 1.0
                        upP[si, fid, pu] = dnP[si, fid, pd] = 1.0
                        T1[pu, fid, pd] = 1.0
                        prev_onehot[pu, fid, p0] = 1.0
                        prev_onehot[pd, fid, pu] = 1.0
                        # a rerouted/sprayed flow's bytes at the host
                        # port have mixed provenance: pause targeting
                        # covers the whole candidate set (same contract
                        # as OutputPort.static_ingress in the scalar
                        # driver)
                        prev_onehot[p3, fid, pd] = 1.0
                else:
                    prev_onehot[p3, fid, p0] = 1.0
            occ = [onehot(stage0), occ1, occ2, onehot(stage3)]
            # dest[0] covers only intra-leaf flows (cross-leaf stage-0
            # output is routed by the per-tick weights); dest[1] is
            # replaced by the T1 map
            dest = [onehot(np.where(crossF, -1, stage3)),
                    np.zeros((P, F)), onehot(stage3)]
            init_spine = np.where(crossF, cols % max(Sn, 1), 0) \
                .astype(np.int32)

        R = len(recv_hosts)
        ridx = {h: i for i, h in enumerate(recv_hosts)}
        recv_of = np.array([ridx[f.dst] for f in flows0], np.int32)
        qos_of = np.array([int(f.qos) for f in flows0], np.int32)
        n_stages = _STAGES_SP if sparse else _STAGES
        stage_mask = np.zeros((n_stages, P), bool)
        for p, st in enumerate(port_stage):
            stage_mask[st, p] = True
        recv_onehot = np.zeros((R, F))
        recv_onehot[recv_of, cols] = 1.0
        owner_recv = np.full(P, -1, np.int32)
        for (a, b), pid in port_id.items():
            if port_stage[pid] == n_stages - 1:
                owner_recv[pid] = ridx[b]

        # ---- stacked per-point parameters -------------------------------- #
        G = len(scens)
        pv: Dict[str, List] = {k: [] for k in
                               ["gbps", "ecn_en", "can_assert",
                                "line", "cap", "burst", "start", "cnp_iv_f",
                                "d_base", "d_strag", "cnp_dly", "clsF",
                                "on_us", "off_us", "fail_at", "fail_until",
                                "rmode", "flet", "hystb", "settle",
                                "sched", "quanta", "hpfc",
                                "m_bytes", "m_win", "m_extra", "cc_algo",
                                "f_salt", "f_thr", "f_cthr", "f_mtu",
                                "flap_start", "flap_period", "flap_down",
                                "crash_at", "crash_until", "rec_en",
                                "rec_sel", "rto_ticks", "nack_ticks",
                                "rto_cap", "rto_mult"]}
        for name, _ in _RECV_SCALARS + _DCQCN_SCALARS + _SWITCH_SCALARS \
                + _SWITCH_TC + _CC_SCALARS:
            pv[name] = []
        # switch traffic class of each flow as a [Q, F] one-hot, built
        # once from flows0: the structure check above rejects grids
        # whose points disagree on Flow.qos.  Legacy per-link points
        # collapse every flow onto TC 0 (one queue, one watermark pair
        # — exactly the pre-per-TC pause semantics)
        cls_true = np.zeros((N_QOS, F))
        cls_true[[int(f.qos) for f in flows0], np.arange(F)] = 1.0
        cls_legacy = np.zeros((N_QOS, F))
        cls_legacy[0, :] = 1.0
        for s in scens:
            topo, sw = s.topology, s.fabric.switch
            for name, fn in _SWITCH_SCALARS:
                pv[name].append(fn(sw))
            for name, fn in _SWITCH_TC:
                pv[name].append([fn(sw, tc) for tc in range(N_QOS)])
            pv["clsF"].append(cls_true if sw.per_tc else cls_legacy)
            pv["gbps"].append([topo.links[k].gbps for k in port_keys])
            is_switch = np.array(port_stage) > 0
            pv["ecn_en"].append(is_switch * float(sw.ecn_enabled))
            pv["can_assert"].append(is_switch * float(sw.pfc_enabled))
            rcfgs = {h: s.fabric.receiver_cfg(h) for h in recv_hosts}
            for h, c in rcfgs.items():
                if c.cpu_membw_schedule is not None:
                    raise ValueError("cpu_membw_schedule is not sweepable; "
                                     "use run_fabric for scheduled "
                                     "contention")
                if c.host_pfc_per_tc and not sw.per_tc:
                    # same contract as run_fabric: the per-class gate
                    # needs classes to exist on the wire
                    raise ValueError("host_pfc_per_tc requires "
                                     "SwitchConfig.per_tc")
            for name, fn in _RECV_SCALARS:
                pv[name].append([fn(rcfgs[h]) for h in recv_hosts])
            d_b, d_s = [], []
            for h in recv_hosts:
                c = rcfgs[h]
                hold = hold_us_jet(c) if c.mode == "jet" \
                    else hold_us_baseline(c)
                d_b.append(max(1, int(hold / dt)))
                d_s.append(max(1, int(hold * c.straggler_mult / dt)))
            pv["d_base"].append(d_b)
            pv["d_strag"].append(d_s)
            # per-flow NP->RP propagation delay (Flow override, falling
            # back to the FabricConfig scalar)
            pv["cnp_dly"].append([
                max(0, int(round(
                    (f.cnp_delay_us if f.cnp_delay_us is not None
                     else s.fabric.cnp_delay_us) / dt)))
                for f in s.flows])
            rc = s.fabric.routing
            if dyn or pack_fail:
                ft = s.topology.failure_ticks(dt)
                nv = (NEVER_TICK, NEVER_TICK)
                pv["fail_at"].append([ft.get(k, nv)[0] for k in port_keys])
                pv["fail_until"].append([ft.get(k, nv)[1]
                                         for k in port_keys])
            if dyn:
                pv["rmode"].append(rc.mode_code())
                pv["flet"].append(max(1, int(round(rc.flowlet_gap_us
                                                   / dt))))
                pv["hystb"].append(rc.hysteresis_frac
                                   * sw.port_buffer_bytes)
                stl = int(round(rc.spray_settle_us / dt)) \
                    if rc.mode == "spray" else 0
                pv["settle"].append([stl if crossF[fid] else 0
                                     for fid in range(F)])
            if any_wrr:
                pv["sched"].append(1 if sw.scheduler == "wrr" else 0)
                pv["quanta"].append(list(sw.quanta()))
            if host_tc:
                pv["hpfc"].append([
                    1.0 if (sw.per_tc and rcfgs[h].host_pfc_per_tc)
                    else 0.0 for h in recv_hosts])
            line = [s.topology.access_gbps(f.src) for f in s.flows]
            pv["line"].append(line)
            msgs, ccs = msg_of(s), cc_of(s)
            # the per-op issue gap is one more rate ceiling (the Mops
            # plateau): folded into the offered cap — min() is order-free,
            # so this matches SenderHost.offer's separate clamp exactly
            pv["cap"].append([
                min(np.inf if f.offered_gbps is None else f.offered_gbps,
                    np.inf if m is None else m.op_rate_gbps)
                for f, m in zip(s.flows, msgs)])
            if any_msg:
                # m_bytes=inf disables the layer per flow: zero messages
                # ever start or complete and the window room is infinite
                pv["m_bytes"].append([np.inf if m is None
                                      else float(m.msg_bytes)
                                      for m in msgs])
                pv["m_win"].append([1.0 if m is None else float(m.window)
                                    for m in msgs])
                pv["m_extra"].append([0.0 if m is None else m.extra_us
                                      for m in msgs])
            if any_cc:
                cl = [c if c is not None else _CC_DEFAULT for c in ccs]
                pv["cc_algo"].append([c.code() for c in cl])
                for name, fn in _CC_SCALARS:
                    pv[name].append([fn(c) for c in cl])
            pv["burst"].append([np.inf if f.burst_bytes is None
                                else f.burst_bytes for f in s.flows])
            pv["start"].append([f.start_us for f in s.flows])
            pv["on_us"].append([np.inf if f.on_off_us is None
                                else f.on_off_us[0] for f in s.flows])
            pv["off_us"].append([0.0 if f.on_off_us is None
                                 else f.on_off_us[1] for f in s.flows])
            pv["cnp_iv_f"].append([rcfgs[f.dst].cnp_interval_us
                                   for f in s.flows])
            # a CcConfig(algo="dcqcn") carrying a DcqcnConfig override
            # replaces the per-line-rate defaults (make_controller)
            dcq = [c.dcqcn if (c is not None and c.algo == "dcqcn"
                               and c.dcqcn is not None)
                   else DcqcnConfig(line_rate_gbps=lr)
                   for c, lr in zip(ccs, line)]
            for name, fn in _DCQCN_SCALARS:
                pv[name].append([fn(d) for d in dcq])
            if any_flap:
                fl = topo.flap_ticks(dt)
                nf = (NEVER_TICK, 2, 1)
                pv["flap_start"].append([fl.get(k, nf)[0]
                                         for k in port_keys])
                pv["flap_period"].append([fl.get(k, nf)[1]
                                          for k in port_keys])
                pv["flap_down"].append([fl.get(k, nf)[2]
                                        for k in port_keys])
            if any_flt:
                # fault layer: per-port hash salts/thresholds, crash
                # windows per receiver, per-flow recovery knobs — a
                # faults-None point packs never-firing values and
                # mtu=inf, so its dropped_pkts stays exactly 0
                ff = s.fabric.faults
                if ff is None:
                    pv["f_salt"].append([0] * P)
                    pv["f_thr"].append([0] * P)
                    pv["f_cthr"].append([0] * P)
                    pv["crash_at"].append([NEVER_TICK] * R)
                    pv["crash_until"].append([NEVER_TICK] * R)
                    pv["f_mtu"].append(np.inf)
                else:
                    pv["f_salt"].append([link_salt(a, b, ff.seed)
                                         for a, b in port_keys])
                    pv["f_thr"].append([loss_threshold(ff.rate_for(a, b))
                                        for a, b in port_keys])
                    # corruption (CRC fail) only on receiver access links
                    pv["f_cthr"].append([
                        loss_threshold(ff.corrupt_rate) if b in ridx
                        else 0 for a, b in port_keys])
                    ca, cu = [NEVER_TICK] * R, [NEVER_TICK] * R
                    for ch, (a_us, r_us) in ff.crashes.items():
                        if ch not in ridx:
                            raise ValueError(
                                f"crash scheduled on {ch!r}, which is "
                                "not a receiver in this fabric")
                        at = max(0, int(round(a_us / dt)))
                        ca[ridx[ch]] = at
                        cu[ridx[ch]] = max(at + 1, int(round(r_us / dt)))
                    pv["crash_at"].append(ca)
                    pv["crash_until"].append(cu)
                    pv["f_mtu"].append(ff.mtu_bytes)
                # recovery ledgers engage per flow iff a FaultConfig is
                # attached AND the flow carries a MessageConfig — same
                # rule as run_fabric
                pv["rec_en"].append([
                    1.0 if (ff is not None and m is not None) else 0.0
                    for m in msgs])
                pv["rec_sel"].append([
                    1.0 if (m is not None and m.recovery == "selective")
                    else 0.0 for m in msgs])
                pv["rto_ticks"].append([
                    1 if m is None else max(1, int(round(m.rto_us / dt)))
                    for m in msgs])
                pv["nack_ticks"].append([
                    1 if m is None else max(1, int(round(m.nack_us / dt)))
                    for m in msgs])
                pv["rto_cap"].append([0 if m is None else int(m.rto_cap)
                                      for m in msgs])
                pv["rto_mult"].append([1.0 if m is None
                                       else float(m.rto_backoff)
                                       for m in msgs])
        pvals = {k: np.asarray(v, np.int32 if k in _INT_KEYS
                               else np.float64)
                 for k, v in pv.items() if v}
        H = int(max(pvals["d_base"].max(), pvals["d_strag"].max())) + 2
        Hc = int(pvals["cnp_dly"].max()) + 1
        Hs = int(pvals["settle"].max()) + 1 if dyn else 1
        # message start-time ring: the window bound keeps outstanding
        # <= W+1; +4 leaves slack for float32 count jitter at boundaries
        Lm = int(pvals["m_win"].max()) + 4 if any_msg else 1
        # chunk-boundary envelope: ring horizons are grid maxima, so a
        # chunk's rings are floored at the enclosing grid's to share the
        # monolithic program's shapes (a longer ring is semantically
        # inert — unread slots hold zeros)
        H = max(H, int(env.get("ring_len", 0)))
        Hc = max(Hc, int(env.get("cnp_ring", 0)))
        if dyn:
            Hs = max(Hs, int(env.get("settle_ring", 0)))
        if any_msg:
            Lm = max(Lm, int(env.get("msg_ring", 0)))

        h = hashlib.sha1()
        extras = [a for a in (upP, dnP, candS, crossF, T1, init_spine,
                              port_of, prv_port, nxt_slot,
                              pause_extra, pausable_extra)
                  if a is not None]
        for arr in (stage_mask, *occ, *dest, recv_onehot, recv_of, qos_of,
                    prev_onehot, owner_recv, *extras):
            h.update(np.ascontiguousarray(arr).tobytes())
        h.update(repr((F, P, R, ticks, dt, H, Hc, Hs, Sn, dyn, any_wrr,
                       host_tc, any_cc, any_msg, Lm, any_flt,
                       any_flap, sparse, pack_fail)).encode())
        return cls(port_keys=port_keys, recv_hosts=recv_hosts,
                   flow_tags=[f.tag for f in flows0],
                   stage_mask=stage_mask, occ=occ, dest=dest,
                   recv_onehot=recv_onehot, recv_of=recv_of, qos_of=qos_of,
                   prev_onehot=prev_onehot, owner_recv=owner_recv,
                   pvals=pvals, n_points=G, n_flows=F, n_ports=P, n_recv=R,
                   ticks=ticks, dt_us=dt, ring_len=H, cnp_ring=Hc,
                   structure_key=h.hexdigest(),
                   upP=upP, dnP=dnP, candS=candS, crossF=crossF, T1=T1,
                   init_spine=init_spine, dyn_route=dyn, any_wrr=any_wrr,
                   host_tc=host_tc, settle_ring=Hs,
                   n_spines=Sn if dyn else 0,
                   any_cc=any_cc, any_msg=any_msg, msg_ring=Lm,
                   any_flt=any_flt, any_flap=any_flap,
                   sparse=sparse, port_of=port_of, prv_port=prv_port,
                   nxt_slot=nxt_slot, pack_fail=pack_fail,
                   pause_extra=pause_extra,
                   pausable_extra=pausable_extra)


# --------------------------------------------------------------------------- #
# The shared per-tick step (numpy [G, ...] and jax vmapped [...])
# --------------------------------------------------------------------------- #
def _make_step(xp, ring_set, st, p, dt: float, H: int, dtype, Hc: int = 1,
               opts: Optional[dict] = None):
    """Build ``step(state, t) -> state`` in array namespace ``xp``.

    ``st`` holds the static structure arrays (no grid axis), ``p`` the
    per-point parameters ([G, ...] under numpy, [...] under vmap).  All
    array ops broadcast over an optional leading grid axis, so the same
    closure is the numpy reference and the vmapped jax program.

    Queued bytes and their ECN-marked subset travel together as one
    ``[2, P, F]`` array (axis -3: 0 = bytes, 1 = marks) and the two
    release rings as one ``[2, R, H]`` array — on the CPU backend per-op
    dispatch dominates at these shapes, so halving the op count nearly
    halves the tick.  Per-point constants are hoisted out of the scan
    body for the same reason.

    ``opts`` carries the trace-time capability flags from
    :class:`FabricSweepParams` (``dyn`` routing, ``wrr`` scheduling,
    ``host_tc`` receiver PFC, ``Hs`` spray-settle ring, ``Sn`` spines,
    ``flt`` fault injection + recovery, ``flap`` link-flap schedules):
    with everything off this builds exactly the pre-routing-layer
    program, so static grids stay bit-identical and pay nothing.
    """
    o = opts or {}
    dyn, wrr = o.get("dyn", False), o.get("wrr", False)
    host_tc, Hs = o.get("host_tc", False), o.get("Hs", 1)
    Sn = o.get("Sn", 0)
    any_cc, any_msg = o.get("cc", False), o.get("msg", False)
    Lm = o.get("Lm", 1)
    flt, flap = o.get("flt", False), o.get("flap", False)
    # fused-kernel tier for the two priority water-fills ("ref" is the
    # inline formulation; "pallas"/"interpret" need the jnp namespace)
    impl = o.get("impl", "ref") if xp is not np else "ref"
    f = dtype
    bpt = f(1e9 / 8.0 * dt * 1e-6)       # bytes per (Gbps * tick)
    fdt = f(dt)
    zero, one, tiny = f(0.0), f(1.0), f(1e-30)
    half, inf = f(0.5), f(np.inf)
    eps_q = f(1e-9)
    arangeF = xp.arange(st["recv_of"].shape[0], dtype=xp.int32)
    # loop-invariant per-point quantities, computed once outside the scan
    budget = p["gbps"] * bpt
    budget_crumb = budget * f(1e-6)
    budgetP = budget                     # step() shadows `budget` locally
    buf = p["buf"][..., None]
    # switch traffic classes: clsF is the per-point [Q, F] flow->TC
    # one-hot (all flows on TC 0 for legacy per-link points); the per-TC
    # knee/watermark thresholds broadcast as [.., Q, 1] against [.., Q, P]
    clsF = p["clsF"]
    buf_tc = p["buf"][..., None, None]
    kmin_th = p["kmin"][..., None] * buf_tc
    ecn_on = p["ecn_en"] > 0.5
    can_assert = p["can_assert"] > 0.5
    sxoff = p["sw_xoff"][..., None]
    sxon = p["sw_xon"][..., None]
    # on-off burst trains: sources offer only while the duty-cycle phase
    # is inside the on-window (off_us == 0 means always on)
    onoff = p["off_us"] > zero
    period = xp.where(onoff, p["on_us"] + p["off_us"], one)
    jet = p["jet"] > 0.5
    avail_dram = xp.maximum(zero, p["membw"] - p["cpu_bw"])
    jet_cap = xp.minimum(p["pcie"], p["line1"] * 4.0) * bpt
    strag_share = xp.where(jet, p["sfrac"], zero)
    inv_knee = one / (p["knee"] * p["ddio"])
    rx_pfc_en = p["pfc_en"] > 0.5
    wm_en = p["wm_cnp"] > 0.5
    linecap = xp.minimum(p["line"], p["cap"])
    if wrr:
        quantaQ = p["quanta"][..., None]            # [.., Q, 1]
        is_wrr = (p["sched"] == 1)[..., None, None]  # [.., 1, 1]
    if host_tc:
        hpfc_b = (p["hpfc"] > half)[..., None, :]   # [.., 1, R]
        rx_pfc_tc = rx_pfc_en[..., None, :]
        xoffQ = p["xoff"][..., None, :]
        xonQ = p["xon"][..., None, :]
    if dyn and Sn:
        bufSF = p["buf"][..., None, None]           # vs [.., S, F]
        hystF = p["hystb"][..., None]               # vs [.., F]
        arangeS = xp.arange(Sn, dtype=xp.int32)[:, None]
    if any_cc:
        # algorithm lanes (CcConfig.code: 0 dcqcn, 1 timely, 2 hpcc)
        is_dcqcn = p["cc_algo"] == 0
        timely_m = p["cc_algo"] == 1
        hpcc_m = p["cc_algo"] == 2
        inv_brtt = one / p["base_rtt"]              # [.., F]
        u_floor = f(0.01)
    if any_msg:
        arangeL = xp.arange(Lm, dtype=xp.int32)[:, None]       # [L, 1]
        arangeB = xp.arange(HIST_BUCKETS, dtype=xp.int32)[:, None, None]
        hist_lo = f(HIST_MIN_US)
        inv_lr = f(1.0 / np.log(hist_ratio()))
        eps_m = f(MSG_COUNT_EPS)
        wbytes = p["m_win"] * p["m_bytes"]          # window, in bytes
    if flt:
        # fault layer (repro.fabric.faults): per-flow recovery masks and
        # the per-port counter-hash salts.  The scalar hash is
        # ((t+1)*M + (salt+1)*9973) % 65536; here the tick multiplier is
        # applied as a split modmul — (t+1) reduced mod 65536 then split
        # into hi/lo bytes, with 256*40503 % 65536 = 14080 and
        # 256*24593 % 65536 = 4352 — so every intermediate product stays
        # far inside int32 at any tick count, and all three engines see
        # bit-identical fault realizations
        rec_en = p["rec_en"]                        # exact 1.0 / 0.0
        rec_keep = one - rec_en
        sel_b = p["rec_sel"] > half
        gbn_b = (rec_en > half) & ~sel_b
        saltp = (p["f_salt"] + 1) * 9973 % 65536    # [.., P]
        rto_f = p["rto_ticks"].astype(dtype)

        def ledger(s, lost_f):
            """Route per-flow lost bytes [.., F]: the fluid core's
            instant re-credit, or the recovery ledger where engaged
            (run_fabric's ``lose()``); go-back-N losses gap the
            receiver window."""
            s["inj_lo"] = s["inj_lo"] - lost_f * rec_keep
            s["lost"] = s["lost"] + lost_f * rec_en
            s["gapped"] = s["gapped"] | (gbn_b & (lost_f > zero))

    def cut(s, fire):
        """DCQCN on_cnp for flows where ``fire`` holds."""
        s = dict(s)
        s["rt"] = xp.where(fire, s["rc"], s["rt"])
        s["rc"] = xp.where(
            fire, xp.maximum(p["minr"], s["rc"] * (1.0 - s["alpha"] / 2.0)),
            s["rc"])
        s["alpha"] = xp.where(
            fire, xp.minimum(one, (1.0 - p["g"]) * s["alpha"] + p["g"]),
            s["alpha"])
        for k in ("t_us", "byts", "t_stage", "b_stage", "a_tus"):
            s[k] = xp.where(fire, zero, s[k])
        return s

    def class_tot(q0):
        """Per-(port, TC) occupancy [.., Q, P] from per-flow bytes
        [.., P, F] — one small matmul with the class one-hot."""
        return xp.matmul(clsF, xp.swapaxes(q0, -1, -2))

    def drain(s, k, upf=None):
        """Stage-k ports forward up to rate*dt: per-class budget grants
        (strict priority unrolled over Q, or WRR water-filling where a
        point schedules it), pro rata across the flows of a class.
        ``upf`` zeroes the budget of dead links.  Returns the per-(port,
        flow) drained tensor ``out`` [.., 2, P, F] — dynamic routing
        needs the port-level provenance at the uplink stage."""
        qm = s["qm"]
        q0 = qm[..., 0, :, :]
        qtc = class_tot(q0)                       # [.., Q, P]
        budget0 = budget if upf is None else budget * upf
        # strict-priority budget grants as one fused water-fill stage:
        # each class takes min(1, left/demand), leftover budget below
        # 1e-6 of the link budget clamps to zero (rounding crumbs after
        # a class eats the whole budget must not become micro-byte
        # trickles for the next class — they would trigger full-size
        # discrete CNPs downstream); relative, so f32 and f64 backends
        # agree with the scalar driver on every grant/no-grant decision
        # (OutputPort.drain).  The ref tier is op for op the unrolled
        # loop it replaced; pallas/interpret run the VMEM kernel.
        can_q = st["stage"][k] & ~s["paused"] & (qtc > zero)  # [.., Q, P]
        frac_q = fused.priority_grants(
            xp, qtc, can_q if impl == "ref"
            else xp.where(can_q, one, zero),
            budget0, budget_crumb, one, zero, impl=impl)
        if wrr:
            # weighted water-filling over backlogged unpaused classes,
            # unrolled Q rounds with the exact op order of
            # OutputPort._wrr_fracs (float64 reference == scalar driver)
            rem = xp.where(can_q, qtc, zero)
            alloc = xp.zeros_like(qtc)
            bl = budget0
            for _ in range(N_QOS):
                wq = xp.where(rem > zero, quantaQ, zero)
                wsum = wq.sum(-2)                 # [.., P]
                share = bl[..., None, :] * wq \
                    / xp.maximum(wsum, tiny)[..., None, :]
                take = xp.minimum(share, rem)
                alloc = alloc + take
                rem = rem - take
                bl = bl - take.sum(-2)
                bl = xp.where(bl < budget_crumb, zero, bl)
            frac_wrr = xp.where(qtc > zero,
                                alloc / xp.maximum(qtc, tiny), zero)
            frac_q = xp.where(is_wrr, frac_wrr, frac_q)
        # scatter per-class grants to (port, flow); one class per flow,
        # so the matmul contraction has a single nonzero term
        frac_pf = xp.matmul(xp.swapaxes(frac_q, -1, -2), clsF)
        can_pf = xp.matmul(xp.swapaxes(xp.where(can_q, one, zero),
                                       -1, -2), clsF)
        out = qm * frac_pf[..., None, :, :]
        qm = qm - out
        # sub-1e-9 residues vanish with their marks (the scalar driver's
        # dict-entry cleanup, per drained class)
        gone = (can_pf > half) & (qm[..., 0, :, :] < eps_q)
        s["qm"] = xp.where(gone[..., None, :, :], zero, qm)
        return s, out

    def enqueue(s, A):
        """Batch-enqueue routed arrivals ``A`` [.., 2, P, F]:
        proportional split of each class's buffer partition, one ECN
        knee decision per (port, TC) against that class's pre-batch
        occupancy."""
        q0 = s["qm"][..., 0, :, :]
        qtc = class_tot(q0)                       # [.., Q, P] pre-batch
        tot_q = class_tot(A[..., 0, :, :])
        space_q = xp.maximum(buf_tc - qtc, zero)
        scale_q = xp.where(tot_q > space_q,
                           space_q / xp.maximum(tot_q, tiny), one)
        scale_pf = xp.matmul(xp.swapaxes(scale_q, -1, -2), clsF)
        take = A * scale_pf[..., None, :, :]
        lost = (A - take)[..., 0, :, :]
        # fluid go-back-N: tail-dropped bytes re-open the sender's tap
        # (or wait in the recovery ledger where it is engaged)
        if flt:
            ledger(s, lost.sum(-2))
        else:
            s["inj_lo"] = s["inj_lo"] - lost.sum(-2)
        s["sw_dropped"] = s["sw_dropped"] + lost.sum((-1, -2))
        mark_q = ecn_on[..., None, :] & (qtc > kmin_th)
        mark_pf = xp.matmul(xp.swapaxes(xp.where(mark_q, one, zero),
                                        -1, -2), clsF)        # [.., P, F]
        dm = xp.where(mark_pf > half,
                      take[..., 0, :, :] - take[..., 1, :, :], zero)
        s["ecn_marked"] = s["ecn_marked"] + dm.sum((-1, -2))
        s["qm"] = s["qm"] + take + dm[..., None, :, :] * st["sel1"]
        return s

    fold_at = f(65536.0)

    def fold(s, hi, lo):
        """Drain a split accumulator's low part into its high part once it
        outgrows 64 KiB.  Keeping per-tick increments on a small-magnitude
        accumulator bounds float32 rounding drift to O(10) bytes over a
        run — tight enough that closed-flow completion thresholds stay
        meaningful — while costing three element-wise ops per tick."""
        full = xp.abs(s[lo]) >= fold_at
        s[hi] = s[hi] + xp.where(full, s[lo], zero)
        s[lo] = xp.where(full, zero, s[lo])

    def step(s, t, it=None):
        # ``t`` is the simulated tick (timers, event windows, fault
        # hashes); ``it`` the iteration counter indexing the slot-major
        # delay rings.  The fine-tick backends pass it = t (identical
        # expressions, so the scan program is unchanged); the adaptive
        # backends advance t by the macro stride while it steps by one,
        # keeping ring writes/reads dense — a delay of d ticks becomes
        # d iterations, exact whenever the stride is 1 and within the
        # documented coarsening bound otherwise.
        if it is None:
            it = t
        s = dict(s)
        now = (xp.asarray(t, dtype) + one) * fdt
        fold(s, "injected", "inj_lo")
        fold(s, "delivered", "deliv_lo")

        # ---- 0. link failure / flap / crash events ------------------------ #
        upf = None
        D0 = None
        route_oh = None
        if dyn:
            downP = (t >= p["fail_at"]) & (t < p["fail_until"])   # [.., P]
            edgeP = t == p["fail_at"]
            if flap:
                # periodic flaps fold into the same down/edge masks
                # (Topology.flap_ticks: down for the first `down` ticks
                # of each `period` cycle from `start`)
                since = t - p["flap_start"]
                live = t >= p["flap_start"]
                downP = downP | (live
                                 & (since % p["flap_period"]
                                    < p["flap_down"]))
                edgeP = edgeP | (live & (since % p["flap_period"] == 0))
            upf = xp.where(downP, zero, one)
            failf = xp.where(edgeP, one, zero)
            # in-flight bytes die with the link; fluid go-back-N
            # re-credits them for retransmission (run_fabric step 0)
            lostF = (s["qm"][..., 0, :, :] * failf[..., :, None]).sum(-2)
            if flt:
                ledger(s, lostF)
                s["flt_drop"] = s["flt_drop"] + lostF.sum(-1)
            else:
                s["inj_lo"] = s["inj_lo"] - lostF
            s["sw_dropped"] = s["sw_dropped"] + lostF.sum(-1)
            s["qm"] = s["qm"] * (one - failf)[..., None, :, None]
        if flt:
            # NIC/host crash: everything queued on the crashed
            # receiver's access link dies and its admission state
            # zeroes (ReceiverHost.crash_reset); cumulative accounting
            # counters and the CNP pacing clock survive the crash
            crash_now = t == p["crash_at"]                        # [.., R]
            crashP = crash_now[..., st["owner_clamp"]] \
                & st["owner_valid"]                               # [.., P]
            deadQ = xp.where(crashP[..., None, :, None], s["qm"], zero)
            lostC = deadQ[..., 0, :, :].sum(-2)
            ledger(s, lostC)
            s["flt_drop"] = s["flt_drop"] + lostC.sum(-1)
            s["sw_dropped"] = s["sw_dropped"] + lostC.sum(-1)
            s["qm"] = s["qm"] - deadQ
            cz = xp.where(crash_now, zero, one)
            for ck in ("resident", "strag_res", "esc_debt", "repl_debt",
                       "repl_mem", "ecn_tus"):
                s[ck] = s[ck] * cz
            s["qos_q"] = s["qos_q"] * cz[..., None, :]
            s["ring"] = s["ring"] * cz[..., None, None, :]
            s["pfc"] = s["pfc"] & ~(crash_now[..., None, :] if host_tc
                                    else crash_now)
            s["heavy"] = xp.where(crash_now, -1, s["heavy"])
            # the cleared RNIC gate unpauses the access link this very
            # tick (the scalar driver reads rx.pfc_paused live in its
            # drain); switch-asserted pauses persist via the carried
            # link-pause mask
            s["paused"] = xp.where(crashP[..., None, :], s["lpause"],
                                   s["paused"])
            # stochastic loss/corruption: one counter hash per (link,
            # tick); when it fires, everything the port drains this
            # tick is lost on the wire (ECN marks die with the bytes)
            tr = (t + 1) % 65536
            thi, tlo = tr // 256, tr % 256
            hl = (thi * 14080 + tlo * 40503 + saltp) % 65536
            hc = (thi * 4352 + tlo * 24593 + saltp) % 65536
            dropP = (hl < p["f_thr"]) | (hc < p["f_cthr"])        # [.., P]

            def kill(s, out):
                """Apply this tick's stochastic drops to one drained
                stage [.., 2, P, F] — before tx accounting and
                forwarding, as run_fabric's drain loop."""
                dead = xp.where(dropP[..., None, :, None], out, zero)
                lost_k = dead[..., 0, :, :].sum(-2)
                ledger(s, lost_k)
                s["flt_drop"] = s["flt_drop"] + lost_k.sum(-1)
                return out - dead

        # ---- 1. senders: DCQCN advance + offer ---------------------------- #
        adv = now > p["start"]
        # the DCQCN timer machinery only moves DCQCN-lane flows; the CC
        # block after forwarding writes the timely/hpcc rates instead
        dadv = (adv & is_dcqcn) if any_cc else adv
        adv_dt = xp.where(dadv, fdt, zero)
        a_tus = s["a_tus"] + adv_dt
        a_fire = dadv & (a_tus >= p["a_tmr"])
        s["alpha"] = xp.where(a_fire, (1.0 - p["g"]) * s["alpha"],
                              s["alpha"])
        s["a_tus"] = xp.where(a_fire, zero, a_tus)
        t_us = s["t_us"] + adv_dt
        byts = xp.where(dadv, s["byts"] + s["rc"] * bpt, s["byts"])
        t_fire = dadv & (t_us >= p["r_tmr"])
        s["t_stage"] = s["t_stage"] + t_fire
        s["t_us"] = xp.where(t_fire, zero, t_us)
        b_fire = dadv & (byts >= p["bctr"])
        s["b_stage"] = s["b_stage"] + b_fire
        s["byts"] = xp.where(b_fire, zero, byts)
        fired = t_fire | b_fire
        stage = xp.minimum(s["t_stage"], s["b_stage"])
        s["rt"] = xp.where(fired & (stage == p["fth"]),
                           xp.minimum(p["dline"], s["rt"] + p["ai"]),
                           s["rt"])
        s["rt"] = xp.where(fired & (stage > p["fth"]),
                           xp.minimum(p["dline"], s["rt"] + p["hai"]),
                           s["rt"])
        s["rc"] = xp.where(fired,
                           xp.minimum(p["dline"],
                                      0.5 * (s["rc"] + s["rt"])),
                           s["rc"])

        gbps = xp.minimum(s["rc"], linecap)
        room = xp.maximum(p["burst"] - (s["injected"] + s["inj_lo"]), zero)
        # burst-train duty cycle: the DCQCN machine keeps running, the
        # tap only opens during the on-phase (matches SenderHost.offer)
        active = adv & (~onoff | (xp.fmod(now - p["start"], period)
                                  < p["on_us"]))
        offer = xp.where(active, xp.minimum(gbps * bpt, room), zero)
        if any_msg:
            # outstanding message window: injection never runs more than
            # W*msg_bytes ahead of delivery (start-of-tick counters, the
            # exact clamp SenderHost.offer applies via window_room)
            wroom = xp.maximum(
                wbytes - (s["injected"] + s["inj_lo"]
                          - s["delivered"] - s["deliv_lo"]), zero)
            offer = xp.minimum(offer, wroom)
        # source-side backpressure: the NIC queue never overflows, bytes
        # that don't fit in the flow's class partition stay un-injected
        off_pf = st["occ"][0] * offer[..., None, :]
        tot_q = class_tot(off_pf)                         # [.., Q, P]
        space_q = xp.maximum(
            buf_tc - class_tot(s["qm"][..., 0, :, :]), zero)
        scale_q = xp.where(tot_q > space_q,
                           space_q / xp.maximum(tot_q, tiny), one)
        scale_pf = xp.matmul(xp.swapaxes(scale_q, -1, -2), clsF)
        take_f = offer * (st["occ"][0] * scale_pf).sum(-2)
        s["inj_lo"] = s["inj_lo"] + take_f
        s["qm"] = s["qm"] + \
            (st["occ"][0] * take_f[..., None, :])[..., None, :, :] \
            * st["sel0"]

        # ---- 1.5 routing weights (after injection, as run_fabric) --------- #
        if dyn:
            if Sn:
                # idle-gap flowlet tracking (run_fabric step 1): a flow
                # injecting again after more than flowlet_gap ticks of
                # silence opens a new flowlet; a continuously-backlogged
                # flow never re-hashes (injection only touches NIC ports,
                # so the uplink occupancies read below are unaffected)
                act = take_f > zero
                boundary = act & ((t - s["flet_last"])
                                  > p["flet"][..., None])
                k_new = s["flet_k"] + boundary.astype(xp.int32)
                s["flet_k"] = k_new
                s["flet_last"] = xp.where(act, xp.asarray(t, xp.int32),
                                          s["flet_last"])
                # per-tick spine selection (run_fabric step 1.5): uplink
                # occupancy/up-state per candidate as [.., S, F] blocks
                occP = s["qm"][..., 0, :, :].sum(-1)              # [.., P]
                occS = xp.einsum('sfp,...p->...sf', st["upP"], occP)
                up1 = xp.einsum('sfp,...p->...sf', st["upP"], upf)
                up2 = xp.einsum('sfp,...p->...sf', st["dnP"], upf)
                upS = st["candS"] & (up1 > half) & (up2 > half)
                free = xp.where(upS, xp.maximum(bufSF - occS, zero), zero)
                cur = s["route"]                                  # [.., F]
                cur_oh = arangeS == cur[..., None, :]             # [.., S, F]
                occ_cur = (occS * xp.where(cur_oh, one, zero)).sum(-2)
                up_cur = (upS & cur_oh).any(-2)
                any_up = upS.any(-2)
                # adaptive: least-congested up candidate + hysteresis
                occ_masked = xp.where(upS, occS, inf)
                best = xp.argmin(occ_masked, -2).astype(xp.int32)
                occ_best = occ_masked.min(-2)
                adapt = xp.where(
                    any_up & (~up_cur | (occ_best < occ_cur - hystF)),
                    best, cur)
                # weighted ECMP: flowlet-boundary (or dead-path) re-hash
                # against the free-space-weighted cumulative distribution;
                # thresholding against the cumsum's own last element keeps
                # the pick identical to routing.weighted_pick (modular
                # reduction of k keeps every product inside int32)
                kred = k_new % 65536
                hv = ((arangeF + 1) * 40503 + kred * 9973) % 65536
                hsh = hv.astype(dtype) / f(65536.0)               # [.., F]
                cum = xp.cumsum(free, -2)
                tot = cum[..., Sn - 1, :]                         # [.., F]
                pick = xp.argmax(cum > (hsh * tot)[..., None, :],
                                 -2).astype(xp.int32)
                repick = boundary | ~up_cur
                wec = xp.where(repick & (tot > zero), pick, cur)
                m = p["rmode"][..., None]                         # [.., 1]
                choice = xp.where(m == 2, adapt,
                                  xp.where(m == 1, wec, cur))
                s["reroutes"] = s["reroutes"] + \
                    xp.where(choice != cur, one, zero)
                s["route"] = choice
                ch_oh = xp.where(arangeS == choice[..., None, :],
                                 one, zero)
                route_oh = ch_oh
                totS = tot[..., None, :]
                spray_w = xp.where(totS > zero,
                                   free / xp.maximum(totS, tiny), ch_oh)
                W = xp.where(m[..., None] == 3, spray_w, ch_oh)
                D0 = st["dest"][0] + xp.einsum('...sf,sfp->...pf',
                                               W, st["upP"])
            else:
                D0 = st["dest"][0]

        # ---- 2. tier-ordered forwarding (cut-through within the tick) ---- #
        s, out = drain(s, 0, upf)
        if flt:
            out = kill(s, out)
        if any_cc:
            # per-tick drained bytes per port: the txRate leg of the
            # HPCC-style INT signal (run_fabric's tick_tx)
            txP = out[..., 0, :, :].sum(-1)
        fbm = (st["occ"][0] * out).sum(-2)
        if dyn:
            # cross-leaf stage-0 output follows this tick's routing
            # weights; intra-leaf flows ride the static dest[0] part
            s = enqueue(s, D0[..., None, :, :] * fbm[..., None, :])
        else:
            s = enqueue(s, st["dest"][0] * fbm[..., None, :])
        s, out = drain(s, 1, upf)
        if flt:
            out = kill(s, out)
        if any_cc:
            txP = txP + out[..., 0, :, :].sum(-1)
        if dyn:
            # uplink-stage output keeps its port-level provenance: the
            # static [P, F, P] map sends bytes drained at (leaf, spine)
            # to that spine's downlink toward the flow's leaf
            s["tx"] = s["tx"] + out[..., 0, :, :].sum(-1)
            s = enqueue(s, xp.einsum('...cpf,pfq->...cqf',
                                     out, st["T1"]))
        else:
            fbm = (st["occ"][1] * out).sum(-2)
            s = enqueue(s, st["dest"][1] * fbm[..., None, :])
        s, out = drain(s, 2, upf)
        if flt:
            out = kill(s, out)
        if any_cc:
            txP = txP + out[..., 0, :, :].sum(-1)
        fbm = (st["occ"][2] * out).sum(-2)
        s = enqueue(s, st["dest"][2] * fbm[..., None, :])
        s, out = drain(s, 3, upf)
        if flt:
            out = kill(s, out)
        if any_cc:
            txP = txP + out[..., 0, :, :].sum(-1)
        fbm = (st["occ"][3] * out).sum(-2)
        if Hs > 1:
            # spray reorder settling: sprayed arrivals wait settle ticks
            # in a slot-major ring before receiver admission (per-flow
            # read offset; 0 = read the slot just written = pass-through)
            s["sring"] = ring_set(s["sring"], it % Hs, fbm)
            sidx = (it - p["settle"]) % Hs
            fbm = xp.take_along_axis(s["sring"], sidx[..., None, None, :],
                                     -3)[..., 0, :, :]
        arr_b = fbm[..., 0, :]
        arr_m = fbm[..., 1, :]
        if flt:
            # crashed receivers discard arrivals until restart, then a
            # gapped go-back-N window discards the rest as duplicates
            # (run_fabric step 3 order: crash first, then dup
            # suppression; duplicates go straight back to the ledger)
            crashF = ((t >= p["crash_at"])
                      & (t < p["crash_until"]))[..., st["recv_of"]]
            dead_b = xp.where(crashF, arr_b, zero)
            ledger(s, dead_b)
            s["flt_drop"] = s["flt_drop"] + dead_b.sum(-1)
            arr_b = arr_b - dead_b
            arr_m = xp.where(crashF, zero, arr_m)
            dup_b = xp.where(s["gapped"], arr_b, zero)
            s["lost"] = s["lost"] + dup_b
            s["flt_drop"] = s["flt_drop"] + dup_b.sum(-1)
            arr_b = arr_b - dup_b
            arr_m = xp.where(s["gapped"], zero, arr_m)

        # ---- 2.2 delay/INT telemetry -> CC zoo updates -------------------- #
        # end-of-forwarding queue state along each flow's current path,
        # folded into rtt = base + sum(q/budget) and util = max per-hop
        # (txRate/B + qlen/(B*T)) — run_fabric's loop as masked lanes
        if any_cc:
            qP = s["qm"][..., 0, :, :].sum(-1)                # [.., P]
            if dyn and Sn:
                leg1 = xp.einsum('...sf,sfp->...pf', route_oh, st["upP"])
                leg2 = xp.einsum('...sf,sfp->...pf', route_oh, st["dnP"])
            elif dyn:
                leg1 = leg2 = None
            else:
                leg1, leg2 = st["occ"][1], st["occ"][2]
            qd = zero
            util = zero
            for leg in (st["occ"][0], leg1, leg2, st["occ"][3]):
                if leg is None:
                    continue
                # [P, F] (static) or [.., P, F] (routed) one-hot gathers
                q_l = (leg * qP[..., :, None]).sum(-2)        # [.., F]
                tx_l = (leg * txP[..., :, None]).sum(-2)
                b_l = (leg * budgetP[..., :, None]).sum(-2)
                ok = b_l > zero
                qd = qd + xp.where(ok, q_l / xp.maximum(b_l, tiny), zero)
                u_l = xp.where(ok, (tx_l + q_l * (fdt * inv_brtt))
                               / xp.maximum(b_l, tiny), zero)
                util = xp.maximum(util, u_l)
            rtt = p["base_rtt"] + qd * fdt
            ctus = s["cc_tus"] + fdt
            fire = ctus >= p["cc_upd"]
            s["cc_tus"] = xp.where(fire, zero, ctus)
            # Timely: smoothed RTT gradient picks the branch
            ft = fire & timely_m
            diff = rtt - s["prev_rtt"]
            rd_new = (1.0 - p["tl_a"]) * s["rtt_diff"] + p["tl_a"] * diff
            s["prev_rtt"] = xp.where(ft, rtt, s["prev_rtt"])
            s["rtt_diff"] = xp.where(ft, rd_new, s["rtt_diff"])
            grad = rd_new * inv_brtt
            rc = s["rc"]
            r_tim = xp.where(
                rtt < p["t_low"], rc + p["tl_add"],
                xp.where(rtt > p["t_high"],
                         rc * (one - p["tl_beta"]
                               * (one - p["t_high"] / rtt)),
                         xp.where(grad <= zero, rc + p["tl_add"],
                                  rc * xp.maximum(
                                      zero, one - p["tl_beta"] * grad))))
            rc_tim = xp.minimum(p["line"],
                                xp.maximum(p["cc_minr"], r_tim))
            # HPCC: drive max per-hop utilization toward eta
            fh = fire & hpcc_m
            mult = xp.clip(p["hp_eta"] / xp.maximum(util, u_floor),
                           half, f(2.0))
            rc_hp = xp.minimum(p["line"],
                               xp.maximum(p["cc_minr"],
                                          rc * mult + p["hp_ai"]))
            s["rc"] = xp.where(ft, rc_tim, xp.where(fh, rc_hp, rc))

        # ---- 3. receivers advance one tick (HostDatapath, stacked) -------- #
        arr_rb = st["recv_onehot"] * arr_b[..., None, :]
        # QoS-classed arrivals [.., Q, R] (admission class x receiver)
        arr_cr = (st["cls_recv"] * arr_b[..., None, None, :]).sum(-1)
        arr_tot = arr_cr.sum(-2)
        # admission: RNIC buffer space granted in QoS-priority order —
        # the second fused priority water-fill (HostDatapath.admit_link)
        space_r = xp.maximum(p["rnic_buf"] - s["qos_q"].sum(-2), zero)
        acc_cr = fused.priority_admit(xp, arr_cr, space_r, impl=impl)
        accepted = acc_cr[..., 0, :]
        for q_i in range(1, N_QOS):
            accepted = accepted + acc_cr[..., q_i, :]
        if flt:
            # first byte accepted after a crash restart stamps the
            # crash-recovery latency (run_fabric step 3)
            rec_hit = (t >= p["crash_until"]) & (accepted > zero) \
                & xp.isinf(s["crash_rec"])
            s["crash_rec"] = xp.where(
                rec_hit, now - p["crash_at"].astype(dtype) * fdt,
                s["crash_rec"])
        s["rnic_drop"] = s["rnic_drop"] + (arr_tot - accepted)
        s["qos_q"] = s["qos_q"] + acc_cr

        ws = p["qp_bytes"] + s["resident"]
        miss = xp.clip((ws - p["ddio"]) * inv_knee, zero, one)
        s["miss_sum"] = s["miss_sum"] + xp.where(jet, zero, miss)
        ddio_bw = xp.where(miss > 1e-9,
                           xp.minimum(p["pcie"],
                                      avail_dram / (2.0 * miss + tiny)),
                           p["pcie"])
        # drain budget granted in QoS-priority order; under Jet pool
        # pressure (< cache_safe free) the LOW class spills to DRAM (§5)
        budget = xp.where(jet, jet_cap, ddio_bw * bpt)
        pool_free = xp.maximum(zero, p["pool"] - s["resident"])
        spill = jet & (pool_free / p["pool"] < p["safe"])
        pf = xp.where(jet, pool_free, inf)
        drained = pool_drained = fallback = zero
        new_q = []
        for q_i in range(N_QOS):
            qq = s["qos_q"][..., q_i, :]
            take = xp.minimum(xp.minimum(qq, budget), pf)
            if q_i == N_QOS - 1:        # LOW spills instead of waiting
                take = xp.where(spill, xp.minimum(qq, budget), take)
                spilled = xp.where(spill, take, zero)
            else:
                spilled = zero
            pf = pf - (take - spilled)
            budget = budget - take
            new_q.append(qq - take)
            drained = drained + take
            pool_drained = pool_drained + (take - spilled)
            fallback = fallback + spilled
        s["qos_q"] = xp.stack(new_q, -2)
        s["nic_dram"] = s["nic_dram"] + \
            xp.where(jet, fallback, drained * 2.0 * miss)
        s["mem_fb"] = s["mem_fb"] + fallback
        strag_part = pool_drained * strag_share
        parts = xp.stack([pool_drained * (1.0 - strag_share), strag_part],
                         -2)
        # ring layout [H, 2, R]: the write is a contiguous leading-axis
        # slice update, which XLA aliases in place inside the scan carry
        s["ring"] = ring_set(s["ring"], it % H, parts)
        s["resident"] = s["resident"] + pool_drained
        s["strag_res"] = s["strag_res"] + strag_part
        s["drained"] = s["drained"] + drained

        idx = (it - p["d2"]) % H                  # [.., 2, R]
        r2 = xp.take_along_axis(s["ring"], idx[..., None, :, :],
                                -3)[..., 0, :, :]
        r2 = xp.where(it >= p["d2"], r2, zero)
        for j, is_strag in ((0, False), (1, True)):
            r = r2[..., j, :]
            void = xp.minimum(r, s["esc_debt"])
            s["esc_debt"] = s["esc_debt"] - void
            r = r - void
            repay = xp.minimum(void, s["repl_debt"])
            s["repl_debt"] = s["repl_debt"] - repay
            s["repl_mem"] = xp.maximum(zero, s["repl_mem"] - repay)
            s["resident"] = xp.maximum(zero, s["resident"] - r)
            if is_strag:
                s["strag_res"] = xp.maximum(zero, s["strag_res"] - r)

        # Jet escape ladder (paper Algorithm 1)
        avail = xp.maximum(zero, p["pool"] - s["resident"]) / p["pool"]
        esc_on = jet & (avail < p["safe"])
        can_rep = s["repl_mem"] < p["mem_esc"]
        x_rep = xp.where(esc_on & can_rep,
                         xp.maximum(zero,
                                    xp.minimum(s["strag_res"],
                                               p["mem_esc"]
                                               - s["repl_mem"])),
                         zero)
        s["resident"] = s["resident"] - x_rep
        s["strag_res"] = s["strag_res"] - x_rep
        s["esc_debt"] = s["esc_debt"] + x_rep
        s["repl_debt"] = s["repl_debt"] + x_rep
        s["repl_mem"] = s["repl_mem"] + x_rep
        s["esc_dram"] = s["esc_dram"] + 0.1 * x_rep
        s["replaces"] = s["replaces"] + (x_rep > zero)
        x_cop = xp.where(esc_on & ~can_rep, s["strag_res"], zero)
        s["resident"] = s["resident"] - x_cop
        s["strag_res"] = s["strag_res"] - x_cop
        s["esc_debt"] = s["esc_debt"] + x_cop
        s["esc_dram"] = s["esc_dram"] + x_cop
        s["copies"] = s["copies"] + (x_cop > zero)
        avail2 = xp.maximum(zero, p["pool"] - s["resident"]) / p["pool"]
        in_danger = esc_on & (avail2 < p["danger"])
        s["ecn_tus"] = xp.where(in_danger, s["ecn_tus"] + fdt, s["ecn_tus"])
        esc_fire = in_danger & (s["ecn_tus"] >= p["cnp_iv"])
        s["ecn_tus"] = xp.where(esc_fire, zero, s["ecn_tus"])
        s["cnps"] = s["cnps"] + esc_fire
        s["ecns"] = s["ecns"] + esc_fire
        s["pool_sum"] = s["pool_sum"] + xp.where(jet, s["resident"], zero)
        s["pool_peak"] = xp.maximum(s["pool_peak"],
                                    xp.where(jet, s["resident"], zero))

        # receiver congestion signalling
        q_frac = s["qos_q"].sum(-2) / p["rnic_buf"]
        if host_tc:
            # per-class receiver gate ([.., Q, R] pause state): per-TC
            # points watermark each class's occupancy of its 1/N_QOS
            # buffer partition (ReceiverHost's arithmetic, op for op),
            # legacy points see the total occupancy in every row —
            # identical decisions to the scalar whole-link gate
            frac_c = s["qos_q"] / (p["rnic_buf"] / f(N_QOS))[..., None, :]
            sel = xp.where(hpfc_b, frac_c, q_frac[..., None, :])
            s["pfc"] = rx_pfc_tc & xp.where(s["pfc"], sel >= xonQ,
                                            sel > xoffQ)
            pfc_any = s["pfc"].any(-2)
        else:
            s["pfc"] = rx_pfc_en & xp.where(s["pfc"], q_frac >= p["xon"],
                                            q_frac > p["xoff"])
            pfc_any = s["pfc"]
        s["pfc_us"] = s["pfc_us"] + xp.where(pfc_any, fdt, zero)
        cnp_tus = s["cnp_tus"] + fdt
        wm_fire = wm_en & (q_frac > p["ecn_th"]) \
            & (cnp_tus >= p["cnp_iv"])
        s["cnp_tus"] = xp.where(wm_fire, zero, cnp_tus)
        s["cnps"] = s["cnps"] + wm_fire

        # ---- 4. feedback routes back to the senders ----------------------- #
        # per-class acceptance share: a flow recovers the share its own
        # admission class received (matches HostDatapath.admit_link)
        share_cr = xp.where(arr_cr > zero,
                            acc_cr / xp.maximum(arr_cr, tiny), zero)
        deliv = arr_b * share_cr[..., st["cls_of"], st["recv_of"]]
        s["deliv_lo"] = s["deliv_lo"] + deliv
        # RNIC tail drops are retransmitted too (fluid RC / the ledger)
        if flt:
            ledger(s, arr_b - deliv)
        else:
            s["inj_lo"] = s["inj_lo"] - (arr_b - deliv)
        s["completion"] = xp.where(
            xp.isinf(s["completion"])
            & (s["delivered"] + s["deliv_lo"] >= p["burst_done"]),
            now, s["completion"])

        # receiver CNPs hit the heaviest recently-arriving flow (lowest
        # flow id on ties); with nothing arriving the previous target
        # stays throttled, as in run_fabric/run_sim
        has_arr = arr_tot > zero
        heavy_new = xp.argmax(arr_rb, -1).astype(xp.int32)
        s["heavy"] = xp.where(has_arr, heavy_new, s["heavy"])
        is_heavy = arangeF == s["heavy"][..., st["recv_of"]]
        f_esc = is_heavy & esc_fire[..., st["recv_of"]]
        f_wm = is_heavy & wm_fire[..., st["recv_of"]]
        # switch ECN marks -> per-flow CNPs, paced per DCQCN NP
        s["backlog"] = s["backlog"] + arr_m
        pace_tus = s["pace_tus"] + fdt
        pace_fire = (s["backlog"] > zero) & (pace_tus >= p["cnp_iv_f"])
        s["pace_tus"] = xp.where(pace_fire, zero, pace_tus)
        s["backlog"] = xp.where(pace_fire, zero, s["backlog"])
        # CNP propagation ring [Hc, 3, F]: notifications generated this
        # tick (slot t % Hc) cut their sender its *own* cnp_delay ticks
        # later — the delay is per flow, so the read index is a [F]
        # gather (slot (t - delay_f) % Hc; Hc > every delay, so for
        # t < delay the read lands on a slot not yet written, which
        # still holds zero)
        fires = xp.stack([xp.where(f_esc, one, zero),
                          xp.where(f_wm, one, zero),
                          xp.where(pace_fire, one, zero)], -2)
        s["cring"] = ring_set(s["cring"], it % Hc, fires)
        cidx = (it - p["cnp_dly"]) % Hc
        due = xp.take_along_axis(s["cring"], cidx[..., None, None, :],
                                 -3)[..., 0, :, :]
        for j in range(3):
            fire_c = due[..., j, :] > half
            if any_cc:
                # timely/hpcc ignore CNPs (CongestionControl.on_cnp)
                fire_c = fire_c & is_dcqcn
            s = cut(s, fire_c)

        # ---- 5. per-priority PFC pause propagation ------------------------ #
        q0 = s["qm"][..., 0, :, :]
        frac_occ = class_tot(q0) / buf_tc                     # [.., Q, P]
        s["asserted"] = can_assert[..., None, :] & \
            xp.where(s["asserted"], frac_occ >= sxon, frac_occ > sxoff)
        # a flow contributes a pause iff its own class is over watermark
        # at the port it is queued in: scatter the per-class assert state
        # back to (port, flow), then to that flow's class on its ingress
        # link — [.., Q, P*F] @ [P*F, P] per class
        assert_pf = xp.matmul(xp.swapaxes(
            xp.where(s["asserted"], one, zero), -1, -2), clsF)
        contrib = xp.where((assert_pf > half) & (q0 > zero), one, zero)
        contrib_q = contrib[..., None, :, :] * clsF[..., :, None, :]
        flat = contrib_q.reshape(contrib_q.shape[:-2] + (-1,))
        link_paused = xp.matmul(flat, st["prev_mat"]) > zero   # [.., Q, P]
        link_any = link_paused.any(-2)
        s["pause_us"] = s["pause_us"] + xp.where(link_any, fdt, zero)
        s["pause_tc_us"] = s["pause_tc_us"] + \
            xp.where(link_paused, fdt, zero)
        s["ever_paused"] = s["ever_paused"] | link_any
        if flt:
            # switch-asserted pause mask, carried so a crash can rebuild
            # the pause state of its access ports without the RNIC gate
            s["lpause"] = link_paused
            # PFC-deadlock watchdog (faults.has_pause_cycle, vectorized):
            # count a tick whenever the switch-asserted pause graph of
            # any single class holds a directed cycle — the per-class
            # [Q, P] mask lifts to node adjacencies through the static
            # port -> (u, v) one-hot and closes in log2(N) squarings
            n_dl = int(round(float(np.sqrt(st["dl_E"].shape[-1]))))
            cyc = fused.cycle_flags(
                xp, xp.where(link_paused, one, zero), st["dl_E"],
                n_dl, one)
            s["deadlock"] = s["deadlock"] + xp.where(cyc, one, zero)
        # the receiver RNIC gate: whole access link (legacy — broadcast
        # across the class axis) or per admission class (host_pfc_per_tc,
        # [.., Q, R] state gathered per stage-3 port)
        if host_tc:
            rx_gate = s["pfc"][..., st["owner_clamp"]] & st["owner_valid"]
            s["paused"] = link_paused | rx_gate
        else:
            rx_gate = s["pfc"][..., st["owner_clamp"]] & st["owner_valid"]
            s["paused"] = link_paused | rx_gate[..., None, :]

        # ---- 6. message-layer crossings (MessageTracker, stacked) --------- #
        # end-of-tick byte counters (post re-credit, so go-back-N losses
        # keep the affected messages open): ceil counts starts (first
        # byte enters the stream), floor counts completions, both with
        # the MSG_COUNT_EPS slack; the start-time ring plays the
        # tracker's per-message start list
        if any_msg:
            inj_tot = s["injected"] + s["inj_lo"]
            del_tot = s["delivered"] + s["deliv_lo"]
            mb = p["m_bytes"]
            ns = xp.ceil(inj_tot / mb - eps_m).astype(xp.int32)
            hw = s["m_hw"]
            new_s = xp.maximum(ns - hw, 0)         # go-back-N: hw grows
            woff = (arangeL - hw[..., None, :] % Lm) % Lm   # [.., L, F]
            wmask = woff < new_s[..., None, :]
            s["mring"] = xp.where(wmask, now - fdt, s["mring"])
            hw = hw + new_s
            s["m_hw"] = hw
            nd = xp.minimum(xp.floor(del_tot / mb + eps_m)
                            .astype(xp.int32), hw)
            done = s["m_done"]
            new_d = xp.maximum(nd - done, 0)
            roff = (arangeL - done[..., None, :] % Lm) % Lm
            rmask = roff < new_d[..., None, :]
            lat = now - s["mring"] + p["m_extra"][..., None, :]
            s["m_lat"] = s["m_lat"] + xp.where(rmask, lat, zero).sum(-2)
            # fixed-bucket log histogram (messages.hist_bucket
            # arithmetic); latencies above the histogram ceiling land in
            # the explicit overflow counter instead of the last bucket,
            # so pod-scale cross-tier tails can't silently report a
            # midpoint below the true value (LogHistogram.overflow_count)
            bi = xp.floor(xp.log(xp.maximum(lat, hist_lo) / hist_lo)
                          * inv_lr).astype(xp.int32)
            over = bi > HIST_BUCKETS - 1
            bi = xp.clip(bi, 0, HIST_BUCKETS - 1)
            inc = (arangeB == bi[..., None, :, :]) \
                & rmask[..., None, :, :] \
                & ~over[..., None, :, :]           # [.., B, L, F]
            s["m_hist"] = s["m_hist"] + xp.where(inc, one, zero).sum(-2)
            s["m_over"] = s["m_over"] + xp.where(rmask & over, one,
                                                 zero).sum(-2)
            s["m_done"] = done + new_d
            s["m_last"] = xp.where(new_d > 0, now, s["m_last"])

        # ---- 6.5 retransmit timers (run_fabric step 3.7) ------------------ #
        # after the message observe, so both engines record this tick's
        # latencies against the pre-fire injected count; the re-credit
        # reopens the sender's tap from the next offer on.  The timer
        # runs while the ledger is non-empty; go-back-N backs the RTO
        # off exponentially (k reset on delivery progress), selective
        # fires after the fixed NACK delay (FlowRecovery.tick)
        if flt:
            prog = deliv > zero
            k = xp.where(prog, 0, s["rto_k"])
            has = s["lost"] > zero
            timer = xp.where(has, s["rto_t"] + 1, 0)
            kc = xp.minimum(k, p["rto_cap"])
            dl_gbn = xp.floor(rto_f * p["rto_mult"]
                              ** kc.astype(dtype)).astype(xp.int32)
            dl = xp.where(sel_b, p["nack_ticks"], dl_gbn)
            fire = has & (timer >= dl)
            credit = xp.where(fire, s["lost"], zero)
            s["inj_lo"] = s["inj_lo"] - credit
            s["retx"] = s["retx"] + credit
            s["lost"] = xp.where(fire, zero, s["lost"])
            s["gapped"] = s["gapped"] & ~fire
            s["rto_t"] = xp.where(fire, 0, timer)
            s["rto_k"] = xp.where(fire & gbn_b,
                                  xp.minimum(k + 1, p["rto_cap"]), k)
        return s

    return step


def _make_step_sparse(xp, ring_set, st, p, dt: float, H: int, dtype,
                      Hc: int = 1, opts: Optional[dict] = None):
    """Build the sparse-incidence ``step(state, t)`` (pod-scale fabrics).

    Tick semantics match :func:`_make_step` exactly, but queue state
    lives as ``[.., 2, S, F]`` *slot* entries (S = 6 tier-ordered stage
    slots; slot ``(s, f)`` is queued at port ``port_of[s, f]``) instead
    of the dense ``[.., 2, P, F]`` port x flow matrix.  Per-(port, TC)
    totals are segment-sums over the S*F (slot, flow) entries and every
    per-port decision (drain fraction, buffer scale, ECN knee, PFC
    assert) comes back to the flows as a padded flat gather at the
    static ``tc * (P+1) + port`` indices — per-tick cost grows with
    flows x hops, not flows x ports, which is what lets a 256-512-host
    pod sweep trace as one jax program.

    Supported per-point features: static ECMP, failure/flap windows,
    strict/WRR scheduling, per-TC switch PFC and per-TC host PFC, burst
    trains, the CNP ring, the CC zoo (DCQCN/Timely/HPCC per flow — the
    delay/INT telemetry walks the route slots in tier order, so the
    per-leg RTT sum accumulates in the dense engine's leg order and
    2-tier grids stay bit-equal) and the full receiver block.  Dynamic
    routing, the message layer and FaultConfig injection stay on the
    dense engine (:meth:`FabricSweepParams.from_scenarios` rejects them
    with a clear error under ``sparse=True``).
    """
    o = opts or {}
    wrr, host_tc = o.get("wrr", False), o.get("host_tc", False)
    any_cc = o.get("cc", False)
    impl = o.get("impl", "ref") if xp is not np else "ref"
    fail = "fail_at" in p
    flap = "flap_start" in p
    f = dtype
    S = _STAGES_SP
    F = int(st["recv_of"].shape[0])
    P = int(st["stage"].shape[-1])
    Ppad = P + 1                     # column P = "slot unused" dummy
    QPpad = N_QOS * Ppad
    if xp is np:
        def seg_sum(vals, idx, size):
            """Batched segment-sum: scatter-add ``vals`` [.., N] at
            ``idx`` [N] into [.., size]."""
            lead = vals.shape[:-1]
            vf = np.ascontiguousarray(vals).reshape(-1, vals.shape[-1])
            acc = np.zeros((vf.shape[0], size), vals.dtype)
            np.add.at(acc, (np.arange(vf.shape[0])[:, None],
                            np.asarray(idx)[None, :]), vf)
            return acc.reshape(lead + (size,))
    else:
        def seg_sum(vals, idx, size):
            return xp.zeros(vals.shape[:-1] + (size,),
                            vals.dtype).at[..., idx].add(vals)

    def segQ(vals, idx):
        """Scatter flow values to [.., Q, P] per-(TC, port) totals
        (dummy pad column sliced off)."""
        return seg_sum(vals, idx, QPpad) \
            .reshape(vals.shape[:-1] + (N_QOS, Ppad))[..., :P]

    def gQ(x_qp, idx):
        """Gather a per-(TC, port) array [.., Q, P] back to flows: zero
        pad column for unused slots, flatten, fancy-gather at the flat
        (tc, port) indices (``idx`` [F] or [S, F])."""
        pad = xp.zeros(x_qp.shape[:-1] + (1,), x_qp.dtype)
        xf = xp.concatenate([x_qp, pad], -1)
        return xf.reshape(xf.shape[:-2] + (QPpad,))[..., idx]

    bpt = f(1e9 / 8.0 * dt * 1e-6)       # bytes per (Gbps * tick)
    fdt = f(dt)
    zero, one, tiny = f(0.0), f(1.0), f(1e-30)
    half, inf = f(0.5), f(np.inf)
    eps_q = f(1e-9)
    arangeF = xp.arange(F, dtype=xp.int32)
    budget = p["gbps"] * bpt
    budget_crumb = budget * f(1e-6)
    buf_tc = p["buf"][..., None, None]
    kmin_th = p["kmin"][..., None] * buf_tc
    ecn_on = p["ecn_en"] > 0.5
    can_assert = p["can_assert"] > 0.5
    sxoff = p["sw_xoff"][..., None]
    sxon = p["sw_xon"][..., None]
    onoff = p["off_us"] > zero
    period = xp.where(onoff, p["on_us"] + p["off_us"], one)
    jet = p["jet"] > 0.5
    avail_dram = xp.maximum(zero, p["membw"] - p["cpu_bw"])
    jet_cap = xp.minimum(p["pcie"], p["line1"] * 4.0) * bpt
    strag_share = xp.where(jet, p["sfrac"], zero)
    inv_knee = one / (p["knee"] * p["ddio"])
    rx_pfc_en = p["pfc_en"] > 0.5
    wm_en = p["wm_cnp"] > 0.5
    linecap = xp.minimum(p["line"], p["cap"])
    if wrr:
        quantaQ = p["quanta"][..., None]            # [.., Q, 1]
        is_wrr = (p["sched"] == 1)[..., None, None]  # [.., 1, 1]
    if host_tc:
        hpfc_b = (p["hpfc"] > half)[..., None, :]   # [.., 1, R]
        rx_pfc_tc = rx_pfc_en[..., None, :]
        xoffQ = p["xoff"][..., None, :]
        xonQ = p["xon"][..., None, :]
    if any_cc:
        # algorithm lanes (CcConfig.code: 0 dcqcn, 1 timely, 2 hpcc)
        is_dcqcn = p["cc_algo"] == 0
        timely_m = p["cc_algo"] == 1
        hpcc_m = p["cc_algo"] == 2
        inv_brtt = one / p["base_rtt"]              # [.., F]
        u_floor = f(0.01)
        # padded per-port budget for the telemetry gathers (column P =
        # "slot unused", budget 0 -> the leg drops out, as the dense
        # engine's zero one-hot columns)
        budget_pad = xp.concatenate(
            [budget, xp.zeros(budget.shape[:-1] + (1,), budget.dtype)],
            -1)
        po_flat = st["port_of"].reshape(S * F)      # [S*F] flat slots

    def cut(s, fire):
        """DCQCN on_cnp for flows where ``fire`` holds."""
        s = dict(s)
        s["rt"] = xp.where(fire, s["rc"], s["rt"])
        s["rc"] = xp.where(
            fire, xp.maximum(p["minr"], s["rc"] * (1.0 - s["alpha"] / 2.0)),
            s["rc"])
        s["alpha"] = xp.where(
            fire, xp.minimum(one, (1.0 - p["g"]) * s["alpha"] + p["g"]),
            s["alpha"])
        for k in ("t_us", "byts", "t_stage", "b_stage", "a_tus"):
            s[k] = xp.where(fire, zero, s[k])
        return s

    def qtc_all(qm):
        """Full per-(TC, port) occupancy [.., Q, P]: one scatter of all
        S*F slot entries (each port hosts exactly one slot's entries)."""
        v = qm[..., 0, :, :]
        return segQ(v.reshape(v.shape[:-2] + (S * F,)), st["qp_flat"])

    def drain(s, k, upf=None):
        """Stage-k ports forward up to rate*dt — the dense drain's
        grants on the slot-k row.  Returns per-flow drained [.., 2, F]
        (the slot row IS the port-level provenance)."""
        qm = s["qm"]
        qrow = qm[..., :, k, :]                   # [.., 2, F]
        qtc = segQ(qrow[..., 0, :], st["qp_idx"][k])
        budget0 = budget if upf is None else budget * upf
        can_q = st["stage"][k] & ~s["paused"] & (qtc > zero)
        frac_q = fused.priority_grants(
            xp, qtc, can_q if impl == "ref"
            else xp.where(can_q, one, zero),
            budget0, budget_crumb, one, zero, impl=impl)
        if wrr:
            rem = xp.where(can_q, qtc, zero)
            alloc = xp.zeros_like(qtc)
            bl = budget0
            for _ in range(N_QOS):
                wq = xp.where(rem > zero, quantaQ, zero)
                wsum = wq.sum(-2)                 # [.., P]
                share = bl[..., None, :] * wq \
                    / xp.maximum(wsum, tiny)[..., None, :]
                take = xp.minimum(share, rem)
                alloc = alloc + take
                rem = rem - take
                bl = bl - take.sum(-2)
                bl = xp.where(bl < budget_crumb, zero, bl)
            frac_wrr = xp.where(qtc > zero,
                                alloc / xp.maximum(qtc, tiny), zero)
            frac_q = xp.where(is_wrr, frac_wrr, frac_q)
        frac_f = gQ(frac_q, st["qp_idx"][k])      # [.., F]
        out = qrow * frac_f[..., None, :]
        left = qrow - out
        # sub-1e-9 residues vanish with their marks (dense drain)
        can_f = gQ(xp.where(can_q, one, zero), st["qp_idx"][k])
        gone = (can_f > half) & (left[..., 0, :] < eps_q)
        left = xp.where(gone[..., None, :], zero, left)
        s["qm"] = qm - (qrow - left)[..., :, None, :] * st["row_oh"][k]
        return s, out

    def enqueue(s, A, k):
        """Batch-enqueue stage-k output ``A`` [.., 2, F] at each flow's
        next slot: proportional split of the class partition, one ECN
        knee per (port, TC) against pre-batch occupancy."""
        dq = st["dq_idx"][k]
        qtc = qtc_all(s["qm"])
        tot_q = segQ(A[..., 0, :], dq)
        space_q = xp.maximum(buf_tc - qtc, zero)
        scale_q = xp.where(tot_q > space_q,
                           space_q / xp.maximum(tot_q, tiny), one)
        take = A * gQ(scale_q, dq)[..., None, :]
        lost = (A - take)[..., 0, :]
        s["inj_lo"] = s["inj_lo"] - lost
        s["sw_dropped"] = s["sw_dropped"] + lost.sum(-1)
        mark_q = ecn_on[..., None, :] & (qtc > kmin_th)
        mark_f = gQ(xp.where(mark_q, one, zero), dq)
        dm = xp.where(mark_f > half,
                      take[..., 0, :] - take[..., 1, :], zero)
        s["ecn_marked"] = s["ecn_marked"] + dm.sum(-1)
        s["qm"] = s["qm"] + \
            (take + dm[..., None, :] * st["selm"])[..., :, None, :] \
            * st["nxt_oh"][k]
        return s

    fold_at = f(65536.0)

    def fold(s, hi, lo):
        full = xp.abs(s[lo]) >= fold_at
        s[hi] = s[hi] + xp.where(full, s[lo], zero)
        s[lo] = xp.where(full, zero, s[lo])

    def step(s, t, it=None):
        if it is None:
            it = t
        s = dict(s)
        now = (xp.asarray(t, dtype) + one) * fdt
        fold(s, "injected", "inj_lo")
        fold(s, "delivered", "deliv_lo")

        # ---- 0. link failure / flap windows ------------------------------- #
        upf = None
        if fail:
            downP = (t >= p["fail_at"]) & (t < p["fail_until"])   # [.., P]
            edgeP = t == p["fail_at"]
            if flap:
                since = t - p["flap_start"]
                live = t >= p["flap_start"]
                downP = downP | (live
                                 & (since % p["flap_period"]
                                    < p["flap_down"]))
                edgeP = edgeP | (live & (since % p["flap_period"] == 0))
            upf = xp.where(downP, zero, one)
            failf = xp.where(edgeP, one, zero)
            failp = xp.concatenate(
                [failf, xp.zeros(failf.shape[:-1] + (1,), failf.dtype)],
                -1)
            fail_sf = failp[..., st["port_of"]]               # [.., S, F]
            lostF = (s["qm"][..., 0, :, :] * fail_sf).sum(-2)
            s["inj_lo"] = s["inj_lo"] - lostF
            s["sw_dropped"] = s["sw_dropped"] + lostF.sum(-1)
            s["qm"] = s["qm"] * (one - fail_sf)[..., None, :, :]

        # ---- 1. senders: DCQCN advance + offer ---------------------------- #
        adv = now > p["start"]
        # the DCQCN timer machinery only moves DCQCN-lane flows; the CC
        # block after forwarding writes the timely/hpcc rates instead
        dadv = (adv & is_dcqcn) if any_cc else adv
        adv_dt = xp.where(dadv, fdt, zero)
        a_tus = s["a_tus"] + adv_dt
        a_fire = dadv & (a_tus >= p["a_tmr"])
        s["alpha"] = xp.where(a_fire, (1.0 - p["g"]) * s["alpha"],
                              s["alpha"])
        s["a_tus"] = xp.where(a_fire, zero, a_tus)
        t_us = s["t_us"] + adv_dt
        byts = xp.where(dadv, s["byts"] + s["rc"] * bpt, s["byts"])
        t_fire = dadv & (t_us >= p["r_tmr"])
        s["t_stage"] = s["t_stage"] + t_fire
        s["t_us"] = xp.where(t_fire, zero, t_us)
        b_fire = dadv & (byts >= p["bctr"])
        s["b_stage"] = s["b_stage"] + b_fire
        s["byts"] = xp.where(b_fire, zero, byts)
        fired = t_fire | b_fire
        stage = xp.minimum(s["t_stage"], s["b_stage"])
        s["rt"] = xp.where(fired & (stage == p["fth"]),
                           xp.minimum(p["dline"], s["rt"] + p["ai"]),
                           s["rt"])
        s["rt"] = xp.where(fired & (stage > p["fth"]),
                           xp.minimum(p["dline"], s["rt"] + p["hai"]),
                           s["rt"])
        s["rc"] = xp.where(fired,
                           xp.minimum(p["dline"],
                                      0.5 * (s["rc"] + s["rt"])),
                           s["rc"])

        gbps = xp.minimum(s["rc"], linecap)
        room = xp.maximum(p["burst"] - (s["injected"] + s["inj_lo"]), zero)
        active = adv & (~onoff | (xp.fmod(now - p["start"], period)
                                  < p["on_us"]))
        offer = xp.where(active, xp.minimum(gbps * bpt, room), zero)
        # source-side backpressure at the NIC queue (slot 0's port)
        qtcI = qtc_all(s["qm"])
        tot_q = segQ(offer, st["qp_idx"][0])
        space_q = xp.maximum(buf_tc - qtcI, zero)
        scale_q = xp.where(tot_q > space_q,
                           space_q / xp.maximum(tot_q, tiny), one)
        take_f = offer * gQ(scale_q, st["qp_idx"][0])
        s["inj_lo"] = s["inj_lo"] + take_f
        s["qm"] = s["qm"] + take_f[..., None, None, :] * st["sel_inj"]

        # ---- 2. tier-ordered forwarding (cut-through within the tick) ---- #
        out = None
        if any_cc:
            txPp = xp.zeros(budget_pad.shape, budget_pad.dtype)
        for k in range(S):
            if not st["stage_any"][k]:
                continue
            s, out = drain(s, k, upf)
            if any_cc:
                # per-tick drained bytes per port: the txRate leg of the
                # HPCC-style INT signal (run_fabric's tick_tx)
                txPp = txPp + seg_sum(out[..., 0, :], st["port_of"][k],
                                      Ppad)
            if k in (1, 2):
                # fabric-uplink tx accounting (leaf->spine, spine->ss)
                txk = seg_sum(out[..., 0, :], st["port_of"][k], Ppad)
                s["tx"] = s["tx"] + txk[..., :P]
            if k < S - 1:
                s = enqueue(s, out, k)
        arr_b = out[..., 0, :]
        arr_m = out[..., 1, :]

        # ---- 2.2 delay/INT telemetry -> CC zoo updates -------------------- #
        # end-of-forwarding queue state along each flow's route slots,
        # folded into rtt = base + sum(q/budget) and util = max per-hop
        # (txRate/B + qlen/(B*T)) — the dense engine's leg loop as
        # padded gathers at port_of[k].  Slots are visited in tier
        # order, so on a 2-tier grid the qd accumulation order matches
        # the dense legs (occ0, occ1, occ2, occ3) term for term.
        if any_cc:
            v = s["qm"][..., 0, :, :]
            qPp = seg_sum(v.reshape(v.shape[:-2] + (S * F,)), po_flat,
                          Ppad)                               # [.., P+1]
            qd = zero
            util = zero
            for k in range(S):
                if not st["stage_any"][k]:
                    continue
                po_k = st["port_of"][k]                       # [F]
                q_l = qPp[..., po_k]
                tx_l = txPp[..., po_k]
                b_l = budget_pad[..., po_k]
                ok = b_l > zero
                qd = qd + xp.where(ok, q_l / xp.maximum(b_l, tiny), zero)
                u_l = xp.where(ok, (tx_l + q_l * (fdt * inv_brtt))
                               / xp.maximum(b_l, tiny), zero)
                util = xp.maximum(util, u_l)
            rtt = p["base_rtt"] + qd * fdt
            ctus = s["cc_tus"] + fdt
            fire = ctus >= p["cc_upd"]
            s["cc_tus"] = xp.where(fire, zero, ctus)
            # Timely: smoothed RTT gradient picks the branch
            ft = fire & timely_m
            diff = rtt - s["prev_rtt"]
            rd_new = (1.0 - p["tl_a"]) * s["rtt_diff"] + p["tl_a"] * diff
            s["prev_rtt"] = xp.where(ft, rtt, s["prev_rtt"])
            s["rtt_diff"] = xp.where(ft, rd_new, s["rtt_diff"])
            grad = rd_new * inv_brtt
            rc = s["rc"]
            r_tim = xp.where(
                rtt < p["t_low"], rc + p["tl_add"],
                xp.where(rtt > p["t_high"],
                         rc * (one - p["tl_beta"]
                               * (one - p["t_high"] / rtt)),
                         xp.where(grad <= zero, rc + p["tl_add"],
                                  rc * xp.maximum(
                                      zero, one - p["tl_beta"] * grad))))
            rc_tim = xp.minimum(p["line"],
                                xp.maximum(p["cc_minr"], r_tim))
            # HPCC: drive max per-hop utilization toward eta
            fh = fire & hpcc_m
            mult = xp.clip(p["hp_eta"] / xp.maximum(util, u_floor),
                           half, f(2.0))
            rc_hp = xp.minimum(p["line"],
                               xp.maximum(p["cc_minr"],
                                          rc * mult + p["hp_ai"]))
            s["rc"] = xp.where(ft, rc_tim, xp.where(fh, rc_hp, rc))

        # ---- 3. receivers advance one tick (HostDatapath, stacked) -------- #
        arr_rb = st["recv_onehot"] * arr_b[..., None, :]
        arr_cr = (st["cls_recv"] * arr_b[..., None, None, :]).sum(-1)
        arr_tot = arr_cr.sum(-2)
        space_r = xp.maximum(p["rnic_buf"] - s["qos_q"].sum(-2), zero)
        acc_cr = fused.priority_admit(xp, arr_cr, space_r, impl=impl)
        accepted = acc_cr[..., 0, :]
        for q_i in range(1, N_QOS):
            accepted = accepted + acc_cr[..., q_i, :]
        s["rnic_drop"] = s["rnic_drop"] + (arr_tot - accepted)
        s["qos_q"] = s["qos_q"] + acc_cr

        ws = p["qp_bytes"] + s["resident"]
        miss = xp.clip((ws - p["ddio"]) * inv_knee, zero, one)
        s["miss_sum"] = s["miss_sum"] + xp.where(jet, zero, miss)
        ddio_bw = xp.where(miss > 1e-9,
                           xp.minimum(p["pcie"],
                                      avail_dram / (2.0 * miss + tiny)),
                           p["pcie"])
        budget_r = xp.where(jet, jet_cap, ddio_bw * bpt)
        pool_free = xp.maximum(zero, p["pool"] - s["resident"])
        spill = jet & (pool_free / p["pool"] < p["safe"])
        pf = xp.where(jet, pool_free, inf)
        drained = pool_drained = fallback = zero
        new_q = []
        for q_i in range(N_QOS):
            qq = s["qos_q"][..., q_i, :]
            take = xp.minimum(xp.minimum(qq, budget_r), pf)
            if q_i == N_QOS - 1:        # LOW spills instead of waiting
                take = xp.where(spill, xp.minimum(qq, budget_r), take)
                spilled = xp.where(spill, take, zero)
            else:
                spilled = zero
            pf = pf - (take - spilled)
            budget_r = budget_r - take
            new_q.append(qq - take)
            drained = drained + take
            pool_drained = pool_drained + (take - spilled)
            fallback = fallback + spilled
        s["qos_q"] = xp.stack(new_q, -2)
        s["nic_dram"] = s["nic_dram"] + \
            xp.where(jet, fallback, drained * 2.0 * miss)
        s["mem_fb"] = s["mem_fb"] + fallback
        strag_part = pool_drained * strag_share
        parts = xp.stack([pool_drained * (1.0 - strag_share), strag_part],
                         -2)
        s["ring"] = ring_set(s["ring"], it % H, parts)
        s["resident"] = s["resident"] + pool_drained
        s["strag_res"] = s["strag_res"] + strag_part
        s["drained"] = s["drained"] + drained

        idx = (it - p["d2"]) % H                  # [.., 2, R]
        r2 = xp.take_along_axis(s["ring"], idx[..., None, :, :],
                                -3)[..., 0, :, :]
        r2 = xp.where(it >= p["d2"], r2, zero)
        for j, is_strag in ((0, False), (1, True)):
            r = r2[..., j, :]
            void = xp.minimum(r, s["esc_debt"])
            s["esc_debt"] = s["esc_debt"] - void
            r = r - void
            repay = xp.minimum(void, s["repl_debt"])
            s["repl_debt"] = s["repl_debt"] - repay
            s["repl_mem"] = xp.maximum(zero, s["repl_mem"] - repay)
            s["resident"] = xp.maximum(zero, s["resident"] - r)
            if is_strag:
                s["strag_res"] = xp.maximum(zero, s["strag_res"] - r)

        # Jet escape ladder (paper Algorithm 1)
        avail = xp.maximum(zero, p["pool"] - s["resident"]) / p["pool"]
        esc_on = jet & (avail < p["safe"])
        can_rep = s["repl_mem"] < p["mem_esc"]
        x_rep = xp.where(esc_on & can_rep,
                         xp.maximum(zero,
                                    xp.minimum(s["strag_res"],
                                               p["mem_esc"]
                                               - s["repl_mem"])),
                         zero)
        s["resident"] = s["resident"] - x_rep
        s["strag_res"] = s["strag_res"] - x_rep
        s["esc_debt"] = s["esc_debt"] + x_rep
        s["repl_debt"] = s["repl_debt"] + x_rep
        s["repl_mem"] = s["repl_mem"] + x_rep
        s["esc_dram"] = s["esc_dram"] + 0.1 * x_rep
        s["replaces"] = s["replaces"] + (x_rep > zero)
        x_cop = xp.where(esc_on & ~can_rep, s["strag_res"], zero)
        s["resident"] = s["resident"] - x_cop
        s["strag_res"] = s["strag_res"] - x_cop
        s["esc_debt"] = s["esc_debt"] + x_cop
        s["esc_dram"] = s["esc_dram"] + x_cop
        s["copies"] = s["copies"] + (x_cop > zero)
        avail2 = xp.maximum(zero, p["pool"] - s["resident"]) / p["pool"]
        in_danger = esc_on & (avail2 < p["danger"])
        s["ecn_tus"] = xp.where(in_danger, s["ecn_tus"] + fdt, s["ecn_tus"])
        esc_fire = in_danger & (s["ecn_tus"] >= p["cnp_iv"])
        s["ecn_tus"] = xp.where(esc_fire, zero, s["ecn_tus"])
        s["cnps"] = s["cnps"] + esc_fire
        s["ecns"] = s["ecns"] + esc_fire
        s["pool_sum"] = s["pool_sum"] + xp.where(jet, s["resident"], zero)
        s["pool_peak"] = xp.maximum(s["pool_peak"],
                                    xp.where(jet, s["resident"], zero))

        # receiver congestion signalling
        q_frac = s["qos_q"].sum(-2) / p["rnic_buf"]
        if host_tc:
            frac_c = s["qos_q"] / (p["rnic_buf"] / f(N_QOS))[..., None, :]
            sel = xp.where(hpfc_b, frac_c, q_frac[..., None, :])
            s["pfc"] = rx_pfc_tc & xp.where(s["pfc"], sel >= xonQ,
                                            sel > xoffQ)
            pfc_any = s["pfc"].any(-2)
        else:
            s["pfc"] = rx_pfc_en & xp.where(s["pfc"], q_frac >= p["xon"],
                                            q_frac > p["xoff"])
            pfc_any = s["pfc"]
        s["pfc_us"] = s["pfc_us"] + xp.where(pfc_any, fdt, zero)
        cnp_tus = s["cnp_tus"] + fdt
        wm_fire = wm_en & (q_frac > p["ecn_th"]) \
            & (cnp_tus >= p["cnp_iv"])
        s["cnp_tus"] = xp.where(wm_fire, zero, cnp_tus)
        s["cnps"] = s["cnps"] + wm_fire

        # ---- 4. feedback routes back to the senders ----------------------- #
        share_cr = xp.where(arr_cr > zero,
                            acc_cr / xp.maximum(arr_cr, tiny), zero)
        deliv = arr_b * share_cr[..., st["cls_of"], st["recv_of"]]
        s["deliv_lo"] = s["deliv_lo"] + deliv
        s["inj_lo"] = s["inj_lo"] - (arr_b - deliv)
        s["completion"] = xp.where(
            xp.isinf(s["completion"])
            & (s["delivered"] + s["deliv_lo"] >= p["burst_done"]),
            now, s["completion"])

        has_arr = arr_tot > zero
        heavy_new = xp.argmax(arr_rb, -1).astype(xp.int32)
        s["heavy"] = xp.where(has_arr, heavy_new, s["heavy"])
        is_heavy = arangeF == s["heavy"][..., st["recv_of"]]
        f_esc = is_heavy & esc_fire[..., st["recv_of"]]
        f_wm = is_heavy & wm_fire[..., st["recv_of"]]
        s["backlog"] = s["backlog"] + arr_m
        pace_tus = s["pace_tus"] + fdt
        pace_fire = (s["backlog"] > zero) & (pace_tus >= p["cnp_iv_f"])
        s["pace_tus"] = xp.where(pace_fire, zero, pace_tus)
        s["backlog"] = xp.where(pace_fire, zero, s["backlog"])
        fires = xp.stack([xp.where(f_esc, one, zero),
                          xp.where(f_wm, one, zero),
                          xp.where(pace_fire, one, zero)], -2)
        s["cring"] = ring_set(s["cring"], it % Hc, fires)
        cidx = (it - p["cnp_dly"]) % Hc
        due = xp.take_along_axis(s["cring"], cidx[..., None, None, :],
                                 -3)[..., 0, :, :]
        for j in range(3):
            fire_c = due[..., j, :] > half
            if any_cc:
                # timely/hpcc ignore CNPs (CongestionControl.on_cnp)
                fire_c = fire_c & is_dcqcn
            s = cut(s, fire_c)

        # ---- 5. per-priority PFC pause propagation ------------------------ #
        q0s = s["qm"][..., 0, :, :]                           # [.., S, F]
        qtcP = qtc_all(s["qm"])
        frac_occ = qtcP / buf_tc
        s["asserted"] = can_assert[..., None, :] & \
            xp.where(s["asserted"], frac_occ >= sxon, frac_occ > sxoff)
        # a slot contributes a pause iff its flow's class is asserted at
        # its own port; the pause targets the slot's ingress port on the
        # flow's class — one gather + one scatter over the S*F entries
        af = gQ(xp.where(s["asserted"], one, zero), st["qp_idx"])
        contrib = xp.where((af > half) & (q0s > zero), one, zero)
        link_paused = segQ(
            contrib.reshape(contrib.shape[:-2] + (S * F,)),
            st["pp_flat"]) > zero                             # [.., Q, P]
        if "ex_f" in st:
            # candidate-ingress semantics under failure schedules: a
            # shallow flow's last-hop (slot 5) contribution also pauses
            # its non-chosen candidate downlinks (the scalar driver's
            # OutputPort.static_ingress targeting)
            extra = contrib[..., 5, :][..., st["ex_f"]]       # [.., E]
            link_paused = link_paused | (segQ(extra, st["ex_flat"])
                                         > zero)
        link_any = link_paused.any(-2)
        s["pause_us"] = s["pause_us"] + xp.where(link_any, fdt, zero)
        s["pause_tc_us"] = s["pause_tc_us"] + \
            xp.where(link_paused, fdt, zero)
        s["ever_paused"] = s["ever_paused"] | link_any
        rx_gate = s["pfc"][..., st["owner_clamp"]] & st["owner_valid"]
        if host_tc:
            s["paused"] = link_paused | rx_gate
        else:
            s["paused"] = link_paused | rx_gate[..., None, :]
        return s

    return step


def _init_state(xp, lead, fsp: FabricSweepParams, p, dtype):
    """Zero/steady-state carry; ``lead`` is () under vmap, (G,) for numpy."""
    F, P, R, H = (fsp.n_flows, fsp.n_ports, fsp.n_recv, fsp.ring_len)
    Hc = fsp.cnp_ring
    z = lambda *sh: xp.zeros(lead + sh, dtype)       # noqa: E731
    s = {
        # flows
        "rc": p["dline"] + z(F), "rt": p["dline"] + z(F),
        "alpha": xp.ones(lead + (F,), dtype),
        "t_us": z(F), "byts": z(F), "t_stage": z(F), "b_stage": z(F),
        "a_tus": z(F), "injected": z(F), "delivered": z(F),
        "inj_lo": z(F), "deliv_lo": z(F),
        "completion": xp.full(lead + (F,), np.inf, dtype),
        "backlog": z(F),
        # immediate first paced CNP, as in the scalar driver
        "pace_tus": xp.full(lead + (F,), np.inf, dtype),
        # CNP propagation ring (slot-major, 3 notification sources)
        "cring": z(Hc, 3, F),
        # ports (axis -3: 0 = queued bytes, 1 = ECN-marked subset);
        # sparse grids queue per (stage slot, flow) instead of
        # (port, flow); PFC state stays classed [Q, P] in both layouts
        "qm": z(2, _STAGES_SP if fsp.sparse else P, F),
        "asserted": xp.zeros(lead + (N_QOS, P), bool),
        "paused": xp.zeros(lead + (N_QOS, P), bool),
        "pause_us": z(P),
        "pause_tc_us": z(N_QOS, P),
        "ever_paused": xp.zeros(lead + (P,), bool),
        # receivers ("qos_q" = HostDatapath's per-class RNIC buffer)
        "qos_q": z(N_QOS, R), "resident": z(R), "strag_res": z(R),
        "esc_debt": z(R), "repl_debt": z(R), "repl_mem": z(R),
        "rnic_drop": z(R), "drained": z(R), "nic_dram": z(R),
        "mem_fb": z(R),
        "esc_dram": z(R), "miss_sum": z(R), "pool_sum": z(R),
        "pool_peak": z(R), "cnps": z(R), "ecns": z(R), "replaces": z(R),
        "copies": z(R), "pfc_us": z(R), "ecn_tus": z(R),
        "cnp_tus": p["cnp_iv"] + z(R),   # allow an immediate first CNP
        # per-class pause state when any point runs per-TC host PFC
        # (legacy points keep every row in lockstep)
        "pfc": xp.zeros(lead + ((N_QOS, R) if fsp.host_tc else (R,)),
                        bool),
        "ring": z(H, 2, R),     # slot-major; axis -2: base / straggler
        "heavy": xp.full(lead + (R,), -1, xp.int32),
        # fleet counters
        "ecn_marked": z(), "sw_dropped": z(),
    }
    if fsp.sparse:
        # per-uplink carried bytes (fabric_uplinks utilization metrics)
        s["tx"] = z(P)
    if fsp.dyn_route:
        # routing carry: current spine choice (static hash seed), reroute
        # counts and per-uplink carried bytes
        s["route"] = xp.zeros(lead + (F,), xp.int32) \
            + xp.asarray(fsp.init_spine)
        s["reroutes"] = z(F)
        s["tx"] = z(P)
        if fsp.n_spines:
            # idle-gap flowlet state: per-flow flowlet index + last
            # active tick (far past, so the first injection opens a
            # flowlet — run_fabric's -(1 << 30) sentinel)
            s["flet_k"] = xp.zeros(lead + (F,), xp.int32)
            s["flet_last"] = xp.full(lead + (F,), -(1 << 30), xp.int32)
    if fsp.settle_ring > 1:
        s["sring"] = z(fsp.settle_ring, 2, F)
    if fsp.any_cc:
        # delay/INT controller carries (TimelyRate/HpccRate)
        s["prev_rtt"] = p["base_rtt"] + z(F)
        s["rtt_diff"] = z(F)
        s["cc_tus"] = z(F)
    if fsp.any_msg:
        # message-layer carries: started/completed counts, start-time
        # ring, latency sum and the fixed-bucket log histogram
        s["m_hw"] = xp.zeros(lead + (F,), xp.int32)
        s["m_done"] = xp.zeros(lead + (F,), xp.int32)
        s["mring"] = z(fsp.msg_ring, F)
        s["m_lat"] = z(F)
        s["m_last"] = z(F)
        s["m_hist"] = z(HIST_BUCKETS, F)
        s["m_over"] = z(F)
    if fsp.any_flt:
        # fault-layer carries: the per-flow recovery ledger (lost bytes,
        # RTO timer/backoff stage, go-back-N gap flag), retransmit and
        # fault-drop accumulators, crash-recovery stamps and the
        # switch-side link-pause mask (crash rebuilds)
        s["lost"] = z(F)
        s["rto_t"] = xp.zeros(lead + (F,), xp.int32)
        s["rto_k"] = xp.zeros(lead + (F,), xp.int32)
        s["gapped"] = xp.zeros(lead + (F,), bool)
        s["retx"] = z(F)
        s["flt_drop"] = z()
        s["crash_rec"] = xp.full(lead + (R,), np.inf, dtype)
        s["lpause"] = xp.zeros(lead + (N_QOS, P), bool)
        s["deadlock"] = z()
    return s


def _static(fsp: FabricSweepParams, xp, dtype):
    P, F = fsp.n_ports, fsp.n_flows
    owner = fsp.owner_recv
    cls_onehot = np.zeros((N_QOS, F))
    cls_onehot[fsp.qos_of, np.arange(F)] = 1.0
    out = {
        "cls_of": xp.asarray(fsp.qos_of),
        "cls_recv": xp.asarray(cls_onehot[:, None, :]
                               * fsp.recv_onehot[None, :, :], dtype),
        "stage": xp.asarray(fsp.stage_mask),
        "recv_onehot": xp.asarray(fsp.recv_onehot, dtype),
        "recv_of": xp.asarray(fsp.recv_of),
        "owner_clamp": xp.asarray(np.maximum(owner, 0)),
        "owner_valid": xp.asarray(owner >= 0),
    }
    if fsp.sparse:
        # segmented-incidence gather/scatter indices: flat
        # tc * (P + 1) + port addresses with column P the "slot unused"
        # dummy, so every per-(port, TC) reduction is one scatter over
        # the S*F slot entries and every read back one flat gather
        S = _STAGES_SP
        Ppad = P + 1
        po = fsp.port_of.astype(np.int64)                 # [S, F]
        qos = fsp.qos_of.astype(np.int64)                 # [F]
        qp = qos[None, :] * Ppad + po
        pp = qos[None, :] * Ppad + fsp.prv_port.astype(np.int64)
        cols = np.arange(F)
        dq_idx, nxt_oh = [], []
        for k in range(S - 1):
            nx = fsp.nxt_slot[k].astype(np.int64)         # [F]
            tp = po[np.minimum(nx, S - 1), cols]
            tp = np.where(nx < S, tp, P)
            dq_idx.append(xp.asarray((qos * Ppad + tp).astype(np.int32)))
            nxt_oh.append(xp.asarray(
                (nx[None, :] == np.arange(S)[:, None]).astype(np.float64),
                dtype))
        sel_inj = np.zeros((2, S, 1))
        sel_inj[0, 0, 0] = 1.0
        selm = np.zeros((2, 1))
        selm[1, 0] = 1.0
        out.update({
            "qp_idx": xp.asarray(qp.astype(np.int32)),
            "qp_flat": xp.asarray(qp.reshape(-1).astype(np.int32)),
            "pp_flat": xp.asarray(pp.reshape(-1).astype(np.int32)),
            "port_of": xp.asarray(fsp.port_of),
            "dq_idx": dq_idx,
            "nxt_oh": nxt_oh,
            "row_oh": [xp.asarray(np.eye(S)[k][:, None], dtype)
                       for k in range(S)],
            "sel_inj": xp.asarray(sel_inj, dtype),
            "selm": xp.asarray(selm, dtype),
            # trace-time skip of slots with no ports (a 2-tier sparse
            # grid leaves the super-spine slots 2-3 empty)
            "stage_any": [bool(fsp.stage_mask[k].any())
                          for k in range(S)],
        })
        if fsp.pause_extra is not None:
            # candidate-ingress pause pairs (failure schedules): gather
            # the last-hop contribution of flow ex_f, scatter it onto
            # its extra candidate downlink on the flow's class
            exf = fsp.pause_extra[0].astype(np.int64)
            exp_ = fsp.pause_extra[1].astype(np.int64)
            out["ex_f"] = xp.asarray(exf.astype(np.int32))
            out["ex_flat"] = xp.asarray(
                (qos[exf] * Ppad + exp_).astype(np.int32))
        return out
    sel = np.zeros((2, 2, 1, 1))
    sel[0, 0], sel[1, 1] = 1.0, 1.0
    out.update({
        "occ": [xp.asarray(a, dtype) for a in fsp.occ],
        "dest": [xp.asarray(a, dtype) for a in fsp.dest],
        "prev_mat": xp.asarray(fsp.prev_onehot.reshape(P * F, P), dtype),
        "sel0": xp.asarray(sel[0], dtype),
        "sel1": xp.asarray(sel[1], dtype),
    })
    if fsp.dyn_route:
        out["upP"] = xp.asarray(fsp.upP, dtype)
        out["dnP"] = xp.asarray(fsp.dnP, dtype)
        out["candS"] = xp.asarray(fsp.candS)
        out["T1"] = xp.asarray(fsp.T1, dtype)
    if fsp.any_flt:
        # deadlock-watchdog scatter: port -> flattened (u, v) node pair
        out["dl_E"] = xp.asarray(
            fused.pause_pair_onehot(fsp.port_keys), dtype)
    return out


# --------------------------------------------------------------------------- #
# Results
# --------------------------------------------------------------------------- #
def _results(s, fsp: FabricSweepParams) -> Dict[str, np.ndarray]:
    sim_us = fsp.ticks * fsp.dt_us
    per_gbps = 8.0 / (sim_us * 1e-6) / 1e9
    deliv = np.asarray(s["delivered"], np.float64) \
        + np.asarray(s["deliv_lo"], np.float64)
    goodput = deliv * per_gbps
    comp = np.asarray(s["completion"], np.float64)
    tags = np.array(fsp.flow_tags)
    inc_mask = (tags == "incast")[None, :] \
        & np.isfinite(fsp.pvals["burst"])
    inc_comp = np.where(
        inc_mask.any(-1),
        np.where(inc_mask, comp, -np.inf).max(-1), np.nan)
    vic = tags == "victim"
    G = fsp.n_points
    victim = goodput[:, vic].mean(-1) if vic.any() else np.zeros(G)
    out = {
        "flow_goodput_gbps": goodput,
        "flow_delivered_bytes": deliv,
        "flow_completion_us": comp,
        "incast_completion_us": inc_comp,
        "victim_goodput_gbps": victim,
        "has_victim": np.full(G, bool(vic.any())),
        "pause_fanout": np.asarray(s["ever_paused"]).sum(-1),
        "pause_total_us": np.asarray(s["pause_us"], np.float64).sum(-1),
        # per-priority pause budget: [G, Q] microseconds summed over
        # ingress links (matches summing FabricResult.pause_tc_us per tc)
        "pause_tc_total_us": np.asarray(s["pause_tc_us"],
                                        np.float64).sum(-1),
        # routing-aware PFC-storm metric: per-TC pause fan-out over the
        # candidate ingress sets (FabricResult.pause_tc_fanout /
        # n_pausable_links / pause_storm)
        "pause_tc_fanout": (np.asarray(s["pause_tc_us"], np.float64)
                            > 0.0).sum(-1),
        "ecn_marked_bytes": np.asarray(s["ecn_marked"], np.float64),
        "switch_dropped_bytes": np.asarray(s["sw_dropped"], np.float64),
        "recv_goodput_gbps": np.asarray(s["drained"], np.float64)
        * per_gbps,
        "recv_cnp_count": np.asarray(s["cnps"], np.float64),
        "recv_escape_ecn": np.asarray(s["ecns"], np.float64),
        "recv_pfc_pause_us": np.asarray(s["pfc_us"], np.float64),
        "recv_rnic_dropped_bytes": np.asarray(s["rnic_drop"], np.float64),
        "recv_mem_fallback_bytes": np.asarray(s["mem_fb"], np.float64),
    }
    # candidate ingress links that can ever receive a pause = ports with
    # ingress support (the scalar driver's `pausable` set exactly);
    # links down for the entire window can neither pause nor carry, so
    # they leave the storm/imbalance denominators (FabricResult's
    # zero-uptime exclusion, mirrored per grid point)
    if fsp.sparse:
        pmask = np.zeros(fsp.n_ports, bool)
        pmask[fsp.prv_port[fsp.prv_port < fsp.n_ports]] = True
        if fsp.pausable_extra is not None:
            # candidate hops of shallow flows under failure schedules
            pmask[fsp.pausable_extra] = True
    elif fsp.prev_onehot.size:
        pmask = fsp.prev_onehot.sum((0, 1)) > 0
    else:
        pmask = np.zeros(fsp.n_ports, bool)
    if "fail_at" in fsp.pvals:
        dead = (fsp.pvals["fail_at"] <= 0) \
            & (fsp.pvals["fail_until"] >= fsp.ticks)         # [G, P]
    else:
        dead = np.zeros((G, fsp.n_ports), bool)
    n_pausable = (pmask[None, :] & ~dead).sum(-1)            # [G]
    out["n_pausable_links"] = n_pausable
    out["pause_storm"] = np.where(
        n_pausable > 0,
        out["pause_tc_fanout"].max(-1) / np.maximum(n_pausable, 1), 0.0)
    if fsp.any_flt:
        out["retransmit_bytes"] = np.asarray(s["retx"],
                                             np.float64).sum(-1)
        # faults-None points packed f_mtu=inf, so their count is 0
        out["dropped_pkts"] = np.asarray(s["flt_drop"], np.float64) \
            / fsp.pvals["f_mtu"]
        out["crash_recovery_us"] = np.asarray(s["crash_rec"], np.float64)
        # vectorized PFC-deadlock watchdog (faults.has_pause_cycle)
        out["deadlock_ticks"] = np.asarray(s["deadlock"], np.float64)
    else:
        out["retransmit_bytes"] = np.zeros(G)
        out["dropped_pkts"] = np.zeros(G)
        out["deadlock_ticks"] = np.zeros(G)
    if fsp.any_msg:
        # message-layer outputs: per-flow counts, the grid-level log
        # histogram (summed over flows) and its percentile estimates —
        # zeros wherever no messages completed (the PR 2 NaN-safety
        # convention)
        mmask = np.isfinite(fsp.pvals["m_bytes"])            # [G, F]
        cnt = np.where(mmask, np.asarray(s["m_done"], np.float64), 0.0)
        tot = cnt.sum(-1)
        hist = np.asarray(s["m_hist"], np.float64).sum(-1)   # [G, B]
        lat_sum = np.asarray(s["m_lat"], np.float64).sum(-1)
        mbytes = np.where(mmask, fsp.pvals["m_bytes"], 0.0)
        # latencies above the histogram ceiling sit in the explicit
        # overflow counter; the percentile estimator returns the bucket
        # ceiling for ranks inside the overflow mass instead of a
        # silent midpoint below the true value
        ovf = np.where(mmask, np.asarray(s["m_over"], np.float64), 0.0)
        ov_tot = ovf.sum(-1)
        out["msg_count"] = cnt
        out["msg_count_total"] = tot
        out["msg_hist"] = hist
        out["msg_overflow_count"] = ov_tot
        out["msg_p50_us"] = percentile_from_counts(hist, 50.0,
                                                   overflow=ov_tot)
        out["msg_p99_us"] = percentile_from_counts(hist, 99.0,
                                                   overflow=ov_tot)
        out["msg_p999_us"] = percentile_from_counts(hist, 99.9,
                                                    overflow=ov_tot)
        out["msg_lat_mean_us"] = np.where(
            tot > 0.0, lat_sum / np.maximum(tot, 1.0), 0.0)
        out["msg_rate_mops"] = tot / sim_us
        out["msg_goodput_gbps"] = (cnt * mbytes).sum(-1) * per_gbps
        out["msg_last_done_us"] = np.where(
            mmask, np.asarray(s["m_last"], np.float64), 0.0)
        out["has_messages"] = mmask.any(-1)
    else:
        out["msg_count_total"] = np.zeros(G)
        out["has_messages"] = np.zeros(G, bool)
    if "reroutes" in s:
        rr = np.asarray(s["reroutes"], np.float64)
        out["flow_reroutes"] = rr
        out["reroute_count"] = rr.sum(-1)
    else:
        out["reroute_count"] = np.zeros(G)
    if "tx" in s:
        # per-uplink utilization (leaf->spine ports; sparse pod grids
        # add the spine->super-spine tier — fabric_uplinks' set); links
        # dead for the whole window leave the mean/max, matching
        # FabricResult.uplink_imbalance's zero-uptime exclusion
        tx = np.asarray(s["tx"], np.float64)
        cap = fsp.pvals["gbps"] * 1e9 / 8.0 * (sim_us * 1e-6)
        util = np.where(cap > 0.0, tx / np.maximum(cap, 1e-30), 0.0)
        up_mask = (fsp.stage_mask[1] | fsp.stage_mask[2]) if fsp.sparse \
            else fsp.stage_mask[1]
        alive = up_mask[None, :] & ~dead
        out["uplink_util"] = np.where(up_mask[None, :], util, 0.0)
        if up_mask.any():
            out["uplink_util_max"] = np.where(alive, util, 0.0).max(-1)
            out["uplink_util_mean"] = np.where(alive, util, 0.0).sum(-1) \
                / np.maximum(alive.sum(-1), 1)
        else:
            out["uplink_util_max"] = np.zeros(G)
            out["uplink_util_mean"] = np.zeros(G)
    return out


# --------------------------------------------------------------------------- #
# Backends
# --------------------------------------------------------------------------- #
def _np_params(fsp: FabricSweepParams, dtype) -> Dict[str, np.ndarray]:
    p = {k: (v if v.dtype == np.int32 else v.astype(dtype))
         for k, v in fsp.pvals.items()}
    # closed-flow completion threshold, shared with the scalar driver
    # (fabric.burst_done_bytes); the split injected/delivered accumulators
    # keep float32 drift to O(1) byte, well inside the threshold
    burst = fsp.pvals["burst"]
    p["burst_done"] = np.where(
        np.isfinite(burst),
        burst - np.maximum(1e-6, 1e-4 * np.where(np.isfinite(burst),
                                                 burst, 0.0)),
        np.inf).astype(dtype)
    p["d2"] = np.stack([p.pop("d_base"), p.pop("d_strag")], -2)
    return p


def _opts(fsp: FabricSweepParams, impl: str = "ref") -> dict:
    """Trace-time capability flags for :func:`_make_step`."""
    return {"dyn": fsp.dyn_route, "wrr": fsp.any_wrr,
            "host_tc": fsp.host_tc, "Hs": fsp.settle_ring,
            "Sn": fsp.n_spines, "cc": fsp.any_cc, "msg": fsp.any_msg,
            "Lm": fsp.msg_ring, "flt": fsp.any_flt, "flap": fsp.any_flap,
            "impl": impl}


def _run_numpy(fsp: FabricSweepParams, dtype=np.float64,
               adaptive: Optional[AdaptiveConfig] = None):
    p = _np_params(fsp, dtype)
    st = _static(fsp, np, dtype)

    def ring_set(ring, idx, v):
        ring[..., idx, :, :] = v
        return ring

    mk = _make_step_sparse if fsp.sparse else _make_step
    step = mk(np, ring_set, st, p, fsp.dt_us, fsp.ring_len, dtype,
              fsp.cnp_ring, _opts(fsp))
    s = _init_state(np, (fsp.n_points,), fsp, p, dtype)
    if adaptive is None:
        for t in range(fsp.ticks):
            s = step(s, t)
    else:
        # adaptive host loop: fine step, then extrapolate over the quiet
        # stride.  The delta comparison is safe on the pre-step dict
        # because every scaled/compared key is freshly allocated by the
        # step (only ring buffers mutate in place, and rings are never
        # scaled).  k == 1 leaves the carry bit-identical to a fine tick.
        stride = fused.make_stride_fn(np, fsp, p, _opts(fsp), adaptive,
                                      dtype)
        t = it = 0
        while t < fsp.ticks:
            s1 = step(s, np.int32(t), np.int32(it))
            k = int(stride(s, s1, np.int32(t)))
            if k > 1:
                s1 = fused.macro_advance(np, s, s1, dtype(k - 1))
            s = s1
            t += k
            it += 1
        res = _results(s, fsp)
        res["adaptive_iterations"] = np.full(fsp.n_points, it)
        return res
    return _results(s, fsp)


_PROGRAMS: Dict[tuple, Callable] = {}
_PROGRAMS_MAX = 8          # bound compiled-executable memory, as sweep.py
# monotonic count of program-cache misses (new traces) in this process:
# the sweep farm's zero-recompile assertion reads it before/after each
# chunk — after the first chunk per canonical shape it must not move
PROGRAM_COMPILES = 0


def _jax_program(fsp: FabricSweepParams, unroll: int, impl: str = "ref"):
    global PROGRAM_COMPILES
    key = (fsp.structure_key, fsp.n_points, fsp.ticks, fsp.ring_len,
           fsp.cnp_ring, fsp.dt_us, unroll, impl)
    fn = _PROGRAMS.get(key)
    if fn is not None:
        return fn
    PROGRAM_COMPILES += 1
    import jax
    import jax.numpy as jnp

    dtype = jnp.float32
    st = _static(fsp, jnp, dtype)
    ticks, H, Hc = fsp.ticks, fsp.ring_len, fsp.cnp_ring

    def ring_set(ring, idx, v):
        return ring.at[..., idx, :, :].set(v)

    def one_point(s0, p):
        mk = _make_step_sparse if fsp.sparse else _make_step
        step = mk(jnp, ring_set, st, p, fsp.dt_us, H, dtype, Hc,
                  _opts(fsp, impl))

        def body(s, t):
            return step(s, t), None

        s, _ = jax.lax.scan(body, s0, jnp.arange(ticks, dtype=jnp.int32),
                            unroll=unroll)
        return s

    # the zero-init carry is rebuilt per call, so its (grid x ring) buffers
    # are donated to the scan instead of staying alive next to it
    fn = jax.jit(jax.vmap(one_point), donate_argnums=(0,))
    while len(_PROGRAMS) >= _PROGRAMS_MAX:
        _PROGRAMS.pop(next(iter(_PROGRAMS)))
    _PROGRAMS[key] = fn
    return fn


def _run_jax(fsp: FabricSweepParams, unroll, impl: str = "ref"):
    import jax.numpy as jnp

    u = pick_unroll(None if unroll == "auto" else unroll)
    fn = _jax_program(fsp, u, impl)
    p_np = _np_params(fsp, np.float32)
    s0 = _init_state(np, (fsp.n_points,), fsp, p_np, np.float32)
    p = {k: jnp.asarray(v) for k, v in p_np.items()}
    final = fn({k: jnp.asarray(v) for k, v in s0.items()}, p)
    return _results({k: np.asarray(v) for k, v in final.items()}, fsp)


def _jax_adaptive_program(fsp: FabricSweepParams, cfg: AdaptiveConfig,
                          impl: str):
    global PROGRAM_COMPILES
    key = ("adaptive", fsp.structure_key, fsp.n_points, fsp.ticks,
           fsp.ring_len, fsp.cnp_ring, fsp.dt_us, impl, cfg.key())
    fn = _PROGRAMS.get(key)
    if fn is not None:
        return fn
    PROGRAM_COMPILES += 1
    import jax
    import jax.numpy as jnp

    dtype = jnp.float32
    st = _static(fsp, jnp, dtype)
    ticks, H, Hc = fsp.ticks, fsp.ring_len, fsp.cnp_ring

    def ring_set(ring, idx, v):
        return ring.at[..., idx, :, :].set(v)

    def run(s0, p):
        # unlike the scan program the adaptive loop is batched, not
        # vmapped: the stride is a whole-grid reduction, so every point
        # advances in lockstep (a per-point stride would desynchronize
        # the shared ring clock)
        step = _make_step(jnp, ring_set, st, p, fsp.dt_us, H, dtype, Hc,
                          _opts(fsp, impl))
        stride = fused.make_stride_fn(jnp, fsp, p, _opts(fsp, impl), cfg,
                                      dtype)

        def cond(carry):
            _, t, _ = carry
            return t < ticks

        def body(carry):
            s, t, it = carry
            s1 = step(s, t, it)
            k = stride(s, s1, t)
            km1 = k.astype(dtype) - dtype(1.0)
            s2 = fused.macro_advance(jnp, s, s1, km1)
            return s2, t + k, it + jnp.int32(1)

        s, _, it = jax.lax.while_loop(
            cond, body, (s0, jnp.int32(0), jnp.int32(0)))
        return s, it

    fn = jax.jit(run, donate_argnums=(0,))
    while len(_PROGRAMS) >= _PROGRAMS_MAX:
        _PROGRAMS.pop(next(iter(_PROGRAMS)))
    _PROGRAMS[key] = fn
    return fn


def _run_jax_adaptive(fsp: FabricSweepParams, cfg: AdaptiveConfig,
                      impl: str = "ref"):
    import jax.numpy as jnp

    fn = _jax_adaptive_program(fsp, cfg, impl)
    p_np = _np_params(fsp, np.float32)
    s0 = _init_state(np, (fsp.n_points,), fsp, p_np, np.float32)
    p = {k: jnp.asarray(v) for k, v in p_np.items()}
    final, iters = fn({k: jnp.asarray(v) for k, v in s0.items()}, p)
    res = _results({k: np.asarray(v) for k, v in final.items()}, fsp)
    res["adaptive_iterations"] = np.full(fsp.n_points, int(iters))
    return res


def run_fabric_sweep(scenarios: Sequence, backend: str = "jax",
                     unroll="auto", adaptive_dt: bool = False,
                     adaptive: Optional[AdaptiveConfig] = None,
                     impl: str = "auto",
                     incidence: str = "auto",
                     envelope: Optional[dict] = None
                     ) -> Dict[str, np.ndarray]:
    """Advance a grid of fabric scenarios through the full multi-host
    recurrence at once; returns ``{metric: array}`` aligned with the input
    order (arrays are ``[G]``, ``[G, F]`` or ``[G, R]`` — flow order is the
    scenario flow list, receiver order is ``sorted({flow.dst})``).

    All scenarios must share topology structure, routes and the flow set;
    receiver/switch/flow *parameters* may vary freely (see
    :class:`FabricSweepParams`).  ``backend="numpy"`` runs the same step
    function batched under float64 — the verification reference.

    ``adaptive_dt=True`` (or an explicit :class:`AdaptiveConfig` via
    ``adaptive=``) turns on macro-tick coarsening: quiet stretches of the
    whole grid advance ``k * dt`` per iteration in closed form, with fine
    ticks near every queue/watermark/timer event (see
    :mod:`repro.fabric.fused` for the quiet predicate, the event caps and
    the documented equivalence bound).  The default ``adaptive_dt=False``
    traces none of this machinery and reproduces today's results exactly.

    ``impl`` selects the fused-stage kernel tier for the jax backend
    (``"auto"`` -> Pallas on TPU, the inline reference elsewhere;
    ``"interpret"`` runs the Pallas kernels under the interpreter so CPU
    CI exercises the kernel path).  The numpy reference always runs the
    inline formulation.

    ``incidence`` picks the queue-state layout: ``"dense"`` is the
    [2, P, F] port x flow formulation, ``"sparse"`` the segmented
    [2, 6, F] slot incidence whose per-tick cost grows with
    flows x hops instead of flows x ports — required for 3-level
    (super-spine) pod fabrics and the scalable choice for any large
    static grid.  ``"auto"`` (default) selects sparse exactly when the
    topology has a super-spine tier, so existing 2-tier grids keep the
    dense engine bit-for-bit.  Sparse supports static ECMP plus
    failure/flap windows and the CC zoo (per-flow DCQCN/Timely/HPCC);
    dynamic routing, the message layer, fault injection and
    ``adaptive_dt`` stay dense-only.

    ``envelope`` is the chunk-boundary contract for the sweep farm
    (:mod:`repro.fabric.farm`): pass
    ``FabricSweepParams.from_scenarios(full_grid).envelope()`` when
    ``scenarios`` is a chunk of a larger grid, so the chunk traces the
    monolithic grid's program structure and reproduces its results
    bit-for-bit (see :meth:`FabricSweepParams.envelope`).
    """
    if incidence not in ("auto", "dense", "sparse"):
        raise ValueError(f"unknown incidence {incidence!r}")
    sparse = incidence == "sparse" or (
        incidence == "auto"
        and any(bool(s.topology.super_spines) for s in scenarios))
    fsp = FabricSweepParams.from_scenarios(scenarios, sparse=sparse,
                                           envelope=envelope)
    cfg = adaptive if adaptive is not None \
        else (AdaptiveConfig() if adaptive_dt else None)
    if fsp.sparse and cfg is not None:
        raise ValueError("adaptive_dt macro-ticking is dense-engine "
                         "only; run sparse grids at the fine tick")
    if backend == "numpy":
        return _run_numpy(fsp, adaptive=cfg)
    if backend == "jax":
        ri = fused.resolve_impl(impl)
        if cfg is not None:
            return _run_jax_adaptive(fsp, cfg, ri)
        return _run_jax(fsp, unroll, ri)
    raise ValueError(f"unknown backend {backend!r}")
