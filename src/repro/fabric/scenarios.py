"""Scenario library: canonical multi-host workloads over the Clos fabric.

Mirrors the paper's evaluation mix (§6): storage incast, HPC all-to-all,
and the three storage traffic classes of fig 9 (OLTP / OLAP / backup),
each returning a ready-to-run (topology, flows, fabric-config) bundle.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.datapath import QoS
from ..core.simulator import SimConfig, testbed_100g
from .cc import CcConfig
from .fabric import FabricConfig, Flow
from .messages import MessageConfig
from .routing import RoutingConfig
from .switch import SwitchConfig
from .topology import (Topology, clos, incast_fabric, jet_testbed,
                       make_pod_clos)


@dataclasses.dataclass
class Scenario:
    name: str
    topology: Topology
    flows: List[Flow]
    fabric: FabricConfig

    def run(self):
        """Advance this one scenario with the scalar driver."""
        from .fabric import run_fabric
        return run_fabric(self.topology, self.flows, self.fabric)


def fabric_grid(mk: Callable[..., Scenario],
                **axes: Sequence) -> Tuple[List[Scenario], List[dict]]:
    """Cartesian grid of scenarios for :func:`repro.fabric.vector
    .run_fabric_sweep`: ``mk(**point)`` per combination of the ``axes``
    lists (the fabric twin of :func:`repro.fabric.sweep.grid_configs`).
    Returns ``(scenarios, point-dicts)``.  Axes must not change the
    topology *structure* (flow set / routes / tick count) — sweep numeric
    knobs (mode, pfc, burst_mb, ...) and keep shape axes (n_senders,
    n_hosts) fixed per grid.
    """
    names = sorted(axes)
    scens, points = [], []
    for combo in itertools.product(*(axes[n] for n in names)):
        pt = dict(zip(names, combo))
        scens.append(mk(**pt))
        points.append(pt)
    return scens, points


def _recv_factory(mode: str, pfc: bool,
                  msg_bytes: Optional[int] = None,
                  **kw) -> Callable[[str], SimConfig]:
    def make(host: str) -> SimConfig:
        extra = dict(kw)
        if msg_bytes is not None:
            extra["msg_bytes"] = msg_bytes
        return testbed_100g(mode, pfc_enabled=pfc, **extra)
    return make


def incast(n_senders: int = 8, mode: str = "jet", burst_mb: float = 2.0,
           pfc: bool = False, with_victim: bool = True,
           sim_time_s: float = 0.02) -> Scenario:
    """N senders on one leaf burst into one receiver on another leaf; an
    optional open-loop victim flow shares a sender host + the fabric path
    but targets a different receiver (measures HoL collateral)."""
    topo = incast_fabric(n_senders)
    flows = [Flow(src=f"h0_{i}", dst="h1_0",
                  burst_bytes=burst_mb * 1e6, tag="incast")
             for i in range(n_senders)]
    if with_victim:
        flows.append(Flow(src=f"h0_{n_senders - 1}", dst="h1_1",
                          tag="victim"))
    sw = SwitchConfig(pfc_enabled=pfc)
    return Scenario(
        name=f"incast{n_senders}_{mode}{'_pfc' if pfc else ''}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory(mode, pfc)))


def all_to_all(n_hosts: int = 8, mode: str = "jet",
               msg_kb: int = 256, pfc: bool = False,
               sim_time_s: float = 0.01) -> Scenario:
    """HPC all-to-all: every host streams to every other host (the MPI
    personalized-exchange shape of the paper's fig 13 substrate)."""
    per_leaf = max(2, (n_hosts + 1) // 2)   # ceil: never truncate odd N
    topo = clos(n_leaves=2, hosts_per_leaf=per_leaf, n_spines=2)
    hosts = topo.hosts[:n_hosts]
    assert len(hosts) == n_hosts
    flows = [Flow(src=a, dst=b, tag="a2a")
             for a in hosts for b in hosts if a != b]
    sw = SwitchConfig(pfc_enabled=pfc)
    return Scenario(
        name=f"a2a{n_hosts}_{mode}", topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory(
                                mode, pfc, msg_bytes=msg_kb << 10)))


# fig 9 storage classes: message size + per-flow open-loop load; num_qps
# shrinks with message size so latency "generations" (num_qps * msg bytes)
# stay observable within a few ms of simulated time
_STORAGE: Dict[str, dict] = {
    "oltp":   dict(msg_kb=8,    flow_gbps=8.0,  n_clients=8, num_qps=32),
    "olap":   dict(msg_kb=1024, flow_gbps=40.0, n_clients=4, num_qps=8),
    "backup": dict(msg_kb=4096, flow_gbps=90.0, n_clients=2, num_qps=2),
}


def storage_mix(kind: str = "oltp", mode: str = "jet",
                pfc: bool = False, sim_time_s: float = 0.02) -> Scenario:
    """Storage traffic fanning into one receiver host (paper fig 9):
    OLTP = many small-message clients, OLAP = 1 MB scans, backup = few
    near-line-rate streams."""
    if kind not in _STORAGE:
        raise ValueError(f"unknown storage mix {kind!r}; "
                         f"pick one of {sorted(_STORAGE)}")
    p = _STORAGE[kind]
    topo = incast_fabric(p["n_clients"])
    flows = [Flow(src=f"h0_{i}", dst="h1_0", offered_gbps=p["flow_gbps"],
                  tag=kind)
             for i in range(p["n_clients"])]
    sw = SwitchConfig(pfc_enabled=pfc)
    return Scenario(
        name=f"storage_{kind}_{mode}", topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory(
                                mode, pfc, msg_bytes=p["msg_kb"] << 10,
                                num_qps=p["num_qps"])))


def mixed_fleet(n_senders: int = 8, pool_mb: float = 12.0,
                burst_mb: float = 1.0, pfc: bool = False,
                rnic_ecn_cnp: bool = False,
                sim_time_s: float = 0.02) -> Scenario:
    """Mixed Jet+DDIO fleet on one fabric (ROADMAP "scenario breadth"):
    N senders burst into a *Jet* receiver (``h1_0``, pool size
    ``pool_mb``) while a victim flow streams open-loop into a *DDIO*
    receiver (``h1_1``) sharing the source leaf and fabric path.

    With ``rnic_ecn_cnp=False`` (the default here) the only
    receiver-side brake on the incast is the escape ladder's ECN ->
    CNP path, so sweeping ``pool_mb`` down makes the host-side
    admission/escape -> network-side DCQCN feedback loop directly
    observable in fleet metrics (incast FCT, victim goodput)."""
    topo = incast_fabric(n_senders)
    flows = [Flow(src=f"h0_{i}", dst="h1_0",
                  burst_bytes=burst_mb * 1e6, tag="incast")
             for i in range(n_senders)]
    flows.append(Flow(src=f"h0_{n_senders - 1}", dst="h1_1",
                      tag="victim"))
    pool_b = int(pool_mb * (1 << 20))

    def recv(host: str) -> SimConfig:
        if host == "h1_0":
            return testbed_100g("jet", pfc_enabled=pfc,
                                jet_pool_bytes=pool_b,
                                rnic_ecn_cnp=rnic_ecn_cnp)
        return testbed_100g("ddio", pfc_enabled=pfc)

    sw = SwitchConfig(pfc_enabled=pfc)
    return Scenario(
        name=f"mixed{n_senders}_pool{pool_mb:g}{'_pfc' if pfc else ''}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=recv))


def mixed_fleet_grid(pool_mb: Sequence[float] = (12.0, 4.0, 1.0),
                     burst_mb: Sequence[float] = (1.0, 2.0),
                     **kw) -> Tuple[List[Scenario], List[dict]]:
    """Grid of :func:`mixed_fleet` scenarios over Jet pool size x burst
    size, for :func:`repro.fabric.vector.run_fabric_sweep` — the
    closed-loop sweep: shrinking the receiver pool raises escape-ladder
    ECN pressure, which throttles that receiver's DCQCN senders and
    shifts fleet incast FCT / victim goodput."""
    return fabric_grid(
        lambda pool_mb, burst_mb: mixed_fleet(
            pool_mb=pool_mb, burst_mb=burst_mb, **kw),
        pool_mb=list(pool_mb), burst_mb=list(burst_mb))


def qos_mixed_storage(n_bulk: int = 4, n_oltp: int = 3, n_olap: int = 2,
                      bulk_gbps: float = 60.0, oltp_gbps: float = 25.0,
                      olap_gbps: float = 25.0,
                      oltp_on_off_us: Tuple[float, float] = (60.0, 60.0),
                      per_tc: bool = True, pfc: bool = True,
                      ecn: bool = False, pool_mb: float = 0.5,
                      sim_time_s: float = 0.01) -> Scenario:
    """QoS-mixed storage fleet (paper fig 9 classes on one fabric): LOW
    bulk/backup writers incast into a small-pool Jet receiver (``h1_0`` —
    pool pressure drives the §5 LOW->DRAM spill), HIGH OLTP clients run
    on-off burst trains into ``h1_1``, and NORMAL OLAP scans stream into
    ``h1_2``.  The bulk class oversubscribes its receiver's access link,
    so with ``pfc`` the congested downlink asserts pause up the tree.

    The scenario exists to measure PFC collateral damage: with
    ``per_tc=True`` (802.1Qbb per-priority pause) only the LOW class is
    paused on the shared spine->leaf links and the OLTP/OLAP classes
    keep flowing; ``per_tc=False`` reproduces the legacy whole-link
    pause, which head-of-line-blocks all three classes (the >= 2x victim
    -goodput gap asserted in tests/test_pfc_priority.py).  ``ecn=False``
    by default: a lossless-without-ECN fabric is held back *only* by
    PFC, the configuration where pause fan-out does real damage (§2.1).
    """
    # OLTP/OLAP clients *share* source hosts with bulk writers: the
    # classes meet at the source NIC and on every fabric link, the
    # worst case for pause collateral.  Per-TC queues keep them apart
    # anyway (own buffer partition, own pause state); the legacy
    # per-link mode lets a paused bulk class freeze the whole NIC.
    n = max(n_bulk, n_oltp, n_olap)
    topo = incast_fabric(n, host_gbps=100.0, uplink_gbps=800.0,
                         extra_receivers=2)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", offered_gbps=bulk_gbps,
                  qos=QoS.LOW, tag="incast")
             for i in range(n_bulk)]
    flows += [Flow(src=f"h0_{i}", dst="h1_1", offered_gbps=oltp_gbps,
                   qos=QoS.HIGH, tag="oltp", on_off_us=oltp_on_off_us)
              for i in range(n_oltp)]
    flows += [Flow(src=f"h0_{i}", dst="h1_2", offered_gbps=olap_gbps,
                   qos=QoS.NORMAL, tag="olap")
              for i in range(n_olap)]

    def recv(host: str) -> SimConfig:
        if host == "h1_0":      # the squeezed Jet pool: LOW spills (§5)
            return testbed_100g("jet", pfc_enabled=False,
                                jet_pool_bytes=int(pool_mb * (1 << 20)),
                                rnic_ecn_cnp=False)
        return testbed_100g("ddio", pfc_enabled=False)

    sw = SwitchConfig(pfc_enabled=pfc, ecn_enabled=ecn, per_tc=per_tc,
                      port_buffer_bytes=1 << 20)
    return Scenario(
        name=f"qosmix{n_bulk}b{n_oltp}o{n_olap}a"
             f"_{'tc' if per_tc else 'link'}{'_pfc' if pfc else ''}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=recv))


def qos_mixed_grid(per_tc: Sequence[bool] = (False, True),
                   pool_mb: Sequence[float] = (0.5,),
                   **kw) -> Tuple[List[Scenario], List[dict]]:
    """Grid of :func:`qos_mixed_storage` scenarios over pause granularity
    x Jet pool size for :func:`repro.fabric.vector.run_fabric_sweep` —
    the fleet-scale view of per-priority PFC: the ``per_tc`` axis flips
    the same workload between 802.1Qbb pause and legacy whole-link pause
    (both are plain per-point parameters, so one sweep covers both)."""
    return fabric_grid(
        lambda per_tc, pool_mb: qos_mixed_storage(
            per_tc=per_tc, pool_mb=pool_mb, **kw),
        per_tc=list(per_tc), pool_mb=list(pool_mb))


def olap_shuffle(n_mappers: int = 4, n_reducers: int = 4,
                 shuffle_mb: float = 2.0, routing: str = "static_ecmp",
                 pfc: bool = False, n_spines: int = 2,
                 sim_time_s: float = 0.02) -> Scenario:
    """Multi-receiver OLAP shuffle (ROADMAP "scenario breadth"): every
    mapper on leaf 0 streams one partition to every reducer on leaf 1 —
    an all-to-all *across* the spine tier, so the uplink choice (not one
    congested receiver) decides completion time.  The natural stress
    test for the routing layer: static ECMP piles the ``n_mappers x
    n_reducers`` partition bursts onto ``flow_id % n_spines`` uplinks
    while ``weighted_ecmp``/``adaptive``/``spray`` spread them by load.
    """
    per_leaf = max(n_mappers, n_reducers)
    topo = clos(n_leaves=2, hosts_per_leaf=per_leaf, n_spines=n_spines,
                host_gbps=100.0, uplink_gbps=200.0)
    flows = [Flow(src=f"h0_{i}", dst=f"h1_{j}",
                  burst_bytes=shuffle_mb * 1e6 / n_reducers,
                  qos=QoS.NORMAL, tag="shuffle")
             for i in range(n_mappers) for j in range(n_reducers)]
    sw = SwitchConfig(pfc_enabled=pfc)
    return Scenario(
        name=f"shuffle{n_mappers}x{n_reducers}_{routing}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory("ddio", pfc),
                            routing=RoutingConfig(mode=routing)))


def link_failure_incast(n_senders: int = 8, mode: str = "ddio",
                        routing: str = "adaptive", burst_mb: float = 2.0,
                        fail_at_us: float = 150.0,
                        restore_us: float = math.inf,
                        fail_spine: int = 0, pfc: bool = False,
                        with_victim: bool = True,
                        uplink_gbps: float = 400.0,
                        sim_time_s: float = 0.02) -> Scenario:
    """Failure injection under load (ROADMAP "failure injection"): the
    incast-N burst is mid-flight when the ``leaf0 -> spine{fail_spine}``
    uplink dies at ``fail_at_us`` (both directions; back at
    ``restore_us``, never by default).  Static ECMP keeps hashing half
    the flows onto the dead spine — their bursts stall until the link
    returns — while ``adaptive``/``spray`` reroute onto the surviving
    uplinks, which is exactly the post-failure FCT gap the routing layer
    exists to show.  ``fail_at_us=inf`` schedules no failure (baseline
    grid points)."""
    topo = incast_fabric(n_senders, uplink_gbps=uplink_gbps)
    if math.isfinite(fail_at_us):
        topo.fail_link("leaf0", f"spine{fail_spine}", at_us=fail_at_us,
                       restore_us=restore_us)
    flows = [Flow(src=f"h0_{i}", dst="h1_0",
                  burst_bytes=burst_mb * 1e6, tag="incast")
             for i in range(n_senders)]
    if with_victim:
        flows.append(Flow(src=f"h0_{n_senders - 1}", dst="h1_1",
                          tag="victim"))
    sw = SwitchConfig(pfc_enabled=pfc)
    fa = "nofail" if not math.isfinite(fail_at_us) else f"f{fail_at_us:g}"
    return Scenario(
        name=f"linkfail{n_senders}_{routing}_{fa}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory(mode, pfc),
                            routing=RoutingConfig(mode=routing)))


def routing_grid(modes: Sequence[str] = ("static_ecmp", "adaptive",
                                         "spray"),
                 fail_at_us: Sequence[float] = (math.inf, 150.0),
                 **kw) -> Tuple[List[Scenario], List[dict]]:
    """Routing mode x link-failure schedule grid over
    :func:`link_failure_incast` for :func:`repro.fabric.vector
    .run_fabric_sweep` — one vector program covers every (mode, failure)
    combination, which is what the lifted shared-routes restriction
    buys: routing mode and failure schedules are per-point parameters,
    not structure."""
    return fabric_grid(
        lambda routing, fail_at_us: link_failure_incast(
            routing=routing, fail_at_us=fail_at_us, **kw),
        routing=list(modes), fail_at_us=list(fail_at_us))


def single_pair(mode: str = "jet", sim_time_s: float = 0.01,
                **recv_kw) -> Scenario:
    """One sender, one receiver under one switch — the fabric rendition of
    the paper's two-host testbed (equivalence anchor for run_sim)."""
    topo = jet_testbed(2)
    return Scenario(
        name=f"pair_{mode}", topology=topo,
        flows=[Flow(src="h0_0", dst="h0_1")],
        fabric=FabricConfig(sim_time_s=sim_time_s,
                            receiver_cfg=_recv_factory(mode, False,
                                                       **recv_kw)))


def message_incast(n_senders: int = 8, algo: str = "dcqcn",
                   verb: str = "write", msg_kb: float = 64.0,
                   window: int = 16, mode: str = "ddio",
                   sim_time_s: float = 0.002,
                   cc: Optional[CcConfig] = None) -> Scenario:
    """N open-loop senders incast one receiver, every flow carrying the
    op layer: fixed-size verbs messages under an outstanding window,
    rate-controlled by ``algo`` from the CC zoo.  The canonical tail-
    latency benchmark — DCQCN's CNP-driven throttling versus the
    delay/INT controllers shows up directly in message p99/p999."""
    topo = incast_fabric(n_senders)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", tag="incast")
             for i in range(n_senders)]
    msg = MessageConfig(verb=verb, msg_bytes=msg_kb * 1024.0,
                        window=window)
    return Scenario(
        name=f"msg_incast{n_senders}_{algo}_{verb}"
             f"_{int(msg_kb)}k_w{window}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, msg=msg,
                            cc=cc if cc is not None else CcConfig(algo=algo),
                            receiver_cfg=_recv_factory(mode, False)))


def message_sweep_grid(msg_kb: Sequence[float] = (4.0, 64.0, 1024.0),
                       window: Sequence[int] = (1, 16, 64),
                       verb: Sequence[str] = ("write", "send"),
                       algo: Sequence[str] = ("dcqcn", "timely", "hpcc"),
                       **kw) -> Tuple[List[Scenario], List[dict]]:
    """Message size x outstanding window x verb x CC algorithm grid over
    :func:`message_incast` for :func:`repro.fabric.vector
    .run_fabric_sweep` — the classic verbs sweep (ib_write_bw-style
    size/queue-depth curves) as ONE vector program.  Per point the
    results carry Mops (``msg_rate_mops``), GiB/s (``msg_goodput_gbps``)
    and tail latency (``msg_p99_us``) — msg/cc are per-point parameters,
    not structure, so all points share one compiled program."""
    return fabric_grid(
        lambda msg_kb, window, verb, algo: message_incast(
            msg_kb=msg_kb, window=window, verb=verb, algo=algo, **kw),
        msg_kb=list(msg_kb), window=list(window), verb=list(verb),
        algo=list(algo))


def lossy_incast(n_senders: int = 8, loss_rate: float = 0.01,
                 recovery: str = "go_back_n", algo: str = "dcqcn",
                 verb: str = "write", msg_kb: float = 64.0,
                 window: int = 16, mode: str = "ddio", seed: int = 7,
                 sim_time_s: float = 0.002,
                 cc: Optional[CcConfig] = None) -> Scenario:
    """:func:`message_incast` on a lossy fabric: every link drops a
    stochastic ``loss_rate`` fraction of its ticks (counter-based hash,
    identical realization in all three engines — see
    :mod:`repro.fabric.faults`), and every flow recovers via
    ``MessageConfig.recovery`` — ``"go_back_n"`` gaps the receive window
    and replays from the RTO with exponential backoff, ``"selective"``
    replays only the lost span after the NACK delay (IRN).  The p999 gap
    between the two recovery modes under the same loss realization is
    the fault layer's headline plot (``examples/fault_recovery.py``)."""
    from .faults import FaultConfig
    topo = incast_fabric(n_senders)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", tag="incast")
             for i in range(n_senders)]
    msg = MessageConfig(verb=verb, msg_bytes=msg_kb * 1024.0,
                        window=window, recovery=recovery)
    return Scenario(
        name=f"lossy_incast{n_senders}_{recovery}"
             f"_l{loss_rate:g}_{algo}_{int(msg_kb)}k",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, msg=msg,
                            cc=cc if cc is not None else CcConfig(algo=algo),
                            faults=FaultConfig(loss_rate=loss_rate,
                                               seed=seed),
                            receiver_cfg=_recv_factory(mode, False)))


def lossy_incast_grid(loss_rate: Sequence[float] = (0.002, 0.01, 0.05),
                      recovery: Sequence[str] = ("go_back_n", "selective"),
                      **kw) -> Tuple[List[Scenario], List[dict]]:
    """Loss rate x recovery mode grid over :func:`lossy_incast` for
    :func:`repro.fabric.vector.run_fabric_sweep` — fault parameters are
    per-point sweep values, not structure, so the whole grid shares one
    compiled program.  Per point the results carry ``dropped_pkts``,
    ``retransmit_bytes`` and the message latency percentiles the
    go-back-N vs selective comparison reads (``msg_p999_us``)."""
    return fabric_grid(
        lambda loss_rate, recovery: lossy_incast(
            loss_rate=loss_rate, recovery=recovery, **kw),
        loss_rate=list(loss_rate), recovery=list(recovery))


# --------------------------------------------------------------------------- #
# Pod-scale (3-level Clos) scenarios
# --------------------------------------------------------------------------- #
def pod_incast(pods: int = 2, leaves_per_pod: int = 2,
               hosts_per_leaf: int = 4, mode: str = "jet",
               burst_mb: float = 1.0, pfc: bool = False,
               with_victim: bool = True,
               sim_time_s: float = 0.005) -> Scenario:
    """Cross-pod incast: every host of pods 1..P-1 bursts into one
    receiver in pod 0, so the fan-in crosses two oversubscription
    points (pod spine, then super-spine) before hitting the last-mile
    receiver bottleneck the paper studies — the hundreds-of-senders
    regime where the cache/PFC cascade differs in kind from the
    single-leaf testbed.  An optional victim inside the destination
    pod measures cross-tier HoL collateral.  Super-spine topologies run
    on the sparse-incidence vector engine (``run_fabric_sweep`` picks
    it automatically)."""
    topo = make_pod_clos(pods, leaves_per_pod, hosts_per_leaf)
    flows = [Flow(src=f"p{pi}h{li}_{hi}", dst="p0h0_0",
                  burst_bytes=burst_mb * 1e6, tag="incast")
             for pi in range(1, pods)
             for li in range(leaves_per_pod)
             for hi in range(hosts_per_leaf)]
    if with_victim and hosts_per_leaf > 1:
        flows.append(Flow(src=f"p0h{leaves_per_pod - 1}_0",
                          dst="p0h0_1", tag="victim"))
    sw = SwitchConfig(pfc_enabled=pfc)
    return Scenario(
        name=f"pod_incast{pods}x{leaves_per_pod}x{hosts_per_leaf}"
             f"_{mode}{'_pfc' if pfc else ''}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory(mode, pfc)))


def pod_incast_grid(mode: Sequence[str] = ("jet", "ddio"),
                    pfc: Sequence[bool] = (False, True),
                    **kw) -> Tuple[List[Scenario], List[dict]]:
    """Receiver mode x PFC grid over :func:`pod_incast` — one sparse
    vector program covers the whole pod-scale comparison."""
    return fabric_grid(
        lambda mode, pfc: pod_incast(mode=mode, pfc=pfc, **kw),
        mode=list(mode), pfc=list(pfc))


def pod_shuffle(pods: int = 2, leaves_per_pod: int = 2,
                hosts_per_leaf: int = 2, shuffle_mb: float = 1.0,
                mode: str = "ddio", pfc: bool = False,
                sim_time_s: float = 0.005) -> Scenario:
    """Pod-wide OLAP shuffle (:func:`olap_shuffle` at pod scale): every
    host of pod ``i`` streams one partition to every host of pod
    ``i+1 mod P`` — an all-to-all *across the super-spine tier*, so
    completion time is decided by the plane-aligned uplink choice and
    the per-tier oversubscription, not one congested receiver.
    ``pods=1`` degenerates to the 2-tier intra-pod shuffle."""
    topo = make_pod_clos(pods, leaves_per_pod, hosts_per_leaf)

    def hosts_of(pi: int) -> List[str]:
        return [f"p{pi}h{li}_{hi}" for li in range(leaves_per_pod)
                for hi in range(hosts_per_leaf)]

    n_red = leaves_per_pod * hosts_per_leaf
    flows = [Flow(src=src, dst=dst,
                  burst_bytes=shuffle_mb * 1e6 / n_red,
                  qos=QoS.NORMAL, tag="shuffle")
             for pi in range(pods)
             for src in hosts_of(pi)
             for dst in hosts_of((pi + 1) % pods)
             if src != dst]
    sw = SwitchConfig(pfc_enabled=pfc)
    return Scenario(
        name=f"pod_shuffle{pods}x{leaves_per_pod}x{hosts_per_leaf}",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory(mode, pfc)))


def pod_pfc_storm(pods: int = 2, leaves_per_pod: int = 2,
                  hosts_per_leaf: int = 4, buffer_kb: float = 64.0,
                  per_tc: bool = True,
                  sim_time_s: float = 0.005) -> Scenario:
    """Cross-tier PFC-storm study: a lossless (PFC everywhere) cross-pod
    incast with deliberately small switch buffers, so xoff cascades from
    the destination leaf back through its pod spine to the super-spine
    tier and out into every source pod.  ``pause_tc_fanout`` /
    ``pause_storm`` measure the blast radius — the pause-propagation
    failure mode Hoefler et al. argue only appears beyond one tier.
    Open-loop senders (no burst cap) keep the cascade fed for the whole
    window."""
    topo = make_pod_clos(pods, leaves_per_pod, hosts_per_leaf)
    flows = [Flow(src=f"p{pi}h{li}_{hi}", dst="p0h0_0",
                  qos=QoS.NORMAL, tag="incast")
             for pi in range(1, pods)
             for li in range(leaves_per_pod)
             for hi in range(hosts_per_leaf)]
    if hosts_per_leaf > 1:
        # cross-pod victim sharing only the paused tiers (collateral)
        flows.append(Flow(src="p1h0_1", dst=f"p0h{leaves_per_pod - 1}_1",
                          qos=QoS.HIGH, tag="victim"))
    sw = SwitchConfig(pfc_enabled=True, per_tc=per_tc,
                      port_buffer_bytes=int(buffer_kb * 1024))
    return Scenario(
        name=f"pod_storm{pods}x{leaves_per_pod}x{hosts_per_leaf}"
             f"_b{buffer_kb:g}k",
        topology=topo, flows=flows,
        fabric=FabricConfig(sim_time_s=sim_time_s, switch=sw,
                            receiver_cfg=_recv_factory("ddio", True)))


def pod_storm_grid(buffer_kb: Sequence[float] = (32.0, 64.0, 128.0),
                   **kw) -> Tuple[List[Scenario], List[dict]]:
    """Buffer-size sweep over :func:`pod_pfc_storm`: smaller per-port
    buffers assert xoff earlier and push the pause frontier deeper into
    the fabric — ``pause_storm`` vs buffer size is the cross-tier
    cascade curve."""
    return fabric_grid(
        lambda buffer_kb: pod_pfc_storm(buffer_kb=buffer_kb, **kw),
        buffer_kb=list(buffer_kb))


# --------------------------------------------------------------------------- #
# Farm layer: named grids + chunk plans
# --------------------------------------------------------------------------- #
def incast_grid(mode: Sequence[str] = ("jet", "ddio"),
                pfc: Sequence[bool] = (False, True),
                burst_mb: Sequence[float] = tuple(
                    0.25 * (i + 1) for i in range(16)),
                n_senders: int = 4,
                sim_time_s: float = 0.002,
                ) -> Tuple[List[Scenario], List[dict]]:
    """Receiver mode x PFC x burst-size grid over :func:`incast` — the
    farm's canonical 64-point 2-tier workload (burst size is a pure
    numeric axis, so chunks of this grid trivially share structure)."""
    return fabric_grid(
        lambda mode, pfc, burst_mb: incast(
            n_senders=n_senders, mode=mode, pfc=pfc, burst_mb=burst_mb,
            sim_time_s=sim_time_s),
        mode=list(mode), pfc=list(pfc), burst_mb=list(burst_mb))


#: Named grids the farm can rebuild by name inside worker processes
#: (Scenario objects embed receiver-config closures and do not pickle;
#: workers re-materialize the grid from this registry instead).  Each
#: entry maps name -> (builder, quick-kwargs): the builder returns
#: ``(scenarios, point-dicts)``; the quick kwargs shrink the grid for
#: smoke runs (``build_grid(name, quick=True)``).
GRIDS: Dict[str, Tuple[Callable[..., Tuple[List[Scenario], List[dict]]],
                       dict]] = {
    "incast": (incast_grid,
               dict(burst_mb=(0.25, 0.5, 1.0, 2.0), n_senders=4,
                    sim_time_s=0.001)),
    "mixed_fleet": (mixed_fleet_grid,
                    dict(pool_mb=(12.0, 4.0), burst_mb=(1.0,),
                         sim_time_s=0.002)),
    "qos_mixed": (qos_mixed_grid, dict(sim_time_s=0.001)),
    "routing": (routing_grid,
                dict(modes=("static_ecmp", "adaptive"),
                     sim_time_s=0.001)),
    "message_sweep": (message_sweep_grid,
                      dict(msg_kb=(64.0,), window=(1, 16),
                           verb=("write",), algo=("dcqcn", "timely"),
                           sim_time_s=0.001)),
    "lossy_incast": (lossy_incast_grid,
                     dict(loss_rate=(0.01,), sim_time_s=0.001)),
    "pod_incast": (pod_incast_grid, dict(sim_time_s=0.002)),
    "pod_storm": (pod_storm_grid,
                  dict(buffer_kb=(32.0, 64.0), sim_time_s=0.002)),
}


def build_grid(name: str, quick: bool = False,
               **overrides) -> Tuple[List[Scenario], List[dict]]:
    """Materialize a named grid from :data:`GRIDS`.

    ``quick=True`` applies the registry's shrunken axes (smoke-test
    size); explicit ``overrides`` win over both defaults and quick
    kwargs.  This is the farm's worker-side entry point: a
    ``(name, quick, overrides)`` triple is picklable where a scenario
    list is not, and rebuilding is deterministic, so every worker sees
    the identical grid."""
    if name not in GRIDS:
        raise ValueError(f"unknown grid {name!r}; "
                         f"pick one of {sorted(GRIDS)}")
    builder, quick_kw = GRIDS[name]
    kw = dict(quick_kw) if quick else {}
    kw.update(overrides)
    return builder(**kw)


def chunk_plan(n_points: int, chunk_size: int) -> List[dict]:
    """Split ``n_points`` grid points into fixed-shape chunks.

    Full chunks use exactly ``chunk_size`` points; the remainder is
    padded *up* to the next power of two (capped at ``chunk_size``), so
    a farm run compiles at most two program shapes regardless of grid
    size — the padding points replicate real scenarios and are sliced
    off after the run (vmap lanes are independent, so padded lanes
    cannot perturb real results).

    Returns a list of ``{"chunk": k, "start": i, "stop": j,
    "padded": m}`` dicts where ``stop - start`` is the real point count
    and ``padded >= stop - start`` is the dispatch shape.
    """
    if chunk_size <= 0:
        raise ValueError("chunk_size must be positive")
    if n_points <= 0:
        raise ValueError("empty grid")
    plan = []
    start = 0
    while start < n_points:
        stop = min(start + chunk_size, n_points)
        real = stop - start
        if real == chunk_size:
            padded = chunk_size
        else:
            padded = 1
            while padded < real:
                padded *= 2
            padded = min(padded, chunk_size)
        plan.append({"chunk": len(plan), "start": start, "stop": stop,
                     "padded": padded})
        start = stop
    return plan
