"""Output-queued switch model with per-traffic-class queues, ECN and PFC.

Fluid model.  Each output port owns one FIFO *per traffic class* (TC —
the fabric reuses the receiver's :class:`repro.core.datapath.QoS`
classes, so ``N_TC == N_QOS``), with

* a per-TC ECN knee: departures of a class are marked once *that class's*
  queue is past the knee (DCTCP-style, knee evaluated on enqueue);
* per-TC PFC xoff/xon watermarks: a congested class asserts pause toward
  exactly the ``(ingress link, tc)`` pairs feeding it, so a paused HIGH
  class no longer stalls LOW traffic sharing the same ingress link — the
  per-priority pause granularity real Clos fabrics run (802.1Qbb), which
  the paper's PFC fan-out / HoL measurements assume (§2, §6);
* inter-class scheduling on the shared link budget: strict priority
  (HIGH drains first — the default) or deficit-weighted round robin
  (``SwitchConfig.scheduler="wrr"``): the budget is water-filled across
  backlogged classes proportionally to per-TC quanta, so a saturated
  port can no longer starve LOW — at the cost of HIGH's absolute
  priority.  Both are pro rata across flows within a class (fluid
  approximation of per-class FIFO);
* per-class buffer space: every class owns a full ``port_buffer_bytes``
  worth of queue memory (the static per-priority-group partition real
  802.1Qbb switches reserve so a paused class cannot squeeze the
  others' headroom); tail drop and the xoff/xon watermark fractions are
  evaluated against the class's own partition.

The legacy per-link behaviour (one FIFO per port, pause stalls the whole
ingress link) is exactly the special case "all traffic in one class":
the driver maps every flow to TC 0 when ``SwitchConfig.per_tc`` is
False, which keeps the old congestion-spreading pathology available as a
comparison baseline (tests/test_pfc_priority.py golden-tests that a
single-TC workload is bit-equal between the two modes and to the
pre-refactor driver).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..core.datapath import N_QOS
from .topology import Link, LinkKey

N_TC = N_QOS                      # switch queues mirror the QoS classes

# (ingress link, traffic class) — the granularity of a PFC pause frame
PauseKey = Tuple[LinkKey, int]


@dataclasses.dataclass
class SwitchConfig:
    port_buffer_bytes: int = 4 << 20
    ecn_enabled: bool = True
    ecn_kmin_frac: float = 0.10       # mark departures once queue > kmin
    pfc_enabled: bool = False
    pfc_xoff_frac: float = 0.60       # assert pause above this occupancy
    pfc_xon_frac: float = 0.30        # release below this occupancy
    # classed queues (per-TC ECN knees + per-priority PFC).  False =
    # legacy per-link behaviour: every flow rides TC 0, one knee, one
    # watermark pair, and a pause stalls the whole ingress link.
    per_tc: bool = True
    # inter-class drain discipline: "strict" (priority ladder, HIGH
    # first — the default and the pre-WRR behaviour) or "wrr" (deficit-
    # weighted round robin by ``wrr_quanta``, so LOW keeps a weighted
    # share of a saturated port instead of starving)
    scheduler: str = "strict"
    wrr_quanta: Optional[Sequence[float]] = None   # len N_TC; default 4:2:1
    # optional per-TC overrides (len N_TC), falling back to the scalars
    tc_ecn_kmin_frac: Optional[Sequence[float]] = None
    tc_pfc_xoff_frac: Optional[Sequence[float]] = None
    tc_pfc_xon_frac: Optional[Sequence[float]] = None

    def __post_init__(self) -> None:
        if self.scheduler not in ("strict", "wrr"):
            raise ValueError(f"unknown scheduler {self.scheduler!r}")
        if self.wrr_quanta is not None and (
                len(self.wrr_quanta) != N_TC
                or any(q <= 0.0 for q in self.wrr_quanta)):
            raise ValueError(f"wrr_quanta needs {N_TC} positive weights")

    def quanta(self) -> Tuple[float, ...]:
        q = self.wrr_quanta if self.wrr_quanta is not None \
            else (4.0, 2.0, 1.0)
        return tuple(float(x) for x in q)

    def kmin_frac(self, tc: int) -> float:
        return (self.tc_ecn_kmin_frac[tc]
                if self.tc_ecn_kmin_frac is not None else self.ecn_kmin_frac)

    def xoff_frac(self, tc: int) -> float:
        return (self.tc_pfc_xoff_frac[tc]
                if self.tc_pfc_xoff_frac is not None else self.pfc_xoff_frac)

    def xon_frac(self, tc: int) -> float:
        return (self.tc_pfc_xon_frac[tc]
                if self.tc_pfc_xon_frac is not None else self.pfc_xon_frac)


@dataclasses.dataclass
class _FlowQ:
    bytes: float = 0.0
    marked: float = 0.0               # ECN-marked subset of ``bytes``


_NO_TCS: frozenset = frozenset()


class OutputPort:
    """One output port: per-TC FIFOs with per-flow byte accounting, ECN
    and per-priority PFC watermarks, drop + pause accounting."""

    def __init__(self, link: Link, cfg: SwitchConfig):
        self.link = link
        self.cfg = cfg
        # per-TC FIFO: tc -> {fid -> _FlowQ}; within a class, dict
        # insertion order is the (fluid) FIFO order
        self.tcq: List[Dict[int, _FlowQ]] = [{} for _ in range(N_TC)]
        # which ingress link each queued flow arrived on (pause targeting)
        self.flow_ingress: Dict[int, Optional[LinkKey]] = {}
        # candidate-ingress override (dynamic routing): flow -> every
        # ingress link that may feed it here.  When set, pause targets
        # cover the whole candidate set — a sprayed/rerouted flow's
        # queued bytes have mixed provenance, so per-arrival tracking
        # would under-pause; the vector engine's static prev-port
        # incidence implements the same semantics.
        self.static_ingress: Optional[Dict[int, Tuple[LinkKey, ...]]] = None
        self.paused = False           # whole-link pause (receiver gate)
        self.paused_tcs: frozenset = _NO_TCS   # downstream per-TC pause
        self.tc_asserted = [False] * N_TC      # this port's per-TC xoff
        self.dropped_bytes = 0.0
        self.marked_bytes = 0.0
        self.pause_us = 0.0
        self.peak_bytes = 0.0
        # running totals: queued_bytes is read per (flow, tick) by the
        # fabric hot loop, so summing the dicts there would be O(flows^2)
        self._tc_bytes = [0.0] * N_TC
        self._total_bytes = 0.0

    @property
    def queued_bytes(self) -> float:
        return self._total_bytes

    def tc_bytes(self, tc: int) -> float:
        return self._tc_bytes[tc]

    @property
    def pause_asserted(self) -> bool:
        """Any class asserting xoff (legacy single-flag view)."""
        return any(self.tc_asserted)

    @property
    def flows(self) -> Dict[int, _FlowQ]:
        """Merged per-flow view across classes (stats / introspection)."""
        merged: Dict[int, _FlowQ] = {}
        for q in self.tcq:
            merged.update(q)
        return merged

    def enqueue(self, fid: int, nbytes: float, marked: float,
                in_link: Optional[LinkKey], tc: int = 0) -> float:
        """Queue up to the buffer limit; returns the bytes dropped (tail
        drop — the fabric re-credits them to the sender, i.e. fluid
        go-back-N retransmission).  Exactly a single-item
        :meth:`enqueue_batch`."""
        if nbytes <= 0.0:
            return 0.0
        return self.enqueue_batch([(fid, nbytes, marked, in_link, tc)]) \
            .get(fid, 0.0)

    def enqueue_batch(
            self,
            items: List[Tuple[int, float, float, Optional[LinkKey], int]],
    ) -> Dict[int, float]:
        """Queue one tick's simultaneous arrivals ``[(fid, bytes, marked,
        in_link, tc)]`` as a single fluid batch: each class's buffer
        partition is allocated proportionally to that class's offered
        bytes, and each class's ECN knee is evaluated once against that
        class's pre-batch occupancy, so the outcome is independent of
        the order arrivals are listed in.  Returns ``{fid: dropped
        bytes}``."""
        tot_tc = [0.0] * N_TC
        for _, b, _, _, tc in items:
            if b > 0.0:
                tot_tc[tc] += b
        if not any(t > 0.0 for t in tot_tc):
            return {}
        buf = self.cfg.port_buffer_bytes
        scale_tc = [1.0] * N_TC
        for tc in range(N_TC):
            if tot_tc[tc] <= 0.0:
                continue
            space = max(0.0, buf - self._tc_bytes[tc])
            if tot_tc[tc] > space:
                scale_tc[tc] = space / tot_tc[tc]
        # one knee decision per class against the pre-batch occupancy
        mark_tc = [self.cfg.ecn_enabled and
                   self._tc_bytes[tc] > self.cfg.kmin_frac(tc) * buf
                   for tc in range(N_TC)]
        dropped: Dict[int, float] = {}
        for fid, b, m, in_link, tc in items:
            if b <= 0.0:
                continue
            take = b if scale_tc[tc] >= 1.0 else b * scale_tc[tc]
            lost = b - take
            if lost > 0.0:
                self.dropped_bytes += lost
                dropped[fid] = dropped.get(fid, 0.0) + lost
            if take <= 0.0:
                continue
            mk = m * (take / b)
            if mark_tc[tc]:
                self.marked_bytes += take - mk
                mk = take
            fq = self.tcq[tc].setdefault(fid, _FlowQ())
            fq.bytes += take
            fq.marked += mk
            self._tc_bytes[tc] += take
            self._total_bytes += take
            self.flow_ingress[fid] = in_link
        self.peak_bytes = max(self.peak_bytes, self._total_bytes)
        return dropped

    def _wrr_fracs(self, budget: float) -> List[float]:
        """Per-class drained fraction under deficit-weighted round robin:
        the link budget is water-filled over backlogged unpaused classes
        proportionally to ``wrr_quanta`` (a class that drains fully
        releases its leftover to the others).  Unrolled to ``N_TC``
        rounds with the exact op order of the vector engines, so the
        float64 reference and this driver make identical grants."""
        quanta = self.cfg.quanta()
        rem = list(self._tc_bytes)
        for tc in self.paused_tcs:
            rem[tc] = 0.0
        alloc = [0.0] * N_TC
        budget_left = budget
        for _ in range(N_TC):
            act = [tc for tc in range(N_TC) if rem[tc] > 0.0]
            if budget_left <= 0.0 or not act:
                break
            wsum = 0.0
            for tc in act:
                wsum += quanta[tc]
            b0 = budget_left
            spent = 0.0
            for tc in act:
                take = min(b0 * quanta[tc] / wsum, rem[tc])
                alloc[tc] += take
                rem[tc] -= take
                spent += take
            budget_left = b0 - spent
            if budget_left < 1e-6 * budget:   # relative crumb clamp, as
                budget_left = 0.0             # in the strict ladder
        return [alloc[tc] / self._tc_bytes[tc]
                if self._tc_bytes[tc] > 0.0 else 0.0
                for tc in range(N_TC)]

    def drain(self, dt_us: float) -> List[Tuple[int, float, float]]:
        """Forward up to rate*dt bytes; returns [(fid, bytes, marked)].

        Inter-class discipline per ``SwitchConfig.scheduler`` — strict
        priority (TC 0 first) or weighted round robin — pro rata across
        flows within a class; paused classes keep their bytes and do not
        consume link budget."""
        if self.paused or self.paused_tcs:
            self.pause_us += dt_us
            if self.paused:
                return []
        if self._total_bytes <= 0.0:
            return []
        budget = self.link.gbps * 1e9 / 8.0 * dt_us * 1e-6
        budget_left = budget
        wrr = self._wrr_fracs(budget) \
            if self.cfg.scheduler == "wrr" else None
        out: List[Tuple[int, float, float]] = []
        for tc in range(N_TC):
            total = self._tc_bytes[tc]
            if total <= 0.0 or tc in self.paused_tcs:
                continue
            frac = min(1.0, budget_left / total) if wrr is None \
                else wrr[tc]
            q = self.tcq[tc]
            for fid, fq in list(q.items()):
                b = fq.bytes * frac
                m = fq.marked * frac
                fq.bytes -= b
                fq.marked -= m
                self._tc_bytes[tc] -= b
                self._total_bytes -= b
                if fq.bytes < 1e-9:
                    self._tc_bytes[tc] -= fq.bytes
                    self._total_bytes -= fq.bytes
                    del q[fid]
                if b > 0.0:
                    out.append((fid, b, m))
            budget_left -= total * frac
            # leftover budget below 1e-6 of the link budget is rounding
            # crumb (budget - frac * total when a class eats the whole
            # budget); granting it to the next class would forward
            # micro-byte trickles that downstream convert into full-size
            # discrete events (ECN marks -> CNPs).  The clamp is
            # *relative* so float32 and float64 engines make the same
            # grant/no-grant decision, keeping the priority ladder
            # deterministic across backends.
            if budget_left < 1e-6 * budget:
                budget_left = 0.0
            self._tc_bytes[tc] = max(0.0, self._tc_bytes[tc])
        self._total_bytes = max(0.0, self._total_bytes)
        return out

    def drop_all(self) -> Dict[int, float]:
        """Drop everything queued (the link just died): clears every
        class, counts the bytes as drops and returns ``{fid: bytes}`` so
        the caller can re-credit senders (fluid go-back-N retransmission
        over whatever path routing picks next)."""
        lost: Dict[int, float] = {}
        for q in self.tcq:
            for fid, fq in q.items():
                if fq.bytes > 0.0:
                    lost[fid] = lost.get(fid, 0.0) + fq.bytes
                    self.dropped_bytes += fq.bytes
            q.clear()
        self._tc_bytes = [0.0] * N_TC
        self._total_bytes = 0.0
        return lost

    def update_pfc(self) -> None:
        if not self.cfg.pfc_enabled:
            return
        buf = self.cfg.port_buffer_bytes
        for tc in range(N_TC):
            q_frac = self._tc_bytes[tc] / buf
            if self.tc_asserted[tc]:
                if q_frac < self.cfg.xon_frac(tc):
                    self.tc_asserted[tc] = False
            elif q_frac > self.cfg.xoff_frac(tc):
                self.tc_asserted[tc] = True

    def pause_targets(self) -> Set[PauseKey]:
        """``(ingress link, tc)`` pairs this port wants paused: only the
        ingress links of flows actually queued in an over-watermark
        class — PFC's per-priority granularity (802.1Qbb).  With a
        ``static_ingress`` candidate map (dynamic routing), every
        ingress link that may feed a queued flow is targeted."""
        out: Set[PauseKey] = set()
        for tc in range(N_TC):
            if not self.tc_asserted[tc]:
                continue
            for fid in self.tcq[tc]:
                if self.static_ingress is not None:
                    for lk in self.static_ingress.get(fid, ()):
                        out.add((lk, tc))
                else:
                    lk = self.flow_ingress.get(fid)
                    if lk is not None:
                        out.add((lk, tc))
        return out


class Switch:
    """A named switch owning one OutputPort per outgoing link."""

    def __init__(self, name: str, out_links: List[Link], cfg: SwitchConfig):
        self.name = name
        self.cfg = cfg
        self.ports: Dict[str, OutputPort] = {
            l.dst: OutputPort(l, cfg) for l in out_links}

    def enqueue(self, out_dst: str, fid: int, nbytes: float, marked: float,
                in_link: Optional[LinkKey], tc: int = 0) -> float:
        """Returns bytes tail-dropped at the output port."""
        return self.ports[out_dst].enqueue(fid, nbytes, marked, in_link, tc)

    def update_pfc(self) -> Set[PauseKey]:
        """Refresh per-port per-TC xoff/xon state; returns the
        ``(ingress link, tc)`` pairs to pause."""
        targets: Set[PauseKey] = set()
        for p in self.ports.values():
            p.update_pfc()
            targets |= p.pause_targets()
        return targets

    # -- stats ----------------------------------------------------------------
    def dropped_bytes(self) -> float:
        return sum(p.dropped_bytes for p in self.ports.values())

    def marked_bytes(self) -> float:
        return sum(p.marked_bytes for p in self.ports.values())

    def queued_bytes(self) -> float:
        return sum(p.queued_bytes for p in self.ports.values())
