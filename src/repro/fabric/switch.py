"""Output-queued switch model with per-port ECN marking and PFC pauses.

Fluid model, one FIFO per output port, per-flow byte accounting so that

* ECN marks survive multi-hop forwarding and reach the right receiver
  (which turns them into per-flow CNPs, DCQCN-style);
* PFC pause targets exactly the ingress links feeding a congested output
  port — pausing a link stalls *everything* riding it, which is the
  head-of-line blocking / congestion-spreading pathology the hyperscale
  RDMA literature documents (Hoefler et al.) and the paper motivates
  against (§2.1).

Queues drain proportionally across flows (fluid approximation of FIFO).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .topology import Link, LinkKey


@dataclasses.dataclass
class SwitchConfig:
    port_buffer_bytes: int = 4 << 20
    ecn_enabled: bool = True
    ecn_kmin_frac: float = 0.10       # mark departures once queue > kmin
    pfc_enabled: bool = False
    pfc_xoff_frac: float = 0.60       # assert pause above this occupancy
    pfc_xon_frac: float = 0.30        # release below this occupancy


@dataclasses.dataclass
class _FlowQ:
    bytes: float = 0.0
    marked: float = 0.0               # ECN-marked subset of ``bytes``


class OutputPort:
    """One output FIFO: per-flow bytes, ECN/PFC watermarks, drop + pause
    accounting."""

    def __init__(self, link: Link, cfg: SwitchConfig):
        self.link = link
        self.cfg = cfg
        self.flows: Dict[int, _FlowQ] = {}
        # which ingress link each queued flow arrived on (pause targeting)
        self.flow_ingress: Dict[int, Optional[LinkKey]] = {}
        self.paused = False           # downstream asserted PFC on this link
        self.pause_asserted = False   # this port's xoff toward upstream
        self.dropped_bytes = 0.0
        self.marked_bytes = 0.0
        self.pause_us = 0.0
        self.peak_bytes = 0.0
        # running total: queued_bytes is read per (flow, tick) by the
        # fabric hot loop, so summing the dict there would be O(flows^2)
        self._total_bytes = 0.0

    @property
    def queued_bytes(self) -> float:
        return self._total_bytes

    def enqueue(self, fid: int, nbytes: float, marked: float,
                in_link: Optional[LinkKey]) -> float:
        """Queue up to the buffer limit; returns the bytes dropped (tail
        drop — the fabric re-credits them to the sender, i.e. fluid
        go-back-N retransmission)."""
        if nbytes <= 0.0:
            return 0.0
        q = self.queued_bytes
        space = self.cfg.port_buffer_bytes - q
        take = min(nbytes, max(0.0, space))
        dropped = nbytes - take
        self.dropped_bytes += dropped
        if take <= 0.0:
            return dropped
        marked = marked * (take / nbytes)
        # DCTCP-style: mark on enqueue when the queue is past the knee
        if self.cfg.ecn_enabled and \
                q > self.cfg.ecn_kmin_frac * self.cfg.port_buffer_bytes:
            new_marks = take - marked
            self.marked_bytes += new_marks
            marked = take
        fq = self.flows.setdefault(fid, _FlowQ())
        fq.bytes += take
        fq.marked += marked
        self._total_bytes += take
        self.flow_ingress[fid] = in_link
        self.peak_bytes = max(self.peak_bytes, q + take)
        return dropped

    def enqueue_batch(
            self, items: List[Tuple[int, float, float, Optional[LinkKey]]],
    ) -> Dict[int, float]:
        """Queue one tick's simultaneous arrivals ``[(fid, bytes, marked,
        in_link)]`` as a single fluid batch: buffer space is allocated
        proportionally to offered bytes and the ECN knee is evaluated once
        against the pre-batch occupancy, so the outcome is independent of
        the order arrivals are listed in (a sequence of :meth:`enqueue`
        calls would privilege earlier callers).  A single-item batch is
        exactly ``enqueue``.  Returns ``{fid: dropped bytes}``."""
        total = sum(b for _, b, _, _ in items if b > 0.0)
        if total <= 0.0:
            return {}
        q = self.queued_bytes
        space = max(0.0, self.cfg.port_buffer_bytes - q)
        scale = 1.0 if total <= space else space / total
        mark_now = (self.cfg.ecn_enabled and
                    q > self.cfg.ecn_kmin_frac * self.cfg.port_buffer_bytes)
        dropped: Dict[int, float] = {}
        for fid, b, m, in_link in items:
            if b <= 0.0:
                continue
            take = b if scale >= 1.0 else b * scale
            lost = b - take
            if lost > 0.0:
                self.dropped_bytes += lost
                dropped[fid] = dropped.get(fid, 0.0) + lost
            if take <= 0.0:
                continue
            mk = m * (take / b)
            if mark_now:
                self.marked_bytes += take - mk
                mk = take
            fq = self.flows.setdefault(fid, _FlowQ())
            fq.bytes += take
            fq.marked += mk
            self._total_bytes += take
            self.flow_ingress[fid] = in_link
        self.peak_bytes = max(self.peak_bytes, self.queued_bytes)
        return dropped

    def drain(self, dt_us: float) -> List[Tuple[int, float, float]]:
        """Forward up to rate*dt bytes; returns [(fid, bytes, marked)]."""
        if self.paused:
            self.pause_us += dt_us
            return []
        budget = self.link.gbps * 1e9 / 8.0 * dt_us * 1e-6
        total = self.queued_bytes
        if total <= 0.0:
            return []
        frac = min(1.0, budget / total)
        out: List[Tuple[int, float, float]] = []
        for fid, fq in list(self.flows.items()):
            b = fq.bytes * frac
            m = fq.marked * frac
            fq.bytes -= b
            fq.marked -= m
            self._total_bytes -= b
            if fq.bytes < 1e-9:
                self._total_bytes -= fq.bytes
                del self.flows[fid]
            if b > 0.0:
                out.append((fid, b, m))
        self._total_bytes = max(0.0, self._total_bytes)
        return out

    def update_pfc(self) -> None:
        if not self.cfg.pfc_enabled:
            return
        q_frac = self.queued_bytes / self.cfg.port_buffer_bytes
        if self.pause_asserted:
            if q_frac < self.cfg.pfc_xon_frac:
                self.pause_asserted = False
        elif q_frac > self.cfg.pfc_xoff_frac:
            self.pause_asserted = True

    def pause_targets(self) -> Set[LinkKey]:
        """Ingress links this congested port wants paused (only links of
        flows actually queued here — PFC's per-ingress granularity)."""
        if not self.pause_asserted:
            return set()
        return {self.flow_ingress[fid] for fid in self.flows
                if self.flow_ingress.get(fid) is not None}


class Switch:
    """A named switch owning one OutputPort per outgoing link."""

    def __init__(self, name: str, out_links: List[Link], cfg: SwitchConfig):
        self.name = name
        self.cfg = cfg
        self.ports: Dict[str, OutputPort] = {
            l.dst: OutputPort(l, cfg) for l in out_links}

    def enqueue(self, out_dst: str, fid: int, nbytes: float, marked: float,
                in_link: Optional[LinkKey]) -> float:
        """Returns bytes tail-dropped at the output port."""
        return self.ports[out_dst].enqueue(fid, nbytes, marked, in_link)

    def update_pfc(self) -> Set[LinkKey]:
        """Refresh per-port xoff/xon state; returns ingress links to pause."""
        targets: Set[LinkKey] = set()
        for p in self.ports.values():
            p.update_pfc()
            targets |= p.pause_targets()
        return targets

    # -- stats ----------------------------------------------------------------
    def dropped_bytes(self) -> float:
        return sum(p.dropped_bytes for p in self.ports.values())

    def marked_bytes(self) -> float:
        return sum(p.marked_bytes for p in self.ports.values())

    def queued_bytes(self) -> float:
        return sum(p.queued_bytes for p in self.ports.values())
