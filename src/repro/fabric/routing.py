"""Routing as a first-class layer: per-tick path selection over the Clos.

Before this module, routing was construction-time metadata: ``Topology
.route`` hashed every flow onto one spine at setup and the drivers froze
the resulting ``flow -> path`` dict.  That cannot express what hyperscale
fabrics actually run against incast/PFC pathologies — load-aware path
selection (adaptive routing, packet spraying; Hoefler et al., "Datacenter
Ethernet and RDMA: Issues at Hyperscale") — nor link-failure rerouting
under load.  Now the *spine choice* of every cross-leaf flow is resolved
per tick from a :class:`RoutingConfig`:

``static_ecmp``
    The pre-refactor behaviour: spine = ``flow_id % n_spines``, frozen
    for the whole run (golden-tested bit-equal to the old driver).
``weighted_ecmp``
    Flowlet-level re-hash: when a flow's arrival gap exceeds
    ``flowlet_gap_us`` — the flow resumes injecting after an idle spell
    long enough that the new burst cannot catch the old one's tail in
    flight (Kandula et al.'s flowlet condition) — or immediately when
    the current path dies, the flow re-picks a spine by a deterministic
    hash weighted by per-uplink *free* buffer space, so emptier uplinks
    attract proportionally more flowlets.  A continuously-backlogged
    flow is one flowlet and never re-hashes; an on-off burst train
    re-hashes once per train.
``adaptive``
    Per-tick least-congested-uplink selection with a hysteresis flap
    guard: the flow moves only when the best candidate's queue is more
    than ``hysteresis_frac * port_buffer`` bytes shorter than the
    current one's (or the current path is down).
``spray``
    Per-tick proportional byte split across *all* up spines (weights =
    free buffer space), i.e. packet-level spraying; the reorder cost is
    modeled as a ``spray_settle_us`` delay before sprayed arrivals reach
    receiver admission (delivery only counts after the settling window).

All decision helpers here are pure and deterministic — integer hashing,
first-minimum tie-breaks — so the scalar driver (float64 Python), the
batched-numpy reference and the jax engine reproduce each other's
choices; :mod:`repro.fabric.vector` implements the same arithmetic in
stacked ``[G, S, F]`` form as per-tick carry state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

ROUTING_MODES = ("static_ecmp", "weighted_ecmp", "adaptive", "spray")


@dataclasses.dataclass
class RoutingConfig:
    """Per-fabric routing policy (one mode per scenario / grid point)."""
    mode: str = "static_ecmp"
    # weighted_ecmp: minimum idle gap between injections that opens a
    # flowlet boundary (re-hash happens on the first active tick after
    # a gap longer than this)
    flowlet_gap_us: float = 50.0
    # adaptive: move only when the best uplink queue is this fraction of
    # the port buffer shorter than the current one (flap guard)
    hysteresis_frac: float = 0.05
    # spray: reorder-settling delay before sprayed arrivals count as
    # delivered at the receiver
    spray_settle_us: float = 8.0

    def __post_init__(self) -> None:
        if self.mode not in ROUTING_MODES:
            raise ValueError(f"unknown routing mode {self.mode!r}; "
                             f"pick one of {ROUTING_MODES}")
        if self.flowlet_gap_us <= 0.0:
            raise ValueError("flowlet_gap_us must be positive")
        if self.hysteresis_frac < 0.0:
            raise ValueError("hysteresis_frac must be >= 0")
        if self.spray_settle_us < 0.0:
            raise ValueError("spray_settle_us must be >= 0")

    @property
    def is_dynamic(self) -> bool:
        return self.mode != "static_ecmp"

    def mode_code(self) -> int:
        """Integer code for stacked per-point parameters (vector engine)."""
        return ROUTING_MODES.index(self.mode)


def flowlet_hash(fid: int, k: int) -> float:
    """Deterministic hash of (flow id, flowlet index) into [0, 1).

    Kept in int32-safe arithmetic (products stay < 2^31 for any
    realistic flow count / tick count) so the jax engine computes the
    identical value; x / 65536 is a power-of-two scale, hence exact in
    both float32 and float64.
    """
    return (((fid + 1) * 40503 + k * 9973) % 65536) / 65536.0


def weighted_pick(weights: Sequence[float], h: float) -> int:
    """First index whose cumulative weight exceeds ``h * total``.

    ``h`` must be in [0, 1); the sequential cumulative sum guarantees a
    hit on the last positively-weighted index even under float rounding
    (the vector engine thresholds against the cumsum's own final element
    for the same reason).  Caller guarantees ``sum(weights) > 0``.
    """
    tot = 0.0
    for w in weights:
        tot += w
    thresh = h * tot
    acc = 0.0
    for i, w in enumerate(weights):
        acc += w
        if acc > thresh:
            return i
    return len(weights) - 1


def adaptive_pick(occ: Sequence[float], up: Sequence[bool], cur: int,
                  hyst_bytes: float) -> int:
    """Least-congested up candidate, with hysteresis against flapping.

    Stays on ``cur`` unless it is down or the best candidate's queue is
    more than ``hyst_bytes`` shorter.  First-minimum tie-break matches
    ``argmin`` in the vector engines.
    """
    best, bocc = -1, math.inf
    for i, o in enumerate(occ):
        if up[i] and o < bocc:
            best, bocc = i, o
    if best < 0:                       # every candidate is down: stuck
        return cur
    if up[cur] and not (bocc < occ[cur] - hyst_bytes):
        return cur
    return best


def spray_weights(occ: Sequence[float], up: Sequence[bool],
                  buffer_bytes: float, cur: int) -> List[float]:
    """Proportional byte split across up candidates by free buffer space;
    falls back to the current path when nothing is up (or nothing has
    room — the flow then keeps hammering its last spine, as a real
    sprayer with every queue full would)."""
    w = [max(buffer_bytes - occ[i], 0.0) if up[i] else 0.0
         for i in range(len(occ))]
    tot = 0.0
    for x in w:
        tot += x
    if tot <= 0.0:
        return [1.0 if i == cur else 0.0 for i in range(len(occ))]
    return [x / tot for x in w]
