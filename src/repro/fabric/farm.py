"""Sweep farm: sharded grid execution across devices and processes.

The vector engine runs any structure-sharing grid as ONE XLA program —
which is exactly wrong once grids reach overnight size: a 10–100x grid
compiles one giant program per shape, holds the whole [G, ...] state in
memory at once, and leaves every other core and device idle.  This
module is the firesim-style run-farm layer on top of it:

* **Fixed-shape chunks.**  The grid is split by
  :func:`repro.fabric.scenarios.chunk_plan` into chunks of one or two
  canonical shapes (full chunks + one power-of-two-padded remainder),
  each padded by replicating a real scenario.  Combined with the
  structure **envelope** (:meth:`FabricSweepParams.envelope` of the full
  grid, forwarded to every chunk), all chunks trace the *same* program:
  zero recompiles after the first chunk per canonical shape, and —
  because vmap lanes are independent and every result is per-point —
  bit-identical per-point results vs the monolithic run at fixed dt.

* **Dispatch.**  ``workers <= 1`` runs chunks in-process with host-side
  chunk packing overlapped against device compute (a one-deep prefetch
  thread builds chunk k+1's parameter pack while chunk k executes; the
  compiled program itself donates its carry buffers).  ``workers > 1``
  fans chunks out to a ``spawn`` multiprocessing pool — each worker
  rebuilds the grid from a picklable :class:`GridSpec` (scenario objects
  embed receiver-config closures and do not pickle), shares the on-disk
  XLA compilation cache when ``JAX_COMPILATION_CACHE_DIR`` is set, and
  writes its own result shards so a killed parent loses nothing.  When
  several local jax devices exist (and
  :func:`repro.parallel.compat.farm_dispatch_probe` says the API
  generation supports it), in-process chunks round-robin across devices;
  otherwise the farm *degrades with a warning* to single-device chunked
  execution — never a crash.

* **Versioned artifacts + resume.**  Every run writes
  ``experiments/runs/<run_id>/`` (manifest + per-chunk shards + merged
  table; see :mod:`repro.fabric.artifacts`).  ``resume=True`` re-reads
  the manifest, verifies the grid fingerprint, and dispatches only the
  chunks whose shards are missing or unloadable — kill a run at 50% and
  the restart completes the other half.

Command line::

    python -m repro.fabric.farm --grid pod_storm --workers 4
    python -m repro.fabric.farm --grid incast --chunk 16 --resume \
        --run-id run-20260809-...

Peak memory is bounded by chunk size, not grid size; results stream to
disk as chunks finish.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import artifacts as A
from . import vector as V
from .scenarios import build_grid, chunk_plan

# set by _worker_init in pool workers; holds the rebuilt grid + run ctx
_WORKER: dict = {}


@dataclasses.dataclass
class GridSpec:
    """Picklable recipe for a named grid (workers rebuild from this)."""
    name: str
    quick: bool = False
    overrides: Optional[dict] = None

    def build(self):
        return build_grid(self.name, quick=self.quick,
                          **(self.overrides or {}))

    def to_json(self) -> dict:
        return {"name": self.name, "quick": self.quick,
                "overrides": self.overrides or {}}


def _resolve_grid(grid, quick: bool, overrides: Optional[dict]
                  ) -> Tuple[List, List[dict], Optional[GridSpec]]:
    """Accept a grid name, a GridSpec, or a raw scenario list."""
    if isinstance(grid, GridSpec):
        scens, points = grid.build()
        return scens, points, grid
    if isinstance(grid, str):
        spec = GridSpec(grid, quick=quick, overrides=overrides)
        scens, points = spec.build()
        return scens, points, spec
    scens = list(grid)
    return scens, [{} for _ in scens], None


def _pick_sparse(scens: Sequence, incidence: str) -> bool:
    if incidence not in ("auto", "dense", "sparse"):
        raise ValueError(f"unknown incidence {incidence!r}")
    return incidence == "sparse" or (
        incidence == "auto"
        and any(bool(s.topology.super_spines) for s in scens))


def _pad_chunk(scens: Sequence, entry: dict) -> Tuple[List, int]:
    """Chunk scenarios padded to the canonical dispatch shape.

    Padding replicates the chunk's first scenario: a duplicate of a real
    point adds nothing to the any-over-points capability flags or ring
    maxima (the envelope already floors those anyway) and its lane is
    sliced off before results leave this module.
    """
    real = list(scens[entry["start"]:entry["stop"]])
    n_pad = entry["padded"] - len(real)
    return real + [real[0]] * n_pad, len(real)


def _pack_chunk(scens: Sequence, entry: dict, sparse: bool,
                envelope: dict):
    padded, n_real = _pad_chunk(scens, entry)
    fsp = V.FabricSweepParams.from_scenarios(padded, sparse=sparse,
                                             envelope=envelope)
    return fsp, n_real


def _execute_packed(fsp, n_real: int, backend: str, unroll) -> Tuple[
        Dict[str, np.ndarray], int]:
    """Run one packed chunk, slice off padding, count compiles."""
    c0 = V.PROGRAM_COMPILES
    if backend == "numpy":
        out = V._run_numpy(fsp)
    elif backend == "jax":
        from . import fused
        out = V._run_jax(fsp, unroll, fused.resolve_impl("auto"))
    else:
        raise ValueError(f"unknown backend {backend!r}")
    out = {k: np.asarray(v)[:n_real] for k, v in out.items()}
    return out, V.PROGRAM_COMPILES - c0


# --------------------------------------------------------------------------- #
# In-process dispatch (single worker, optional multi-device round-robin)
# --------------------------------------------------------------------------- #
def _device_cycle(backend: str):
    """Devices to round-robin chunks over; [None] = jax's default."""
    if backend != "jax":
        return [None]
    from ..parallel import compat
    ok, reason = compat.farm_dispatch_probe()
    if not ok:
        warnings.warn(f"farm device dispatch unavailable ({reason}); "
                      "falling back to single-device chunked execution",
                      RuntimeWarning, stacklevel=3)
        return [None]
    import jax
    return list(jax.devices())


def _run_chunks_inprocess(scens, plan, todo, sparse, envelope, backend,
                          unroll, rdir: Optional[str]) -> List[dict]:
    """Execute ``todo`` chunks in this process.

    Host-side prep (scenario padding + parameter packing, pure numpy) is
    overlapped with device compute via a one-deep prefetch thread: while
    chunk k runs under jax, chunk k+1 is already being packed.  Each
    finished chunk is sliced to its real points and streamed to its
    shard before the next result materializes, so peak memory tracks the
    chunk shape, not the grid.
    """
    from concurrent.futures import ThreadPoolExecutor

    devices = _device_cycle(backend)
    records = []
    with ThreadPoolExecutor(max_workers=1) as pool:
        nxt = pool.submit(_pack_chunk, scens, plan[todo[0]], sparse,
                          envelope)
        for i, k in enumerate(todo):
            fsp, n_real = nxt.result()
            if i + 1 < len(todo):
                nxt = pool.submit(_pack_chunk, scens, plan[todo[i + 1]],
                                  sparse, envelope)
            entry = plan[k]
            dev = devices[i % len(devices)]
            t0 = time.perf_counter()
            if dev is None:
                out, compiles = _execute_packed(fsp, n_real, backend,
                                                unroll)
            else:
                import jax
                with jax.default_device(dev):
                    out, compiles = _execute_packed(fsp, n_real,
                                                    backend, unroll)
            wall = time.perf_counter() - t0
            rec = {"chunk": k, "start": entry["start"],
                   "stop": entry["stop"], "padded": entry["padded"],
                   "wall_s": wall, "compiles": compiles,
                   "device": str(dev) if dev is not None else "default",
                   "worker": "inprocess"}
            if rdir is not None:
                A.save_chunk(rdir, k, out, meta=rec)
            else:
                rec["results"] = out
            records.append(rec)
    return records


# --------------------------------------------------------------------------- #
# Multiprocess dispatch (spawn pool; workers rebuild the grid by name)
# --------------------------------------------------------------------------- #
def _worker_init(spec_json: dict, sparse: bool, envelope: dict,
                 backend: str, rdir: str) -> None:
    """Pool initializer: rebuild the grid once per worker process."""
    from ._scan import configure_persistent_cache
    configure_persistent_cache()   # share the on-disk XLA cache
    spec = GridSpec(spec_json["name"], spec_json["quick"],
                    spec_json["overrides"] or None)
    scens, _ = spec.build()
    _WORKER.update(scens=scens, sparse=sparse, envelope=envelope,
                   backend=backend, rdir=rdir)


def _worker_run_chunk(entry: dict) -> dict:
    """Run one chunk inside a pool worker; writes the shard itself so a
    killed parent cannot lose finished work."""
    w = _WORKER
    t0 = time.perf_counter()
    fsp, n_real = _pack_chunk(w["scens"], entry, w["sparse"],
                              w["envelope"])
    out, compiles = _execute_packed(fsp, n_real, w["backend"], "auto")
    rec = {"chunk": entry["chunk"], "start": entry["start"],
           "stop": entry["stop"], "padded": entry["padded"],
           "wall_s": time.perf_counter() - t0, "compiles": compiles,
           "device": "default", "worker": f"pid{os.getpid()}"}
    A.save_chunk(w["rdir"], entry["chunk"], out, meta=rec)
    return rec


def _run_chunks_pool(spec: GridSpec, plan, todo, sparse, envelope,
                     backend, workers: int, rdir: str) -> List[dict]:
    import multiprocessing as mp

    ctx = mp.get_context("spawn")   # fork after jax init is unsafe
    n = min(workers, len(todo))
    with ctx.Pool(n, initializer=_worker_init,
                  initargs=(spec.to_json(), sparse, envelope, backend,
                            rdir)) as pool:
        records = pool.map(_worker_run_chunk, [plan[k] for k in todo])
    return records


# --------------------------------------------------------------------------- #
# The farm entry point
# --------------------------------------------------------------------------- #
def run_farm(grid: Union[str, GridSpec, Sequence],
             workers: int = 0,
             chunk_size: int = 16,
             backend: str = "jax",
             incidence: str = "auto",
             unroll="auto",
             quick: bool = False,
             grid_overrides: Optional[dict] = None,
             out_dir: str = A.DEFAULT_RUNS_DIR,
             run_id: Optional[str] = None,
             resume: bool = False,
             artifacts: bool = True) -> dict:
    """Execute a scenario grid as fixed-shape chunks and gather versioned
    artifacts.

    ``grid`` is a registry name (:data:`repro.fabric.scenarios.GRIDS`),
    a :class:`GridSpec`, or a raw scenario list (in-process only — raw
    lists cannot cross to spawn workers).  Returns ``{"run_id",
    "run_dir", "manifest", "results"}`` where ``results`` is the merged
    ``{metric: array[G]}`` table in input order, bit-identical at fixed
    dt to ``run_fabric_sweep(grid)`` run monolithically.

    ``resume=True`` with an existing ``run_id`` skips chunks whose
    shards already load; the manifest records which chunks ran in which
    invocation (``records[k]["worker"]``).  ``artifacts=False`` keeps
    everything in memory (bench/smoke use; implies no resume).
    """
    scens, points, spec = _resolve_grid(grid, quick, grid_overrides)
    if not scens:
        raise ValueError("empty grid")
    if workers > 1 and spec is None:
        warnings.warn("raw scenario lists cannot be shipped to worker "
                      "processes (unpicklable closures); running "
                      "in-process instead — pass a named grid for "
                      "multiprocess dispatch", RuntimeWarning,
                      stacklevel=2)
        workers = 0
    if workers > 1 and not artifacts:
        raise ValueError("multiprocess dispatch requires artifacts "
                         "(workers stream shards to disk)")

    sparse = _pick_sparse(scens, incidence)
    full = V.FabricSweepParams.from_scenarios(scens, sparse=sparse)
    envelope = full.envelope()
    plan = chunk_plan(len(scens), chunk_size)
    fingerprint = A.config_hash(scens)

    rdir = None
    done: List[int] = []
    if artifacts:
        run_id = run_id or A.new_run_id()
        rdir = A.run_dir(run_id, out_dir)
        prev = A.read_manifest(rdir)
        if resume and prev is not None:
            if prev.get("config_hash") != fingerprint:
                raise ValueError(
                    f"resume mismatch: run {run_id} was recorded for a "
                    f"different grid (hash {prev.get('config_hash')} != "
                    f"{fingerprint})")
            done = A.completed_chunks(rdir, len(plan))
        manifest = {
            "run_id": run_id, "status": "running",
            "grid": spec.to_json() if spec else {"name": "<inline>"},
            "n_points": len(scens), "chunk_size": chunk_size,
            "chunks": len(plan), "plan": plan,
            "backend": backend, "engine":
                "sparse" if sparse else "dense",
            "envelope": {k: (bool(v) if isinstance(v, (bool, np.bool_))
                             else int(v)) for k, v in envelope.items()},
            "structure_key": full.structure_key,
            "config_hash": fingerprint, "git_sha": A.git_sha(),
            "workers": workers, "records": (prev or {}).get("records",
                                                            []),
        }
        A.write_manifest(rdir, manifest)
    else:
        manifest = {"run_id": run_id or "<in-memory>",
                    "status": "running", "records": []}

    todo = [e["chunk"] for e in plan if e["chunk"] not in set(done)]
    t0 = time.perf_counter()
    if todo:
        if workers > 1:
            new_recs = _run_chunks_pool(spec, plan, todo, sparse,
                                        envelope, backend, workers,
                                        rdir)
        else:
            new_recs = _run_chunks_inprocess(scens, plan, todo, sparse,
                                             envelope, backend, unroll,
                                             rdir)
    else:
        new_recs = []
    wall = time.perf_counter() - t0

    if rdir is not None:
        results = A.merge_chunks(rdir, plan, len(scens))
        kept = [r for r in manifest["records"]
                if r["chunk"] not in set(todo)]
        manifest["records"] = sorted(kept + new_recs,
                                     key=lambda r: r["chunk"])
        manifest["status"] = "complete"
        manifest["wall_s"] = wall
        manifest["resumed_chunks"] = sorted(done)
        A.write_manifest(rdir, manifest)
    else:
        results: Dict[str, np.ndarray] = {}
        for rec in new_recs:
            out = rec.pop("results")
            for k, v in out.items():
                if k not in results:
                    results[k] = np.zeros((len(scens),) + v.shape[1:],
                                          v.dtype)
                results[k][rec["start"]:rec["stop"]] = v
        manifest["records"] = new_recs
        manifest["status"] = "complete"
        manifest["wall_s"] = wall

    return {"run_id": manifest["run_id"], "run_dir": rdir,
            "manifest": manifest, "results": results,
            "points": points}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.fabric.farm",
        description="Run a scenario grid as a chunked sweep farm.")
    ap.add_argument("--grid", required=True,
                    help="named grid from repro.fabric.scenarios.GRIDS")
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes (<=1: in-process dispatch)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="grid points per chunk")
    ap.add_argument("--backend", default="jax",
                    choices=("jax", "numpy"))
    ap.add_argument("--incidence", default="auto",
                    choices=("auto", "dense", "sparse"))
    ap.add_argument("--quick", action="store_true",
                    help="use the registry's shrunken smoke axes")
    ap.add_argument("--out-dir", default=A.DEFAULT_RUNS_DIR)
    ap.add_argument("--run-id", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="skip chunks whose shards already exist")
    args = ap.parse_args(argv)

    res = run_farm(args.grid, workers=args.workers,
                   chunk_size=args.chunk, backend=args.backend,
                   incidence=args.incidence, quick=args.quick,
                   out_dir=args.out_dir, run_id=args.run_id,
                   resume=args.resume)
    m = res["manifest"]
    ran = [r for r in m["records"] if r["chunk"]
           not in set(m.get("resumed_chunks", []))]
    print(f"run {res['run_id']}: {m['n_points']} points, "
          f"{m['chunks']} chunks ({len(m.get('resumed_chunks', []))} "
          f"resumed), engine={m['engine']}, "
          f"wall={m['wall_s']:.2f}s, "
          f"compiles={sum(r['compiles'] for r in ran)}")
    if res["run_dir"]:
        print(f"artifacts: {res['run_dir']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
