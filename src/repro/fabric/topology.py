"""Leaf–spine and pod-scale Clos topologies for the multi-host RDCA fabric.

A topology is a set of hosts, leaf switches, spine switches — and,
pod-scale, super-spine switches — joined by unidirectional
capacity-annotated links.  :meth:`Topology.route` gives the *static
ECMP* path (flow hashes onto one candidate path; cross-leaf pairs
transit a common spine, cross-pod pairs climb to a super-spine) — the
pre-routing-layer behaviour and still the ``static_ecmp`` baseline.
Dynamic path selection lives in :mod:`repro.fabric.routing`; this
module contributes the *candidate* structure
(:meth:`candidate_spines` / :meth:`candidate_paths`) and per-link
up/down state with scheduled failure events (:meth:`fail_link`) and
periodic flap schedules (:meth:`flap_link`) — both work on any tier —
which the drivers turn into per-tick reroutes under load.

Two preset families:

* :func:`clos` — the classic 2-tier leaf–spine fabric (every leaf wired
  to every spine);
* :func:`make_pod_clos` — a 3-level fabric: ``pods`` pods of
  ``leaves_per_pod`` leaves + ``spines_per_pod`` pod-local spines, with
  a super-spine *plane* per pod-spine index (pod spine ``i`` of every
  pod wires to the plane-``i`` super-spines), per-tier link speeds and
  therefore per-tier oversubscription.

Candidate sets are *wiring-restricted*: a spine is a candidate for a
host pair only if it has links to both endpoints' leaves, so partially
connected fabrics (any leaf not wired to every spine — the normal case
in multi-pod topologies) route correctly instead of raising ``KeyError``
on a nonexistent link; an unroutable pair raises a clear ``ValueError``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

LinkKey = Tuple[str, str]                  # (src node, dst node)

# failure-schedule sentinel for "never" in integer tick space
NEVER_TICK = 1 << 30


@dataclasses.dataclass(frozen=True)
class Link:
    src: str
    dst: str
    gbps: float

    @property
    def key(self) -> LinkKey:
        return (self.src, self.dst)


@dataclasses.dataclass
class Topology:
    hosts: List[str]
    leaves: List[str]
    spines: List[str]
    links: Dict[LinkKey, Link]             # both directions present
    host_leaf: Dict[str, str]              # host -> its leaf
    # 3-level fabrics only: super-spine tier above the pod spines.  A
    # 2-tier fabric leaves this empty and nothing else changes.
    super_spines: List[str] = dataclasses.field(default_factory=list)
    # pod index per leaf/spine (presets fill this; purely informational
    # for single-pod fabrics)
    pod_of: Dict[str, int] = dataclasses.field(default_factory=dict)
    # scheduled failure windows: link key -> (down_at_us, restore_us);
    # a link is down while down_at_us <= t < restore_us
    link_down: Dict[LinkKey, Tuple[float, float]] = \
        dataclasses.field(default_factory=dict)
    # periodic flap schedules (generalized fail_link): link key ->
    # (start_us, period_us, down_us); from start_us the link repeats a
    # period_us cycle — down for the first down_us of each cycle
    link_flaps: Dict[LinkKey, Tuple[float, float, float]] = \
        dataclasses.field(default_factory=dict)

    # -- queries ------------------------------------------------------------
    def link(self, src: str, dst: str) -> Link:
        return self.links[(src, dst)]

    def access_gbps(self, host: str) -> float:
        return self.links[(host, self.host_leaf[host])].gbps

    def uplinks(self, leaf: str) -> List[Link]:
        return [l for l in self.links.values()
                if l.src == leaf and l.dst in self.spines]

    def super_uplinks(self, spine: str) -> List[Link]:
        """Spine -> super-spine links (empty on 2-tier fabrics)."""
        ss = set(self.super_spines)
        return [l for l in self.links.values()
                if l.src == spine and l.dst in ss]

    def fabric_uplinks(self) -> List[Link]:
        """All upward-facing fabric links: leaf->spine on every fabric
        plus spine->super-spine on 3-level fabrics — the link set the
        drivers track for uplink utilization/imbalance."""
        out = [l for leaf in self.leaves for l in self.uplinks(leaf)]
        if self.super_spines:
            out += [l for s in self.spines for l in self.super_uplinks(s)]
        return out

    def hosts_on(self, leaf: str) -> List[str]:
        return [h for h in self.hosts if self.host_leaf[h] == leaf]

    def oversubscription(self, leaf: str) -> float:
        """Host-facing bandwidth / spine-facing bandwidth (1.0 = rearrange-
        ably non-blocking, >1 = oversubscribed)."""
        down = sum(self.links[(h, leaf)].gbps for h in self.hosts_on(leaf))
        up = sum(l.gbps for l in self.uplinks(leaf))
        return down / up if up else float("inf")

    def spine_oversubscription(self, spine: str) -> float:
        """Leaf-facing bandwidth / super-spine-facing bandwidth of a pod
        spine — the tier-2 analogue of :meth:`oversubscription`."""
        ss = set(self.super_spines)
        down = sum(l.gbps for l in self.links.values()
                   if l.src == spine and l.dst in self.leaves)
        up = sum(l.gbps for l in self.links.values()
                 if l.src == spine and l.dst in ss)
        return down / up if up else float("inf")

    def bisection_gbps(self) -> float:
        """Aggregate leaf->spine capacity (the fabric's bisection)."""
        return sum(l.gbps for leaf in self.leaves for l in self.uplinks(leaf))

    def candidate_paths(self, src_host: str, dst_host: str) \
            -> List[List[str]]:
        """Interior (leaf..leaf) candidate node paths for a host pair,
        restricted to wired links.  ``[]`` for intra-leaf pairs;
        ``[sl, spine, dl]`` triples when a common spine exists;
        ``[sl, spineA, ss, spineB, dl]`` five-tuples through the
        super-spine tier otherwise.  Raises a clear ``ValueError`` when
        the pair is unroutable (no common spine and no super-spine
        path)."""
        sl, dl = self.host_leaf[src_host], self.host_leaf[dst_host]
        if sl == dl:
            return []
        common = [s for s in self.spines
                  if (sl, s) in self.links and (s, dl) in self.links]
        if common:
            return [[sl, s, dl] for s in common]
        out: List[List[str]] = []
        for ss in self.super_spines:
            ups = [s for s in self.spines
                   if (sl, s) in self.links and (s, ss) in self.links]
            dns = [s for s in self.spines
                   if (ss, s) in self.links and (s, dl) in self.links]
            out += [[sl, sa, ss, sb, dl] for sa in ups for sb in dns]
        if not out:
            raise ValueError(
                f"no spine or super-spine path connects {sl} and {dl} "
                f"(pair {src_host}->{dst_host} is unroutable)")
        return out

    def route(self, src_host: str, dst_host: str, flow_id: int) -> List[str]:
        """Node path for a flow; ECMP picks among the wired candidate
        paths by flow-id hash (on a fully-wired 2-tier Clos this is the
        classic spine = spines[flow_id % n_spines] pick)."""
        if src_host == dst_host:
            raise ValueError("flow endpoints must differ")
        sl = self.host_leaf[src_host]
        if sl == self.host_leaf[dst_host]:
            return [src_host, sl, dst_host]
        paths = self.candidate_paths(src_host, dst_host)
        return [src_host] + paths[flow_id % len(paths)] + [dst_host]

    def route_links(self, src_host: str, dst_host: str,
                    flow_id: int) -> List[Link]:
        nodes = self.route(src_host, dst_host, flow_id)
        return [self.links[(a, b)] for a, b in zip(nodes, nodes[1:])]

    def candidate_spines(self, src_host: str, dst_host: str) -> List[str]:
        """Spines that can carry this pair's traffic (the ECMP candidate
        set a dynamic routing mode chooses from), restricted to spines
        with wired links to *both* endpoints' leaves; empty for
        intra-leaf pairs (which never transit a spine) and for
        cross-pod pairs (whose candidates are super-spine paths — see
        :meth:`candidate_paths`)."""
        sl = self.host_leaf[src_host]
        dl = self.host_leaf[dst_host]
        if sl == dl:
            return []
        return [s for s in self.spines
                if (sl, s) in self.links and (s, dl) in self.links]

    # -- link failure schedule ----------------------------------------------
    def fail_link(self, src: str, dst: str, at_us: float,
                  restore_us: float = math.inf,
                  bidi: bool = True) -> "Topology":
        """Schedule a link failure: ``(src, dst)`` goes down at ``at_us``
        and comes back at ``restore_us`` (default: never).  ``bidi``
        fails the reverse direction too — the physical-link semantics.
        Returns ``self`` for chaining."""
        if (src, dst) not in self.links:
            raise ValueError(f"no link {src}->{dst} to fail")
        if at_us < 0.0 or restore_us <= at_us:
            raise ValueError("need 0 <= at_us < restore_us")
        self.link_down[(src, dst)] = (at_us, restore_us)
        if bidi:
            self.link_down[(dst, src)] = (at_us, restore_us)
        return self

    def flap_link(self, src: str, dst: str, start_us: float,
                  period_us: float, down_us: float,
                  bidi: bool = True) -> "Topology":
        """Schedule a periodic link flap: from ``start_us`` the link
        repeats a ``period_us`` cycle, down for the first ``down_us``
        of each cycle (in-flight bytes drop on every falling edge).
        Returns ``self`` for chaining."""
        if (src, dst) not in self.links:
            raise ValueError(f"no link {src}->{dst} to flap")
        if start_us < 0.0 or not 0.0 < down_us < period_us:
            raise ValueError("need start_us >= 0 and 0 < down_us "
                             "< period_us")
        self.link_flaps[(src, dst)] = (start_us, period_us, down_us)
        if bidi:
            self.link_flaps[(dst, src)] = (start_us, period_us, down_us)
        return self

    def link_up_at(self, key: LinkKey, now_us: float) -> bool:
        w = self.link_down.get(key)
        if w is not None and w[0] <= now_us < w[1]:
            return False
        f = self.link_flaps.get(key)
        if f is not None and now_us >= f[0] \
                and (now_us - f[0]) % f[1] < f[2]:
            return False
        return True

    def failure_ticks(self, dt_us: float) -> Dict[LinkKey,
                                                  Tuple[int, int]]:
        """Failure windows as integer tick indices (down while
        ``at <= t < until``); ``NEVER_TICK`` encodes +inf so every
        engine compares the same int32-safe values."""
        out = {}
        for key, (a, u) in self.link_down.items():
            at = max(0, int(round(a / dt_us)))
            until = NEVER_TICK if math.isinf(u) \
                else max(at + 1, int(round(u / dt_us)))
            out[key] = (at, until)
        return out

    def flap_ticks(self, dt_us: float) -> Dict[LinkKey,
                                               Tuple[int, int, int]]:
        """Flap schedules as integer tick triples ``(start, period,
        down)``; down while ``t >= start and (t - start) % period <
        down`` — the contract every engine shares (see
        :func:`repro.fabric.faults.flap_down_now`)."""
        out = {}
        for key, (s, p, d) in self.link_flaps.items():
            start = max(0, int(round(s / dt_us)))
            period = max(2, int(round(p / dt_us)))
            down = min(period - 1, max(1, int(round(d / dt_us))))
            out[key] = (start, period, down)
        return out

    # -- invariants ----------------------------------------------------------
    def validate(self) -> None:
        names = self.hosts + self.leaves + self.spines + self.super_spines
        if len(set(names)) != len(names):
            raise ValueError("node names must be unique")
        for h in self.hosts:
            leaf = self.host_leaf.get(h)
            if leaf not in self.leaves:
                raise ValueError(f"host {h} not attached to a leaf")
            if (h, leaf) not in self.links or (leaf, h) not in self.links:
                raise ValueError(f"host {h} missing bidirectional access "
                                 "link")
        for (src, dst), l in self.links.items():
            if (l.src, l.dst) != (src, dst):
                raise ValueError(f"link key {src}->{dst} mismatches payload")
            if l.gbps <= 0:
                raise ValueError(f"link {src}->{dst} has non-positive rate")
            if (dst, src) not in self.links:
                raise ValueError(f"link {src}->{dst} has no reverse link")
        # Partial leaf<->spine wiring is legal (the normal case in
        # multi-pod fabrics) — candidate sets are wiring-restricted and
        # route() raises on unroutable pairs.  Structurally we only
        # require each fabric switch to be wired at all.
        spine_set, ss_set = set(self.spines), set(self.super_spines)
        for s in self.spines:
            if not any(l.dst == s and l.src in self.leaves
                       for l in self.links.values()):
                raise ValueError(f"spine {s} not connected to any leaf")
        for ss in self.super_spines:
            if not any(l.dst == ss and l.src in spine_set
                       for l in self.links.values()):
                raise ValueError(f"super-spine {ss} not connected to any "
                                 "spine")
        if ss_set and not spine_set:
            raise ValueError("super-spines require a spine tier")
        if len(self.leaves) > 1 and not self.spines:
            raise ValueError("multi-leaf topology requires spines")
        for key in self.link_down:
            if key not in self.links:
                raise ValueError(f"failure scheduled on unknown link "
                                 f"{key[0]}->{key[1]}")
        for key in self.link_flaps:
            if key not in self.links:
                raise ValueError(f"flap scheduled on unknown link "
                                 f"{key[0]}->{key[1]}")


def _bidi(links: Dict[LinkKey, Link], a: str, b: str, gbps: float) -> None:
    links[(a, b)] = Link(a, b, gbps)
    links[(b, a)] = Link(b, a, gbps)


# --------------------------------------------------------------------------- #
# Presets
# --------------------------------------------------------------------------- #
def clos(n_leaves: int = 2, hosts_per_leaf: int = 4, n_spines: int = 2,
         host_gbps: float = 200.0, uplink_gbps: float = 400.0) -> Topology:
    """Generic two-tier Clos: ``n_leaves`` leaves x ``hosts_per_leaf`` hosts,
    each leaf wired to every spine at ``uplink_gbps``."""
    if n_leaves < 1 or hosts_per_leaf < 1 or n_spines < 0:
        raise ValueError("invalid Clos dimensions")
    hosts, leaves, spines = [], [], []
    links: Dict[LinkKey, Link] = {}
    host_leaf: Dict[str, str] = {}
    for li in range(n_leaves):
        leaf = f"leaf{li}"
        leaves.append(leaf)
        for hi in range(hosts_per_leaf):
            h = f"h{li}_{hi}"
            hosts.append(h)
            host_leaf[h] = leaf
            _bidi(links, h, leaf, host_gbps)
    for si in range(n_spines):
        spine = f"spine{si}"
        spines.append(spine)
        for leaf in leaves:
            _bidi(links, leaf, spine, uplink_gbps)
    topo = Topology(hosts, leaves, spines, links, host_leaf)
    topo.validate()
    return topo


def jet_testbed(n_hosts: int = 2, host_gbps: float = 200.0) -> Topology:
    """The paper's measurement testbed: hosts under a single switch
    (2x100 Gbps dual-port NICs -> 200 Gbps access links, §2.1)."""
    return clos(n_leaves=1, hosts_per_leaf=n_hosts, n_spines=0,
                host_gbps=host_gbps)


def incast_fabric(n_senders: int, host_gbps: float = 200.0,
                  uplink_gbps: float = 800.0,
                  extra_receivers: int = 1) -> Topology:
    """Senders on one leaf, receiver(s) on another — the paper's storage
    incast shape.  ``extra_receivers`` >= 1 leaves room for a victim flow's
    receiver next to the incast target."""
    return clos(n_leaves=2, hosts_per_leaf=max(n_senders,
                                               1 + extra_receivers),
                n_spines=2, host_gbps=host_gbps, uplink_gbps=uplink_gbps)


def make_pod_clos(pods: int, leaves_per_pod: int, hosts_per_leaf: int,
                  spines_per_pod: int = 2, sspines_per_plane: int = 1,
                  host_gbps: float = 100.0, leaf_spine_gbps: float = 200.0,
                  spine_sspine_gbps: float = 400.0) -> Topology:
    """Pod-scale 3-level Clos.

    Each pod is a fully-wired 2-tier Clos of ``leaves_per_pod`` leaves
    (``hosts_per_leaf`` hosts each) and ``spines_per_pod`` pod-local
    spines.  Above the pods sit super-spine *planes*: pod spine ``i``
    of every pod wires to the ``sspines_per_plane`` super-spines of
    plane ``i`` — the standard plane-aligned wiring, which means
    choosing the source pod's spine chooses the plane, and the rest of
    a cross-pod path is determined.  Per-tier link speeds give per-tier
    oversubscription (:meth:`Topology.oversubscription` at the leaf,
    :meth:`Topology.spine_oversubscription` at the pod spine).

    Node naming: host ``p{pod}h{leaf}_{i}``, leaf ``p{pod}l{leaf}``,
    spine ``p{pod}s{i}``, super-spine ``ss{plane}`` (or
    ``ss{plane}_{k}`` when ``sspines_per_plane > 1``).

    ``pods == 1`` builds a plain 2-tier pod (no super-spine tier).
    """
    if pods < 1 or leaves_per_pod < 1 or hosts_per_leaf < 1 \
            or spines_per_pod < 1 or sspines_per_plane < 1:
        raise ValueError("invalid pod-Clos dimensions")
    hosts, leaves, spines, sspines = [], [], [], []
    links: Dict[LinkKey, Link] = {}
    host_leaf: Dict[str, str] = {}
    pod_of: Dict[str, int] = {}
    for pi in range(pods):
        pod_leaves = []
        for li in range(leaves_per_pod):
            leaf = f"p{pi}l{li}"
            leaves.append(leaf)
            pod_leaves.append(leaf)
            pod_of[leaf] = pi
            for hi in range(hosts_per_leaf):
                h = f"p{pi}h{li}_{hi}"
                hosts.append(h)
                host_leaf[h] = leaf
                _bidi(links, h, leaf, host_gbps)
        for si in range(spines_per_pod):
            spine = f"p{pi}s{si}"
            spines.append(spine)
            pod_of[spine] = pi
            for leaf in pod_leaves:
                _bidi(links, leaf, spine, leaf_spine_gbps)
    if pods > 1:
        for plane in range(spines_per_pod):
            for k in range(sspines_per_plane):
                ss = f"ss{plane}" if sspines_per_plane == 1 \
                    else f"ss{plane}_{k}"
                sspines.append(ss)
                for pi in range(pods):
                    _bidi(links, f"p{pi}s{plane}", ss, spine_sspine_gbps)
    topo = Topology(hosts, leaves, spines, links, host_leaf,
                    super_spines=sspines, pod_of=pod_of)
    topo.validate()
    return topo
