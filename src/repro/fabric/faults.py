"""Fault injection and loss recovery for the fabric.

Two halves, deliberately split:

**Injection** (`FaultConfig`, the hash helpers): per-link stochastic
loss and receiver-side corruption, link flap schedules (held on
:class:`~repro.fabric.topology.Topology`, generalizing ``fail_link``),
and NIC/host crash--restart events that zero a receiver's admission
state mid-transfer.  All randomness is *counter-based*: a fault fires
iff ``hash(tick, link_salt) < floor(rate * 65536)``, where the salt is
derived from the link name and the config seed at setup time.  The
hash is pure modular int arithmetic (the vector engines evaluate it
with a split-modmul decomposition that stays int32-exact at any tick
count), so the scalar driver, the batched-numpy engine, and the jax
engine see bit-identical fault realizations — fault runs stay
equivalence-testable, and per-point fault parameters ride the sweep
axes like every other knob.

**Recovery** (`FlowRecovery`): the sender-side ledger that replaces
the fluid core's instant drop-re-credit when a flow has a message
config and a :class:`FaultConfig` is attached.  Lost bytes accumulate
in the ledger and are re-credited to the sender only when a
retransmission fires: after an RTO with exponential backoff under
``go_back_n`` (where every byte arriving while the receiver window is
gapped is also discarded as a duplicate), or after a short NACK delay
under IRN-style ``selective`` (only the lost span replays; arrivals
keep landing).  This class is the scalar reference semantics — the
vector engines carry the same state machine as ``[G, F]`` arrays.

A small PFC-deadlock watchdog (`has_pause_cycle`) rounds out the
graceful-degradation metrics: it detects cyclic pause dependencies in
the per-TC pause state each tick.  The vector engines run the same
predicate as boolean-matrix squaring over the precomputed pause-pair
graph (``repro.fabric.fused.cycle_flags``), so ``deadlock_ticks`` is
engine-equivalent and rides sweep grids.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, Iterable, Optional, Tuple

HASH_MOD = 65536          # hash range; power of two -> exact in f32/f64
_LOSS_MULT = 40503        # tick multiplier, loss stream (routing.py idiom)
_CORRUPT_MULT = 24593     # tick multiplier, corruption stream
_SALT_MULT = 9973


def link_salt(src: str, dst: str, seed: int) -> int:
    """Per-link, per-seed salt in [0, 65536) from the link *name* —
    computable identically at scalar setup and vector pack time."""
    base = zlib.crc32(f"{src}->{dst}".encode()) % HASH_MOD
    return int((base + int(seed) * 7919) % HASH_MOD)


def loss_threshold(rate: float) -> int:
    """``floor(rate * 65536)``: 0.0 never fires, 1.0 always fires."""
    return int(math.floor(float(rate) * HASH_MOD))


def fault_hash(t: int, salt: int) -> int:
    """Counter-based loss hash (vector.py evaluates the same value via
    a high/low split of ``t`` so int32 never overflows)."""
    return ((t + 1) * _LOSS_MULT + (salt + 1) * _SALT_MULT) % HASH_MOD


def corrupt_hash(t: int, salt: int) -> int:
    """Independent stream for receiver-side corruption (CRC fail)."""
    return ((t + 1) * _CORRUPT_MULT + (salt + 1) * _SALT_MULT) % HASH_MOD


def flap_down_now(t: int, start: int, period: int, down: int) -> bool:
    """Is a flapping link down at tick ``t``?  The link repeats a
    ``period``-tick cycle from ``start``: down for the first ``down``
    ticks of each cycle, up for the rest."""
    return t >= start and (t - start) % period < down


def flap_edge(t: int, start: int, period: int) -> bool:
    """First down-tick of a flap cycle (in-flight bytes drop here)."""
    return t >= start and (t - start) % period == 0


@dataclasses.dataclass
class FaultConfig:
    """Stochastic fault injection knobs for one fabric run.

    Attaching any ``FaultConfig`` to ``FabricConfig.faults`` — even an
    all-zero one — also *engages* the recovery ledger for every flow
    that carries a message config (``MessageConfig.recovery`` picks
    go-back-N vs selective); flows without one keep the fluid core's
    instant drop-re-credit.  ``faults=None`` is bit-equal to the
    pre-fault engines.

    - ``loss_rate``: per-tick probability that a link drops everything
      it drained that tick (fluid burst loss; the expected *byte* loss
      fraction equals the rate).  Applied to every link.
    - ``corrupt_rate``: an independent second stream applied only to
      the receiver access links (stage 3) — modeling CRC failures at
      the NIC; same drop effect, different realization.
    - ``link_loss``: per-link ``(src, dst) -> rate`` overrides.
    - ``crashes``: ``host -> (at_us, restart_us)``: at ``at_us`` the
      receiver's in-flight admission state is zeroed and everything
      queued on its access link is dropped; arrivals are discarded
      until ``restart_us``.
    - ``seed`` perturbs every link's hash salt; ``mtu_bytes`` converts
      dropped bytes into the ``dropped_pkts`` metric.
    """

    loss_rate: float = 0.0
    corrupt_rate: float = 0.0
    link_loss: Dict[Tuple[str, str], float] = \
        dataclasses.field(default_factory=dict)
    crashes: Dict[str, Tuple[float, float]] = \
        dataclasses.field(default_factory=dict)
    seed: int = 0
    mtu_bytes: float = 4096.0

    def __post_init__(self) -> None:
        for name, r in (("loss_rate", self.loss_rate),
                        ("corrupt_rate", self.corrupt_rate)):
            if not 0.0 <= float(r) <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {r!r}")
        for k, r in self.link_loss.items():
            if not 0.0 <= float(r) <= 1.0:
                raise ValueError(
                    f"link_loss[{k!r}] must be in [0, 1], got {r!r}")
        for host, (at, until) in self.crashes.items():
            if not (0.0 <= at < until):
                raise ValueError(
                    f"crash window for {host!r} needs 0 <= at < "
                    f"restart, got ({at!r}, {until!r})")
        if self.mtu_bytes <= 0:
            raise ValueError(f"mtu_bytes must be > 0, got {self.mtu_bytes!r}")

    def crash(self, host: str, at_us: float,
              restart_us: float) -> "FaultConfig":
        """Schedule a crash--restart window (chainable)."""
        self.crashes[host] = (float(at_us), float(restart_us))
        return self

    def rate_for(self, src: str, dst: str) -> float:
        return float(self.link_loss.get((src, dst), self.loss_rate))

    @property
    def any_loss(self) -> bool:
        return (self.loss_rate > 0.0 or self.corrupt_rate > 0.0
                or any(r > 0.0 for r in self.link_loss.values()))


class FlowRecovery:
    """Per-flow sender-side loss-recovery ledger (scalar reference).

    The fluid analogue of a retransmission queue: ``lost`` bytes wait
    in the ledger; when the timer fires they are re-credited to the
    sender (``injected -= lost``) so the rate machine replays them,
    and counted as ``retransmit_bytes``.  go-back-N gaps the receiver
    window — every byte arriving while gapped is a duplicate of the
    pre-loss prefix, discarded and added to the ledger — and backs the
    RTO off exponentially (``rto_us * backoff**k``, ``k`` capped and
    reset on delivery progress).  Selective (IRN) keeps arrivals and
    replays only the lost span after a fixed NACK delay.

    Timers run in whole ticks; with the default power-of-two backoff
    the deadline arithmetic is exact in float32, so the jax engine
    fires on the same tick as this class.
    """

    __slots__ = ("sel", "rto_ticks", "nack_ticks", "mult", "cap",
                 "lost", "timer", "k", "gapped", "retx_bytes",
                 "dup_bytes")

    def __init__(self, *, selective: bool, rto_us: float, backoff: float,
                 cap: int, nack_us: float, dt_us: float):
        self.sel = bool(selective)
        self.rto_ticks = max(1, int(round(rto_us / dt_us)))
        self.nack_ticks = max(1, int(round(nack_us / dt_us)))
        self.mult = float(backoff)
        self.cap = int(cap)
        self.lost = 0.0
        self.timer = 0
        self.k = 0
        self.gapped = False
        self.retx_bytes = 0.0
        self.dup_bytes = 0.0

    @classmethod
    def from_msg(cls, mcfg, dt_us: float) -> "FlowRecovery":
        return cls(selective=(mcfg.recovery == "selective"),
                   rto_us=mcfg.rto_us, backoff=mcfg.rto_backoff,
                   cap=mcfg.rto_cap, nack_us=mcfg.nack_us, dt_us=dt_us)

    def on_loss(self, b: float) -> None:
        """Bytes dropped somewhere on the wire for this flow."""
        if b <= 0.0:
            return
        self.lost += b
        if not self.sel:
            self.gapped = True

    def on_arrival(self, b: float) -> float:
        """Bytes reaching the receiver; returns the bytes admitted.
        While a go-back-N window is gapped, everything is a duplicate:
        discarded and appended to the retransmit ledger."""
        if self.gapped and b > 0.0:
            self.dup_bytes += b
            self.lost += b
            return 0.0
        return b

    def deadline_ticks(self) -> int:
        if self.sel:
            return self.nack_ticks
        return int(self.rto_ticks * (self.mult ** min(self.k, self.cap)))

    def tick(self, progressed: bool) -> float:
        """Advance one tick; returns the bytes to re-credit to the
        sender (nonzero exactly when the retransmit timer fires)."""
        if progressed:
            self.k = 0
        if self.lost <= 0.0:
            self.timer = 0
            return 0.0
        self.timer += 1
        if self.timer < self.deadline_ticks():
            return 0.0
        fire = self.lost
        self.lost = 0.0
        self.timer = 0
        self.gapped = False
        if not self.sel:
            self.k = min(self.k + 1, self.cap)
        self.retx_bytes += fire
        return fire


def has_pause_cycle(pairs: Iterable) -> bool:
    """PFC-deadlock watchdog: do the currently-paused ``(link, tc)``
    pairs contain a cyclic pause dependency within any single traffic
    class?  A paused link ``u -> v`` means ``u`` cannot drain until
    ``v`` unpauses it (edge ``u -> v`` in the dependency digraph); a
    cycle is the classic PFC deadlock precondition."""
    by_tc: Dict[int, Dict[str, set]] = {}
    for link, tc in pairs:
        u, v = link[0], link[1]
        by_tc.setdefault(tc, {}).setdefault(u, set()).add(v)
    for adj in by_tc.values():
        color: Dict[str, int] = {}
        for root in list(adj):
            if color.get(root):
                continue
            color[root] = 1
            stack = [(root, iter(adj.get(root, ())))]
            while stack:
                node, it = stack[-1]
                advanced = False
                for nxt in it:
                    c = color.get(nxt, 0)
                    if c == 1:
                        return True
                    if c == 0:
                        color[nxt] = 1
                        stack.append((nxt, iter(adj.get(nxt, ()))))
                        advanced = True
                        break
                if not advanced:
                    color[node] = 2
                    stack.pop()
    return False
