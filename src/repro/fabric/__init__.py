"""Multi-host RDCA fabric: Clos topologies, switches, hosts, driver, sweep.

- topology:  leaf–spine Clos graphs + presets (jet_testbed, incast_fabric)
- switch:    output-queued switch (per-port ECN marking, PFC propagation)
- hosts:     step-able ReceiverHost (the refactored run_sim tick body) and
             DCQCN SenderHost
- fabric:    multi-host discrete-event driver -> per-host SimResults +
             fabric metrics (victim goodput, pause fan-out, incast FCT)
- scenarios: incast-N / all-to-all HPC / storage OLTP-OLAP-backup bundles
- sweep:     vectorized parameter-sweep engine (jax.vmap + lax.scan over
             stacked per-host fluid state; numpy reference backend)
"""
from .fabric import FabricConfig, FabricResult, Flow, run_fabric
from .hosts import HostFeedback, ReceiverHost, SenderHost
from .scenarios import Scenario, all_to_all, incast, single_pair, storage_mix
from .switch import OutputPort, Switch, SwitchConfig
from .sweep import SweepParams, grid_configs, run_sweep
from .topology import Link, Topology, clos, incast_fabric, jet_testbed

__all__ = [
    "FabricConfig", "FabricResult", "Flow", "HostFeedback", "Link",
    "OutputPort", "ReceiverHost", "Scenario", "SenderHost", "Switch",
    "SwitchConfig", "SweepParams", "Topology", "all_to_all", "clos",
    "grid_configs", "incast", "incast_fabric", "jet_testbed", "run_fabric",
    "run_sweep", "single_pair", "storage_mix",
]
