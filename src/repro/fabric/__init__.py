"""Multi-host RDCA fabric: Clos topologies, switches, hosts, driver, sweeps.

- topology:  leaf–spine Clos graphs + presets (jet_testbed, incast_fabric)
- switch:    output-queued switch with per-traffic-class queues (one
             FIFO + buffer partition + ECN knee + PFC xoff/xon pair per
             TC; pause targets are `(ingress link, tc)` pairs, 802.1Qbb
             style; `SwitchConfig.per_tc=False` restores the legacy
             whole-link pause for comparison baselines)
- hosts:     step-able ReceiverHost (wrapping the shared
             `repro.core.datapath.HostDatapath` — the same QoS admission/
             escape/recycle machine behind run_sim and JetService) and
             DCQCN SenderHost
- fabric:    scalar multi-host driver -> per-host SimResults + fabric
             metrics (victim goodput, pause fan-out + per-TC pause
             breakdown, incast FCT); `Flow.qos` selects both the
             receiver admission class and the switch queue along the
             route, escape-ladder ECN comes back as CNPs, and NP->RP
             CNP propagation delay is per flow (`Flow.cnp_delay_us`
             falling back to `FabricConfig.cnp_delay_us`); burst-train
             sources via `Flow.on_off_us`
- scenarios: incast-N / all-to-all HPC / storage OLTP-OLAP-backup /
             mixed Jet+DDIO fleet / QoS-mixed storage (LOW bulk incast
             + HIGH on-off OLTP + NORMAL OLAP, per-TC vs per-link
             pause) bundles + fabric_grid / mixed_fleet_grid /
             qos_mixed_grid for building scenario grids
- sweep:     vectorized receiver-datapath grid (jax.vmap + lax.scan over
             stacked single-host fluid state; numpy reference backend)
- vector:    vectorized *fabric* grid — the whole multi-host tick body
             (flows x ports x receivers, with the HostDatapath QoS
             classes as a stacked [G, Q, R] block and a per-flow
             CNP-delay ring) as one vmap+scan program; switch state is
             classed too ([G, Q, P] occupancy/assert/pause via the
             flow->TC one-hot, priority-unrolled drain grants)
- _scan:     shared lax.scan compile-cost machinery (unroll autotune,
             donated carries)

Which engine advances which datapath backend: the scalar driver steps
real ``HostDatapath`` objects (float64 Python, via ``ReceiverHost``);
``run_sweep`` and ``run_fabric_sweep`` advance the equivalent stacked-
array recurrence (batched-numpy float64 reference / jax float32
vmap+scan), verified against the scalar machine in the test suite.

Choosing an engine
------------------
``run_fabric`` (scalar driver)
    One scenario at a time, Python objects, float64.  The semantic
    reference: returns full per-host :class:`~repro.core.simulator
    .SimResult` (including message latency percentiles) and per-link
    pause breakdowns.  Also the only engine for things that resist
    stacking, e.g. ``cpu_membw_schedule`` callables.  Seconds per point.

``run_sweep`` (datapath sweep)
    Grids over *receiver* ``SimConfig`` knobs with the single-host
    sender model (no switches, no cross-flow coupling).  Cheapest per
    point; use it to map the receiver datapath (DDIO knee, pool sizing,
    DCQCN constants) before involving a fabric.

``run_fabric_sweep`` (fabric sweep)
    Grids over whole scenarios — topology rates, switch config, per-flow
    offered/burst/start, per-receiver knobs — with every flow, port and
    receiver advanced together ([G, F] / [G, P, F] / [G, R] arrays).
    Matches the scalar driver to float32 round-off (float64 exact via
    ``backend="numpy"``) and turns minutes-per-grid into seconds.  Grid
    points must share topology *structure* (same flows/routes/ticks).

Per-TC queue support across engines
-----------------------------------
Every engine implements the classed switch identically (the test suite
in ``tests/test_pfc_priority.py`` holds them together): per-TC FIFOs
with their own buffer partition, ECN knee and PFC xoff/xon watermarks,
strict-priority drain, and ``(ingress link, tc)`` pause targeting.
``Flow.qos`` selects the class end to end — switch queue on every hop
*and* receiver RNIC admission class.  The scalar driver additionally
reports the per-``(link, tc)`` pause breakdown
(``FabricResult.pause_tc_us``); the vector engines aggregate it to a
per-class total (``pause_tc_total_us``, [G, Q]).  ``per_tc`` and the
``tc_*`` watermark overrides are plain per-point parameters, so one
sweep grid can compare 802.1Qbb pause against the legacy whole-link
pause (``SwitchConfig.per_tc=False``, which is bit-equal to the
pre-refactor switch for single-class traffic in every engine).
"""
from .fabric import (FabricConfig, FabricResult, Flow, burst_done_bytes,
                     run_fabric)
from .hosts import HostFeedback, ReceiverHost, SenderHost
from .scenarios import (Scenario, all_to_all, fabric_grid, incast,
                        mixed_fleet, mixed_fleet_grid, qos_mixed_grid,
                        qos_mixed_storage, single_pair, storage_mix)
from .switch import OutputPort, Switch, SwitchConfig
from .sweep import SweepParams, grid_configs, run_sweep
from .topology import Link, Topology, clos, incast_fabric, jet_testbed
from .vector import FabricSweepParams, run_fabric_sweep

__all__ = [
    "FabricConfig", "FabricResult", "FabricSweepParams", "Flow",
    "HostFeedback", "Link", "OutputPort", "ReceiverHost", "Scenario",
    "SenderHost", "Switch", "SwitchConfig", "SweepParams", "Topology",
    "all_to_all", "burst_done_bytes", "clos", "fabric_grid",
    "grid_configs", "incast", "incast_fabric", "jet_testbed",
    "mixed_fleet", "mixed_fleet_grid", "qos_mixed_grid",
    "qos_mixed_storage", "run_fabric", "run_fabric_sweep", "run_sweep",
    "single_pair", "storage_mix",
]
