"""Multi-host RDCA fabric: Clos topologies, switches, hosts, driver, sweeps.

- topology:  leaf–spine Clos graphs + presets (jet_testbed, incast_fabric)
- switch:    output-queued switch (per-port ECN marking, PFC propagation)
- hosts:     step-able ReceiverHost (wrapping the shared
             `repro.core.datapath.HostDatapath` — the same QoS admission/
             escape/recycle machine behind run_sim and JetService) and
             DCQCN SenderHost
- fabric:    scalar multi-host driver -> per-host SimResults + fabric
             metrics (victim goodput, pause fan-out, incast FCT); flows
             carry a QoS class into receiver admission, escape-ladder
             ECN comes back as CNPs, `cnp_delay_us` models NP->RP
             propagation
- scenarios: incast-N / all-to-all HPC / storage OLTP-OLAP-backup /
             mixed Jet+DDIO fleet bundles + fabric_grid /
             mixed_fleet_grid for building scenario grids
- sweep:     vectorized receiver-datapath grid (jax.vmap + lax.scan over
             stacked single-host fluid state; numpy reference backend)
- vector:    vectorized *fabric* grid — the whole multi-host tick body
             (flows x ports x receivers, with the HostDatapath QoS
             classes as a stacked [G, Q, R] block and a CNP-delay ring)
             as one vmap+scan program
- _scan:     shared lax.scan compile-cost machinery (unroll autotune,
             donated carries)

Which engine advances which datapath backend: the scalar driver steps
real ``HostDatapath`` objects (float64 Python, via ``ReceiverHost``);
``run_sweep`` and ``run_fabric_sweep`` advance the equivalent stacked-
array recurrence (batched-numpy float64 reference / jax float32
vmap+scan), verified against the scalar machine in the test suite.

Choosing an engine
------------------
``run_fabric`` (scalar driver)
    One scenario at a time, Python objects, float64.  The semantic
    reference: returns full per-host :class:`~repro.core.simulator
    .SimResult` (including message latency percentiles) and per-link
    pause breakdowns.  Also the only engine for things that resist
    stacking, e.g. ``cpu_membw_schedule`` callables.  Seconds per point.

``run_sweep`` (datapath sweep)
    Grids over *receiver* ``SimConfig`` knobs with the single-host
    sender model (no switches, no cross-flow coupling).  Cheapest per
    point; use it to map the receiver datapath (DDIO knee, pool sizing,
    DCQCN constants) before involving a fabric.

``run_fabric_sweep`` (fabric sweep)
    Grids over whole scenarios — topology rates, switch config, per-flow
    offered/burst/start, per-receiver knobs — with every flow, port and
    receiver advanced together ([G, F] / [G, P, F] / [G, R] arrays).
    Matches the scalar driver to float32 round-off (float64 exact via
    ``backend="numpy"``) and turns minutes-per-grid into seconds.  Grid
    points must share topology *structure* (same flows/routes/ticks).
"""
from .fabric import (FabricConfig, FabricResult, Flow, burst_done_bytes,
                     run_fabric)
from .hosts import HostFeedback, ReceiverHost, SenderHost
from .scenarios import (Scenario, all_to_all, fabric_grid, incast,
                        mixed_fleet, mixed_fleet_grid, single_pair,
                        storage_mix)
from .switch import OutputPort, Switch, SwitchConfig
from .sweep import SweepParams, grid_configs, run_sweep
from .topology import Link, Topology, clos, incast_fabric, jet_testbed
from .vector import FabricSweepParams, run_fabric_sweep

__all__ = [
    "FabricConfig", "FabricResult", "FabricSweepParams", "Flow",
    "HostFeedback", "Link", "OutputPort", "ReceiverHost", "Scenario",
    "SenderHost", "Switch", "SwitchConfig", "SweepParams", "Topology",
    "all_to_all", "burst_done_bytes", "clos", "fabric_grid",
    "grid_configs", "incast", "incast_fabric", "jet_testbed",
    "mixed_fleet", "mixed_fleet_grid", "run_fabric",
    "run_fabric_sweep", "run_sweep", "single_pair", "storage_mix",
]
