"""Multi-host RDCA fabric: Clos topologies, switches, hosts, driver, sweeps.

- topology:  leaf–spine Clos graphs + presets (jet_testbed, incast_fabric)
             and 3-level pod-of-pods fabrics (`make_pod_clos`), with
             per-link up/down state and scheduled failure events
             (`Topology.fail_link` / `flap_link`) — see "Choosing a
             topology" below
- routing:   first-class per-tick path selection (`RoutingConfig`):
             static ECMP / flowlet-weighted ECMP / adaptive
             least-congested / packet spray, with link-failure rerouting
             — see "The routing layer" below
- switch:    output-queued switch with per-traffic-class queues (one
             FIFO + buffer partition + ECN knee + PFC xoff/xon pair per
             TC; pause targets are `(ingress link, tc)` pairs, 802.1Qbb
             style; `SwitchConfig.per_tc=False` restores the legacy
             whole-link pause for comparison baselines)
- hosts:     step-able ReceiverHost (wrapping the shared
             `repro.core.datapath.HostDatapath` — the same QoS admission/
             escape/recycle machine behind run_sim and JetService) and
             DCQCN SenderHost
- messages:  op-granular verbs layer over the fluid byte streams
             (`MessageConfig`: WRITE/SEND, msg size, outstanding-op
             window, go-back-N replay) with deterministic per-message
             completion latency — exact sorted percentiles in the
             scalar driver, a fixed-bucket log histogram with a proven
             relative error bound in the vector engines — see "The
             message layer" below
- faults:    fault-injection + loss-recovery layer (`FaultConfig`):
             per-link stochastic loss/corruption, link flaps
             (`Topology.flap_link`), NIC/host crash--restart, sender
             RTO timers with exponential backoff and IRN-style
             selective retransmit (`MessageConfig.recovery`), plus a
             PFC-deadlock watchdog — see "The fault layer" below
- cc:        pluggable congestion-control zoo (`CcConfig`): DCQCN
             (default, bit-equal to the pre-zoo driver), Timely
             delay-gradient and HPCC utilization controllers,
             selectable per flow and per sweep point — see "Choosing a
             congestion controller" below
- fabric:    scalar multi-host driver -> per-host SimResults + fabric
             metrics (victim goodput, pause fan-out + per-TC pause
             breakdown, incast FCT); `Flow.qos` selects both the
             receiver admission class and the switch queue along the
             route, escape-ladder ECN comes back as CNPs, and NP->RP
             CNP propagation delay is per flow (`Flow.cnp_delay_us`
             falling back to `FabricConfig.cnp_delay_us`); burst-train
             sources via `Flow.on_off_us`
- scenarios: incast-N / all-to-all HPC / storage OLTP-OLAP-backup /
             mixed Jet+DDIO fleet / QoS-mixed storage (LOW bulk incast
             + HIGH on-off OLTP + NORMAL OLAP, per-TC vs per-link
             pause) / pod-scale cross-pod incast, shuffle and PFC-storm
             bundles + fabric_grid / mixed_fleet_grid / qos_mixed_grid
             / pod_incast_grid / pod_storm_grid for building scenario
             grids; the named-grid registry (`GRIDS` / `build_grid`)
             and `chunk_plan` behind the sweep farm
- sweep:     vectorized receiver-datapath grid (jax.vmap + lax.scan over
             stacked single-host fluid state; numpy reference backend)
- vector:    vectorized *fabric* grid — the whole multi-host tick body
             (flows x ports x receivers, with the HostDatapath QoS
             classes as a stacked [G, Q, R] block and a per-flow
             CNP-delay ring) as one vmap+scan program; switch state is
             classed too ([G, Q, P] occupancy/assert/pause via the
             flow->TC one-hot, priority-unrolled drain grants); 3-level
             pod fabrics run a segmented-incidence ("sparse") variant
             of the same program whose cost is linear in flows + ports
- fused:     fused hot-tick stages for the vector engines (strict-
             priority drain grants + QoS receiver admission as single
             water-fill primitives with a Pallas kernel tier), the
             jaxpr op-census profiling hooks behind the bench, and the
             adaptive time-stepping machinery (quiet-stride predicate,
             closed-form macro-tick advance) — see "Engine
             performance" below
- farm:      sweep farm (`run_farm`, `python -m repro.fabric.farm`):
             any scenario grid executed as fixed-shape chunks across
             local jax devices and/or a multiprocess worker pool, with
             versioned run artifacts and resume — see "Running sweeps
             at farm scale" below
- artifacts: versioned run-artifact layer behind the farm
             (`experiments/runs/<run_id>/`: manifest + per-chunk
             result shards + merged table; atomic writes, resume
             contract)
- _scan:     shared lax.scan compile-cost machinery (unroll autotune,
             donated carries, persistent XLA compilation cache)

Which engine advances which datapath backend: the scalar driver steps
real ``HostDatapath`` objects (float64 Python, via ``ReceiverHost``);
``run_sweep`` and ``run_fabric_sweep`` advance the equivalent stacked-
array recurrence (batched-numpy float64 reference / jax float32
vmap+scan), verified against the scalar machine in the test suite.

Choosing an engine
------------------
``run_fabric`` (scalar driver)
    One scenario at a time, Python objects, float64.  The semantic
    reference: returns full per-host :class:`~repro.core.simulator
    .SimResult` (including message latency percentiles) and per-link
    pause breakdowns.  Also the only engine for things that resist
    stacking, e.g. ``cpu_membw_schedule`` callables.  Seconds per point.

``run_sweep`` (datapath sweep)
    Grids over *receiver* ``SimConfig`` knobs with the single-host
    sender model (no switches, no cross-flow coupling).  Cheapest per
    point; use it to map the receiver datapath (DDIO knee, pool sizing,
    DCQCN constants) before involving a fabric.

``run_fabric_sweep`` (fabric sweep)
    Grids over whole scenarios — topology rates, switch config, per-flow
    offered/burst/start, per-receiver knobs — with every flow, port and
    receiver advanced together ([G, F] / [G, P, F] / [G, R] arrays).
    Matches the scalar driver to float32 round-off (float64 exact via
    ``backend="numpy"``) and turns minutes-per-grid into seconds.  Grid
    points must share topology *structure* (same flows/routes/ticks).

Choosing a topology
-------------------
Two construction families, one :class:`~repro.fabric.topology.Topology`
contract (named nodes, per-link rate and up/down schedule, route /
candidate_paths / fail_link / flap_link):

``clos(...)`` and the presets (``jet_testbed``, ``incast_fabric``)
    2-level leaf–spine: hosts ``h{leaf}_{i}``, every leaf wired to
    every spine.  Routes are 3 hops (same-leaf) or 5 hops (cross-leaf,
    one spine choice).  The right size for last-mile receiver studies
    — every dense-engine feature (dynamic routing, CC zoo, message
    layer, fault injection, adaptive dt) is available.

``make_pod_clos(pods, leaves_per_pod, hosts_per_leaf, ...)``
    3-level pod-of-pods Clos: hosts ``p{pod}h{leaf}_{i}``, leaves
    ``p{pod}l{leaf}``, per-pod spines ``p{pod}s{k}``, and a global
    super-spine tier ``ss{k}`` with plane-aligned wiring (pod spine
    ``k`` connects to super-spine ``k``).  Cross-pod routes are 7 hops
    and climb two oversubscription points; tier speeds default to
    100/200/400 Gbps.  ``pods=1`` degenerates to the 2-level fabric.
    Partial wiring is legal: spines may serve a leaf subset, and
    ``Topology.candidate_spines`` / ``route`` skip spines that cannot
    reach both endpoints (raising ``unroutable`` only when *no*
    candidate survives).

Engine support: the scalar driver takes either family.  For vector
sweeps, ``run_fabric_sweep(..., incidence="auto")`` (default) picks the
dense one-hot program for 2-level grids and the segmented-incidence
("sparse") program whenever a super-spine tier is present.  The sparse
program freezes routes as incidence structure, so it supports static
ECMP plus failure/flap windows *and* the full CC zoo (per-flow
DCQCN / Timely / HPCC — per-flow state plus segment-summed per-port
telemetry, bit-equal to the dense formulation on 2-tier grids, held by
``tests/test_sparse_cc.py``); dynamic routing modes, the message
layer, FaultConfig injection and adaptive dt stay dense-only (it
rejects them with clear errors).  Within that envelope it is bit-equal
to the dense engine on 2-level grids and matches the scalar driver
like any other engine (held by ``tests/test_topology_pods.py``).  Its per-tick cost is linear in
flows + ports instead of the dense flows x ports — the bench ``scale``
section gates the measured growth exponent (~1.2 at 64 -> 256 hosts)
below the dense engine's 2.0.

Pod-scale scenario bundles: ``pod_incast`` (cross-pod fan-in through
both oversubscription tiers, optional in-pod victim), ``pod_shuffle``
(all-to-all across pods, ``uplink_util`` observability), and
``pod_pfc_storm`` (small-buffer pause cascade climbing tiers), each
with a ``*_grid`` companion that runs the mode x PFC (or buffer) grid
as ONE sparse vector program.

Engine performance
------------------
The vector tick body is built from *fused stages*: the innermost
strict-priority port drain and the QoS receiver admission are single
water-fill primitives (:func:`repro.fabric.fused.priority_grants` /
:func:`~repro.fabric.fused.priority_admit`) rather than per-class
op chains.  Each primitive has three interchangeable tiers selected by
``run_fabric_sweep(..., impl=...)``:

``"ref"``
    The stacked jnp/numpy formulation.  The default everywhere off-TPU,
    and always the tier behind ``backend="numpy"`` (float64 reference).
``"pallas"``
    A Pallas TPU kernel (grid/BlockSpec idiom shared with
    ``repro.kernels``): queue/port panels are padded to (8, 128) tiles
    and the water-fill runs on-chip.  ``impl="auto"`` (the default)
    activates it exactly when ``jax.default_backend() == "tpu"``.
``"interpret"``
    The same Pallas kernel run under ``pl.pallas_call(interpret=True)``
    — bit-equal to what the TPU executes, runnable on CPU CI, but
    *slow* (it emulates the kernel lane-by-lane); use it to validate
    kernel changes (``tests/test_fused.py`` pins interpret == ref
    bit-for-bit), never for throughput.

Adaptive time-stepping (``run_fabric_sweep(..., adaptive_dt=True)``,
tuned via :class:`repro.fabric.fused.AdaptiveConfig`) takes closed-form
macro-ticks over quiet stretches — every queue steady, no pause/timer/
watermark within a guard band — and fine dt near events.  Delivered
bytes stay within ``AdaptiveConfig.rel_bytes_bound`` (default 1 %,
relative) of the fine-tick run and completion timestamps shift at most
``(max_stride + 1) * dt`` per crossed macro window (property-tested in
``tests/test_fused.py``); ``adaptive_dt=False`` (the default) traces
none of this machinery and stays bit-equal to the fixed-dt engines.

Reading the bench profiling fields (``experiments/bench/
BENCH_fabric.json``, emitted per vector section by
``benchmarks/bench_fabric.py``): ``per_tick_ms_warm`` is warm wall
clock per simulated tick; ``compile_s`` the cold-minus-warm split;
``op_count_step`` the jaxpr op census of the scan body (the per-tick
dispatch load — if a perf regression shows here it is op growth, if
wall clock moves while the census is flat it is runtime);
``op_count_total`` / ``op_kinds`` the whole-program census.  The
``adaptive`` section gates what adaptivity promises — ``coarsen_ratio``
(fine ticks per adaptive iteration) and ``dev_delivered_vs_fixed``
(against ``rel_bytes_bound``) — while recording its wall clock
honestly (on CPU the ``lax.while_loop`` per-iteration overhead can eat
the iteration savings; the win is the iteration count, which is what
transfers to accelerators).

Running sweeps at farm scale
----------------------------
One ``run_fabric_sweep`` call is one process, one device, one XLA
program over the whole grid — the right shape up to a few hundred
points, and exactly wrong beyond that.  ``repro.fabric.farm``
(``run_farm(...)`` / ``python -m repro.fabric.farm --grid pod_storm
--workers N``) runs any grid — a registry name from
``scenarios.GRIDS``, a picklable ``GridSpec``, or a raw scenario list —
as **fixed-shape chunks**:

- **Chunking + padding semantics.**  ``scenarios.chunk_plan`` cuts the
  grid into full chunks of ``chunk_size`` plus one remainder padded up
  to the next power of two (at most two program shapes per run, so at
  most two compiles after the caches are cold).  Padding replicates a
  real scenario; vmap lanes are independent and every result is
  per-point, so padded lanes are sliced off without perturbing real
  points.  Because capability flags (CC/messages/faults/…) and ring
  lengths are any-over-points, a chunk of a heterogeneous grid would
  naturally trace a *different* program — the farm prevents that by
  passing the full grid's **structure envelope**
  (``FabricSweepParams.envelope()``) into every chunk's packing, which
  floors flags and ring sizes to the monolithic values.  Net effect,
  gated in the bench ``farm`` section and ``tests/test_farm.py``: at
  fixed dt, chunked results are **bit-identical** to the monolithic
  program (``adaptive_dt`` is the one exception — its macro-stride is
  a grid-wide lockstep reduction, so chunk membership legitimately
  changes stride schedules; the farm therefore always runs fixed dt).
- **Dispatch.**  ``workers <= 1`` stays in-process: a one-deep
  prefetch thread packs chunk k+1 while chunk k computes, and chunks
  round-robin across local jax devices when
  ``repro.parallel.compat.farm_dispatch_probe()`` allows (on jax < 0.6
  or single-device hosts it *warns and degrades* to one device).
  ``workers > 1`` fans chunks to a ``spawn`` pool; workers rebuild the
  grid from the registry name (scenario closures don't pickle), share
  the on-disk XLA cache via ``JAX_COMPILATION_CACHE_DIR``, and write
  their own shards.
- **Artifact layout + resume contract.**  Each run writes
  ``experiments/runs/<run_id>/``: ``manifest.json`` (grid spec, chunk
  plan, structure envelope + key, config hash, git SHA, engine,
  per-chunk wall/compile timings, status), ``chunk_NNNN.npz`` shards
  (real points only, written atomically), and the merged ``result.npz``
  table in input order.  ``run_farm(..., run_id=..., resume=True)``
  re-reads the manifest, verifies the grid fingerprint, and executes
  only chunks whose shards are missing or unreadable — kill a run at
  50% and the restart completes the other half (CI smoke-tests
  exactly this).  ``benchmarks/bench_trajectory.py`` reads the
  ``BENCH_*.json`` history the same artifacts-first way for the
  per-metric trajectory dashboard.

The routing layer
-----------------
Routing used to be construction-time metadata (`Topology.route` froze a
`flow -> path` dict).  It is now a per-tick layer shared by every
engine: `FabricConfig.routing` selects a :class:`~repro.fabric.routing
.RoutingConfig` mode and the spine choice of each cross-leaf flow is
resolved every tick from per-uplink queue depth and link up/down state.

``static_ecmp`` (default)
    `flow_id % n_spines`, frozen — bit-equal to the pre-routing-layer
    driver (golden-tested in tests/test_routing.py) and the baseline
    the dynamic modes are judged against.
``weighted_ecmp``
    Deterministic flowlet re-hash whenever a flow's injection has been
    idle longer than `flowlet_gap_us` (Kandula-style flowlet boundary;
    immediately on a dead path), weighted by per-uplink free buffer
    space.  A flow that never pauses keeps its path — steady grids are
    bit-equal to the pre-gap-semantics engine.
``adaptive``
    Per-tick least-congested uplink with a `hysteresis_frac` flap
    guard.
``spray``
    Per-tick proportional byte split across all up spines; the reorder
    cost is a `spray_settle_us` delay before sprayed arrivals reach
    receiver admission.

`Topology.fail_link(src, dst, at_us, restore_us)` schedules link
failures: in-flight bytes on the dead link are dropped and re-credited
(fluid go-back-N) and dynamic modes reroute around it, which is the
`scenarios.link_failure_incast` / `routing_grid` experiment (adaptive
and spray complete the incast after a failure that stalls static ECMP).
Observability: `FabricResult.uplink_util` / `flow_reroutes` /
`uplink_imbalance()`, and `uplink_util[_max/_mean]` + `reroute_count`
in sweep outputs.

The vector engines treat routing mode, failure schedules, WRR
scheduling and per-TC host PFC as *per-point parameters*: the old
"grid points must share routes" restriction is lifted (points must
only share node/link structure and the flow set), so one
`run_fabric_sweep` program can compare `static_ecmp` against
`adaptive` under a mid-burst uplink failure (`scenarios.routing_grid`).
Grids whose points are all static ECMP without failures keep the
original single-path program, bit-for-bit.  One caveat: in a
dynamic-routing grid, pause targeting is candidate-ingress-granular
for every point (a rerouted flow's queued bytes have mixed
provenance), matching the scalar driver's behaviour for dynamic
scenarios — keep PFC'd static baselines in their own static grid when
bit-parity with the frozen-route program matters.

Per-TC queue support across engines
-----------------------------------
Every engine implements the classed switch identically (the test suite
in ``tests/test_pfc_priority.py`` holds them together): per-TC FIFOs
with their own buffer partition, ECN knee and PFC xoff/xon watermarks,
strict-priority drain, and ``(ingress link, tc)`` pause targeting.
``Flow.qos`` selects the class end to end — switch queue on every hop
*and* receiver RNIC admission class.  The scalar driver additionally
reports the per-``(link, tc)`` pause breakdown
(``FabricResult.pause_tc_us``); the vector engines aggregate it to a
per-class total (``pause_tc_total_us``, [G, Q]).  ``per_tc`` and the
``tc_*`` watermark overrides are plain per-point parameters, so one
sweep grid can compare 802.1Qbb pause against the legacy whole-link
pause (``SwitchConfig.per_tc=False``, which is bit-equal to the
pre-refactor switch for single-class traffic in every engine).

The message layer
-----------------
The fluid core moves continuous byte streams; applications issue
discrete verbs ops.  `FabricConfig.msg` (or per-flow `Flow.msg`)
attaches a :class:`~repro.fabric.messages.MessageConfig` to a flow and
the engines carve its byte stream into fixed-size messages:

- **verb**: ``"write"`` (RDMA WRITE — no receiver CPU touch, small
  per-op gap) or ``"send"`` (SEND/RECV — adds a receive-side completion
  cost `send_extra_us` to every message latency and a larger issue
  gap).  The per-op issue gap caps the flow's offered rate at
  ``msg_bytes * 8e-3 / op_gap_us`` Gbps.
- **window**: max outstanding (unacked) ops; injection stalls when
  ``window * msg_bytes`` are in flight beyond the delivered watermark.
  ``window=None`` means unbounded (scalar driver only — the vector
  engines need a static completion ring and reject it with a clear
  error).  With DCQCN and an unbounded window the message layer is
  pure observability: goodput reproduces the plain fluid run exactly.
- **go-back-N**: drops re-credit the flow's injected watermark, so a
  message's clock keeps running across replays — its completion time
  includes every retransmission, matching NACK-based verbs recovery.

A message *starts* when its first byte is injected and *completes*
when its last byte is delivered (or escapes to the slow path — the
latency then includes the escape penalty).  Per-message completion
times feed latency percentiles in every engine:

- scalar driver: exact — all completion times are kept and sorted
  (`FabricResult.msg_p50_us` / `msg_p99_us` / `msg_p999_us`,
  NaN-safe accessors returning 0.0 when no messages completed, with
  `FabricResult.has_messages` to tell "no ops" apart from "fast ops").
- vector engines: a fixed 128-bucket log-spaced histogram
  (1 µs … 100 ms) accumulated inside the scan; the bucket-midpoint
  estimate is within ``sqrt(ratio) - 1`` ≈ 4.6 % relative error of the
  exact value (pinned in tests/test_messages.py), and message *counts*
  are exact — the numpy engine matches the scalar driver's completion
  times to 1e-9.

`scenarios.message_incast` builds an N-to-1 verbs incast and
`scenarios.message_sweep_grid` sweeps msg-size x window x verb x CC as
ONE vectorized program, reporting Mops, goodput GiB/s and p99 per
point — the msg-rate-vs-msg-size curve of the paper's Fig. 2 family.

The fault layer
---------------
The fluid core is lossless by construction — drops exist only as the
instant drop-re-credit idiom.  `FabricConfig.faults` attaches a
:class:`~repro.fabric.faults.FaultConfig` and makes failure a
first-class, *deterministic* experiment axis:

- **stochastic link loss** (`loss_rate`, per-link `link_loss`
  overrides, an independent `corrupt_rate` stream on the receiver
  access links): a link drops everything it drained on a tick iff
  ``hash(tick, link_salt) < floor(rate * 65536)``.  The hash is pure
  modular int arithmetic seeded from the link *name*, so the scalar
  driver, the batched-numpy engine and the jax engine see
  bit-identical fault realizations — fault runs stay
  equivalence-testable, and loss-rate sweeps are coherent (raising the
  rate only *adds* drops to the same realization; nested thresholds).
- **link flaps**: `Topology.flap_link(src, dst, start_us, period_us,
  down_us)` generalizes `fail_link` to a periodic up/down schedule;
  in-flight bytes drop on each down edge and dynamic routing modes
  steer around the hole every cycle.
- **NIC/host crash--restart**: `FaultConfig.crash(host, at_us,
  restart_us)` zeroes the receiver's admission state at `at_us`, drops
  everything queued on its access link, and discards arrivals until
  `restart_us`; `FabricResult.crash_recovery_us` stamps the first
  re-accepted byte after restart.
- **loss recovery**: flows with a message config get a sender-side
  retransmission ledger replacing the instant re-credit.
  `MessageConfig.recovery` picks ``"go_back_n"`` (RTO with exponential
  backoff — `rto_us` x `rto_backoff`**k capped at `rto_cap`, reset on
  delivery progress; bytes arriving while the window is gapped are
  discarded as duplicates and replayed too) or IRN-style
  ``"selective"`` (arrivals keep landing; only the lost span replays
  after a short `nack_us` NACK delay).  `examples/fault_recovery.py`
  puts numbers on the gap: under stochastic loss go-back-N's p999 and
  retransmitted bytes blow up while selective stays near the lossless
  baseline (asserted in tests/test_faults.py).
- **graceful-degradation metrics**: `FabricResult.dropped_pkts`,
  `retransmit_bytes`, `crash_recovery_us`, `deadlock_ticks` (a per-tick
  PFC pause-cycle watchdog in every engine — the vector engines run the
  same cycle predicate via boolean-matrix squaring over the pause-pair
  graph, equivalence-tested against the scalar walker), and the
  routing-aware
  PFC-storm view `pause_tc_fanout` / `n_pausable_links` /
  `pause_storm()` (paused fraction of the pausable link set, NaN-safe).

All fault knobs ride the sweep axes like every other parameter:
`scenarios.lossy_incast` / `lossy_incast_grid` race loss-rate x
recovery-mode grids as ONE vectorized program.  ``faults=None`` (the
default) is bit-equal to the pre-fault engines.

Choosing a congestion controller
--------------------------------
`FabricConfig.cc` (or per-flow `Flow.cc`) selects the rate controller
behind every sender; vector sweeps take it per point, so one grid can
race the zoo:

``dcqcn`` (default)
    ECN-mark driven rate cuts + additive/hyper increase — the classic
    RoCE controller, bit-equal to the pre-zoo engines (a ``CcConfig``
    with ``algo="dcqcn"`` reuses the existing `DcqcnRate` machinery,
    including per-flow `Flow.dcqcn` overrides).
``timely``
    RTT-gradient control: the fluid RTT signal is base RTT plus the
    queue-drain delay along the flow's current path; rates are cut
    proportionally to the smoothed RTT gradient and increased
    additively below `t_low_us` / when the gradient is negative.
    Reacts to *queue growth* before queues are deep, which is why it
    wins the incast p99 race below.
``hpcc``
    INT-style utilization control: every hop reports
    ``(tx + queue/base_rtt) / capacity``; the rate is multiplied by
    ``eta / max_utilization`` each update (clipped to [0.5, 2]) plus
    an additive term — drives utilization to `hpcc_eta` (95 %) with
    near-empty queues.

Under the 8-to-1 message incast both alternatives beat DCQCN's p99
message latency by ~4x (asserted in tests/test_messages.py): DCQCN
only reacts once the ECN knee is crossed, so its window oscillates
around a standing queue, while Timely/HPCC hold the queue near zero.
Signals are computed from the same per-tick state in every engine
(scalar and vector runs agree on counts exactly and on percentiles
within the histogram bound).
"""
from .cc import CC_ALGOS, CcConfig, HpccRate, TimelyRate, make_controller
from .fabric import (FabricConfig, FabricResult, Flow, burst_done_bytes,
                     run_fabric)
from .farm import GridSpec, run_farm
from .faults import FaultConfig, FlowRecovery, has_pause_cycle
from .hosts import HostFeedback, ReceiverHost, SenderHost
from .messages import (LogHistogram, MessageConfig, MessageTracker,
                       exact_percentile, percentile_from_counts)
from .routing import ROUTING_MODES, RoutingConfig
from .scenarios import (GRIDS, Scenario, all_to_all, build_grid,
                        chunk_plan, fabric_grid, incast, incast_grid,
                        link_failure_incast, lossy_incast,
                        lossy_incast_grid, message_incast,
                        message_sweep_grid, mixed_fleet,
                        mixed_fleet_grid, olap_shuffle, pod_incast,
                        pod_incast_grid, pod_pfc_storm, pod_shuffle,
                        pod_storm_grid, qos_mixed_grid,
                        qos_mixed_storage, routing_grid, single_pair,
                        storage_mix)
from .switch import OutputPort, Switch, SwitchConfig
from .sweep import SweepParams, grid_configs, run_sweep
from .topology import (Link, Topology, clos, incast_fabric, jet_testbed,
                       make_pod_clos)
from .vector import FabricSweepParams, run_fabric_sweep

__all__ = [
    "CC_ALGOS", "CcConfig", "FabricConfig", "FabricResult",
    "FabricSweepParams", "FaultConfig", "Flow", "FlowRecovery",
    "GRIDS", "GridSpec",
    "HostFeedback", "HpccRate", "Link",
    "LogHistogram", "MessageConfig", "MessageTracker", "OutputPort",
    "ROUTING_MODES", "ReceiverHost", "RoutingConfig", "Scenario",
    "SenderHost", "Switch", "SwitchConfig", "SweepParams", "TimelyRate",
    "Topology", "all_to_all", "build_grid", "burst_done_bytes",
    "chunk_plan", "clos",
    "exact_percentile", "fabric_grid", "grid_configs",
    "has_pause_cycle", "incast", "incast_grid",
    "incast_fabric", "jet_testbed", "link_failure_incast",
    "lossy_incast", "lossy_incast_grid",
    "make_controller", "make_pod_clos", "message_incast",
    "message_sweep_grid", "mixed_fleet", "mixed_fleet_grid",
    "olap_shuffle", "percentile_from_counts", "pod_incast",
    "pod_incast_grid", "pod_pfc_storm", "pod_shuffle", "pod_storm_grid",
    "qos_mixed_grid", "qos_mixed_storage",
    "routing_grid", "run_fabric", "run_fabric_sweep", "run_farm",
    "run_sweep", "single_pair", "storage_mix",
]
