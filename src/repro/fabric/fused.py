"""Fused tick stages + adaptive time-stepping for the vector fabric engine.

Two per-tick overhead attacks for :mod:`repro.fabric.vector`, both gated
so the default program stays bit-identical to the pre-fusion engine:

**1. Fused priority stages.**  The two innermost sequential loops of the
tick — the switch drain's strict-priority budget grants and the receiver
RNIC's QoS admission — are priority water-fills unrolled over ``N_QOS``.
:func:`priority_grants` and :func:`priority_admit` package them as single
fused kernels with three implementations (the :mod:`repro.kernels.ops`
tiering):

* ``impl="ref"`` — the inline ``xp`` formulation, op for op the scalar
  driver's ``OutputPort.drain`` / ``HostDatapath`` arithmetic.  This is
  what XLA lowers on CPU hosts and what the numpy float64 reference
  runs, so the ~1e-13 scalar-vs-numpy and <=5e-4 jax equivalence suites
  gate every other tier against it.
* ``impl="pallas"`` — one Pallas kernel per call: the whole ``[Q, N]``
  water-fill lives in VMEM and the ``Q`` rounds run register-resident
  instead of as ``Q`` rounds of stacked XLA ops (grid/BlockSpec idiom
  from ``src/repro/kernels/jet_staged_matmul.py``).  TPU only.
* ``impl="interpret"`` — the same kernel body under the Pallas
  interpreter, so CPU CI executes the kernel path (``tests/test_fused.py``
  pins it to the ref tier bit-for-bit in float32).

**2. Adaptive time-stepping** (:class:`AdaptiveConfig`).  When the whole
grid is *quiet* — every port queue and admission class empty, no PFC
pause or assert anywhere, no CNPs in flight, no recovery ledger entries,
every flow's injection delta matched by its delivery delta (no rate
step still riding the transit rings), receiver pools steady and outside
the configured guard band of their spill watermark, and the fine step
just taken contained no DCQCN/CC timer fire — the engine takes a
*macro-tick*: the last fine step's state
delta is integrated in closed form over ``k * dt`` (linear extrapolation
of the byte/timer accumulators; counts, rings and discrete carries are
left to the next fine step, which catches them up exactly).  The stride
``k`` is clamped by the distance to the next *event*: flow start ticks,
link failure/flap/crash window edges, finite-burst exhaustion, message-
window exhaustion, and (for weighted-ECMP points) the flowlet idle gap.
Rate-timer fires are handled *exactly*: the stride is additionally
capped so a macro window may end on, but never cross, the next
DCQCN/CC timer deadline (``ceil((threshold - timer) / rate)`` fine
ticks away) — the fine step that follows the window then performs the
fire on the same absolute tick as the fine reference, with the same
state, because rates are constant between fires in a quiet stretch.
Recovery ramps (a DCQCN flow climbing back toward its target rate
fires every ``r_tmr``/``bctr`` period for thousands of ticks) thus
coarsen between fires without accumulating any phase drift.
Stochastic-loss points and on/off burst trains disable coarsening
outright (their per-tick dynamics cannot be integrated in closed form
without changing realizations).

Equivalence bound (documented contract, tested by
``tests/test_fused.py``): against the fine-tick reference on the same
grid, adaptive stepping keeps per-flow delivered bytes within
``AdaptiveConfig.rel_bytes_bound`` (relative, default 1%) and shifts any
completion / message-latency timestamp by at most
``(max_stride + 1) * dt`` per crossed macro window — events are never
jumped over, only quantized to macro boundaries.  ``adaptive_dt=False``
(the default) does not trace any of this machinery: the scan program is
unchanged and static grids stay bit-equal to the frozen goldens.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import numpy as np

from ..core.datapath import N_QOS

_BIG = np.int32(1 << 30)       # "no event" sentinel for integer gaps


# --------------------------------------------------------------------------- #
# Implementation selection (the repro.kernels.ops tiering)
# --------------------------------------------------------------------------- #
def _on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"


def resolve_impl(impl: str) -> str:
    """``auto`` -> Pallas on TPU, ref elsewhere; everything else passes
    through (``pallas`` / ``interpret`` / ``ref``)."""
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    if impl not in ("pallas", "interpret", "ref"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl


def _pad_to(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


@functools.lru_cache(maxsize=16)
def _grants_call(nq: int, n: int, interpret: bool):
    """Build the Pallas water-fill kernel for padded shape [Qp, Np]."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    qp, npad = _pad_to(nq, 8), _pad_to(n, 128)

    def kernel(demand_ref, can_ref, budget_ref, crumb_ref, out_ref):
        one, zero = jnp.float32(1.0), jnp.float32(0.0)
        bl = budget_ref[0, :]
        crumb = crumb_ref[0, :]
        for qi in range(qp):
            if qi >= nq:
                out_ref[qi, :] = jnp.zeros_like(bl)
                continue
            qsum = demand_ref[qi, :]
            can = can_ref[qi, :] > 0.5
            frac = jnp.where(
                can, jnp.minimum(one, bl / jnp.where(qsum > zero, qsum,
                                                     one)), zero)
            out_ref[qi, :] = frac
            bl = bl - frac * qsum
            bl = jnp.where(bl < crumb, zero, bl)

    call = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((qp, npad), jnp.float32),
        interpret=interpret,
    )

    def run(demand, can, budget, crumb):
        pq, pn = qp - nq, npad - n
        args2 = [jnp.pad(demand, ((0, pq), (0, pn))),
                 jnp.pad(can, ((0, pq), (0, pn)))]
        args1 = [jnp.pad(budget[None, :], ((0, 0), (0, pn))),
                 jnp.pad(crumb[None, :], ((0, 0), (0, pn)))]
        return call(*args2, *args1)[:nq, :n]

    return run


@functools.lru_cache(maxsize=16)
def _admit_call(nq: int, n: int, interpret: bool):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    qp, npad = _pad_to(nq, 8), _pad_to(n, 128)

    def kernel(demand_ref, space_ref, out_ref):
        sp = space_ref[0, :]
        for qi in range(qp):
            if qi >= nq:
                out_ref[qi, :] = jnp.zeros_like(sp)
                continue
            a = jnp.minimum(demand_ref[qi, :], sp)
            out_ref[qi, :] = a
            sp = sp - a

    call = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((qp, npad), jnp.float32),
        interpret=interpret,
    )

    def run(demand, space):
        pq, pn = qp - nq, npad - n
        return call(jnp.pad(demand, ((0, pq), (0, pn))),
                    jnp.pad(space[None, :], ((0, 0), (0, pn))))[:nq, :n]

    return run


def priority_grants(xp, demand, can, budget, crumb, one, zero,
                    impl: str = "ref"):
    """Strict-priority budget water-fill: per-class drain fractions.

    ``demand`` [.., Q, N] per-class byte totals, ``can`` [.., Q, N]
    {0,1} eligibility, ``budget`` / ``crumb`` [.., N].  Returns the
    grant fraction per (class, port) [.., Q, N] with the exact op order
    of ``OutputPort.drain``: each class takes ``min(1, left/demand)`` of
    its demand, leftovers below ``crumb`` are clamped to zero.
    ``one`` / ``zero`` are the caller's dtype scalars so the ref tier is
    bit-identical to the inline formulation it replaced.
    """
    if impl in ("pallas", "interpret"):
        import jax
        run = _grants_call(demand.shape[-2], demand.shape[-1],
                           impl == "interpret")
        for _ in range(demand.ndim - 2):
            run = jax.vmap(run)
        return run(demand, can, budget, crumb)
    bl = budget
    rows = []
    for qi in range(demand.shape[-2]):
        qsum = demand[..., qi, :]
        cq = can[..., qi, :]
        ok = cq if cq.dtype == bool else cq > 0.5
        frac = xp.where(ok,
                        xp.minimum(one, bl / xp.where(qsum > zero, qsum,
                                                      one)), zero)
        rows.append(frac)
        bl = bl - frac * qsum
        bl = xp.where(bl < crumb, zero, bl)
    return xp.stack(rows, -2)


def priority_admit(xp, demand, space, impl: str = "ref"):
    """QoS-priority admission: grant ``min(demand, space)`` per class in
    priority order (``HostDatapath`` RNIC-buffer arithmetic).  ``demand``
    [.., Q, N], ``space`` [.., N] -> accepted [.., Q, N]."""
    if impl in ("pallas", "interpret"):
        import jax
        run = _admit_call(demand.shape[-2], demand.shape[-1],
                          impl == "interpret")
        for _ in range(demand.ndim - 2):
            run = jax.vmap(run)
        return run(demand, space)
    rows = []
    for qi in range(demand.shape[-2]):
        a = xp.minimum(demand[..., qi, :], space)
        space = space - a
        rows.append(a)
    return xp.stack(rows, -2)


# --------------------------------------------------------------------------- #
# PFC-deadlock watchdog (vectorized has_pause_cycle)
# --------------------------------------------------------------------------- #
def pause_pair_onehot(port_keys) -> np.ndarray:
    """Static port -> (src-node, dst-node) scatter: [P, N*N] one-hot so
    ``link_paused @ E`` reshapes to the per-TC pause-dependency adjacency
    that :func:`repro.fabric.faults.has_pause_cycle` walks."""
    nodes = sorted({a for a, _ in port_keys} | {b for _, b in port_keys})
    ni = {h: i for i, h in enumerate(nodes)}
    n = len(nodes)
    E = np.zeros((len(port_keys), n * n))
    for p, (a, b) in enumerate(port_keys):
        E[p, ni[a] * n + ni[b]] = 1.0
    return E


def cycle_flags(xp, lp, E, n: int, one):
    """Per-point deadlock flag from the pause mask ``lp`` [.., Q, P]
    ({0,1} floats).  Builds the per-TC node adjacency and closes it with
    ``ceil(log2 n)`` boolean-semiring squarings; a nonzero diagonal in
    any class's closure is the cyclic pause dependency (the exact
    predicate of ``has_pause_cycle``, which detects a cycle in any
    single-TC digraph)."""
    adj = xp.matmul(lp, E)
    C = xp.minimum(adj, one).reshape(adj.shape[:-1] + (n, n))
    hops = max(1, int(np.ceil(np.log2(max(n, 2)))))
    for _ in range(hops):
        C = xp.minimum(C + xp.matmul(C, C), one)
    diag = xp.einsum('...ii->...i', C)
    return diag.sum((-1, -2)) > 0.0          # any TC, any node


# --------------------------------------------------------------------------- #
# jaxpr profiling hooks (bench dispatch/op-count attribution)
# --------------------------------------------------------------------------- #
def jaxpr_op_counts(jaxpr) -> Dict[str, int]:
    """Primitive -> count over a (Closed)Jaxpr, recursing into scans,
    conds, calls and pjit bodies — the per-tick dispatch fingerprint the
    bench emits so perf regressions are attributable to op growth."""
    counts: Dict[str, int] = {}

    def walk(jx):
        for eqn in jx.eqns:
            counts[eqn.primitive.name] = \
                counts.get(eqn.primitive.name, 0) + 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    inner = getattr(sub, "jaxpr", None)
                    if inner is not None and hasattr(inner, "eqns"):
                        walk(inner)
                    elif hasattr(sub, "eqns"):
                        walk(sub)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return counts


def program_op_stats(fn, *args) -> Dict[str, int]:
    """Trace ``fn(*args)`` and summarize its op census: total primitive
    count plus the scan-body count (the per-tick dispatch load)."""
    import jax

    jx = jax.make_jaxpr(fn)(*args)
    counts = jaxpr_op_counts(jx)
    total = int(sum(counts.values()))
    scan_body = 0
    for eqn in jx.jaxpr.eqns:
        stack = [eqn]
        while stack:
            e = stack.pop()
            if e.primitive.name in ("scan", "while"):
                body = e.params.get("jaxpr") or e.params.get("body_jaxpr")
                if body is not None:
                    scan_body += int(sum(jaxpr_op_counts(body).values()))
            else:
                for v in e.params.values():
                    for sub in (v if isinstance(v, (list, tuple))
                                else (v,)):
                        if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                            stack.extend(getattr(sub, "eqns", []) or
                                         getattr(sub.jaxpr, "eqns", []))
    return {"op_count_total": total, "op_count_step": scan_body,
            "op_kinds": len(counts)}


# --------------------------------------------------------------------------- #
# Adaptive time-stepping
# --------------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Macro-tick coarsening knobs + the documented equivalence bound.

    ``max_stride`` caps a single macro window (``k * dt``);
    ``guard_frac`` is the watermark guard band: a jet pool within
    ``guard_frac`` of its ``cache_safe`` spill fraction is treated as
    near-event and keeps fine ticks.  ``resident_eps_bytes`` is the
    steady-pool test (float accumulators jitter at ~1e-7 relative).

    The contract tested by ``tests/test_fused.py``: per-flow delivered
    bytes within ``rel_bytes_bound`` of the fine reference, timestamps
    (completion, message latency) within ``(max_stride + 1) * dt`` per
    crossed macro window.
    """
    max_stride: int = 16
    guard_frac: float = 0.05
    resident_eps_bytes: float = 1.0
    rel_bytes_bound: float = 0.01

    def key(self):
        return (self.max_stride, self.guard_frac,
                self.resident_eps_bytes)


# accumulators advanced in closed form over a macro window: the paired
# hi/lo split counters scale via the *sum* delta applied to the lo part
# (a fold between the two fine steps must not double), plain linear
# byte counters, and the us/byte timers (finite-delta guarded: pace_tus
# idles at +inf, and inf - inf must not poison the carry)
_SCALE_PAIRS = (("injected", "inj_lo"), ("delivered", "deliv_lo"))
_SCALE_SINGLE = ("drained", "miss_sum", "pool_sum", "nic_dram",
                 "mem_fb", "esc_dram", "tx", "resident", "strag_res")
_SCALE_TIMERS = ("t_us", "byts", "a_tus", "cnp_tus", "ecn_tus",
                 "pace_tus", "cc_tus")


def zero_of(xp, a):
    return a.dtype.type(0) if hasattr(a.dtype, "type") else 0.0


def macro_advance(xp, s, s1, km1):
    """Extrapolate the fine step ``s -> s1`` over ``km1`` further ticks
    (``km1 = k - 1`` as a float scalar).  Everything not listed scales by
    construction of the quiet predicate (its delta is zero) or is a
    discrete carry the next fine step catches up exactly: message
    counts re-derive from the cumulative byte totals, completion stamps
    land on the next fine boundary, rings hold a steady value."""
    s2 = dict(s1)
    for hi, lo in _SCALE_PAIRS:
        d = (s1[hi] + s1[lo]) - (s[hi] + s[lo])
        s2[lo] = s1[lo] + km1 * d
    for key in _SCALE_SINGLE + _SCALE_TIMERS:
        if key not in s1:
            continue
        # masked subtract: idle timers park at +inf and inf - inf must
        # neither poison the carry nor raise numpy warnings
        ok = xp.isfinite(s1[key]) & xp.isfinite(s[key])
        d = xp.where(ok, s1[key], zero_of(xp, s1[key])) \
            - xp.where(ok, s[key], zero_of(xp, s[key]))
        s2[key] = xp.where(ok, s1[key] + km1 * d, s1[key])
    # the peak tracker follows the (sub-eps) extrapolated pool drift —
    # but only where the step tracks residency at all (jet points;
    # ddio points keep pool_peak at zero and the carry must not invent
    # one from the ddio pool occupancy)
    z = zero_of(xp, s1["pool_peak"])
    s2["pool_peak"] = xp.where(s1["pool_peak"] > z,
                               xp.maximum(s1["pool_peak"],
                                          s2["resident"]),
                               s1["pool_peak"])
    return s2


def make_stride_fn(xp, fsp, p, opts, cfg: AdaptiveConfig, dtype):
    """Build ``stride(s, s1, t) -> k`` for one packed sweep.

    Returns the whole-grid macro stride after the fine step ``s -> s1``
    at tick ``t``: 1 unless every point is quiet, else the largest
    ``k <= max_stride`` that stays short of the next event (see module
    docstring).  Pure ``xp`` arithmetic — the jax adaptive program
    traces it inside its ``while_loop``.
    """
    o = opts or {}
    dyn, flap, flt = o.get("dyn", False), o.get("flap", False), \
        o.get("flt", False)
    any_cc, any_msg = o.get("cc", False), o.get("msg", False)
    Sn = o.get("Sn", 0)
    f = dtype
    zero, one = f(0.0), f(1.0)
    tiny = f(1e-30)
    bigf = f(float(_BIG))
    dt = fsp.dt_us
    ticks = fsp.ticks
    # static plan: on/off trains are per-tick duty cycles — no closed
    # form that preserves the phase, so any such flow disables macros
    any_onoff = bool((fsp.pvals["off_us"] > 0).any())
    start_tick = xp.asarray(
        np.floor(fsp.pvals["start"] / dt).astype(np.int32))
    if flt:
        thr_any = xp.asarray(
            ((fsp.pvals["f_thr"] > 0) | (fsp.pvals["f_cthr"] > 0))
            .any(-1))                                       # [G]
    max_stride = np.int32(cfg.max_stride)
    eps_res = f(cfg.resident_eps_bytes)
    guard = f(cfg.guard_frac)

    def imin(g, gap):
        return xp.minimum(g, gap.min())

    def fgap(g, gapf):
        """Fold a float tick-gap array into the int stride bound."""
        return xp.minimum(
            g, xp.minimum(gapf, bigf).min().astype(xp.int32))

    def stride(s, s1, t):
        if any_onoff or cfg.max_stride <= 1:
            return xp.int32(1)
        inj1 = s1["injected"] + s1["inj_lo"]
        dinj = inj1 - (s["injected"] + s["inj_lo"])
        del1 = s1["delivered"] + s1["deliv_lo"]
        ddel = del1 - (s["delivered"] + s["deliv_lo"])
        moving = dinj > zero
        # ---- quiet: every queue steady, nothing paused or mid-fire --- #
        # "steady" rather than "empty": a constant port/admission queue
        # (inflow == outflow, e.g. a parked residual behind a line-rate
        # open flow) integrates in closed form exactly like an empty
        # one — every per-tick drain/admission fraction repeats, so the
        # slot-major rings hold a constant value and the byte
        # accumulators advance linearly
        quiet = (xp.abs(s1["qm"] - s["qm"]).max() <= eps_res)
        quiet &= (xp.abs(s1["qos_q"] - s["qos_q"]).max() <= eps_res)
        quiet &= ~s1["paused"].any() & ~s1["asserted"].any()
        quiet &= ~s1["pfc"].any()
        quiet &= (s1["backlog"].sum() == zero)
        # ECN marking / switch drops accrue per tick against the live
        # queue — only coarsen while neither made progress
        quiet &= (s1["ecn_marked"] == s["ecn_marked"]).all()
        quiet &= (s1["sw_dropped"] == s["sw_dropped"]).all()
        quiet &= (s1["cring"].sum() == zero)
        quiet &= (s1["esc_debt"].sum() == zero)
        quiet &= (s1["repl_debt"].sum() == zero)
        # per-flow rate balance: a quiet flow's injection delta must
        # match its delivery delta.  While a rate step (a DCQCN/CC fire
        # landed a tick ago) is still in flight through the transit
        # rings, injection runs at the new rate but arrivals still land
        # at the old one — a macro there would stretch the old-rate
        # arrivals over k ticks and the per-fire deficit compounds
        # across a recovery ramp.  The imbalance is visible directly,
        # so the wavefront pins fine ticks until it lands
        quiet &= (xp.abs(dinj - ddel).max() <= eps_res)
        # pool residency is a sliding-window sum of the delayed drain
        # ring: it moves exactly while that window straddles a rate
        # kink, and the fine steps must track the kink tick for tick —
        # so quiet requires the pools steady too (the extrapolation
        # then holds them constant, and the jet guard band below keeps
        # the whole window clear of the spill watermark)
        quiet &= (xp.abs(s1["resident"] - s["resident"]).max() <= eps_res)
        quiet &= (xp.abs(s1["strag_res"] - s["strag_res"]).max()
                  <= eps_res)
        jet = p["jet"] > 0.5
        avail = xp.maximum(zero, p["pool"] - s1["resident"]) \
            / xp.maximum(p["pool"], tiny)
        quiet &= xp.where(jet, avail >= p["safe"] + guard, True).all()
        # no timer fired during the fine step (a fire's reset makes the
        # step non-representative of the window it would be scaled over)
        for tk in _SCALE_TIMERS:
            if tk in s1:
                quiet &= (s1[tk] >= s[tk]).all()
        if flt:
            quiet &= (s1["lost"].sum() == zero) & ~s1["gapped"].any()
            # stochastic loss draws once per (link, tick): points with a
            # live threshold may only coarsen while nothing is moving
            quiet &= ~(thr_any & moving.any(-1)).any()
        # ---- stride: distance to the next event ---------------------- #
        g = xp.minimum(max_stride, xp.int32(ticks) - t)
        g = imin(g, xp.where(start_tick > t, start_tick - t, _BIG))
        if dyn:
            g = imin(g, xp.where(p["fail_at"] > t,
                                 p["fail_at"] - t, _BIG))
            g = imin(g, xp.where(p["fail_until"] > t,
                                 p["fail_until"] - t, _BIG))
            if flap:
                st_, per = p["flap_start"], p["flap_period"]
                dn = p["flap_down"]
                phase = (t - st_) % per
                nxt = xp.minimum(per - phase,
                                 xp.where(phase < dn, dn - phase, _BIG))
                g = imin(g, xp.where(st_ > t, st_ - t, nxt))
        if flt:
            g = imin(g, xp.where(p["crash_at"] > t,
                                 p["crash_at"] - t, _BIG))
            g = imin(g, xp.where(p["crash_until"] > t,
                                 p["crash_until"] - t, _BIG))
        # finite bursts: scaled injection must not overshoot the tap
        room = p["burst"] - inj1
        g = fgap(g, xp.where(moving & xp.isfinite(room),
                             xp.floor(xp.maximum(room, zero)
                                      / xp.maximum(dinj, tiny)) + one,
                             bigf))
        if any_msg:
            # message-window room shrinks while injection outruns
            # delivery; never let a macro jam the window shut
            dout = xp.maximum(dinj - ddel, zero)
            wroom = p["m_win"] * p["m_bytes"] - (inj1 - del1)
            g = fgap(g, xp.where((dout > tiny) & xp.isfinite(wroom),
                                 xp.floor(xp.maximum(wroom, zero)
                                          / xp.maximum(dout, tiny))
                                 + one, bigf))
        if dyn and Sn:
            # weighted-ECMP flowlet bookkeeping gaps by k ticks under a
            # macro; keep k at or below the idle gap so no spurious
            # flowlet boundary opens
            wec_move = (p["rmode"][..., None] == 1) & moving
            g = imin(g, xp.where(wec_move, p["flet"][..., None], _BIG))
        # exact fire landing: a window may end ON the tick a rate timer
        # fires — the next fine step performs the fire with the state
        # the fine reference had (rates are constant between fires in a
        # quiet stretch), so DCQCN/CC recovery ramps coarsen between
        # fires with zero phase drift.  ceil lands integral quotients
        # on the right tick (floor(q)+1 is one late there); the small
        # down-bias eats float noise in the division — an under-cap
        # only costs one extra fine step, never a crossed fire
        bias = f(1e-3)

        def fire_gap(g, t0, t1, thr, rate):
            run = t1 > t0          # this timer advanced this fine step
            q = (thr - t1) / xp.maximum(rate, tiny)
            gapf = xp.maximum(xp.ceil(q - bias), one)
            return fgap(g, xp.where(run, gapf, bigf))

        fdt = f(dt)
        g = fire_gap(g, s["t_us"], s1["t_us"], p["r_tmr"], fdt)
        g = fire_gap(g, s["a_tus"], s1["a_tus"], p["a_tmr"], fdt)
        g = fire_gap(g, s["byts"], s1["byts"], p["bctr"],
                     s1["byts"] - s["byts"])
        if any_cc:
            g = fire_gap(g, s["cc_tus"], s1["cc_tus"], p["cc_upd"], fdt)
        k = xp.maximum(g, xp.int32(1))
        return xp.where(quiet, k, xp.int32(1))

    return stride
