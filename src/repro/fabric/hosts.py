"""Host components for the multi-host fabric.

:class:`ReceiverHost` — the network-facing wrapper around the shared
:class:`repro.core.datapath.HostDatapath` state machine that also powers
``run_sim`` — lives in :mod:`repro.core.simulator` (core stays the bottom
layer; the fabric composes N of them) and is re-exported here alongside
the fabric-only :class:`SenderHost`.  Fabric arrivals enter its QoS
admission classes (``Flow.qos``) and its escape-ladder ECN comes back as
CNPs that the driver routes to the offending DCQCN senders.  Its RNIC
PFC gate pauses the whole access link by default, or — with
``SimConfig.host_pfc_per_tc`` — only the congested admission classes
(``ReceiverHost.paused_classes``), mirroring the switch's per-priority
pause so a bulk class filling the RNIC buffer no longer stalls OLTP
traffic sharing the link.

:class:`SenderHost` wraps one DCQCN rate machine per flow, adding burst
(closed-flow) bookkeeping for the fabric driver.  PFC pause gating is the
driver's job: it pauses the host NIC egress queue (``run_fabric`` step 2),
so backpressure reaches the flow through queue space, not a sender flag.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

from ..core.dcqcn import DcqcnConfig, DcqcnRate
from ..core.simulator import (HostFeedback, ReceiverHost,  # noqa: F401
                              hold_us_baseline, hold_us_jet)
from .cc import CcConfig, make_controller

__all__ = ["HostFeedback", "ReceiverHost", "SenderHost",
           "hold_us_baseline", "hold_us_jet"]


class SenderHost:
    """One rate-controlled flow source (per-QP rate machine, paper §2.1).

    The rate machine defaults to DCQCN; a :class:`~repro.fabric.cc
    .CcConfig` swaps in any controller from the CC zoo (Timely, HPCC)
    behind the same ``advance``/``on_cnp``/``on_signal`` hooks.

    ``offer(dt_us)`` advances the rate machine and returns the bytes the
    flow wants to inject this tick.  Closed flows (``burst_bytes``) stop
    offering once the burst has been injected; the fabric re-credits
    ``injected`` for bytes lost downstream (fluid go-back-N), which
    re-opens the tap.  Message-layer flows add two more taps the driver
    controls: ``op_cap_gbps`` (per-op issue-gap rate ceiling — the Mops
    plateau) folds into the rate minimum, and ``offer``'s
    ``window_room`` argument clamps injection to the outstanding
    message window's remaining bytes.

    ``on_off_us=(on, off)`` makes the source a burst train (on-off OLTP
    client): after ``start_us`` the flow offers bytes only while
    ``(now - start) mod (on + off) < on``.  The DCQCN machine keeps
    advancing through off-phases (timers run; the tap is simply shut),
    mirroring the vectorized engine's gating.
    """

    def __init__(self, line_rate_gbps: float,
                 dcqcn: Optional[DcqcnConfig] = None,
                 offered_gbps: Optional[float] = None,
                 burst_bytes: Optional[float] = None,
                 start_us: float = 0.0,
                 on_off_us: Optional[Tuple[float, float]] = None,
                 cc: Optional[CcConfig] = None,
                 op_cap_gbps: Optional[float] = None):
        self.line_rate_gbps = line_rate_gbps
        if cc is None and dcqcn is not None:
            self.rate = DcqcnRate(dcqcn)
        else:
            self.rate = make_controller(cc, line_rate_gbps)
        self.offered_gbps = offered_gbps
        self.op_cap_gbps = op_cap_gbps
        self.burst_bytes = burst_bytes
        self.start_us = start_us
        if on_off_us is not None and (on_off_us[0] <= 0.0
                                      or on_off_us[1] < 0.0):
            raise ValueError("on_off_us needs on > 0 and off >= 0")
        self.on_off_us = on_off_us
        self.injected = 0.0
        self.now_us = 0.0

    @property
    def exhausted(self) -> bool:
        return (self.burst_bytes is not None
                and self.injected >= self.burst_bytes)

    def offer(self, dt_us: float,
              window_room: Optional[float] = None) -> float:
        """Bytes this flow injects into its NIC queue this tick.

        ``window_room`` (message layer) caps the injection at the
        outstanding window's remaining bytes; the rate machine still
        advances so its timers track wall clock even while the window
        is closed.
        """
        self.now_us += dt_us
        if self.now_us <= self.start_us:
            return 0.0
        gbps = min(self.rate.advance(dt_us), self.line_rate_gbps)
        if self.offered_gbps is not None:
            gbps = min(gbps, self.offered_gbps)
        if self.op_cap_gbps is not None:
            gbps = min(gbps, self.op_cap_gbps)
        if self.on_off_us is not None and self.on_off_us[1] > 0.0:
            on, off = self.on_off_us
            if math.fmod(self.now_us - self.start_us, on + off) >= on:
                return 0.0
        if self.exhausted:
            return 0.0
        b = gbps * 1e9 / 8.0 * dt_us * 1e-6
        if self.burst_bytes is not None:
            b = min(b, self.burst_bytes - self.injected)
        if window_room is not None:
            b = min(b, window_room)
        self.injected += b
        return b

    def credit(self, b: float) -> None:
        """Give back ``b`` injected bytes so the tap re-opens: either
        the fluid core's instant drop-re-credit, or — under the fault
        layer — a :class:`~repro.fabric.faults.FlowRecovery` ledger
        firing a retransmission."""
        self.injected -= b

    def on_cnp(self) -> None:
        self.rate.on_cnp()

    def on_signal(self, rtt_us: float, util: float, dt_us: float) -> None:
        """Forward per-tick path telemetry to the rate machine (no-op
        for DCQCN; drives the Timely/HPCC control loops)."""
        self.rate.on_signal(rtt_us, util, dt_us)
