"""Pluggable congestion control: DCQCN lifted behind an interface, plus
Timely-style delay-gradient and HPCC-style utilization controllers.

Every sender-side controller exposes the same three hooks (duck-typed —
:class:`repro.core.dcqcn.DcqcnRate` already satisfies them):

``advance(dt_us) -> gbps``
    Advance internal timers one tick; return the current sending rate.
``on_cnp()``
    Explicit congestion notification arrived (ECN-echo CNP).  DCQCN's
    multiplicative decrease lives here; the delay/INT controllers
    ignore CNPs (they sense congestion through their own signals).
``on_signal(rtt_us, util, dt_us)``
    Per-tick telemetry from the fabric: ``rtt_us`` is the flow's
    base RTT plus the queueing delay its path's queues currently imply,
    and ``util`` is the max per-hop utilization HPCC-style INT would
    report (``txRate/B + qlen/(B * T)``).  DCQCN ignores it.

The fabric drivers compute both signals from state they already carry —
queue occupancy and per-tick drained bytes along the flow's current
path — so no new wire machinery is needed, and the scalar and vector
engines can evaluate the identical arithmetic (the vector engines run
the update rules below as masked ``where`` lanes selected by
:meth:`CcConfig.code`).

Timely (Mittal et al., SIGCOMM'15) reacts to the *gradient* of the RTT:
rising delay cuts the rate multiplicatively before queues fill, falling
or low delay additively recovers; the HAI/low/high thresholds follow
the paper's structure.  HPCC (Li et al., SIGCOMM'19) drives per-hop
utilization toward a target ``eta < 1`` with multiplicative correction
plus a small additive probe — near-empty queues, hence the low tail
latency it is known for.  Both update on an ``update_us`` timer (one
control decision per RTT-scale window), not per tick.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

from ..core.dcqcn import DcqcnConfig, DcqcnRate

CC_ALGOS = ("dcqcn", "timely", "hpcc")


@dataclasses.dataclass
class CcConfig:
    """Per-flow congestion-control selection + shared knob set.

    One dataclass covers all three algorithms so a sweep grid can vary
    ``algo`` per point while holding the rest fixed; irrelevant knobs
    are simply unread (DCQCN reads only ``dcqcn``/``min_rate_gbps``).
    """
    algo: str = "dcqcn"
    min_rate_gbps: float = 0.1
    # propagation-only RTT of the path (us): the floor the queueing
    # delay signal is added onto, and HPCC's T in qlen/(B*T)
    base_rtt_us: float = 8.0
    # control-decision period for the delay/INT loops (us)
    update_us: float = 16.0
    # -- Timely knobs --------------------------------------------------
    t_low_us: float = 12.0        # below: additive increase regardless
    t_high_us: float = 40.0       # above: multiplicative decrease
    timely_beta: float = 0.8      # MD strength
    timely_add_gbps: float = 2.0  # AI step
    timely_ewma: float = 0.5      # gradient EWMA gain
    # -- HPCC knobs ----------------------------------------------------
    hpcc_eta: float = 0.95        # target per-hop utilization
    hpcc_ai_gbps: float = 1.0     # additive probe (W_AI)
    # DCQCN parameter override; None = per-line-rate defaults
    dcqcn: Optional[DcqcnConfig] = None

    def __post_init__(self) -> None:
        if self.algo not in CC_ALGOS:
            raise ValueError(f"unknown cc algo {self.algo!r}; "
                             f"pick one of {CC_ALGOS}")
        if self.base_rtt_us <= 0.0 or self.update_us <= 0.0:
            raise ValueError("base_rtt_us and update_us must be positive")
        if not (0.0 < self.t_low_us <= self.t_high_us):
            raise ValueError("need 0 < t_low_us <= t_high_us")
        if not (0.0 < self.hpcc_eta <= 1.0):
            raise ValueError("hpcc_eta must be in (0, 1]")

    def code(self) -> int:
        """Integer algorithm code for stacked per-point parameters."""
        return CC_ALGOS.index(self.algo)


class TimelyRate:
    """Delay-gradient rate control (Timely-style).

    Once per ``update_us`` window the smoothed RTT gradient (normalized
    by ``base_rtt_us``) picks the branch — the exact arithmetic the
    vector engines replicate with ``where`` lanes:

    * ``rtt < t_low``: additive increase (no congestion possible);
    * ``rtt > t_high``: multiplicative decrease proportional to the
      overshoot, ``rc *= 1 - beta * (1 - t_high/rtt)``;
    * gradient <= 0: delay falling — additive increase;
    * gradient > 0: delay rising — ``rc *= max(0, 1 - beta * grad)``.
    """

    def __init__(self, cfg: CcConfig, line_rate_gbps: float):
        self.cfg = cfg
        self.line = line_rate_gbps
        self.rc = line_rate_gbps
        self.prev_rtt_us = cfg.base_rtt_us
        self.rtt_diff_us = 0.0
        self._t_us = 0.0

    def advance(self, dt_us: float) -> float:
        return self.rc

    def on_cnp(self) -> None:
        pass

    def on_signal(self, rtt_us: float, util: float, dt_us: float) -> None:
        c = self.cfg
        self._t_us += dt_us
        if self._t_us < c.update_us:
            return
        self._t_us = 0.0
        diff = rtt_us - self.prev_rtt_us
        self.prev_rtt_us = rtt_us
        self.rtt_diff_us = (1.0 - c.timely_ewma) * self.rtt_diff_us \
            + c.timely_ewma * diff
        grad = self.rtt_diff_us / c.base_rtt_us
        if rtt_us < c.t_low_us:
            r = self.rc + c.timely_add_gbps
        elif rtt_us > c.t_high_us:
            r = self.rc * (1.0 - c.timely_beta * (1.0 - c.t_high_us
                                                  / rtt_us))
        elif grad <= 0.0:
            r = self.rc + c.timely_add_gbps
        else:
            r = self.rc * max(0.0, 1.0 - c.timely_beta * grad)
        self.rc = min(self.line, max(c.min_rate_gbps, r))


class HpccRate:
    """Utilization-targeting rate control (HPCC-style INT).

    Once per ``update_us`` window the max per-hop utilization ``U``
    (from :meth:`on_signal`) is driven toward ``eta``: multiplicative
    correction ``rc *= clip(eta/U, 0.5, 2.0)`` plus the additive probe
    ``hpcc_ai_gbps``.  The clip bounds one decision's swing (HPCC's
    per-ack correction is similarly bounded by its reference window).
    """

    def __init__(self, cfg: CcConfig, line_rate_gbps: float):
        self.cfg = cfg
        self.line = line_rate_gbps
        self.rc = line_rate_gbps
        self._t_us = 0.0

    def advance(self, dt_us: float) -> float:
        return self.rc

    def on_cnp(self) -> None:
        pass

    def on_signal(self, rtt_us: float, util: float, dt_us: float) -> None:
        c = self.cfg
        self._t_us += dt_us
        if self._t_us < c.update_us:
            return
        self._t_us = 0.0
        mult = c.hpcc_eta / max(util, 0.01)
        mult = min(max(mult, 0.5), 2.0)
        self.rc = min(self.line,
                      max(c.min_rate_gbps, self.rc * mult + c.hpcc_ai_gbps))


CongestionControl = Union[DcqcnRate, TimelyRate, HpccRate]


def make_controller(cc: Optional[CcConfig],
                    line_rate_gbps: float) -> CongestionControl:
    """Build the per-flow rate machine a :class:`CcConfig` selects.

    ``None`` (or ``algo="dcqcn"`` without an override) keeps today's
    per-line-rate DCQCN defaults, so existing scenarios are untouched.
    """
    if cc is None or cc.algo == "dcqcn":
        dcfg = cc.dcqcn if cc is not None and cc.dcqcn is not None \
            else DcqcnConfig(line_rate_gbps=line_rate_gbps)
        return DcqcnRate(dcfg)
    if cc.algo == "timely":
        return TimelyRate(cc, line_rate_gbps)
    return HpccRate(cc, line_rate_gbps)
