from .pipeline import PipelineConfig, SyntheticPipeline, for_arch
__all__ = ["PipelineConfig", "SyntheticPipeline", "for_arch"]
