"""Deterministic, checkpointable, host-sharded synthetic token pipeline.

Production shape: each host materializes only its slice of the global batch
(``process_index``/``process_count``), the cursor is a single integer (the
step), and resuming from a checkpoint reproduces the exact byte stream —
bit-identical restart is a fault-tolerance requirement (tests prove it).

Tokens are a hash-mixed sequence with enough local structure that a model's
loss decreases (next token depends on the previous one), which the 100M
example exploits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class PipelineConfig:
    vocab_size: int
    global_batch: int
    seq_len: int
    num_codebooks: int = 0
    num_patches: int = 0
    d_model: int = 0
    seed: int = 0
    process_index: int = 0
    process_count: int = 1


def _mix(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> 16)) * np.uint64(0x45D9F3B)
    x = (x ^ (x >> 16)) * np.uint64(0x45D9F3B)
    return x ^ (x >> 16)


class SyntheticPipeline:
    """Iterator over batches; ``cursor`` is the only state."""

    def __init__(self, cfg: PipelineConfig, cursor: int = 0):
        self.cfg = cfg
        self.cursor = cursor
        if cfg.global_batch % cfg.process_count:
            raise ValueError("global batch must divide across hosts")
        self.local_batch = cfg.global_batch // cfg.process_count

    def _tokens(self, step: int, rows: np.ndarray, t: int) -> np.ndarray:
        c = self.cfg
        base = _mix(np.uint64(c.seed) + np.uint64(step) * np.uint64(1 << 20)
                    + rows.astype(np.uint64)[:, None] * np.uint64(7919))
        pos = np.arange(t, dtype=np.uint64)[None, :]
        raw = _mix(base + pos * np.uint64(2654435761))
        tok = (raw % np.uint64(c.vocab_size)).astype(np.int64)
        # inject learnable structure: every odd position is a fixed mix of
        # the preceding token (so next-token prediction is partly learnable)
        n_odd = tok[:, 1::2].shape[1]
        tok[:, 1::2] = (tok[:, 0::2][:, :n_odd] * 31 + 7) % c.vocab_size
        return tok.astype(np.int32)

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        step = self.cursor
        self.cursor += 1
        row0 = self.cfg.process_index * self.local_batch
        rows = np.arange(row0, row0 + self.local_batch)
        t = c.seq_len + 1
        if c.num_codebooks:
            toks = np.stack([self._tokens(step * 131 + k, rows, t)
                             for k in range(c.num_codebooks)], axis=1)
            batch = {"tokens": toks[:, :, :-1],
                     "targets": toks[:, 0, 1:]}
        else:
            toks = self._tokens(step, rows, t)
            batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        if c.num_patches:
            rng = np.random.default_rng(c.seed * 7 + step)
            batch["patches"] = rng.standard_normal(
                (self.local_batch, c.num_patches, c.d_model),
                dtype=np.float32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def for_arch(arch: ArchConfig, shape: ShapeConfig, seed: int = 0,
             global_batch: Optional[int] = None,
             seq_len: Optional[int] = None) -> SyntheticPipeline:
    return SyntheticPipeline(PipelineConfig(
        vocab_size=arch.vocab_size,
        global_batch=global_batch or shape.global_batch,
        seq_len=seq_len or shape.seq_len,
        num_codebooks=arch.num_codebooks,
        num_patches=arch.num_patches,
        d_model=arch.d_model,
        seed=seed,
    ))
