"""repro: Jet/RDCA (Li et al., 2022) as a TPU-native JAX training/serving
framework.  See DESIGN.md for the paper->TPU mapping."""
__version__ = "1.0.0"
