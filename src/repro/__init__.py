"""repro: Jet/RDCA (Li et al., 2022) as a TPU-native JAX training/serving
framework.  See DESIGN.md for the paper->TPU mapping.

Module map
----------
- ``core``       Jet/RDCA primitives: buffer pool, READ window, recycle
                 model, escape ladder, DCQCN, Jet service facade, and the
                 single-receiver datapath simulator (``run_sim``).
- ``fabric``     multi-host Clos fabric: ``topology`` (leaf-spine graphs),
                 ``switch`` (output-queued, ECN + PFC), ``hosts`` (the
                 step-able ReceiverHost behind run_sim + DCQCN senders),
                 ``fabric`` (N-host driver -> per-host SimResults, victim
                 goodput, pause fan-out, incast FCT), ``scenarios``
                 (incast / all-to-all / storage mixes) and ``sweep`` (the
                 jax.vmap + lax.scan vectorized parameter-sweep engine
                 with a batched-numpy verification backend).
- ``kernels``    Pallas kernels (staged matmul, jet flash/decode
                 attention, mamba2 SSD) + jnp oracles.
- ``models``     architectures (transformer, MoE, SSM, xLSTM) behind one
                 ``api`` for train/prefill/decode.
- ``parallel``   sharding rules, jet staged collectives, int8+EF grad
                 compression, pipeline stages, shard_map compat shim.
- ``train``      step construction (FSDP/TP/EP, accum microbatching) and
                 the training loop.
- ``serving``    batched engine + paged KV cache over the device pool.
- ``launch``     dry-run lowering/compile audit, HLO analysis, meshes.
- ``configs``    architectures x input shapes, and the paper's own
                 ``jet_testbed`` configuration.
- ``checkpoint`` elastic (reshardable) checkpointing.
- ``data``/``optim``  input pipeline; AdamW with int8 moments.
"""
__version__ = "1.1.0"
