"""Sharded, async, atomic checkpointing with elastic reshard.

Layout:  <dir>/step_<N>/  manifest.json + one .npy per leaf
Commit protocol: write into ``step_<N>.tmp`` then atomic rename — a crashed
writer never corrupts the latest checkpoint.  ``keep_last`` trims history.
``restore(..., mesh/shardings)`` device_puts leaves with the *target* mesh's
shardings, which is exactly elastic rescale (checkpoint from a 16-chip run
restores onto 4 or 64 chips).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(tree, directory: str, step: int, extra: Optional[dict] = None,
         keep_last: int = 3) -> str:
    """Synchronous atomic save; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(jax.device_get(tree))
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(leaf)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _trim(directory, keep_last)
    return final


class AsyncSaver:
    """Background-thread checkpoint writer (one in flight at a time)."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, tree, directory: str, step: int,
             extra: Optional[dict] = None, keep_last: int = 3) -> None:
        self.wait()
        host_tree = jax.device_get(tree)   # snapshot before returning

        def work():
            self.last_path = save(host_tree, directory, step, extra,
                                  keep_last)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, like, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the *target* mesh (elastic reshard)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key in flat_like:
        meta = manifest["leaves"][key]
        arr = np.load(os.path.join(path, meta["file"]))
        sh = flat_shard.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None \
            else jax.numpy.asarray(arr)
    # rebuild tree in like's structure
    leaves_paths = jax.tree_util.tree_flatten_with_path(like)
    keys_in_order = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                              for p in path_) for path_, _ in
                     leaves_paths[0]]
    rebuilt = [out[k] for k in keys_in_order]
    extra = manifest.get("extra", {})
    return jax.tree_util.tree_unflatten(leaves_paths[1], rebuilt), extra


def _trim(directory: str, keep_last: int) -> None:
    steps = sorted([d for d in os.listdir(directory)
                    if d.startswith("step_") and not d.endswith(".tmp")])
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d))
