"""Optimizers: AdamW (+8-bit moments), schedules."""
from .adamw import OptConfig, global_norm, init, schedule, update

__all__ = ["OptConfig", "global_norm", "init", "schedule", "update"]
