"""AdamW with optional blockwise-int8 moment states (8-bit Adam).

At 400B parameters x 256 chips, fp32 (m, v) is 3.1 GB/chip *each*; int8
moments with per-256-block fp32 scales cut optimizer state ~3.9x, which is
what lets llama4-maverick train_4k fit v5e HBM (see EXPERIMENTS.md §Dry-run).
Optimizer state inherits the parameters' (FSDP) sharding — ZeRO-style.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.compression import (dequantize_int8_rowwise,
                                    quantize_int8_rowwise)


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    int8_moments: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # hierarchical cross-pod gradient sync: in-pod reduction stays exact
    # (XLA reduce-scatter over data/model); the pod-axis mean is int8 with
    # error feedback (parallel.compression.compressed_psum) — 4x less
    # pod-link traffic.  Adds a bf16 residual tree to the train state.
    compressed_pod_grads: bool = False


def schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(1, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# Row-wise (last-dim-scale) int8: q keeps the parameter's exact shape and
# scale its leading dims, so the quantized state inherits the parameter's
# sharding with no reshape (see parallel.compression.quantize_int8_rowwise).
def _q(x):
    q, s = quantize_int8_rowwise(x)
    return {"q": q, "s": s}


def _dq(m, shape):
    del shape
    return dequantize_int8_rowwise(m["q"], m["s"])


def init(params, cfg: OptConfig) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    if cfg.int8_moments:
        m = jax.tree.map(_q, zeros)
        v = jax.tree.map(_q, zeros)
    else:
        m, v = zeros, jax.tree.map(jnp.copy, zeros)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def update(grads, state, params, cfg: OptConfig
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, stats)."""
    count = state["count"] + 1
    lr = schedule(state["count"], cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    is_q = cfg.int8_moments

    def leafwise(g, p, m, v):
        m_f = _dq(m, g.shape) if is_q else m
        v_f = _dq(v, g.shape) if is_q else v
        m_n = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_n = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mhat = m_n / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v_n / (1 - cfg.b2 ** count.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p_new = (p.astype(jnp.float32) * (1 - lr * cfg.weight_decay)
                 - lr * upd).astype(p.dtype)
        return p_new, (_q(m_n) if is_q else m_n), \
            (_q(v_n) if is_q else v_n)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"]) if is_q else \
        jax.tree.leaves(state["m"])
    flat_v = treedef.flatten_up_to(state["v"]) if is_q else \
        jax.tree.leaves(state["v"])
    out = [leafwise(g, p, m, v)
           for g, p, m, v in zip(flat_g, flat_p, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"m": new_m, "v": new_v, "count": count}, stats
