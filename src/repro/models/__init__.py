"""Model zoo covering the 10 assigned architectures."""
from . import api
from .api import (abstract_params, decode_step, forward, init_decode_state,
                  init_params, input_specs, loss_fn, prefill,
                  synthetic_inputs)

__all__ = ["abstract_params", "api", "decode_step", "forward",
           "init_decode_state", "init_params", "input_specs", "loss_fn",
           "prefill", "synthetic_inputs"]
