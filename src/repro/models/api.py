"""Model API: abstract init, input specs per (arch x shape), entry points.

``input_specs`` returns ShapeDtypeStructs for every model input of a cell —
weak-type-correct, shardable, no device allocation — exactly what the
multi-pod dry-run lowers against.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeConfig
from ..parallel.sharding import ParallelCtx
from . import decoding, transformer

# re-exports
init_params = transformer.init_params
forward = transformer.forward
loss_fn = transformer.loss_fn
prefill = decoding.prefill
decode_step = decoding.decode_step
init_decode_state = decoding.init_decode_state


def abstract_params(cfg: ArchConfig, dtype=jnp.float32):
    """Parameter ShapeDtypeStructs via eval_shape (no allocation)."""
    return jax.eval_shape(
        lambda k: transformer.init_params(cfg, k, dtype),
        jax.random.key(0))


def token_shape(cfg: ArchConfig, batch: int, seq: int):
    if cfg.num_codebooks:
        return (batch, cfg.num_codebooks, seq)
    return (batch, seq)


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the step function."""
    b, t = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        out = {
            "tokens": sds(token_shape(cfg, b, t), jnp.int32),
            "targets": sds((b, t), jnp.int32),
        }
        if cfg.num_patches:
            out["patches"] = sds((b, cfg.num_patches, cfg.d_model), dtype)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds(token_shape(cfg, b, t), jnp.int32)}
        if cfg.num_patches:
            out["patches"] = sds((b, cfg.num_patches, cfg.d_model), dtype)
        return out
    # decode: one new token against a cache of t tokens
    state = jax.eval_shape(
        functools.partial(decoding.init_decode_state, cfg, b, t,
                          dtype=dtype))
    tok = sds((b, cfg.num_codebooks) if cfg.num_codebooks else (b,),
              jnp.int32)
    return {"tokens": tok, "state": state,
            "lengths": sds((b,), jnp.int32)}


def synthetic_inputs(cfg: ArchConfig, shape: ShapeConfig, key,
                     dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Concrete random inputs matching input_specs (for smoke tests)."""
    specs = input_specs(cfg, shape, dtype)
    out: Dict[str, Any] = {}
    for name, s in specs.items():
        if name == "state":
            out[name] = decoding.init_decode_state(cfg, shape.global_batch,
                                                   shape.seq_len, dtype)
        elif s.dtype == jnp.int32 and name in ("tokens", "targets"):
            key, sub = jax.random.split(key)
            out[name] = jax.random.randint(sub, s.shape, 0,
                                           cfg.vocab_size, jnp.int32)
        elif name == "lengths":
            out[name] = jnp.full(s.shape, shape.seq_len - 1, jnp.int32)
        else:
            key, sub = jax.random.split(key)
            out[name] = jax.random.normal(sub, s.shape, s.dtype)
    return out
