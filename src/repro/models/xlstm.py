"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential recurrence with block-diagonal recurrent weights).

The chunked mLSTM is mathematically a gated linear attention; like the SSD
kernel it streams sequence fragments through a recycled (Dk,Dv) state carry
(the Jet pipeline shape).  Simplification vs. the paper's stabilized
exponential gating: input/forget gates use sigmoid (bounded, so no max-
stabilizer state is required); recorded in DESIGN.md.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..parallel.sharding import ParallelCtx


def _dims(cfg: ArchConfig) -> Tuple[int, int]:
    h = cfg.num_heads
    dh = cfg.hd
    return h, dh


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #
def mlstm_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, dh = _dims(cfg)
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, h * dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, h * dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, h * dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (h * dh, d), dtype) * ((h * dh) ** -0.5),
        "w_if": jax.random.normal(ks[4], (d, 2 * h), dtype) * s,
        "if_bias": jnp.concatenate([jnp.full((h,), -2.0),
                                    jnp.full((h,), 3.0)]).astype(dtype),
    }


def mlstm_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                ctx: ParallelCtx, chunk: int = 128,
                return_state: bool = False):
    """Chunk-parallel mLSTM. x: [B, T, D]."""
    b, t, d = x.shape
    h, dh = _dims(cfg)
    L = min(chunk, t)
    assert t % L == 0
    nc = t // L
    q = (x @ params["wq"]).reshape(b, t, h, dh).astype(jnp.float32) \
        * (dh ** -0.5)
    k = (x @ params["wk"]).reshape(b, t, h, dh).astype(jnp.float32)
    v = (x @ params["wv"]).reshape(b, t, h, dh).astype(jnp.float32)
    gates = x @ params["w_if"] + params["if_bias"]
    ig = jax.nn.sigmoid(gates[..., :h].astype(jnp.float32))   # [B,T,H]
    lf = jax.nn.log_sigmoid(gates[..., h:].astype(jnp.float32))

    qc = q.reshape(b, nc, L, h, dh)
    kc = k.reshape(b, nc, L, h, dh)
    vc = v.reshape(b, nc, L, h, dh)
    ic = ig.reshape(b, nc, L, h)
    fc = lf.reshape(b, nc, L, h)

    def step(carry, inp):
        cmat, nvec = carry                  # [B,H,Dk,Dv], [B,H,Dk]
        qq, kk, vv, ii, ff = inp
        cum = jnp.cumsum(ff, axis=1)        # [B,L,H]
        seg = cum[:, :, None, :] - cum[:, None, :, :]
        tril = jnp.tril(jnp.ones((L, L), bool))[None, :, :, None]
        dec = jnp.where(tril, jnp.exp(seg), 0.0) * ii[:, None, :, :]
        sc = jnp.einsum("blhd,bmhd->blmh", qq, kk) * dec
        num = jnp.einsum("blmh,bmhv->blhv", sc, vv)
        den = sc.sum(axis=2)                 # [B,L,H]
        dq = jnp.exp(cum)
        num = num + dq[..., None] * jnp.einsum("blhk,bhkv->blhv", qq, cmat)
        den = den + dq * jnp.einsum("blhk,bhk->blh", qq, nvec)
        y = num / jnp.maximum(jnp.abs(den)[..., None], 1.0)
        to_end = jnp.exp(cum[:, -1:, :] - cum) * ii      # [B,L,H]
        cmat = (jnp.exp(cum[:, -1, :])[..., None, None] * cmat +
                jnp.einsum("blh,blhk,blhv->bhkv", to_end, kk, vv))
        nvec = (jnp.exp(cum[:, -1, :])[..., None] * nvec +
                jnp.einsum("blh,blhk->bhk", to_end, kk))
        return (cmat, nvec), y

    init = (jnp.zeros((b, h, dh, dh), jnp.float32),
            jnp.zeros((b, h, dh), jnp.float32))
    (cmat, nvec), ys = jax.lax.scan(
        step, init, (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
                     vc.transpose(1, 0, 2, 3, 4), ic.transpose(1, 0, 2, 3),
                     fc.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, t, h * dh).astype(x.dtype)
    out = y @ params["wo"]
    if return_state:
        return out, (cmat, nvec)
    return out


def mlstm_decode(params: dict, x: jnp.ndarray, state, cfg: ArchConfig,
                 ctx: ParallelCtx):
    """x: [B,1,D]; state=(C [B,H,Dk,Dv], n [B,H,Dk])."""
    b = x.shape[0]
    h, dh = _dims(cfg)
    cmat, nvec = state
    q = (x[:, 0] @ params["wq"]).reshape(b, h, dh).astype(jnp.float32) \
        * (dh ** -0.5)
    k = (x[:, 0] @ params["wk"]).reshape(b, h, dh).astype(jnp.float32)
    v = (x[:, 0] @ params["wv"]).reshape(b, h, dh).astype(jnp.float32)
    gates = x[:, 0] @ params["w_if"] + params["if_bias"]
    ig = jax.nn.sigmoid(gates[..., :h].astype(jnp.float32))
    fg = jax.nn.sigmoid(gates[..., h:].astype(jnp.float32))
    cmat = fg[..., None, None] * cmat + \
        ig[..., None, None] * k[..., :, None] * v[..., None, :]
    nvec = fg[..., None] * nvec + ig[..., None] * k
    num = jnp.einsum("bhk,bhkv->bhv", q, cmat)
    den = jnp.einsum("bhk,bhk->bh", q, nvec)
    y = num / jnp.maximum(jnp.abs(den)[..., None], 1.0)
    out = y.reshape(b, 1, h * dh).astype(x.dtype) @ params["wo"]
    return out, (cmat, nvec)


def mlstm_state_init(cfg: ArchConfig, batch: int):
    h, dh = _dims(cfg)
    return (jnp.zeros((batch, h, dh, dh), jnp.float32),
            jnp.zeros((batch, h, dh), jnp.float32))


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #
def slstm_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, dh = _dims(cfg)
    ks = jax.random.split(key, 3)
    s = d ** -0.5
    return {
        # input projections for (z, i, f, o)
        "w_x": jax.random.normal(ks[0], (d, 4 * d), dtype) * s,
        # block-diagonal recurrent weights, one [Dh, 4Dh] block per head
        "r_h": jax.random.normal(ks[1], (h, dh, 4 * dh), dtype) * (dh ** -0.5),
        "bias": jnp.zeros((4 * d,), dtype),
        "wo": jax.random.normal(ks[2], (d, d), dtype) * s,
    }


def _slstm_cell(params, cfg, xproj_t, carry):
    """One recurrent step. xproj_t: [B, 4D]; carry = (hidden, c, n)."""
    h_heads, dh = _dims(cfg)
    hidden, c, n = carry                     # [B,D], [B,D], [B,D]
    b = hidden.shape[0]
    hh = hidden.reshape(b, h_heads, dh)
    rec = jnp.einsum("bhk,hkm->bhm", hh, params["r_h"]).reshape(
        b, 4 * cfg.d_model)
    za, ia, fa, oa = jnp.split(xproj_t + rec + params["bias"], 4, axis=-1)
    z = jnp.tanh(za)
    i = jax.nn.sigmoid(ia)
    f = jax.nn.sigmoid(fa)
    o = jax.nn.sigmoid(oa)
    c = f * c + i * z
    n = f * n + i
    hidden = o * c / jnp.maximum(jnp.abs(n), 1.0)
    return hidden, c, n


def slstm_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                ctx: ParallelCtx, return_state: bool = False):
    """Sequential sLSTM. x: [B, T, D] (scan over T — inherently serial)."""
    b, t, d = x.shape
    xproj = x @ params["w_x"]                # [B, T, 4D]

    def step(carry, xt):
        carry = _slstm_cell(params, cfg, xt, carry)
        return carry, carry[0]

    init = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3))
    carry, hs = jax.lax.scan(step, init, xproj.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype) @ params["wo"]
    if return_state:
        return y, carry
    return y


def slstm_decode(params: dict, x: jnp.ndarray, state, cfg: ArchConfig,
                 ctx: ParallelCtx):
    xproj = x[:, 0] @ params["w_x"]
    carry = _slstm_cell(params, cfg, xproj, state)
    y = carry[0][:, None, :].astype(x.dtype) @ params["wo"]
    return y, carry


def slstm_state_init(cfg: ArchConfig, batch: int):
    return tuple(jnp.zeros((batch, cfg.d_model), jnp.float32)
                 for _ in range(3))
