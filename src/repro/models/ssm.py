"""Mamba2 block (SSD) for zamba2: projections + causal depthwise conv +
chunked SSD scan + gated output.

The chunked SSD scan (kernels/mamba2_ssd.py, ref.ssd_chunked_ref) is itself a
Jet-style pipeline: sequence fragments stream through a recycled (N,P) state
carry — the full state history never materializes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops
from ..parallel.sharding import ParallelCtx

CONV_K = 4


def mamba_dims(cfg: ArchConfig) -> Tuple[int, int, int, int, int]:
    d_in = 2 * cfg.d_model
    h = cfg.ssm_heads
    p = cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    return d_in, h, p, g, n


def mamba_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    d_in, h, p, g, n = mamba_dims(cfg)
    conv_ch = d_in + 2 * g * n
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "w_xbc": jax.random.normal(ks[0], (d, conv_ch), dtype) * s,
        "w_z": jax.random.normal(ks[1], (d, d_in), dtype) * s,
        "w_dt": jax.random.normal(ks[2], (d, h), dtype) * s,
        "dt_bias": jnp.zeros((h,), dtype) + jnp.asarray(
            jnp.log(jnp.expm1(0.05)), dtype),       # softplus^-1(0.05)
        "a_log": jnp.log(jnp.linspace(1.0, 8.0, h)).astype(dtype),
        "d_skip": jnp.ones((h,), dtype),
        "conv_w": jax.random.normal(ks[3], (CONV_K, conv_ch), dtype) * 0.3,
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "w_out": jax.random.normal(ks[4], (d_in, d), dtype) * (d_in ** -0.5),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along T. x: [B, T, C]; w: [K, C].
    ``state``: [B, K-1, C] left context (decode).  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k)) + b
    new_state = xp[:, -(k - 1):, :]
    return y, new_state


def mamba_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                ctx: ParallelCtx, return_state: bool = False):
    """Training/prefill. x: [B, T, D] -> [B, T, D]."""
    b, t, d = x.shape
    d_in, h, p, g, n = mamba_dims(cfg)
    xbc, conv_state = _causal_conv(x @ params["w_xbc"], params["conv_w"],
                                   params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(b, t, h, p)
    bmat = xbc[..., d_in:d_in + g * n].reshape(b, t, g, n)
    cmat = xbc[..., d_in + g * n:].reshape(b, t, g, n)
    dt = jax.nn.softplus(x @ params["w_dt"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    y, h_t = ops.ssd(xs, dt, a, bmat, cmat, chunk=min(256, t))
    y = y + params["d_skip"][None, None, :, None] * xs
    y = y.reshape(b, t, d_in) * jax.nn.silu(x @ params["w_z"])
    out = y @ params["w_out"]
    if return_state:
        return out, (conv_state, h_t)
    return out


def mamba_decode(params: dict, x: jnp.ndarray, state, cfg: ArchConfig,
                 ctx: ParallelCtx):
    """One-token decode. x: [B, 1, D]; state = (conv_state [B,K-1,C],
    h [B,H,N,P]) -> (out [B,1,D], new_state)."""
    b = x.shape[0]
    d_in, h, p, g, n = mamba_dims(cfg)
    conv_state, h_ssm = state
    xbc, conv_state = _causal_conv(x @ params["w_xbc"], params["conv_w"],
                                   params["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)[:, 0]                       # [B, C]
    xs = xbc[..., :d_in].reshape(b, h, p)
    bm = xbc[..., d_in:d_in + g * n].reshape(b, g, n)
    cm = xbc[..., d_in + g * n:].reshape(b, g, n)
    bm = jnp.repeat(bm, h // g, axis=1)                # [B, H, N]
    cm = jnp.repeat(cm, h // g, axis=1)
    dt = jax.nn.softplus(x[:, 0] @ params["w_dt"] + params["dt_bias"])
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)[..., None, None]           # [B, H, 1, 1]
    h_new = h_ssm * decay + (dt[..., None, None] * bm[..., :, None] *
                             xs[..., None, :].astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", cm.astype(jnp.float32), h_new)
    y = y.astype(x.dtype) + params["d_skip"][None, :, None] * xs
    y = y.reshape(b, 1, d_in) * jax.nn.silu(x @ params["w_z"])
    return y @ params["w_out"], (conv_state, h_new)


def mamba_state_init(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    d_in, h, p, g, n = mamba_dims(cfg)
    conv_ch = d_in + 2 * g * n
    return (jnp.zeros((batch, CONV_K - 1, conv_ch), dtype),
            jnp.zeros((batch, h, n, p), jnp.float32))
