"""Attention: GQA / MQA / sliding-window / cross-attention, with training
(flash, KV streamed in fragments), prefill (returns the built KV cache) and
decode (sequence-parallel partial-softmax combine) paths.

Distributed decode is the model-level image of the paper's two-path design:
KV fragments are the *large messages* (each shard consumes its KV slice from
a staged buffer) and the per-shard (o, lse) partials are the *small messages*
merged SRQ-style (`repro.kernels.ref.combine_partial_attention`)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..kernels import ops, ref
from ..parallel.sharding import ParallelCtx
from .layers import apply_rope


def attn_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, ad, kvd = cfg.d_model, cfg.attn_dim, cfg.kv_dim
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(ks[0], (d, ad), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kvd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kvd), dtype) * s,
        "wo": jax.random.normal(ks[3], (ad, d), dtype) * (ad ** -0.5),
    }


def _project_qkv(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                 positions: jnp.ndarray, rope: bool = True):
    b, t, _ = x.shape
    q = (x @ params["wq"]).reshape(b, t, cfg.num_heads, cfg.hd)
    k = (x @ params["wk"]).reshape(b, t, cfg.num_kv_heads, cfg.hd)
    v = (x @ params["wv"]).reshape(b, t, cfg.num_kv_heads, cfg.hd)
    if rope:
        q = apply_rope(q, positions, cfg.hd, cfg.rope_fraction,
                       cfg.rope_theta)
        k = apply_rope(k, positions, cfg.hd, cfg.rope_fraction,
                       cfg.rope_theta)
    return q, k, v


def self_attention(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                   ctx: ParallelCtx,
                   return_kv: bool = False):
    """Training/prefill self-attention. x: [B, T, D]."""
    b, t, _ = x.shape
    positions = jnp.arange(t)[None, :]
    q, k, v = _project_qkv(params, x, cfg, positions)
    # [B, H, T, hd] layout for the kernels
    o = ops.flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3),
                            causal=True, window=cfg.sliding_window)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.attn_dim)
    out = o @ params["wo"]
    if return_kv:
        return out, (k, v)   # [B, T, Hkv, hd] — prefill cache build
    return out


def cross_attention(params: dict, x: jnp.ndarray, kv_src: jnp.ndarray,
                    cfg: ArchConfig, ctx: ParallelCtx) -> jnp.ndarray:
    """x: [B, T, D] attends over kv_src: [B, P, D] (patch embeddings)."""
    b, t, _ = x.shape
    p = kv_src.shape[1]
    q = (x @ params["wq"]).reshape(b, t, cfg.num_heads, cfg.hd)
    k = (kv_src @ params["wk"]).reshape(b, p, cfg.num_kv_heads, cfg.hd)
    v = (kv_src @ params["wv"]).reshape(b, p, cfg.num_kv_heads, cfg.hd)
    o = ops.flash_attention(q.transpose(0, 2, 1, 3),
                            k.transpose(0, 2, 1, 3),
                            v.transpose(0, 2, 1, 3), causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.attn_dim)
    return o @ params["wo"]


# --------------------------------------------------------------------------- #
# Decode (one token, KV cache)
# --------------------------------------------------------------------------- #
def cache_update(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                 k_new: jnp.ndarray, v_new: jnp.ndarray,
                 lengths: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Insert one token per sequence. cache: [B, S, Hkv, hd]; ring-buffer
    semantics (pos = len % S) support sliding-window caches."""
    b, s = cache_k.shape[0], cache_k.shape[1]
    pos = (lengths % s).astype(jnp.int32)
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, pos].set(k_new[:, 0])
    cache_v = cache_v.at[bidx, pos].set(v_new[:, 0])
    return cache_k, cache_v


def decode_self_attention(params: dict, x: jnp.ndarray,
                          cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                          lengths: jnp.ndarray, cfg: ArchConfig,
                          ctx: ParallelCtx):
    """x: [B, 1, D]; cache: [B, S, Hkv, hd]; lengths: [B] tokens already in
    cache.  Returns (out [B,1,D], new_cache_k, new_cache_v).

    When ``ctx`` has a mesh and the cache is sequence-sharded, XLA partitions
    the softmax reduction; the (o, lse)-combine formulation below keeps that
    reduction per-shard-local followed by a small combine (SRQ path)."""
    b = x.shape[0]
    q, k_new, v_new = _project_qkv(params, x, cfg, lengths[:, None])
    cache_k, cache_v = cache_update(cache_k, cache_v, k_new, v_new, lengths)
    s = cache_k.shape[1]
    # Ring-buffer validity: before wrap-around slots [0, len+1) hold data;
    # after wrap every slot does.  SWA caches are allocated with S = window,
    # so the ring itself enforces the sliding window.
    valid_count = jnp.minimum(lengths + 1, s)
    o, _lse = ref.decode_attention_naive(
        q.reshape(b, cfg.num_heads, cfg.hd), cache_k, cache_v, valid_count)
    out = o.reshape(b, 1, cfg.attn_dim) @ params["wo"]
    return out, cache_k, cache_v
