"""Prefill and decode paths (serving): KV caches, SSM states, ring buffers.

Decode state mirrors the parameter layout (pattern-stacked + remainder) so
the decode step is the same ``lax.scan`` over units as training.  KV caches
are ring buffers sized ``min(max_len, sliding_window)`` — a sliding-window
arch at 500k context carries only its window (the Little's-law sizing).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ref as kref
from ..parallel.sharding import ParallelCtx
from . import attention as attn
from . import ssm, xlstm
from .layers import mlp_apply, rms_norm
from .transformer import (embed_tokens, layer_kinds, segments, unembed,
                          _shared_block)

State = Dict[str, Any]


def cache_len_for(cfg: ArchConfig, max_len: int) -> int:
    if cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


# --------------------------------------------------------------------------- #
# state init
# --------------------------------------------------------------------------- #
def _layer_state(kind: str, cfg: ArchConfig, batch: int, s_cache: int,
                 dtype) -> State:
    st: State = {}
    kv_shape = (batch, s_cache, cfg.num_kv_heads, cfg.hd)
    if kind.startswith("attn") or kind == "mamba_attn":
        st["kv"] = (jnp.zeros(kv_shape, dtype), jnp.zeros(kv_shape, dtype))
    if kind == "attn_cross":
        xshape = (batch, cfg.num_patches, cfg.num_kv_heads, cfg.hd)
        st["xkv"] = (jnp.zeros(xshape, dtype), jnp.zeros(xshape, dtype))
    if kind in ("mamba", "mamba_attn"):
        st["mamba"] = ssm.mamba_state_init(cfg, batch, dtype)
    if kind == "mlstm":
        st["mlstm"] = xlstm.mlstm_state_init(cfg, batch)
    if kind == "slstm":
        st["slstm"] = xlstm.slstm_state_init(cfg, batch)
    return st


def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> State:
    pattern, n_units, rem = segments(cfg)
    s_cache = cache_len_for(cfg, max_len)

    def stacked(kind):
        one = _layer_state(kind, cfg, batch, s_cache, dtype)
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n_units,) + l.shape).copy(), one)

    return {
        "pattern": tuple(stacked(k) for k in pattern),
        "remainder": tuple(_layer_state(k, cfg, batch, s_cache, dtype)
                           for k in rem),
    }


# --------------------------------------------------------------------------- #
# prefill
# --------------------------------------------------------------------------- #
def _ring_place(kv: jnp.ndarray, s_cache: int) -> jnp.ndarray:
    """Place the last ``s_cache`` tokens of [B,T,...] into ring slots such
    that token t sits at slot t % s_cache."""
    t = kv.shape[1]
    if t <= s_cache:
        pad = [(0, 0)] * kv.ndim
        pad[1] = (0, s_cache - t)
        return jnp.pad(kv, pad)
    tail = kv[:, -s_cache:]
    return jnp.roll(tail, shift=t % s_cache, axis=1)


def _prefill_layer(kind: str, p, x, cfg, ctx, shared, patches, s_cache):
    st: State = {}
    h = rms_norm(x, p["ln1"])
    if kind.startswith("attn"):
        y, (k, v) = attn.self_attention(p["attn"], h, cfg, ctx,
                                        return_kv=True)
        x = x + y
        st["kv"] = (_ring_place(k, s_cache), _ring_place(v, s_cache))
        if kind == "attn_cross":
            x = x + attn.cross_attention(p["xattn"], rms_norm(x, p["ln_x"]),
                                         patches, cfg, ctx)
            b, np_, _ = patches.shape
            xk = (patches @ p["xattn"]["wk"]).reshape(
                b, np_, cfg.num_kv_heads, cfg.hd)
            xv = (patches @ p["xattn"]["wv"]).reshape(
                b, np_, cfg.num_kv_heads, cfg.hd)
            st["xkv"] = (xk, xv)
        h2 = rms_norm(x, p["ln2"])
        if kind == "attn_moe":
            from .moe import moe_apply
            y2, _ = moe_apply(p["ffn"], h2, cfg, ctx)
            x = x + y2
        else:
            x = x + mlp_apply(p["ffn"], h2, cfg.mlp)
    elif kind in ("mamba", "mamba_attn"):
        y, mst = ssm.mamba_apply(p["mamba"], h, cfg, ctx, return_state=True)
        x = x + y
        st["mamba"] = mst
        if kind == "mamba_attn":
            hs = rms_norm(x, shared["ln1"])
            ys, (k, v) = attn.self_attention(shared["attn"], hs, cfg, ctx,
                                             return_kv=True)
            x = x + ys
            x = x + mlp_apply(shared["ffn"], rms_norm(x, shared["ln2"]),
                              cfg.mlp)
            st["kv"] = (_ring_place(k, s_cache), _ring_place(v, s_cache))
    elif kind == "mlstm":
        y, mst = xlstm.mlstm_apply(p["mlstm"], h, cfg, ctx,
                                   return_state=True)
        x = x + y
        st["mlstm"] = mst
    elif kind == "slstm":
        y, sst = xlstm.slstm_apply(p["slstm"], h, cfg, ctx,
                                   return_state=True)
        x = x + y
        st["slstm"] = sst
    return x, st


def prefill(params, cfg: ArchConfig, ctx: ParallelCtx, tokens: jnp.ndarray,
            patches: Optional[jnp.ndarray] = None, max_len: int = 0,
            compute_dtype=jnp.bfloat16):
    """Process the prompt; returns (last-position logits [B,V], state,
    lengths [B]).  ``max_len`` sizes the decode cache (default: prompt len)."""
    pattern, n_units, rem = segments(cfg)
    t = tokens.shape[-1]
    max_len = max_len or t
    s_cache = cache_len_for(cfg, max_len)
    cast = lambda tr: jax.tree.map(lambda w: w.astype(compute_dtype)
                                   if w.dtype == jnp.float32 else w, tr)
    x = embed_tokens(params, tokens, cfg, compute_dtype)
    bsz = x.shape[0]
    x = ctx.constrain(x, ctx.act_for(bsz))
    if patches is not None:
        patches = patches.astype(compute_dtype)
    shared = cast(params.get("shared_attn"))

    def scan_body(x, unit_params):
        sts = []
        for pos, kind in enumerate(pattern):
            x, st = _prefill_layer(kind, cast(unit_params[pos]), x, cfg,
                                   ctx, shared, patches, s_cache)
            x = ctx.constrain(x, ctx.act_for(bsz))
            sts.append(st)
        return x, tuple(sts)

    x, pat_state = jax.lax.scan(scan_body, x, params["pattern"])
    rem_states = []
    for p_l, kind in zip(params["remainder"],
                         layer_kinds(cfg)[n_units * len(pattern):]):
        x, st = _prefill_layer(kind, cast(p_l), x, cfg, ctx, shared,
                               patches, s_cache)
        rem_states.append(st)
    logits = unembed(params, x[:, -1:, :], cfg)[:, 0]
    lengths = jnp.full((tokens.shape[0],), t, jnp.int32)
    return logits, {"pattern": pat_state, "remainder": tuple(rem_states)}, \
        lengths


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def _decode_layer(kind: str, p, st: State, x, lengths, cfg, ctx, shared):
    new: State = {}
    h = rms_norm(x, p["ln1"])
    if kind.startswith("attn"):
        y, ck, cv = attn.decode_self_attention(p["attn"], h, st["kv"][0],
                                               st["kv"][1], lengths, cfg,
                                               ctx)
        x = x + y
        new["kv"] = (ck, cv)
        if kind == "attn_cross":
            xk, xv = st["xkv"]
            b = x.shape[0]
            q = (rms_norm(x, p["ln_x"]) @ p["xattn"]["wq"]).reshape(
                b, cfg.num_heads, cfg.hd)
            np_ = xk.shape[1]
            o, _ = kref.decode_attention_naive(
                q, xk, xv, jnp.full((b,), np_, jnp.int32))
            x = x + o.reshape(b, 1, cfg.attn_dim) @ p["xattn"]["wo"]
            new["xkv"] = (xk, xv)
        h2 = rms_norm(x, p["ln2"])
        if kind == "attn_moe":
            from .moe import moe_apply
            y2, _ = moe_apply(p["ffn"], h2, cfg, ctx)
            x = x + y2
        else:
            x = x + mlp_apply(p["ffn"], h2, cfg.mlp)
    elif kind in ("mamba", "mamba_attn"):
        y, mst = ssm.mamba_decode(p["mamba"], h, st["mamba"], cfg, ctx)
        x = x + y
        new["mamba"] = mst
        if kind == "mamba_attn":
            hs = rms_norm(x, shared["ln1"])
            y2, ck, cv = attn.decode_self_attention(
                shared["attn"], hs, st["kv"][0], st["kv"][1], lengths, cfg,
                ctx)
            x = x + y2
            x = x + mlp_apply(shared["ffn"], rms_norm(x, shared["ln2"]),
                              cfg.mlp)
            new["kv"] = (ck, cv)
    elif kind == "mlstm":
        y, mst = xlstm.mlstm_decode(p["mlstm"], h, st["mlstm"], cfg, ctx)
        x = x + y
        new["mlstm"] = mst
    elif kind == "slstm":
        y, sst = xlstm.slstm_decode(p["slstm"], h, st["slstm"], cfg, ctx)
        x = x + y
        new["slstm"] = sst
    return x, new


def decode_step(params, cfg: ArchConfig, ctx: ParallelCtx, state: State,
                tokens: jnp.ndarray, lengths: jnp.ndarray,
                compute_dtype=jnp.bfloat16):
    """One decode step. tokens: [B] (or [B,K] audio); lengths: [B] tokens
    already in the cache.  Returns (logits [B,V], new_state)."""
    pattern, n_units, rem = segments(cfg)
    cast = lambda tr: jax.tree.map(lambda w: w.astype(compute_dtype)
                                   if w.dtype == jnp.float32 else w, tr)
    tok = tokens[..., None]        # [B,1] (or [B,K,1] audio)
    x = embed_tokens(params, tok, cfg, compute_dtype)   # [B,1,D]
    bsz = x.shape[0]
    shared = cast(params.get("shared_attn"))

    def scan_body(x, xs):
        unit_params, unit_state = xs
        new_states = []
        for pos, kind in enumerate(pattern):
            x, st = _decode_layer(kind, cast(unit_params[pos]),
                                  unit_state[pos], x, lengths, cfg, ctx,
                                  shared)
            new_states.append(st)
        return x, tuple(new_states)

    x, new_pat = jax.lax.scan(scan_body, x,
                              (params["pattern"], state["pattern"]))
    new_rem = []
    for p_l, st, kind in zip(params["remainder"], state["remainder"],
                             layer_kinds(cfg)[n_units * len(pattern):]):
        x, nst = _decode_layer(kind, cast(p_l), st, x, lengths, cfg, ctx,
                               shared)
        new_rem.append(nst)
    logits = unembed(params, x, cfg)[:, 0]
    return logits, {"pattern": new_pat, "remainder": tuple(new_rem)}
