"""Mixture-of-Experts with SRQ-style capacity dispatch + escape (paper §4.1).

The token-dispatch path is the paper's small/large message design mapped to
EP: each expert owns a fixed-capacity slab buffer (the SRQ's pre-posted
WQEs); tokens are scattered into slots, all-to-all'd to their expert shard
(the READ large-message move, fixed fragment size = capacity slab), processed,
and combined.  Tokens beyond capacity take the *escape* path: they bypass the
expert (residual pass-through) and are counted — the MoE image of
"copy to memory / mark ECN".

Two implementations:
  * ``moe_dense_ref`` — all-experts-for-all-tokens oracle (tiny configs/tests)
  * ``moe_ep``        — shard_map expert parallelism over the model axis
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..parallel.compat import shard_map
from ..parallel.sharding import ParallelCtx
from .layers import mlp_apply, mlp_init


def moe_init(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    gated = cfg.mlp in ("swiglu", "geglu")
    def stack(k, shape, scale):
        return jax.random.normal(k, shape, dtype) * scale
    p = {
        "router": stack(ks[0], (d, e), d ** -0.5),
        "e_in": stack(ks[1], (e, d, f), d ** -0.5),
        "e_out": stack(ks[2], (e, f, d), f ** -0.5),
    }
    if gated:
        p["e_gate"] = stack(ks[3], (e, d, f), d ** -0.5)
    if cfg.shared_expert:
        p["shared"] = mlp_init(ks[4], d, f, cfg.mlp, dtype)
    return p


def _expert_ffn(p: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """x: [E, C, D] through per-expert stacked weights."""
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(jnp.einsum("ecd,edf->ecf", x, p["e_gate"])) * \
            jnp.einsum("ecd,edf->ecf", x, p["e_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", x, p["e_in"]),
                        approximate=True)
    return jnp.einsum("ecf,efd->ecd", h, p["e_out"])


def _route_top1(logits: jnp.ndarray):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    gate = jnp.max(probs, axis=-1)
    return idx, gate, probs


def _aux_losses(probs: jnp.ndarray, idx: jnp.ndarray, e: int) -> jnp.ndarray:
    """Switch-style load-balance loss."""
    frac = jnp.mean(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac * mean_p)


def capacity(cf: float, n_tokens: int, e: int) -> int:
    return max(1, int(cf * n_tokens / e))


# --------------------------------------------------------------------------- #
def moe_dense_ref(params: dict, x: jnp.ndarray, cfg: ArchConfig,
                  cap_factor: float) -> Tuple[jnp.ndarray, Dict]:
    """Oracle: compute every expert on every token, mask by routing+capacity.
    x: [B, T, D]."""
    b, t, d = x.shape
    e = cfg.num_experts
    xt = x.reshape(b * t, d)
    idx, gate, probs = _route_top1(xt @ params["router"])
    c = capacity(cap_factor, b * t, e)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) * onehot          # 1-based within expert
    keep = jnp.take_along_axis(rank, idx[:, None], axis=1)[:, 0] <= c
    y_all = _expert_ffn(params,
                        jnp.broadcast_to(xt, (e, b * t, d)), cfg.mlp)
    sel = jax.nn.one_hot(idx, e, dtype=y_all.dtype)     # [n, E]
    y = jnp.einsum("ne,end->nd", sel, y_all)
    y = y * (gate * keep)[:, None].astype(y.dtype)
    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt, cfg.mlp)
    aux = {"lb_loss": _aux_losses(probs, idx, e),
           "overflow": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y.reshape(b, t, d), aux


# --------------------------------------------------------------------------- #
def _ep_body_decode(wr, w_gate, w_in, w_out, x_blk, *, cfg: ArchConfig,
                    cap_factor: float, model_axis: str, model_size: int,
                    fsdp_gather: bool):
    """Decode-path EP: too few tokens to split across model ranks, so every
    rank routes all (replicated) tokens, serves only its local experts, and
    the combine is a psum — the SRQ small-message path (no all-to-all
    latency on the decode critical path)."""
    if fsdp_gather:
        w_in = jax.lax.all_gather(w_in, "data", axis=1, tiled=True)
        w_out = jax.lax.all_gather(w_out, "data", axis=2, tiled=True)
        w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
    b_loc, t, d = x_blk.shape
    e = cfg.num_experts
    e_loc = e // model_size
    r = jax.lax.axis_index(model_axis)
    n = b_loc * t
    xt = x_blk.reshape(n, d)
    idx, gate, probs = _route_top1(xt @ wr)
    c = capacity(cap_factor, n, e)
    local_idx = idx - r * e_loc
    is_local = (local_idx >= 0) & (local_idx < e_loc)
    order = jnp.argsort(jnp.where(is_local, local_idx, e_loc))
    se = jnp.where(is_local, local_idx, e_loc)[order]
    starts = jnp.searchsorted(se, jnp.arange(e_loc))
    rank = jnp.arange(n) - starts[jnp.minimum(se, e_loc - 1)]
    keep = (se < e_loc) & (rank < c)
    dest = jnp.where(keep, se * c + rank, e_loc * c)
    buf = jnp.zeros((e_loc * c + 1, d), xt.dtype).at[dest].set(xt[order])
    out = _expert_ffn({"e_gate": w_gate, "e_in": w_in, "e_out": w_out},
                      buf[:-1].reshape(e_loc, c, d), cfg.mlp)
    flat = jnp.concatenate([out.reshape(e_loc * c, d),
                            jnp.zeros((1, d), out.dtype)], axis=0)
    y_sorted = flat[dest] * keep[:, None].astype(out.dtype)
    y = jnp.zeros_like(xt).at[order].set(y_sorted)
    y = y * gate[:, None].astype(y.dtype)
    y = jax.lax.psum(y, model_axis)           # SRQ combine
    lb = _aux_losses(probs, idx, e)
    dropped = jax.lax.psum(jnp.sum(keep.astype(jnp.float32)), model_axis)
    overflow = 1.0 - dropped / n
    return y.reshape(b_loc, t, d), lb, overflow


def _staged_expert_ffn(w_gate, w_in, w_out, x, kind: str, data_size: int):
    """RDCA in-graph (paper §4.1.2): the expert weights' FSDP shards ride a
    ring over the ``data`` axis and the MXU consumes each fragment the hop
    it arrives — the gathered [E, D, F] weight never exists in HBM.  The
    two live ring slots are the cache-resident buffer pool; the ring depth
    is the in-flight window (1 fragment in flight per tensor).

    x: [E, C, D] tokens (full D locally); w_gate/w_in: [E, D/m, F] shards;
    w_out: [E, F, D/m] shards.  Same collective bytes as all-gather, no
    materialization, compute/comm overlapped by construction.

    VMEM sizing: a llama4 hop fragment is [8, 320, 8192] bf16 = 42 MB; on
    TPU the per-hop einsum runs through kernels/jet_staged_matmul, whose
    BlockSpec tiling sub-fragments the hop into <=256 KB VMEM tiles (the
    paper's READ fragment size) so the staging pool stays well under the
    128 MB VMEM budget with double buffering.
    """
    m = data_size
    r = jax.lax.axis_index("data")
    perm = [(i, (i + 1) % m) for i in range(m)]
    e, c, d = x.shape
    f = w_in.shape[-1]
    dk = d // m
    act = jax.nn.silu if kind == "swiglu" else \
        (lambda v: jax.nn.gelu(v, approximate=True))

    # phase A: h = act(x @ Wg) * (x @ Wi), contraction over D fragments
    def step_a(carry, i):
        hg, hi, wg, wi = carry
        src = (r - i) % m                      # owner of the held fragment
        xs = jax.lax.dynamic_slice_in_dim(x, src * dk, dk, axis=2)
        hg = hg + jnp.einsum("ecd,edf->ecf", xs, wg)
        hi = hi + jnp.einsum("ecd,edf->ecf", xs, wi)
        return (hg, hi, jax.lax.ppermute(wg, "data", perm),
                jax.lax.ppermute(wi, "data", perm)), None

    h0 = jnp.zeros((e, c, f), x.dtype)
    (hg, hi, _, _), _ = jax.lax.scan(step_a, (h0, h0, w_gate, w_in),
                                     jnp.arange(m))
    h = act(hg) * hi

    # phase B: out[:, :, D_src] = h @ Wo_src as Wo shards ride the ring
    def step_b(carry, i):
        out, wo = carry
        src = (r - i) % m
        out = jax.lax.dynamic_update_slice_in_dim(
            out, jnp.einsum("ecf,efd->ecd", h, wo), src * dk, axis=2)
        return (out, jax.lax.ppermute(wo, "data", perm)), None

    (out, _), _ = jax.lax.scan(step_b,
                               (jnp.zeros((e, c, d), x.dtype), w_out),
                               jnp.arange(m))
    return out


def _ep_body(wr, w_gate, w_in, w_out, x_blk, *, cfg: ArchConfig,
             cap_factor: float, model_axis: str, model_size: int,
             fsdp_gather: bool, jet_staged: bool = False):
    """Per-device body under shard_map.  x_blk: [B_loc, T, D] (replicated
    across the model axis); expert weights sharded on E."""
    if x_blk.shape[0] * x_blk.shape[1] % model_size != 0:
        return _ep_body_decode(wr, w_gate, w_in, w_out, x_blk, cfg=cfg,
                               cap_factor=cap_factor, model_axis=model_axis,
                               model_size=model_size,
                               fsdp_gather=fsdp_gather)
    staged = fsdp_gather and jet_staged
    if fsdp_gather and not staged:
        # ZeRO-3: expert weights arrive sharded on D over 'data'; gather
        # (this all-gather is the jet staged-collective hillclimb target)
        w_in = jax.lax.all_gather(w_in, "data", axis=1, tiled=True)
        w_out = jax.lax.all_gather(w_out, "data", axis=2, tiled=True)
        w_gate = jax.lax.all_gather(w_gate, "data", axis=1, tiled=True)
    b_loc, t, d = x_blk.shape
    e = cfg.num_experts
    r = jax.lax.axis_index(model_axis)
    n_all = b_loc * t
    n = n_all // model_size
    xt = x_blk.reshape(n_all, d)
    mine = jax.lax.dynamic_slice_in_dim(xt, r * n, n, 0)

    idx, gate, probs = _route_top1(mine @ wr)
    c = capacity(cap_factor, n, e)
    order = jnp.argsort(idx)
    se = idx[order]                                  # sorted expert ids
    starts = jnp.searchsorted(se, jnp.arange(e))     # first pos per expert
    rank = jnp.arange(n) - starts[se]
    keep = rank < c
    dest = jnp.where(keep, se * c + rank, e * c)     # overflow -> trash slot
    buf = jnp.zeros((e * c + 1, d), xt.dtype).at[dest].set(mine[order])
    buf = buf[:-1].reshape(e, c, d)

    # ---- large-message path: all-to-all to expert shards ----------------- #
    recv = jax.lax.all_to_all(buf, model_axis, split_axis=0,
                              concat_axis=1, tiled=True)   # [E_loc, m*C, D]
    if staged:
        # data-axis size from the shard shape: w_in is [E_loc, D/m, F]
        out = _staged_expert_ffn(w_gate, w_in, w_out, recv, cfg.mlp,
                                 data_size=d // w_in.shape[1])
    else:
        out = _expert_ffn({"e_gate": w_gate, "e_in": w_in, "e_out": w_out},
                          recv, cfg.mlp)
    back = jax.lax.all_to_all(out, model_axis, split_axis=1,
                              concat_axis=0, tiled=True)   # [E, C, D]
    flat = jnp.concatenate([back.reshape(e * c, d),
                            jnp.zeros((1, d), back.dtype)], axis=0)
    y_sorted = flat[dest] * (keep[:, None].astype(back.dtype))
    y_mine = jnp.zeros_like(mine).at[order].set(y_sorted)
    y_mine = y_mine * gate[:, None].astype(y_mine.dtype)

    # ---- small-message path: combine across model ranks (SRQ) ------------ #
    y_all = jax.lax.all_gather(y_mine, model_axis, axis=0, tiled=True)
    lb = jax.lax.pmean(_aux_losses(probs, idx, e), model_axis)
    overflow = jax.lax.pmean(1.0 - jnp.mean(keep.astype(jnp.float32)),
                             model_axis)
    return y_all.reshape(b_loc, t, d), lb, overflow


def moe_ep(params: dict, x: jnp.ndarray, cfg: ArchConfig,
           ctx: ParallelCtx) -> Tuple[jnp.ndarray, Dict]:
    """shard_map expert-parallel MoE. x: [B, T, D]."""
    cf = ctx.moe_capacity_factor or cfg.capacity_factor
    mesh = ctx.mesh
    ax = ctx.model_axis
    assert "e_gate" in params, "EP path expects gated experts (llama4)"
    fsdp_gather = (ctx.fsdp and "data" in mesh.axis_names and
                   params["e_in"].shape[1] % mesh.shape["data"] == 0)
    wspec_in = P(ax, "data" if fsdp_gather else None, None)
    wspec_out = P(ax, None, "data" if fsdp_gather else None)
    xspec = P(ctx.batch_axes_for(x.shape[0]) or None, None, None)

    body = functools.partial(
        _ep_body, cfg=cfg, cap_factor=cf, model_axis=ax,
        model_size=mesh.shape[ax], fsdp_gather=fsdp_gather,
        jet_staged=ctx.jet_collectives)
    # when already inside a manual region (e.g. the compressed-pod-grads
    # shard_map), nested shard_map must target the context's abstract mesh
    try:
        cur = jax.sharding.get_abstract_mesh()
        if cur.shape_tuple and any(
                t == jax.sharding.AxisType.Manual for t in cur.axis_types):
            mesh = cur
    except Exception:  # noqa: BLE001 — fall back to the concrete mesh
        pass
    y, lb, overflow = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), wspec_in, wspec_in, wspec_out, xspec),
        out_specs=(xspec, P(), P()),
        check_vma=False,
    )(params["router"], params["e_gate"], params["e_in"],
      params["e_out"], x)
    if "shared" in params:
        b, t, d = x.shape
        y = y + mlp_apply(params["shared"], x.reshape(b * t, d),
                          cfg.mlp).reshape(b, t, d)
    return y, {"lb_loss": lb, "overflow": overflow}


def moe_apply(params: dict, x: jnp.ndarray, cfg: ArchConfig,
              ctx: ParallelCtx) -> Tuple[jnp.ndarray, Dict]:
    cf = ctx.moe_capacity_factor or cfg.capacity_factor
    if ctx.have_mesh and ctx.use_ep:
        return moe_ep(params, x, cfg, ctx)
    return moe_dense_ref(params, x, cfg, cf)
