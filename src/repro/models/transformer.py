"""Generic decoder LM assembled from an ArchConfig.

Covers all 10 assigned architectures with one machinery:
  * layer *kinds* per position (attn_dense / attn_moe / attn_cross / mamba /
    mamba_attn / mlstm / slstm) repeat with a pattern period (moe_every,
    attn_every, slstm_every, cross_attn_every);
  * parameters for one pattern unit are stacked over the repeat count and the
    forward pass is a ``lax.scan`` over units (compact HLO — essential for
    compiling 40+ dry-run cells on one CPU);
  * a remainder segment handles non-divisible layer counts (zamba2: 38 = 6*6+2);
  * zamba2's *shared* attention block has unstacked weights referenced by
    every ``mamba_attn`` position (its KV caches are per-invocation).

Three entry points: ``forward`` (train logits), ``prefill`` (logits + decode
state), ``decode_step`` (one token with state).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import PartitionSpec as P

from ..configs.base import ArchConfig
from ..parallel.sharding import ParallelCtx
from . import attention as attn
from . import moe as moe_mod
from . import ssm, xlstm
from .layers import (cross_entropy, embed_init, init_rms, mlp_apply,
                     mlp_init, rms_norm)

Params = Dict[str, Any]


# --------------------------------------------------------------------------- #
# structure
# --------------------------------------------------------------------------- #
def layer_kinds(cfg: ArchConfig) -> List[str]:
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.xlstm:
            kinds.append("slstm" if cfg.slstm_every and
                         (i + 1) % cfg.slstm_every == 0 else "mlstm")
        elif cfg.family in ("ssm", "hybrid"):
            kinds.append("mamba_attn" if cfg.attn_every and
                         (i + 1) % cfg.attn_every == 0 else "mamba")
        elif cfg.cross_attn_every and (i + 1) % cfg.cross_attn_every == 0:
            kinds.append("attn_cross")
        elif cfg.is_moe_layer(i):
            kinds.append("attn_moe")
        else:
            kinds.append("attn_dense")
    return kinds


def pattern_period(cfg: ArchConfig) -> int:
    for c in (cfg.moe_every if cfg.num_experts else 0, cfg.attn_every,
              cfg.slstm_every, cfg.cross_attn_every):
        if c and c > 1:
            return c
    return 1


def segments(cfg: ArchConfig) -> Tuple[List[str], int, List[str]]:
    """(pattern_kinds, n_units, remainder_kinds)."""
    kinds = layer_kinds(cfg)
    period = pattern_period(cfg)
    n_units = cfg.num_layers // period
    return kinds[:period], n_units, kinds[n_units * period:]


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #
def _init_layer(kind: str, key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"ln1": init_rms(d, dtype)}
    if kind.startswith("attn"):
        p["attn"] = attn.attn_init(ks[0], cfg, dtype)
        if kind == "attn_cross":
            p["ln_x"] = init_rms(d, dtype)
            p["xattn"] = attn.attn_init(ks[1], cfg, dtype)
        p["ln2"] = init_rms(d, dtype)
        p["ffn"] = (moe_mod.moe_init(ks[2], cfg, dtype)
                    if kind == "attn_moe"
                    else mlp_init(ks[2], d, cfg.d_ff, cfg.mlp, dtype))
    elif kind in ("mamba", "mamba_attn"):
        p["mamba"] = ssm.mamba_init(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.mlstm_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = xlstm.slstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> Params:
    pattern, n_units, rem = segments(cfg)
    keys = jax.random.split(key, 8)
    params: Params = {}
    if cfg.num_codebooks:
        params["embed"] = embed_init(keys[0], cfg.num_codebooks *
                                     cfg.vocab_size, cfg.d_model, dtype
                                     ).reshape(cfg.num_codebooks,
                                               cfg.vocab_size, cfg.d_model)
    else:
        params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model,
                                     dtype)
    # stacked pattern params: tuple over pattern positions, leaves [n_units,.]
    unit_keys = jax.random.split(keys[1], n_units)
    params["pattern"] = tuple(
        jax.vmap(lambda k, kind=kind: _init_layer(
            kind, jax.random.fold_in(k, pos), cfg, dtype))(unit_keys)
        for pos, kind in enumerate(pattern))
    params["remainder"] = tuple(
        _init_layer(kind, jax.random.fold_in(keys[2], i), cfg, dtype)
        for i, kind in enumerate(rem))
    if any(k == "mamba_attn" for k in pattern + rem):
        # zamba2 shared transformer block (attn + mlp), weights shared
        params["shared_attn"] = {
            "ln1": init_rms(cfg.d_model, dtype),
            "attn": attn.attn_init(keys[3], cfg, dtype),
            "ln2": init_rms(cfg.d_model, dtype),
            "ffn": mlp_init(keys[4], cfg.d_model, cfg.d_ff, cfg.mlp, dtype),
        }
    params["final_norm"] = init_rms(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(keys[5], cfg.vocab_size, cfg.d_model,
                                       dtype).T
    return params


# --------------------------------------------------------------------------- #
# per-layer application
# --------------------------------------------------------------------------- #
def _shared_block(shared: Params, x, cfg, ctx):
    x = x + attn.self_attention(shared["attn"],
                                rms_norm(x, shared["ln1"]), cfg, ctx)
    x = x + mlp_apply(shared["ffn"], rms_norm(x, shared["ln2"]), cfg.mlp)
    return x


def _apply_layer(kind: str, p: Params, x, cfg, ctx, shared, patches, aux):
    # name the TP-psum'd sublayer outputs so the "layer_out" remat policy
    # can save exactly these (backward replay then skips re-running the
    # forward all-reduces — EXPERIMENTS.md §Perf)
    mark = lambda v: checkpoint_name(v, "layer_out")
    h = rms_norm(x, p["ln1"])
    if kind.startswith("attn"):
        x = x + mark(attn.self_attention(p["attn"], h, cfg, ctx))
        if kind == "attn_cross":
            x = x + mark(attn.cross_attention(p["xattn"],
                                              rms_norm(x, p["ln_x"]),
                                              patches, cfg, ctx))
        h2 = rms_norm(x, p["ln2"])
        if kind == "attn_moe":
            y, a = moe_mod.moe_apply(p["ffn"], h2, cfg, ctx)
            aux = {k: aux[k] + a[k] for k in aux}
            x = x + mark(y)
        else:
            x = x + mark(mlp_apply(p["ffn"], h2, cfg.mlp))
    elif kind in ("mamba", "mamba_attn"):
        x = x + ssm.mamba_apply(p["mamba"], h, cfg, ctx)
        if kind == "mamba_attn":
            x = _shared_block(shared, x, cfg, ctx)
    elif kind == "mlstm":
        x = x + xlstm.mlstm_apply(p["mlstm"], h, cfg, ctx)
    elif kind == "slstm":
        x = x + xlstm.slstm_apply(p["slstm"], h, cfg, ctx)
    return x, aux


AUX0 = {"lb_loss": jnp.float32(0.0), "overflow": jnp.float32(0.0)}


def _remat(fn, ctx: ParallelCtx):
    if ctx.remat == "none":
        return fn
    if ctx.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    if ctx.remat == "layer_out":
        # save the TP-psum'd sublayer outputs only: backward replay skips
        # the forward all-reduces at ~2 saved activations per layer
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "layer_out"))
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------- #
# forward (train)
# --------------------------------------------------------------------------- #
def embed_tokens(params: Params, tokens: jnp.ndarray, cfg: ArchConfig,
                 dtype) -> jnp.ndarray:
    if cfg.num_codebooks:
        # tokens: [B, K, T] — sum the codebook embeddings (EnCodec stub)
        parts = [jnp.take(params["embed"][k], tokens[:, k], axis=0)
                 for k in range(cfg.num_codebooks)]
        return sum(parts).astype(dtype)
    return jnp.take(params["embed"], tokens, axis=0).astype(dtype)


def unembed(params: Params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"])
    if cfg.tie_embeddings:
        table = params["embed"]
        if cfg.num_codebooks:
            table = table[0]
        return x @ table.T.astype(x.dtype)
    return x @ params["unembed"].astype(x.dtype)


def forward(params: Params, cfg: ArchConfig, ctx: ParallelCtx,
            tokens: jnp.ndarray, patches: Optional[jnp.ndarray] = None,
            compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward -> (logits [B,T,V], aux)."""
    pattern, n_units, rem = segments(cfg)
    cast = lambda t: jax.tree.map(lambda w: w.astype(compute_dtype)
                                  if w.dtype == jnp.float32 else w, t)
    x = embed_tokens(params, tokens, cfg, compute_dtype)
    bsz = x.shape[0]
    x = ctx.constrain(x, ctx.act_for(bsz))
    if patches is not None:
        patches = patches.astype(compute_dtype)
    shared = cast(params.get("shared_attn"))

    # bf16_weight_gather: cast the stacked pattern tree to compute dtype
    # BEFORE the scan, while every leaf is still in its home (FSDP/TP)
    # sharding — the per-unit FSDP all-gathers inside the scan then move
    # 2-byte values instead of 4-byte masters (EXPERIMENTS.md §Perf).
    pattern_params = cast(params["pattern"]) if ctx.bf16_weight_gather \
        else params["pattern"]
    body_cast = (lambda t: t) if ctx.bf16_weight_gather else cast

    def unit_body(x, unit_params):
        aux = dict(AUX0)
        for pos, kind in enumerate(pattern):
            x, aux = _apply_layer(kind, body_cast(unit_params[pos]), x, cfg,
                                  ctx, shared, patches, aux)
            x = ctx.constrain(x, ctx.act_for(bsz))
        return x, aux

    def scan_body(carry, unit_params):
        x, aux_sum = carry
        x, aux = _remat(unit_body, ctx)(x, unit_params)
        return (x, {k: aux_sum[k] + aux[k] for k in aux_sum}), None

    (x, aux), _ = jax.lax.scan(scan_body, (x, dict(AUX0)), pattern_params)
    for p_l, kind in zip(params["remainder"],
                         layer_kinds(cfg)[n_units * len(pattern):]):
        x, aux = _apply_layer(kind, cast(p_l), x, cfg, ctx, shared, patches,
                              aux)
    logits = unembed(params, x, cfg)   # unembed casts tables to x.dtype
    logits = ctx.constrain(logits, P(ctx.batch_axes_for(bsz) or None, None,
                                     ctx.model_axis))
    return logits, aux


def loss_fn(params: Params, cfg: ArchConfig, ctx: ParallelCtx,
            batch: Dict[str, jnp.ndarray],
            compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(params, cfg, ctx, batch["tokens"],
                          batch.get("patches"), compute_dtype)
    loss = cross_entropy(logits, batch["targets"])
    if cfg.num_experts:
        loss = loss + 0.01 * aux["lb_loss"] / max(1, cfg.num_layers)
    metrics = {"loss": loss, **aux}
    return loss, metrics
