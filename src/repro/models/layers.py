"""Shared neural layers: norms, RoPE (full / partial "2d"), MLP variants."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray,
             eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * (1.0 + scale)).astype(x.dtype)


def init_rms(d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.zeros((d,), dtype)


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_freqs(hd: int, fraction: float, theta: float) -> jnp.ndarray:
    """Inverse frequencies for the rotary fraction of the head dim."""
    rot = int(hd * fraction) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, hd: int,
               fraction: float = 1.0,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: [..., T, H, hd]; positions: broadcastable to [..., T].

    ``fraction < 1`` applies rotary to the leading ``fraction*hd`` dims and
    passes the rest through (ChatGLM's 2d/partial rotary)."""
    rot = int(hd * fraction) // 2 * 2
    if rot == 0:
        return x
    inv = rope_freqs(hd, fraction, theta)                      # [rot/2]
    ang = positions[..., None].astype(jnp.float32) * inv        # [...,T,rot/2]
    cos = jnp.cos(ang)[..., None, :]                            # [...,T,1,r/2]
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2].astype(jnp.float32), \
        xr[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], axis=-1) if rot < hd else out


# --------------------------------------------------------------------------- #
# MLP
# --------------------------------------------------------------------------- #
def mlp_apply(params: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind in ("swiglu", "geglu"):
        act = jax.nn.silu if kind == "swiglu" else \
            (lambda v: jax.nn.gelu(v, approximate=True))
        h = act(x @ params["w_gate"]) * (x @ params["w_in"])
        return h @ params["w_out"]
    # plain gelu
    return jax.nn.gelu(x @ params["w_in"], approximate=True) @ params["w_out"]


def mlp_init(key, d: int, f: int, kind: str, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    p = {"w_in": jax.random.normal(k1, (d, f), dtype) * s_in,
         "w_out": jax.random.normal(k2, (f, d), dtype) * s_out}
    if kind in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(k3, (d, f), dtype) * s_in
    return p


def embed_init(key, v: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jax.random.normal(key, (v, d), dtype) * (d ** -0.5)


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  z_loss: float = 1e-4) -> jnp.ndarray:
    """Mean next-token CE with optional z-loss; logits [..., V] fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None],
                             axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse ** 2)
    return loss
