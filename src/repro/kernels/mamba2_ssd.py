"""Chunked Mamba2 SSD scan (for zamba2 and long-context decode).

The SSD recurrence h_t = exp(dt_t a) h_{t-1} + dt_t b_t x_t^T, y_t = c_t h_t
is computed chunk-by-chunk: intra-chunk work is a masked decay-attention (MXU
friendly), inter-chunk state is a (N,P) carry in VMEM scratch.  The sequence
streams through the kernel in fragments exactly like Jet's receive pipeline —
the carry is the recycled "cache-resident" state; the full [T,N,P] state
history never exists in HBM.

Grid: (batch, heads, chunks) with chunks innermost so the VMEM state scratch
persists across a head's chunk sequence.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                n_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)        # [L, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # [L]
    a = a_ref[0].astype(jnp.float32)              # scalar
    b = b_ref[0, :, 0].astype(jnp.float32)        # [L, N]
    c = c_ref[0, :, 0].astype(jnp.float32)        # [L, N]
    L = chunk

    ad = dt * a                                    # [L] (negative)
    cum = jnp.cumsum(ad)                           # [L]
    seg = cum[:, None] - cum[None, :]              # [L, L]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dec = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ()))) * dec
    y_intra = jax.lax.dot_general(scores * dt[None, :], x,
                                  (((1,), (0,)), ((), ())))     # [L, P]
    h = h_ref[...]                                 # [N, P]
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        c, h, (((1,), (0,)), ((), ())))            # [L, P]
    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    to_end = jnp.exp(cum[-1] - cum)                # [L]
    h_new = jnp.exp(cum[-1]) * h + jax.lax.dot_general(
        b * (dt * to_end)[:, None], x, (((0,), (0,)), ((), ())))
    h_ref[...] = h_new

    @pl.when(ci == n_chunks - 1)
    def _emit():
        hout_ref[0, 0] = h_new.astype(hout_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, *, chunk: int = 256,
             interpret: bool = False
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x:[B,T,H,P] dt:[B,T,H] a:[H] b,c:[B,T,G,N] -> (y:[B,T,H,P],
    h:[B,H,N,P]).  T must divide by ``chunk``; G must divide H."""
    B, T, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert T % min(chunk, T) == 0
    L = min(chunk, T)
    nc = T // L
    rep = H // G
    grid = (B, H, nc)

    y, h = pl.pallas_call(
        functools.partial(_ssd_kernel, n_chunks=nc, chunk=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, L, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, L, 1, N),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
            pl.BlockSpec((1, L, 1, N),
                         lambda bi, hi, ci, r=rep: (bi, ci, hi // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, 1, P), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, h
