"""Public, jit'd wrappers for the kernels package.

Implementation selection:
  * ``impl="auto"``   — Pallas on TPU, reference (pure-jnp) elsewhere. The
                        reference tier is what XLA lowers for the CPU-hosted
                        multi-pod dry-run (Mosaic cannot target host CPU).
  * ``impl="pallas"`` — force the Pallas kernel (compiled on TPU).
  * ``impl="interpret"`` — Pallas kernel body executed in interpret mode
                        (CPU correctness validation; used by the test suite).
  * ``impl="ref"``    — force the pure-jnp reference.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .jet_decode_attention import decode_attention_paged
from .jet_flash_attention import flash_attention as _flash_pallas
from .jet_staged_matmul import staged_matmul as _matmul_pallas
from .mamba2_ssd import ssd_scan as _ssd_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# --------------------------------------------------------------------------- #
def staged_matmul(a, b, *, impl: str = "auto", **kw):
    impl = _resolve(impl)
    if impl == "ref":
        return ref.matmul_naive(a, b)
    return _matmul_pallas(a, b, interpret=(impl == "interpret"), **kw)


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, impl: str = "auto", **kw):
    impl = _resolve(impl)
    if impl == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _flash_pallas(q, k, v, causal=causal, window=window,
                         interpret=(impl == "interpret"), **kw)


def decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                     impl: str = "auto", **kw) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    impl = _resolve(impl)
    if impl == "ref":
        return ref.decode_attention_paged_ref(q, k_pages, v_pages,
                                              page_table, lengths)
    return decode_attention_paged(q, k_pages, v_pages, page_table, lengths,
                                  interpret=(impl == "interpret"), **kw)


def ssd(x, dt, a, b, c, *, chunk: int = 256, impl: str = "auto", **kw):
    impl = _resolve(impl)
    if impl == "ref":
        y, h = ref.ssd_chunked_ref(x, dt, a, b, c, chunk=min(chunk,
                                                             x.shape[1]))
        return y, h
    return _ssd_pallas(x, dt, a, b, c, chunk=chunk,
                       interpret=(impl == "interpret"), **kw)
