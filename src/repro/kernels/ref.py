"""Pure-jnp oracles for every Pallas kernel in this package.

Two tiers:
  * ``*_naive``   — maximally-simple math (the ground truth for tests);
  * ``*_ref``     — memory-efficient pure-JAX forms (scan-over-chunks) used by
                    the model code on CPU and for the dry-run lowering, where
                    Mosaic kernels cannot compile. These are numerically
                    equivalent to the kernels and are themselves tested
                    against the naive tier.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# matmul
# --------------------------------------------------------------------------- #
def matmul_naive(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


# --------------------------------------------------------------------------- #
# attention (training/prefill)
# --------------------------------------------------------------------------- #
def _gqa_expand(k: jnp.ndarray, num_q_heads: int) -> jnp.ndarray:
    """[B, Hkv, S, D] -> [B, Hq, S, D] by repeating kv heads."""
    b, hkv, s, d = k.shape
    group = num_q_heads // hkv
    return jnp.repeat(k, group, axis=1)


def attention_naive(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    window: Optional[int] = None,
                    kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-softmax attention. q:[B,Hq,T,D] k/v:[B,Hkv,S,D] -> [B,Hq,T,D]."""
    b, hq, t, d = q.shape
    kf = _gqa_expand(k, hq).astype(jnp.float32)
    vf = _gqa_expand(v, hq).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bhtd,bhsd->bhts", qf, kf)
    t_idx = jnp.arange(t)[:, None]
    s_idx = jnp.arange(kf.shape[2])[None, :]
    # align causality for prefill (T==S) and decode-style (T<S, right-aligned)
    offset = kf.shape[2] - t
    mask = jnp.ones((t, kf.shape[2]), dtype=bool)
    if causal:
        mask &= (t_idx + offset) >= s_idx
    if window is not None:
        mask &= (t_idx + offset) - s_idx < window
    if kv_len is not None:
        mask = mask[None, :, :] & (s_idx[None, :, :] < kv_len[:, None, None])
        mask = mask[:, None]
    else:
        mask = mask[None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", p, vf).astype(q.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True,
                        window: Optional[int] = None,
                        block_kv: int = 512) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in ``block_kv`` fragments.

    This is the pure-JAX image of the Jet receive pipeline: each KV fragment
    is a "message fragment" staged through a recycled buffer (the scan carry
    holds only (m, l, acc) — memory out of the datapath). Used for 32k-token
    prefill lowering where naive T x S scores would not fit.
    """
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    nblk = -(-s // block_kv)
    pad = nblk * block_kv - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hkv, nblk, block_kv, d).astype(jnp.float32)
    vb = v.reshape(b, hkv, nblk, block_kv, d).astype(jnp.float32)
    qf = q.astype(jnp.float32) * (d ** -0.5)
    qg = qf.reshape(b, hkv, group, t, d)

    offset = s - t
    t_idx = jnp.arange(t)[:, None] + offset

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        kblk, vblk, start = blk
        sc = jnp.einsum("bhgtd,bhsd->bhgts", qg, kblk)
        s_idx = start + jnp.arange(block_kv)[None, :]
        mask = s_idx < s  # padding
        if causal:
            mask = mask & (t_idx >= s_idx)
        if window is not None:
            mask = mask & (t_idx - s_idx < window)
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)
        m_new = jnp.maximum(m_prev, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhgts,bhsd->bhgtd", p, vblk)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, hkv, group, t), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, group, t), jnp.float32),
            jnp.zeros((b, hkv, group, t, d), jnp.float32))
    starts = jnp.arange(nblk) * block_kv
    (m, l, acc), _ = jax.lax.scan(
        step, init, (kb.transpose(2, 0, 1, 3, 4), vb.transpose(2, 0, 1, 3, 4),
                     starts))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, hq, t, d).astype(q.dtype)


# --------------------------------------------------------------------------- #
# decode attention (paged + distributed combine)
# --------------------------------------------------------------------------- #
def decode_attention_naive(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           lengths: jnp.ndarray):
    """q:[B,Hq,D]; contiguous k/v:[B,S,Hkv,D]; lengths:[B].

    Returns (o:[B,Hq,D], lse:[B,Hq]) — lse enables cross-shard combining
    (the "small message" SRQ path of distributed decode)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k.shape
    group = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, group, d) * (d ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    sc = jnp.einsum("bkgd,bskd->bkgs", qf, kf)
    mask = jnp.arange(s)[None, :] < lengths[:, None]
    sc = jnp.where(mask[:, None, None], sc, NEG_INF)
    m = sc.max(axis=-1)
    p = jnp.exp(sc - m[..., None])
    l = p.sum(axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, vf) / jnp.maximum(l[..., None],
                                                           1e-30)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return (o.reshape(b, hq, d).astype(q.dtype), lse.reshape(b, hq))


def decode_attention_paged_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                               v_pages: jnp.ndarray,
                               page_table: jnp.ndarray,
                               lengths: jnp.ndarray):
    """Paged oracle. k_pages:[P,page,Hkv,D], page_table:[B,maxp] (-1 = hole).

    Gathers each sequence's pages into a contiguous view, then defers to the
    dense oracle."""
    b, maxp = page_table.shape
    page = k_pages.shape[1]
    safe = jnp.maximum(page_table, 0)
    kc = k_pages[safe]                      # [B, maxp, page, Hkv, D]
    vc = v_pages[safe]
    kc = kc.reshape(b, maxp * page, *k_pages.shape[2:])
    vc = vc.reshape(b, maxp * page, *v_pages.shape[2:])
    return decode_attention_naive(q, kc, vc, lengths)


def combine_partial_attention(o_parts: jnp.ndarray, lse_parts: jnp.ndarray):
    """Merge per-shard partial attention (the SRQ small-message combine).

    o_parts:[S,B,H,D], lse_parts:[S,B,H] -> (o:[B,H,D]).  Numerically stable
    weighted merge: softmax over shard lse."""
    m = lse_parts.max(axis=0, keepdims=True)
    w = jnp.exp(lse_parts - m)
    w = w / jnp.maximum(w.sum(axis=0, keepdims=True), 1e-30)
    return (o_parts * w[..., None]).sum(axis=0)


# --------------------------------------------------------------------------- #
# Mamba2 SSD scan
# --------------------------------------------------------------------------- #
def ssd_naive(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
              b: jnp.ndarray, c: jnp.ndarray,
              h0: Optional[jnp.ndarray] = None):
    """Sequential state-space (SSD) oracle.

    x:[B,T,H,P] dt:[B,T,H] a:[H] (negative) b,c:[B,T,G,N] -> y:[B,T,H,P].
    h_t = exp(dt_t a) h_{t-1} + dt_t * b_t x_t^T ;  y_t = c_t h_t
    """
    B, T, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    bx = jnp.repeat(b, rep, axis=2).astype(jnp.float32)   # [B,T,H,N]
    cx = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp          # [B,H,P],[B,H],[B,H,N],[B,H,N]
        decay = jnp.exp(dt_t * a)[..., None, None]         # [B,H,1,1]
        h = h * decay + (dt_t[..., None, None] *
                         b_t[..., :, None] * x_t[..., None, :])  # [B,H,N,P]
        y = jnp.einsum("bhn,bhnp->bhp", c_t, h)
        return h, y

    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((B, H, N, P), jnp.float32))
    hT, ys = jax.lax.scan(
        step, h_init,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         bx.transpose(1, 0, 2, 3), cx.transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hT.astype(jnp.float32)


def ssd_chunked_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, c: jnp.ndarray, chunk: int = 256,
                    h0: Optional[jnp.ndarray] = None):
    """Chunked SSD (the math of the Pallas kernel, as a pure-JAX scan over
    chunks). Intra-chunk is a masked 'attention'; inter-chunk carries the
    (N,P) state — i.e. fragments stream through a recycled carry, never
    materializing the full sequence state history."""
    B, T, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    rep = H // G
    assert T % chunk == 0, "pad sequence to a chunk multiple"
    L = chunk
    nc = T // L
    bx = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cx = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32).reshape(B, nc, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, L, H)
    bxc = bx.reshape(B, nc, L, H, N)
    cxc = cx.reshape(B, nc, L, H, N)

    def chunk_step(h, inp):
        xc, dtc, bc, cc = inp              # [B,L,H,P],[B,L,H],[B,L,H,N]x2
        ad = dtc * a                        # [B,L,H]  (negative)
        cum = jnp.cumsum(ad, axis=1)        # [B,L,H]
        # intra-chunk masked attention
        seg = cum[:, :, None, :] - cum[:, None, :, :]       # [B,L,L,H]
        il = jnp.tril(jnp.ones((L, L), bool))
        dec = jnp.where(il[None, :, :, None], jnp.exp(seg), 0.0)
        sc = jnp.einsum("blhn,bmhn->blmh", cc, bc) * dec
        y_intra = jnp.einsum("blmh,bmh,bmhp->blhp", sc, dtc, xc)
        # inter-chunk state contribution
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "blhn,bhnp->blhp", cc, h)
        # state update
        to_end = jnp.exp(cum[:, -1:, :] - cum)              # [B,L,H]
        h = (jnp.exp(cum[:, -1, :])[..., None, None] * h +
             jnp.einsum("blhn,blh,blhp->bhnp", bc, dtc * to_end, xc))
        return h, y_intra + y_inter

    h_init = (h0.astype(jnp.float32) if h0 is not None
              else jnp.zeros((B, H, N, P), jnp.float32))
    hT, ys = jax.lax.scan(
        chunk_step, h_init,
        (xf.transpose(1, 0, 2, 3, 4), dtf.transpose(1, 0, 2, 3),
         bxc.transpose(1, 0, 2, 3, 4), cxc.transpose(1, 0, 2, 3, 4)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, P)
    return y.astype(x.dtype), hT.astype(jnp.float32)
