"""Jet decode attention over a paged KV cache.

The serving engine stores KV in fixed-size pages allocated from the
cache-resident buffer pool (`repro.core.pool.DevicePool`) — the slab design of
paper §4.2 applied to the KV cache.  This kernel consumes one page per grid
step, staged HBM->VMEM by the Pallas pipeline (the recycle controller), and
maintains an online-softmax carry.  The page table rides the scalar-prefetch
channel, mirroring Jet's shared-cache metadata hand-off (paper §3.2 step 4:
"notifies the application ... with the pointer").

Returns (o, lse): the log-sum-exp makes the output mergeable across sequence
shards — those (o, lse) tuples are the *small messages* that ride the SRQ path
in distributed decode (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(table_ref, lengths_ref, q_ref, k_ref, v_ref,
                   o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                   n_pages: int, page: int, group: int, scale: float):
    b = pl.program_id(0)
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = lengths_ref[b]
    valid_page = (p * page) < seq_len

    @pl.when(valid_page)
    def _consume():
        q = q_ref[0].astype(jnp.float32) * scale         # [Hq, D]
        k = k_ref[0].astype(jnp.float32)                 # [page, Hkv, D]
        v = v_ref[0].astype(jnp.float32)
        hq, d = q.shape
        hkv = k.shape[1]
        qg = q.reshape(hkv, group, d)
        s = jnp.einsum("kgd,pkd->kgp", qg, k)            # [Hkv, G, page]
        pos = p * page + jax.lax.broadcasted_iota(
            jnp.int32, (hkv, group, page), 2)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        s = s.reshape(hq, page)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        pexp = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pexp.sum(axis=-1, keepdims=True)
        pv = jnp.einsum("kgp,pkd->kgd", pexp.reshape(hkv, group, page), v)
        acc_ref[...] = acc_ref[...] * corr + pv.reshape(hq, d)
        m_ref[...] = m_new

    @pl.when(p == n_pages - 1)
    def _emit():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, ...] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0, ...] = m_ref[...] + jnp.log(l)


def decode_attention_paged(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, *,
                           interpret: bool = False
                           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """q:[B,Hq,D]; k/v_pages:[P,page,Hkv,D]; page_table:[B,maxp] (-1 holes);
    lengths:[B] -> (o:[B,Hq,D], lse:[B,Hq])."""
    bsz, hq, d = q.shape
    n_pool, page, hkv, _ = k_pages.shape
    _, maxp = page_table.shape
    group = hq // hkv
    # holes (-1) are clamped to page 0; the length mask voids their scores.
    table = jnp.maximum(page_table, 0).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, maxp),
        in_specs=[
            pl.BlockSpec((1, hq, d), lambda b, p, t_, l_: (b, 0, 0)),
            pl.BlockSpec((1, page, hkv, d),
                         lambda b, p, t_, l_: (t_[b, p], 0, 0, 0)),
            pl.BlockSpec((1, page, hkv, d),
                         lambda b, p, t_, l_: (t_[b, p], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hq, d), lambda b, p, t_, l_: (b, 0, 0)),
            pl.BlockSpec((1, hq, 1), lambda b, p, t_, l_: (b, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, 1), jnp.float32),
            pltpu.VMEM((hq, d), jnp.float32),
        ],
    )
    o, lse = pl.pallas_call(
        functools.partial(_decode_kernel, n_pages=maxp, page=page,
                          group=group, scale=d ** -0.5),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bsz, hq, d), q.dtype),
            jax.ShapeDtypeStruct((bsz, hq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(table, lengths.astype(jnp.int32), q, k_pages, v_pages)
    return o, lse[..., 0]
