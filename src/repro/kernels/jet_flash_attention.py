"""Jet flash attention: KV streamed through a windowed VMEM staging pool.

Causal (optionally sliding-window) GQA attention where the KV sequence is
consumed in fragments of ``block_kv`` tokens.  The (m, l, acc) online-softmax
carry is the only persistent state — the S x T score matrix never exists
(memory out of the datapath), and each KV fragment's staging slot is recycled
by the Pallas pipeline as soon as the MXU consumed it (the swift-recycle
controller, paper §4.2).

Block sizes map to the paper's knobs:
    block_kv  ~ READ fragment size (<=256 KB rule -> block_kv*D*2B per head)
    2 staging buffers (Pallas double-buffering) ~ in-flight window

TPU-performance note: on real TPU, fully-masked KV blocks (beyond the causal
diagonal or outside the sliding window) should be skipped by folding the
block-level predicate into the grid; in interpret mode we keep the full grid
and rely on masking for correctness.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_kv: int, block_q: int, block_kv: int, causal: bool,
                  window: Optional[int], kv_seq: int, q_seq: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale           # [bq, D]
    k = k_ref[0].astype(jnp.float32)                   # [bkv, D]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bkv]

    offset = kv_seq - q_seq   # right-aligned causality (decode-style q<kv)
    t_idx = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_kv), 0)
    s_idx = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                     (block_q, block_kv), 1)
    mask = s_idx < kv_seq
    if causal:
        mask &= (t_idx + offset) >= s_idx
    if window is not None:
        mask &= (t_idx + offset) - s_idx < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        o_ref[0, ...] = (acc_ref[...] /
                         jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    block_q: int = 256, block_kv: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q:[B,Hq,T,D] k/v:[B,Hkv,S,D] -> [B,Hq,T,D] (GQA via head grouping)."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq = min(block_q, t)
    bkv = min(block_kv, s)

    qf = q.reshape(b * hq, t, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)
    tp, sp = -(-t // bq) * bq, -(-s // bkv) * bkv
    if tp != t:
        qf = jnp.pad(qf, ((0, 0), (0, tp - t), (0, 0)))
    if sp != s:
        kf = jnp.pad(kf, ((0, 0), (0, sp - s), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, sp - s), (0, 0)))
    grid = (b * hq, tp // bq, sp // bkv)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, n_kv=grid[2], block_q=bq,
                          block_kv=bkv, causal=causal, window=window,
                          kv_seq=s, q_seq=t, scale=d ** -0.5),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, tp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denominator
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out[:, :t, :].reshape(b, hq, t, d)
