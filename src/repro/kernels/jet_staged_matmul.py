"""RDCA staged-consumption matmul (the paper's receive path, in-kernel).

C[M,N] = A[M,K] @ B[K,N] where A's K dimension arrives as *fragments* (the
paper's <=256 KB READ fragments).  The kernel consumes each fragment from a
small recycled VMEM staging area and accumulates into a VMEM-resident
accumulator — the gathered operand never round-trips through HBM
("move memory out of the receiver datapath").

The Pallas pipeline (BlockSpec double-buffering) plays the role of the swift
cache-recycle controller: a staging slot is rewritten the moment the MXU has
consumed it.  Block sizes are the pool-sizing knobs:

    VMEM pool = bm*bk (A slot) + bk*bn (B slot) + bm*bn (acc)   x 2 buffers

sized by the same Little's-law reasoning the paper uses for its 12 MB LLC pool
(see benchmarks/bench_kernels.py for the sizing sweep).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(x: jnp.ndarray, mult, axes) -> jnp.ndarray:
    pads = [(0, 0)] * x.ndim
    for m, ax in zip(mult, axes):
        pads[ax] = (0, (-x.shape[ax]) % m)
    if any(p != (0, 0) for p in pads):
        x = jnp.pad(x, pads)
    return x


def staged_matmul(a: jnp.ndarray, b: jnp.ndarray, *,
                  block_m: int = 256, block_n: int = 256,
                  block_k: int = 512,
                  out_dtype: Optional[jnp.dtype] = None,
                  interpret: bool = False) -> jnp.ndarray:
    """Fragment-staged matmul. a:[M,K] @ b:[K,N] -> [M,N]."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bn, bk = (min(block_m, M), min(block_n, N), min(block_k, K))
    a = _pad_to(a, (bm, bk), (0, 1))
    b = _pad_to(b, (bk, bn), (0, 1))
    Mp, Kp = a.shape
    _, Np = b.shape
    grid = (Mp // bm, Np // bn, Kp // bk)

    kernel = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )
    out = kernel(a, b)
    return out[:M, :N]


def staging_pool_bytes(block_m: int, block_n: int, block_k: int,
                       dtype_bytes: int = 2, num_buffers: int = 2) -> int:
    """VMEM footprint of the staging pool for a given tiling (the in-kernel
    analogue of the paper's 12 MB pool-sizing arithmetic, §4.1.3)."""
    a_slot = block_m * block_k * dtype_bytes
    b_slot = block_k * block_n * dtype_bytes
    acc = block_m * block_n * 4
    return num_buffers * (a_slot + b_slot) + acc
