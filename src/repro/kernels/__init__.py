"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel embodies the RDCA principle in-kernel: operands stream through a
small recycled VMEM staging pool (BlockSpec double-buffering == the swift
cache-recycle pipeline) and the big intermediate never exists in HBM.

Validated on CPU with interpret=True against the pure-jnp oracles in ref.py;
selected automatically on TPU via ops.py.
"""
from . import ops, ref
from .jet_decode_attention import decode_attention_paged
from .jet_flash_attention import flash_attention
from .jet_staged_matmul import staged_matmul, staging_pool_bytes
from .mamba2_ssd import ssd_scan

__all__ = ["decode_attention_paged", "flash_attention", "ops", "ref",
           "ssd_scan", "staged_matmul", "staging_pool_bytes"]
