"""Paged KV cache backed by the cache-resident buffer pool (DevicePool).

The slab design of paper §4.2 applied to serving KV: pages of ``page_size``
tokens are allocated from a functional free bitmap; sequences map to pages
via a page table; the Pallas jet_decode_attention kernel consumes pages
directly.  Freeing a finished sequence recycles its pages immediately
(swift recycle); an exhausted pool surfaces the escape path (caller evicts
or rejects — see serving.engine).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.pool import DevicePool


@dataclasses.dataclass
class PagedKVConfig:
    num_pages: int
    page_size: int
    num_kv_heads: int
    head_dim: int
    max_pages_per_seq: int
    dtype: object = jnp.bfloat16


@jax.tree_util.register_pytree_node_class
class PagedKV:
    """Single-layer paged KV store + allocator state."""

    def __init__(self, k_pages, v_pages, pool: DevicePool, page_table,
                 lengths):
        self.k_pages = k_pages          # [P, page, Hkv, D]
        self.v_pages = v_pages
        self.pool = pool
        self.page_table = page_table    # [B, maxp] int32, -1 = hole
        self.lengths = lengths          # [B] int32

    def tree_flatten(self):
        return ((self.k_pages, self.v_pages, self.pool, self.page_table,
                 self.lengths), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def create(cls, cfg: PagedKVConfig, batch: int) -> "PagedKV":
        shape = (cfg.num_pages, cfg.page_size, cfg.num_kv_heads,
                 cfg.head_dim)
        return cls(jnp.zeros(shape, cfg.dtype), jnp.zeros(shape, cfg.dtype),
                   DevicePool.create(cfg.num_pages),
                   jnp.full((batch, cfg.max_pages_per_seq), -1, jnp.int32),
                   jnp.zeros((batch,), jnp.int32))

    def append(self, b: int, k_new: jnp.ndarray, v_new: jnp.ndarray
               ) -> Tuple["PagedKV", jnp.ndarray]:
        """Append one token's (k, v) [Hkv, D] to sequence ``b``.  Allocates
        a fresh page from the pool on page boundaries.  Returns
        (new_cache, ok) — ok=False means pool exhausted (escape)."""
        page = self.k_pages.shape[1]
        pos = self.lengths[b]
        page_idx = pos // page
        off = pos % page
        need_page = off == 0
        pool, fresh, got = self.pool.alloc(1)
        use_pool = jnp.logical_and(need_page, got)
        pool = DevicePool(jnp.where(need_page, pool.free, self.pool.free))
        table = self.page_table.at[b, page_idx].set(
            jnp.where(need_page, fresh[0], self.page_table[b, page_idx]))
        phys = table[b, page_idx]
        safe = jnp.maximum(phys, 0)
        k_pages = self.k_pages.at[safe, off].set(k_new.astype(
            self.k_pages.dtype))
        v_pages = self.v_pages.at[safe, off].set(v_new.astype(
            self.v_pages.dtype))
        ok = jnp.logical_or(jnp.logical_not(need_page), got)
        return PagedKV(k_pages, v_pages, pool, table,
                       self.lengths.at[b].add(1)), ok

    def release(self, b: int) -> "PagedKV":
        """Free all pages of sequence ``b`` back to the pool (recycle)."""
        pages = self.page_table[b]
        pool = self.pool.release(pages)
        table = self.page_table.at[b].set(-1)
        return PagedKV(self.k_pages, self.v_pages, pool, table,
                       self.lengths.at[b].set(0))
