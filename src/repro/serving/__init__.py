"""Serving: Jet-admitted batched engine + paged KV cache."""
from .engine import EngineConfig, Request, ServingEngine
from .kv_cache import PagedKV, PagedKVConfig

__all__ = ["EngineConfig", "PagedKV", "PagedKVConfig", "Request",
           "ServingEngine"]
