"""Batched serving engine with the Jet receive path as admission control.

The mapping (paper §3.2 workflow -> serving):
  * requests = incoming transfers; prompt bytes ride the *READ* path
    (fragmented, windowed admission via JetService), generated tokens are
    *small messages* (SRQ);
  * batch lanes = the cache-resident buffer pool: a fixed slab of decode
    lanes whose KV state is pre-allocated once; a lane is recycled the
    moment its sequence finishes (swift recycle);
  * slow/stuck sequences (consumer stalls) are stragglers: the escape
    ladder first flags them, then evicts (copy-out) their lane, and under
    danger pressure rejects new admissions (ECN).

The admission machinery behind ``JetService`` is the shared
:mod:`repro.core.datapath` ``AdmissionQueues`` — the same QoS policy the
fluid simulator and the fabric engines advance — so the engine can be
driven *by a fabric*: route the receiving host's congestion state (PFC
pause, pool danger) into :meth:`ServingEngine.set_network_pressure` and
switch backpressure throttles decode-lane admission
(``examples/serving_on_fabric.py`` demonstrates the loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..core.jet import JetConfig, JetService, QoS
from ..models import api as model_api
from ..parallel.sharding import ParallelCtx


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # [T] token ids
    max_new_tokens: int
    qos: QoS = QoS.NORMAL
    # filled by the engine
    lane: int = -1
    generated: Optional[List[int]] = None
    xfer_id: int = -1


@dataclasses.dataclass
class EngineConfig:
    max_lanes: int = 8           # decode batch slab (the buffer pool)
    max_len: int = 256
    bytes_per_token: int = 4096  # KV bytes/token — Jet admission accounting
    eos_token: int = 1


class ServingEngine:
    def __init__(self, cfg: ArchConfig, ectx: EngineConfig,
                 params, ctx: ParallelCtx,
                 jet_cfg: Optional[JetConfig] = None,
                 compute_dtype=jnp.float32):
        self.cfg = cfg
        self.ecfg = ectx
        self.params = params
        self.ctx = ctx
        self.jet = JetService(jet_cfg or JetConfig())
        for q in QoS:        # one Jet app per service class (paper §3.2)
            self.jet.register(int(q), q)
        self.compute_dtype = compute_dtype
        self.state = model_api.init_decode_state(
            cfg, ectx.max_lanes, ectx.max_len, compute_dtype)
        self.lengths = jnp.zeros((ectx.max_lanes,), jnp.int32)
        self.tokens = jnp.zeros((ectx.max_lanes,), jnp.int32)
        self.active: Dict[int, Request] = {}     # lane -> request
        self.waiting: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.now = 0.0
        self._decode = jax.jit(
            lambda p, s, t, l: model_api.decode_step(
                p, cfg, ctx, s, t, l, compute_dtype=compute_dtype))
        self._prefill = jax.jit(
            lambda p, t: model_api.prefill(
                p, cfg, ctx, t, max_len=ectx.max_len,
                compute_dtype=compute_dtype))

    # ---- submission (paper step 2) --------------------------------------- #
    def submit(self, req: Request) -> None:
        req.generated = []
        req.xfer_id = self.jet.request(
            int(req.qos), len(req.prompt) * self.ecfg.bytes_per_token,
            self.now)
        self.waiting.append(req)

    def _free_lanes(self) -> List[int]:
        return [i for i in range(self.ecfg.max_lanes)
                if i not in self.active]

    # ---- network feedback (fabric backpressure -> admission) -------------- #
    def set_network_pressure(self, paused: bool) -> None:
        """Gate decode-lane admission on network congestion state: while
        asserted (e.g. the host's PFC pause or pool-danger signal from a
        fabric co-simulation), no new transfers are admitted to the pool;
        already-admitted lanes keep decoding."""
        self.jet.set_backpressure(paused)

    @property
    def network_paused(self) -> bool:
        return self.jet.network_paused

    # ---- admission + prefill (paper step 3/4) ----------------------------- #
    def _admit(self) -> None:
        # Jet admissions are sticky: a transfer admitted to the pool waits
        # for a free lane (its pool reservation is already held).
        self._jet_admitted = getattr(self, "_jet_admitted", set())
        self._jet_admitted |= {t.xfer_id for t in self.jet.pump(self.now)}
        still = []
        for req in self.waiting:
            lanes = self._free_lanes()
            if req.xfer_id in self._jet_admitted and lanes:
                lane = lanes[0]
                req.lane = lane
                self.active[lane] = req
                prompt = jnp.asarray(req.prompt)[None, :]
                logits, state1, lengths1 = self._prefill(self.params, prompt)
                # scatter the single-sequence state into the lane slab;
                # pattern leaves are [n_units, B, ...], remainder [B, ...]
                self.state = {
                    "pattern": jax.tree.map(
                        lambda slab, new: slab.at[:, lane].set(new[:, 0]),
                        self.state["pattern"], state1["pattern"]),
                    "remainder": jax.tree.map(
                        lambda slab, new: slab.at[lane].set(new[0]),
                        self.state["remainder"], state1["remainder"]),
                }
                self.lengths = self.lengths.at[lane].set(len(req.prompt))
                tok = int(jnp.argmax(logits[0]))
                req.generated.append(tok)
                self.tokens = self.tokens.at[lane].set(tok)
            else:
                still.append(req)
        self.waiting = still

    # ---- one engine tick --------------------------------------------------- #
    def step(self, dt: float = 1e-3) -> None:
        self.now += dt
        self._admit()
        if self.active:
            logits, self.state = self._decode(self.params, self.state,
                                              self.tokens, self.lengths)
            self.lengths = self.lengths + jnp.asarray(
                [1 if i in self.active else 0
                 for i in range(self.ecfg.max_lanes)], jnp.int32)
            next_tok = jnp.argmax(logits, axis=-1)
            self.tokens = next_tok.astype(jnp.int32)
            finished = []
            for lane, req in self.active.items():
                tok = int(next_tok[lane])
                req.generated.append(tok)
                if (tok == self.ecfg.eos_token or
                        len(req.generated) >= req.max_new_tokens):
                    finished.append(lane)
            for lane in finished:          # swift recycle of the lane slab
                req = self.active.pop(lane)
                self.jet.complete(req.xfer_id, self.now)
                self.done[req.req_id] = req
        self.jet.tick_escape(self.now)

    def run_until_done(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            if not self.active and not self.waiting:
                return
            self.step()
