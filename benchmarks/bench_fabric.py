"""Fabric benchmarks: Clos incast/HoL behaviour + both vectorized engines.

Four parts:

1. **Incast scaling** — N storage senders burst into one Jet/DDIO receiver
   across a 2-leaf Clos; reports incast completion time, victim-flow
   goodput and (with PFC) pause fan-out — the fleet-level pathologies a
   single-receiver simulator cannot show.
2. **Equivalence anchor** — a 1-sender/1-receiver fabric must reproduce
   ``run_sim(testbed_100g(...))`` goodput (acceptance: within 5%; actual:
   exact, the fabric is cut-through at 1 tick).
3. **Datapath sweep engine** — a >=32-point receiver-knob grid advanced by
   the jax vmap+scan engine vs the batched-numpy reference vs sequential
   ``run_sim``; also autotunes the scan ``unroll`` over {1, 4, 8} (cold
   compile + warm run recorded for each, winner persisted for future
   processes) and records before (the old hard-coded ``unroll=8``) vs
   after (autotuned + donated carry) compile and run times.
4. **Fabric sweep engine** — a >=32-point *fabric* grid (mode x PFC x
   burst over the incast-8 scenario) advanced by
   ``repro.fabric.vector.run_fabric_sweep`` vs the scalar ``run_fabric``
   loop vs the batched-numpy reference; acceptance: <=1e-3 max relative
   deviation on per-flow goodput / incast completion and >=5x warm
   speedup over the scalar loop.
5. **Routing grid** — the dynamic-routing program: routing mode x
   link-failure schedule over ``link_failure_incast`` as ONE vector
   program (per-tick ``[G, F]`` route state, failure masks, spray
   settling); records warm speedup vs the scalar loop and the
   numpy-vs-scalar deviation, so the regression gate covers the
   per-tick routing state too.
6. **Message grid** — the op-layer program: msg-size x window x CC
   (DCQCN / Timely / HPCC) over the 8-to-1 verbs incast as ONE vector
   program carrying per-flow completion rings + log-bucket latency
   histograms; records warm speedup vs the scalar loop, the exactness
   of the numpy engine's message bookkeeping (counts / completion
   times vs the scalar tracker) and the histogram-p99 error vs the
   scalar exact percentile, gating the documented ~4.6% bound.
7. **Scale (pod) grid** — the sparse-incidence program: the same
   3-level cross-pod incast grid at 64 and 256 hosts, each advanced as
   ONE jax program; XLA's compiled cost analysis gives per-tick flops
   at both sizes and the growth exponent
   ``log(cost ratio) / log(host ratio)`` documents the ~linear
   (sub-quadratic) scaling in fabric size that the dense ``[P, F]``
   incidence cannot offer (its one-hot products grow with
   flows x ports, i.e. quadratically in hosts).
8. **Faults grid** — the robustness program: loss-rate x recovery-mode
   over the lossy 8-to-1 verbs incast as ONE vector program carrying
   the per-flow RTO/retransmit ledgers, plus a receiver crash--restart
   point; records warm speedup vs the scalar loop and gates the fault
   accounting (counter-based hashing makes the loss realization
   engine-identical: retransmit/dropped bytes agree to f64 round-off,
   message counts exactly, and the zero-loss selective point drops
   exactly zero packets — only real wire loss or go-back-N duplicate
   discards may feed ``dropped_pkts``).

9. **Farm** — the chunked sweep farm (``repro.fabric.farm``) vs the
   monolithic single-program run on the 64-point incast grid: gates
   exact result equality (chunk padding + structure envelope must not
   perturb any real point), zero program-cache recompiles after
   warmup, and the multiprocess warm speedup on multi-core hosts.

Everything is also written machine-readable to
``experiments/bench/BENCH_fabric.json`` so the perf trajectory is
tracked across PRs.  ``--quick`` shrinks sim time and grids for CI.
"""
from __future__ import annotations

import json
import math
import os
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core import simulator as S
from repro.fabric import scenarios as SC
from repro.fabric import vector as V
from repro.fabric._scan import (UNROLL_CANDIDATES, configure_persistent_cache,
                                pick_unroll, save_autotune)
from repro.fabric.fused import AdaptiveConfig, program_op_stats
from repro.fabric.scenarios import fabric_grid
from repro.fabric.sweep import grid_configs, run_sweep
from repro.fabric.vector import run_fabric_sweep

from .common import OUT_DIR, emit

NAME = "fabric"
PAPER_REF = "§2.1/§6 testbed at fleet scale"
JSON_PATH = os.path.join(OUT_DIR, "BENCH_fabric.json")

QUICK = False


def _sim_time(full: float) -> float:
    return 0.004 if QUICK else full


def run_incast() -> List[Dict]:
    rows: List[Dict] = []
    for mode in ("ddio", "jet"):
        for n in (2, 4, 8):
            for pfc in (False, True):
                sc = SC.incast(n_senders=n, mode=mode, pfc=pfc,
                               burst_mb=1.0, sim_time_s=_sim_time(0.02))
                r = sc.run()
                rx = r.per_host["h1_0"]
                rows.append({
                    "scenario": sc.name,
                    "mode": mode, "senders": n, "pfc": int(pfc),
                    "incast_fct_us": r.incast_completion_us,
                    "victim_gbps": r.victim_goodput_gbps,
                    "recv_gbps": rx.goodput_gbps,
                    "pause_fanout": r.pause_fanout,
                    "ecn_mb": r.ecn_marked_bytes / 1e6,
                    "dropped_mb": r.switch_dropped_bytes / 1e6,
                })
    return rows


def run_equivalence() -> List[Dict]:
    rows: List[Dict] = []
    for mode in ("ddio", "jet"):
        ref = S.run_sim(S.testbed_100g(mode, sim_time_s=_sim_time(0.01)))
        got = SC.single_pair(mode, sim_time_s=_sim_time(0.01)).run() \
            .per_host["h0_1"]
        rows.append({
            "mode": mode,
            "run_sim_gbps": ref.goodput_gbps,
            "fabric_gbps": got.goodput_gbps,
            "rel_err": abs(got.goodput_gbps - ref.goodput_gbps)
            / max(ref.goodput_gbps, 1e-9),
        })
    return rows


def run_sweep_bench() -> List[Dict]:
    cfgs, _ = grid_configs(
        S.testbed_100g, mode="ddio", sim_time_s=_sim_time(0.01),
        msg_bytes=[64 << 10, 128 << 10, 256 << 10, 512 << 10,
                   768 << 10, 1 << 20],
        cpu_membw_gbps=[1200.0, 1400.0, 1500.0, 1600.0, 1760.0, 1900.0],
        ddio_bytes=[4 << 20, 6 << 20])

    # -- unroll autotune over {1, 4, 8}: cold (compile) + warm per factor -- #
    times = {}
    for u in UNROLL_CANDIDATES:
        t0 = time.time()
        run_sweep(cfgs, backend="jax", unroll=u)
        cold = time.time() - t0
        # the winner is persisted (save_autotune) and steers every
        # later section's scan program — a single noisy warm sample
        # here must not crown the wrong unroll for the whole process
        warm, _ = _best_of(lambda: run_sweep(cfgs, backend="jax",
                                             unroll=u))
        times[u] = (cold, warm)
    best = min(times, key=lambda u: times[u][1])
    save_autotune(best)

    # autotuned, program cached
    t_warm, jx = _best_of(lambda: run_sweep(cfgs, backend="jax"))
    t0 = time.time()
    ref = run_sweep(cfgs, backend="numpy")
    t_np = time.time() - t0
    t0 = time.time()
    seq = np.array([S.run_sim(c).goodput_gbps for c in cfgs])
    t_seq = time.time() - t0

    g_jx, g_np = jx["goodput_gbps"], ref["goodput_gbps"]
    dev_np = float(np.max(np.abs(g_jx - g_np) / np.maximum(g_np, 1e-9)))
    dev_seq = float(np.max(np.abs(g_np - seq) / np.maximum(seq, 1e-9)))
    return [{
        "grid_points": len(cfgs),
        "seq_run_sim_s": t_seq,
        "numpy_batched_s": t_np,
        # before: the old hard-coded unroll=8 (no donation existed then
        # either, but compile time dominates the cold number)
        "before_cold_s": times[8][0],
        "before_warm_s": times[8][1],
        # after: autotuned unroll + donated scan carry
        "after_cold_s": times[best][0],
        "after_warm_s": t_warm,
        "best_unroll": best,
        "unroll_times": {str(u): {"cold_s": c, "warm_s": w}
                         for u, (c, w) in times.items()},
        "speedup_cold": t_seq / times[best][0],
        "speedup_warm": t_seq / t_warm,
        "max_rel_dev_vs_numpy": dev_np,
        "max_rel_dev_numpy_vs_run_sim": dev_seq,
    }]


def _best_of(fn, reps: int = 3):
    """Best-of-N wall clock for a *warm* (already-compiled) call,
    returning ``(best_seconds, last_result)``.  The bench hosts are
    shared single-core VMs where a single sample routinely eats a
    30-60% neighbor-noise spike; the minimum over a few reps is the
    standard estimator for the true cost of a deterministic program
    (the scalar reference runs long enough to average the noise out
    and stays single-shot)."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        best = min(best, time.time() - t0)
    return best, out


def _profile_program(scens, t_cold: float, t_warm: float) -> Dict:
    """Dispatch/op-count attribution for one vector-grid section: the
    per-tick wall clock, the compile-vs-warm split, and the jaxpr op
    census of the (cached) fixed-dt program — so a perf regression can
    be blamed on either op growth (census moved) or runtime (census
    flat, wall clock moved)."""
    import jax.numpy as jnp

    fsp = V.FabricSweepParams.from_scenarios(scens)
    fn = V._jax_program(fsp, pick_unroll(None), "ref")
    p_np = V._np_params(fsp, np.float32)
    s0 = V._init_state(np, (fsp.n_points,), fsp, p_np, np.float32)
    stats = program_op_stats(
        fn, {k: jnp.asarray(v) for k, v in s0.items()},
        {k: jnp.asarray(v) for k, v in p_np.items()})
    return {
        "ticks": fsp.ticks,
        "per_tick_ms_warm": t_warm / fsp.ticks * 1e3,
        "compile_s": max(t_cold - t_warm, 0.0),
        "op_count_total": stats["op_count_total"],
        "op_count_step": stats["op_count_step"],
        "op_kinds": stats["op_kinds"],
    }


def _incast_grid():
    """The >=32-point incast fabric grid shared by the fixed-dt sweep
    bench and the adaptive-dt bench (same scenarios -> same cached
    program -> the adaptive comparison is apples-to-apples)."""
    bursts = ([0.5, 1.0, 2.0, 4.0] if QUICK else
              [0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 2.5, 3.0,
               3.5, 4.0, 5.0, 6.0])
    scens, _ = fabric_grid(
        lambda mode, pfc, burst_mb: SC.incast(
            n_senders=8, mode=mode, pfc=pfc, burst_mb=burst_mb,
            sim_time_s=_sim_time(0.02)),
        mode=["ddio", "jet"], pfc=[False, True], burst_mb=bursts)
    return scens


def run_fabric_sweep_bench() -> List[Dict]:
    scens = _incast_grid()

    t0 = time.time()
    scalar = [sc.run() for sc in scens]
    t_scalar = time.time() - t0
    t0 = time.time()
    jx = run_fabric_sweep(scens, backend="jax")
    t_cold = time.time() - t0
    t_warm, jx = _best_of(lambda: run_fabric_sweep(scens, backend="jax"))
    t0 = time.time()
    ref = run_fabric_sweep(scens, backend="numpy")
    t_np = time.time() - t0

    F = len(scens[0].flows)
    gp_sc = np.array([[r.flow_goodput_gbps[f] for f in range(F)]
                      for r in scalar])
    cp_sc = np.array([[r.flow_completion_us[f] for f in range(F)]
                      for r in scalar])

    def rel(a, b):
        """Max relative deviation; inf if the engines disagree about
        which entries are finite (e.g. one thinks a flow completed and
        the other does not) — a masked mean must never hide that."""
        if not (np.isfinite(a) == np.isfinite(b)).all():
            return float("inf")
        m = np.isfinite(b)
        if not m.any():
            return 0.0
        return float(np.max(np.abs(a[m] - b[m])
                            / np.maximum(np.abs(b[m]), 1e-9)))

    inc_sc = np.array([r.incast_completion_us for r in scalar])
    inc_jx = jx["incast_completion_us"]
    fin = np.isfinite(inc_jx)
    return [{
        **_profile_program(scens, t_cold, t_warm),
        "grid_points": len(scens),
        "flows": F,
        "scalar_run_fabric_s": t_scalar,
        "numpy_batched_s": t_np,
        "jax_cold_s": t_cold,
        "jax_warm_s": t_warm,
        "speedup_cold": t_scalar / t_cold,
        "speedup_warm": t_scalar / t_warm,
        "dev_goodput_vs_scalar": rel(jx["flow_goodput_gbps"], gp_sc),
        "dev_completion_vs_scalar": rel(jx["flow_completion_us"], cp_sc),
        "dev_incast_fct_vs_scalar": rel(jx["incast_completion_us"],
                                        inc_sc),
        "dev_goodput_vs_numpy": rel(jx["flow_goodput_gbps"],
                                    ref["flow_goodput_gbps"]),
        "mean_incast_fct_us": (float(inc_jx[fin].mean())
                               if fin.any() else None),
        "unfinished_incast_points": int((~fin).sum()),
        "mean_victim_gbps": float(jx["victim_goodput_gbps"].mean()),
        "max_pause_fanout": int(jx["pause_fanout"].max()),
    }]


def _xla_flops(scens) -> Dict:
    """Compiled-cost census of one vector-grid program: lower the
    (cached) fixed-dt program for the grid and ask XLA's cost model for
    the flop count.  Unlike the jaxpr op census (which counts program
    *structure* and is size-independent), the compiled cost grows with
    the array extents — exactly the quantity whose growth law the scale
    bench gates."""
    import jax
    import jax.numpy as jnp

    fsp = V.FabricSweepParams.from_scenarios(scens, sparse=True)
    fn = V._jax_program(fsp, pick_unroll(None), "ref")
    p_np = V._np_params(fsp, np.float32)
    s0 = V._init_state(np, (fsp.n_points,), fsp, p_np, np.float32)
    ca = jax.jit(fn).lower(
        {k: jnp.asarray(v) for k, v in s0.items()},
        {k: jnp.asarray(v) for k, v in p_np.items()}).compile() \
        .cost_analysis()
    if isinstance(ca, (list, tuple)):          # older jax returns [dict]
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", float("nan"))),
            "ticks": fsp.ticks, "flows": fsp.n_flows,
            "ports": fsp.n_ports, "points": fsp.n_points}


def run_scale_bench() -> List[Dict]:
    """Pod-scale cost growth of the sparse-incidence engine: the same
    3-level cross-pod incast grid at 64 and 256 hosts, each advanced
    as ONE jax program.  The gated number is the growth exponent
    ``log(flops ratio) / log(host ratio)`` of XLA's compiled per-tick
    cost — segment-sum over a static incidence list is linear in
    (flows + ports), so the exponent must stay well under 2 (the dense
    one-hot engine's flows x ports products would put it at ~2)."""
    sim_s = 0.002 if QUICK else 0.004
    rows: List[Dict] = []
    for hosts, (pods, leaves, hpl) in ((64, (2, 2, 16)),
                                       (256, (4, 4, 16))):
        scens, _ = SC.pod_incast_grid(
            mode=("jet", "ddio"), pfc=(False,), pods=pods,
            leaves_per_pod=leaves, hosts_per_leaf=hpl,
            burst_mb=0.2, sim_time_s=sim_s)
        cost = _xla_flops(scens)
        t0 = time.time()
        run_fabric_sweep(scens, backend="jax")
        t_cold = time.time() - t0
        t_warm, out = _best_of(lambda: run_fabric_sweep(scens,
                                                        backend="jax"))
        fin = np.isfinite(out["incast_completion_us"])
        rows.append({
            "hosts": hosts,
            "pods": pods, "leaves_per_pod": leaves,
            "hosts_per_leaf": hpl,
            "grid_points": cost["points"],
            "flows": cost["flows"], "ports": cost["ports"],
            "ticks": cost["ticks"],
            "flops_per_tick": cost["flops"] / cost["ticks"],
            "jax_cold_s": t_cold, "jax_warm_s": t_warm,
            "per_tick_ms_warm": t_warm / cost["ticks"] * 1e3,
            "mean_incast_fct_us": (
                float(out["incast_completion_us"][fin].mean())
                if fin.any() else None),
        })
    small, big = rows
    host_ratio = big["hosts"] / small["hosts"]
    cost_ratio = big["flops_per_tick"] / small["flops_per_tick"]
    warm_ratio = big["jax_warm_s"] / small["jax_warm_s"]
    return [{
        "host_ratio": host_ratio,
        "flops_ratio": cost_ratio,
        "warm_ratio": warm_ratio,
        # compiled-cost growth law: 1.0 = linear in hosts, 2.0 = the
        # dense engine's quadratic one-hot products
        "growth_exponent": math.log(cost_ratio) / math.log(host_ratio),
        "warm_growth_exponent": (math.log(warm_ratio)
                                 / math.log(host_ratio)),
        "sizes": rows,
    }]


def run_routing_bench() -> List[Dict]:
    # bursts must overflow the 4 MB downlink buffer partition or the
    # whole incast teleports past the uplinks (cut-through) before the
    # failure fires; 8 x 1 MB keeps uplink traffic alive for ms, and
    # adaptive's post-failure FCT lands ~5 ms -> quick sim stays 8 ms
    scens, pts = SC.routing_grid(
        modes=("static_ecmp", "weighted_ecmp", "adaptive", "spray"),
        fail_at_us=(math.inf, 150.0),
        sim_time_s=0.008 if QUICK else 0.02, burst_mb=1.0)

    t0 = time.time()
    scalar = [sc.run() for sc in scens]
    t_scalar = time.time() - t0
    t0 = time.time()
    run_fabric_sweep(scens, backend="jax")
    t_cold = time.time() - t0
    t_warm, jx = _best_of(lambda: run_fabric_sweep(scens, backend="jax"))
    t0 = time.time()
    ref = run_fabric_sweep(scens, backend="numpy")
    t_np = time.time() - t0

    F = len(scens[0].flows)
    gp_sc = np.array([[r.flow_goodput_gbps[f] for f in range(F)]
                      for r in scalar])
    dev_np = float(np.max(
        np.abs(ref["flow_goodput_gbps"] - gp_sc)
        / np.maximum(np.abs(gp_sc), 1e-9)))
    rr_sc = np.array([r.reroute_count for r in scalar])
    fct = {(p["routing"], math.isfinite(p["fail_at_us"])):
           jx["incast_completion_us"][i] for i, p in enumerate(pts)}
    return [{
        **_profile_program(scens, t_cold, t_warm),
        "grid_points": len(scens),
        "flows": F,
        "scalar_run_fabric_s": t_scalar,
        "numpy_batched_s": t_np,
        "jax_cold_s": t_cold,
        "jax_warm_s": t_warm,
        "speedup_warm": t_scalar / t_warm,
        # float64 reference vs scalar driver across every routing mode
        # and failure schedule (routing decisions must agree exactly)
        "dev_goodput_numpy_vs_scalar": dev_np,
        "reroutes_match": bool(
            (ref["reroute_count"] == rr_sc).all()),
        "static_fail_stalls": bool(
            not np.isfinite(fct[("static_ecmp", True)])),
        "adaptive_fail_fct_us": float(fct[("adaptive", True)]),
        "spray_fail_fct_us": float(fct[("spray", True)]),
        "max_reroutes": int(ref["reroute_count"].max()),
        "mean_uplink_util_max": float(ref["uplink_util_max"].mean()),
    }]


def run_messages_bench() -> List[Dict]:
    sizes = [64.0] if QUICK else [16.0, 64.0, 256.0]
    wins = [16] if QUICK else [4, 16]
    scens, pts = SC.message_sweep_grid(
        msg_kb=sizes, window=wins, verb=("write",),
        algo=("dcqcn", "timely", "hpcc"),
        sim_time_s=_sim_time(0.01))

    t0 = time.time()
    scalar = [sc.run() for sc in scens]
    t_scalar = time.time() - t0
    t0 = time.time()
    run_fabric_sweep(scens, backend="jax")
    t_cold = time.time() - t0
    t_warm, jx = _best_of(lambda: run_fabric_sweep(scens, backend="jax"))
    t0 = time.time()
    ref = run_fabric_sweep(scens, backend="numpy")
    t_np = time.time() - t0

    F = len(scens[0].flows)
    cnt_sc = np.array([[len(r.msg_latency_us.get(f, []))
                        for f in range(F)] for r in scalar])
    last_sc = np.array([[r.msg_last_done_us.get(f, 0.0)
                         for f in range(F)] for r in scalar])
    p99_sc = np.array([r.msg_percentile(99.0) for r in scalar])
    # numpy bookkeeping is exact: counts bit-equal, times to 1e-9
    count_mismatch = int(np.abs(ref["msg_count"] - cnt_sc).sum())
    dev_last = float(np.max(np.abs(ref["msg_last_done_us"] - last_sc)
                            / np.maximum(np.abs(last_sc), 1e-9)))
    # histogram estimate vs exact percentile: the documented bound
    p99_err = float(np.max(np.abs(ref["msg_p99_us"] - p99_sc)
                           / np.maximum(p99_sc, 1e-9)))
    p99 = {(p["algo"], p["window"]): float(jx["msg_p99_us"][i])
           for i, p in enumerate(pts)}
    wmax = max(wins)
    return [{
        **_profile_program(scens, t_cold, t_warm),
        "grid_points": len(scens),
        "flows": F,
        "scalar_run_fabric_s": t_scalar,
        "numpy_batched_s": t_np,
        "jax_cold_s": t_cold,
        "jax_warm_s": t_warm,
        "speedup_warm": t_scalar / t_warm,
        "count_mismatch_numpy_vs_scalar": count_mismatch,
        "dev_last_done_numpy_vs_scalar": dev_last,
        "p99_hist_err_vs_exact": p99_err,
        "total_messages": int(ref["msg_count_total"].sum()),
        "mean_rate_mops": float(ref["msg_rate_mops"].mean()),
        "dcqcn_p99_us": p99[("dcqcn", wmax)],
        "timely_p99_us": p99[("timely", wmax)],
        "hpcc_p99_us": p99[("hpcc", wmax)],
    }]


def run_faults_bench() -> List[Dict]:
    from repro.fabric.faults import FaultConfig

    rates = (0.0, 0.01) if QUICK else (0.0, 0.002, 0.01, 0.05)
    scens, pts = SC.lossy_incast_grid(
        loss_rate=rates, recovery=("go_back_n", "selective"),
        sim_time_s=_sim_time(0.004))

    t0 = time.time()
    scalar = [sc.run() for sc in scens]
    t_scalar = time.time() - t0
    t0 = time.time()
    run_fabric_sweep(scens, backend="jax")
    t_cold = time.time() - t0
    t_warm, jx = _best_of(lambda: run_fabric_sweep(scens, backend="jax"))
    t0 = time.time()
    ref = run_fabric_sweep(scens, backend="numpy")
    t_np = time.time() - t0

    F = len(scens[0].flows)
    # the counter-based hash gives every engine the same loss
    # realization -> the fault accounting must agree to f64 round-off
    retx_sc = np.array([r.retransmit_bytes for r in scalar])
    drop_sc = np.array([r.dropped_pkts for r in scalar])
    cnt_sc = np.array([[len(r.msg_latency_us.get(f, []))
                        for f in range(F)] for r in scalar])
    dev_retx = float(np.max(np.abs(ref["retransmit_bytes"] - retx_sc)
                            / np.maximum(retx_sc, 1.0)))
    dev_drop = float(np.max(np.abs(ref["dropped_pkts"] - drop_sc)
                            / np.maximum(drop_sc, 1.0)))
    count_mismatch = int(np.abs(ref["msg_count"] - cnt_sc).sum())
    # zero wire loss + selective: nothing gaps, nothing is discarded —
    # dropped_pkts must be exactly 0 (go-back-N still discards dups on
    # RNIC admission shortfalls, so only the selective point qualifies)
    lossless_sel = [i for i, p in enumerate(pts)
                    if p["loss_rate"] == 0.0
                    and p["recovery"] == "selective"]
    lossless_sel_dropped = float(ref["dropped_pkts"][lossless_sel].sum())

    def pick(arr, rec, rate):
        return next(float(arr[i]) for i, p in enumerate(pts)
                    if p["recovery"] == rec and p["loss_rate"] == rate)

    worst = max(rates)

    # crash--restart: receiver dies mid-incast, the RTO ledgers replay
    crash = SC.lossy_incast(loss_rate=0.005, recovery="selective",
                            sim_time_s=_sim_time(0.004))
    crash.fabric.faults = FaultConfig(loss_rate=0.005, seed=7).crash(
        "h1_0", at_us=400.0, restart_us=600.0)
    cr_sc = crash.run()
    cr_np = run_fabric_sweep([crash], backend="numpy")
    cr_dev = abs(float(np.ravel(cr_np["crash_recovery_us"][0])[0])
                 - cr_sc.crash_recovery_us["h1_0"])

    return [{
        **_profile_program(scens, t_cold, t_warm),
        "grid_points": len(scens),
        "flows": F,
        "scalar_run_fabric_s": t_scalar,
        "numpy_batched_s": t_np,
        "jax_cold_s": t_cold,
        "jax_warm_s": t_warm,
        "speedup_warm": t_scalar / t_warm,
        "dev_retransmit_numpy_vs_scalar": dev_retx,
        "dev_dropped_numpy_vs_scalar": dev_drop,
        "count_mismatch_numpy_vs_scalar": count_mismatch,
        "lossless_sel_dropped_pkts": lossless_sel_dropped,
        "crash_recovery_dev_us": cr_dev,
        "crash_recovery_us": cr_sc.crash_recovery_us["h1_0"],
        "gbn_retx_mb_worst": pick(ref["retransmit_bytes"], "go_back_n",
                                  worst) / 1e6,
        "sel_retx_mb_worst": pick(ref["retransmit_bytes"], "selective",
                                  worst) / 1e6,
        "gbn_p999_us_worst": pick(jx["msg_p999_us"], "go_back_n", worst),
        "sel_p999_us_worst": pick(jx["msg_p999_us"], "selective", worst),
    }]


def run_adaptive_bench() -> List[Dict]:
    """Adaptive time-stepping on a *drain-bounded* incast grid: every
    burst finite (no open victim flow) and small enough that every
    point completes well inside the horizon, leaving the long quiet
    tail that event-aware stepping exists to skip.  (The fabric-sweep
    grid above deliberately includes points whose incast never
    finishes, and open victims sit in a permanent DCQCN sawtooth —
    per-tick dynamics the stride correctly refuses to coarsen; the
    stride is also a grid-wide lockstep reduction, so one busy point
    pins the whole grid at fine dt.)  Gated on what adaptivity
    promises — macro-tick coarsening (iterations << ticks) within the
    documented delivered-bytes bound — with wall clock recorded
    honestly: the jax backend trades the scan for a
    ``lax.while_loop`` whose per-iteration cost on CPU can eat part of
    the iteration savings."""
    bursts = [0.25] if QUICK else [0.25, 0.5]
    scens, _ = fabric_grid(
        lambda mode, pfc, burst_mb: SC.incast(
            n_senders=8, mode=mode, pfc=pfc, burst_mb=burst_mb,
            with_victim=False, sim_time_s=_sim_time(0.02)),
        mode=["ddio", "jet"], pfc=[False, True], burst_mb=bursts)
    cfg = AdaptiveConfig()

    t0 = time.time()
    [sc.run() for sc in scens]
    t_scalar = time.time() - t0
    run_fabric_sweep(scens, backend="jax")
    t_fixed, fine = _best_of(lambda: run_fabric_sweep(scens,
                                                      backend="jax"))
    t0 = time.time()
    run_fabric_sweep(scens, backend="jax", adaptive_dt=True)
    t_cold = time.time() - t0
    t_warm, ad = _best_of(lambda: run_fabric_sweep(
        scens, backend="jax", adaptive_dt=True))

    ticks = V.FabricSweepParams.from_scenarios(scens).ticks
    iters = int(np.ravel(ad["adaptive_iterations"])[0])
    db_a, db_f = ad["flow_delivered_bytes"], fine["flow_delivered_bytes"]
    dev = float(np.max(np.abs(db_a - db_f) / np.maximum(db_f, 1.0)))
    ca, cf = ad["flow_completion_us"], fine["flow_completion_us"]
    both = np.isfinite(ca) & np.isfinite(cf)
    shift = float(np.abs(ca[both] - cf[both]).max()) if both.any() else 0.0
    return [{
        "grid_points": len(scens),
        "ticks": ticks,
        "adaptive_iterations": iters,
        "coarsen_ratio": ticks / max(iters, 1),
        "scalar_run_fabric_s": t_scalar,
        "jax_fixed_warm_s": t_fixed,
        "jax_adaptive_cold_s": t_cold,
        "jax_adaptive_warm_s": t_warm,
        "speedup_warm_vs_scalar": t_scalar / t_warm,
        "speedup_warm_vs_fixed": t_fixed / t_warm,
        "dev_delivered_vs_fixed": dev,
        "rel_bytes_bound": cfg.rel_bytes_bound,
        "max_completion_shift_us": shift,
        "max_stride": cfg.max_stride,
    }]


def run_farm_bench() -> List[Dict]:
    """Sweep farm vs the monolithic single-program run on the
    64-point incast grid (16-pt with ``--quick``).

    Three gated promises: (1) **equal results** — the farm's chunked,
    envelope-forced programs must reproduce the monolithic run exactly
    (``dev_farm_vs_mono`` is an exact-zero ceiling); (2) **zero
    recompiles after warmup** — a second farm pass over the same plan
    must hit the program cache on every chunk
    (``recompiles_after_warmup``, exact-zero); (3) **warm speedup** —
    on a multi-core host the multiprocess farm beats the monolithic
    program >=2x (``speedup_warm``; the quick floor is lower because CI
    runs single-core in-process dispatch, where chunking can only cost
    a little, never win).  The multiprocess timing re-spawns the worker
    pool per rep, so it includes the real dispatch overhead an
    overnight run pays; workers share the on-disk XLA cache when
    ``JAX_COMPILATION_CACHE_DIR`` is set."""
    import tempfile
    import warnings as _warnings

    from repro.fabric.farm import run_farm

    scens, _ = SC.build_grid("incast", quick=QUICK)
    chunk = 8 if QUICK else 16
    cpus = os.cpu_count() or 1
    workers = min(4, cpus) if (not QUICK and cpus >= 2) else 0

    run_fabric_sweep(scens, backend="jax")               # compile mono
    t_mono, mono = _best_of(lambda: run_fabric_sweep(scens,
                                                     backend="jax"))

    with _warnings.catch_warnings():
        # single-device fallback is expected on CI hosts
        _warnings.simplefilter("ignore", RuntimeWarning)
        warm = run_farm(scens, workers=0, chunk_size=chunk,
                        backend="jax", artifacts=False)   # chunk compile
        t_farm_ip, farm = _best_of(lambda: run_farm(
            scens, workers=0, chunk_size=chunk, backend="jax",
            artifacts=False))
    recompiles = sum(r["compiles"]
                     for r in farm["manifest"]["records"])

    dev = 0.0
    for k in mono:
        a = np.asarray(mono[k], np.float64)
        b = np.asarray(farm["results"][k], np.float64)
        a = np.where(np.isfinite(a), a, -1.0)
        b = np.where(np.isfinite(b), b, -1.0)
        dev = max(dev, float(np.max(np.abs(a - b))))

    t_farm = t_farm_ip
    if workers > 1:
        with tempfile.TemporaryDirectory() as td:
            t_farm, _ = _best_of(lambda: run_farm(
                "incast", quick=QUICK, workers=workers,
                chunk_size=chunk, backend="jax", out_dir=td), reps=2)

    return [{
        "grid_points": len(scens),
        "chunk_size": chunk,
        "chunks": len(warm["manifest"]["records"]),
        "workers": workers,
        "mono_warm_s": t_mono,
        "farm_inprocess_warm_s": t_farm_ip,
        "farm_warm_s": t_farm,
        "speedup_warm": t_mono / t_farm,
        "dev_farm_vs_mono": dev,
        "recompiles_after_warmup": recompiles,
        "warmup_compiles": sum(r["compiles"] for r in
                               warm["manifest"]["records"]),
    }]


def _jsonable(obj):
    """Strict-JSON payload: non-finite floats become None (json.dump's
    Infinity/NaN literals break jq / JSON.parse on the CI artifact)."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (float, np.floating)):
        return float(obj) if np.isfinite(obj) else None
    if isinstance(obj, np.integer):
        return int(obj)
    return obj


def run() -> List[Dict]:
    return run_incast()


def main() -> None:
    cache = configure_persistent_cache()
    if cache:
        print(f"# jax persistent compilation cache: {cache}")
    rows = run_incast()
    emit(NAME, rows)
    eq = run_equivalence()
    emit(NAME + "_equivalence", eq)
    sw = run_sweep_bench()
    emit(NAME + "_sweep", sw, quiet=True)
    fs = run_fabric_sweep_bench()
    emit(NAME + "_vector", fs)
    sc = run_scale_bench()
    emit(NAME + "_scale", sc)
    rt = run_routing_bench()
    emit(NAME + "_routing", rt)
    ms = run_messages_bench()
    emit(NAME + "_messages", ms)
    ft = run_faults_bench()
    emit(NAME + "_faults", ft)
    ad = run_adaptive_bench()
    emit(NAME + "_adaptive", ad)
    fm = run_farm_bench()
    emit(NAME + "_farm", fm)

    os.makedirs(OUT_DIR, exist_ok=True)
    with open(JSON_PATH, "w") as f:
        json.dump(_jsonable({"quick": QUICK, "incast": rows,
                             "equivalence": eq, "sweep": sw[0],
                             "fabric_sweep": fs[0],
                             "scale": sc[0],
                             "routing": rt[0],
                             "messages": ms[0],
                             "faults": ft[0],
                             "adaptive": ad[0],
                             "farm": fm[0]}), f, indent=2)

    worst_eq = max(r["rel_err"] for r in eq)
    s, v = sw[0], fs[0]
    print(f"# single-pair fabric == run_sim within {worst_eq:.2%} "
          f"(acceptance 5%)")
    print(f"# datapath sweep {s['grid_points']} pts: best unroll "
          f"{s['best_unroll']}; cold {s['before_cold_s']:.1f}s -> "
          f"{s['after_cold_s']:.1f}s, warm {s['before_warm_s']:.2f}s -> "
          f"{s['after_warm_s']:.2f}s; x{s['speedup_warm']:.1f} warm vs "
          f"sequential run_sim; dev vs numpy "
          f"{s['max_rel_dev_vs_numpy']:.3%}")
    print(f"# fabric sweep {v['grid_points']} pts x {v['flows']} flows: "
          f"x{v['speedup_warm']:.1f} warm / x{v['speedup_cold']:.1f} cold "
          f"vs scalar run_fabric (acceptance >=5x warm); goodput dev "
          f"{v['dev_goodput_vs_scalar']:.2e}, incast-FCT dev "
          f"{v['dev_incast_fct_vs_scalar']:.2e} (acceptance <=1e-3); "
          f"{v['per_tick_ms_warm']:.3f} ms/tick warm, "
          f"{v['op_count_step']} ops/step ({v['op_kinds']} kinds), "
          f"compile {v['compile_s']:.1f}s")
    sb = sc[0]
    b64, b256 = sb["sizes"]
    print(f"# pod scale {b64['hosts']} -> {b256['hosts']} hosts (one "
          f"program each, {b256['flows']} flows / {b256['ports']} ports "
          f"at {b256['hosts']}): compiled-cost growth exponent "
          f"{sb['growth_exponent']:.2f} (1.0 linear, 2.0 dense-quadratic"
          f"); warm {b64['per_tick_ms_warm']:.3f} -> "
          f"{b256['per_tick_ms_warm']:.3f} ms/tick "
          f"(exp {sb['warm_growth_exponent']:.2f})")
    a = ad[0]
    print(f"# adaptive dt, drain-bounded {a['grid_points']}-pt grid: "
          f"{a['adaptive_iterations']} iterations for {a['ticks']} ticks "
          f"(x{a['coarsen_ratio']:.1f} coarsening, stride cap "
          f"{a['max_stride']}); delivered dev vs fixed dt "
          f"{a['dev_delivered_vs_fixed']:.2e} (bound "
          f"{a['rel_bytes_bound']:.0%}); warm "
          f"x{a['speedup_warm_vs_scalar']:.1f} vs scalar / "
          f"x{a['speedup_warm_vs_fixed']:.2f} vs fixed-dt jax")
    r = rt[0]
    print(f"# routing grid {r['grid_points']} pts (mode x failure, one "
          f"program): x{r['speedup_warm']:.1f} warm vs scalar; numpy dev "
          f"{r['dev_goodput_numpy_vs_scalar']:.2e}; static stalls on "
          f"failure: {r['static_fail_stalls']}, adaptive FCT "
          f"{r['adaptive_fail_fct_us']:.0f} us")
    m = ms[0]
    print(f"# message grid {m['grid_points']} pts (size x window x CC, "
          f"one program): x{m['speedup_warm']:.1f} warm vs scalar; "
          f"numpy count mismatch {m['count_mismatch_numpy_vs_scalar']}, "
          f"hist-p99 err {m['p99_hist_err_vs_exact']:.2%} (bound 4.6%); "
          f"p99 dcqcn {m['dcqcn_p99_us']:.0f} us vs timely "
          f"{m['timely_p99_us']:.0f} / hpcc {m['hpcc_p99_us']:.0f} us")
    ff = ft[0]
    print(f"# faults grid {ff['grid_points']} pts (loss x recovery, one "
          f"program): x{ff['speedup_warm']:.1f} warm vs scalar; retx dev "
          f"{ff['dev_retransmit_numpy_vs_scalar']:.2e}, count mismatch "
          f"{ff['count_mismatch_numpy_vs_scalar']}; at worst loss "
          f"go-back-N replays {ff['gbn_retx_mb_worst']:.1f} MB "
          f"(p999 {ff['gbn_p999_us_worst']:.0f} us) vs selective "
          f"{ff['sel_retx_mb_worst']:.1f} MB "
          f"(p999 {ff['sel_p999_us_worst']:.0f} us); crash recovery "
          f"{ff['crash_recovery_us']:.0f} us (engine dev "
          f"{ff['crash_recovery_dev_us']:.1e})")
    fa = fm[0]
    print(f"# farm {fa['grid_points']} pts in {fa['chunks']} chunks of "
          f"{fa['chunk_size']} ({fa['workers']} workers): warm "
          f"x{fa['speedup_warm']:.2f} vs monolithic "
          f"({fa['mono_warm_s']:.2f}s -> {fa['farm_warm_s']:.2f}s); "
          f"dev {fa['dev_farm_vs_mono']:.1e}, "
          f"{fa['recompiles_after_warmup']} recompiles after warmup")
    print(f"# machine-readable: {os.path.abspath(JSON_PATH)}")


if __name__ == "__main__":
    QUICK = "--quick" in sys.argv[1:]
    main()
