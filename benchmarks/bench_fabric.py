"""Fabric benchmarks: Clos incast/HoL behaviour + vectorized sweep engine.

Three parts:

1. **Incast scaling** — N storage senders burst into one Jet/DDIO receiver
   across a 2-leaf Clos; reports incast completion time, victim-flow
   goodput and (with PFC) pause fan-out — the fleet-level pathologies a
   single-receiver simulator cannot show.
2. **Equivalence anchor** — a 1-sender/1-receiver fabric must reproduce
   ``run_sim(testbed_100g(...))`` goodput (acceptance: within 5%; actual:
   exact, the fabric is cut-through at 1 tick).
3. **Sweep engine** — a >=32-point grid advanced by the jax vmap+scan
   engine vs the batched-numpy reference vs sequential ``run_sim`` calls;
   reports max relative deviation (acceptance: <=1%) and speedups (cold =
   including XLA compile; warm = steady-state, the operating point when a
   grid shape is re-swept).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import simulator as S
from repro.fabric import scenarios as SC
from repro.fabric.sweep import grid_configs, run_sweep

from .common import emit

NAME = "fabric"
PAPER_REF = "§2.1/§6 testbed at fleet scale"


def run_incast() -> List[Dict]:
    rows: List[Dict] = []
    for mode in ("ddio", "jet"):
        for n in (2, 4, 8):
            for pfc in (False, True):
                sc = SC.incast(n_senders=n, mode=mode, pfc=pfc,
                               burst_mb=1.0, sim_time_s=0.02)
                r = sc.run()
                rx = r.per_host["h1_0"]
                rows.append({
                    "scenario": sc.name,
                    "mode": mode, "senders": n, "pfc": int(pfc),
                    "incast_fct_us": r.incast_completion_us,
                    "victim_gbps": r.victim_goodput_gbps,
                    "recv_gbps": rx.goodput_gbps,
                    "pause_fanout": r.pause_fanout,
                    "ecn_mb": r.ecn_marked_bytes / 1e6,
                    "dropped_mb": r.switch_dropped_bytes / 1e6,
                })
    return rows


def run_equivalence() -> List[Dict]:
    rows: List[Dict] = []
    for mode in ("ddio", "jet"):
        ref = S.run_sim(S.testbed_100g(mode, sim_time_s=0.01))
        got = SC.single_pair(mode, sim_time_s=0.01).run() \
            .per_host["h0_1"]
        rows.append({
            "mode": mode,
            "run_sim_gbps": ref.goodput_gbps,
            "fabric_gbps": got.goodput_gbps,
            "rel_err": abs(got.goodput_gbps - ref.goodput_gbps)
            / max(ref.goodput_gbps, 1e-9),
        })
    return rows


def run_sweep_bench() -> List[Dict]:
    cfgs, _ = grid_configs(
        S.testbed_100g, mode="ddio", sim_time_s=0.01,
        msg_bytes=[64 << 10, 128 << 10, 256 << 10, 512 << 10,
                   768 << 10, 1 << 20],
        cpu_membw_gbps=[1200.0, 1400.0, 1500.0, 1600.0, 1760.0, 1900.0],
        ddio_bytes=[4 << 20, 6 << 20])

    t0 = time.time()
    jx_cold = run_sweep(cfgs, backend="jax")
    t_cold = time.time() - t0
    t0 = time.time()
    jx = run_sweep(cfgs, backend="jax")
    t_warm = time.time() - t0
    t0 = time.time()
    ref = run_sweep(cfgs, backend="numpy")
    t_np = time.time() - t0
    t0 = time.time()
    seq = np.array([S.run_sim(c).goodput_gbps for c in cfgs])
    t_seq = time.time() - t0

    g_jx, g_np = jx["goodput_gbps"], ref["goodput_gbps"]
    dev_np = float(np.max(np.abs(g_jx - g_np) / np.maximum(g_np, 1e-9)))
    dev_seq = float(np.max(np.abs(g_np - seq) / np.maximum(seq, 1e-9)))
    del jx_cold
    return [{
        "grid_points": len(cfgs),
        "seq_run_sim_s": t_seq,
        "numpy_batched_s": t_np,
        "jax_cold_s": t_cold,       # includes one-time XLA compile
        "jax_warm_s": t_warm,       # steady state (compiled program cached)
        "speedup_cold": t_seq / t_cold,
        "speedup_warm": t_seq / t_warm,
        "max_rel_dev_vs_numpy": dev_np,
        "max_rel_dev_numpy_vs_run_sim": dev_seq,
    }]


def run() -> List[Dict]:
    return run_incast()


def main() -> None:
    rows = run_incast()
    emit(NAME, rows)
    eq = run_equivalence()
    emit(NAME + "_equivalence", eq)
    sw = run_sweep_bench()
    emit(NAME + "_sweep", sw)

    worst_eq = max(r["rel_err"] for r in eq)
    hol = [r for r in rows if r["pfc"] and r["senders"] == 8
           and r["mode"] == "ddio"]
    free = [r for r in rows if not r["pfc"] and r["senders"] == 8
            and r["mode"] == "ddio"]
    s = sw[0]
    print(f"# single-pair fabric == run_sim within {worst_eq:.2%} "
          f"(acceptance 5%)")
    if hol and free:
        print(f"# incast-8 PFC HoL: victim {hol[0]['victim_gbps']:.1f} Gbps "
              f"(pause fan-out {hol[0]['pause_fanout']}) vs "
              f"{free[0]['victim_gbps']:.1f} Gbps PFC-free")
    print(f"# sweep {s['grid_points']} pts: vectorized matches numpy ref "
          f"within {s['max_rel_dev_vs_numpy']:.3%} (acceptance 1%); "
          f"x{s['speedup_warm']:.1f} warm / x{s['speedup_cold']:.1f} cold "
          f"vs sequential run_sim (acceptance >=5x warm)")


if __name__ == "__main__":
    main()
