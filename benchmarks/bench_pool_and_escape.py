"""Paper figures 10/11 + §4.3 (C7): pool occupancy, recycle ablation and the
escape ladder's DRAM bill.

Three sub-studies:
  1. *Little's-law pool sizing* — required pool bytes vs recycle
     optimizations (multi-thread / pipeline / offload+struct), the §4.2
     argument that a shorter post-NIC timespan shrinks the reservable LLC.
  2. *Steady-state pool monitor* — fig 11: allocated/peak pool bytes and
     escape actions at line rate with the production 12 MB pool.
  3. *Escape ladder engagement* — shrunken pool + stragglers: replaces ->
     copies -> ECN, with the DRAM bandwidth each rung consumes (paper:
     < 0.5-1 GB/s).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core import recycle as R
from repro.core import simulator as S

from .common import emit

NAME = "pool_and_escape"
PAPER_REF = "figs 10/11, §4.3"

LINE_GBPS = 200.0
MSG = 256 << 10


def recycle_ablation() -> List[Dict]:
    variants = [
        ("unoptimized", R.paper_unoptimized()),
        ("+threads(4)", dataclasses.replace(R.paper_unoptimized(),
                                            threads=4)),
        ("+pipeline", dataclasses.replace(R.paper_unoptimized(), threads=4,
                                          pipelined=True)),
        ("+offload+struct (jet)", R.paper_default()),
    ]
    rows = []
    for name, m in variants:
        rows.append({
            "variant": name,
            "hold_us_256k": m.slot_holding_time_us(MSG),
            "msg_latency_us_256k": m.message_latency_us(MSG),
            "resident_mb_at_200g": m.resident_bytes(LINE_GBPS, MSG)
            / (1 << 20),
            "required_pool_mb": m.required_pool_bytes(LINE_GBPS, MSG)
            / (1 << 20),
        })
    return rows


def steady_state() -> List[Dict]:
    rows = []
    for msg_kb in (4, 16, 64, 256):
        r = S.run_sim(S.testbed_100g("jet", msg_bytes=msg_kb << 10,
                                     sim_time_s=0.03))
        rows.append({
            "msg_kb": msg_kb,
            "goodput_gbps": r.goodput_gbps,
            "pool_peak_mb": r.pool_peak_bytes / (1 << 20),
            "pool_avg_mb": r.pool_avg_bytes / (1 << 20),
            "replaces": r.escape_replaces, "copies": r.escape_copies,
            "ecn": r.escape_ecn,
            "escape_dram_gbps": r.escape_dram_gbps,
            "total_dram_gbps": r.nic_dram_gbps + r.escape_dram_gbps,
        })
    return rows


def escape_ladder() -> List[Dict]:
    rows = []
    cases = [
        ("nominal", dict()),
        ("stragglers", dict(straggler_frac=0.05, straggler_mult=50.0)),
        ("tiny_pool+stragglers", dict(jet_pool_bytes=2 << 20,
                                      straggler_frac=0.3,
                                      straggler_mult=100.0,
                                      sim_time_s=0.12)),
    ]
    for name, kw in cases:
        base = dict(msg_bytes=MSG, sim_time_s=0.04)
        base.update(kw)
        r = S.run_sim(S.testbed_100g("jet", **base))
        rows.append({
            "case": name, "goodput_gbps": r.goodput_gbps,
            "pool_peak_mb": r.pool_peak_bytes / (1 << 20),
            "replaces": r.escape_replaces, "copies": r.escape_copies,
            "ecn": r.escape_ecn,
            "escape_dram_gbps": r.escape_dram_gbps,
        })
    return rows


def main() -> None:
    ab = recycle_ablation()
    emit(NAME + "_recycle", ab)
    print(f"# pipelined+offload shrinks required pool "
          f"{ab[0]['required_pool_mb']:.0f} MB -> "
          f"{ab[-1]['required_pool_mb']:.0f} MB at 200 Gbps / 256 KB "
          f"(paper: 12 MB operating point)")
    ss = steady_state()
    emit(NAME + "_steady", ss)
    big = ss[-1]
    print(f"# steady state 256KB: pool peak {big['pool_peak_mb']:.1f} MB "
          f"(<12), escape DRAM {big['escape_dram_gbps']:.2f} Gbps "
          f"(paper <8 Gbps = 1 GB/s)")
    emit(NAME + "_ladder", escape_ladder())


if __name__ == "__main__":
    main()
