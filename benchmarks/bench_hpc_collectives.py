"""Paper figure 13: HPC collective latency (MVAPICH benchmarks), Jet vs DDIO.

Topology follows the paper's §6.4 setup exactly: 2 hosts x 4 processes = 8
MPI ranks, dual-port 100 Gbps, 4 MB messages per rank, membw contention on.

Sub-study 1 — receive-path completion model.  Each collective is
characterised by (bytes received over the NIC, receive buffers posted,
synchronization phases, in-cast degree, reduction bytes).  The per-mode
receive bandwidth comes from the same constants as the event simulator
(`repro.core.simulator.testbed_100g`):

  * DDIO miss ramps once posted buffers exceed the DDIO capacity (leaky
    DMA); each missed byte costs ~2x DRAM traffic out of the bandwidth the
    contending CPU leaves over, so drain collapses to ``avail_dram/2``;
    in-cast additionally causes drops/retransmits in the baseline.
  * Jet drains at line rate (the cache pool absorbs the burst — validated
    by the event sim in bench_receiver_datapath).
  * Reductions read their operands from LLC under Jet (the data IS in the
    pool) vs DRAM-under-contention for the baseline — why all-reduce gains
    only a few percent (paper: -5.5%) while all-to-all gains -35.1%.

Sub-study 2 — structural comparison on 8 host devices (subprocess): lower
XLA's one-shot all-gather vs the Jet ring collective and compare compiled
per-device collective bytes + temp memory ("the gathered tensor never
exists").
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

from repro.core import simulator as S

from .common import emit

NAME = "hpc_collectives"
PAPER_REF = "fig 13"

RANKS = 8
PROCS_PER_HOST = 4
MSG = 4 << 20                     # per-rank message (paper §6.4)
SW_US = 150.0                     # MPI per-phase software/sync overhead
LLC_GBPS = 3200.0                 # cache read bandwidth (x~13 DRAM here)
PAPER_PCT = {"all-to-all": 35.1, "all-gather": 25.0, "all-reduce": 5.5}


def _testbed() -> S.SimConfig:
    return S.testbed_100g("ddio")


def _recv_bw_gbps(cfg: S.SimConfig, mode: str, posted_bytes: int,
                  incast: int) -> float:
    """Receive drain bandwidth, from the simulator's datapath constants."""
    line = cfg.line_rate_gbps
    if mode == "jet":
        return min(line, cfg.pcie_gbps)
    over = posted_bytes - cfg.ddio_bytes
    miss = min(1.0, max(0.0, over / (cfg.miss_knee * cfg.ddio_bytes)))
    avail = max(1e-9, cfg.membw_total_gbps - cfg.cpu_membw_gbps)
    bw = min(line, cfg.pcie_gbps)
    if miss > 1e-9:
        bw = min(bw, avail / (2.0 * miss))
    # in-cast overflow drops -> retransmits (RNIC buffer is 2 MB, a 4 MB
    # burst per extra sender overflows it; DCQCN recovers but pays ~30%)
    bw /= 1.0 + 0.3 * (incast - 1) / (RANKS - 1)
    return bw


# (name, recv_bytes_over_nic, posted_bytes, phases, incast, reduce_bytes)
def _patterns() -> List[tuple]:
    n, p, m = RANKS, PROCS_PER_HOST, MSG
    remote = n - p                 # peers across the NIC per rank
    return [
        # every rank exchanges m with each peer; NIC sees the remote share;
        # posted buffers cover all n-1 inbound messages (the leaky set)
        ("all-to-all", p * remote * m, (n - 1) * m, n - 1, p, 0),
        # ring: n-1 phases, the host-crossing links carry every shard;
        # each rank posts the full (n-1)-shard receive buffer up front
        ("all-gather", p * remote * m // p, (n - 1) * m, n - 1, 1, 0),
        # ring reduce-scatter: chunked m/n fragments, small posted set,
        # but every phase reduces a fragment (reads under contention)
        ("reduce-scatter", (n - 1) * m // n, 2 * m // n, n - 1, 1,
         (n - 1) * m // n),
        # rs + ag: twice the phases, reduction on the rs half
        ("all-reduce", 2 * (n - 1) * m // n, 2 * m // n, 2 * (n - 1), 1,
         (n - 1) * m // n),
        # binomial tree, log2(n) phases, whole message per hop
        ("broadcast", m, m, 3, 1, 0),
        # root receives n-1 messages at once (worst in-cast, small posted)
        ("gather", (n - 1) * m // n, (n - 1) * m // n, 1, n - 1, 0),
    ]


def run() -> List[Dict]:
    cfg = _testbed()
    avail_dram = cfg.membw_total_gbps - cfg.cpu_membw_gbps
    rows: List[Dict] = []
    for name, recv, posted, phases, incast, red in _patterns():
        lat = {}
        for mode in ("ddio", "jet"):
            bw = _recv_bw_gbps(cfg, mode, posted, incast)
            wire_us = recv * 8.0 / (bw * 1e9) * 1e6
            red_bw = LLC_GBPS if mode == "jet" else avail_dram
            red_us = red * 8.0 / (red_bw * 1e9) * 1e6
            lat[mode] = wire_us + phases * SW_US + red_us
        rows.append({
            "collective": name, "incast": incast, "phases": phases,
            "recv_mb": recv / (1 << 20),
            "ddio_lat_us": lat["ddio"], "jet_lat_us": lat["jet"],
            "improvement_pct": 100 * (1 - lat["jet"] / lat["ddio"]),
            "paper_pct": PAPER_PCT.get(name, float("nan")),
        })
    return rows


_DRIVER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.parallel import collectives as coll
from repro.parallel.compat import shard_map
from repro.launch import hlo_analysis

m = 8
mesh = jax.make_mesh((m,), ("model",))
D, F = 4096, 512          # x:[B=16, D], w:[D, F] sharded on D
x = jax.ShapeDtypeStruct((16, D), jnp.bfloat16)
w = jax.ShapeDtypeStruct((D, F), jnp.bfloat16)

def xla_ag_matmul(x, w):           # baseline: all-gather W then matmul
    wf = jax.lax.all_gather(w, "model", axis=0, tiled=True)
    return x @ wf

def jet_ring(x, w):
    return coll.ring_allgather_matmul(x, w, "model", m, frags=2)

rows = []
for name, fn, w_spec in (("xla_allgather", xla_ag_matmul, P("model", None)),
                         ("jet_ring", jet_ring, P("model", None))):
    sm = shard_map(fn, mesh=mesh, in_specs=(P(), w_spec),
                       out_specs=P(), check_vma=False)
    lowered = jax.jit(sm).lower(x, w)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    deep = hlo_analysis.analyze(hlo)
    memq = compiled.memory_analysis()
    rows.append(dict(impl=name,
                     coll_bytes_per_dev=deep["coll_total"],
                     coll_counts=deep["coll_counts"],
                     temp_bytes=getattr(memq, "temp_size_in_bytes", -1)))
print("JSON:" + json.dumps(rows))
"""


def structural() -> List[Dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _DRIVER], env=env,
                         capture_output=True, text=True, timeout=600)
    for line in out.stdout.splitlines():
        if line.startswith("JSON:"):
            rows = json.loads(line[5:])
            for r in rows:
                r["coll_counts"] = json.dumps(r["coll_counts"])
            return rows
    raise RuntimeError(f"driver failed:\n{out.stdout}\n{out.stderr}")


def main() -> None:
    rows = run()
    emit(NAME, rows)
    by = {r["collective"]: r for r in rows}
    for c in ("all-to-all", "all-gather", "all-reduce"):
        print(f"# {c}: -{by[c]['improvement_pct']:.1f}% "
              f"(paper -{PAPER_PCT[c]}%)")
    try:
        st = structural()
        emit(NAME + "_structural", st)
        xla = next(r for r in st if r["impl"] == "xla_allgather")
        jet = next(r for r in st if r["impl"] == "jet_ring")
        if xla["temp_bytes"] > 0 and jet["temp_bytes"] > 0:
            print(f"# jet_ring temp memory {jet['temp_bytes']/1e6:.2f} MB vs "
                  f"xla all-gather {xla['temp_bytes']/1e6:.2f} MB "
                  f"(gathered W never materializes)")
    except Exception as e:  # noqa: BLE001 — structural part is best-effort
        print(f"# structural sub-benchmark skipped: {e}")


if __name__ == "__main__":
    main()
