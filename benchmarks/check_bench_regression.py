"""CI bench-regression gate: compare the machine-readable
``experiments/bench/BENCH_fabric.json`` (as produced by
``python -m benchmarks.bench_fabric --quick``) against the checked-in
reference values in ``benchmarks/bench_floors.json`` and fail on
a >20% regression.

Two kinds of guarded fields:

* ``floor``  — bigger is better (warm speedups): fail when the measured
  value drops more than 20% below the reference;
* ``ceiling`` — smaller is better (vector-vs-scalar deviations): fail
  when the measured value exceeds the reference by more than 20% (a
  ``null`` — the JSON encoding of inf/NaN, i.e. the engines disagreed —
  always fails).

The gate fails loudly — with distinct messages — when a gated section
or field is *absent* from a fresh BENCH_fabric.json (a silently dropped
bench is itself a regression), when a value is ``null`` (non-finite),
and when a value is non-numeric (a schema change must come with a
floors update, not slip past the comparison).

A rule may carry a ``quick_value`` next to ``value``: CI runs the bench
with ``--quick`` (smaller grids and sim times, where e.g. warm speedups
are lower because compile-amortization differs), and the checker picks
``quick_value`` when the bench was produced in quick mode.  ``value``
documents the full-run envelope.

Reference values are deliberately conservative (well below the numbers
a warmed-up run produces locally) so the gate only trips on genuine
regressions, not runner-to-runner jitter; refresh them when a PR
intentionally moves the perf or accuracy envelope.

  PYTHONPATH=src python -m benchmarks.check_bench_regression \
      [bench.json] [floors.json]
"""
from __future__ import annotations

import json
import os
import sys

from .common import OUT_DIR

REGRESSION = 0.20

BENCH_PATH = os.path.join(OUT_DIR, "BENCH_fabric.json")
FLOORS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "bench_floors.json")


def check(bench: dict, floors: dict) -> list:
    failures = []
    quick = bool(bench.get("quick"))
    for section, rules in floors.items():
        row = bench.get(section)
        if row is None:
            msg = (f"{section}: gated section missing from bench output "
                   f"(the bench must always produce it)")
            print(f"FAIL {msg}")
            failures.append(msg)
            continue
        for field, spec in rules.items():
            kind = spec["kind"]
            ref = spec["value"]
            if quick and "quick_value" in spec:
                ref = spec["quick_value"]
            if field not in row:
                msg = (f"{section}.{field}: gated field missing from "
                       f"bench output (schema drifted under the gate)")
                print(f"FAIL {msg}")
                failures.append(msg)
                continue
            val = row[field]
            if val is None:
                msg = (f"{section}.{field} is null (non-finite measured "
                       f"value — the engines disagreed or the metric "
                       f"never resolved)")
                print(f"FAIL {msg}")
                failures.append(msg)
                continue
            if isinstance(val, bool) or not isinstance(val, (int, float)):
                msg = (f"{section}.{field} = {val!r} is not numeric — "
                       f"update floors with the schema, do not gate "
                       f"non-numeric fields")
                print(f"FAIL {msg}")
                failures.append(msg)
                continue
            if kind == "floor":
                limit = ref * (1.0 - REGRESSION)
                ok = val >= limit
                cmp = f">= {limit:.4g} (ref {ref:.4g} - 20%)"
            elif kind == "ceiling":
                limit = ref * (1.0 + REGRESSION)
                ok = val <= limit
                cmp = f"<= {limit:.4g} (ref {ref:.4g} + 20%)"
            else:
                failures.append(f"{section}.{field}: bad kind {kind!r}")
                continue
            status = "ok  " if ok else "FAIL"
            print(f"{status} {section}.{field} = {val} (need {cmp})")
            if not ok:
                failures.append(f"{section}.{field} = {val}, need {cmp}")
    return failures


def main(argv) -> int:
    bench_path = argv[1] if len(argv) > 1 else BENCH_PATH
    floors_path = argv[2] if len(argv) > 2 else FLOORS_PATH
    with open(bench_path) as f:
        bench = json.load(f)
    with open(floors_path) as f:
        floors = json.load(f)
    failures = check(bench, floors)
    if failures:
        print(f"\nbench regression gate FAILED "
              f"({len(failures)} field(s)):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
