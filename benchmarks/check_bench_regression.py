"""CI bench-regression gate: compare the machine-readable
``experiments/bench/BENCH_fabric.json`` (as produced by
``python -m benchmarks.bench_fabric --quick``) against the checked-in
reference values in ``benchmarks/bench_floors.json`` and fail on
a >20% regression.

Two kinds of guarded fields:

* ``floor``  — bigger is better (warm speedups): fail when the measured
  value drops more than 20% below the reference;
* ``ceiling`` — smaller is better (vector-vs-scalar deviations): fail
  when the measured value exceeds the reference by more than 20% (a
  ``null`` — the JSON encoding of inf/NaN, i.e. the engines disagreed —
  always fails).

Reference values are deliberately conservative (well below the numbers
a warmed-up run produces locally) so the gate only trips on genuine
regressions, not runner-to-runner jitter; refresh them when a PR
intentionally moves the perf or accuracy envelope.

  PYTHONPATH=src python -m benchmarks.check_bench_regression \
      [bench.json] [floors.json]
"""
from __future__ import annotations

import json
import os
import sys

from .common import OUT_DIR

REGRESSION = 0.20

BENCH_PATH = os.path.join(OUT_DIR, "BENCH_fabric.json")
FLOORS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "bench_floors.json")


def check(bench: dict, floors: dict) -> list:
    failures = []
    for section, rules in floors.items():
        row = bench.get(section)
        if row is None:
            failures.append(f"{section}: missing from bench output")
            continue
        for field, spec in rules.items():
            val = row.get(field)
            kind, ref = spec["kind"], spec["value"]
            if kind == "floor":
                limit = ref * (1.0 - REGRESSION)
                ok = val is not None and val >= limit
                cmp = f">= {limit:.4g} (ref {ref:.4g} - 20%)"
            elif kind == "ceiling":
                limit = ref * (1.0 + REGRESSION)
                ok = val is not None and val <= limit
                cmp = f"<= {limit:.4g} (ref {ref:.4g} + 20%)"
            else:
                failures.append(f"{section}.{field}: bad kind {kind!r}")
                continue
            status = "ok  " if ok else "FAIL"
            print(f"{status} {section}.{field} = {val} (need {cmp})")
            if not ok:
                failures.append(f"{section}.{field} = {val}, need {cmp}")
    return failures


def main(argv) -> int:
    bench_path = argv[1] if len(argv) > 1 else BENCH_PATH
    floors_path = argv[2] if len(argv) > 2 else FLOORS_PATH
    with open(bench_path) as f:
        bench = json.load(f)
    with open(floors_path) as f:
        floors = json.load(f)
    failures = check(bench, floors)
    if failures:
        print(f"\nbench regression gate FAILED "
              f"({len(failures)} field(s)):")
        for msg in failures:
            print(f"  - {msg}")
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
