"""Pallas kernel microbench: correctness (vs ref oracle) + structural
roofline terms per kernel.

Wall-clock on CPU is meaningless for TPU kernels, so alongside the
interpret-mode allclose check we report each kernel's *arithmetic intensity*
(FLOPs / HBM bytes) at production shapes and its implied roofline bound on a
v5e chip (197 TFLOP/s bf16, 819 GB/s HBM) — the number the BlockSpec tiling
is designed against.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from .common import emit

NAME = "kernels"
PAPER_REF = "kernel tier (DESIGN.md §2)"

PEAK_FLOPS = 197e12
HBM_BW = 819e9


def _ai_row(name: str, flops: float, bytes_: float) -> Dict:
    ai = flops / bytes_
    knee = PEAK_FLOPS / HBM_BW           # FLOP/byte at the roofline ridge
    bound = "compute" if ai > knee else "memory"
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    return {"kernel": name, "gflops": flops / 1e9,
            "mbytes": bytes_ / 1e6, "arith_intensity": ai,
            "ridge": knee, "bound": bound,
            "roofline_us": max(t_c, t_m) * 1e6,
            "mxu_frac": t_c / max(t_c, t_m)}


def intensity() -> List[Dict]:
    rows = []
    # staged matmul at a production FFN tile: [4096 x 5120] @ [5120 x 8192]
    m, k, n = 4096, 5120, 8192
    fl = 2.0 * m * k * n
    by = 2.0 * (m * k + k * n + m * n)
    rows.append(_ai_row("jet_staged_matmul(ffn tile)", fl, by))
    # flash attention: B=1 H=40 T=4096 hd=128
    b, h, t, hd = 1, 40, 4096, 128
    fl = 4.0 * b * h * t * t * hd * 0.5          # causal half
    by = 2.0 * (3 * b * h * t * hd + b * h * t * hd)
    rows.append(_ai_row("jet_flash_attention(train 4k)", fl, by))
    # decode attention: one token against 32k KV, B=128
    b, t = 128, 32_768
    h, hd, hkv = 40, 128, 8
    fl = 4.0 * b * h * t * hd
    by = 2.0 * (2 * b * t * hkv * hd)            # stream K,V once
    rows.append(_ai_row("jet_decode_attention(32k)", fl, by))
    # mamba2 SSD chunk: B=1 T=4096 d_in=4096 N=64, chunk 256
    b, t, d, n = 1, 4096, 4096, 64
    fl = 6.0 * b * t * d * n
    by = 2.0 * (2 * b * t * d + 2 * b * t * n)
    rows.append(_ai_row("mamba2_ssd(4k)", fl, by))
    return rows


def correctness() -> List[Dict]:
    rows = []
    key = jax.random.key(0)

    def timed(fn, *a):
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn(*a))
        return out, (time.perf_counter() - t0) * 1e3

    # staged matmul
    a = jax.random.normal(key, (256, 512), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (512, 256), jnp.float32)
    got, ms_i = timed(lambda x, y: ops.staged_matmul(x, y,
                                                     impl="interpret"), a, b)
    want, ms_r = timed(lambda x, y: ops.staged_matmul(x, y, impl="ref"),
                       a, b)
    err = float(np.abs(np.asarray(got) - np.asarray(want)).max())
    rows.append({"kernel": "staged_matmul", "shape": "256x512x256",
                 "interpret_ms": ms_i, "ref_ms": ms_r, "max_err": err,
                 "ok": int(err < 1e-3)})

    # flash attention
    q = jax.random.normal(key, (1, 2, 256, 64), jnp.float32)
    k = jax.random.normal(jax.random.key(2), (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(jax.random.key(3), (1, 2, 256, 64), jnp.float32)
    got, ms_i = timed(lambda *t: ops.flash_attention(*t, impl="interpret"),
                      q, k, v)
    want, ms_r = timed(lambda *t: ops.flash_attention(*t, impl="ref"),
                       q, k, v)
    err = float(np.abs(np.asarray(got) - np.asarray(want)).max())
    rows.append({"kernel": "flash_attention", "shape": "1x2x256x64",
                 "interpret_ms": ms_i, "ref_ms": ms_r, "max_err": err,
                 "ok": int(err < 2e-3)})

    # ssd scan
    bsz, t, h, p, n = 1, 512, 4, 32, 16
    x = jax.random.normal(key, (bsz, t, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(jax.random.key(4), (bsz, t, h)))
    a_ = -jnp.exp(jax.random.normal(jax.random.key(5), (h,)))
    b_ = jax.random.normal(jax.random.key(6), (bsz, t, 1, n))
    c_ = jax.random.normal(jax.random.key(7), (bsz, t, 1, n))
    (got, _), ms_i = timed(lambda *ts: ops.ssd(*ts, chunk=128,
                                               impl="interpret"),
                           x, dt, a_, b_, c_)
    (want, _), ms_r = timed(lambda *ts: ops.ssd(*ts, chunk=128, impl="ref"),
                            x, dt, a_, b_, c_)
    err = float(np.abs(np.asarray(got) - np.asarray(want)).max())
    rows.append({"kernel": "mamba2_ssd", "shape": f"{bsz}x{t}x{h}x{p}",
                 "interpret_ms": ms_i, "ref_ms": ms_r, "max_err": err,
                 "ok": int(err < 2e-2)})
    return rows


def main() -> None:
    rows = correctness()
    emit(NAME + "_correctness", rows)
    assert all(r["ok"] for r in rows), "kernel mismatch vs oracle"
    emit(NAME + "_intensity", intensity())


if __name__ == "__main__":
    main()
