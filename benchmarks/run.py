"""Benchmark orchestrator: one module per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--only name] [--skip name]

Each module prints its CSV (also persisted under experiments/bench/) and a
``#``-prefixed derived-claims line mirroring the paper's headline numbers.
"""
from __future__ import annotations

import argparse
import time
import traceback

MODULES = [
    ("receiver_datapath", "figs 2/3/6/7 — datapath degradation, Jet vs DDIO"),
    ("concurrency_window", "fig 5 — READ concurrency saturation"),
    ("pool_and_escape", "figs 10/11 — pool sizing, recycle, escape ladder"),
    ("traffic_patterns", "fig 9 — OLAP / backup / OLTP"),
    ("fabric", "Clos incast/HoL + vectorized sweep engine"),
    ("hpc_collectives", "fig 13 — MPI collective latency"),
    ("kernels", "Pallas kernel correctness + arithmetic intensity"),
    ("roofline", "dry-run roofline terms per (arch x shape)"),
    ("capacity", "HBM-fit audit per cell"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip", default="")
    args = ap.parse_args()
    skip = set(args.skip.split(",")) if args.skip else set()

    failures = []
    for name, desc in MODULES:
        if args.only and name != args.only:
            continue
        if name in skip:
            continue
        print(f"\n=== {name}: {desc} ===", flush=True)
        t0 = time.time()
        try:
            if name == "roofline":
                from . import roofline
                import sys
                argv, sys.argv = sys.argv, ["roofline"]
                try:
                    roofline.main()
                finally:
                    sys.argv = argv
            elif name == "capacity":
                from . import capacity
                capacity.main()
            else:
                mod = __import__(f"benchmarks.bench_{name}",
                                 fromlist=["main"])
                mod.main()
        except Exception:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
        print(f"=== {name} done in {time.time() - t0:.1f}s ===", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
