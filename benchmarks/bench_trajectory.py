"""Bench trajectory dashboard: gated metrics over the BENCH_*.json
history.

:mod:`benchmarks.check_bench_regression` compares one fresh bench run
against the single checked-in ``bench_floors.json`` snapshot — which
catches a cliff but not a slow slide: three PRs each losing 15% of a
warm speedup all pass a 20% gate individually.  This tool closes that
gap by looking at the *history*:

* ``--snapshot`` archives the current ``experiments/bench/BENCH_*.json``
  files into ``experiments/bench/history/`` stamped with their mtime
  (CI calls this after every bench run, so history accrues one snapshot
  per push; locally it is opt-in).
* The default run scans every snapshot plus the current files, builds a
  per-gated-metric trajectory table (one row per metric from
  ``bench_floors.json``, one column per snapshot), and writes it as
  markdown (``experiments/bench/TRAJECTORY.md``) and json
  (``TRAJECTORY.json``).
* Any gated metric whose **latest** value is worse than its
  **best-ever** by more than 20% (respecting the floor/ceiling
  direction) is flagged — and fails the run under ``--strict``.

Quick-mode and full-mode runs measure different envelopes (smaller
grids amortize compiles differently), so best-ever is computed only
over snapshots with the same ``quick`` flag as the latest run.

  PYTHONPATH=src python -m benchmarks.bench_trajectory [--snapshot]
      [--strict] [--bench-dir DIR] [--floors FILE]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import shutil
import time
from typing import Dict, List, Optional, Tuple

from .common import OUT_DIR

SLIDE = 0.20          # worse-than-best-ever tolerance
HISTORY_SUBDIR = "history"
FLOORS_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "bench_floors.json")


def _history_dir(bench_dir: str) -> str:
    return os.path.join(bench_dir, HISTORY_SUBDIR)


def snapshot(bench_dir: str = OUT_DIR) -> List[str]:
    """Archive current BENCH_*.json files into the history dir, stamped
    with their mtime (idempotent: an existing stamp is not rewritten)."""
    hdir = _history_dir(bench_dir)
    os.makedirs(hdir, exist_ok=True)
    copied = []
    for path in sorted(glob.glob(os.path.join(bench_dir,
                                              "BENCH_*.json"))):
        base = os.path.splitext(os.path.basename(path))[0]
        stamp = time.strftime("%Y%m%d-%H%M%S",
                              time.localtime(os.path.getmtime(path)))
        dst = os.path.join(hdir, f"{base}_{stamp}.json")
        if not os.path.exists(dst):
            shutil.copyfile(path, dst)
            copied.append(dst)
    return copied


def _load(path: str) -> Optional[dict]:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def collect(bench_dir: str = OUT_DIR) -> List[Tuple[str, dict]]:
    """(label, bench-dict) pairs, history first, current files last —
    i.e. chronological, so the last entry is the latest measurement."""
    entries = []
    for path in sorted(glob.glob(os.path.join(_history_dir(bench_dir),
                                              "BENCH_*.json"))):
        d = _load(path)
        if d is not None:
            label = os.path.splitext(os.path.basename(path))[0]
            entries.append((label.replace("BENCH_", ""), d))
    for path in sorted(glob.glob(os.path.join(bench_dir,
                                              "BENCH_*.json"))):
        d = _load(path)
        if d is not None:
            entries.append(("current", d))
    return entries


def trajectories(entries: List[Tuple[str, dict]],
                 floors: dict) -> List[dict]:
    """One record per gated metric: its full value trajectory, the
    best-ever among like-mode snapshots, the latest value, and whether
    the latest slid >20% off the best."""
    out = []
    for section, rules in floors.items():
        for field, spec in rules.items():
            kind = spec["kind"]
            traj = []
            for label, bench in entries:
                row = bench.get(section)
                val = row.get(field) if isinstance(row, dict) else None
                if isinstance(val, bool) or \
                        not isinstance(val, (int, float)):
                    val = None
                traj.append({"run": label, "value": val,
                             "quick": bool(bench.get("quick"))})
            seen = [t for t in traj if t["value"] is not None]
            rec = {"section": section, "field": field, "kind": kind,
                   "trajectory": traj, "latest": None, "best": None,
                   "flagged": False}
            if seen:
                latest = seen[-1]
                like = [t["value"] for t in seen
                        if t["quick"] == latest["quick"]]
                best = max(like) if kind == "floor" else min(like)
                rec["latest"] = latest["value"]
                rec["best"] = best
                if kind == "floor":
                    rec["flagged"] = latest["value"] < \
                        best * (1.0 - SLIDE)
                else:
                    limit = best * (1.0 + SLIDE) if best > 0 \
                        else 1e-12
                    rec["flagged"] = latest["value"] > limit
            out.append(rec)
    return out


def _fmt(v) -> str:
    if v is None:
        return "—"
    return f"{v:.4g}"


def render_markdown(recs: List[dict],
                    entries: List[Tuple[str, dict]]) -> str:
    labels = [label for label, _ in entries]
    lines = ["# Bench trajectory (gated metrics)", "",
             f"Snapshots, oldest → latest: {', '.join(labels)}", "",
             "| metric | kind | " + " | ".join(labels)
             + " | best | slide |",
             "|---|---|" + "---|" * (len(labels) + 2)]
    for r in recs:
        vals = " | ".join(_fmt(t["value"]) for t in r["trajectory"])
        flag = "**FLAGGED**" if r["flagged"] else "ok"
        lines.append(f"| {r['section']}.{r['field']} | {r['kind']} | "
                     f"{vals} | {_fmt(r['best'])} | {flag} |")
    lines.append("")
    lines.append(f"Flag rule: latest worse than best-ever (same "
                 f"quick/full mode) by more than {SLIDE:.0%}.")
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.bench_trajectory",
        description="Per-metric trajectory over BENCH_*.json history.")
    ap.add_argument("--bench-dir", default=OUT_DIR)
    ap.add_argument("--floors", default=FLOORS_PATH)
    ap.add_argument("--snapshot", action="store_true",
                    help="archive current BENCH files into history/ "
                         "before scanning")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when any gated metric slid >20% off "
                         "its best-ever")
    args = ap.parse_args(argv)

    if args.snapshot:
        for path in snapshot(args.bench_dir):
            print(f"archived {path}")

    with open(args.floors) as f:
        floors = json.load(f)
    entries = collect(args.bench_dir)
    if not entries:
        print(f"no BENCH_*.json found under {args.bench_dir}")
        return 0
    recs = trajectories(entries, floors)

    md = render_markdown(recs, entries)
    md_path = os.path.join(args.bench_dir, "TRAJECTORY.md")
    json_path = os.path.join(args.bench_dir, "TRAJECTORY.json")
    with open(md_path, "w") as f:
        f.write(md)
    with open(json_path, "w") as f:
        json.dump({"snapshots": [label for label, _ in entries],
                   "metrics": recs}, f, indent=2)
    print(md)
    print(f"wrote {md_path} and {json_path}")

    flagged = [r for r in recs if r["flagged"]]
    for r in flagged:
        print(f"FLAGGED {r['section']}.{r['field']}: latest "
              f"{_fmt(r['latest'])} vs best-ever {_fmt(r['best'])} "
              f"({r['kind']})")
    if flagged and args.strict:
        print(f"\ntrajectory check FAILED ({len(flagged)} metric(s) "
              f"slid >{SLIDE:.0%} off best-ever)")
        return 1
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main(sys.argv[1:]))
