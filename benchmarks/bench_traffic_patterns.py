"""Paper figure 9: Jet vs DDIO under the three production storage traffic
patterns (OLAP / File Backup / OLTP).

Each pattern is a message-size mix abstracted from the paper's five-year
cloud-storage trace description: OLTP is small-message dominated, OLAP mixes
mid/large scans, backup is large sequential.  The simulator runs the
byte-weighted mean message size of the mix (fluid model) per mode.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import simulator as S

from .common import emit

NAME = "traffic_patterns"
PAPER_REF = "fig 9"

# (msg_kb, byte_fraction) mixes
PATTERNS = {
    "oltp": [(4, 0.5), (16, 0.5)],
    "olap": [(16, 0.3), (64, 0.4), (256, 0.3)],
    "backup": [(256, 0.9), (64, 0.1)],
}


def _mix_msg_bytes(mix) -> int:
    return int(sum(kb * frac for kb, frac in mix)) << 10


def run() -> List[Dict]:
    rows: List[Dict] = []
    for name, mix in PATTERNS.items():
        msg = _mix_msg_bytes(mix)
        res = {}
        for mode in ("ddio", "jet"):
            res[mode] = S.run_sim(S.testbed_100g(mode, msg_bytes=msg,
                                                 sim_time_s=0.02))
        rows.append({
            "pattern": name, "mean_msg_kb": msg >> 10,
            "ddio_gbps": res["ddio"].goodput_gbps,
            "jet_gbps": res["jet"].goodput_gbps,
            "speedup": res["jet"].goodput_gbps / res["ddio"].goodput_gbps,
            "ddio_avg_lat_us": res["ddio"].avg_latency_us,
            "jet_avg_lat_us": res["jet"].avg_latency_us,
            "lat_improvement": 1 - res["jet"].avg_latency_us /
            res["ddio"].avg_latency_us,
        })
    return rows


def main() -> None:
    rows = run()
    emit(NAME, rows)
    best = max(rows, key=lambda r: r["speedup"])
    print(f"# best pattern {best['pattern']}: x{best['speedup']:.2f} "
          f"throughput (paper: up to 1.97x), "
          f"lat -{best['lat_improvement']:.0%}")


if __name__ == "__main__":
    main()
