"""Paper figure 5: READ concurrency x message size -> network throughput.

The receiver-side control admits ``conc`` concurrent READ fragments; each
in-flight READ can carry at most one bandwidth-delay product, so throughput
is min(line_rate, conc x frag / RTT).  The simulator receives that offered
load and reports what survives the datapath.  Validates C6: concurrency 4
saturates 2x100 Gbps with 256 KB fragments; the paper operates at 32.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import simulator as S
from repro.core.window import ReadWindow

from .common import emit

NAME = "concurrency_window"
PAPER_REF = "fig 5"

RTT_US = 30.0
CONC = (1, 2, 4, 8, 16, 32)
MSG_KB = (16, 64, 256)


def offered_gbps(conc: int, msg_bytes: int, line_gbps: float) -> float:
    return min(line_gbps, conc * msg_bytes * 8 / (RTT_US * 1e-6) / 1e9)


def run() -> List[Dict]:
    rows: List[Dict] = []
    for msg_kb in MSG_KB:
        for conc in CONC:
            off = offered_gbps(conc, msg_kb << 10, 200.0)
            r = S.run_sim(S.testbed_100g("jet", msg_bytes=msg_kb << 10,
                                         sim_time_s=0.01,
                                         offered_gbps=off))
            rows.append({"msg_kb": msg_kb, "concurrency": conc,
                         "offered_gbps": off,
                         "goodput_gbps": r.goodput_gbps,
                         "saturated": int(r.goodput_gbps > 190)})
    return rows


def window_behaviour() -> List[Dict]:
    """The two windows in action: admit/defer counts for a burst of large
    messages (the in-cast admission story, paper §4.1.2)."""
    rows = []
    for n_msgs, msg_mb in ((64, 1), (16, 4)):
        w = ReadWindow()
        ids = []
        for _ in range(n_msgs):
            ids.extend(w.submit_message(msg_mb << 20, now=0.0))
        admitted = w.pump(now=0.0)
        w.check_invariants()
        rows.append({"burst_msgs": n_msgs, "msg_mb": msg_mb,
                     "fragments": len(ids),
                     "admitted_first_round": len(admitted),
                     "inflight_bytes_mb": w.inflight_bytes / (1 << 20),
                     "deferred": len(w.pending)})
    return rows


def main() -> None:
    rows = run()
    emit(NAME, rows)
    emit(NAME + "_admission", window_behaviour())
    sat4 = [r for r in rows if r["concurrency"] == 4 and r["msg_kb"] == 256]
    print(f"# conc=4 @256KB saturates: {bool(sat4[0]['saturated'])} "
          f"(paper fig 5: yes)")


if __name__ == "__main__":
    main()
