"""Shared benchmark plumbing: CSV emission + result directory layout.

Every benchmark module exposes ``run() -> list[dict]`` and a module-level
``NAME``/``PAPER_REF``.  Rows are printed as CSV and written under
``experiments/bench/<NAME>.csv`` so EXPERIMENTS.md tables can be regenerated
from disk without re-running.
"""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def emit(name: str, rows: List[Dict], quiet: bool = False) -> str:
    """Write rows to experiments/bench/<name>.csv and echo as CSV."""
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.abspath(os.path.join(OUT_DIR, f"{name}.csv"))
    if not rows:
        return path
    keys = list(rows[0].keys())
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=keys)
        w.writeheader()
        for r in rows:
            w.writerow({k: _fmt(r.get(k)) for k in keys})
    if not quiet:
        print(",".join(keys))
        for r in rows:
            print(",".join(str(_fmt(r.get(k))) for k in keys))
    return path


def _fmt(v):
    if isinstance(v, float):
        return round(v, 4)
    return v


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
