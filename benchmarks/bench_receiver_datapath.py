"""Paper figures 2/3 (baseline degradation) and 6/7 (Jet vs DDIO testbed).

Sweeps message size x {ddio, jet} x {25g-pfc, 100g-pfc-free} on the
calibrated receive-datapath simulator and reports every observable the paper
plots: goodput, avg/P99 latency, PFC pause, CNP count, DDIO miss rate and
the DRAM bandwidth the datapath induces (the PCIe-back-pressure proxy).

Claims validated (bands asserted in tests/test_simulator.py):
  C1  ~43% throughput drop 64 KB -> 1 MB under membw contention (fig 2a/2b)
  C2  ~10x latency growth (fig 2c)
  C3  DDIO miss rate -> 100% at 1 MB; 2x DDIO ways do not help (fig 3b)
  C4  Jet >= 1.96x testbed throughput at 256 KB; PFC/CNP ~= 0 (figs 6a/7a/6c/7c)
  C5  Jet cuts avg latency by >= 35% (figs 6b/7b)
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import simulator as S

from .common import emit

NAME = "receiver_datapath"
PAPER_REF = "figs 2/3/6/7"

MSG_KB = (64, 128, 256, 512, 1024)
SIM_S = 0.02


def run() -> List[Dict]:
    rows: List[Dict] = []
    for bed, mk in (("25g_pfc", S.testbed_25g), ("100g_pfcfree",
                                                 S.testbed_100g)):
        for msg_kb in MSG_KB:
            for mode in ("ddio", "jet"):
                r = S.run_sim(mk(mode, msg_bytes=msg_kb << 10,
                                 sim_time_s=SIM_S))
                rows.append({
                    "testbed": bed, "mode": mode, "msg_kb": msg_kb,
                    "goodput_gbps": r.goodput_gbps,
                    "avg_lat_us": r.avg_latency_us,
                    "p99_lat_us": r.p99_latency_us,
                    "pfc_pause_us": r.pfc_pause_us,
                    "cnp": r.cnp_count,
                    "ddio_miss": r.ddio_miss_rate,
                    "nic_dram_gbps": r.nic_dram_gbps,
                    "pool_peak_mb": r.pool_peak_bytes / (1 << 20),
                })
    # C3b: doubling DDIO ways at 1 MB (the paper's strawman rebuttal)
    r2 = S.run_sim(S.testbed_100g("ddio", msg_bytes=1 << 20,
                                  sim_time_s=SIM_S, ddio_bytes=12 << 20))
    rows.append({"testbed": "100g_pfcfree", "mode": "ddio_2x_ways",
                 "msg_kb": 1024, "goodput_gbps": r2.goodput_gbps,
                 "avg_lat_us": r2.avg_latency_us,
                 "p99_lat_us": r2.p99_latency_us,
                 "pfc_pause_us": r2.pfc_pause_us, "cnp": r2.cnp_count,
                 "ddio_miss": r2.ddio_miss_rate,
                 "nic_dram_gbps": r2.nic_dram_gbps, "pool_peak_mb": 0.0})
    return rows


def derived(rows: List[Dict]) -> List[str]:
    """Headline ratios mirroring the paper's claims."""
    idx = {(r["testbed"], r["mode"], r["msg_kb"]): r for r in rows}
    out = []
    for bed in ("25g_pfc", "100g_pfcfree"):
        d64 = idx[(bed, "ddio", 64)]
        d1m = idx[(bed, "ddio", 1024)]
        out.append(f"{bed}: baseline 64K->1M throughput drop "
                   f"{1 - d1m['goodput_gbps'] / d64['goodput_gbps']:.1%} "
                   f"(paper ~43%)")
        j = idx[(bed, "jet", 256)]
        d = idx[(bed, "ddio", 256)]
        out.append(f"{bed}: Jet/DDIO throughput x{j['goodput_gbps'] / d['goodput_gbps']:.2f} "
                   f"at 256 KB (paper 1.54-1.96x); "
                   f"avg lat -{1 - j['avg_lat_us'] / d['avg_lat_us']:.1%}; "
                   f"Jet PFC={j['pfc_pause_us']:.0f}us CNP={j['cnp']:.0f}")
    return out


def main() -> None:
    rows = run()
    emit(NAME, rows)
    for line in derived(rows):
        print("#", line)


if __name__ == "__main__":
    main()
