"""Roofline analysis over the dry-run artifacts (deliverable g).

For every (arch x shape) cell on the single-pod mesh (256 chips), derive the
three roofline terms from the compiled HLO numbers recorded by
``repro.launch.dryrun``:

  compute    = HLO_dot_FLOPs_per_device / PEAK_FLOPS          [s]
  memory     = HLO_dot_bytes_per_device / HBM_BW              [s]
  collective = collective_bytes_per_device / ICI_BW           [s]

(all three are *per-device* times; the mesh divides the work, the constants
are per-chip).  The dominant term is the bottleneck; the roofline fraction
reported is compute / max(terms) — the fraction of the bound the MXU would
be busy if compute, HBM traffic and ICI traffic overlap perfectly (XLA
latency-hiding overlaps collectives with compute; memory traffic is what the
BlockSpec tiling hides).

MODEL_FLOPS = 6 * N_active * tokens (train) / 2 * N_active * tokens
(prefill/decode); the ratio MODEL_FLOPS / HLO_FLOPs exposes remat and
redundancy waste (full remat => ~0.75, since fwd is recomputed: 8ND vs 6ND).

Hardware constants (TPU v5e, per chip):
  197 TFLOP/s bf16, 819 GB/s HBM, 3 usable ICI links x 50 GB/s.

Usage:
  python -m benchmarks.roofline                 # baseline table (tag "")
  python -m benchmarks.roofline --tag staged    # variant table
  python -m benchmarks.roofline --compare a,b   # baseline vs variant deltas
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_arch, get_shape

from .common import emit

NAME = "roofline"
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 3 * 50e9          # per-chip aggregate over usable torus links

# The CPU backend's float-normalization pass legalizes every bf16 dot to
# f32 (convert-dot-convert), so HLO dot operand/result bytes read off the
# CPU-compiled module are 2x the TPU deployment's, where dots execute in
# bf16 natively.  Collective bytes are corrected per-op during HLO parsing
# (launch.hlo_analysis._bf16_on_tpu); dots get this uniform factor.
BF16_DOT_CORRECTION = 0.5

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

_SUGGEST = {
    "collective": "shrink/overlap collectives: jet staged ring (no HBM "
                  "materialization), hierarchical + compressed grads, "
                  "fewer all-reduces via 2D-sharded activations",
    "memory": "raise arithmetic intensity: larger fused blocks, less remat "
              "recompute traffic, bf16 end-to-end, keep gathered operands "
              "out of HBM (jet staged consumption)",
    "compute": "already MXU-bound: tighten MODEL/HLO ratio (drop remat), "
               "then only kernel-level tiling (128-aligned MXU dims) helps",
}


def model_flops_per_device(arch_name: str, shape_name: str,
                           n_devices: int) -> float:
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    _, n_active = cfg.param_counts()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_devices
    if shape.kind == "prefill":
        # prefill emits last-token-only logits: the unembedding projection
        # contributes ~zero matmul FLOPs (1 of seq_len positions)
        tokens = shape.global_batch * shape.seq_len
        n_eff = n_active - cfg.d_model * cfg.vocab_size
        return 2.0 * n_eff * tokens / n_devices
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch / n_devices


def load(mesh: str = "single", tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        if not r.get("ok"):
            continue
        recs.append(r)
    return recs


def attn_kernel_credit_bytes(arch_name: str, shape_name: str,
                             n_dev: int) -> float:
    """Per-device HBM dot traffic the Pallas flash-attention kernel keeps
    in VMEM on TPU (the CPU dry-run lowers the pure-jnp reference, which
    materializes score tensors in HBM).

    Naive attention does two batched dots per head-block: scores = Q K^T
    (writes S = B_loc*H_loc*T*T_blk) and O = P V (re-reads S).  Per pass
    that is ~3*S bytes of dot traffic (write + read + softmax-side read);
    full-remat training runs 4 passes (fwd, replay, 2 bwd dots).  The
    fused kernel streams KV and keeps S in VMEM: the credit is the whole
    score-side traffic.  bf16 (2-byte) accounting.
    """
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if shape.kind == "decode" or cfg.xlstm:
        return 0.0             # decode kernel scores are tiny; xlstm: none
    # attention layers only (hybrid archs: every attn_every-th block)
    if cfg.family in ("ssm", "hybrid"):
        n_attn = (cfg.num_layers // cfg.attn_every) if cfg.attn_every \
            else 0
    else:
        n_attn = cfg.num_layers
    dp, tp = 16, 16            # single-pod production mesh
    b_loc = max(1, shape.global_batch // dp)
    h_loc = max(1, cfg.num_heads // tp)
    t = shape.seq_len
    t_eff = min(t, cfg.sliding_window or t)
    s_bytes = 2.0 * b_loc * h_loc * t * t_eff
    if shape.kind == "train":
        s_bytes *= 0.5         # causal masking halves the useful area
        passes = 4
    else:
        passes = 1
    return 3.0 * s_bytes * passes * n_attn


def analyze_record(r: Dict) -> Dict:
    n_dev = 1
    for v in r["mesh_shape"].values():
        n_dev *= v
    c = r["flops_per_device"] / PEAK_FLOPS
    m_raw = r["dot_bytes_per_device"] * BF16_DOT_CORRECTION
    credit = min(attn_kernel_credit_bytes(r["arch"], r["shape"], n_dev),
                 0.9 * m_raw)
    m = m_raw / HBM_BW
    mk = (m_raw - credit) / HBM_BW      # with Pallas attention kernels
    k = r["collective_total_per_device"] / ICI_BW
    bound = max(c, mk, k)
    dom = ("compute", "memory", "collective")[[c, mk, k].index(bound)]
    mf = model_flops_per_device(r["arch"], r["shape"], n_dev)
    return {
        "arch": r["arch"], "shape": r["shape"], "tag": r.get("tag", ""),
        "compute_s": c, "memory_s": m, "memory_kernel_s": mk,
        "collective_s": k,
        "bound": dom,
        "roofline_frac": c / bound if bound > 0 else 0.0,
        "model_gflops_dev": mf / 1e9,
        "hlo_gflops_dev": r["flops_per_device"] / 1e9,
        "useful_ratio": mf / r["flops_per_device"]
        if r["flops_per_device"] else 0.0,
        "hbm_gb_dev": r.get("argument_size_in_bytes", 0) / 1e9,
        "temp_gb_dev": r.get("temp_size_in_bytes", 0) / 1e9,
        "suggest": _SUGGEST[dom],
    }


def table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | mem (kernels) s | "
           "collective s | bound | roofline frac | useful FLOP ratio |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['memory_kernel_s']:.3f} | "
            f"{r['collective_s']:.3f} | "
            f"**{r['bound']}** | {r['roofline_frac']:.2f} | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def compare(tag_a: str, tag_b: str, mesh: str = "single") -> List[Dict]:
    """Per-cell deltas between two variants (hillclimb bookkeeping)."""
    a = {(r["arch"], r["shape"]): analyze_record(r)
         for r in load(mesh, tag_a)}
    b = {(r["arch"], r["shape"]): analyze_record(r)
         for r in load(mesh, tag_b)}
    rows = []
    for key in sorted(set(a) & set(b)):
        ra, rb = a[key], b[key]
        dom = ra["bound"]
        col = f"{dom}_s"
        rows.append({
            "arch": key[0], "shape": key[1],
            "bound": dom,
            f"{tag_a or 'base'}_s": ra[col],
            f"{tag_b or 'base'}_s": rb[col],
            "delta": (rb[col] - ra[col]) / ra[col] if ra[col] else 0.0,
            "frac_before": ra["roofline_frac"],
            "frac_after": rb["roofline_frac"],
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", default="")
    ap.add_argument("--compare", default=None,
                    help="tagA,tagB — print per-cell deltas")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    if args.compare:
        ta, tb = args.compare.split(",")
        rows = compare(ta, tb, args.mesh)
        emit(f"{NAME}_compare_{ta or 'base'}_{tb or 'base'}", rows)
        return

    recs = load(args.mesh, args.tag)
    rows = [analyze_record(r) for r in recs]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    emit(NAME + (f"_{args.tag}" if args.tag else ""),
         [{k: v for k, v in r.items() if k != "suggest"} for r in rows],
         quiet=args.markdown)
    if args.markdown:
        print(table(rows))
    worst = sorted(rows, key=lambda r: r["roofline_frac"])[:3]
    print(f"# {len(rows)} cells analyzed (mesh={args.mesh}, "
          f"tag={args.tag or 'baseline'})")
    for r in worst:
        print(f"# worst: {r['arch']} x {r['shape']} frac="
              f"{r['roofline_frac']:.2f} bound={r['bound']} -> "
              f"{r['suggest'][:80]}")


if __name__ == "__main__":
    main()
