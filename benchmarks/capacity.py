"""HBM capacity audit per (arch x shape) cell — the 'does it actually fit
a 16 GB v5e chip' column of the runnability story.

Sources: the dry-run's compiled ``memory_analysis()``.  CPU-backend temp
is an upper bound (~2x TPU: f32 promotion + weaker fusion); we report it
raw plus a /2 TPU estimate, and flag the fitting strategy for the cells
over budget (accum microbatching for train, serving meshes for decode —
both measured in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, List

from .common import emit
from .roofline import load

NAME = "capacity"
HBM_GB = 16.0


def run(mesh: str = "single") -> List[Dict]:
    rows = []
    for r in load(mesh, ""):
        args = r.get("argument_size_in_bytes", 0) / 1e9
        temp = r.get("temp_size_in_bytes", 0) / 1e9
        out = r.get("output_size_in_bytes", 0) / 1e9
        tpu_est = args + temp / 2 + out / 2
        fits = tpu_est <= HBM_GB
        if fits:
            strategy = "-"
        elif r["shape"] == "train_4k":
            strategy = "accum microbatching (temp / A; §Perf A-v5)"
        elif r["shape"].startswith("decode") or "prefill" in r["shape"]:
            strategy = "serving mesh / bf16-int8 weights (§Perf C)"
        else:
            strategy = "shard wider"
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "args_gb": args, "temp_gb_cpu": temp,
            "tpu_estimate_gb": tpu_est,
            "fits_16gb": int(fits),
            "strategy": strategy,
        })
    rows.sort(key=lambda x: -x["tpu_estimate_gb"])
    return rows


def main() -> None:
    rows = run()
    emit(NAME, rows)
    over = [r for r in rows if not r["fits_16gb"]]
    print(f"# {len(rows) - len(over)}/{len(rows)} cells fit 16 GB as-is; "
          f"{len(over)} need a fitting strategy (all have one measured)")


if __name__ == "__main__":
    main()
