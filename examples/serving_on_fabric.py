"""Fabric-driven serving: switch backpressure throttles decode-lane
admission (the closed host/network loop of paper §3-§4).

Two co-simulated timescales share one host:

  * **fabric time** (1 us ticks): eight DCQCN senders burst KV/prompt
    traffic through a congested leaf downlink (an :class:`OutputPort`
    with ECN + PFC) into the serving host's receive datapath — the same
    :class:`~repro.core.datapath.HostDatapath`-backed ``ReceiverHost``
    that powers ``run_sim`` and the fabric driver;
  * **engine time** (1 ms ticks): a batched decode engine whose
    admission control is ``JetService`` — the event-driven wrapper of
    the same datapath policy module.

Every engine tick, the receiver's congestion state (PFC pause asserted,
or the cache pool past its danger watermark) is routed into
``engine.set_network_pressure``: while the fabric squeezes the host,
no new decode lanes are admitted; when the incast burst completes and
the pool drains, admission resumes and the backlog clears.

  PYTHONPATH=src python examples/serving_on_fabric.py [--requests 16]

The second half sweeps a mixed Jet+DDIO fleet (``mixed_fleet_grid``)
with the vectorized fabric engine: shrinking the serving receiver's
pool raises escape-ladder ECN pressure, which throttles its senders'
DCQCN rates and stretches fleet incast FCT — the same loop, fleet-wide.
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCHS, tiny_config  # noqa: E402
from repro.core.jet import JetConfig, QoS  # noqa: E402
from repro.core.simulator import testbed_100g  # noqa: E402
from repro.fabric.hosts import ReceiverHost, SenderHost  # noqa: E402
from repro.fabric.scenarios import mixed_fleet_grid  # noqa: E402
from repro.fabric.switch import OutputPort, SwitchConfig  # noqa: E402
from repro.fabric.topology import Link  # noqa: E402
from repro.fabric.vector import run_fabric_sweep  # noqa: E402
from repro.models import api  # noqa: E402
from repro.parallel.sharding import single_device_ctx  # noqa: E402
from repro.serving.engine import (EngineConfig, Request,  # noqa: E402
                                  ServingEngine)

FABRIC_US_PER_ENGINE_TICK = 200     # 200 us of fabric per 1 ms engine tick
N_SENDERS = 8


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    # ---- the serving host's receive datapath behind a congested port -- #
    rcfg = testbed_100g("jet", pfc_enabled=True, jet_pool_bytes=1 << 20,
                        rnic_ecn_cnp=False)
    ticks_total = args.steps * FABRIC_US_PER_ENGINE_TICK
    rx = ReceiverHost(rcfg, sim_ticks=ticks_total)
    port = OutputPort(Link("leaf0", "serve0", rcfg.line_rate_gbps),
                      SwitchConfig(pfc_enabled=True))
    # incast burst: ~80% of the run's line-rate capacity, split evenly
    burst = rcfg.line_rate_gbps * 1e9 / 8.0 * ticks_total * 1e-6 \
        / N_SENDERS * 0.8
    senders = [SenderHost(line_rate_gbps=rcfg.line_rate_gbps,
                          burst_bytes=burst)
               for _ in range(N_SENDERS)]

    # ---- the decode engine on the same host --------------------------- #
    cfg = tiny_config(ARCHS["h2o-danube-1.8b"])
    ctx = single_device_ctx()
    params = api.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(
        cfg, EngineConfig(max_lanes=args.lanes, max_len=64), params, ctx,
        jet_cfg=JetConfig(pool_bytes=1 << 20))
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            req_id=i,
            prompt=rng.integers(2, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=6,
            qos=QoS.HIGH if i % 4 == 0 else QoS.NORMAL))

    print(f"{'step':>4} {'pool_free%':>10} {'pfc':>4} {'gate':>5} "
          f"{'waiting':>8} {'active':>7} {'done':>5}")
    dt = rcfg.dt_us
    for step in range(args.steps):
        # -- fabric sub-ticks: senders -> switch port -> receiver ------- #
        for _ in range(FABRIC_US_PER_ENGINE_TICK):
            port.paused = rx.pfc_paused
            batch = [(fid, b, 0.0, None, 0)
                     for fid, s in enumerate(senders)
                     if (b := s.offer(dt)) > 0.0]
            if batch:
                port.enqueue_batch(batch)
            arriving = sum(b for _, b, _ in port.drain(dt))
            fb = rx.step(arriving)
            if fb.cnps:
                # receiver CNPs throttle the heaviest sender
                heavy = max(range(N_SENDERS),
                            key=lambda i: senders[i].injected)
                for _ in range(fb.cnps):
                    senders[heavy].on_cnp()
        # -- backpressure gate: fabric congestion -> decode admission --- #
        avail = max(0.0, rx.dp.pool_cap - rx.dp.resident) / rx.dp.pool_cap
        squeezed = rx.pfc_paused or avail < rcfg.cache_safe
        engine.set_network_pressure(squeezed)
        engine.step()
        if step % 5 == 0 or (not engine.waiting and not engine.active):
            print(f"{step:>4} {avail * 100:>10.1f} "
                  f"{'on' if rx.pfc_paused else '-':>4} "
                  f"{'shut' if squeezed else 'open':>5} "
                  f"{len(engine.waiting):>8} {len(engine.active):>7} "
                  f"{len(engine.done):>5}")
        if not engine.waiting and not engine.active:
            break
    st = engine.jet.stats()
    print(f"served {len(engine.done)}/{args.requests}; jet stats: "
          f"fallbacks={st['memory_fallbacks']} queued={st['queued']} "
          f"escape={st['escape']}")

    # ---- fleet view: the same loop, vectorized over a mixed fleet ----- #
    print("\n--- mixed Jet+DDIO fleet sweep (pool size x burst):")
    scens, pts = mixed_fleet_grid(pool_mb=(2.0, 1.0, 0.5),
                                  burst_mb=(1.0, 2.0), sim_time_s=0.015)
    out = run_fabric_sweep(scens)
    print(f"  {'pool_mb':>8} {'burst_mb':>9} {'fct_us':>9} "
          f"{'jet_rx_gbps':>12} {'esc_ecn':>8} {'victim':>7}")
    for i, pt in enumerate(pts):
        fct = out["incast_completion_us"][i]
        print(f"  {pt['pool_mb']:>8.1f} {pt['burst_mb']:>9.1f} "
              f"{fct if np.isfinite(fct) else float('nan'):>9.0f} "
              f"{out['recv_goodput_gbps'][i][0]:>12.2f} "
              f"{out['recv_escape_ecn'][i][0]:>8.0f} "
              f"{out['victim_goodput_gbps'][i]:>7.1f}")
    print("  (smaller pool -> more escape ECN -> throttled senders -> "
        "longer incast FCT)")


if __name__ == "__main__":
    main()
