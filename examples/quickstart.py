"""Quickstart: the public API in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py [--arch chatglm3-6b]

1. pick an assigned architecture, shrink it to a CPU-sized config;
2. train a few steps (loss printed);
3. prefill + greedy-decode a few tokens;
4. drive the Jet receive service directly (the paper's §3.2 workflow).
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, tiny_config
from repro.configs.base import ShapeConfig
from repro.core.jet import JetConfig, JetService, QoS
from repro.data import pipeline
from repro.models import api
from repro.optim import adamw
from repro.parallel.sharding import single_device_ctx
from repro.train import steps as steps_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    cfg = tiny_config(ARCHS[args.arch])
    ctx = single_device_ctx()
    print(f"arch {args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model})")

    # --- 2. train ----------------------------------------------------------
    opt_cfg = adamw.OptConfig(lr=1e-3, total_steps=args.steps)
    state = steps_mod.init_state(cfg, opt_cfg, jax.random.key(0))
    step = jax.jit(steps_mod.make_train_step(cfg, ctx, opt_cfg, jnp.float32))
    data = pipeline.for_arch(cfg, ShapeConfig("q", "train", 128, 4))
    for i, batch in zip(range(args.steps), data):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step(state, batch)
        print(f"  step {i:3d}  loss {float(metrics['loss']):.4f}")

    # --- 3. prefill + decode ------------------------------------------------
    params = state["params"]
    prompt = jnp.arange(8, dtype=jnp.int32)[None, :] % cfg.vocab_size
    if cfg.num_codebooks:
        prompt = jnp.tile(prompt[:, None, :], (1, cfg.num_codebooks, 1))
    logits, dstate, lengths = api.prefill(params, cfg, ctx, prompt,
                                          max_len=64,
                                          compute_dtype=jnp.float32)
    toks = [int(jnp.argmax(logits[0]))]
    tok = jnp.full((1, cfg.num_codebooks) if cfg.num_codebooks else (1,),
                   toks[0], jnp.int32)
    for _ in range(8):
        logits, dstate = api.decode_step(params, cfg, ctx, dstate, tok,
                                         lengths,
                                         compute_dtype=jnp.float32)
        lengths = lengths + 1
        nxt = int(jnp.argmax(logits[0]))
        toks.append(nxt)
        tok = jnp.full_like(tok, nxt)
    print(f"greedy continuation: {toks}")

    # --- 4. the Jet service (paper §3.2) ------------------------------------
    jet = JetService(JetConfig(pool_bytes=12 << 20))
    jet.register(app_id=1, qos=QoS.HIGH)
    xid = jet.request(app_id=1, nbytes=1 << 20, now=0.0)   # 1 MB READ
    admitted = jet.pump(now=0.0)
    print(f"jet: admitted {len(admitted)} transfer(s), "
          f"pool available {jet.pool.available_bytes >> 20} MB")
    jet.complete(xid, now=1e-4)                            # swift recycle
    print(f"jet: after release, pool available "
          f"{jet.pool.available_bytes >> 20} MB; stats {jet.stats()}")


if __name__ == "__main__":
    main()
