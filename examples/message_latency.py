"""Verbs ops over the fabric: the msg-rate / latency view of the paper.

The paper's testbed numbers are *op-granular* — Mops for small messages,
GiB/s for large ones, and p99 message latency under load.  This example
reproduces that view on the fluid fabric: an 8-to-1 verbs incast where
every flow is a stream of fixed-size WRITE or SEND ops with a bounded
outstanding window, and the whole msg-size x window x verb x CC grid is
advanced as ONE vectorized program (``message_sweep_grid`` ->
``run_fabric_sweep``).

Things to watch in the output:

* small messages hit the per-op issue gap (the Mops plateau), large
  ones hit the wire (the GiB/s plateau) — the classic verbs crossover;
* SEND trails WRITE: every two-sided op pays the receiver completion
  cost on top of the wire time;
* deep windows buy throughput but park a standing queue under DCQCN —
  its p99 explodes while Timely/HPCC (the delay/INT controllers from
  the congestion-control zoo) hold the tail flat at the same window.

  PYTHONPATH=src python examples/message_latency.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.fabric.scenarios import message_sweep_grid  # noqa: E402
from repro.fabric.vector import run_fabric_sweep  # noqa: E402


def main() -> None:
    scens, points = message_sweep_grid(
        msg_kb=(4.0, 64.0, 1024.0), window=(1, 16), verb=("write", "send"),
        algo=("dcqcn", "timely", "hpcc"), sim_time_s=0.004)
    t0 = time.time()
    out = run_fabric_sweep(scens)      # one jax program, all 36 points
    dt = time.time() - t0
    print(f"--- message grid: {len(scens)} points "
          f"(msg-size x window x verb x CC) in {dt:.1f}s, one program\n")
    hdr = (f"{'cc':7s} {'verb':5s} {'msg':>6s} {'win':>4s}"
           f" {'Mops':>8s} {'GiB/s':>8s} {'p50 us':>9s} {'p99 us':>9s}")
    print(hdr)
    print("-" * len(hdr))
    for i, p in enumerate(points):
        kb = p["msg_kb"]
        size = f"{int(kb)}K" if kb < 1024 else f"{int(kb / 1024)}M"
        gib = out["msg_goodput_gbps"][i] / 8.0 * (1e9 / 2**30)
        print(f"{p['algo']:7s} {p['verb']:5s} {size:>6s} {p['window']:4d}"
              f" {out['msg_rate_mops'][i]:8.4f} {gib:8.2f}"
              f" {out['msg_p50_us'][i]:9.2f} {out['msg_p99_us'][i]:9.2f}")

    # the headline: same offered load, same window — the tail is the CC
    def p99(algo):
        return max(out["msg_p99_us"][i] for i, p in enumerate(points)
                   if p["algo"] == algo and p["window"] == 16
                   and p["verb"] == "write")
    print(f"\n--- deepest-window WRITE p99: dcqcn {p99('dcqcn'):.0f} us, "
          f"timely {p99('timely'):.0f} us, hpcc {p99('hpcc'):.0f} us")
    print("    (latency percentiles from the in-scan log-bucket "
          "histogram, within 4.6% of exact — see repro.fabric.messages)")


if __name__ == "__main__":
    main()
