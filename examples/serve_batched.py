"""End-to-end serving driver (the paper's kind: a receive-path service).

  PYTHONPATH=src python examples/serve_batched.py [--requests 24] [--lanes 6]

A batched serving engine whose admission control IS the Jet receive path:
prompts ride the READ path (windowed, fragment-granular admission against
the cache-resident pool), decode lanes are the recycled buffer pool, and
stuck sequences are handled by the escape ladder.  Prints per-request
latency and the Jet pool/escape statistics.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, tiny_config
from repro.core.jet import JetConfig, QoS
from repro.models import api
from repro.parallel.sharding import single_device_ctx
from repro.serving.engine import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b",
                    choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--lanes", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = tiny_config(ARCHS[args.arch])
    ctx = single_device_ctx()
    params = api.init_params(cfg, jax.random.key(0))
    engine = ServingEngine(
        cfg, EngineConfig(max_lanes=args.lanes, max_len=128), params, ctx,
        jet_cfg=JetConfig(pool_bytes=2 << 20, max_inflight_bytes=1 << 20))

    rng = np.random.default_rng(0)
    t0 = time.time()
    submit_t = {}
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        req = Request(req_id=i,
                      prompt=rng.integers(0, cfg.vocab_size, plen
                                          ).astype(np.int32),
                      max_new_tokens=args.max_new,
                      qos=QoS.HIGH if i % 4 == 0 else QoS.NORMAL)
        engine.submit(req)
        submit_t[i] = time.time()

    ticks = 0
    while (engine.active or engine.waiting) and ticks < 2000:
        engine.step()
        ticks += 1
        for rid, req in list(engine.done.items()):
            if rid in submit_t:
                lat = time.time() - submit_t.pop(rid)
                print(f"req {rid:3d} done: {len(req.generated)} tokens, "
                      f"{lat*1e3:7.1f} ms, qos={req.qos.name}")

    n_done = len(engine.done)
    dt = time.time() - t0
    print(f"\n{n_done}/{args.requests} requests served in {dt:.2f}s "
          f"({ticks} engine ticks)")
    print(f"jet stats: {engine.jet.stats()}")
    assert n_done == args.requests, "engine failed to drain all requests"


if __name__ == "__main__":
    main()
