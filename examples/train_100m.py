"""Train a ~100M-parameter model end to end with the fault-tolerant loop.

  PYTHONPATH=src python examples/train_100m.py --steps 300        # full run
  PYTHONPATH=src python examples/train_100m.py --steps 20 --ci    # smoke

The config is the xlstm-125m assignment's *transformer sibling* at ~100M
matmul params (12L, d=768, vocab 8192) so the run demonstrates the real
substrate: sharded data pipeline, AdamW(+schedule), remat, async
checkpointing, crash-resume (simulated preemption at --preempt-at), and the
straggler monitor.  On a host with N CPU devices a DxM mesh is used.
"""
import argparse
import dataclasses
import os


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    ap.add_argument("--preempt-at", type=int, default=0,
                    help="simulate preemption at this step, then resume")
    ap.add_argument("--ci", action="store_true",
                    help="shrink to a seconds-scale smoke run")
    ap.add_argument("--host-devices", type=int, default=0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.host_devices}")

    import jax

    from repro.configs import get_arch
    from repro.configs.base import ShapeConfig
    from repro.data import pipeline
    from repro.optim import adamw
    from repro.parallel.sharding import single_device_ctx
    from repro.train import loop as loop_mod
    from repro.launch.mesh import ctx_for_mesh, small_host_mesh

    base = get_arch("xlstm-125m")
    cfg = dataclasses.replace(
        base, name="lm-100m", xlstm=False, slstm_every=0, family="dense",
        num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
        vocab_size=8192, mlp="swiglu", subquadratic=False)
    if args.ci:
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=128,
                                  num_heads=4, num_kv_heads=4, head_dim=32,
                                  d_ff=256, vocab_size=512)
        args.steps = min(args.steps, 20)
        args.seq, args.batch = 64, 4
    total, _ = cfg.param_counts()
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"({total/1e6:.0f}M matmul params)")

    n_dev = len(jax.devices())
    if n_dev > 1:
        mesh = small_host_mesh(n_dev, model=2 if n_dev % 2 == 0 else 1)
        ctx = ctx_for_mesh(mesh, remat="dots")
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    else:
        mesh, ctx = None, single_device_ctx(remat="dots")

    shape = ShapeConfig("e2e", "train", args.seq, args.batch)
    opt_cfg = adamw.OptConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(10, args.steps // 20))
    loop_cfg = loop_mod.LoopConfig(
        total_steps=args.steps, ckpt_every=max(10, args.steps // 5),
        ckpt_dir=args.ckpt_dir, log_every=max(1, args.steps // 20))

    def fault(step: int):
        if args.preempt_at and step == args.preempt_at:
            args.preempt_at = 0            # fire once
            raise KeyboardInterrupt("simulated preemption")

    def run_once():
        data = pipeline.for_arch(cfg, shape)
        return loop_mod.run(cfg, ctx, opt_cfg, loop_cfg, data,
                            jax.random.key(0), fault_injector=fault)

    def run():
        try:
            out = run_once()
        except KeyboardInterrupt:
            print(">>> preempted; restarting from the latest checkpoint")
            out = run_once()               # resumes from ckpt + data cursor
        for h in out["history"]:
            print(f"step {h['step']:5d} loss {h['loss']:.4f} "
                  f"dt {h['dt']*1e3:6.0f}ms"
                  + (" [straggler]" if h["straggler"] else ""))
        print(f"final step {out['final_step']}, "
              f"stragglers flagged: {out['straggler_flags']}")
        first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
        print(f"loss {first:.3f} -> {last:.3f} "
              f"({(1 - last / first):.0%} reduction)")

    if mesh is not None:
        with mesh:
            run()
    else:
        run()


if __name__ == "__main__":
    main()
