"""Elastic scaling + crash recovery, end to end.

  PYTHONPATH=src python examples/elastic_restart.py

Trains on an 8-device (4x2) host mesh, "loses half the fleet" (simulated
preemption mid-run), restores the checkpoint onto a 4-device (2x2) mesh
with different shardings, finishes training there, and verifies the loss
trajectory continued — the elastic-rescale path a 1000-node deployment
needs when a pod drops out.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil                                          # noqa: E402

import jax                                             # noqa: E402
import numpy as np                                     # noqa: E402

from repro.configs import ARCHS, ShapeConfig, tiny_config  # noqa: E402
from repro.data import pipeline                        # noqa: E402
from repro.launch.mesh import ctx_for_mesh             # noqa: E402
from repro.optim import adamw                          # noqa: E402
from repro.train import loop as loop_mod               # noqa: E402

CKPT = "/tmp/repro_elastic"


def main() -> None:
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = tiny_config(ARCHS["gemma-7b"])
    shape = ShapeConfig("e", "train", 64, 8)
    opt_cfg = adamw.OptConfig(lr=3e-3, total_steps=60)

    # ---- phase 1: 8 devices (4 data x 2 model), preempt at step 25 ----
    devs = jax.devices()
    mesh8 = jax.make_mesh((4, 2), ("data", "model"), devices=devs[:8])
    ctx8 = ctx_for_mesh(mesh8)

    def preempt(step):
        if step == 25:
            raise KeyboardInterrupt("simulated pod loss")

    print("phase 1: training on 8 devices (4x2)")
    try:
        with mesh8:
            loop_mod.run(cfg, ctx8, opt_cfg,
                         loop_mod.LoopConfig(total_steps=60, ckpt_every=10,
                                             ckpt_dir=CKPT, log_every=10),
                         pipeline.for_arch(cfg, shape), jax.random.key(0),
                         fault_injector=preempt)
    except KeyboardInterrupt:
        print(">>> preempted at step 25; checkpoint committed")

    # ---- phase 2: resume on 4 devices (2x2) — half the fleet ----------
    mesh4 = jax.make_mesh((2, 2), ("data", "model"), devices=devs[:4])
    ctx4 = ctx_for_mesh(mesh4)
    print("phase 2: resuming on 4 devices (2x2)")
    with mesh4:
        out = loop_mod.run(cfg, ctx4, opt_cfg,
                           loop_mod.LoopConfig(total_steps=60,
                                               ckpt_every=20,
                                               ckpt_dir=CKPT,
                                               log_every=10),
                           pipeline.for_arch(cfg, shape),
                           jax.random.key(0))
    for h in out["history"]:
        print(f"  step {h['step']:3d} loss {h['loss']:.4f}")
    assert out["final_step"] == 60
    losses = [h["loss"] for h in out["history"]]
    print(f"resumed at step >25 and finished at 60; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not keep improving"
    print("elastic restart OK: 8 -> 4 devices, training continued")


if __name__ == "__main__":
    main()
