"""Incast over a Clos fabric: the fleet-level view of Jet vs DDIO.

Eight storage senders on one leaf burst 1 MB each into a receiver on
another leaf while a victim flow (same source leaf, different receiver)
streams open-loop.  Run twice — lossy/ECN and PFC/lossless — and watch the
classic trade-off: PFC protects the incast from drops but the pause frames
fan out across the fabric and flatten the victim flow (head-of-line
blocking), exactly the §2.1 pathology that motivates RDCA's receiver-side
relief valve.

  PYTHONPATH=src python examples/fabric_incast.py
"""
import sys

sys.path.insert(0, "src")

from repro.fabric import scenarios  # noqa: E402


def show(title, r):
    rx = r.per_host["h1_0"]
    print(f"--- {title}")
    print(f"  incast completion     : {r.incast_completion_us:9.1f} us")
    print(f"  receiver goodput      : {rx.goodput_gbps:9.1f} Gbps")
    print(f"  victim-flow goodput   : {r.victim_goodput_gbps:9.1f} Gbps")
    print(f"  pause fan-out (links) : {r.pause_fanout:9d}")
    print(f"  ECN-marked            : {r.ecn_marked_bytes / 1e6:9.2f} MB")
    print(f"  switch drops          : {r.switch_dropped_bytes / 1e6:9.2f}"
          " MB")


def main() -> None:
    for mode in ("jet", "ddio"):
        for pfc in (False, True):
            sc = scenarios.incast(n_senders=8, mode=mode, pfc=pfc,
                                  burst_mb=1.0, sim_time_s=0.02)
            show(f"incast-8 {mode}{' + PFC' if pfc else ' (lossy)'}",
                 sc.run())
    print("\nTakeaway: PFC trades drops for fabric-wide pauses; Jet's "
          "receiver-side cache relief keeps the incast fast without "
          "leaning on either.")


if __name__ == "__main__":
    main()
