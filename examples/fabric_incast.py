"""Incast over a Clos fabric: the fleet-level view of Jet vs DDIO.

Eight storage senders on one leaf burst 1 MB each into a receiver on
another leaf while a victim flow (same source leaf, different receiver)
streams open-loop.  Run twice — lossy/ECN and PFC/lossless — and watch the
classic trade-off: PFC protects the incast from drops but the pause frames
fan out across the fabric and flatten the victim flow (head-of-line
blocking), exactly the §2.1 pathology that motivates RDCA's receiver-side
relief valve.

The second half re-runs the experiment as a *grid*: burst size x mode x
PFC, all advanced at once by the vectorized fabric engine
(``run_fabric_sweep`` — one jax vmap+scan program over every point)
instead of one scalar ``run_fabric`` loop per point.

  PYTHONPATH=src python examples/fabric_incast.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.fabric import scenarios  # noqa: E402
from repro.fabric.scenarios import fabric_grid  # noqa: E402
from repro.fabric.vector import run_fabric_sweep  # noqa: E402


def show(title, r):
    rx = r.per_host["h1_0"]
    print(f"--- {title}")
    print(f"  incast completion     : {r.incast_completion_us:9.1f} us")
    print(f"  receiver goodput      : {rx.goodput_gbps:9.1f} Gbps")
    print(f"  victim-flow goodput   : {r.victim_goodput_gbps:9.1f} Gbps")
    print(f"  pause fan-out (links) : {r.pause_fanout:9d}")
    print(f"  ECN-marked            : {r.ecn_marked_bytes / 1e6:9.2f} MB")
    print(f"  switch drops          : {r.switch_dropped_bytes / 1e6:9.2f}"
          " MB")


def grid_demo() -> None:
    bursts = [0.5, 1.0, 2.0, 4.0]
    scens, points = fabric_grid(
        lambda mode, pfc, burst_mb: scenarios.incast(
            n_senders=8, mode=mode, pfc=pfc, burst_mb=burst_mb,
            sim_time_s=0.02),
        mode=["jet", "ddio"], pfc=[False, True], burst_mb=bursts)
    t0 = time.time()
    out = run_fabric_sweep(scens)          # one program, all 16 points
    dt = time.time() - t0
    print(f"\n--- vectorized grid: {len(scens)} incast-8 scenarios in "
          f"{dt:.1f}s (one vmap+scan program)")
    print(f"  {'burst':>6} {'mode':>5} {'pfc':>5} {'fct_us':>9} "
          f"{'victim_gbps':>12} {'fanout':>7}")
    for i, pt in enumerate(points):
        print(f"  {pt['burst_mb']:>6.1f} {pt['mode']:>5} "
              f"{str(pt['pfc']):>5} {out['incast_completion_us'][i]:>9.0f} "
              f"{out['victim_goodput_gbps'][i]:>12.1f} "
              f"{out['pause_fanout'][i]:>7d}")


def main() -> None:
    for mode in ("jet", "ddio"):
        for pfc in (False, True):
            sc = scenarios.incast(n_senders=8, mode=mode, pfc=pfc,
                                  burst_mb=1.0, sim_time_s=0.02)
            show(f"incast-8 {mode}{' + PFC' if pfc else ' (lossy)'}",
                 sc.run())
    grid_demo()
    print("\nTakeaway: PFC trades drops for fabric-wide pauses; Jet's "
          "receiver-side cache relief keeps the incast fast without "
          "leaning on either.")


if __name__ == "__main__":
    main()
