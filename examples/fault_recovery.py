"""Loss recovery under fault injection: go-back-N vs selective retransmit.

The last mile of the paper's argument assumes a lossless fabric — PFC
holds packets back instead of dropping them.  Real deployments run PFC
off (or per-priority) and eat stochastic loss: cut through a lossy link
and RDMA's go-back-N replays the whole window per drop, which is why
IRN-style selective retransmit is the standard fix.  This example puts
numbers on that gap with the fault layer (``repro.fabric.faults``):

* an 8-to-1 verbs incast where every link drops a stochastic fraction
  of its ticks (counter-based hash — the same loss realization hits the
  scalar, numpy and jax engines tick-for-tick);
* the loss-rate x recovery-mode grid runs as ONE vectorized program
  (``lossy_incast_grid`` -> ``run_fabric_sweep``): go-back-N's p999
  and retransmitted bytes blow up with loss while selective stays
  near the lossless baseline;
* a NIC crash--restart: the receiver dies mid-incast, its admission
  state zeroes, in-flight arrivals are discarded until restart — and
  every sender's RTO ledger replays the lost span, so all flows still
  complete (``crash_recovery_us`` stamps the first accepted byte).

  PYTHONPATH=src python examples/fault_recovery.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.fabric.faults import FaultConfig  # noqa: E402
from repro.fabric.scenarios import lossy_incast, lossy_incast_grid  # noqa: E402
from repro.fabric.vector import run_fabric_sweep  # noqa: E402


def main() -> None:
    # ---- loss-rate x recovery grid, one vectorized program ----------- #
    rates = (0.0, 0.005, 0.02)
    scens, points = lossy_incast_grid(
        loss_rate=rates, recovery=("go_back_n", "selective"),
        sim_time_s=0.002)
    t0 = time.time()
    out = run_fabric_sweep(scens)
    dt = time.time() - t0
    print(f"--- lossy incast grid: {len(scens)} points "
          f"(loss-rate x recovery) in {dt:.1f}s, one program\n")
    hdr = (f"{'recovery':10s} {'loss':>6s} {'msgs':>6s} {'p99 us':>9s}"
           f" {'p999 us':>9s} {'retx MB':>9s} {'lost pkts':>10s}")
    print(hdr)
    print("-" * len(hdr))
    for i, p in enumerate(points):
        print(f"{p['recovery']:10s} {p['loss_rate']:6.3f}"
              f" {out['msg_count_total'][i]:6.0f}"
              f" {out['msg_p99_us'][i]:9.1f} {out['msg_p999_us'][i]:9.1f}"
              f" {out['retransmit_bytes'][i] / 1e6:9.2f}"
              f" {out['dropped_pkts'][i]:10.1f}")

    def p999(rec, rate):
        return next(out["msg_p999_us"][i] for i, p in enumerate(points)
                    if p["recovery"] == rec and p["loss_rate"] == rate)
    worst = max(rates)
    print(f"\n--- p999 at {worst:.0%} loss: "
          f"go-back-N {p999('go_back_n', worst):.0f} us vs "
          f"selective {p999('selective', worst):.0f} us — replaying only "
          f"the lost span keeps the tail near the lossless baseline "
          f"({p999('selective', 0.0):.0f} us)")

    # ---- NIC crash--restart: liveness through a dead receiver -------- #
    sc = lossy_incast(loss_rate=0.005, recovery="selective",
                      sim_time_s=0.002)
    sc.fabric.faults = FaultConfig(loss_rate=0.005, seed=7).crash(
        "h1_0", at_us=400.0, restart_us=600.0)
    r = sc.run()
    print(f"\n--- crash--restart: receiver h1_0 dies at 400 us, "
          f"restarts at 600 us")
    print(f"    first byte re-accepted {r.crash_recovery_us['h1_0']:.0f} us "
          f"after the crash; {sum(len(v) for v in r.msg_latency_us.values())}"
          f" messages still completed "
          f"({r.retransmit_bytes / 1e6:.1f} MB replayed)")


if __name__ == "__main__":
    main()
