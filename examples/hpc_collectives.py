"""Jet staged collectives on an 8-device host mesh (the paper's §6.4 story
mapped to TPU: keep the gathered operand out of HBM).

  PYTHONPATH=src python examples/hpc_collectives.py

Runs the three Jet collective primitives against their XLA one-shot
equivalents, verifies numerics, and prints the compiled per-device
collective bytes + temp memory of each — the structural evidence that the
ring-staged version never materializes the gathered tensor.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                            # noqa: E402
import jax.numpy as jnp                               # noqa: E402
import numpy as np                                    # noqa: E402
from jax.sharding import PartitionSpec as P           # noqa: E402

from repro.launch import hlo_analysis                 # noqa: E402
from repro.parallel import collectives as coll        # noqa: E402
from repro.parallel.compat import shard_map           # noqa: E402

M = 8
MESH = jax.make_mesh((M,), ("model",))


def report(name, fn, in_specs, args, want, out_specs=P()):
    sm = shard_map(fn, mesh=MESH, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    jitted = jax.jit(sm)
    got = jitted(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    compiled = jitted.lower(*args).compile()
    deep = hlo_analysis.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    temp = getattr(mem, "temp_size_in_bytes", -1)
    counts = {k: v for k, v in deep["coll_counts"].items() if v}
    print(f"{name:34s} coll_bytes/dev={deep['coll_total']/1e6:8.3f} MB  "
          f"temp={temp/1e6:8.3f} MB  ops={counts}")
    return got


def main() -> None:
    key = jax.random.key(0)
    d, f, b = 4096, 512, 16
    x = jax.random.normal(key, (b, d), jnp.float32)
    w = jax.random.normal(jax.random.key(1), (d, f), jnp.float32)
    want = x @ w

    print("— allgather-matmul: y = x @ W, W row-sharded over 8 devices —")
    report("xla: all_gather(W) @ x",
           lambda xx, ww: xx @ jax.lax.all_gather(ww, "model", axis=0,
                                                  tiled=True),
           (P(), P("model", None)), (x, w), want)
    report("jet: ring staged (frags=2)",
           lambda xx, ww: coll.ring_allgather_matmul(xx, ww, "model", M,
                                                     frags=2),
           (P(), P("model", None)), (x, w), want)

    print("\n— reduce-scatter of per-rank partials [8, 16, 4096] —")
    y = jax.random.normal(jax.random.key(2), (M, b, d), jnp.float32)
    full = np.asarray(y.sum(axis=0))
    want_stack = np.concatenate(
        [full[:, r * (d // M):(r + 1) * (d // M)] for r in range(M)], axis=0)
    report("xla: psum_scatter",
           lambda yy: jax.lax.psum_scatter(yy[0], "model",
                                           scatter_dimension=1, tiled=True),
           (P("model", None, None),), (y,), want_stack, P("model"))
    report("jet: ring reduce-scatter",
           lambda yy: coll.ring_reduce_scatter(yy[0], "model", M),
           (P("model", None, None),), (y,), want_stack, P("model"))

    print("\n— windowed all-gather (the READ path: <=window in flight) —")
    xs = jax.random.normal(jax.random.key(3), (64, 128), jnp.float32)
    report("xla: one-shot all_gather",
           lambda v: jax.lax.all_gather(v, "model", axis=0, tiled=True),
           (P("model", None),), (xs,), xs)
    report("jet: windowed (window=4)",
           lambda v: coll.windowed_allgather(v, "model", M, window=4),
           (P("model", None),), (xs,), xs)
    print("\nall numerics verified against XLA one-shot equivalents")


if __name__ == "__main__":
    main()
