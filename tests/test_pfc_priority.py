"""Per-priority PFC (per-TC switch queues): the ISSUE 4 contract.

Three layers of evidence that the classed switch model is both *correct*
and *worth having*:

1. **Golden regression** — a single-TC workload under the per-TC switch
   is bit-equal (scalar) to the pre-refactor per-link pause driver.  The
   literals below were captured from the scalar driver at the commit
   before the per-TC refactor (``incast`` and ``mixed_fleet`` with
   ``pfc_enabled``); the legacy ``per_tc=False`` mode must reproduce
   them too, and the vector engines must stay inside their PR 2 bounds
   (numpy ~1e-13, jax <= 5e-4) while agreeing with each other across
   the per-TC/per-link flag.

2. **Hypothesis properties** — (a) HoL isolation: pausing the incast
   class never pulls an uncongested victim class below its no-incast
   baseline (minus tolerance) on random fabrics; (b) engine
   equivalence: random multi-class fabrics with PFC agree between the
   scalar driver and the numpy backend.  Example counts follow the
   ``FABRIC_TEST_EXAMPLES`` env var (CI fast tier keeps the default;
   the ``slow`` job raises it).

3. **Isolation acceptance** — in ``qos_mixed_storage`` the non-incast
   classes' goodput under per-TC PFC is >= 2x their goodput under the
   legacy per-link pause, while the LOW class exercises the §5 DRAM
   spill at fleet scale.
"""
import math
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.datapath import QoS
from repro.fabric import scenarios as SC
from repro.fabric import topology
from repro.fabric.fabric import FabricConfig, Flow, run_fabric
from repro.fabric.switch import N_TC, OutputPort, SwitchConfig
from repro.fabric.vector import run_fabric_sweep

EXAMPLES = int(os.environ.get("FABRIC_TEST_EXAMPLES", "2"))
# the slow-marked deep variants also follow the env var (CI's slow job
# raises it), but never drop below their own floor
DEEP_EXAMPLES = max(30, EXAMPLES)
SIM_S = 0.015

# --------------------------------------------------------------------------- #
# golden literals: scalar run_fabric at the commit *before* the per-TC
# switch refactor (per-link pause), sim_time_s=0.015, dt=1us
# --------------------------------------------------------------------------- #
GOLDEN = {
    "incast8_jet_pfc": dict(
        goodput=[0.5333333333333341, 0.5333333333333319,
                 0.5333333333333341, 0.5333333333333319,
                 0.5333333333333341, 0.5333333333333319,
                 0.5333333333333341, 0.5333333333333357,
                 7.886817239667703],
        completion=[320.0] * 8 + [math.inf],
        pause_fanout=3,
        pause_link_us={("leaf0", "spine0"): 127.0,
                       ("spine0", "leaf1"): 160.0,
                       ("spine1", "leaf1"): 160.0},
        ecn_marked=10185267.893679425,
        victim=7.886817239667703,
        incast_fct=320.0,
    ),
    "incast8_ddio_pfc": dict(
        goodput=[0.5333333333333324, 0.533333333333333,
                 0.5333333333333341, 0.533333333333333,
                 0.5333333333333341, 0.533333333333333,
                 0.5333333333333341, 0.5333333333333338,
                 3.359098529481528],
        completion=[481.0] + [402.0] * 7 + [math.inf],
        pause_fanout=3,
        pause_link_us={("leaf0", "spine0"): 164.0,
                       ("spine0", "leaf1"): 200.0,
                       ("spine1", "leaf1"): 200.0},
        ecn_marked=10251117.670557445,
        victim=3.359098529481528,
        incast_fct=481.0,
    ),
    "mixed_fleet_pfc": dict(
        goodput=[0.5333333333333341, 0.5333333333333319,
                 0.5333333333333341, 0.5333333333333319,
                 0.5333333333333341, 0.5333333333333319,
                 0.5333333333333341, 0.5333333333333357,
                 3.296417023565302],
        completion=[320.0] * 8 + [math.inf],
        pause_fanout=3,
        pause_link_us={("leaf0", "spine0"): 127.0,
                       ("spine0", "leaf1"): 160.0,
                       ("spine1", "leaf1"): 160.0},
        ecn_marked=10167082.46982359,
        victim=3.296417023565302,
        incast_fct=320.0,
    ),
}


def _golden_scenario(key, per_tc=True):
    if key == "incast8_jet_pfc":
        sc = SC.incast(n_senders=8, mode="jet", pfc=True, burst_mb=1.0,
                       sim_time_s=SIM_S)
    elif key == "incast8_ddio_pfc":
        sc = SC.incast(n_senders=8, mode="ddio", pfc=True, burst_mb=1.0,
                       sim_time_s=SIM_S)
    else:
        sc = SC.mixed_fleet(pfc=True, sim_time_s=SIM_S)
    sc.fabric.switch.per_tc = per_tc
    return sc


def _check_scalar_golden(r, g):
    F = len(g["goodput"])
    assert [r.flow_goodput_gbps[f] for f in range(F)] == g["goodput"]
    assert [r.flow_completion_us[f] for f in range(F)] == g["completion"]
    assert r.pause_fanout == g["pause_fanout"]
    assert r.pause_link_us == g["pause_link_us"]
    assert r.ecn_marked_bytes == g["ecn_marked"]
    assert r.victim_goodput_gbps == g["victim"]
    assert r.incast_completion_us == g["incast_fct"]


# one golden key stays in the fast tier as the bit-equality smoke;
# the full key set rides the slow job
@pytest.mark.parametrize("key", [
    "incast8_ddio_pfc",
    pytest.param("incast8_jet_pfc", marks=pytest.mark.slow),
    pytest.param("mixed_fleet_pfc", marks=pytest.mark.slow)])
def test_scalar_single_tc_bit_equal_to_pre_refactor(key):
    """Classed switch, single-TC workload: bit-equal to the per-link
    driver the refactor replaced — in both pause modes."""
    _check_scalar_golden(_golden_scenario(key).run(), GOLDEN[key])
    _check_scalar_golden(_golden_scenario(key, per_tc=False).run(),
                         GOLDEN[key])


@pytest.mark.parametrize("key", [
    "incast8_ddio_pfc",
    pytest.param("incast8_jet_pfc", marks=pytest.mark.slow),
    pytest.param("mixed_fleet_pfc", marks=pytest.mark.slow)])
def test_scalar_per_tc_pause_breakdown_single_tc(key):
    """With one TC in use, the per-priority breakdown carries the whole
    pause budget on that class and sums back to pause_link_us."""
    r = _golden_scenario(key).run()
    assert all(tc == int(QoS.NORMAL) for _, tc in r.pause_tc_us)
    for lk, us in r.pause_link_us.items():
        assert r.pause_tc_us[(lk, int(QoS.NORMAL))] == us
    r_legacy = _golden_scenario(key, per_tc=False).run()
    assert all(tc == 0 for _, tc in r_legacy.pause_tc_us)


@pytest.fixture(scope="module")
def single_tc_grid():
    """incast-8 jet/pfc at both pause granularities in ONE sweep grid
    (per_tc is a per-point parameter), plus both vector backends."""
    scens = [_golden_scenario("incast8_jet_pfc", per_tc=True),
             _golden_scenario("incast8_jet_pfc", per_tc=False)]
    out_np = run_fabric_sweep(scens, backend="numpy")
    out_jx = run_fabric_sweep(scens, backend="jax")
    return out_np, out_jx


def _maxrel(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    m = np.isfinite(a) & np.isfinite(b)
    assert (np.isfinite(a) == np.isfinite(b)).all()
    if not m.any():
        return 0.0
    return float(np.max(np.abs(a[m] - b[m])
                        / np.maximum(np.abs(b[m]), 1e-9)))


@pytest.mark.slow
def test_vector_single_tc_equivalent_to_per_link(single_tc_grid):
    """1-TC == old per-link pause in the vector engines: the per-TC and
    legacy grid points agree with each other and with the pre-refactor
    scalar goldens (numpy ~1e-13, jax <= 5e-4)."""
    out_np, out_jx = single_tc_grid
    g = GOLDEN["incast8_jet_pfc"]
    for out, tol in ((out_np, 1e-13), (out_jx, 5e-4)):
        # the two pause granularities are indistinguishable on 1 TC
        assert _maxrel(out["flow_goodput_gbps"][0],
                       out["flow_goodput_gbps"][1]) <= tol
        assert _maxrel(out["flow_completion_us"][0],
                       out["flow_completion_us"][1]) <= tol
        np.testing.assert_array_equal(out["pause_fanout"][0],
                                      out["pause_fanout"][1])
        # ...and both reproduce the pre-refactor scalar numbers
        for i in range(2):
            assert _maxrel(out["flow_goodput_gbps"][i],
                           g["goodput"]) <= tol
            assert _maxrel(out["flow_completion_us"][i],
                           g["completion"]) <= tol
            assert out["pause_fanout"][i] == g["pause_fanout"]
            assert _maxrel(out["victim_goodput_gbps"][i],
                           g["victim"]) <= tol
    # per-TC pause budget sits on the (single) NORMAL class in the
    # classed point and on TC 0 in the legacy point, same total
    tc_np = single_tc_grid[0]["pause_tc_total_us"]
    assert tc_np[0, int(QoS.NORMAL)] == tc_np[1, 0] > 0
    assert tc_np[0, [0, 2]].sum() == tc_np[1, 1:].sum() == 0.0


@pytest.mark.slow
def test_vector_single_tc_golden_mixed_fleet():
    """Same 1-TC == per-link contract on the closed-loop mixed_fleet
    scenario (escape-ladder CNPs active), vs the pre-refactor goldens."""
    scens = [_golden_scenario("mixed_fleet_pfc", per_tc=True),
             _golden_scenario("mixed_fleet_pfc", per_tc=False)]
    g = GOLDEN["mixed_fleet_pfc"]
    # numpy: 15000 closed-loop ticks accumulate a few ulps more drift
    # than the incast grid (matmul class totals vs scalar running sums)
    for backend, tol in (("numpy", 5e-13), ("jax", 5e-4)):
        out = run_fabric_sweep(scens, backend=backend)
        for i in range(2):
            assert _maxrel(out["flow_goodput_gbps"][i],
                           g["goodput"]) <= tol, backend
            assert _maxrel(out["flow_completion_us"][i],
                           g["completion"]) <= tol, backend
            assert out["pause_fanout"][i] == g["pause_fanout"], backend
            assert _maxrel(out["victim_goodput_gbps"][i],
                           g["victim"]) <= tol, backend


# --------------------------------------------------------------------------- #
# switch-unit mechanics of the classed port
# --------------------------------------------------------------------------- #
def _port(**kw):
    cfg = SwitchConfig(port_buffer_bytes=1 << 20, **kw)
    return OutputPort(topology.Link("a", "b", 80.0), cfg)


def test_port_per_class_buffer_partition():
    """Each class owns a full port_buffer_bytes partition: one class
    filling its FIFO drops, the others still have room."""
    p = _port()
    assert p.enqueue(0, 3 << 20, 0.0, None, tc=2) == pytest.approx(2 << 20)
    assert p.tc_bytes(2) == pytest.approx(1 << 20)
    # LOW is full; HIGH still takes a full buffer without dropping
    assert p.enqueue(1, 1 << 20, 0.0, None, tc=0) == 0.0
    assert p.queued_bytes == pytest.approx(2 << 20)


def test_port_strict_priority_drain():
    p = _port()
    p.enqueue(0, 500 << 10, 0.0, None, tc=2)      # LOW
    p.enqueue(1, 500 << 10, 0.0, None, tc=0)      # HIGH
    out = dict((fid, b) for fid, b, _ in p.drain(10.0))
    # 80 Gbps * 10 us = 100 KB: all of it goes to HIGH
    assert out[1] == pytest.approx(1e5)
    assert 0 not in out


def test_port_paused_class_keeps_bytes_others_drain():
    p = _port()
    p.enqueue(0, 500 << 10, 0.0, None, tc=0)      # HIGH
    p.enqueue(1, 500 << 10, 0.0, None, tc=2)      # LOW
    p.paused_tcs = frozenset({0})                 # downstream paused HIGH
    out = dict((fid, b) for fid, b, _ in p.drain(10.0))
    assert 0 not in out                           # HIGH held back
    assert out[1] == pytest.approx(1e5)           # LOW unaffected
    assert p.pause_us == 10.0


def test_port_per_tc_knee_and_watermark_overrides():
    p = _port(ecn_kmin_frac=0.5,
              tc_ecn_kmin_frac=(0.5, 0.1, 0.5),
              pfc_enabled=True, pfc_xoff_frac=0.9,
              tc_pfc_xoff_frac=(0.9, 0.2, 0.9),
              tc_pfc_xon_frac=(0.45, 0.1, 0.45))
    # 300 KB on NORMAL: past its 0.1 knee (102 KB), under the others'
    p.enqueue(0, 300 << 10, 0.0, ("x", "a"), tc=1)
    p.enqueue(0, 300 << 10, 0.0, ("x", "a"), tc=1)
    assert p.marked_bytes == pytest.approx(300 << 10)
    p.enqueue(1, 300 << 10, 0.0, ("y", "a"), tc=0)
    p.enqueue(1, 300 << 10, 0.0, ("y", "a"), tc=0)
    assert p.marked_bytes == pytest.approx(300 << 10)   # HIGH knee not hit
    p.update_pfc()
    assert p.tc_asserted == [False, True, False]
    assert p.pause_targets() == {(("x", "a"), 1)}


# --------------------------------------------------------------------------- #
# isolation acceptance: per-TC pause vs legacy per-link pause
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def qos_mixed_pair():
    per_tc = SC.qos_mixed_storage(per_tc=True).run()
    legacy = SC.qos_mixed_storage(per_tc=False).run()
    return per_tc, legacy


def test_qos_mixed_per_tc_isolates_victim_classes(qos_mixed_pair):
    """ISSUE 4 acceptance: the non-incast classes keep >= 2x the goodput
    per-priority pause grants them vs the legacy whole-link pause."""
    per_tc, legacy = qos_mixed_pair
    for tag in ("oltp", "olap"):
        assert per_tc.has_tag(tag) and legacy.has_tag(tag)
        assert per_tc.tagged_goodput(tag) >= 2.0 * legacy.tagged_goodput(tag)
    # the bulk class itself is pause-bound either way, not helped
    assert per_tc.tagged_goodput("incast") == \
        pytest.approx(legacy.tagged_goodput("incast"), rel=0.2)


def test_qos_mixed_pause_stays_on_the_bulk_class(qos_mixed_pair):
    per_tc, legacy = qos_mixed_pair
    assert {tc for _, tc in per_tc.pause_tc_us} == {int(QoS.LOW)}
    assert {tc for _, tc in legacy.pause_tc_us} == {0}
    assert sum(per_tc.pause_tc_us.values()) > 0


def test_qos_mixed_low_spill_at_fleet_scale(qos_mixed_pair):
    """The squeezed Jet receiver pushes the LOW bulk class through the
    §5 DRAM spill path while per-TC pause keeps the fabric classes
    isolated — admission QoS and switch QoS working together."""
    per_tc, _ = qos_mixed_pair
    assert per_tc.per_host["h1_0"].mem_fallback_bytes > 0


@pytest.mark.slow
def test_qos_mixed_grid_vector_matches_scalar(qos_mixed_pair):
    per_tc, legacy = qos_mixed_pair
    scens, pts = SC.qos_mixed_grid()        # per_tc x pool grid
    order = [pt["per_tc"] for pt in pts]
    ref = {True: per_tc, False: legacy}
    F = len(scens[0].flows)
    gp = np.array([[ref[o].flow_goodput_gbps[f] for f in range(F)]
                   for o in order])
    out_np = run_fabric_sweep(scens, backend="numpy")
    out_jx = run_fabric_sweep(scens, backend="jax")
    assert _maxrel(out_np["flow_goodput_gbps"], gp) <= 1e-12
    assert _maxrel(out_jx["flow_goodput_gbps"], gp) <= 5e-4
    for i, o in enumerate(order):
        per_cls = [sum(v for (lk, tc), v in ref[o].pause_tc_us.items()
                       if tc == q) for q in range(N_TC)]
        np.testing.assert_allclose(out_np["pause_tc_total_us"][i], per_cls)


# --------------------------------------------------------------------------- #
# property: HoL isolation on random fabrics
# --------------------------------------------------------------------------- #
def _hol_isolation_case(n_bulk, bulk_gbps, vic_gbps, cls_pick, buf_kb):
    """Pausing the bulk class must not pull an uncongested victim class
    below its no-incast baseline (HoL-isolation invariant)."""
    pairs = [(a, b) for a in range(N_TC) for b in range(N_TC) if a != b]
    bulk_cls, vic_cls = pairs[cls_pick % len(pairs)]
    topo = topology.incast_fabric(n_bulk + 1, host_gbps=100.0,
                                  uplink_gbps=800.0)

    def flows(bulk_start):
        fl = [Flow(src=f"h0_{i}", dst="h1_0", offered_gbps=bulk_gbps,
                   start_us=bulk_start, qos=QoS(bulk_cls), tag="incast")
              for i in range(n_bulk)]
        # the victim rides its own source host and receiver: only the
        # fabric links (and their pause state) couple it to the incast
        fl.append(Flow(src=f"h0_{n_bulk}", dst="h1_1",
                       offered_gbps=vic_gbps, qos=QoS(vic_cls),
                       tag="victim"))
        return fl

    sim_s = 0.0015
    fcfg = FabricConfig(
        sim_time_s=sim_s,
        switch=SwitchConfig(pfc_enabled=True, ecn_enabled=False,
                            port_buffer_bytes=buf_kb << 10))
    mk = lambda start: SC.Scenario(        # noqa: E731
        name="hol", topology=topo, flows=flows(start), fabric=fcfg)
    # baseline grid point: the bulk class never starts
    out = run_fabric_sweep([mk(0.0), mk(sim_s * 1e6 + 1.0)],
                           backend="numpy")
    incast_run, baseline = (out["victim_goodput_gbps"][i] for i in (0, 1))
    # the incast point must actually engage PFC, else this is vacuous
    assert out["pause_fanout"][0] >= 1
    assert out["pause_tc_total_us"][0, bulk_cls] > 0
    # ...but never by pausing the victim's class...
    assert out["pause_tc_total_us"][0, vic_cls] == 0.0
    # ...so the victim keeps its baseline goodput (8% tolerance for
    # shared-link scheduling noise)
    assert incast_run >= baseline * 0.92
    assert baseline > 0


@pytest.mark.slow
@settings(max_examples=EXAMPLES, deadline=None)
@given(st.integers(3, 5), st.integers(50, 70), st.integers(5, 35),
       st.integers(0, 5), st.integers(256, 640))
def test_hol_isolation_property(n_bulk, bulk_gbps, vic_gbps, cls_pick,
                                buf_kb):
    _hol_isolation_case(n_bulk, float(bulk_gbps), float(vic_gbps),
                        cls_pick, buf_kb)


@pytest.mark.slow
@settings(max_examples=DEEP_EXAMPLES, deadline=None)
@given(st.integers(3, 5), st.integers(50, 70), st.integers(5, 35),
       st.integers(0, 5), st.integers(256, 640))
def test_hol_isolation_property_deep(n_bulk, bulk_gbps, vic_gbps,
                                     cls_pick, buf_kb):
    _hol_isolation_case(n_bulk, float(bulk_gbps), float(vic_gbps),
                        cls_pick, buf_kb)


# --------------------------------------------------------------------------- #
# property: classed engines agree on random multi-class fabrics
# --------------------------------------------------------------------------- #
def _equivalence_case(n_leaves, per_leaf, n_spines, flow_specs):
    topo = topology.clos(n_leaves=n_leaves, hosts_per_leaf=per_leaf,
                         n_spines=n_spines, host_gbps=100.0,
                         uplink_gbps=200.0)
    hosts = topo.hosts
    flows = []
    for si, di, load, qos in flow_specs:
        src, dst = hosts[si % len(hosts)], hosts[di % len(hosts)]
        if src == dst:
            dst = hosts[(di + 1) % len(hosts)]
            if src == dst:
                continue
        flows.append(Flow(src=src, dst=dst,
                          offered_gbps=None if load == 0 else 25.0 * load,
                          qos=QoS(qos % N_TC), tag="t"))
    if not flows:
        return
    fcfg = FabricConfig(sim_time_s=0.0006,
                        switch=SwitchConfig(pfc_enabled=True,
                                            port_buffer_bytes=1 << 18))
    ref = run_fabric(topo, flows, fcfg)
    sc = SC.Scenario(name="rand", topology=topo, flows=flows, fabric=fcfg)
    out = run_fabric_sweep([sc], backend="numpy")
    F = len(flows)
    gp_ref = np.array([ref.flow_goodput_gbps[f] for f in range(F)])
    assert np.allclose(out["flow_goodput_gbps"][0], gp_ref,
                       rtol=1e-9, atol=1e-9)
    assert out["ecn_marked_bytes"][0] == pytest.approx(
        ref.ecn_marked_bytes, rel=1e-9, abs=1e-6)
    assert out["switch_dropped_bytes"][0] == pytest.approx(
        ref.switch_dropped_bytes, rel=1e-9, abs=1e-6)
    assert out["pause_fanout"][0] == ref.pause_fanout
    per_cls = [sum(v for (lk, tc), v in ref.pause_tc_us.items()
                   if tc == q) for q in range(N_TC)]
    np.testing.assert_allclose(out["pause_tc_total_us"][0], per_cls)


@pytest.mark.slow
@settings(max_examples=EXAMPLES, deadline=None)
@given(st.integers(1, 2), st.integers(2, 3), st.integers(1, 2),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(0, 4), st.integers(0, 2)),
                min_size=1, max_size=4))
def test_per_tc_vector_matches_scalar_on_random_fabrics(
        n_leaves, per_leaf, n_spines, flow_specs):
    _equivalence_case(n_leaves, per_leaf, n_spines, flow_specs)


@pytest.mark.slow
@settings(max_examples=DEEP_EXAMPLES, deadline=None)
@given(st.integers(1, 2), st.integers(2, 3), st.integers(1, 2),
       st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.integers(0, 4), st.integers(0, 2)),
                min_size=1, max_size=5))
def test_per_tc_vector_matches_scalar_on_random_fabrics_deep(
        n_leaves, per_leaf, n_spines, flow_specs):
    _equivalence_case(n_leaves, per_leaf, n_spines, flow_specs)
