"""Message layer + congestion-control zoo: correctness contracts.

The contract under test (ISSUE 6 acceptance):

* the log-bucket histogram percentile estimate agrees with the exact
  sorted percentile within the *documented* relative bound
  ``sqrt(r) - 1`` (pinned here so the docstring can't drift from the
  arithmetic), and percentiles are ordered (p50 <= p99 <= p999) and
  monotone under added latency — property-tested;
* the numpy vector engine reproduces the scalar driver's message
  bookkeeping exactly: same per-flow completion counts, last-completion
  times to 1e-9, and the identical bucket histogram;
* the jax engine's percentile estimates stay within the documented
  bound (plus fluid-tick slack) of the scalar exact values;
* with DCQCN and an unbounded window the op layer is pure
  observability — goodput reproduces the plain fluid run within 1%;
* at least one zoo controller (Timely / HPCC) beats DCQCN's p99
  message latency under the 8-to-1 verbs incast;
* ``message_sweep_grid`` runs msg-size x window x verb x CC as ONE
  vectorized program; the vector engines reject ``window=None``.
"""
import dataclasses
import math

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.fabric import scenarios as SC
from repro.fabric.cc import CC_ALGOS, CcConfig, make_controller
from repro.fabric.fabric import run_fabric
from repro.fabric.messages import (HIST_BUCKETS, HIST_MAX_US, HIST_MIN_US,
                                   LogHistogram, MessageConfig,
                                   MessageTracker, exact_percentile,
                                   hist_bucket, hist_estimate,
                                   hist_rel_error_bound, hist_ratio,
                                   msg_count, msg_started,
                                   percentile_from_counts)
from repro.fabric.vector import run_fabric_sweep

SIM_S = 0.002
BOUND = hist_rel_error_bound()

# a few µs of slack on top of the histogram bound for the jax engine:
# float32 byte accumulation can shift a completion by a fluid tick,
# which can move a sample across a bucket edge
JAX_SLACK_US = 2.0


def _lat_list(ints):
    """Map shim/hypothesis integer lists to latencies in the domain."""
    return [max(HIST_MIN_US, v / 10.0) for v in ints]


# --------------------------------------------------------------------------- #
# histogram arithmetic
# --------------------------------------------------------------------------- #
def test_error_bound_is_pinned():
    # sqrt((1e5/1.0)**(1/128)) - 1 — the number quoted in the module
    # docstring and in fabric/__init__.py ("~4.6%")
    assert BOUND == pytest.approx(0.04599895343025362, abs=1e-12)
    assert BOUND < 0.047


def test_bucket_midpoint_within_bound():
    r = hist_ratio()
    for v in [1.0, 1.5, 3.7, 10.0, 99.9, 1234.5, 99_999.0]:
        b = hist_bucket(v)
        est = hist_estimate(b)
        assert abs(est - v) / v <= BOUND + 1e-12, v
        # edges: values inside bucket b really map to bucket b
        assert HIST_MIN_US * r ** b <= v * (1 + 1e-12)
        assert v <= HIST_MIN_US * r ** (b + 1) * (1 + 1e-12)


def test_bucket_clamps_domain_ends():
    assert hist_bucket(0.0) == 0
    assert hist_bucket(HIST_MIN_US / 2) == 0
    assert hist_bucket(HIST_MAX_US * 100) == HIST_BUCKETS - 1


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=10, max_value=900_000),
                min_size=1, max_size=200))
def test_histogram_percentile_within_bound_of_exact(ints):
    vals = _lat_list(ints)
    h = LogHistogram()
    for v in vals:
        h.add(v)
    for q in (50.0, 99.0, 99.9):
        exact = exact_percentile(vals, q)
        est = h.percentile(q)
        assert abs(est - exact) / exact <= BOUND + 1e-12


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(min_value=10, max_value=900_000),
                min_size=0, max_size=100))
def test_percentiles_are_ordered(ints):
    vals = _lat_list(ints)
    h = LogHistogram()
    for v in vals:
        h.add(v)
    for impl in (lambda q: exact_percentile(vals, q), h.percentile):
        p50, p99, p999 = impl(50.0), impl(99.0), impl(99.9)
        assert p50 <= p99 <= p999


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=10, max_value=400_000),
                min_size=1, max_size=100),
       st.integers(min_value=0, max_value=400_000))
def test_percentiles_monotone_in_added_latency(ints, shift_int):
    """Delaying every message never lowers a percentile estimate."""
    vals = _lat_list(ints)
    shift = shift_int / 10.0
    shifted = [v + shift for v in vals]
    ha, hb = LogHistogram(), LogHistogram()
    for v in vals:
        ha.add(v)
    for v in shifted:
        hb.add(v)
    for q in (50.0, 99.0, 99.9):
        assert exact_percentile(shifted, q) >= exact_percentile(vals, q)
        assert hb.percentile(q) >= ha.percentile(q)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(min_value=10, max_value=900_000),
                min_size=0, max_size=150))
def test_percentile_from_counts_matches_reference(ints):
    vals = _lat_list(ints)
    h = LogHistogram()
    for v in vals:
        h.add(v)
    counts = np.asarray(h.counts, dtype=np.float64)
    for q in (50.0, 99.0, 99.9):
        got = float(percentile_from_counts(counts, q))
        assert got == pytest.approx(h.percentile(q), rel=1e-12)


def test_empty_percentiles_are_zero():
    assert exact_percentile([], 99.0) == 0.0
    assert LogHistogram().percentile(99.0) == 0.0
    z = percentile_from_counts(np.zeros((3, HIST_BUCKETS)), 99.0)
    np.testing.assert_array_equal(z, 0.0)


# --------------------------------------------------------------------------- #
# config + tracker semantics
# --------------------------------------------------------------------------- #
def test_message_config_validation():
    with pytest.raises(ValueError):
        MessageConfig(verb="read")
    with pytest.raises(ValueError):
        MessageConfig(msg_bytes=0.0)
    with pytest.raises(ValueError):
        MessageConfig(window=0)
    assert MessageConfig(window=None).window is None
    w = MessageConfig(verb="write", msg_bytes=4096.0, write_gap_us=0.25)
    assert w.op_rate_gbps == pytest.approx(4096.0 * 0.008 / 0.25)
    assert w.extra_us == 0.0
    s = MessageConfig(verb="send", send_extra_us=1.5)
    assert s.extra_us == 1.5
    assert s.op_gap_us == s.send_gap_us


def test_cc_config_codes():
    assert CC_ALGOS == ("dcqcn", "timely", "hpcc")
    for i, a in enumerate(CC_ALGOS):
        assert CcConfig(algo=a).code() == i
    with pytest.raises(ValueError):
        CcConfig(algo="bbr")
    assert make_controller(None, line_rate_gbps=100.0) is not None


def test_count_epsilon_robust():
    m = 4096.0
    # exact boundary with a hair of float noise on either side
    assert msg_count(10 * m * (1 + 1e-13), m) == 10
    assert msg_count(10 * m * (1 - 1e-13), m) == 10
    assert msg_started(10 * m * (1 - 1e-13), m) == 10
    assert msg_started(10 * m + 1.0, m) == 11


def test_tracker_go_back_n_keeps_clock_running():
    cfg = MessageConfig(msg_bytes=1000.0, window=None)
    tr = MessageTracker(cfg)
    tr.observe(1.0, injected=1000.0, delivered=0.0, start_us=0.0)
    assert tr.hw == 1 and tr.done == 0
    # drop: go-back-N re-credits injected below the started threshold —
    # the message must NOT restart
    tr.observe(2.0, injected=500.0, delivered=0.0, start_us=1.0)
    assert tr.hw == 1
    tr.observe(10.0, injected=1000.0, delivered=1000.0, start_us=9.0)
    assert tr.done == 1
    # latency spans the original start (0.0) to final delivery (10.0)
    assert tr.latencies == [10.0]
    assert tr.last_done_us == 10.0


def test_tracker_window_room():
    cfg = MessageConfig(msg_bytes=1000.0, window=4)
    tr = MessageTracker(cfg)
    assert tr.window_room_bytes(0.0, 0.0) == 4000.0
    assert tr.window_room_bytes(3500.0, 0.0) == 500.0
    assert tr.window_room_bytes(9000.0, 1000.0) == 0.0
    assert math.isinf(
        MessageTracker(MessageConfig(window=None)).window_room_bytes(1e9, 0))


def test_tracker_one_tick_latency_floor():
    cfg = MessageConfig(msg_bytes=100.0, window=None)
    tr = MessageTracker(cfg)
    # injected and delivered within one tick: one tick of latency
    tr.observe(1.0, injected=100.0, delivered=100.0, start_us=0.0)
    assert tr.latencies == [1.0]


# --------------------------------------------------------------------------- #
# scalar driver: observability + the CC race
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def scalar_runs():
    """8-to-1 verbs incast under each controller (scalar reference)."""
    return {algo: SC.message_incast(8, algo=algo, sim_time_s=SIM_S).run()
            for algo in CC_ALGOS}


def test_unbounded_window_dcqcn_is_pure_observability():
    sc = SC.message_incast(8, sim_time_s=SIM_S, window=None)
    plain = dataclasses.replace(
        sc, name="plain", fabric=dataclasses.replace(sc.fabric, msg=None))
    with_msg = sc.run()
    without = plain.run()
    assert with_msg.has_messages and not without.has_messages
    for fid in range(len(sc.flows)):
        a = with_msg.flow_goodput_gbps[fid]
        b = without.flow_goodput_gbps[fid]
        assert a == pytest.approx(b, rel=0.01), fid
    # NaN-safe accessors on the message-free run
    assert without.msg_percentile(99.0) == 0.0
    assert without.msg_count() == 0


def test_cc_zoo_beats_dcqcn_p99(scalar_runs):
    p99 = {a: scalar_runs[a].msg_percentile(99.0) for a in CC_ALGOS}
    assert all(scalar_runs[a].msg_count() > 0 for a in CC_ALGOS)
    assert p99["dcqcn"] > 0.0
    # the acceptance claim: at least one alternative beats DCQCN tail
    assert min(p99["timely"], p99["hpcc"]) < p99["dcqcn"]
    # and not marginally — DCQCN parks a standing queue at the ECN knee
    assert min(p99["timely"], p99["hpcc"]) < 0.5 * p99["dcqcn"]


def test_send_pays_more_than_write():
    w = SC.message_incast(2, verb="write", sim_time_s=SIM_S).run()
    s = SC.message_incast(2, verb="send", sim_time_s=SIM_S).run()
    # two-sided ops pay send_extra_us per message: the p50 must shift
    assert s.msg_percentile(50.0) > w.msg_percentile(50.0)


# --------------------------------------------------------------------------- #
# vector engines vs scalar
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def cc_grid():
    return [SC.message_incast(8, algo=a, sim_time_s=SIM_S)
            for a in CC_ALGOS]


def _scalar_hist(result, flows):
    h = LogHistogram()
    for fid in range(len(flows)):
        for v in result.msg_latency_us.get(fid, []):
            h.add(v)
    return np.asarray(h.counts, dtype=np.float64)


def test_numpy_matches_scalar_messages(scalar_runs, cc_grid):
    out = run_fabric_sweep(cc_grid, backend="numpy")
    assert out["has_messages"].all()
    for g, algo in enumerate(CC_ALGOS):
        ref = scalar_runs[algo]
        F = len(cc_grid[g].flows)
        ref_counts = np.array(
            [len(ref.msg_latency_us.get(f, [])) for f in range(F)])
        np.testing.assert_array_equal(out["msg_count"][g], ref_counts,
                                      err_msg=algo)
        # completion times agree to 1e-9 (same float64 batch fluid)
        ref_last = np.array(
            [ref.msg_last_done_us.get(f, 0.0) for f in range(F)])
        np.testing.assert_allclose(out["msg_last_done_us"][g], ref_last,
                                   atol=1e-9, err_msg=algo)
        # the identical histogram: bucketizing the scalar latencies
        # reproduces the vector engine's count tensor bucket-for-bucket
        np.testing.assert_array_equal(out["msg_hist"][g],
                                      _scalar_hist(ref, cc_grid[g].flows),
                                      err_msg=algo)
        # hence the percentile estimate is within the documented bound
        exact = ref.msg_percentile(99.0)
        assert abs(out["msg_p99_us"][g] - exact) / exact <= BOUND + 1e-9


def test_jax_percentiles_within_documented_bound(scalar_runs, cc_grid):
    out = run_fabric_sweep(cc_grid, backend="jax")
    for g, algo in enumerate(CC_ALGOS):
        ref = scalar_runs[algo]
        # float32: counts may differ by a message at burst boundaries
        ref_total = sum(len(v) for v in ref.msg_latency_us.values())
        assert abs(out["msg_count_total"][g] - ref_total) <= 8, algo
        for q, key in ((50.0, "msg_p50_us"), (99.0, "msg_p99_us")):
            exact = ref.msg_percentile(q)
            tol = exact * BOUND + JAX_SLACK_US
            assert abs(out[key][g] - exact) <= tol, (algo, q)


def test_vector_rejects_unbounded_window():
    sc = SC.message_incast(2, sim_time_s=SIM_S, window=None)
    with pytest.raises(ValueError, match="window=None"):
        run_fabric_sweep([sc], backend="numpy")


def test_message_sweep_grid_one_program():
    scens, axes = SC.message_sweep_grid(
        msg_kb=(64.0,), window=(1, 16), verb=("write",),
        algo=("dcqcn", "timely"), sim_time_s=SIM_S)
    assert len(scens) == 4
    out = run_fabric_sweep(scens, backend="jax")   # ONE jax program
    assert out["has_messages"].all()
    assert (out["msg_count_total"] > 0).all()
    assert (out["msg_rate_mops"] > 0).all()
    assert (out["msg_goodput_gbps"] > 0).all()
    # percentiles come out ordered per point
    assert (out["msg_p50_us"] <= out["msg_p99_us"] + 1e-9).all()
    assert (out["msg_p99_us"] <= out["msg_p999_us"] + 1e-9).all()
    # the race is visible inside one grid: timely's tail beats dcqcn's
    # at the deep window (same claim the scalar test pins)
    at = {(p["algo"], p["window"]): i for i, p in enumerate(axes)}
    dc = out["msg_p99_us"][at[("dcqcn", 16)]]
    tm = out["msg_p99_us"][at[("timely", 16)]]
    assert tm < dc
