"""Fault tolerance: checkpoint/restart bit-identical resume, crash recovery,
straggler monitor, async saver, data-pipeline cursor determinism."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import ARCHS, tiny_config
from repro.data.pipeline import PipelineConfig, SyntheticPipeline
from repro.optim import adamw
from repro.parallel.sharding import single_device_ctx
from repro.train import loop as loop_mod
from repro.train import steps as steps_mod


def _tiny():
    cfg = dataclasses.replace(tiny_config(ARCHS["h2o-danube-1.8b"]),
                              num_layers=2)
    return cfg


def _data(cfg, cursor=0):
    return SyntheticPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, global_batch=2, seq_len=16), cursor)


def test_pipeline_cursor_determinism():
    cfg = _tiny()
    a = _data(cfg)
    batches = [a.next_batch() for _ in range(5)]
    b = _data(cfg, cursor=3)
    resumed = b.next_batch()
    np.testing.assert_array_equal(batches[3]["tokens"], resumed["tokens"])
    np.testing.assert_array_equal(batches[3]["targets"], resumed["targets"])


def test_ckpt_roundtrip_and_keep_last(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.int32)}}
    for step in (1, 2, 3, 4):
        ckpt.save(tree, str(tmp_path), step, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    dirs = sorted(os.listdir(tmp_path))
    assert len(dirs) == 2                      # keep_last trims
    restored, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_atomic_commit_no_tmp_left(tmp_path):
    tree = {"x": jnp.zeros(3)}
    ckpt.save(tree, str(tmp_path), 1)
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_async_saver(tmp_path):
    saver = ckpt.AsyncSaver()
    tree = {"x": jnp.arange(10.0)}
    saver.save(tree, str(tmp_path), 5)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 5


class _Boom(RuntimeError):
    pass


@pytest.mark.slow
def test_crash_restart_resumes_identically(tmp_path):
    """Train 6 steps with a crash at step 4; the restarted run must land on
    the same final loss as an uninterrupted run."""
    cfg = _tiny()
    opt_cfg = adamw.OptConfig(lr=1e-3, total_steps=6)
    ctx = single_device_ctx()
    key = jax.random.key(0)

    # uninterrupted reference
    ref_dir = str(tmp_path / "ref")
    out_ref = loop_mod.run(cfg, ctx, opt_cfg,
                           loop_mod.LoopConfig(total_steps=6, ckpt_every=2,
                                               ckpt_dir=ref_dir,
                                               log_every=1),
                           _data(cfg), key)

    # crashing run
    crash_dir = str(tmp_path / "crash")

    def injector(step):
        if step == 4 and not os.environ.get("_RESUMED"):
            raise _Boom("simulated node failure")

    cfg_loop = loop_mod.LoopConfig(total_steps=6, ckpt_every=2,
                                   ckpt_dir=crash_dir, log_every=1)
    with pytest.raises(_Boom):
        loop_mod.run(cfg, ctx, opt_cfg, cfg_loop, _data(cfg), key,
                     fault_injector=injector)
    # restart: picks up from the last checkpoint (step 4) automatically
    os.environ["_RESUMED"] = "1"
    try:
        out2 = loop_mod.run(cfg, ctx, opt_cfg, cfg_loop, _data(cfg), key,
                            fault_injector=injector)
    finally:
        del os.environ["_RESUMED"]
    assert out2["final_step"] == 6
    ref_final = out_ref["history"][-1]["loss"]
    got_final = out2["history"][-1]["loss"]
    assert abs(ref_final - got_final) < 1e-5   # bit-identical resume


def test_straggler_monitor_flags_outliers():
    mon = loop_mod.StragglerMonitor(factor=3.0, ewma=0.9)
    assert not mon.observe(1.0)
    for _ in range(5):
        assert not mon.observe(1.0)
    assert mon.observe(10.0)                   # 10x the EWMA -> flagged
    assert mon.flags == 1


def test_int8_adam_close_to_fp32():
    cfg = _tiny()
    key = jax.random.key(0)
    params = {"w": jax.random.normal(key, (64, 64))}
    grads = {"w": jax.random.normal(jax.random.key(1), (64, 64)) * 0.1}
    o32 = adamw.OptConfig(lr=1e-2)
    o8 = adamw.OptConfig(lr=1e-2, int8_moments=True)
    s32 = adamw.init(params, o32)
    s8 = adamw.init(params, o8)
    p32, p8 = params, params
    for _ in range(5):
        p32, s32, _ = adamw.update(grads, s32, p32, o32)
        p8, s8, _ = adamw.update(grads, s8, p8, o8)
    diff = np.abs(np.asarray(p32["w"]) - np.asarray(p8["w"])).max()
    scale = np.abs(np.asarray(p32["w"])).max()
    assert diff < 0.05 * scale                 # 8-bit moments track fp32


def test_grad_clip_and_schedule():
    o = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(adamw.schedule(jnp.int32(0), o)) == pytest.approx(0.1)
    assert float(adamw.schedule(jnp.int32(9), o)) == pytest.approx(1.0)
    assert float(adamw.schedule(jnp.int32(99), o)) == pytest.approx(
        0.1, abs=1e-2)
    params = {"w": jnp.ones((4,))}
    st = adamw.init(params, o)
    big = {"w": jnp.full((4,), 1e6)}
    _, _, stats = adamw.update(big, st, params, o)
    assert float(stats["grad_norm"]) > 1e6     # norm reported pre-clip
