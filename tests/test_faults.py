"""Fault-injection layer + loss recovery: correctness contracts.

The contract under test (ISSUE 7 acceptance):

* ``faults=None`` is bit-equal to the pre-fault engines — the fault
  metrics all report zero and the goldens elsewhere in the suite stay
  untouched;
* the counter-based fault hash produces *identical* loss realizations
  in the scalar driver and the batched-numpy engine (exact counts /
  1e-9 byte agreement at nonzero loss), and the jax engine stays
  within its documented float32 slack;
* IRN-style ``selective`` retransmit beats ``go_back_n`` on p999 and
  retransmitted bytes under the same loss realization
  (``lossy_incast_grid``, asserted);
* a crashed-then-restarted receiver's flows all complete: closed
  bursts finish after the restart, ``crash_recovery_us`` stamps the
  first re-accepted byte identically in all three engines (liveness);
* go-back-N replay across a PR 5 ``fail_link`` outage window (NO
  FaultConfig — the fluid core's instant re-credit) completes after
  restore with scalar == numpy message counts (regression);
* the routing-aware PFC-storm metrics (``pause_tc_fanout`` /
  ``n_pausable_links`` / ``pause_storm``) agree between engines and
  are NaN-safe when nothing ever pauses;
* slow-tier hypothesis properties: retransmit bytes are monotone in
  the loss rate (threshold events are nested by construction) and
  crash--restart liveness holds across schedules.
"""
import dataclasses
import math
import os

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.fabric import scenarios as SC
from repro.fabric.fabric import FabricConfig, Flow, run_fabric
from repro.fabric.faults import (FaultConfig, FlowRecovery, HASH_MOD,
                                 corrupt_hash, fault_hash, flap_down_now,
                                 flap_edge, has_pause_cycle, link_salt,
                                 loss_threshold)
from repro.fabric.messages import MessageConfig
from repro.fabric.routing import RoutingConfig
from repro.fabric.topology import incast_fabric
from repro.fabric.vector import run_fabric_sweep

SIM_S = 0.002
EXAMPLES = int(os.environ.get("FABRIC_TEST_EXAMPLES", "2"))
DEEP_EXAMPLES = max(20, EXAMPLES)


# --------------------------------------------------------------------------- #
# config validation + hash plumbing
# --------------------------------------------------------------------------- #
def test_fault_config_validation():
    with pytest.raises(ValueError, match="loss_rate"):
        FaultConfig(loss_rate=-0.1)
    with pytest.raises(ValueError, match="corrupt_rate"):
        FaultConfig(corrupt_rate=1.5)
    with pytest.raises(ValueError, match="link_loss"):
        FaultConfig(link_loss={("a", "b"): 2.0})
    with pytest.raises(ValueError, match="crash window"):
        FaultConfig(crashes={"h": (300.0, 200.0)})
    with pytest.raises(ValueError, match="mtu_bytes"):
        FaultConfig(mtu_bytes=0.0)
    # chainable crash scheduling
    f = FaultConfig().crash("h1_0", 100.0, 200.0).crash("h1_1", 50.0, 60.0)
    assert f.crashes == {"h1_0": (100.0, 200.0), "h1_1": (50.0, 60.0)}
    assert not f.any_loss
    assert FaultConfig(loss_rate=0.01).any_loss


def test_message_config_rejects_unknown_recovery():
    with pytest.raises(ValueError, match="recovery"):
        MessageConfig(recovery="hope")
    with pytest.raises(ValueError, match="rto_us"):
        MessageConfig(rto_us=0.0)


def test_rate_for_prefers_link_override():
    f = FaultConfig(loss_rate=0.01, link_loss={("a", "b"): 0.5})
    assert f.rate_for("a", "b") == 0.5
    assert f.rate_for("b", "a") == 0.01


def test_loss_threshold_endpoints_and_hash_range():
    assert loss_threshold(0.0) == 0          # never fires
    assert loss_threshold(1.0) == HASH_MOD   # always fires
    for t in (0, 1, 499, 49999, 10 ** 6):
        for salt in (0, 1, 65535):
            assert 0 <= fault_hash(t, salt) < HASH_MOD
            assert 0 <= corrupt_hash(t, salt) < HASH_MOD
    # the two streams are genuinely different realizations
    salt = link_salt("leaf0", "h1_0", 3)
    seq_l = [fault_hash(t, salt) for t in range(64)]
    seq_c = [corrupt_hash(t, salt) for t in range(64)]
    assert seq_l != seq_c


def test_link_salt_depends_on_direction_and_seed():
    assert link_salt("a", "b", 0) != link_salt("b", "a", 0)
    assert link_salt("a", "b", 0) != link_salt("a", "b", 1)
    assert 0 <= link_salt("a", "b", 12345) < HASH_MOD


def test_flap_schedule_shape():
    # period 10, down 3, from tick 20: down exactly on [20+10k, 23+10k)
    downs = [t for t in range(60) if flap_down_now(t, 20, 10, 3)]
    assert downs == [20, 21, 22, 30, 31, 32, 40, 41, 42, 50, 51, 52]
    edges = [t for t in range(60) if flap_edge(t, 20, 10)]
    assert edges == [20, 30, 40, 50]
    assert not flap_down_now(19, 20, 10, 3)


def test_has_pause_cycle():
    c = [(("a", "b"), 0), (("b", "c"), 0), (("c", "a"), 0)]
    assert has_pause_cycle(c)
    chain = [(("a", "b"), 0), (("b", "c"), 0)]
    assert not has_pause_cycle(chain)
    # the same edges split across TCs close no single-class cycle
    split = [(("a", "b"), 0), (("b", "c"), 1), (("c", "a"), 2)]
    assert not has_pause_cycle(split)
    assert not has_pause_cycle([])
    # two-node ping-pong (the classic PFC deadlock) in one class
    assert has_pause_cycle([(("a", "b"), 1), (("b", "a"), 1)])


# --------------------------------------------------------------------------- #
# FlowRecovery: the scalar reference state machine
# --------------------------------------------------------------------------- #
def _rec(sel=False, rto=50.0, backoff=2.0, cap=6, nack=8.0):
    return FlowRecovery(selective=sel, rto_us=rto, backoff=backoff,
                        cap=cap, nack_us=nack, dt_us=1.0)


def test_recovery_gbn_fires_after_rto_and_backs_off():
    r = _rec()
    r.on_loss(1000.0)
    assert r.gapped
    for _ in range(49):
        assert r.tick(False) == 0.0
    assert r.tick(False) == 1000.0           # tick 50 == rto_ticks
    assert not r.gapped and r.lost == 0.0 and r.retx_bytes == 1000.0
    # second loss without progress: deadline doubled
    r.on_loss(500.0)
    for _ in range(99):
        assert r.tick(False) == 0.0
    assert r.tick(False) == 500.0
    # delivery progress resets the backoff stage
    r.on_loss(100.0)
    r.tick(True)
    assert r.k == 0
    fires = [r.tick(False) for _ in range(49)]
    assert fires[-1] == 100.0 and all(f == 0.0 for f in fires[:-1])


def test_recovery_gbn_dups_join_the_ledger():
    r = _rec()
    r.on_loss(1000.0)
    assert r.on_arrival(300.0) == 0.0        # dup while gapped: discarded
    assert r.lost == 1300.0 and r.dup_bytes == 300.0
    for _ in range(50):
        credit = r.tick(False)
    assert credit == 1300.0                  # dups replay too


def test_recovery_selective_keeps_arrivals_short_deadline():
    r = _rec(sel=True)
    r.on_loss(1000.0)
    assert not r.gapped                      # IRN: window never gaps
    assert r.on_arrival(300.0) == 300.0      # arrivals keep landing
    for _ in range(7):
        assert r.tick(False) == 0.0
    assert r.tick(False) == 1000.0           # nack_ticks == 8
    # selective never backs off
    r.on_loss(10.0)
    assert r.deadline_ticks() == 8


def test_recovery_backoff_cap():
    r = _rec(cap=2)
    for _ in range(8):
        r.on_loss(1.0)
        while r.tick(False) == 0.0:
            pass
    assert r.k == 2
    assert r.deadline_ticks() == int(50 * 2.0 ** 2)


def test_recovery_timer_idles_without_loss():
    r = _rec()
    for _ in range(200):
        assert r.tick(False) == 0.0
    assert r.timer == 0 and r.retx_bytes == 0.0


# --------------------------------------------------------------------------- #
# faults=None: the fault layer is invisible
# --------------------------------------------------------------------------- #
def test_no_faults_reports_zero_fault_metrics():
    sc = SC.message_incast(4, msg_kb=16.0, window=8, sim_time_s=0.001)
    r = sc.run()
    assert r.dropped_pkts == 0.0
    assert r.retransmit_bytes == 0.0
    assert r.deadlock_ticks == 0
    assert r.crash_recovery_us == {}
    out = run_fabric_sweep([sc], backend="numpy")
    assert float(out["dropped_pkts"][0]) == 0.0
    assert float(out["retransmit_bytes"][0]) == 0.0
    assert float(out["deadlock_ticks"][0]) == 0.0


def test_pause_storm_nan_safe_when_nothing_pauses():
    sc = SC.message_incast(2, msg_kb=16.0, window=4, sim_time_s=0.001)
    r = sc.run()
    assert r.pause_storm() == 0.0            # no pauses, no NaN
    out = run_fabric_sweep([sc], backend="numpy")
    assert np.isfinite(out["pause_storm"]).all()
    assert float(out["pause_storm"][0]) == 0.0


# --------------------------------------------------------------------------- #
# satellite: routing-aware PFC-storm metrics agree between engines
# --------------------------------------------------------------------------- #
def test_pause_fanout_metrics_match_scalar():
    sc = SC.incast(n_senders=6, mode="ddio", burst_mb=1.0, pfc=True,
                   sim_time_s=SIM_S)
    r = sc.run()
    out = run_fabric_sweep([sc], backend="numpy")
    assert r.n_pausable_links == int(out["n_pausable_links"][0])
    vec_fanout = out["pause_tc_fanout"][0]
    for tc in range(vec_fanout.shape[-1]):
        assert r.pause_tc_fanout.get(tc, 0) == int(vec_fanout[tc])
    assert r.pause_storm() == pytest.approx(float(out["pause_storm"][0]))
    assert 0.0 < r.pause_storm() <= 1.0      # PFC incast does pause


# --------------------------------------------------------------------------- #
# engine equivalence at nonzero loss (identical fault realizations)
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def lossy_scen():
    sc = SC.message_incast(4, msg_kb=16.0, window=8, sim_time_s=0.001)
    f = FaultConfig(loss_rate=0.02, seed=3)
    return dataclasses.replace(
        sc, fabric=dataclasses.replace(sc.fabric, faults=f))


@pytest.fixture(scope="module")
def lossy_ref(lossy_scen):
    return run_fabric(lossy_scen.topology, lossy_scen.flows,
                      lossy_scen.fabric)


def test_numpy_matches_scalar_at_nonzero_loss(lossy_scen, lossy_ref):
    r = lossy_ref
    out = run_fabric_sweep([lossy_scen], backend="numpy")
    # identical loss realization -> identical fault accounting
    np.testing.assert_allclose(out["dropped_pkts"][0], r.dropped_pkts,
                               rtol=1e-12)
    np.testing.assert_allclose(out["retransmit_bytes"][0],
                               r.retransmit_bytes, rtol=1e-12)
    F = len(lossy_scen.flows)
    ref_counts = np.array(
        [len(r.msg_latency_us.get(f, [])) for f in range(F)])
    np.testing.assert_array_equal(out["msg_count"][0], ref_counts)
    ref_gp = np.array([r.flow_goodput_gbps[i] for i in range(F)])
    np.testing.assert_allclose(out["flow_goodput_gbps"][0], ref_gp,
                               atol=1e-9)


def test_jax_matches_scalar_at_nonzero_loss(lossy_scen, lossy_ref):
    r = lossy_ref
    out = run_fabric_sweep([lossy_scen], backend="jax")
    # float32: same realization, byte totals within relative slack
    np.testing.assert_allclose(out["dropped_pkts"][0], r.dropped_pkts,
                               rtol=1e-4)
    np.testing.assert_allclose(out["retransmit_bytes"][0],
                               r.retransmit_bytes, rtol=1e-4)
    ref_total = sum(len(v) for v in r.msg_latency_us.values())
    assert abs(float(out["msg_count_total"][0]) - ref_total) <= 8


def test_mixed_grid_faulted_and_clean_points(lossy_scen):
    # a faults=None point and a faulted point share one program; the
    # clean point's fault metrics stay exactly zero
    clean = SC.message_incast(4, msg_kb=16.0, window=8, sim_time_s=0.001)
    out = run_fabric_sweep([clean, lossy_scen], backend="numpy")
    assert float(out["retransmit_bytes"][0]) == 0.0
    assert float(out["dropped_pkts"][0]) == 0.0
    assert float(out["retransmit_bytes"][1]) > 0.0
    assert float(out["dropped_pkts"][1]) > 0.0


# --------------------------------------------------------------------------- #
# selective vs go-back-N: the IRN argument, asserted
# --------------------------------------------------------------------------- #
def test_selective_beats_go_back_n_tail():
    scens, points = SC.lossy_incast_grid(
        loss_rate=(0.005, 0.02), recovery=("go_back_n", "selective"),
        sim_time_s=SIM_S)
    out = run_fabric_sweep(scens, backend="numpy")

    def pick(rec, rate, key):
        return next(float(out[key][i]) for i, p in enumerate(points)
                    if p["recovery"] == rec and p["loss_rate"] == rate)

    worst = 0.02
    # selective replays only the lost span: order-of-magnitude fewer
    # retransmitted bytes, more completed messages, and a lower p999
    assert pick("selective", worst, "retransmit_bytes") \
        < 0.5 * pick("go_back_n", worst, "retransmit_bytes")
    assert pick("selective", worst, "msg_count_total") \
        > pick("go_back_n", worst, "msg_count_total")
    assert pick("selective", worst, "msg_p999_us") \
        < pick("go_back_n", worst, "msg_p999_us")
    # and the gap grows with the loss rate on the go-back-N side
    assert pick("go_back_n", worst, "retransmit_bytes") \
        > pick("go_back_n", 0.005, "retransmit_bytes")


# --------------------------------------------------------------------------- #
# crash--restart liveness
# --------------------------------------------------------------------------- #
def _crash_scenario():
    sc = SC.lossy_incast(n_senders=4, loss_rate=0.005,
                         recovery="go_back_n", msg_kb=16.0, window=8,
                         sim_time_s=SIM_S)
    flows = [dataclasses.replace(f, burst_bytes=1.5e6) for f in sc.flows]
    sc = dataclasses.replace(sc, flows=flows)
    sc.fabric.faults = FaultConfig(loss_rate=0.005, seed=7).crash(
        "h1_0", 100.0, 200.0)
    return sc


def test_crashed_receiver_flows_all_complete():
    sc = _crash_scenario()
    r = sc.run()
    # liveness: every closed burst finishes, after the restart
    for fid, done in r.flow_completion_us.items():
        assert math.isfinite(done), fid
        assert done > 200.0, fid
    assert math.isfinite(r.crash_recovery_us["h1_0"])
    assert r.crash_recovery_us["h1_0"] > 100.0   # restart gap + re-accept
    assert r.retransmit_bytes > 0.0

    out = run_fabric_sweep([sc], backend="numpy")
    ref_done = np.array([r.flow_completion_us[i]
                         for i in range(len(sc.flows))])
    np.testing.assert_allclose(out["flow_completion_us"][0], ref_done,
                               atol=1e-9)
    np.testing.assert_allclose(out["crash_recovery_us"][0],
                               [r.crash_recovery_us["h1_0"]], atol=1e-9)
    np.testing.assert_allclose(out["retransmit_bytes"][0],
                               r.retransmit_bytes, rtol=1e-12)


def test_crash_liveness_jax():
    sc = _crash_scenario()
    r = sc.run()
    out = run_fabric_sweep([sc], backend="jax")
    ref_done = np.array([r.flow_completion_us[i]
                         for i in range(len(sc.flows))])
    # float32 completions land within a tick of the scalar reference
    np.testing.assert_allclose(out["flow_completion_us"][0], ref_done,
                               atol=1.0)
    np.testing.assert_allclose(out["crash_recovery_us"][0],
                               [r.crash_recovery_us["h1_0"]], atol=1.0)


def test_vector_rejects_crash_of_unknown_host():
    sc = SC.message_incast(2, msg_kb=16.0, window=4, sim_time_s=0.001)
    sc.fabric.faults = FaultConfig().crash("h0_0", 100.0, 200.0)
    with pytest.raises(ValueError, match="crash"):
        run_fabric_sweep([sc], backend="numpy")


# --------------------------------------------------------------------------- #
# satellite: go-back-N replay across a PR 5 fail_link window (no faults)
# --------------------------------------------------------------------------- #
def test_burst_replay_across_link_outage_matches_numpy():
    topo = incast_fabric(2)
    topo.fail_link("leaf0", "spine0", at_us=20.0, restore_us=400.0)
    flows = [Flow(src=f"h0_{i}", dst="h1_0", burst_bytes=600e3,
                  tag="incast") for i in range(2)]
    fc = FabricConfig(sim_time_s=SIM_S,
                      msg=MessageConfig(msg_bytes=32 * 1024.0, window=8),
                      routing=RoutingConfig(mode="static_ecmp"),
                      receiver_cfg=SC._recv_factory("ddio", False))
    r = run_fabric(topo, flows, fc)
    # static ECMP pins one flow to the dead spine: its burst stalls
    # through the outage (instant fluid re-credit — no FaultConfig) and
    # completes right after the 400 us restore; the other sails through
    done = sorted(r.flow_completion_us.values())
    assert done[0] < 100.0
    assert 400.0 < done[1] < 500.0
    assert r.retransmit_bytes == 0.0         # ledger never engaged

    sc = SC.Scenario("regression", topo, flows, fc)
    out = run_fabric_sweep([sc], backend="numpy")
    F = len(flows)
    ref_counts = np.array(
        [len(r.msg_latency_us.get(f, [])) for f in range(F)])
    np.testing.assert_array_equal(out["msg_count"][0], ref_counts)
    ref_done = np.array([r.flow_completion_us[i] for i in range(F)])
    np.testing.assert_allclose(out["flow_completion_us"][0], ref_done,
                               atol=1e-9)


def test_flap_link_matches_numpy():
    sc = SC.message_incast(4, msg_kb=16.0, window=8, sim_time_s=0.001)
    sc.topology.flap_link("leaf0", "spine0", start_us=300.0,
                          period_us=120.0, down_us=30.0)
    sc.fabric.faults = FaultConfig(seed=0)
    r = sc.run()
    out = run_fabric_sweep([sc], backend="numpy")
    F = len(sc.flows)
    ref_gp = np.array([r.flow_goodput_gbps[i] for i in range(F)])
    np.testing.assert_allclose(out["flow_goodput_gbps"][0], ref_gp,
                               atol=1e-9)
    ref_counts = np.array(
        [len(r.msg_latency_us.get(f, [])) for f in range(F)])
    np.testing.assert_array_equal(out["msg_count"][0], ref_counts)


# --------------------------------------------------------------------------- #
# slow tier: hypothesis properties
# --------------------------------------------------------------------------- #
@pytest.mark.slow
@settings(max_examples=DEEP_EXAMPLES, deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(1, 500), st.integers(1, 120),
       st.integers(0, 2000))
def test_loss_events_nested_in_rate(seed, r1_milli, gap_milli, t0):
    # the counter-based design makes loss-rate sweeps coherent: a drop
    # fires iff hash < floor(rate * 65536), and thresholds are nested,
    # so every event at the lower rate also fires at the higher rate —
    # raising the rate only *adds* drops to the same realization
    r1 = r1_milli / 1000.0
    r2 = min(1.0, (r1_milli + gap_milli) / 1000.0)
    thr1, thr2 = loss_threshold(r1), loss_threshold(r2)
    assert thr1 <= thr2
    salt = link_salt("leaf0", f"h1_{seed % 7}", seed)
    for t in range(t0, t0 + 256):
        if fault_hash(t, salt) < thr1:
            assert fault_hash(t, salt) < thr2


@pytest.mark.slow
def test_selective_retransmit_bytes_monotone_in_loss_rate():
    # closed-loop byte totals inherit the event nesting as long as the
    # fabric doesn't collapse: selective keeps goodput near baseline,
    # so the replayed span grows with the rate.  (go-back-N is *not*
    # monotone at high rates — throughput collapse puts fewer bytes on
    # the wire per drop event — which is exactly the IRN argument.)
    for seed in (0, 3, 7):
        vals = []
        for rate in (0.002, 0.01, 0.04):
            sc = SC.lossy_incast(n_senders=4, loss_rate=rate,
                                 recovery="selective", msg_kb=16.0,
                                 window=8, seed=seed, sim_time_s=0.001)
            out = run_fabric_sweep([sc], backend="numpy")
            vals.append(float(out["retransmit_bytes"][0]))
        assert vals[0] < vals[1] < vals[2], (seed, vals)


@pytest.mark.slow
@settings(max_examples=EXAMPLES, deadline=None)
@given(st.integers(0, 1000), st.integers(50, 200), st.integers(20, 200))
def test_crash_recovery_liveness(seed, at_us, outage_us):
    # at_us capped below the ~360 us lossless completion time so the
    # crash always interrupts the transfer; restart_us <= 400 leaves
    # the RTO ledger room to replay well inside the 2 ms horizon
    sc = SC.lossy_incast(n_senders=3, loss_rate=0.002,
                         recovery="go_back_n", msg_kb=16.0, window=8,
                         seed=seed, sim_time_s=SIM_S)
    flows = [dataclasses.replace(f, burst_bytes=1.5e6) for f in sc.flows]
    sc = dataclasses.replace(sc, flows=flows)
    sc.fabric.faults = FaultConfig(loss_rate=0.002, seed=seed).crash(
        "h1_0", float(at_us), float(at_us + outage_us))
    r = sc.run()
    assert math.isfinite(r.crash_recovery_us["h1_0"])
    for fid, done in r.flow_completion_us.items():
        assert math.isfinite(done), (seed, at_us, outage_us, fid)
