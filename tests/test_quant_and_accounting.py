"""Property tests for the perf-loop additions: row-wise int8 quantization
(sharding-preserving optimizer state) and the TPU-faithful HLO collective
accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.launch import hlo_analysis
from repro.parallel.compression import (dequantize_int8_rowwise,
                                        quantize_int8_rowwise)


# --------------------------------------------------------------------------- #
# row-wise int8
# --------------------------------------------------------------------------- #
@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(1, 6), min_size=1, max_size=3),
       st.integers(1, 257), st.integers(0, 2 ** 31 - 1))
@pytest.mark.slow
def test_rowwise_int8_shapes_and_error_bound(lead, last, seed):
    """q keeps x's shape; scale drops the last dim; |x - deq| <= scale/2
    per row (symmetric rounding bound)."""
    shape = tuple(lead) + (last,)
    x = np.asarray(jax.random.normal(jax.random.key(seed), shape,
                                     jnp.float32)) * 3.0
    q, s = quantize_int8_rowwise(jnp.asarray(x))
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert s.shape == x.shape[:-1]
    deq = np.asarray(dequantize_int8_rowwise(q, s))
    bound = np.asarray(s)[..., None] * 0.5 + 1e-7
    assert (np.abs(deq - x) <= bound + 1e-6).all()


def test_rowwise_int8_zero_and_extremes():
    z = jnp.zeros((4, 8))
    q, s = quantize_int8_rowwise(z)
    assert np.asarray(q).max() == 0
    np.testing.assert_allclose(np.asarray(dequantize_int8_rowwise(q, s)),
                               0.0)
    # max magnitude maps to +-127 exactly
    x = jnp.asarray([[1.0, -2.0, 0.5, 2.0]])
    q, s = quantize_int8_rowwise(x)
    assert int(np.abs(np.asarray(q)).max()) == 127


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
def test_rowwise_int8_scale_invariance(n, seed):
    """Quantization commutes with positive per-tensor scaling."""
    x = np.asarray(jax.random.normal(jax.random.key(seed), (3, n)))
    q1, _ = quantize_int8_rowwise(jnp.asarray(x))
    q2, _ = quantize_int8_rowwise(jnp.asarray(x * 7.25))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


# --------------------------------------------------------------------------- #
# HLO collective accounting
# --------------------------------------------------------------------------- #
def _entry(body: str) -> str:
    return ("ENTRY %main (p0: f32[8]) -> f32[8] {\n" + body +
            "\n}\n")


def test_ring_model_factors():
    """all-gather (n-1)/n, all-reduce 2(n-1)/n, reduce-scatter result*(n-1),
    permute 1x — on synthetic single-op modules."""
    cases = [
        ("%ag = f32[64,4]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, "
         "dimensions={0}", "all-gather", 64 * 4 * 4 * 3 / 4),
        ("%ar = f32[64,4]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, "
         "to_apply=%add", "all-reduce", 64 * 4 * 4 * 2 * 3 / 4),
        ("%rs = f32[16,4]{1,0} reduce-scatter(%x), "
         "replica_groups={{0,1,2,3}}, dimensions={0}, to_apply=%add",
         "reduce-scatter", 16 * 4 * 4 * 3),
        ("%cp = f32[64,4]{1,0} collective-permute(%x), "
         "source_target_pairs={{0,1},{1,0}}", "collective-permute",
         64 * 4 * 4),
    ]
    for line, op, want in cases:
        out = hlo_analysis.analyze(_entry("  " + line))
        assert abs(out["coll"][op] - want) < 1e-6, (op, out["coll"], want)


def test_promoted_and_convert_fed_counted_bf16():
    """CPU-widened collectives count at bf16 (half) width."""
    promoted = ("  %ar = f32[64]{0} all-reduce(%x), "
                "replica_groups={{0,1}}, to_apply=%add.clone_promoted")
    out = hlo_analysis.analyze(_entry(promoted))
    assert abs(out["coll"]["all-reduce"] - 64 * 4 * 2 * 0.5 / 2) < 1e-6
    conv = ("  %ag = f32[64]{0} all-gather(%wrapped_convert.3), "
            "replica_groups={{0,1}}, dimensions={0}")
    out = hlo_analysis.analyze(_entry(conv))
    assert abs(out["coll"]["all-gather"] - 64 * 4 * 0.5 * 0.5) < 1e-6
    # genuine f32 (non-convert operand) is NOT halved
    raw = ("  %ag2 = f32[64]{0} all-gather(%x), "
           "replica_groups={{0,1}}, dimensions={0}")
    out = hlo_analysis.analyze(_entry(raw))
    assert abs(out["coll"]["all-gather"] - 64 * 4 * 0.5) < 1e-6


def test_trip_count_weighting():
    """Collectives inside a while body multiply by the trip count."""
    hlo = """
%cond (c: (s32[], f32[8])) -> pred[] {
  %c = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%c), index=0
  %k = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}
%body (b: (s32[], f32[8])) -> (s32[], f32[8]) {
  %b = (s32[], f32[8]) parameter(0)
  %v = f32[8]{0} get-tuple-element(%b), index=1
  %ar = f32[8]{0} all-reduce(%v), replica_groups={{0,1}}, to_apply=%add
  %i2 = s32[] get-tuple-element(%b), index=0
  ROOT %t = (s32[], f32[8]) tuple(%i2, %ar)
}
ENTRY %main (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  ROOT %w = (s32[], f32[8]) while(%p), condition=%cond, body=%body
}
"""
    out = hlo_analysis.analyze(hlo)
    assert out["trip_counts"] == [12]
    assert abs(out["coll"]["all-reduce"] - 12 * 8 * 4 * 2 * 0.5) < 1e-6


def test_opt_state_specs_rowwise_layout():
    """int8 moment specs mirror the parameter sharding (q exact, s
    truncated) — the fix that removed 2 TB/step of resharding."""
    import dataclasses
    from jax.sharding import PartitionSpec as P
    from repro.configs import ARCHS, tiny_config
    from repro.launch.mesh import ctx_for_mesh
    from repro.optim import adamw
    from repro.train import steps as steps_mod

    cfg = tiny_config(ARCHS["llama4-scout-17b-a16e"])
    opt_cfg = adamw.OptConfig(int8_moments=True)
    state = steps_mod.abstract_state(cfg, opt_cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ctx = ctx_for_mesh(mesh)
    specs = steps_mod.state_specs(state, ctx)
    flat_p = jax.tree_util.tree_leaves_with_path(state["params"])
    flat_m = dict(jax.tree_util.tree_leaves_with_path(state["opt"]["m"]))
    flat_ms = dict(jax.tree_util.tree_leaves_with_path(specs["opt"]["m"],
                   is_leaf=lambda x: isinstance(x, P)))
    checked = 0
    for path, leaf in flat_p:
        qpath = tuple(path) + (jax.tree_util.DictKey("q"),)
        spath = tuple(path) + (jax.tree_util.DictKey("s"),)
        if qpath in flat_m:
            assert flat_m[qpath].shape == leaf.shape          # q mirrors p
            assert flat_m[spath].shape == leaf.shape[:-1]     # s drops last
            assert len(flat_ms[qpath]) <= leaf.ndim
            checked += 1
    assert checked > 5
