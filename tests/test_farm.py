"""Sweep-farm tests: chunk/padding invariance, artifacts + resume, and
the legacy-jax / single-device fallback.

The farm's core promise is that chunking is *invisible*: a grid run as
one monolithic program, as several chunks, and as chunks padded with
duplicate points must produce bit-identical per-point results at fixed
dt — held here for the numpy (f64) and jax (f32) engines, for a faults
grid (whose counter-based loss RNG must stay realization-identical
across chunk boundaries), and against the scalar driver golden.
"""
from __future__ import annotations

import os
import warnings

import numpy as np
import pytest

from repro.fabric import artifacts as A
from repro.fabric import vector as V
from repro.fabric.farm import GridSpec, run_farm
from repro.fabric.scenarios import (build_grid, chunk_plan, incast_grid,
                                    lossy_incast_grid)
from repro.fabric.vector import FabricSweepParams, run_fabric_sweep
from repro.parallel import compat


def _grid(n=8):
    scens, _ = incast_grid(burst_mb=tuple(0.25 * (i + 1)
                                          for i in range(n // 4)),
                           n_senders=4, sim_time_s=0.001)
    return scens[:n]


def _assert_identical(a: dict, b: dict, label: str) -> None:
    assert set(a) == set(b)
    for k in a:
        x, y = np.asarray(a[k]), np.asarray(b[k])
        assert np.array_equal(x, y, equal_nan=True), \
            f"{label}: metric {k} differs"


# --------------------------------------------------------------------------- #
# chunk planning
# --------------------------------------------------------------------------- #
def test_chunk_plan_shapes():
    plan = chunk_plan(23, 8)
    assert [(e["stop"] - e["start"], e["padded"]) for e in plan] == \
        [(8, 8), (8, 8), (7, 8)]           # remainder pads up to pow2<=8
    assert plan[-1]["padded"] >= plan[-1]["stop"] - plan[-1]["start"]
    # at most two canonical shapes per plan
    assert len({e["padded"] for e in plan}) <= 2
    # full coverage, no overlap
    covered = [i for e in plan for i in range(e["start"], e["stop"])]
    assert covered == list(range(23))


def test_chunk_plan_rejects_bad_input():
    with pytest.raises(ValueError):
        chunk_plan(0, 8)
    with pytest.raises(ValueError):
        chunk_plan(8, 0)


def test_envelope_forces_structure_key():
    # heterogeneous grid: first half carries CC + faults, second half
    # is plain — naive per-chunk packing would change capability flags
    from repro.fabric.cc import CcConfig
    from repro.fabric.faults import FaultConfig
    scens = _grid(8)
    for sc in scens[:4]:
        sc.fabric.cc = CcConfig(algo="timely")
        sc.fabric.faults = FaultConfig(loss_rate=1e-4, seed=7)
    full = FabricSweepParams.from_scenarios(scens)
    env = full.envelope()
    for lo, hi in ((0, 4), (4, 8)):
        chunk = FabricSweepParams.from_scenarios(scens[lo:hi],
                                                 envelope=env)
        assert chunk.structure_key == full.structure_key
    # without the envelope the plain chunk traces a smaller program
    bare = FabricSweepParams.from_scenarios(scens[4:])
    assert bare.structure_key != full.structure_key


# --------------------------------------------------------------------------- #
# chunk/padding invariance
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", ["numpy", "jax"])
def test_farm_bit_identical_vs_monolithic(backend):
    scens = _grid(8)
    mono = run_fabric_sweep(scens, backend=backend)
    farm = run_farm(scens, workers=0, chunk_size=4, backend=backend,
                    artifacts=False)
    _assert_identical(mono, farm["results"], f"farm-{backend}")


def test_padded_chunks_bit_identical_numpy():
    # 7 real points with chunk_size=4 -> chunks (4, 3-padded-to-4):
    # the padded lane replicates a real scenario and must not perturb
    # any real point
    scens = _grid(8)[:7]
    mono = run_fabric_sweep(scens, backend="numpy")
    farm = run_farm(scens, workers=0, chunk_size=4, backend="numpy",
                    artifacts=False)
    plan = farm["manifest"]["records"]
    assert [r["padded"] for r in plan] == [4, 4]
    assert [r["stop"] - r["start"] for r in plan] == [4, 3]
    _assert_identical(mono, farm["results"], "farm-padded")


def test_faults_grid_chunk_invariance_numpy():
    # counter-based loss RNG hashes (tick, link, seed) only — chunk
    # boundaries must not shift any realization
    scens, _ = lossy_incast_grid(loss_rate=(0.01, 0.05),
                                 n_senders=4, sim_time_s=0.001)
    assert len(scens) == 4
    mono = run_fabric_sweep(scens, backend="numpy")
    farm = run_farm(scens, workers=0, chunk_size=3, backend="numpy",
                    artifacts=False)   # chunks (3, 1): boundary mid-grid
    _assert_identical(mono, farm["results"], "farm-faults")
    assert np.asarray(mono["retransmit_bytes"]).sum() > 0  # non-trivial


def test_farm_matches_scalar_golden():
    scens = _grid(4)
    farm = run_farm(scens, workers=0, chunk_size=3, backend="numpy",
                    artifacts=False)
    ref = scens[2].run()   # point in the second (padded) chunk
    got = np.asarray(farm["results"]["flow_goodput_gbps"][2])
    want = np.array([ref.flow_goodput_gbps[f]
                     for f in range(len(scens[2].flows))])
    np.testing.assert_allclose(got, want, rtol=1e-9)


# --------------------------------------------------------------------------- #
# artifacts + resume
# --------------------------------------------------------------------------- #
def test_resume_reexecutes_only_missing_chunks(tmp_path):
    td = str(tmp_path)
    res = run_farm("incast", quick=True, workers=0, chunk_size=6,
                   backend="numpy", out_dir=td)
    m = res["manifest"]
    assert m["status"] == "complete"
    assert m["chunks"] == 3
    assert os.path.exists(os.path.join(res["run_dir"],
                                       "manifest.json"))
    # kill-at-50% simulation: drop one shard, resume
    os.remove(A.chunk_path(res["run_dir"], 1))
    res2 = run_farm("incast", quick=True, workers=0, chunk_size=6,
                    backend="numpy", out_dir=td, run_id=res["run_id"],
                    resume=True)
    m2 = res2["manifest"]
    assert sorted(m2["resumed_chunks"]) == [0, 2]
    reran = [r["chunk"] for r in m2["records"]
             if r["chunk"] not in m2["resumed_chunks"]]
    assert reran == [1]
    _assert_identical(res["results"], res2["results"], "resume")


def test_resume_rejects_different_grid(tmp_path):
    td = str(tmp_path)
    res = run_farm("incast", quick=True, workers=0, chunk_size=8,
                   backend="numpy", out_dir=td)
    with pytest.raises(ValueError, match="resume mismatch"):
        run_farm("mixed_fleet", quick=True, workers=0, chunk_size=8,
                 backend="numpy", out_dir=td, run_id=res["run_id"],
                 resume=True)


def test_artifacts_roundtrip(tmp_path):
    rdir = str(tmp_path / "run")
    out = {"m": np.arange(6, dtype=np.float64).reshape(3, 2)}
    A.save_chunk(rdir, 0, out, meta={"chunk": 0})
    loaded = A.load_chunk(rdir, 0)
    assert loaded is not None
    results, meta = loaded
    assert meta["chunk"] == 0
    np.testing.assert_array_equal(results["m"], out["m"])
    # corrupt shard -> treated as missing (resume re-runs it)
    with open(A.chunk_path(rdir, 0), "wb") as f:
        f.write(b"garbage")
    assert A.load_chunk(rdir, 0) is None
    assert A.completed_chunks(rdir, 1) == []


def test_grid_spec_picklable_and_deterministic():
    import pickle
    spec = GridSpec("incast", quick=True)
    spec2 = pickle.loads(pickle.dumps(spec))
    a, _ = spec.build()
    b, _ = spec2.build()
    assert [s.name for s in a] == [s.name for s in b]


# --------------------------------------------------------------------------- #
# capability probe + graceful fallback (legacy jax / single device)
# --------------------------------------------------------------------------- #
def test_farm_dispatch_probe_single_device():
    import jax
    ok, reason = compat.farm_dispatch_probe(
        min_devices=len(jax.devices()) + 1)
    assert not ok
    assert "device" in reason


def test_farm_dispatch_probe_legacy_jax(monkeypatch):
    # force the legacy-jax path: native shard_map absent must yield a
    # (False, reason) probe, never an exception
    monkeypatch.setattr(compat, "_HAS_NATIVE", False)
    ok, reason = compat.farm_dispatch_probe(min_devices=1)
    assert not ok
    assert "legacy jax" in reason


def test_farm_degrades_gracefully_without_devices(monkeypatch):
    # the farm must warn and fall back to single-device chunked
    # execution — not crash — when device dispatch is unavailable
    monkeypatch.setattr(compat, "_HAS_NATIVE", False)
    scens = _grid(4)
    mono = run_fabric_sweep(scens, backend="jax")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        farm = run_farm(scens, workers=0, chunk_size=4, backend="jax",
                        artifacts=False)
    assert any("falling back to single-device" in str(w.message)
               for w in rec)
    _assert_identical(mono, farm["results"], "fallback")


def test_raw_scenarios_with_workers_fall_back_inprocess():
    scens = _grid(4)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        farm = run_farm(scens, workers=4, chunk_size=4,
                        backend="numpy", artifacts=False)
    assert any("raw scenario lists" in str(w.message) for w in rec)
    assert farm["manifest"]["records"][0]["worker"] == "inprocess"


# --------------------------------------------------------------------------- #
# program-cache accounting
# --------------------------------------------------------------------------- #
def test_zero_recompiles_after_warmup():
    scens = _grid(8)
    run_farm(scens, workers=0, chunk_size=4, backend="jax",
             artifacts=False)                       # warmup compiles
    farm = run_farm(scens, workers=0, chunk_size=4, backend="jax",
                    artifacts=False)
    assert sum(r["compiles"]
               for r in farm["manifest"]["records"]) == 0


def test_named_grid_registry():
    scens, points = build_grid("incast", quick=True)
    assert len(scens) == len(points) == 16
    with pytest.raises(ValueError, match="unknown grid"):
        build_grid("nope")
