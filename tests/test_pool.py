"""Property tests for the cache-resident buffer pool (paper §4.1/§4.2)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.pool import DevicePool, SlabPool


@given(st.lists(st.tuples(st.integers(0, 3),          # app
                          st.integers(1, 64 * 4096),  # nbytes
                          st.booleans()),              # free-after?
                min_size=1, max_size=60))
@settings(max_examples=60, deadline=None)
def test_slab_pool_invariants(ops):
    pool = SlabPool(capacity_bytes=64 * 4096, slot_bytes=4096)
    live = {}
    now = 0.0
    for i, (app, nbytes, free_after) in enumerate(ops):
        now += 1.0
        ids = pool.alloc(app, nbytes, now)
        need = pool.slots_needed(nbytes)
        if ids is None:
            # refusal must be justified
            assert need * pool.slot_bytes > pool.available_bytes
            continue
        assert len(ids) == need
        assert len(set(ids)) == len(ids)            # no double-allocation
        for sid in ids:
            assert all(sid not in v for v in live.values())
        live.setdefault(app, []).extend(ids)
        if free_after and live.get(app):
            pool.free(app, live.pop(app))
    # conservation: free + live slots == capacity (no replaced slots here)
    n_live = sum(len(v) for v in live.values())
    assert pool.available_bytes == (pool.num_slots - n_live) * pool.slot_bytes


def test_double_free_raises():
    pool = SlabPool(capacity_bytes=8 * 4096)
    ids = pool.alloc(0, 4096, 0.0)
    pool.free(0, ids)
    with pytest.raises(KeyError):
        pool.free(0, ids)


def test_wrong_owner_free_raises():
    pool = SlabPool(capacity_bytes=8 * 4096)
    ids = pool.alloc(0, 4096, 0.0)
    with pytest.raises(ValueError):
        pool.free(1, ids)


def test_straggler_accounting_monotone_head():
    pool = SlabPool(capacity_bytes=32 * 4096)
    for t in range(8):
        pool.alloc(7, 4096, float(t))
    assert pool.oldest_age(7, 10.0) == 10.0
    # slots older than 5.5 at t=10: alloc_ts < 4.5 -> ts 0..4 = 5 slots
    assert len(pool.straggler_slots(7, 10.0, 5.5)) == 5
    assert pool.straggler_ratio(7, 10.0, 5.5) == pytest.approx(5 / 8)


def test_replace_keeps_recyclable_size_constant():
    """Paper §4.3: replacement swaps a straggler for a DRAM-backed slot so
    the usable pool size is unchanged."""
    pool = SlabPool(capacity_bytes=4 * 4096)
    ids = pool.alloc(0, 4 * 4096, 0.0)
    assert pool.available_bytes == 0
    borrowed = pool.replace(ids[:2])
    assert borrowed == 2 * 4096
    assert pool.available_bytes == 2 * 4096        # fresh slots joined
    assert pool.replace_mem_bytes == 2 * 4096
    pool.free(0, ids)                               # replaced slots retire
    assert pool.replace_mem_bytes == 0
    assert pool.available_bytes == 4 * 4096


@pytest.mark.slow
@given(st.integers(1, 16), st.integers(0, 16))
@settings(max_examples=30, deadline=None)
def test_device_pool_alloc_release(n_slots, n_alloc):
    pool = DevicePool.create(n_slots)
    pool2, idx, ok = pool.alloc(n_alloc)
    idx = np.asarray(idx)
    if n_alloc <= n_slots:
        assert bool(ok)
        assert len(set(idx.tolist())) == n_alloc or n_alloc == 0
        assert int(pool2.available()) == n_slots - n_alloc
    else:
        assert not bool(ok)
    pool3 = pool2.release(idx)
    expected = n_slots if n_alloc <= n_slots else n_slots
    if n_alloc <= n_slots:
        assert int(pool3.available()) == expected
