"""The shared host receive datapath (`repro.core.datapath`): extraction
equivalence against pre-refactor `run_sim`, the QoS admission machinery,
and the JetService facade under network backpressure."""
import pytest

from repro.core import simulator as S
from repro.core.datapath import (Admit, AdmissionQueues, HostDatapath,
                                 N_QOS, QoS, expected_footprint)
from repro.core.jet import JetConfig, JetService


# --------------------------------------------------------------------------- #
# datapath-extraction equivalence: run_sim numerics preserved
# --------------------------------------------------------------------------- #
# Golden values recorded from the pre-refactor ReceiverHost (its former
# monolithic tick body, commit aa60dff) on both calibrated testbeds.
# The extraction is arithmetic-preserving — per-class loops with all
# traffic in the NORMAL class reduce to the original scalar ops — so the
# comparison is exact (== on floats), not approximate.
_GOLD = {
    ("100g", "ddio"): dict(goodput_gbps=116.68822835927475,
                           avg_latency_us=635.5263277419357,
                           cnp_count=15.0,
                           ddio_miss_rate=0.9444188874605015,
                           nic_dram_gbps=221.05323616147538,
                           pfc_pause_us=0.0, completed_messages=1088),
    ("100g", "jet"): dict(goodput_gbps=200.0,
                          avg_latency_us=396.0716515555555,
                          cnp_count=0.0, ddio_miss_rate=0.0,
                          nic_dram_gbps=0.0, pfc_pause_us=0.0,
                          completed_messages=1888),
    ("25g", "ddio"): dict(goodput_gbps=28.0,
                          avg_latency_us=2787.78036,
                          cnp_count=0.0, ddio_miss_rate=1.0,
                          nic_dram_gbps=56.0, pfc_pause_us=8598.0,
                          completed_messages=256),
    ("25g", "jet"): dict(goodput_gbps=50.0,
                         avg_latency_us=1402.669942153846,
                         cnp_count=0.0, ddio_miss_rate=0.0,
                         nic_dram_gbps=0.0, pfc_pause_us=0.0,
                         completed_messages=448),
}


@pytest.mark.parametrize("bed,mode", sorted(_GOLD))
def test_extraction_bit_equal_to_pre_refactor(bed, mode):
    mk = S.testbed_100g if bed == "100g" else S.testbed_25g
    r = S.run_sim(mk(mode, msg_bytes=256 << 10, sim_time_s=0.02))
    for key, want in _GOLD[(bed, mode)].items():
        assert getattr(r, key) == want, (bed, mode, key)


def test_extraction_bit_equal_escape_pressure_corner():
    """The full escape ladder (replace + ECN rungs) under a shrunken pool
    must reproduce the pre-refactor trajectory exactly."""
    r = S.run_sim(S.testbed_100g("jet", msg_bytes=256 << 10,
                                 sim_time_s=0.05, jet_pool_bytes=2 << 20,
                                 straggler_frac=0.3,
                                 straggler_mult=100.0))
    assert r.goodput_gbps == 1.1592876095847413
    assert r.escape_replaces == 5908
    assert r.escape_ecn == 102
    assert r.cnp_count == 103.0
    assert r.pool_peak_bytes == 2096875


# --------------------------------------------------------------------------- #
# AdmissionQueues: the shared QoS pump
# --------------------------------------------------------------------------- #
def test_pump_priority_and_fifo_order():
    q = AdmissionQueues()
    q.push("n0", QoS.NORMAL)
    q.push("h0", QoS.HIGH)
    q.push("l0", QoS.LOW)
    q.push("h1", QoS.HIGH)
    assert q.pump(lambda item: Admit.OK) == ["h0", "h1", "n0", "l0"]
    assert len(q) == 0


def test_pump_defer_blocks_only_its_class():
    """A deferred NORMAL head must not stop LOW from being probed (a
    small LOW transfer may fit where a big NORMAL one did not)."""
    q = AdmissionQueues()
    q.push("big_n", QoS.NORMAL)
    q.push("small_l", QoS.LOW)
    out = q.pump(lambda item: Admit.DEFER if item == "big_n" else Admit.OK)
    assert out == ["small_l"]
    assert q.depth(QoS.NORMAL) == 1        # still queued, not dropped


def test_pump_low_falls_back_instead_of_waiting():
    q = AdmissionQueues()
    q.push("l0", QoS.LOW)
    q.push("l1", QoS.LOW)
    spilled = []
    out = q.pump(lambda item: Admit.DEFER, fallback=spilled.append)
    assert out == [] and spilled == ["l0", "l1"]
    assert len(q) == 0


def test_pump_stop_ends_everything():
    q = AdmissionQueues()
    q.push("h0", QoS.HIGH)
    q.push("l0", QoS.LOW)
    assert q.pump(lambda item: Admit.STOP) == []
    assert len(q) == 2


def test_expected_footprint_capped_by_size():
    assert expected_footprint(1000, 200.0) <= 1000
    assert expected_footprint(1 << 30, 1e-9) <= 1 << 30


# --------------------------------------------------------------------------- #
# HostDatapath: QoS-classed fluid tick
# --------------------------------------------------------------------------- #
def test_admit_link_priority_space_allocation():
    c = S.testbed_100g("jet", rnic_buffer_bytes=1000)
    dp = HostDatapath(c, sim_ticks=10)
    total, per, offered = dp.admit_link([600.0, 600.0, 600.0])
    assert per == [600.0, 400.0, 0.0]      # HIGH first, LOW starved
    assert total == 1000.0
    assert offered == 1800.0
    assert dp.rnic_q == 1000.0


def test_low_qos_spills_to_dram_under_pool_pressure():
    c = S.testbed_100g("jet", jet_pool_bytes=1 << 20)
    dp = HostDatapath(c, sim_ticks=100)
    dp.resident = 0.9 * dp.pool_cap        # past the cache_safe watermark
    dp.admit_link([0.0, 0.0, 50_000.0])
    fb = dp.step(0, c.cpu_membw_gbps)
    assert fb.fallback == pytest.approx(50_000.0)
    assert dp.mem_fallback_bytes == pytest.approx(50_000.0)
    # spilled bytes are goodput (they reached DRAM buffers), but they
    # never took pool residency
    assert fb.drained == pytest.approx(50_000.0)
    assert fb.pool_drained == 0.0


def test_normal_qos_takes_pool_residency_when_safe():
    c = S.testbed_100g("jet", jet_pool_bytes=1 << 20)
    dp = HostDatapath(c, sim_ticks=100)
    dp.admit_link(50_000.0)                # plain float = NORMAL class
    fb = dp.step(0, c.cpu_membw_gbps)
    assert fb.pool_drained == pytest.approx(50_000.0)
    assert fb.fallback == 0.0
    assert dp.resident == pytest.approx(50_000.0)


def test_datapath_horizon_guard():
    c = S.testbed_100g("jet")
    dp = HostDatapath(c, sim_ticks=1, dt_us=1e6)   # horizon of 2 ticks
    dp.step(0, 0.0)
    with pytest.raises(RuntimeError):
        dp.step(dp.horizon, 0.0)


# --------------------------------------------------------------------------- #
# JetService QoS admission under network backpressure (PFC pause)
# --------------------------------------------------------------------------- #
def _jet(**kw):
    jet = JetService(JetConfig(**kw))
    for q in QoS:
        jet.register(int(q), q)
    return jet


def test_jet_admission_stalls_under_pfc_pause():
    jet = _jet(pool_bytes=4 << 20)
    ids = [jet.request(int(q), 64 << 10, 0.0) for q in QoS]
    jet.set_backpressure(True)             # receiver asserted PFC pause
    assert jet.pump(0.0) == []
    assert jet.queue_depth() == 3          # nothing admitted, nothing lost
    assert jet.stats()["network_paused"]
    # LOW must NOT fall back to DRAM while paused: arrivals are stalled
    # on the wire, there is nothing to buffer yet
    assert jet.memory_fallbacks == 0
    jet.set_backpressure(False)            # xon: admission resumes
    admitted = jet.pump(1.0)
    assert [t.xfer_id for t in admitted] == ids   # priority order intact
    assert jet.queue_depth() == 0


def test_jet_qos_priority_and_low_fallback_under_pool_pressure():
    jet = _jet(pool_bytes=256 << 10, expected_timespan_us=1e5)
    hi = jet.request(int(QoS.HIGH), 128 << 10, 0.0)
    jet.request(int(QoS.NORMAL), 512 << 10, 0.0)    # too big for the pool
    jet.request(int(QoS.LOW), 512 << 10, 0.0)       # too big -> DRAM (§5)
    admitted = jet.pump(0.0)
    assert [t.xfer_id for t in admitted] == [hi]
    assert jet.memory_fallbacks == 1       # LOW spilled, NORMAL waits
    assert jet.queue_depth(QoS.NORMAL) == 1
    assert jet.queue_depth(QoS.LOW) == 0
    st = jet.stats()
    assert st["queued_by_qos"]["NORMAL"] == 1


def test_jet_stats_surface_queue_depths():
    jet = _jet()
    jet.request(int(QoS.HIGH), 64 << 10, 0.0)
    jet.request(int(QoS.LOW), 64 << 10, 0.0)
    st = jet.stats()
    assert st["queued"] == 2
    assert st["queued_by_qos"] == {"HIGH": 1, "NORMAL": 0, "LOW": 1}
    assert N_QOS == 3
