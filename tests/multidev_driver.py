"""Multi-device checks, run in a subprocess with 8 host CPU devices.
Each check prints PASS/FAIL; exits nonzero on any failure."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, ShapeConfig, tiny_config
from repro.launch.mesh import ctx_for_mesh
from repro.models import api
from repro.models.moe import moe_dense_ref, moe_ep, moe_init
from repro.optim import adamw
from repro.parallel import collectives as coll
from repro.parallel.compat import shard_map
from repro.parallel.compression import compressed_psum, dequantize_int8, \
    quantize_int8
from repro.parallel.sharding import single_device_ctx
from repro.train import steps as steps_mod

FAILED = []


def check(name):
    def deco(fn):
        try:
            fn()
            print(f"PASS {name}", flush=True)
        except Exception:
            FAILED.append(name)
            print(f"FAIL {name}", flush=True)
            traceback.print_exc()
    return deco


MESH = jax.make_mesh((4, 2), ("data", "model"))
MESH8 = jax.make_mesh((2, 4), ("data", "model"))


@check("moe_ep_equals_dense_ref")
def _():
    """EP shard_map MoE == dense oracle when capacity is ample."""
    cfg = dataclasses.replace(tiny_config(ARCHS["llama4-scout-17b-a16e"]),
                              num_experts=4)
    key = jax.random.key(0)
    params = moe_init(key, cfg)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    ctx = ctx_for_mesh(MESH8, moe_capacity_factor=16.0, fsdp=False)
    with MESH8:
        y_ep, aux_ep = jax.jit(lambda p, xx: moe_ep(p, xx, cfg, ctx))(
            params, x)
    y_ref, aux_ref = moe_dense_ref(params, x, cfg, cap_factor=16.0)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    assert float(aux_ep["overflow"]) == 0.0


@check("moe_ep_jet_staged_matches_dense_ref")
def _():
    """RDCA staged expert FFN (ppermute ring) == dense oracle."""
    cfg = dataclasses.replace(tiny_config(ARCHS["llama4-scout-17b-a16e"]),
                              num_experts=4)
    params = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    ctx = ctx_for_mesh(MESH, moe_capacity_factor=16.0, fsdp=True,
                       jet_collectives=True)
    with MESH:
        y, aux = jax.jit(lambda p, xx: moe_ep(p, xx, cfg, ctx))(params, x)
    y_ref, _ = moe_dense_ref(params, x, cfg, cap_factor=16.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)


@check("accum_microbatching_matches_full_batch")
def _():
    """accum=4 grad accumulation == single full-batch step (same data)."""
    cfg = dataclasses.replace(tiny_config(ARCHS["gemma-7b"]), num_layers=2)
    opt_cfg = adamw.OptConfig(lr=1e-3)
    key = jax.random.key(0)
    shape = ShapeConfig("t", "train", 16, 8)
    batch = api.synthetic_inputs(cfg, shape, key, dtype=jnp.float32)
    ctx = ctx_for_mesh(MESH8)
    with MESH8:
        s1, m1 = jax.jit(steps_mod.make_train_step(
            cfg, ctx, opt_cfg, jnp.float32))(
            steps_mod.init_state(cfg, opt_cfg, key), batch)
        micro = {k: v.reshape((4, 2) + v.shape[1:])
                 for k, v in batch.items()}
        s2, m2 = jax.jit(steps_mod.make_train_step(
            cfg, ctx, opt_cfg, jnp.float32, accum_steps=4))(
            steps_mod.init_state(cfg, opt_cfg, key), micro)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


@check("moe_ep_capacity_escape")
def _():
    """Tokens above capacity take the escape path (zero update, counted)."""
    cfg = dataclasses.replace(tiny_config(ARCHS["llama4-scout-17b-a16e"]),
                              num_experts=4)
    params = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model))
    ctx = ctx_for_mesh(MESH8, moe_capacity_factor=0.3, fsdp=False)
    with MESH8:
        _, aux = jax.jit(lambda p, xx: moe_ep(p, xx, cfg, ctx))(params, x)
    assert float(aux["overflow"]) > 0.0


@check("ring_allgather_matmul")
def _():
    m = 8
    mesh = jax.make_mesh((m,), ("model",))
    x = jax.random.normal(jax.random.key(0), (16, 64))
    w = jax.random.normal(jax.random.key(1), (64, 32))
    want = x @ w

    def body(x_blk, w_blk):
        return coll.ring_allgather_matmul(x_blk, w_blk, "model", m,
                                          frags=2)
    got = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P(), P("model", None)),
        out_specs=P(), check_vma=False))(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@check("ring_reduce_scatter")
def _():
    m = 8
    mesh = jax.make_mesh((m,), ("model",))
    y = jax.random.normal(jax.random.key(0), (m, 16, 64))  # per-rank partials

    def body(y_blk):
        return coll.ring_reduce_scatter(y_blk[0], "model", m)
    got = jax.jit(shard_map(body, mesh=mesh,
                                in_specs=(P("model", None, None),),
                                out_specs=P("model"),
                                check_vma=False))(y)
    # rank r's shard is columns [r*8, (r+1)*8) of the full sum; stacking
    # along axis 0 per out_specs groups rows by rank
    want = np.asarray(y.sum(axis=0))
    want_stack = np.concatenate([want[:, r * 8:(r + 1) * 8]
                                 for r in range(m)], axis=0)
    np.testing.assert_allclose(np.asarray(got), want_stack,
                               rtol=1e-4, atol=1e-4)


@check("windowed_allgather")
def _():
    m = 8
    mesh = jax.make_mesh((m,), ("model",))
    x = jax.random.normal(jax.random.key(0), (64, 8))

    def body(x_blk):
        return coll.windowed_allgather(x_blk, "model", m, window=4)
    got = jax.jit(shard_map(body, mesh=mesh,
                                in_specs=(P("model", None),),
                                out_specs=P(None, None) if False else P(),
                                check_vma=False))(x)
    # every rank assembles the full tensor; out_specs=P() takes rank 0's
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), rtol=1e-5,
                               atol=1e-5)


@check("srq_combine_distributed_decode")
def _():
    from repro.kernels import ref as kref
    m = 4
    mesh = jax.make_mesh((m,), ("model",))
    b, h, d, s = 2, 2, 8, 32
    q = jax.random.normal(jax.random.key(0), (b, h, d))
    k = jax.random.normal(jax.random.key(1), (b, s, h, d))
    v = jax.random.normal(jax.random.key(2), (b, s, h, d))
    want, _ = kref.decode_attention_naive(q, k, v,
                                          jnp.full((b,), s, jnp.int32))

    def body(q_full, k_blk, v_blk):
        o, lse = kref.decode_attention_naive(
            q_full, k_blk, v_blk,
            jnp.full((q_full.shape[0],), k_blk.shape[1], jnp.int32))
        return coll.srq_combine(o, lse, "model")
    got = jax.jit(shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(None, "model", None, None),
                  P(None, "model", None, None)),
        out_specs=P(), check_vma=False))(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@check("gpipe_two_stage_matches_sequential")
def _():
    """2-stage GPipe over a 'pod' axis == sequential layer stack, for both
    the forward values and the parameter gradients."""
    from repro.parallel import pipeline as pp
    s, layers_per, d, m_micro, b = 2, 3, 16, 4, 8
    mesh = jax.make_mesh((s,), ("pod",))
    key = jax.random.key(0)
    w = jax.random.normal(key, (s * layers_per, d, d)) * (d ** -0.5)
    x = jax.random.normal(jax.random.key(1), (m_micro, b, d))

    def seq_apply(w_all, xm):
        def layer(h, wi):
            return jnp.tanh(h @ wi), None
        out, _ = jax.lax.scan(layer, xm.reshape(-1, d), w_all)
        return out.reshape(xm.shape)

    def piped(w_all, x_micro):
        w_stages = pp.stack_stages(w_all, s)          # [S, L/S, d, d]

        def body(w_stage, xm):
            def stage_fn(h):
                def layer(hh, wi):
                    return jnp.tanh(hh @ wi), None
                out, _ = jax.lax.scan(layer, h.reshape(-1, d), w_stage[0])
                return out.reshape(h.shape)
            y = pp.gpipe(stage_fn, xm, "pod", s)
            return pp.broadcast_from_last(y, "pod", s)
        from jax.sharding import PartitionSpec as P
        return shard_map(body, mesh=mesh,
                             in_specs=(P("pod"), P()), out_specs=P(),
                             check_vma=False)(w_stages, x_micro)

    want = jax.vmap(lambda xm: seq_apply(w, xm))(x)
    got = jax.jit(piped)(w, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    # gradients flow through the ppermute schedule
    g_seq = jax.grad(lambda ww: jax.vmap(
        lambda xm: seq_apply(ww, xm))(x).sum())(w)
    g_pipe = jax.grad(lambda ww: piped(ww, x).sum())(w)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               rtol=5e-4, atol=5e-4)


@check("compressed_psum_error_feedback")
def _():
    m = 4
    mesh = jax.make_mesh((m,), ("pod",))
    g = jax.random.normal(jax.random.key(0), (m, 512))

    def body(g_blk, err):
        mean, new_err = compressed_psum(g_blk[0], err[0], "pod")
        return mean, new_err[None]
    mean, err = jax.jit(shard_map(
        body, mesh=mesh, in_specs=(P("pod", None), P("pod", None)),
        out_specs=(P(), P("pod", None)), check_vma=False))(
        g, jnp.zeros_like(g))
    want = np.asarray(g).mean(axis=0)
    got = np.asarray(mean)
    # int8 quantization error is bounded by scale/2 per block
    assert np.abs(got - want).max() < np.abs(g).max() / 127 + 1e-3
    # error feedback: residual equals what was lost
    q, s = quantize_int8(g[0] + 0)
    assert np.isfinite(np.asarray(err)).all()


@check("compressed_pod_grads_train_step")
def _():
    """Hierarchical int8+EF cross-pod grad sync: one step stays close to
    the exact (uncompressed) step; the EF residual is populated."""
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = dataclasses.replace(tiny_config(ARCHS["chatglm3-6b"]),
                              num_layers=2)
    key = jax.random.key(0)
    shape = ShapeConfig("t", "train", 16, 4)
    batch = api.synthetic_inputs(cfg, shape, key, dtype=jnp.float32)
    from repro.launch.mesh import ctx_for_mesh as cfm
    ctx = cfm(mesh3)
    assert ctx.data_axes == ("pod", "data")

    exact_cfg = adamw.OptConfig(lr=1e-3)
    comp_cfg = adamw.OptConfig(lr=1e-3, compressed_pod_grads=True)
    with mesh3:
        s1, m1 = jax.jit(steps_mod.make_train_step(
            cfg, ctx, exact_cfg, jnp.float32))(
            steps_mod.init_state(cfg, exact_cfg, key), batch)
        s2, m2 = jax.jit(steps_mod.make_train_step(
            cfg, ctx, comp_cfg, jnp.float32))(
            steps_mod.init_state(cfg, comp_cfg, key), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    # int8 quantization error is bounded; params stay close after 1 step
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0.1, atol=2e-3)
    # error feedback captured the quantization residual
    err_mag = max(float(jnp.abs(e).max())
                  for e in jax.tree.leaves(s2["err"]))
    assert np.isfinite(err_mag)


@check("distributed_train_step_matches_single_device")
def _():
    cfg = tiny_config(ARCHS["chatglm3-6b"])
    cfg = dataclasses.replace(cfg, num_layers=2)
    opt_cfg = adamw.OptConfig(lr=1e-3)
    key = jax.random.key(0)
    shape = ShapeConfig("t", "train", 16, 4)
    batch = api.synthetic_inputs(cfg, shape, key, dtype=jnp.float32)

    # single device
    ctx1 = single_device_ctx()
    state1 = steps_mod.init_state(cfg, opt_cfg, key)
    step1 = jax.jit(steps_mod.make_train_step(cfg, ctx1, opt_cfg,
                                              jnp.float32))
    s1, m1 = step1(state1, batch)

    # 4x2 mesh
    ctx2 = ctx_for_mesh(MESH)
    state2 = steps_mod.init_state(cfg, opt_cfg, key)
    with MESH:
        step2 = jax.jit(steps_mod.make_train_step(cfg, ctx2, opt_cfg,
                                                  jnp.float32))
        s2, m2 = step2(state2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    # parameters after one step agree
    l1 = jax.tree.leaves(s1["params"])
    l2 = jax.tree.leaves(s2["params"])
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-3)


@check("moe_arch_distributed_train_step")
def _():
    cfg = tiny_config(ARCHS["llama4-scout-17b-a16e"])
    cfg = dataclasses.replace(cfg, num_layers=2)
    opt_cfg = adamw.OptConfig(lr=1e-3)
    key = jax.random.key(0)
    shape = ShapeConfig("t", "train", 16, 4)
    batch = api.synthetic_inputs(cfg, shape, key, dtype=jnp.float32)
    ctx = ctx_for_mesh(MESH, moe_capacity_factor=8.0)
    state = steps_mod.init_state(cfg, opt_cfg, key)
    with MESH:
        step = jax.jit(steps_mod.make_train_step(cfg, ctx, opt_cfg,
                                                 jnp.float32))
        s, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))


@check("elastic_reshard_roundtrip")
def _():
    import tempfile
    from repro.checkpoint import ckpt
    cfg = dataclasses.replace(tiny_config(ARCHS["gemma-7b"]), num_layers=2)
    opt_cfg = adamw.OptConfig()
    state = steps_mod.init_state(cfg, opt_cfg, jax.random.key(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(state, d, step=7, extra={"step": 7})
        # restore onto a 2x4 mesh with shardings (elastic: 1 dev -> 8 dev)
        ctx = ctx_for_mesh(MESH8)
        like = steps_mod.abstract_state(cfg, opt_cfg)
        specs = steps_mod.state_specs(like, ctx)
        shardings = jax.tree.map(
            lambda s: ctx.sharding(s),
            specs, is_leaf=lambda x: isinstance(x, P))
        with MESH8:
            restored, extra = ckpt.restore(d, like, shardings=shardings)
        assert extra["step"] == 7
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))


print(f"{len(FAILED)} failures: {FAILED}", flush=True)
raise SystemExit(1 if FAILED else 0)
