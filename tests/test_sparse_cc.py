"""Sparse-incidence engine x CC zoo equivalence (ISSUE 10 satellite).

The CC zoo (per-flow DCQCN / Timely / HPCC selection) is per-flow state
plus per-port telemetry; porting it to the segmented-incidence layout
must not change a single result.  On a 2-tier grid the sparse engine
visits route legs in the same tier order as the dense engine's leg
loop, so even the order-sensitive f32 telemetry sums agree bit-for-bit
in f64 and to float32 round-off under jax.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.fabric import scenarios as SC
from repro.fabric.cc import CcConfig
from repro.fabric.vector import FabricSweepParams, run_fabric_sweep

_METRICS = ("flow_goodput_gbps", "flow_completion_us",
            "incast_completion_us", "ecn_marked_bytes",
            "pause_total_us", "recv_cnp_count")


def _cc_mixed_grid():
    """2-tier incast grid racing the CC zoo: algo x PFC per point."""
    scens = []
    for algo in ("dcqcn", "timely", "hpcc"):
        for pfc in (False, True):
            sc = SC.incast(n_senders=4, mode="ddio", pfc=pfc,
                           burst_mb=0.5, sim_time_s=0.001)
            sc.fabric.cc = CcConfig(algo=algo)
            scens.append(sc)
    return scens


def test_sparse_accepts_cc():
    # the NotImplementedError rejection is lifted: packing a CC grid
    # sparse must succeed and carry the cc capability flag
    fsp = FabricSweepParams.from_scenarios(_cc_mixed_grid(),
                                           sparse=True)
    assert fsp.sparse and fsp.any_cc


def test_sparse_cc_bit_equal_dense_numpy():
    scens = _cc_mixed_grid()
    dense = run_fabric_sweep(scens, backend="numpy",
                             incidence="dense")
    sparse = run_fabric_sweep(scens, backend="numpy",
                              incidence="sparse")
    for k in _METRICS:
        assert np.array_equal(np.asarray(dense[k]),
                              np.asarray(sparse[k]),
                              equal_nan=True), k


def test_sparse_cc_matches_dense_jax():
    scens = _cc_mixed_grid()
    dense = run_fabric_sweep(scens, backend="jax", incidence="dense")
    sparse = run_fabric_sweep(scens, backend="jax",
                              incidence="sparse")
    for k in _METRICS:
        a = np.asarray(dense[k], np.float64)
        b = np.asarray(sparse[k], np.float64)
        fin = np.isfinite(a) & np.isfinite(b)
        assert np.array_equal(np.isfinite(a), np.isfinite(b)), k
        dev = np.max(np.abs(a[fin] - b[fin])
                     / np.maximum(np.abs(a[fin]), 1.0)) \
            if fin.any() else 0.0
        assert dev <= 5e-4, f"{k}: rel dev {dev:.2e}"


@pytest.mark.parametrize("point", [1, 2, 4])   # dcqcn+pfc, timely, hpcc
def test_sparse_cc_matches_scalar_golden(point):
    scens = _cc_mixed_grid()
    sparse = run_fabric_sweep(scens, backend="numpy",
                              incidence="sparse")
    ref = scens[point].run()
    want = np.array([ref.flow_goodput_gbps[f]
                     for f in range(len(scens[point].flows))])
    np.testing.assert_allclose(
        np.asarray(sparse["flow_goodput_gbps"][point]), want,
        rtol=1e-9)


def test_sparse_cc_with_default_flows():
    # points without an explicit CcConfig (legacy DCQCN receiver path)
    # mixed into a CC grid: the forced any_cc flag must leave them on
    # the default algorithm in both layouts
    scens = _cc_mixed_grid()[:2]
    plain = SC.incast(n_senders=4, mode="ddio", pfc=False,
                      burst_mb=0.5, sim_time_s=0.001)
    scens.append(plain)
    dense = run_fabric_sweep(scens, backend="numpy",
                             incidence="dense")
    sparse = run_fabric_sweep(scens, backend="numpy",
                              incidence="sparse")
    for k in _METRICS:
        assert np.array_equal(np.asarray(dense[k]),
                              np.asarray(sparse[k]),
                              equal_nan=True), k
