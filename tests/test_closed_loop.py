"""The closed host/network loop (ISSUE 3 acceptance): receiver-side
pool pressure must feed back into fabric-level congestion control —
shrinking one receiver's cache pool throttles *its senders'* DCQCN rates
and shifts fleet incast FCT — with the vector engines matching the
scalar driver within the PR 2-style bounds (numpy ~1e-13 relative,
jax/f32 <= ~5e-4) on incast-8 grids.  Also covers the two new fabric
knobs: QoS-classed flows and configurable CNP propagation delay."""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.datapath import QoS
from repro.fabric import scenarios as SC
from repro.fabric.scenarios import mixed_fleet_grid
from repro.fabric.vector import run_fabric_sweep

SIM_S = 0.015
JET_RX = 0                  # recv index of "h1_0" in sorted recv hosts


def _flow_goodput(res, n_flows):
    return np.array([[r.flow_goodput_gbps[f] for f in range(n_flows)]
                     for r in res])


def _maxrel(a, b):
    return float(np.max(np.abs(a - b) / np.maximum(np.abs(b), 1e-9)))


@pytest.fixture(scope="module")
def pool_sweep():
    """Jet pool size swept down on the incast receiver of a mixed
    Jet+DDIO fleet; scalar reference + both vector backends."""
    scens, pts = mixed_fleet_grid(pool_mb=(2.0, 1.0, 0.5),
                                  burst_mb=(2.0,), sim_time_s=SIM_S)
    scalar = [sc.run() for sc in scens]
    out_np = run_fabric_sweep(scens, backend="numpy")
    out_jx = run_fabric_sweep(scens, backend="jax")
    return scens, pts, scalar, out_np, out_jx


@pytest.mark.slow
def test_pool_shrink_throttles_senders(pool_sweep):
    """The loop itself: less pool -> more escape-ladder ECN -> CNPs cut
    the incast senders -> lower receiver goodput, longer incast FCT."""
    scens, pts, scalar, out, _ = pool_sweep
    pools = [pt["pool_mb"] for pt in pts]
    assert pools == sorted(pools, reverse=True)       # big -> small
    # escape-ladder ECN pressure grows monotonically as the pool shrinks
    ecn = out["recv_escape_ecn"][:, JET_RX]
    assert all(a <= b for a, b in zip(ecn, ecn[1:]))
    assert ecn[-1] > 0                                # ladder engaged
    # ...which measurably reduces the incast senders' achieved DCQCN
    # rates (receiver goodput is their sum)
    g = out["recv_goodput_gbps"][:, JET_RX]
    assert all(a > b for a, b in zip(g, g[1:])), g
    # ...and stretches fleet incast FCT (an unfinished burst, NaN from
    # the sweep / inf from the scalar driver, orders after any finite
    # completion)
    fct = [x if np.isfinite(x) else math.inf
           for x in out["incast_completion_us"]]
    assert all(a <= b for a, b in zip(fct, fct[1:])), fct
    assert np.isfinite(out["incast_completion_us"][0])
    assert fct[-1] == math.inf                        # starved burst
    # the scalar driver tells the same story through per-host results
    sc_ecn = [r.per_host["h1_0"].escape_ecn for r in scalar]
    assert all(a <= b for a, b in zip(sc_ecn, sc_ecn[1:]))
    assert sc_ecn[-1] > 0


@pytest.mark.slow
def test_pool_sweep_vector_matches_scalar(pool_sweep):
    """PR 2-style acceptance bounds on the closed-loop incast-8 grid."""
    scens, _, scalar, out_np, out_jx = pool_sweep
    F = len(scens[0].flows)
    gp = _flow_goodput(scalar, F)
    assert _maxrel(out_np["flow_goodput_gbps"], gp) < 1e-9
    assert _maxrel(out_jx["flow_goodput_gbps"], gp) <= 5e-4
    for r, e_np, e_jx in zip(scalar, out_np["recv_escape_ecn"],
                             out_jx["recv_escape_ecn"]):
        assert e_np[JET_RX] == r.per_host["h1_0"].escape_ecn
        assert e_jx[JET_RX] == r.per_host["h1_0"].escape_ecn
    # LOW-QoS DRAM spill accounting agrees too
    for r, m_np in zip(scalar, out_np["recv_mem_fallback_bytes"]):
        assert m_np[JET_RX] == pytest.approx(
            r.per_host["h1_0"].mem_fallback_bytes, rel=1e-9, abs=1e-6)


# --------------------------------------------------------------------------- #
# QoS-classed flows through the fabric
# --------------------------------------------------------------------------- #
def _qos_incast(**kw):
    sc = SC.incast(n_senders=8, mode="jet", pfc=False, burst_mb=1.0,
                   sim_time_s=0.005, **kw)
    for i, f in enumerate(sc.flows):
        f.qos = (QoS.HIGH, QoS.NORMAL, QoS.LOW)[i % 3]
    return sc


@pytest.mark.slow
def test_qos_flows_scalar_matches_vector():
    sc = _qos_incast()
    r = sc.run()
    out = run_fabric_sweep([sc], backend="numpy")
    F = len(sc.flows)
    gp = _flow_goodput([r], F)
    assert _maxrel(out["flow_goodput_gbps"], gp) < 1e-9
    out_jx = run_fabric_sweep([sc], backend="jax")
    assert _maxrel(out_jx["flow_goodput_gbps"], gp) <= 5e-4


def test_qos_grid_requires_matching_classes():
    a, b = _qos_incast(), _qos_incast()
    b.flows[0].qos = QoS.LOW
    from repro.fabric.vector import FabricSweepParams
    with pytest.raises(ValueError):
        FabricSweepParams.from_scenarios([a, b])


# --------------------------------------------------------------------------- #
# CNP propagation delay
# --------------------------------------------------------------------------- #
def _delayed(delay_us):
    sc = SC.incast(n_senders=8, mode="jet", pfc=False, burst_mb=1.0,
                   sim_time_s=0.005)
    sc.fabric = dataclasses.replace(sc.fabric, cnp_delay_us=delay_us)
    return sc


@pytest.mark.slow
@pytest.mark.parametrize("delay_us", [0.0, 20.0])
def test_cnp_delay_scalar_matches_vector(delay_us):
    sc = _delayed(delay_us)
    r = sc.run()
    F = len(sc.flows)
    gp = _flow_goodput([r], F)
    out = run_fabric_sweep([sc], backend="numpy")
    assert _maxrel(out["flow_goodput_gbps"], gp) < 1e-9
    out_jx = run_fabric_sweep([sc], backend="jax")
    assert _maxrel(out_jx["flow_goodput_gbps"], gp) <= 5e-4


def test_cnp_delay_changes_dynamics():
    """A 200 us NP->RP propagation delay must visibly change the control
    loop (senders throttle later), not be silently ignored."""
    r0, r200 = _delayed(0.0).run(), _delayed(200.0).run()
    g0 = sum(r0.flow_goodput_gbps.values())
    g200 = sum(r200.flow_goodput_gbps.values())
    assert g0 != pytest.approx(g200, rel=1e-6)


@pytest.mark.slow
def test_cnp_delay_nonzero_closed_loop():
    """The escape-ladder ECN -> delayed CNP -> DCQCN loop at a nonzero
    propagation delay: scalar pending-heap vs vector delay-ring
    agreement was previously only exercised at delay 0 on closed-loop
    (escape-driven) scenarios."""
    sc = SC.mixed_fleet(pool_mb=0.5, burst_mb=2.0, sim_time_s=0.01)
    sc.fabric = dataclasses.replace(sc.fabric, cnp_delay_us=30.0)
    r = sc.run()
    # the delayed path must actually carry escape CNPs, else this test
    # degenerates to the open-loop delay case
    assert r.per_host["h1_0"].escape_ecn > 0
    F = len(sc.flows)
    gp = _flow_goodput([r], F)
    out_np = run_fabric_sweep([sc], backend="numpy")
    assert _maxrel(out_np["flow_goodput_gbps"], gp) < 1e-9
    assert out_np["recv_escape_ecn"][0, JET_RX] == \
        r.per_host["h1_0"].escape_ecn
    out_jx = run_fabric_sweep([sc], backend="jax")
    assert _maxrel(out_jx["flow_goodput_gbps"], gp) <= 5e-4


# --------------------------------------------------------------------------- #
# per-flow CNP delay (Flow.cnp_delay_us overrides FabricConfig)
# --------------------------------------------------------------------------- #
@pytest.mark.slow
def test_per_flow_cnp_delay_overrides_config():
    """Flows carry their own NP->RP delay: a mixed-delay fleet must
    differ from every uniform-delay fleet and agree across engines."""
    def mixed():
        sc = _delayed(40.0)                  # config-level fallback: 40us
        for i, f in enumerate(sc.flows):
            if i % 2 == 0:
                f.cnp_delay_us = 0.0         # half the flows override to 0
        return sc

    r = mixed().run()
    F = len(mixed().flows)
    gp = _flow_goodput([r], F)
    # differs from both uniform delays: the override is per flow, not
    # per config
    for uniform in (0.0, 40.0):
        gu = _flow_goodput([_delayed(uniform).run()], F)
        assert np.abs(gp - gu).max() > 1e-6
    out_np = run_fabric_sweep([mixed()], backend="numpy")
    assert _maxrel(out_np["flow_goodput_gbps"], gp) < 1e-9
    out_jx = run_fabric_sweep([mixed()], backend="jax")
    assert _maxrel(out_jx["flow_goodput_gbps"], gp) <= 5e-4
