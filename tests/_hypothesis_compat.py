"""Fallback shim so property tests collect without ``hypothesis``.

When hypothesis is installed (the recommended setup — see requirements.txt
test extras) this module re-exports the real ``given``/``settings``/``st``.
Otherwise it provides a miniature deterministic stand-in: ``@given`` draws a
fixed number of pseudo-random examples (seeded RNG, so failures reproduce)
from a tiny strategy algebra covering exactly what this repo's tests use —
``st.integers``, ``st.booleans``, ``st.lists``, ``st.tuples``.

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng: random.Random):
            return self._draw(rng)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def tuples(*strats):
            return _Strategy(
                lambda rng: tuple(s.example(rng) for s in strats))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.example(rng) for _ in range(n)]
            return _Strategy(draw)

    st = _St()

    def given(*gstrats, **gkwargs):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(_FALLBACK_EXAMPLES):
                    ex_args = tuple(s.example(rng) for s in gstrats)
                    ex_kw = {k: s.example(rng) for k, s in gkwargs.items()}
                    fn(*args, *ex_args, **kwargs, **ex_kw)
            # hide the example parameters from pytest's fixture resolution
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    def settings(*_a, **_k):      # accepts and ignores all hypothesis knobs
        def deco(fn):
            return fn
        return deco
